//! Regenerates paper Table 3: dataset summary statistics — dimensions,
//! sparsity, Shotgun's P*, coloring size/time, the chosen lambda, and
//! the best objective/NNZ found.
//!
//!     cargo bench --bench table3_datasets

fn main() {
    gencd::bench_harness::experiments::print_table3();
}
