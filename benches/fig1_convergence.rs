//! Regenerates paper Figure 1: convergence (objective + NNZ vs time)
//! for SHOTGUN / THREAD-GREEDY / GREEDY / COLORING on the DOROTHEA and
//! REUTERS twins with the paper's lambdas and the Sec. 4.1 line search.
//!
//!     cargo bench --bench fig1_convergence
//!
//! Env: GENCD_BENCH_SCALE (default 0.1), GENCD_BENCH_SECONDS (per run).
//! Expected shape (paper Sec. 5.1): SHOTGUN/COLORING overshoot NNZ early
//! on DOROTHEA then recover; GREEDY adds NNZ slowly; THREAD-GREEDY
//! stabilizes fastest; COLORING ~ SHOTGUN throughout.

fn main() {
    gencd::bench_harness::experiments::print_fig1(Some("target/fig1_csv"));
    println!("(per-run history CSVs in target/fig1_csv/)");
}
