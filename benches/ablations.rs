//! Ablations over the design choices DESIGN.md calls out:
//!
//!  1. line-search depth (Sec. 4.1's 500 steps vs cheaper settings)
//!  2. accept policy: THREAD-GREEDY vs the §7 TopK extension
//!  3. coloring strategy: greedy vs balanced (§7's open question)
//!  4. gradient path: cached dloss vs on-the-fly (engine heuristic)
//!  5. SHOTGUN selection size: P*/2, P*, 2 P* (the divergence cliff)
//!  6. Update-phase z discipline: auto / atomic CAS / buffered (engine
//!     heuristic, §Perf)
//!
//!     cargo bench --bench ablations

use gencd::bench_harness::{bench_budget, bench_config, bench_scale, Table};
use gencd::coloring::{color_features, Strategy};
use gencd::coordinator::driver::run_on;
use gencd::coordinator::Algorithm;
use gencd::data;

fn main() {
    let scale = bench_scale();
    let ds_name = format!("dorothea@{scale}");
    let lam = data::dorothea::PAPER_LAMBDA;
    let ds = data::by_name(&ds_name).expect("dataset");
    println!(
        "# Ablations on {ds_name} (lambda {lam:.0e}, {}s/run)\n",
        bench_budget()
    );

    // ---- 1. line-search depth --------------------------------------------
    println!("## line-search steps (Sec. 4.1; paper uses 500)\n");
    let mut t = Table::new(&["steps", "objective", "nnz", "updates", "upd/s"]);
    for steps in [0usize, 5, 20, 100, 500] {
        let mut cfg = bench_config(&ds_name, lam, Algorithm::ThreadGreedy);
        cfg.solver.line_search_steps = steps;
        let r = run_on(&cfg, ds.clone(), None).expect("run");
        t.row(vec![
            steps.to_string(),
            format!("{:.6}", r.objective),
            r.nnz.to_string(),
            r.metrics.updates.to_string(),
            format!("{:.2e}", r.metrics.updates_per_sec(r.elapsed_secs)),
        ]);
    }
    println!("{}", t.render());

    // ---- 2. accept policy ---------------------------------------------------
    println!("## accept policy: thread-greedy vs global TopK (§7 extension)\n");
    let mut t = Table::new(&["policy", "objective", "nnz", "updates"]);
    for (name, alg) in [
        ("thread-greedy", Algorithm::ThreadGreedy),
        ("topk (global)", Algorithm::TopK),
    ] {
        let mut cfg = bench_config(&ds_name, lam, alg);
        cfg.solver.line_search_steps = 20;
        let r = run_on(&cfg, ds.clone(), None).expect("run");
        t.row(vec![
            name.into(),
            format!("{:.6}", r.objective),
            r.nnz.to_string(),
            r.metrics.updates.to_string(),
        ]);
    }
    println!("{}", t.render());

    // ---- 3. coloring strategy -------------------------------------------------
    println!("## coloring strategy (paper §7: balance vs fewer colors)\n");
    let mut t = Table::new(&["strategy", "colors", "feat/color", "imbalance", "secs"]);
    let mut normalized = ds.clone();
    normalized.x.normalize_columns();
    for strategy in [
        Strategy::Greedy,
        Strategy::GreedyRandomOrder,
        Strategy::LargestFirst,
        Strategy::Balanced,
    ] {
        let c = color_features(&normalized.x, strategy, 42);
        t.row(vec![
            strategy.name().into(),
            c.n_colors().to_string(),
            format!("{:.1}", c.mean_class_size()),
            format!("{:.2}", c.imbalance()),
            format!("{:.3}", c.elapsed_secs),
        ]);
    }
    println!("{}", t.render());

    // ---- 4. gradient path --------------------------------------------------------
    println!("## gradient path: cached dloss vs on-the-fly ell'\n");
    let mut t = Table::new(&["path", "objective", "updates", "upd/s"]);
    for (name, force) in [
        ("heuristic", None),
        ("always dloss", Some(true)),
        ("always on-the-fly", Some(false)),
    ] {
        // go through the engine directly to force the path
        let r = shotgun_engine_run(&ds, &ds_name, lam, force, None);
        t.row(vec![
            name.into(),
            format!("{:.6}", r.out.objective),
            r.out.metrics.updates.to_string(),
            format!("{:.2e}", r.out.metrics.updates_per_sec(r.out.elapsed_secs)),
        ]);
    }
    println!("{}", t.render());

    // ---- 5. shotgun selection size (divergence cliff) ------------------------------
    println!("## shotgun |J| around P* (Bradley et al. bound)\n");
    let mut cfg = bench_config(&ds_name, lam, Algorithm::Shotgun);
    cfg.solver.threads = 1;
    cfg.solver.max_iters = 200;
    let base = run_on(&cfg, ds.clone(), None).expect("run");
    let pstar = base.pstar.unwrap_or(16);
    let mut t = Table::new(&["|J|", "objective", "stop", "updates"]);
    for mult in [0.5f64, 1.0, 2.0, 8.0] {
        let size = ((pstar as f64 * mult) as usize).max(1);
        let mut cfg = bench_config(&ds_name, lam, Algorithm::Shotgun);
        cfg.solver.select_size = size;
        let r = run_on(&cfg, ds.clone(), None).expect("run");
        t.row(vec![
            format!("{size} ({mult}x P*)"),
            format!("{:.6}", r.objective),
            r.stop.to_string(),
            r.metrics.updates.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("(P* = {pstar} on this twin at scale {scale})");

    // ---- 6. update path (atomic CAS vs buffered scatter/reduce) ---------------
    println!("\n## update path: CAS fetch-add vs buffered scatter+reduce (T=4)\n");
    let mut t = Table::new(&["path", "objective", "updates", "upd/s", "z drift"]);
    for (name, path) in [
        ("auto", gencd::coordinator::engine::UpdatePath::Auto),
        ("atomic", gencd::coordinator::engine::UpdatePath::Atomic),
        ("buffered", gencd::coordinator::engine::UpdatePath::Buffered),
    ] {
        let r = shotgun_engine_run(&ds, &ds_name, lam, None, Some(path));
        t.row(vec![
            name.into(),
            format!("{:.6}", r.out.objective),
            r.out.metrics.updates.to_string(),
            format!("{:.2e}", r.out.metrics.updates_per_sec(r.out.elapsed_secs)),
            format!("{:.1e}", r.state.z_drift(&r.problem)),
        ]);
    }
    println!("{}", t.render());
}

/// Output of [`shotgun_engine_run`]: the solve plus the state/problem
/// pair needed for drift checks.
struct EngineRun {
    out: gencd::coordinator::engine::SolveOutput,
    state: gencd::coordinator::problem::SharedState,
    problem: gencd::coordinator::Problem,
}

/// Direct-engine Shotgun run shared by the forced-path ablations
/// (sections 4 and 6): normalize, preprocess P*, instantiate the preset
/// policy pair, solve.
fn shotgun_engine_run(
    ds: &gencd::sparse::io::Dataset,
    ds_name: &str,
    lam: f64,
    force_dloss: Option<bool>,
    update_path: Option<gencd::coordinator::engine::UpdatePath>,
) -> EngineRun {
    use gencd::coordinator::engine::{solve_from, EngineConfig, EngineHooks};

    let alg = Algorithm::Shotgun;
    let cfg = bench_config(ds_name, lam, alg);
    let mut d = ds.clone();
    if cfg.dataset.normalize {
        d.x.normalize_columns();
    }
    let pre = gencd::coordinator::algorithms::Preprocessed::for_algorithm(
        alg,
        &d.x,
        Strategy::Greedy,
        7,
    );
    let problem = gencd::coordinator::Problem::new(
        d,
        gencd::loss::by_name("logistic").unwrap(),
        lam,
    );
    let inst = gencd::coordinator::algorithms::instantiate(
        alg,
        problem.n_features(),
        cfg.solver.threads,
        0,
        0,
        &pre,
        7,
    )
    .unwrap();
    let ecfg = EngineConfig {
        threads: cfg.solver.threads,
        max_seconds: bench_budget(),
        force_dloss,
        update_path: update_path.unwrap_or(gencd::coordinator::engine::UpdatePath::Auto),
        ..Default::default()
    };
    let state = gencd::coordinator::problem::SharedState::new(
        problem.n_samples(),
        problem.n_features(),
    );
    let out = solve_from(
        &problem,
        &state,
        inst.selector,
        inst.acceptor,
        &ecfg,
        EngineHooks::none(),
    );
    EngineRun {
        out,
        state,
        problem,
    }
}
