//! Hot-path microbenchmarks (§Perf): the operations the solve loop is
//! made of, measured in isolation so regressions are attributable.
//!
//!     cargo bench --bench hotpath
//!
//! Covers: sparse propose (dloss vs on-the-fly), dloss refresh, atomic
//! vs plain z update, line-search refinement, panel gather, and — when
//! artifacts are built — the HLO dense-block propose for comparison.

use std::sync::atomic::Ordering::Relaxed;

use gencd::coordinator::problem::{Problem, SharedState};
use gencd::coordinator::{linesearch, propose};
use gencd::data::{reuters_like, GenOptions};
use gencd::loss::Logistic;
use gencd::util::timer::bench_loop;
use gencd::util::Pcg64;

fn main() {
    let mut ds = reuters_like(&GenOptions::with_scale(0.05));
    ds.x.normalize_columns();
    let n = ds.n_samples();
    let k = ds.n_features();
    let nnz = ds.x.nnz();
    println!("workload: reuters@0.05 ({n} x {k}, {nnz} nnz)\n");
    let problem = Problem::new(ds, Box::new(Logistic), 1e-5);

    let mut rng = Pcg64::seeded(3);
    let w0: Vec<f64> = (0..k)
        .map(|j| if j % 61 == 0 { rng.range_f64(-0.3, 0.3) } else { 0.0 })
        .collect();
    let state = SharedState::from_warm_start(&problem, &w0);
    propose::refresh_dloss(&problem, &state, 0, n);

    let cols: Vec<usize> = (0..256).map(|_| rng.below(k)).collect();
    let col_nnz: usize = cols.iter().map(|&j| problem.x.col_nnz(j)).sum();

    // ---- propose: cached dloss ------------------------------------------
    let s = bench_loop(0.5, 20, || {
        let mut acc = 0.0;
        for &j in &cols {
            acc += propose::propose(&problem, &state, j, true).delta;
        }
        std::hint::black_box(acc);
    });
    println!(
        "propose/dloss      {:>9.1} ns/col ({:.2} ns/nnz)   {s}",
        s.best * 1e9 / cols.len() as f64,
        s.best * 1e9 / col_nnz as f64
    );

    // ---- propose: on-the-fly ell' -----------------------------------------
    let s = bench_loop(0.5, 20, || {
        let mut acc = 0.0;
        for &j in &cols {
            acc += propose::propose(&problem, &state, j, false).delta;
        }
        std::hint::black_box(acc);
    });
    println!(
        "propose/on-the-fly {:>9.1} ns/col ({:.2} ns/nnz)   {s}",
        s.best * 1e9 / cols.len() as f64,
        s.best * 1e9 / col_nnz as f64
    );

    // ---- dloss refresh -----------------------------------------------------
    let s = bench_loop(0.5, 20, || {
        propose::refresh_dloss(&problem, &state, 0, n);
    });
    println!("dloss refresh      {:>9.2} ns/sample          {s}", s.best * 1e9 / n as f64);

    // ---- update: atomic z scatter ------------------------------------------
    let s = bench_loop(0.5, 20, || {
        for &j in &cols {
            let (rows, vals) = problem.x.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                state.z[i as usize].fetch_add(1e-12 * v, Relaxed);
            }
        }
    });
    println!("update/atomic      {:>9.2} ns/nnz             {s}", s.best * 1e9 / col_nnz as f64);

    // ---- update: unsync load+store (T=1 / coloring fast path, §Perf) -------
    let s = bench_loop(0.5, 20, || {
        for &j in &cols {
            let (rows, vals) = problem.x.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                let zi = &state.z[i as usize];
                zi.store(zi.load(Relaxed) + 1e-12 * v, Relaxed);
            }
        }
    });
    println!("update/unsync      {:>9.2} ns/nnz             {s}", s.best * 1e9 / col_nnz as f64);

    // ---- update: single-thread plain scatter (the atomics overhead) --------
    let mut z_plain = state.z_snapshot();
    let s = bench_loop(0.5, 20, || {
        for &j in &cols {
            problem.x.axpy_col(j, 1e-12, &mut z_plain);
        }
        std::hint::black_box(&z_plain);
    });
    println!("update/plain       {:>9.2} ns/nnz             {s}", s.best * 1e9 / col_nnz as f64);

    // ---- line search ---------------------------------------------------------
    for steps in [20usize, 500] {
        let s = bench_loop(0.5, 10, || {
            let mut acc = 0.0;
            for &j in &cols[..32] {
                acc += linesearch::refine(&problem, &state, j, 0.01, steps);
            }
            std::hint::black_box(acc);
        });
        println!(
            "line search s={steps:<4} {:>9.2} us/coord          {s}",
            s.best * 1e6 / 32.0
        );
    }

    // ---- objective evaluation (the logging cost) ------------------------------
    let s = bench_loop(0.5, 10, || {
        let w = state.w_snapshot();
        let z = state.z_snapshot();
        std::hint::black_box(problem.objective(&w, &z));
    });
    println!("objective eval     {:>9.2} us                {s}", s.best * 1e6);

    // ---- HLO dense-block propose (needs artifacts) ------------------------------
    match gencd::runtime::Runtime::from_default_dir() {
        Ok(rt) => match gencd::runtime::HloProposer::new(&rt, &problem) {
            Ok(mut hlo) => {
                let js: Vec<u32> =
                    cols.iter().take(hlo.block_width()).map(|&j| j as u32).collect();
                let s = bench_loop(1.0, 5, || {
                    hlo.run_block(&problem, &state, &js).expect("hlo");
                });
                println!(
                    "propose/hlo-block  {:>9.1} us/col ({} cols/call) {s}",
                    s.best * 1e6 / js.len() as f64,
                    js.len()
                );
            }
            Err(e) => println!("propose/hlo-block  skipped: {e}"),
        },
        Err(e) => println!("propose/hlo-block  skipped: {e}"),
    }
}
