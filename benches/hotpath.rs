//! Hot-path microbenchmarks (§Perf): the operations the solve loop is
//! made of, measured in isolation so regressions are attributable.
//!
//!     cargo bench --bench hotpath
//!
//! Covers: sparse propose (dloss vs on-the-fly), dloss refresh, the
//! three z-update disciplines (atomic CAS, unsync store, plain scatter)
//! single-threaded AND under real multi-thread contention (CAS vs the
//! engine's buffered scatter+reduce vs the cache-blocked slab+drain),
//! phase-barrier crossings (std mutex
//! barrier vs the spin barrier), the event stream (disabled-emit delta
//! vs the bare loop, dyn-dispatch floor), the screening layer (full vs screened
//! proposal sweep, the full-set KKT sweep kernel — reference and SIMD),
//! the scalar vs 4-way-unrolled vs runtime-dispatched SIMD
//! gather/scatter kernels, line-search refinement,
//! objective evaluation, and — when artifacts are built — the HLO
//! dense-block propose for comparison.
//!
//! Besides the human-readable table, results are appended to a
//! machine-readable JSON file (`BENCH_hotpath.json`, override with
//! `GENCD_BENCH_JSON=...`) so successive PRs leave a perf trajectory.

use std::sync::atomic::Ordering::Relaxed;

use gencd::coordinator::problem::{Problem, SharedState};
use gencd::coordinator::{linesearch, propose};
use gencd::data::{reuters_like, GenOptions};
use gencd::loss::Logistic;
use gencd::util::atomic::SyncF64Vec;
use gencd::util::par::{aligned_chunk, SpinBarrier};
use gencd::util::timer::bench_loop;
use gencd::util::Pcg64;

/// Collected (key, value) metrics destined for the JSON trail.
struct Report {
    entries: Vec<(String, f64)>,
}

impl Report {
    fn push(&mut self, key: &str, value: f64) {
        self.entries.push((key.to_string(), value));
    }

    fn write_json(&self, header: &[(String, String)]) {
        let path = std::env::var("GENCD_BENCH_JSON")
            .unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
        let mut out = String::from("{\n");
        for (k, v) in header {
            out.push_str(&format!("  \"{k}\": {v},\n"));
        }
        out.push_str("  \"kernels\": {\n");
        for (i, (k, v)) in self.entries.iter().enumerate() {
            let comma = if i + 1 == self.entries.len() { "" } else { "," };
            out.push_str(&format!("    \"{k}\": {v:.4}{comma}\n"));
        }
        out.push_str("  }\n}\n");
        match std::fs::write(&path, out) {
            Ok(()) => println!("\nwrote {path}"),
            Err(e) => eprintln!("\ncould not write {path}: {e}"),
        }
    }
}

fn main() {
    let mut ds = reuters_like(&GenOptions::with_scale(0.05));
    ds.x.normalize_columns();
    let n = ds.n_samples();
    let k = ds.n_features();
    let nnz = ds.x.nnz();
    println!("workload: reuters@0.05 ({n} x {k}, {nnz} nnz)\n");
    let problem = Problem::new(ds, Box::new(Logistic), 1e-5);
    let mut report = Report { entries: Vec::new() };

    let mut rng = Pcg64::seeded(3);
    let w0: Vec<f64> = (0..k)
        .map(|j| if j % 61 == 0 { rng.range_f64(-0.3, 0.3) } else { 0.0 })
        .collect();
    let state = SharedState::from_warm_start(&problem, &w0);
    propose::refresh_dloss(&problem, &state, 0, n);

    let cols: Vec<usize> = (0..256).map(|_| rng.below(k)).collect();
    let col_nnz: usize = cols.iter().map(|&j| problem.x.col_nnz(j)).sum();

    // ---- propose: cached dloss ------------------------------------------
    let s = bench_loop(0.5, 20, || {
        let mut acc = 0.0;
        for &j in &cols {
            acc += propose::propose(&problem, &state, j, true).delta;
        }
        std::hint::black_box(acc);
    });
    println!(
        "propose/dloss      {:>9.1} ns/col ({:.2} ns/nnz)   {s}",
        s.best * 1e9 / cols.len() as f64,
        s.best * 1e9 / col_nnz as f64
    );
    report.push("propose_dloss_ns_per_nnz", s.best * 1e9 / col_nnz as f64);

    // ---- propose: on-the-fly ell' -----------------------------------------
    let s = bench_loop(0.5, 20, || {
        let mut acc = 0.0;
        for &j in &cols {
            acc += propose::propose(&problem, &state, j, false).delta;
        }
        std::hint::black_box(acc);
    });
    println!(
        "propose/on-the-fly {:>9.1} ns/col ({:.2} ns/nnz)   {s}",
        s.best * 1e9 / cols.len() as f64,
        s.best * 1e9 / col_nnz as f64
    );
    report.push("propose_onthefly_ns_per_nnz", s.best * 1e9 / col_nnz as f64);

    // ---- dloss refresh -----------------------------------------------------
    let s = bench_loop(0.5, 20, || {
        propose::refresh_dloss(&problem, &state, 0, n);
    });
    println!("dloss refresh      {:>9.2} ns/sample          {s}", s.best * 1e9 / n as f64);
    report.push("dloss_refresh_ns_per_sample", s.best * 1e9 / n as f64);

    // ---- update: atomic z scatter (single thread) ---------------------------
    let s = bench_loop(0.5, 20, || {
        for &j in &cols {
            let (rows, vals) = problem.x.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                state.z[i as usize].fetch_add(1e-12 * v, Relaxed);
            }
        }
    });
    println!("update/atomic      {:>9.2} ns/nnz             {s}", s.best * 1e9 / col_nnz as f64);
    report.push("update_atomic_1t_ns_per_nnz", s.best * 1e9 / col_nnz as f64);

    // ---- update: unsync plain store (T=1 / coloring / buffered-scatter
    // discipline; the gap to update/atomic is the CAS overhead) --------------
    let s = bench_loop(0.5, 20, || {
        for &j in &cols {
            let (rows, vals) = problem.x.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                state.z.add(i as usize, 1e-12 * v);
            }
        }
    });
    println!("update/unsync      {:>9.2} ns/nnz             {s}", s.best * 1e9 / col_nnz as f64);
    report.push("update_unsync_1t_ns_per_nnz", s.best * 1e9 / col_nnz as f64);

    // ---- update under contention: CAS vs buffered scatter+reduce ------------
    // The acceptance kernel of the buffered-update work: mt_threads
    // workers scatter disjoint column sets into the SAME z. The CAS
    // variant is Algorithm 3's `omp atomic`; the buffered variant is the
    // engine's per-thread accumulator + cache-aligned chunked reduce.
    let mt_threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .clamp(2, 4);
    // distinct columns partitioned across threads, like the engine's
    // deduplicated accepted set: contention comes from shared rows, not
    // from two threads scattering the same column
    let total_cols = (mt_threads * 2048).min(k);
    let distinct: Vec<usize> = rng.sample_distinct(k, total_cols);
    let per_thread = total_cols / mt_threads;
    let mt_cols: Vec<Vec<usize>> = (0..mt_threads)
        .map(|t| distinct[t * per_thread..(t + 1) * per_thread].to_vec())
        .collect();
    let mt_nnz: usize = mt_cols
        .iter()
        .flat_map(|set| set.iter())
        .map(|&j| problem.x.col_nnz(j))
        .sum();
    println!("\nmulti-thread z-update: {mt_threads} threads, {mt_nnz} nnz/round");

    let s_cas = bench_loop(0.5, 5, || {
        std::thread::scope(|scope| {
            let problem = &problem;
            let state = &state;
            for cols in &mt_cols {
                scope.spawn(move || {
                    for &j in cols {
                        let (rows, vals) = problem.x.col(j);
                        for (&i, &v) in rows.iter().zip(vals) {
                            state.z[i as usize].fetch_add(1e-12 * v, Relaxed);
                        }
                    }
                });
            }
        });
    });
    println!(
        "update/atomic-mt   {:>9.2} ns/nnz             {s_cas}",
        s_cas.best * 1e9 / mt_nnz as f64
    );
    report.push("update_atomic_mt_ns_per_nnz", s_cas.best * 1e9 / mt_nnz as f64);

    // per-thread accumulators; each SyncF64Vec slab is 128-byte aligned.
    // One spawn round per measured iteration (same as the CAS kernel);
    // scatter and reduce are separated by the engine's own SpinBarrier,
    // so the spawn/join overhead cancels in the speedup ratio.
    let bufs: Vec<SyncF64Vec> = (0..mt_threads).map(|_| SyncF64Vec::zeros(n)).collect();
    let reduce_barrier = SpinBarrier::new(mt_threads);
    let s_buf = bench_loop(0.5, 5, || {
        std::thread::scope(|scope| {
            let problem = &problem;
            let state = &state;
            let bufs = &bufs;
            let reduce_barrier = &reduce_barrier;
            for (t, cols) in mt_cols.iter().enumerate() {
                scope.spawn(move || {
                    // phase 1: scatter into this thread's accumulator
                    let buf = &bufs[t];
                    for &j in cols {
                        let (rows, vals) = problem.x.col(j);
                        for (&i, &v) in rows.iter().zip(vals) {
                            buf.add(i as usize, 1e-12 * v);
                        }
                    }
                    reduce_barrier.wait();
                    // phase 2: fold all accumulators over my aligned chunk
                    for i in aligned_chunk(n, t, mt_threads) {
                        let mut acc = 0.0;
                        for b in bufs {
                            let v = b.get(i);
                            if v != 0.0 {
                                acc += v;
                                b.set(i, 0.0);
                            }
                        }
                        if acc != 0.0 {
                            state.z.add(i, acc);
                        }
                    }
                });
            }
        });
    });
    println!(
        "update/buffered-mt {:>9.2} ns/nnz             {s_buf}",
        s_buf.best * 1e9 / mt_nnz as f64
    );
    report.push("update_buffered_mt_ns_per_nnz", s_buf.best * 1e9 / mt_nnz as f64);
    let speedup = s_cas.best / s_buf.best;
    println!("update/buffered-mt speedup vs CAS: {speedup:.2}x");
    report.push("update_buffered_vs_cas_speedup", speedup);

    // ---- update under contention: cache-blocked scatter+drain ---------------
    // `UpdatePath::Blocked`: same buffered semantics, but one
    // stride-padded slab (strip starts on 128-byte lines, a guard line
    // between strips) and a block-at-a-time drain instead of the
    // per-element strided fold — the false-sharing and the strided
    // walk are what this row prices against update/buffered-mt.
    let blk = gencd::kernel::BlockedScatter::new(n, mt_threads);
    let blk_barrier = SpinBarrier::new(mt_threads);
    let s_blk = bench_loop(0.5, 5, || {
        std::thread::scope(|scope| {
            let problem = &problem;
            let state = &state;
            let blk = &blk;
            let blk_barrier = &blk_barrier;
            for (t, cols) in mt_cols.iter().enumerate() {
                scope.spawn(move || {
                    // phase 1: scatter into this thread's strip
                    for &j in cols {
                        let (rows, vals) = problem.x.col(j);
                        for (&i, &v) in rows.iter().zip(vals) {
                            blk.add(t, i as usize, 1e-12 * v);
                        }
                    }
                    blk_barrier.wait();
                    // phase 2: line-aligned block drain over my chunk
                    blk.drain_range(&state.z, aligned_chunk(n, t, mt_threads));
                });
            }
        });
    });
    println!(
        "update/blocked-mt  {:>9.2} ns/nnz             {s_blk}",
        s_blk.best * 1e9 / mt_nnz as f64
    );
    report.push("update_blocked_mt_ns_per_nnz", s_blk.best * 1e9 / mt_nnz as f64);
    let blk_speedup = s_buf.best / s_blk.best;
    println!("update/blocked-mt speedup vs buffered-mt: {blk_speedup:.2}x");
    report.push("update_blocked_vs_buffered_speedup", blk_speedup);

    // ---- sharded replicas: private-z scatter + round reconcile --------------
    // The shards dimension: each of `shards` pools scatters its column
    // set into its OWN full-length z replica (plain stores, zero
    // cross-shard traffic), then all fold replica deltas into the
    // canonical z over aligned chunks and refresh the replicas — the
    // per-round cost of gencd::shard's bulk-synchronous reconcile.
    let shards = mt_threads;
    let replicas: Vec<SyncF64Vec> = (0..shards).map(|_| SyncF64Vec::zeros(n)).collect();
    let z_canon = SyncF64Vec::zeros(n);
    let shard_barrier = SpinBarrier::new(shards);
    let s_shard = bench_loop(0.5, 5, || {
        std::thread::scope(|scope| {
            let problem = &problem;
            let replicas = &replicas;
            let z_canon = &z_canon;
            let shard_barrier = &shard_barrier;
            for (t, cols) in mt_cols.iter().enumerate() {
                scope.spawn(move || {
                    // round: scatter into this shard's replica
                    let rep = &replicas[t];
                    for &j in cols {
                        let (rows, vals) = problem.x.col(j);
                        for (&i, &v) in rows.iter().zip(vals) {
                            rep.add(i as usize, 1e-12 * v);
                        }
                    }
                    shard_barrier.wait();
                    // boundary: fold every replica's delta over my
                    // aligned chunk, refresh all replicas
                    for i in aligned_chunk(n, t, shards) {
                        let base = z_canon.get(i);
                        let mut acc = base;
                        for rep in replicas {
                            let d = rep.get(i) - base;
                            if d != 0.0 {
                                acc += d;
                            }
                        }
                        for rep in replicas {
                            if rep.get(i) != acc {
                                rep.set(i, acc);
                            }
                        }
                        if acc != base {
                            z_canon.set(i, acc);
                        }
                    }
                });
            }
        });
    });
    println!(
        "update/sharded-mt  {:>9.2} ns/nnz ({} shards)  {s_shard}",
        s_shard.best * 1e9 / mt_nnz as f64,
        shards
    );
    report.push("update_sharded_mt_ns_per_nnz", s_shard.best * 1e9 / mt_nnz as f64);

    // reconcile fold alone (replicas already scattered once: measures
    // the O(n·S) boundary sweep the shard layer pays per round)
    let s_rec = bench_loop(0.3, 5, || {
        std::thread::scope(|scope| {
            let replicas = &replicas;
            let z_canon = &z_canon;
            for t in 0..shards {
                scope.spawn(move || {
                    for i in aligned_chunk(n, t, shards) {
                        let base = z_canon.get(i);
                        let mut acc = base;
                        for rep in replicas {
                            let d = rep.get(i) - base;
                            if d != 0.0 {
                                acc += d;
                            }
                        }
                        for rep in replicas {
                            if rep.get(i) != acc {
                                rep.set(i, acc);
                            }
                        }
                        if acc != base {
                            z_canon.set(i, acc);
                        }
                    }
                });
            }
        });
    });
    println!(
        "shard/reconcile    {:>9.2} ns/sample          {s_rec}",
        s_rec.best * 1e9 / n as f64
    );
    report.push("shard_reconcile_ns_per_sample", s_rec.best * 1e9 / n as f64);

    // ---- NUMA: pinned vs unpinned replica scatter ----------------------------
    // The §NUMA row: each shard thread scatters into its own replica,
    // once with threads pinned round-robin across NUMA nodes (replica
    // first-touched on the pinned thread => node-local) and once
    // unpinned (the scheduler migrates threads across sockets and the
    // replica pages stay wherever first touch put them). On a
    // single-node box the two rows measure the same thing — the
    // topology line says which reading you got.
    let topo = gencd::util::topo::Topology::detect();
    println!(
        "\nNUMA scatter: {} node(s) detected{}",
        topo.n_nodes(),
        if topo.n_nodes() < 2 {
            " — pinned == unpinned on this host"
        } else {
            ""
        }
    );
    let scatter_pass = |pin: bool| {
        // fresh replicas per measurement so first touch happens on the
        // (possibly pinned) scatter thread, like the shard layer does
        std::thread::scope(|scope| {
            let problem = &problem;
            let topo = &topo;
            for (t, cols) in mt_cols.iter().enumerate() {
                scope.spawn(move || {
                    if pin && topo.n_nodes() >= 2 {
                        topo.pin_thread_to_node(t % topo.n_nodes());
                    }
                    let rep = SyncF64Vec::zeros(problem.n_samples());
                    for &j in cols {
                        let (rows, vals) = problem.x.col(j);
                        for (&i, &v) in rows.iter().zip(vals) {
                            rep.add(i as usize, 1e-12 * v);
                        }
                    }
                    std::hint::black_box(rep.get(0));
                });
            }
        });
    };
    let s_unpin = bench_loop(0.5, 5, || scatter_pass(false));
    println!(
        "scatter/unpinned   {:>9.2} ns/nnz             {s_unpin}",
        s_unpin.best * 1e9 / mt_nnz as f64
    );
    report.push(
        "replica_scatter_unpinned_ns_per_nnz",
        s_unpin.best * 1e9 / mt_nnz as f64,
    );
    let s_pin = bench_loop(0.5, 5, || scatter_pass(true));
    println!(
        "scatter/pinned     {:>9.2} ns/nnz             {s_pin}",
        s_pin.best * 1e9 / mt_nnz as f64
    );
    report.push(
        "replica_scatter_pinned_ns_per_nnz",
        s_pin.best * 1e9 / mt_nnz as f64,
    );
    report.push("replica_scatter_pin_speedup", s_unpin.best / s_pin.best);

    // ---- reconcile: dense full-scan vs dirty-chunk delta fold ----------------
    // The delta-reconcile row: same fold arithmetic, but only chunks a
    // dirty map flags (~5% here, the screened-run shape) are visited —
    // shard_reconcile_ns_per_sample above is the dense baseline.
    use gencd::util::par::{DirtyChunks, DIRTY_CHUNK_ELEMS};
    let dirty: Vec<DirtyChunks> = (0..shards).map(|_| DirtyChunks::new(n)).collect();
    for (t, cols) in mt_cols.iter().enumerate() {
        // mark ~5% of each shard's columns' rows, like a settled
        // screened run where most of z never moves
        for &j in cols.iter().step_by(20) {
            let (rows, _) = problem.x.col(j);
            for &i in rows {
                dirty[t].mark(i as usize);
            }
        }
    }
    let frac = dirty.iter().map(|d| d.count()).max().unwrap_or(0) as f64
        / dirty[0].n_chunks() as f64;
    let s_delta = bench_loop(0.3, 5, || {
        std::thread::scope(|scope| {
            let replicas = &replicas;
            let z_canon = &z_canon;
            let dirty = &dirty;
            for t in 0..shards {
                scope.spawn(move || {
                    let range = aligned_chunk(n, t, shards);
                    let c_lo = range.start / DIRTY_CHUNK_ELEMS;
                    let c_hi = range.end.div_ceil(DIRTY_CHUNK_ELEMS);
                    for c in c_lo..c_hi {
                        if !dirty.iter().any(|d| d.is_dirty(c)) {
                            continue;
                        }
                        let lo = c * DIRTY_CHUNK_ELEMS;
                        let hi = ((c + 1) * DIRTY_CHUNK_ELEMS).min(range.end);
                        for i in lo..hi {
                            let base = z_canon.get(i);
                            let mut acc = base;
                            for rep in replicas {
                                let d = rep.get(i) - base;
                                if d != 0.0 {
                                    acc += d;
                                }
                            }
                            for rep in replicas {
                                if rep.get(i) != acc {
                                    rep.set(i, acc);
                                }
                            }
                            if acc != base {
                                z_canon.set(i, acc);
                            }
                        }
                    }
                });
            }
        });
    });
    println!(
        "shard/delta-rec    {:>9.2} ns/sample ({:.0}% dirty) {s_delta}",
        s_delta.best * 1e9 / n as f64,
        frac * 100.0
    );
    report.push(
        "reconcile_delta_ns_per_sample",
        s_delta.best * 1e9 / n as f64,
    );
    report.push("reconcile_delta_speedup", s_rec.best / s_delta.best);

    // ---- screening: full vs screened proposal sweep --------------------------
    // The tentpole row: proposing over every column (GREEDY's Propose
    // phase, the O(p) shape) vs over a 5% active set via the screening
    // bitmask — the work an l1 path actually needs once KKT screening
    // has settled.
    let active = gencd::screen::ActiveSet::new_full(k, 1);
    for j in 0..k {
        if j % 20 != 0 {
            active.deactivate(j);
        }
    }
    active.rebuild_dense();
    let s_full = bench_loop(0.5, 10, || {
        let mut acc = 0.0;
        for j in 0..k {
            acc += propose::propose(&problem, &state, j, true).delta;
        }
        std::hint::black_box(acc);
    });
    println!(
        "\npropose/full-sweep {:>9.1} us ({k} cols)        {s_full}",
        s_full.best * 1e6
    );
    report.push("propose_full_sweep_us", s_full.best * 1e6);
    let n_active = active.popcount();
    let s_screened = bench_loop(0.5, 10, || {
        let mut acc = 0.0;
        active.for_each_active(|j| {
            acc += propose::propose(&problem, &state, j as usize, true).delta;
        });
        std::hint::black_box(acc);
    });
    println!(
        "propose/screened   {:>9.1} us ({n_active} cols)         {s_screened}",
        s_screened.best * 1e6
    );
    report.push("propose_screened_sweep_us", s_screened.best * 1e6);
    let sweep_speedup = s_full.best / s_screened.best;
    println!("propose/screened speedup vs full sweep: {sweep_speedup:.2}x");
    report.push("screened_sweep_speedup", sweep_speedup);

    // ---- screening: the full-set KKT sweep (the safety net's price) ---------
    // One fused dot_col + violation test per zero-weight column, paid
    // every kkt_every iterations.
    let sweep_set = gencd::screen::ActiveSet::new_full(k, 1);
    let s_kkt = bench_loop(0.5, 10, || {
        std::hint::black_box(gencd::screen::sweep_range(
            &problem,
            &state,
            &sweep_set,
            1e-7,
            0..sweep_set.n_words(),
            gencd::kernel::KernelMode::Reference,
        ));
    });
    println!(
        "screen/kkt-sweep   {:>9.2} ns/nnz             {s_kkt}",
        s_kkt.best * 1e9 / nnz as f64
    );
    report.push("kkt_sweep_ns_per_nnz", s_kkt.best * 1e9 / nnz as f64);

    // same sweep under the dispatched SIMD tier (the --kernel auto path)
    let simd_tier = gencd::kernel::dispatch(gencd::kernel::KernelChoice::Auto);
    let s_kkt_simd = bench_loop(0.5, 10, || {
        std::hint::black_box(gencd::screen::sweep_range(
            &problem,
            &state,
            &sweep_set,
            1e-7,
            0..sweep_set.n_words(),
            gencd::kernel::KernelMode::Fast(simd_tier),
        ));
    });
    println!(
        "screen/kkt-simd    {:>9.2} ns/nnz ({})     {s_kkt_simd}",
        s_kkt_simd.best * 1e9 / nnz as f64,
        simd_tier.name()
    );
    report.push("kkt_sweep_simd_ns_per_nnz", s_kkt_simd.best * 1e9 / nnz as f64);

    // ---- fast kernels: scalar vs 4-way unrolled gather/scatter --------------
    let dvec: Vec<f64> = (0..n).map(|i| ((i * 2654435761) % 1000) as f64 * 1e-3).collect();
    let s_dot = bench_loop(0.5, 20, || {
        let mut acc = 0.0;
        for &j in &cols {
            acc += problem.x.dot_col(j, &dvec);
        }
        std::hint::black_box(acc);
    });
    println!(
        "dot_col/scalar     {:>9.2} ns/nnz             {s_dot}",
        s_dot.best * 1e9 / col_nnz as f64
    );
    report.push("dot_col_scalar_ns_per_nnz", s_dot.best * 1e9 / col_nnz as f64);
    let s_dotf = bench_loop(0.5, 20, || {
        let mut acc = 0.0;
        for &j in &cols {
            acc += problem.x.dot_col_fast(j, &dvec);
        }
        std::hint::black_box(acc);
    });
    println!(
        "dot_col/unrolled   {:>9.2} ns/nnz             {s_dotf}",
        s_dotf.best * 1e9 / col_nnz as f64
    );
    report.push("dot_col_unrolled_ns_per_nnz", s_dotf.best * 1e9 / col_nnz as f64);
    let mut yvec = vec![0.0f64; n];
    let s_axpy = bench_loop(0.5, 20, || {
        for &j in &cols {
            problem.x.axpy_col(j, 1e-12, &mut yvec);
        }
    });
    println!(
        "axpy_col/scalar    {:>9.2} ns/nnz             {s_axpy}",
        s_axpy.best * 1e9 / col_nnz as f64
    );
    report.push("axpy_col_scalar_ns_per_nnz", s_axpy.best * 1e9 / col_nnz as f64);
    let s_axpyf = bench_loop(0.5, 20, || {
        for &j in &cols {
            problem.x.axpy_col_fast(j, 1e-12, &mut yvec);
        }
    });
    println!(
        "axpy_col/unrolled  {:>9.2} ns/nnz             {s_axpyf}",
        s_axpyf.best * 1e9 / col_nnz as f64
    );
    report.push(
        "axpy_col_unrolled_ns_per_nnz",
        s_axpyf.best * 1e9 / col_nnz as f64,
    );

    // ---- fast kernels: the runtime-dispatched SIMD tier ----------------------
    // Whatever `--kernel auto` would pick on this host; on a machine
    // without AVX2 the tier clamps to scalar and these rows converge to
    // the unrolled ones (the tier name in the row says which reading
    // you got).
    let fast_mode = gencd::kernel::KernelMode::Fast(simd_tier);
    let s_dots = bench_loop(0.5, 20, || {
        let mut acc = 0.0;
        for &j in &cols {
            acc += problem.x.dot_col_tier(j, &dvec, simd_tier);
        }
        std::hint::black_box(acc);
    });
    println!(
        "dot_col/simd       {:>9.2} ns/nnz ({})     {s_dots}",
        s_dots.best * 1e9 / col_nnz as f64,
        simd_tier.name()
    );
    report.push("dot_col_simd_ns_per_nnz", s_dots.best * 1e9 / col_nnz as f64);
    let s_axpys = bench_loop(0.5, 20, || {
        for &j in &cols {
            problem.x.axpy_col_mode(j, 1e-12, &mut yvec, fast_mode);
        }
    });
    println!(
        "axpy_col/simd      {:>9.2} ns/nnz ({})     {s_axpys}",
        s_axpys.best * 1e9 / col_nnz as f64,
        simd_tier.name()
    );
    report.push("axpy_col_simd_ns_per_nnz", s_axpys.best * 1e9 / col_nnz as f64);

    // ---- phase barrier crossings: std::sync::Barrier vs SpinBarrier ---------
    const ROUNDS: usize = 2000;
    let s_std = bench_loop(0.3, 5, || {
        let b = std::sync::Barrier::new(mt_threads);
        std::thread::scope(|scope| {
            let b = &b;
            for _ in 0..mt_threads {
                scope.spawn(move || {
                    for _ in 0..ROUNDS {
                        b.wait();
                    }
                });
            }
        });
    });
    println!(
        "barrier/std        {:>9.0} ns/crossing        {s_std}",
        s_std.best * 1e9 / ROUNDS as f64
    );
    report.push("barrier_std_ns_per_crossing", s_std.best * 1e9 / ROUNDS as f64);

    let s_spin = bench_loop(0.3, 5, || {
        let b = SpinBarrier::new(mt_threads);
        std::thread::scope(|scope| {
            let b = &b;
            for _ in 0..mt_threads {
                scope.spawn(move || {
                    for _ in 0..ROUNDS {
                        b.wait();
                    }
                });
            }
        });
    });
    println!(
        "barrier/spin       {:>9.0} ns/crossing        {s_spin}",
        s_spin.best * 1e9 / ROUNDS as f64
    );
    report.push("barrier_spin_ns_per_crossing", s_spin.best * 1e9 / ROUNDS as f64);

    // ---- wire codec: delta frames, exact vs f32 ------------------------------
    {
        use gencd::net::frame::{decode_frame, encode_delta, Frame, WirePrecision};
        // 1-in-8 chunks dirty: the sparse-round shape the delta
        // reconcile produces on the reference workload
        let dirty_every = 8usize;
        let replica: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let n_chunks = n.div_ceil(16);
        let dirty_chunks = (0..n_chunks).filter(|c| c % dirty_every == 0).count().max(1);
        let mut exact_len = 0usize;
        for precision in [WirePrecision::Exact, WirePrecision::F32] {
            let mut wire = Vec::with_capacity(n * 8 + 64);
            let s_enc = bench_loop(0.3, 10, || {
                wire.clear();
                let len = encode_delta(
                    &mut wire,
                    0,
                    1,
                    precision,
                    n,
                    |c| c % dirty_every == 0,
                    |i| replica[i],
                );
                std::hint::black_box(len);
            });
            println!(
                "wire/encode {:<6} {:>9.1} ns/dirty-chunk     {s_enc}",
                precision.name(),
                s_enc.best * 1e9 / dirty_chunks as f64
            );
            report.push(
                &format!("wire_encode_{}_ns_per_dirty_chunk", precision.name()),
                s_enc.best * 1e9 / dirty_chunks as f64,
            );
            let mut sink = vec![0.0f64; n];
            let s_dec = bench_loop(0.3, 10, || {
                match decode_frame(&wire).expect("frame") {
                    Frame::Delta(d) => d.apply(|i, v| sink[i] = v),
                    other => panic!("unexpected frame: {other:?}"),
                }
                std::hint::black_box(&mut sink);
            });
            println!(
                "wire/decode {:<6} {:>9.1} ns/dirty-chunk     {s_dec}",
                precision.name(),
                s_dec.best * 1e9 / dirty_chunks as f64
            );
            report.push(
                &format!("wire_decode_{}_ns_per_dirty_chunk", precision.name()),
                s_dec.best * 1e9 / dirty_chunks as f64,
            );
            match precision {
                WirePrecision::Exact => exact_len = wire.len(),
                WirePrecision::F32 => {
                    report.push("wire_f32_volume_ratio", wire.len() as f64 / exact_len as f64)
                }
            }
        }
    }

    // ---- event stream: disabled emit vs dyn-dispatched subscriber ------------
    // The observability contract: a `NoopSink` emit site costs nothing
    // (`enabled()` is a compile-time `false`, the event is never even
    // constructed), so the first row reports the DELTA against the bare
    // loop. The second row prices the enabled path: construct the event
    // and match-dispatch it through `&mut dyn EventSink` to a no-op
    // subscriber method — the floor any real subscriber pays per event.
    {
        use gencd::event::{
            EventSink, Events, IterationCompleted, Meta, NoopSink, NoopSubscriber, SolveInfo,
            Subscribed,
        };
        const EMITS: u64 = 100_000;
        let iter_body = |i: u64| -> u64 { std::hint::black_box(i).wrapping_mul(0x9e3779b97f4a7c15) };
        let s_bare = bench_loop(0.3, 10, || {
            let mut acc = 0u64;
            for i in 0..EMITS {
                acc = acc.wrapping_add(iter_body(i));
            }
            std::hint::black_box(acc);
        });
        let mut noop = NoopSink;
        let s_disabled = bench_loop(0.3, 10, || {
            let mut acc = 0u64;
            for i in 0..EMITS {
                acc = acc.wrapping_add(iter_body(i));
                if noop.enabled() {
                    noop.emit(
                        &Meta { timestamp_ticks: i, shard: 0, thread: 0 },
                        &Events::from(IterationCompleted {
                            iter: i,
                            updates: 1,
                            selected: 1,
                            objective: None,
                            nnz: None,
                        }),
                    );
                }
            }
            std::hint::black_box(acc);
        });
        let disabled_delta = (s_disabled.best - s_bare.best) * 1e9 / EMITS as f64;
        println!(
            "\nevent/disabled     {:>9.3} ns/iter (delta vs bare loop) {s_disabled}",
            disabled_delta
        );
        report.push("event_emit_disabled_ns_per_iter", disabled_delta.max(0.0));

        let mut subscribed = Subscribed::new(NoopSubscriber, &SolveInfo::default());
        let sink: &mut dyn EventSink = &mut subscribed;
        let s_dyn = bench_loop(0.3, 10, || {
            let mut acc = 0u64;
            for i in 0..EMITS {
                acc = acc.wrapping_add(iter_body(i));
                if sink.enabled() {
                    sink.emit(
                        &Meta { timestamp_ticks: i, shard: 0, thread: 0 },
                        &Events::from(IterationCompleted {
                            iter: i,
                            updates: 1,
                            selected: 1,
                            objective: None,
                            nnz: None,
                        }),
                    );
                }
            }
            std::hint::black_box(acc);
        });
        let dyn_cost = (s_dyn.best - s_bare.best) * 1e9 / EMITS as f64;
        println!("event/dyn-noop     {:>9.2} ns/event           {s_dyn}", dyn_cost);
        report.push("event_emit_dyn_ns_per_event", dyn_cost.max(0.0));
    }

    // ---- line search ---------------------------------------------------------
    for steps in [20usize, 500] {
        let s = bench_loop(0.5, 10, || {
            let mut acc = 0.0;
            for &j in &cols[..32] {
                acc += linesearch::refine(&problem, &state, j, 0.01, steps);
            }
            std::hint::black_box(acc);
        });
        println!(
            "line search s={steps:<4} {:>9.2} us/coord          {s}",
            s.best * 1e6 / 32.0
        );
        report.push(
            &format!("linesearch_{steps}_us_per_coord"),
            s.best * 1e6 / 32.0,
        );
    }

    // ---- objective evaluation (the logging cost) ------------------------------
    let s = bench_loop(0.5, 10, || {
        let w = state.w_snapshot();
        let z = state.z_snapshot();
        std::hint::black_box(problem.objective(&w, &z));
    });
    println!("objective eval     {:>9.2} us                {s}", s.best * 1e6);
    report.push("objective_eval_us", s.best * 1e6);

    // ---- HLO dense-block propose (needs artifacts) ------------------------------
    match gencd::runtime::Runtime::from_default_dir() {
        Ok(rt) => match gencd::runtime::HloProposer::new(&rt, &problem) {
            Ok(mut hlo) => {
                let js: Vec<u32> =
                    cols.iter().take(hlo.block_width()).map(|&j| j as u32).collect();
                let s = bench_loop(1.0, 5, || {
                    hlo.run_block(&problem, &state, &js).expect("hlo");
                });
                println!(
                    "propose/hlo-block  {:>9.1} us/col ({} cols/call) {s}",
                    s.best * 1e6 / js.len() as f64,
                    js.len()
                );
                report.push("propose_hlo_us_per_col", s.best * 1e6 / js.len() as f64);
            }
            Err(e) => println!("propose/hlo-block  skipped: {e}"),
        },
        Err(e) => println!("propose/hlo-block  skipped: {e}"),
    }

    let header = vec![
        (
            "comment".to_string(),
            "\"measured by cargo bench --bench hotpath\"".to_string(),
        ),
        ("workload".to_string(), "\"reuters@0.05\"".to_string()),
        ("n".to_string(), n.to_string()),
        ("k".to_string(), k.to_string()),
        ("nnz".to_string(), nnz.to_string()),
        ("mt_threads".to_string(), mt_threads.to_string()),
        ("shards".to_string(), shards.to_string()),
    ];
    report.write_json(&header);
}
