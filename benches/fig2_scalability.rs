//! Regenerates paper Figure 2: updates/second vs thread count for the
//! four algorithms on both dataset twins. T=1 is measured with the real
//! engine; T>1 uses the calibrated cost model (this container has one
//! core — DESIGN.md §4 substitution). Expected shape (paper Sec. 5.2):
//! GREEDY flattest (serial accept); THREAD-GREEDY scales best; SHOTGUN
//! scales further on REUTERS (P*≈800) than DOROTHEA (P*≈23); COLORING
//! is bounded by its mean color size.
//!
//!     cargo bench --bench fig2_scalability

fn main() {
    gencd::bench_harness::experiments::print_fig2(&[1, 2, 4, 8, 16, 32]);
}
