//! Runtime-dispatched SIMD kernels — the instruction-level execution
//! layer under every GenCD hot loop.
//!
//! Everything above this module (the engine's phases, screening,
//! sharding, the wire) bottoms out in four kernel shapes: the
//! gather-based column dot (`Propose`'s gradient numerator and the
//! fused KKT sweep inner product), the column axpy scatter (`Update`'s
//! `z += delta X_j`), dense reductions (dloss/objective sums), and the
//! buffered Update drain. This module owns all of them, in three tiers:
//!
//! * [`KernelTier::Scalar`] — the existing 4-way unrolled, prefetching
//!   scalar kernels (moved here from `sparse/csc.rs`; the csc methods
//!   now delegate). Runs everywhere, and is the arm the
//!   `GENCD_FORCE_SCALAR` escape hatch pins for differential testing.
//! * [`KernelTier::Avx2`] — 4-lane `core::arch` AVX2+FMA: hardware
//!   `vgatherdpd` for the dots, vectorized multiplies with scalar
//!   read-modify-write stores for the axpy (AVX2 has no scatter).
//! * [`KernelTier::Avx512`] — 8-lane AVX-512F with native
//!   `vscatterdpd` on the axpy path (sound because CSC rows are
//!   strictly sorted within a column — the gathered/scattered lanes of
//!   one step are always unique).
//!
//! ## Dispatch
//!
//! [`dispatch`] resolves a [`KernelChoice`] (config/CLI `--kernel
//! auto|scalar|avx2|avx512`) to the best *available* tier: hardware
//! capability is probed once with `is_x86_feature_detected!` and cached
//! in a `OnceLock`; a requested tier the host lacks clamps down, and
//! non-x86 hosts always resolve to `Scalar`. The `GENCD_FORCE_SCALAR`
//! environment variable is re-read on every call (deliberately not
//! cached) so tests can pin and unpin the scalar arm at will. The
//! engine resolves the tier once per solve ([`resolve`]) and reports it
//! in `MetricsSnapshot::kernel_tier` and `SolveInfo::kernel`.
//!
//! ## Bit-exactness discipline
//!
//! The same A/B contract the unrolled kernels established: the plain
//! scalar path ([`KernelMode::Reference`], `fast_kernels = false`)
//! stays the bit-exactness reference. Every **axpy** arm is
//! bit-identical to it (each element is touched exactly once —
//! elementwise multiply-then-add, no re-association, no FMA
//! contraction). The **dot**/reduction arms re-associate the sum
//! (4 scalar accumulators, 4 or 8 SIMD lanes), so engine-level
//! agreement is pinned at 1e-12, exactly like the unrolled kernels
//! today (`rust/tests/kernels.rs`).
//!
//! This module is also the one documented home of the software-prefetch
//! constants ([`PREFETCH_DIST`], [`prefetch_read`]) that were
//! previously split between `sparse/csc.rs` and `coordinator/propose.rs`,
//! and of [`BlockedScatter`], the stride-padded cache-blocked
//! accumulator slab behind `UpdatePath::Blocked`.

use std::sync::OnceLock;

use crate::util::atomic::SyncF64Vec;
use crate::util::par::{padded_stride, F64S_PER_LINE};

#[cfg(target_arch = "x86_64")]
mod x86;

/// How many gather targets ahead the unrolled/SIMD kernels prefetch —
/// deep enough to cover a memory round-trip at ~1 gather per cycle
/// group, shallow enough that the prefetched line is still resident
/// when the loop arrives. Shared by every gather/scatter kernel in the
/// crate (this module, `sparse/csc.rs`, the on-the-fly gradient in
/// `coordinator/propose.rs`).
pub const PREFETCH_DIST: usize = 16;

/// Best-effort read-prefetch hint for the gather/scatter kernels;
/// compiles to `prefetcht0` on x86-64 and to nothing elsewhere.
#[inline(always)]
pub fn prefetch_read(p: *const f64) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: prefetch is a hint — it never faults and has no
    // observable effect on memory, for any address
    unsafe {
        std::arch::x86_64::_mm_prefetch::<{ std::arch::x86_64::_MM_HINT_T0 }>(p as *const i8)
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = p;
    }
}

/// What the user *asked for* (`--kernel`, `solver.kernel`,
/// `SolverBuilder::kernel`). [`dispatch`] resolves it against what the
/// host can actually run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelChoice {
    /// Best tier the host supports (the default).
    #[default]
    Auto,
    /// Pin the 4-way unrolled scalar kernels.
    Scalar,
    /// Request AVX2+FMA; clamps to scalar where unavailable.
    Avx2,
    /// Request AVX-512F; clamps to the best available tier below it.
    Avx512,
}

impl KernelChoice {
    pub fn by_name(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "auto" => KernelChoice::Auto,
            "scalar" => KernelChoice::Scalar,
            "avx2" => KernelChoice::Avx2,
            "avx512" => KernelChoice::Avx512,
            other => anyhow::bail!("unknown kernel '{other}' (auto|scalar|avx2|avx512)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelChoice::Auto => "auto",
            KernelChoice::Scalar => "scalar",
            KernelChoice::Avx2 => "avx2",
            KernelChoice::Avx512 => "avx512",
        }
    }
}

/// A kernel implementation the host can actually execute, ordered by
/// width (`Scalar < Avx2 < Avx512`) so requested tiers clamp with
/// `min`. `Scalar` here means the 4-way *unrolled* kernels — the plain
/// reference path is [`KernelMode::Reference`], not a tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum KernelTier {
    Scalar,
    Avx2,
    Avx512,
}

impl KernelTier {
    pub fn name(&self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Avx2 => "avx2",
            KernelTier::Avx512 => "avx512",
        }
    }
}

/// The per-solve kernel decision the engine threads through Propose,
/// the KKT sweep and the Update scatter: the plain scalar reference
/// (`fast_kernels = false` — bit-exact, the default) or a dispatched
/// fast tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelMode {
    /// Plain scalar loops — the bit-exactness reference.
    Reference,
    /// The dispatched fast arm (unrolled scalar, AVX2 or AVX-512).
    Fast(KernelTier),
}

impl KernelMode {
    #[inline]
    pub fn is_fast(self) -> bool {
        matches!(self, KernelMode::Fast(_))
    }

    /// Reported tier string (`MetricsSnapshot::kernel_tier`,
    /// `SolveInfo::kernel`).
    pub fn name(self) -> &'static str {
        match self {
            KernelMode::Reference => "reference",
            KernelMode::Fast(tier) => tier.name(),
        }
    }
}

/// `GENCD_FORCE_SCALAR` escape hatch: set (to anything but `0`) it pins
/// [`dispatch`] to [`KernelTier::Scalar`], regardless of hardware or
/// the requested [`KernelChoice`] — the differential-testing lever the
/// CI kernel matrix exercises. Read per call, never cached.
pub const FORCE_SCALAR_ENV: &str = "GENCD_FORCE_SCALAR";

fn force_scalar() -> bool {
    matches!(std::env::var(FORCE_SCALAR_ENV), Ok(v) if v != "0")
}

/// Hardware capability, probed once per process and cached.
fn hw_tier() -> KernelTier {
    static BEST: OnceLock<KernelTier> = OnceLock::new();
    *BEST.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            // AVX-512F without AVX2+FMA does not exist on real silicon;
            // requiring the lower tiers keeps the clamp order total.
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                if is_x86_feature_detected!("avx512f") {
                    return KernelTier::Avx512;
                }
                return KernelTier::Avx2;
            }
        }
        KernelTier::Scalar
    })
}

/// Resolve a requested [`KernelChoice`] to the tier that will actually
/// run: the escape hatch wins, then the request clamps to the probed
/// hardware capability. Cheap enough to call per solve.
pub fn dispatch(choice: KernelChoice) -> KernelTier {
    if force_scalar() {
        return KernelTier::Scalar;
    }
    match choice {
        KernelChoice::Auto => hw_tier(),
        KernelChoice::Scalar => KernelTier::Scalar,
        KernelChoice::Avx2 => KernelTier::Avx2.min(hw_tier()),
        KernelChoice::Avx512 => KernelTier::Avx512.min(hw_tier()),
    }
}

/// The engine's once-per-solve resolution: `fast_kernels = false` is
/// the bit-exact reference, otherwise the dispatched tier.
pub fn resolve(fast_kernels: bool, choice: KernelChoice) -> KernelMode {
    if fast_kernels {
        KernelMode::Fast(dispatch(choice))
    } else {
        KernelMode::Reference
    }
}

// ---------------------------------------------------------------------
// Scalar tier: the 4-way unrolled kernels (the former csc fast arms)
// ---------------------------------------------------------------------

/// `sum_i vals[i] * d[rows[i]]` unrolled 4-way with independent
/// accumulators and a software-prefetch hint [`PREFETCH_DIST`] gathers
/// ahead — the gather is latency-bound on the random `d[rows[i]]`
/// loads, so splitting the dependency chain and prefetching the
/// upcoming lines is worth ~2x on wide columns. **Not bit-identical**
/// to a plain scalar loop: the 4 partial sums re-associate the
/// reduction (1e-12 discipline).
pub fn dot_unrolled(rows: &[u32], vals: &[f64], d: &[f64]) -> f64 {
    let len = rows.len();
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut i = 0;
    while i + 4 <= len {
        if i + PREFETCH_DIST < len {
            prefetch_read(&d[rows[i + PREFETCH_DIST] as usize]);
        }
        a0 += vals[i] * d[rows[i] as usize];
        a1 += vals[i + 1] * d[rows[i + 1] as usize];
        a2 += vals[i + 2] * d[rows[i + 2] as usize];
        a3 += vals[i + 3] * d[rows[i + 3] as usize];
        i += 4;
    }
    let mut acc = (a0 + a1) + (a2 + a3);
    while i < len {
        acc += vals[i] * d[rows[i] as usize];
        i += 1;
    }
    acc
}

/// `y[rows[i]] += alpha * vals[i]` unrolled 4-way with a prefetch
/// hint. Bit-identical to the plain scalar scatter: each element is
/// touched once, no re-association.
pub fn axpy_unrolled(rows: &[u32], vals: &[f64], alpha: f64, y: &mut [f64]) {
    let len = rows.len();
    let mut i = 0;
    while i + 4 <= len {
        if i + PREFETCH_DIST < len {
            prefetch_read(&y[rows[i + PREFETCH_DIST] as usize]);
        }
        y[rows[i] as usize] += alpha * vals[i];
        y[rows[i + 1] as usize] += alpha * vals[i + 1];
        y[rows[i + 2] as usize] += alpha * vals[i + 2];
        y[rows[i + 3] as usize] += alpha * vals[i + 3];
        i += 4;
    }
    while i < len {
        y[rows[i] as usize] += alpha * vals[i];
        i += 1;
    }
}

/// [`axpy_unrolled`] writing through a raw base pointer — the
/// multi-thread conflict-free scatter's kernel. Same unroll, same
/// prefetch, bit-identical arithmetic.
///
/// # Safety
///
/// `y` must point to a live `f64` array indexable by every entry of
/// `rows`, and for the duration of the call no other thread may read or
/// write the elements those rows touch.
pub unsafe fn axpy_unrolled_ptr(rows: &[u32], vals: &[f64], alpha: f64, y: *mut f64) {
    let len = rows.len();
    let mut i = 0;
    while i + 4 <= len {
        if i + PREFETCH_DIST < len {
            prefetch_read(y.add(rows[i + PREFETCH_DIST] as usize) as *const f64);
        }
        *y.add(rows[i] as usize) += alpha * vals[i];
        *y.add(rows[i + 1] as usize) += alpha * vals[i + 1];
        *y.add(rows[i + 2] as usize) += alpha * vals[i + 2];
        *y.add(rows[i + 3] as usize) += alpha * vals[i + 3];
        i += 4;
    }
    while i < len {
        *y.add(rows[i] as usize) += alpha * vals[i];
        i += 1;
    }
}

/// Plain dense dot product — the reference arm of [`dot_dense`].
pub fn dot_dense_scalar(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

/// Dense dot unrolled 4-way (contiguous loads need no prefetch; the
/// split accumulators feed the FP pipes). Re-associates.
pub fn dot_dense_unrolled(a: &[f64], b: &[f64]) -> f64 {
    let len = a.len().min(b.len());
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut i = 0;
    while i + 4 <= len {
        a0 += a[i] * b[i];
        a1 += a[i + 1] * b[i + 1];
        a2 += a[i + 2] * b[i + 2];
        a3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut acc = (a0 + a1) + (a2 + a3);
    while i < len {
        acc += a[i] * b[i];
        i += 1;
    }
    acc
}

/// Plain `sum |a_i|` — the reference arm of [`sum_abs`].
pub fn sum_abs_scalar(a: &[f64]) -> f64 {
    a.iter().map(|x| x.abs()).sum()
}

/// `sum |a_i|` unrolled 4-way. Re-associates.
pub fn sum_abs_unrolled(a: &[f64]) -> f64 {
    let len = a.len();
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut i = 0;
    while i + 4 <= len {
        a0 += a[i].abs();
        a1 += a[i + 1].abs();
        a2 += a[i + 2].abs();
        a3 += a[i + 3].abs();
        i += 4;
    }
    let mut acc = (a0 + a1) + (a2 + a3);
    while i < len {
        acc += a[i].abs();
        i += 1;
    }
    acc
}

// ---------------------------------------------------------------------
// Tier-dispatched entry points
// ---------------------------------------------------------------------

/// AVX2/AVX-512 gathers index with *signed* 32-bit offsets; arrays past
/// `i32::MAX` elements fall back to the unrolled kernels (no dataset in
/// this crate's scale comes near 2^31 samples).
#[cfg(target_arch = "x86_64")]
const MAX_GATHER_LEN: usize = i32::MAX as usize;

/// Gather-based column dot at the given tier: `sum_i vals[i] *
/// d[rows[i]]`. The tier is clamped to the probed hardware capability,
/// so a stale or hostile tier value can never select an unsupported
/// instruction set.
///
/// # Safety
///
/// Every `rows[i]` must be `< d.len()` (the CSC row-bound invariant;
/// validated by `CscMatrix::from_parts`). `rows` and `vals` must be the
/// same length.
#[inline]
pub unsafe fn dot_gather(tier: KernelTier, rows: &[u32], vals: &[f64], d: &[f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    match tier.min(hw_tier()) {
        KernelTier::Avx512 if d.len() <= MAX_GATHER_LEN => x86::dot_avx512(rows, vals, d),
        KernelTier::Avx2 if d.len() <= MAX_GATHER_LEN => x86::dot_avx2(rows, vals, d),
        _ => dot_unrolled(rows, vals, d),
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = tier;
        dot_unrolled(rows, vals, d)
    }
}

/// Scatter-based column axpy at the given tier, through a raw base
/// pointer: `y[rows[i]] += alpha * vals[i]`. Bit-identical to the
/// scalar scatter at every tier (elementwise multiply-then-add; the
/// AVX-512 arm's gather/scatter lanes are unique because CSC rows are
/// strictly sorted). The tier is clamped to the probed hardware
/// capability.
///
/// # Safety
///
/// `y` must point to a live `f64` array indexable by every entry of
/// `rows`; `rows` must be strictly increasing (the CSC
/// sorted-and-unique invariant — required for the AVX-512
/// gather-modify-scatter step to be collision-free); and no other
/// thread may access the touched elements during the call. The caller
/// must also ensure `y`'s length fits in `i32` when a SIMD tier is
/// requested (`CscMatrix` guards on `n_rows`).
#[inline]
pub unsafe fn axpy_scatter_ptr(
    tier: KernelTier,
    rows: &[u32],
    vals: &[f64],
    alpha: f64,
    y: *mut f64,
) {
    #[cfg(target_arch = "x86_64")]
    match tier.min(hw_tier()) {
        KernelTier::Avx512 => x86::axpy_avx512(rows, vals, alpha, y),
        KernelTier::Avx2 => x86::axpy_avx2(rows, vals, alpha, y),
        KernelTier::Scalar => axpy_unrolled_ptr(rows, vals, alpha, y),
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = tier;
        axpy_unrolled_ptr(rows, vals, alpha, y)
    }
}

/// Dense dot at the given tier — the dloss/objective reduction kernel.
/// Safe: contiguous loads over the common prefix of `a` and `b`, tier
/// clamped to hardware capability. Re-associates at every fast tier.
#[inline]
pub fn dot_dense(tier: KernelTier, a: &[f64], b: &[f64]) -> f64 {
    let len = a.len().min(b.len());
    let (a, b) = (&a[..len], &b[..len]);
    #[cfg(target_arch = "x86_64")]
    // SAFETY: tier is clamped to the probed capability of this host
    unsafe {
        match tier.min(hw_tier()) {
            KernelTier::Avx512 => x86::dot_dense_avx512(a, b),
            KernelTier::Avx2 => x86::dot_dense_avx2(a, b),
            KernelTier::Scalar => dot_dense_unrolled(a, b),
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = tier;
        dot_dense_unrolled(a, b)
    }
}

/// Dense `sum |a_i|` at the given tier — the l1-term reduction kernel.
/// Safe; re-associates at every fast tier.
#[inline]
pub fn sum_abs(tier: KernelTier, a: &[f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: tier is clamped to the probed capability of this host
    unsafe {
        match tier.min(hw_tier()) {
            KernelTier::Avx512 => x86::sum_abs_avx512(a),
            KernelTier::Avx2 => x86::sum_abs_avx2(a),
            KernelTier::Scalar => sum_abs_unrolled(a),
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = tier;
        sum_abs_unrolled(a)
    }
}

// ---------------------------------------------------------------------
// BlockedScatter: the cache-blocked buffered-Update accumulator slab
// ---------------------------------------------------------------------

/// Stride-padded per-thread accumulator slab for the buffered Update
/// discipline — `UpdatePath::Blocked`.
///
/// The classic buffered path allocates one dense accumulator per thread
/// as separate vectors and reduces them element-by-element with a
/// branchy per-buffer fold. This variant packs all `threads` strips
/// into **one** slab, each strip [`padded_stride`]-spaced: strip starts
/// land on 128-byte boundaries (the slab's element 0 is line-aligned
/// and the stride is a whole number of lines) and a full guard line
/// separates consecutive strips, so two threads scattering near their
/// strip edges never false-share a cache line — the parlaylib-lasso
/// stride-padding trick.
///
/// [`drain_range`](Self::drain_range) then folds in 128-byte-aligned
/// blocks: for each 16-element block it accumulates every strip into a
/// stack-local block buffer, zeroes the strips, and commits the block
/// to `z` — one sequential pass per strip per block instead of a
/// per-element strided walk, with arithmetic identical to the classic
/// per-element fold (same strip order, same skip-zeros semantics).
pub struct BlockedScatter {
    slab: SyncF64Vec,
    stride: usize,
    threads: usize,
    n: usize,
}

impl BlockedScatter {
    /// Bytes a slab for `threads` accumulators over `n` elements would
    /// occupy — the same budget accounting the classic buffered path
    /// applies against `EngineConfig::buffer_budget_mb`.
    pub fn bytes(n: usize, threads: usize) -> usize {
        padded_stride(n) * threads * std::mem::size_of::<f64>()
    }

    /// Zeroed slab of `threads` stride-padded strips over `n` elements.
    pub fn new(n: usize, threads: usize) -> Self {
        let stride = padded_stride(n);
        Self {
            slab: SyncF64Vec::zeros(stride * threads.max(1)),
            stride,
            threads: threads.max(1),
            n,
        }
    }

    #[inline]
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Plain accumulate `v` into thread `t`'s strip at element `i`.
    /// Sound under the engine's phase protocol: thread `t` is the
    /// strip's unique accessor during the scatter phase.
    #[inline(always)]
    pub fn add(&self, t: usize, i: usize, v: f64) {
        debug_assert!(t < self.threads && i < self.n);
        self.slab.add(t * self.stride + i, v);
    }

    /// Fold all strips over `range` into `z` and zero them, in
    /// 128-byte-aligned blocks. Callers partition `0..n` with
    /// [`crate::util::par::aligned_chunk`], so `range.start` is
    /// line-aligned and concurrent drainers never share a block.
    pub fn drain_range(&self, z: &SyncF64Vec, range: std::ops::Range<usize>) {
        debug_assert!(range.end <= self.n);
        debug_assert!(range.start % F64S_PER_LINE == 0 || range.start >= range.end);
        let mut block = [0.0f64; F64S_PER_LINE];
        let mut lo = range.start;
        while lo < range.end {
            let hi = (lo + F64S_PER_LINE).min(range.end);
            let w = hi - lo;
            block[..w].fill(0.0);
            let mut any = false;
            for t in 0..self.threads {
                let base = t * self.stride + lo;
                for (o, acc) in block[..w].iter_mut().enumerate() {
                    let v = self.slab.get(base + o);
                    if v != 0.0 {
                        *acc += v;
                        self.slab.set(base + o, 0.0);
                        any = true;
                    }
                }
            }
            if any {
                for (o, &acc) in block[..w].iter().enumerate() {
                    if acc != 0.0 {
                        z.add(lo + o, acc);
                    }
                }
            }
            lo = hi;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ragged_column(rng: &mut crate::util::Pcg64, n: usize, len: usize) -> (Vec<u32>, Vec<f64>) {
        let mut rows: Vec<u32> = rng
            .sample_distinct(n, len.min(n))
            .into_iter()
            .map(|i| i as u32)
            .collect();
        rows.sort_unstable();
        let vals: Vec<f64> = rows.iter().map(|_| rng.range_f64(-2.0, 2.0)).collect();
        (rows, vals)
    }

    #[test]
    fn choice_names_roundtrip() {
        for name in ["auto", "scalar", "avx2", "avx512"] {
            assert_eq!(KernelChoice::by_name(name).unwrap().name(), name);
        }
        assert!(KernelChoice::by_name("sse9").is_err());
    }

    #[test]
    fn tier_order_clamps() {
        assert!(KernelTier::Scalar < KernelTier::Avx2);
        assert!(KernelTier::Avx2 < KernelTier::Avx512);
        // an explicit scalar request never widens
        assert_eq!(dispatch(KernelChoice::Scalar), KernelTier::Scalar);
        // whatever the host is, a request clamps to at most itself
        assert!(dispatch(KernelChoice::Avx2) <= KernelTier::Avx2);
        assert!(dispatch(KernelChoice::Auto) <= KernelTier::Avx512);
    }

    #[test]
    fn mode_resolution_and_names() {
        assert_eq!(resolve(false, KernelChoice::Auto), KernelMode::Reference);
        assert!(!KernelMode::Reference.is_fast());
        assert_eq!(KernelMode::Reference.name(), "reference");
        let fast = resolve(true, KernelChoice::Scalar);
        assert_eq!(fast, KernelMode::Fast(KernelTier::Scalar));
        assert!(fast.is_fast());
        assert_eq!(fast.name(), "scalar");
        assert_eq!(KernelMode::Fast(KernelTier::Avx512).name(), "avx512");
    }

    #[test]
    fn gather_tiers_agree_with_scalar() {
        let mut rng = crate::util::Pcg64::seeded(11);
        let n = 400usize;
        let d: Vec<f64> = (0..n).map(|i| ((i * 7919) % 83) as f64 - 41.0).collect();
        for len in [0usize, 1, 3, 4, 5, 7, 8, 15, 16, 17, 64, 65, 200] {
            let (rows, vals) = ragged_column(&mut rng, n, len);
            let scalar: f64 = rows
                .iter()
                .zip(&vals)
                .map(|(&i, &v)| v * d[i as usize])
                .sum();
            for tier in [KernelTier::Scalar, KernelTier::Avx2, KernelTier::Avx512] {
                // SAFETY: rows sampled < n = d.len()
                let got = unsafe { dot_gather(tier, &rows, &vals, &d) };
                let tol = 1e-12 * scalar.abs().max(1.0);
                assert!(
                    (scalar - got).abs() <= tol,
                    "{tier:?} len={len}: {scalar} vs {got}"
                );
            }
            // the unrolled arm is exactly dot_unrolled
            let via_tier = unsafe { dot_gather(KernelTier::Scalar, &rows, &vals, &d) };
            assert_eq!(via_tier.to_bits(), dot_unrolled(&rows, &vals, &d).to_bits());
        }
    }

    #[test]
    fn axpy_tiers_are_bit_identical() {
        let mut rng = crate::util::Pcg64::seeded(12);
        let n = 300usize;
        let base: Vec<f64> = (0..n).map(|i| (i as f64 * 0.73).sin()).collect();
        for len in [0usize, 1, 4, 7, 8, 9, 16, 31, 64, 150] {
            let (rows, vals) = ragged_column(&mut rng, n, len);
            let mut want = base.clone();
            for (&i, &v) in rows.iter().zip(&vals) {
                want[i as usize] += 0.37 * v;
            }
            for tier in [KernelTier::Scalar, KernelTier::Avx2, KernelTier::Avx512] {
                let mut y = base.clone();
                // SAFETY: rows < n, strictly sorted, single thread
                unsafe { axpy_scatter_ptr(tier, &rows, &vals, 0.37, y.as_mut_ptr()) };
                assert_eq!(y, want, "{tier:?} len={len}");
            }
        }
    }

    #[test]
    fn dense_reductions_agree() {
        let mut rng = crate::util::Pcg64::seeded(13);
        for len in [0usize, 1, 3, 4, 7, 8, 15, 16, 17, 100, 1000] {
            let a: Vec<f64> = (0..len).map(|_| rng.range_f64(-3.0, 3.0)).collect();
            let b: Vec<f64> = (0..len).map(|_| rng.range_f64(-3.0, 3.0)).collect();
            let dot_ref = dot_dense_scalar(&a, &b);
            let abs_ref = sum_abs_scalar(&a);
            for tier in [KernelTier::Scalar, KernelTier::Avx2, KernelTier::Avx512] {
                let dt = dot_dense(tier, &a, &b);
                let st = sum_abs(tier, &a);
                assert!((dot_ref - dt).abs() <= 1e-12 * dot_ref.abs().max(1.0), "{tier:?} len={len}");
                assert!((abs_ref - st).abs() <= 1e-12 * abs_ref.max(1.0), "{tier:?} len={len}");
            }
        }
    }

    #[test]
    fn blocked_scatter_matches_per_element_fold() {
        let mut rng = crate::util::Pcg64::seeded(14);
        for n in [1usize, 15, 16, 17, 100, 333] {
            for threads in [1usize, 2, 4] {
                let blocked = BlockedScatter::new(n, threads);
                let mut want = vec![0.0f64; n];
                for t in 0..threads {
                    for _ in 0..(n * 2) {
                        let i = rng.below(n);
                        let v = rng.range_f64(-1.0, 1.0);
                        blocked.add(t, i, v);
                        want[i] += v;
                    }
                }
                let z = SyncF64Vec::zeros(n);
                // drain in two chunks like the engine's workers do
                let mid = crate::util::par::aligned_chunk(n, 0, 2).end;
                blocked.drain_range(&z, 0..mid);
                blocked.drain_range(&z, mid..n);
                for i in 0..n {
                    assert!(
                        (z.get(i) - want[i]).abs() <= 1e-12 * want[i].abs().max(1.0),
                        "n={n} t={threads} i={i}"
                    );
                }
                // strips are zeroed: a second drain is a no-op
                blocked.drain_range(&z, 0..n);
                for i in 0..n {
                    assert!((z.get(i) - want[i]).abs() <= 1e-12 * want[i].abs().max(1.0));
                }
            }
        }
    }

    #[test]
    fn blocked_scatter_budget_accounting() {
        // stride padding costs at most two extra lines per thread
        let b = BlockedScatter::bytes(1000, 4);
        assert!(b >= 1000 * 4 * 8);
        assert!(b <= (1000 + 32) * 4 * 8);
        assert_eq!(BlockedScatter::new(0, 2).threads(), 2);
    }
}
