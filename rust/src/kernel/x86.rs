//! AVX2 / AVX-512F arms of the kernel layer (x86-64 only).
//!
//! Every function here carries a `#[target_feature]` attribute and is
//! only reachable through the tier-dispatched entry points in the
//! parent module, which clamp the requested tier to what
//! `is_x86_feature_detected!` actually probed — these bodies never run
//! on silicon that lacks their instructions.
//!
//! Arithmetic contract (see the parent module docs): the **dot** and
//! dense-reduction arms use FMA and multi-lane accumulators, so they
//! re-associate the sum (1e-12 engine discipline). The **axpy** arms
//! deliberately avoid FMA — elementwise `mul` then `add`, each element
//! touched exactly once — so they are bit-identical to the scalar
//! scatter at every tier.

#![allow(clippy::missing_safety_doc)] // SAFETY contracts live on the pub dispatchers

use core::arch::x86_64::*;

use super::{prefetch_read, PREFETCH_DIST};

/// Horizontal sum of a 4-lane double register.
#[inline(always)]
unsafe fn hsum256(v: __m256d) -> f64 {
    let lo = _mm256_castpd256_pd128(v);
    let hi = _mm256_extractf128_pd::<1>(v);
    let s = _mm_add_pd(lo, hi);
    _mm_cvtsd_f64(_mm_add_sd(s, _mm_unpackhi_pd(s, s)))
}

/// Gather-based column dot, 8 elements per step (2 × 4-lane gathers
/// feeding 2 FMA accumulator chains), 4-lane cleanup, scalar tail.
#[target_feature(enable = "avx2,fma")]
pub(super) unsafe fn dot_avx2(rows: &[u32], vals: &[f64], d: &[f64]) -> f64 {
    let len = rows.len();
    let dp = d.as_ptr();
    let rp = rows.as_ptr();
    let vp = vals.as_ptr();
    let mut acc0 = _mm256_setzero_pd();
    let mut acc1 = _mm256_setzero_pd();
    let mut i = 0usize;
    while i + 8 <= len {
        if i + PREFETCH_DIST < len {
            prefetch_read(dp.add(*rp.add(i + PREFETCH_DIST) as usize));
        }
        let idx0 = _mm_loadu_si128(rp.add(i) as *const __m128i);
        let idx1 = _mm_loadu_si128(rp.add(i + 4) as *const __m128i);
        let g0 = _mm256_i32gather_pd::<8>(dp, idx0);
        let g1 = _mm256_i32gather_pd::<8>(dp, idx1);
        acc0 = _mm256_fmadd_pd(_mm256_loadu_pd(vp.add(i)), g0, acc0);
        acc1 = _mm256_fmadd_pd(_mm256_loadu_pd(vp.add(i + 4)), g1, acc1);
        i += 8;
    }
    if i + 4 <= len {
        let idx = _mm_loadu_si128(rp.add(i) as *const __m128i);
        let g = _mm256_i32gather_pd::<8>(dp, idx);
        acc0 = _mm256_fmadd_pd(_mm256_loadu_pd(vp.add(i)), g, acc0);
        i += 4;
    }
    let mut acc = hsum256(_mm256_add_pd(acc0, acc1));
    while i < len {
        acc += *vp.add(i) * *dp.add(*rp.add(i) as usize);
        i += 1;
    }
    acc
}

/// Column axpy: vectorized `alpha * vals`, scalar read-modify-write
/// stores (AVX2 has no scatter). `mul` not FMA — bit-identical to the
/// scalar `y[r] += alpha * v`.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn axpy_avx2(rows: &[u32], vals: &[f64], alpha: f64, y: *mut f64) {
    let len = rows.len();
    let rp = rows.as_ptr();
    let vp = vals.as_ptr();
    let a = _mm256_set1_pd(alpha);
    let mut p = [0.0f64; 4];
    let mut i = 0usize;
    while i + 4 <= len {
        if i + PREFETCH_DIST < len {
            prefetch_read(y.add(*rp.add(i + PREFETCH_DIST) as usize) as *const f64);
        }
        _mm256_storeu_pd(p.as_mut_ptr(), _mm256_mul_pd(a, _mm256_loadu_pd(vp.add(i))));
        *y.add(*rp.add(i) as usize) += p[0];
        *y.add(*rp.add(i + 1) as usize) += p[1];
        *y.add(*rp.add(i + 2) as usize) += p[2];
        *y.add(*rp.add(i + 3) as usize) += p[3];
        i += 4;
    }
    while i < len {
        *y.add(*rp.add(i) as usize) += alpha * *vp.add(i);
        i += 1;
    }
}

/// Gather-based column dot, 16 elements per step (2 × 8-lane gathers,
/// 2 FMA chains), 8-lane cleanup, scalar tail. Note the AVX-512 gather
/// signature: `(offsets, base as *const u8)` — reversed from AVX2.
#[target_feature(enable = "avx512f")]
pub(super) unsafe fn dot_avx512(rows: &[u32], vals: &[f64], d: &[f64]) -> f64 {
    let len = rows.len();
    let dp = d.as_ptr();
    let rp = rows.as_ptr();
    let vp = vals.as_ptr();
    let mut acc0 = _mm512_setzero_pd();
    let mut acc1 = _mm512_setzero_pd();
    let mut i = 0usize;
    while i + 16 <= len {
        if i + PREFETCH_DIST < len {
            prefetch_read(dp.add(*rp.add(i + PREFETCH_DIST) as usize));
        }
        let idx0 = _mm256_loadu_si256(rp.add(i) as *const __m256i);
        let idx1 = _mm256_loadu_si256(rp.add(i + 8) as *const __m256i);
        let g0 = _mm512_i32gather_pd::<8>(idx0, dp as *const u8);
        let g1 = _mm512_i32gather_pd::<8>(idx1, dp as *const u8);
        acc0 = _mm512_fmadd_pd(_mm512_loadu_pd(vp.add(i)), g0, acc0);
        acc1 = _mm512_fmadd_pd(_mm512_loadu_pd(vp.add(i + 8)), g1, acc1);
        i += 16;
    }
    if i + 8 <= len {
        let idx = _mm256_loadu_si256(rp.add(i) as *const __m256i);
        let g = _mm512_i32gather_pd::<8>(idx, dp as *const u8);
        acc0 = _mm512_fmadd_pd(_mm512_loadu_pd(vp.add(i)), g, acc0);
        i += 8;
    }
    let mut acc = _mm512_reduce_add_pd(_mm512_add_pd(acc0, acc1));
    while i < len {
        acc += *vp.add(i) * *dp.add(*rp.add(i) as usize);
        i += 1;
    }
    acc
}

/// Column axpy with native gather-modify-scatter, 8 lanes per step.
/// Collision-free because CSC rows are strictly increasing within a
/// column (unique lanes — the dispatcher's safety contract). `add(g,
/// mul(a, v))` matches the scalar `y[r] + alpha * v` rounding exactly:
/// bit-identical, like every axpy tier.
#[target_feature(enable = "avx512f")]
pub(super) unsafe fn axpy_avx512(rows: &[u32], vals: &[f64], alpha: f64, y: *mut f64) {
    let len = rows.len();
    let rp = rows.as_ptr();
    let vp = vals.as_ptr();
    let a = _mm512_set1_pd(alpha);
    let mut i = 0usize;
    while i + 8 <= len {
        if i + PREFETCH_DIST < len {
            prefetch_read(y.add(*rp.add(i + PREFETCH_DIST) as usize) as *const f64);
        }
        let idx = _mm256_loadu_si256(rp.add(i) as *const __m256i);
        let g = _mm512_i32gather_pd::<8>(idx, y as *const u8);
        let r = _mm512_add_pd(g, _mm512_mul_pd(a, _mm512_loadu_pd(vp.add(i))));
        _mm512_i32scatter_pd::<8>(y as *mut u8, idx, r);
        i += 8;
    }
    while i < len {
        *y.add(*rp.add(i) as usize) += alpha * *vp.add(i);
        i += 1;
    }
}

/// Dense dot, 8 per step, 2 FMA chains.
#[target_feature(enable = "avx2,fma")]
pub(super) unsafe fn dot_dense_avx2(a: &[f64], b: &[f64]) -> f64 {
    let len = a.len();
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc0 = _mm256_setzero_pd();
    let mut acc1 = _mm256_setzero_pd();
    let mut i = 0usize;
    while i + 8 <= len {
        acc0 = _mm256_fmadd_pd(_mm256_loadu_pd(ap.add(i)), _mm256_loadu_pd(bp.add(i)), acc0);
        acc1 = _mm256_fmadd_pd(
            _mm256_loadu_pd(ap.add(i + 4)),
            _mm256_loadu_pd(bp.add(i + 4)),
            acc1,
        );
        i += 8;
    }
    let mut acc = hsum256(_mm256_add_pd(acc0, acc1));
    while i < len {
        acc += *ap.add(i) * *bp.add(i);
        i += 1;
    }
    acc
}

/// Dense dot, 16 per step, 2 FMA chains.
#[target_feature(enable = "avx512f")]
pub(super) unsafe fn dot_dense_avx512(a: &[f64], b: &[f64]) -> f64 {
    let len = a.len();
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc0 = _mm512_setzero_pd();
    let mut acc1 = _mm512_setzero_pd();
    let mut i = 0usize;
    while i + 16 <= len {
        acc0 = _mm512_fmadd_pd(_mm512_loadu_pd(ap.add(i)), _mm512_loadu_pd(bp.add(i)), acc0);
        acc1 = _mm512_fmadd_pd(
            _mm512_loadu_pd(ap.add(i + 8)),
            _mm512_loadu_pd(bp.add(i + 8)),
            acc1,
        );
        i += 16;
    }
    let mut acc = _mm512_reduce_add_pd(_mm512_add_pd(acc0, acc1));
    while i < len {
        acc += *ap.add(i) * *bp.add(i);
        i += 1;
    }
    acc
}

/// Dense `sum |a_i|`: abs via andnot with the sign-bit mask.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn sum_abs_avx2(a: &[f64]) -> f64 {
    let len = a.len();
    let ap = a.as_ptr();
    let sign = _mm256_set1_pd(-0.0);
    let mut acc0 = _mm256_setzero_pd();
    let mut acc1 = _mm256_setzero_pd();
    let mut i = 0usize;
    while i + 8 <= len {
        acc0 = _mm256_add_pd(acc0, _mm256_andnot_pd(sign, _mm256_loadu_pd(ap.add(i))));
        acc1 = _mm256_add_pd(acc1, _mm256_andnot_pd(sign, _mm256_loadu_pd(ap.add(i + 4))));
        i += 8;
    }
    let mut acc = hsum256(_mm256_add_pd(acc0, acc1));
    while i < len {
        acc += (*ap.add(i)).abs();
        i += 1;
    }
    acc
}

/// Dense `sum |a_i|` with the native AVX-512 abs.
#[target_feature(enable = "avx512f")]
pub(super) unsafe fn sum_abs_avx512(a: &[f64]) -> f64 {
    let len = a.len();
    let ap = a.as_ptr();
    let mut acc0 = _mm512_setzero_pd();
    let mut acc1 = _mm512_setzero_pd();
    let mut i = 0usize;
    while i + 16 <= len {
        acc0 = _mm512_add_pd(acc0, _mm512_abs_pd(_mm512_loadu_pd(ap.add(i))));
        acc1 = _mm512_add_pd(acc1, _mm512_abs_pd(_mm512_loadu_pd(ap.add(i + 8))));
        i += 16;
    }
    let mut acc = _mm512_reduce_add_pd(_mm512_add_pd(acc0, acc1));
    while i < len {
        acc += (*ap.add(i)).abs();
        i += 1;
    }
    acc
}
