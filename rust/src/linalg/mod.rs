//! Dense linear-algebra helpers: the power-iteration estimate of the
//! spectral radius of `X^T X`, which determines Shotgun's safe
//! parallelism bound `P* = k / (2 rho)` (paper Sec. 4.1).

use crate::sparse::CscMatrix;
use crate::util::Pcg64;

/// Result of a spectral-radius estimation run.
#[derive(Clone, Copy, Debug)]
pub struct SpectralEstimate {
    /// Estimated maximal eigenvalue of `X^T X`.
    pub rho: f64,
    /// Iterations performed.
    pub iters: usize,
    /// Relative change of the estimate at the last iteration.
    pub rel_change: f64,
}

/// Power iteration on `X^T X` (never materialized: each step is one
/// `X v` and one `X^T (X v)` sparse pass).
pub fn spectral_radius_xtx(
    x: &CscMatrix,
    max_iters: usize,
    tol: f64,
    seed: u64,
) -> SpectralEstimate {
    let k = x.n_cols();
    if k == 0 || x.nnz() == 0 {
        return SpectralEstimate {
            rho: 0.0,
            iters: 0,
            rel_change: 0.0,
        };
    }
    let mut rng = Pcg64::seeded(seed);
    let mut v: Vec<f64> = (0..k).map(|_| rng.next_normal()).collect();
    normalize(&mut v);

    let mut rho = 0.0;
    let mut rel_change = f64::INFINITY;
    let mut iters = 0;
    for it in 0..max_iters {
        let xv = x.matvec(&v);
        let mut xtxv = x.matvec_t(&xv);
        // Rayleigh quotient: v is unit, so rho ~ <v, X^T X v> = |X v|^2
        let new_rho: f64 = xv.iter().map(|t| t * t).sum();
        let norm = normalize(&mut xtxv);
        if norm == 0.0 {
            rho = 0.0;
            iters = it + 1;
            rel_change = 0.0;
            break;
        }
        v = xtxv;
        rel_change = (new_rho - rho).abs() / new_rho.max(1e-300);
        rho = new_rho;
        iters = it + 1;
        if rel_change < tol {
            break;
        }
    }
    SpectralEstimate {
        rho,
        iters,
        rel_change,
    }
}

/// Shotgun's maximum safe parallel update count `P* = k / (2 rho)`,
/// clamped to at least 1 (Bradley et al. 2011, as used in Sec. 4.1).
pub fn shotgun_pstar(n_features: usize, rho: f64) -> usize {
    if rho <= 0.0 {
        return n_features.max(1);
    }
    ((n_features as f64 / (2.0 * rho)).floor() as usize).max(1)
}

/// Normalize to unit L2 norm in place; returns the original norm.
pub fn normalize(v: &mut [f64]) -> f64 {
    let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    if norm > 0.0 {
        for x in v.iter_mut() {
            *x /= norm;
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooBuilder;

    #[test]
    fn identity_block_has_rho_one() {
        // orthonormal columns => X^T X = I => rho = 1
        let mut b = CooBuilder::new(6, 6);
        for i in 0..6 {
            b.push(i, i, 1.0);
        }
        let m = b.build();
        let est = spectral_radius_xtx(&m, 200, 1e-12, 1);
        assert!((est.rho - 1.0).abs() < 1e-9, "rho={}", est.rho);
    }

    #[test]
    fn duplicated_column_doubles_rho() {
        // two identical unit columns: X^T X = [[1,1],[1,1]], rho = 2
        let mut b = CooBuilder::new(4, 2);
        b.push(0, 0, 1.0);
        b.push(0, 1, 1.0);
        let m = b.build();
        let est = spectral_radius_xtx(&m, 500, 1e-14, 2);
        assert!((est.rho - 2.0).abs() < 1e-6, "rho={}", est.rho);
    }

    #[test]
    fn matches_dense_eigen_small() {
        // random 5x4, compare against the dominant eigenvalue obtained by
        // straightforward dense power iteration with many iterations.
        let mut rng = Pcg64::seeded(3);
        let mut b = CooBuilder::new(5, 4);
        for i in 0..5 {
            for j in 0..4 {
                if rng.next_f64() < 0.7 {
                    b.push(i, j, rng.range_f64(-1.0, 1.0));
                }
            }
        }
        let m = b.build();
        let dense = m.to_dense();
        // dense X^T X
        let mut xtx = [[0.0f64; 4]; 4];
        for a in 0..4 {
            for c in 0..4 {
                xtx[a][c] = (0..5).map(|i| dense[i][a] * dense[i][c]).sum();
            }
        }
        let mut v = [1.0, 0.5, -0.3, 0.8];
        let mut lam = 0.0;
        for _ in 0..2000 {
            let mut nv = [0.0; 4];
            for a in 0..4 {
                for c in 0..4 {
                    nv[a] += xtx[a][c] * v[c];
                }
            }
            lam = (0..4).map(|a| nv[a] * v[a]).sum::<f64>()
                / (0..4).map(|a| v[a] * v[a]).sum::<f64>();
            let norm = nv.iter().map(|x| x * x).sum::<f64>().sqrt();
            for a in 0..4 {
                v[a] = nv[a] / norm;
            }
        }
        let est = spectral_radius_xtx(&m, 2000, 1e-14, 4);
        assert!(
            (est.rho - lam).abs() < 1e-6 * lam.max(1.0),
            "sparse {} dense {}",
            est.rho,
            lam
        );
    }

    #[test]
    fn pstar_formula() {
        // paper Table 3: DOROTHEA has k=100000, P*~23 => rho ~ 2174
        assert_eq!(shotgun_pstar(100_000, 2173.9), 23);
        assert_eq!(shotgun_pstar(10, 0.0), 10);
        assert_eq!(shotgun_pstar(10, 1e9), 1);
    }

    #[test]
    fn zero_matrix() {
        let m = CooBuilder::new(3, 3).build();
        let est = spectral_radius_xtx(&m, 10, 1e-10, 5);
        assert_eq!(est.rho, 0.0);
    }
}
