//! Loss functions with bounded convexity (paper Sec. 3.2).
//!
//! A [`Loss`] provides the pointwise value `ell(y, t)`, its derivative in
//! the fitted value `t`, and a global bound `beta >= d^2/dt^2 ell` used by
//! the Eq. (7) quadratic-upper-bound step. The three instances mirror the
//! paper: squared (Lasso, beta = 1), logistic (beta = 1/4), plus a
//! smoothed hinge (beta = 1/gamma) as the extension exercise.

use crate::sparse::CscMatrix;

/// A convex, twice-differentiable-in-t loss with bounded second
/// derivative.
pub trait Loss: Send + Sync {
    /// Pointwise loss `ell(y, t)`.
    fn value(&self, y: f64, t: f64) -> f64;
    /// `d/dt ell(y, t)`.
    fn deriv(&self, y: f64, t: f64) -> f64;
    /// Global upper bound on `d^2/dt^2 ell` (Sec. 3.2).
    fn beta(&self) -> f64;
    /// Stable identifier (matches the python kernels' `loss` arg).
    fn name(&self) -> &'static str;
    /// Boxed copy — the sharded execution layer gives each shard
    /// sub-problem its own loss instance (`#[derive(Clone)]` plus
    /// `Box::new(self.clone())` is the standard implementation).
    fn clone_box(&self) -> Box<dyn Loss>;
}

/// Squared loss `(y - t)^2 / 2` — Lasso. Exact coordinate minimization
/// (Sec. 3.1) coincides with the Eq. (7) step since `ell'' == 1`.
#[derive(Clone, Copy, Debug, Default)]
pub struct Squared;

impl Loss for Squared {
    #[inline]
    fn value(&self, y: f64, t: f64) -> f64 {
        0.5 * (y - t) * (y - t)
    }

    #[inline]
    fn deriv(&self, y: f64, t: f64) -> f64 {
        t - y
    }

    #[inline]
    fn beta(&self) -> f64 {
        1.0
    }

    fn clone_box(&self) -> Box<dyn Loss> {
        Box::new(*self)
    }

    fn name(&self) -> &'static str {
        "squared"
    }
}

/// Logistic loss `log(1 + exp(-y t))` with labels in {-1, +1}.
#[derive(Clone, Copy, Debug, Default)]
pub struct Logistic;

impl Loss for Logistic {
    #[inline]
    fn value(&self, y: f64, t: f64) -> f64 {
        // stable log1p(exp(m)) for m = -y t
        let m = -y * t;
        if m > 35.0 {
            m
        } else {
            m.exp().ln_1p()
        }
    }

    #[inline]
    fn deriv(&self, y: f64, t: f64) -> f64 {
        // -y * sigmoid(-y t), stable in both tails
        let m = y * t;
        if m > 35.0 {
            -y * (-m).exp()
        } else {
            -y / (1.0 + m.exp())
        }
    }

    #[inline]
    fn beta(&self) -> f64 {
        0.25
    }

    fn clone_box(&self) -> Box<dyn Loss> {
        Box::new(*self)
    }

    fn name(&self) -> &'static str {
        "logistic"
    }
}

/// Quadratically-smoothed hinge (Shalev-Shwartz & Tewari's smooth hinge):
/// gamma-smoothed, so `beta = 1/gamma`. Not in the paper's experiments;
/// included as the "domain researchers tailor the framework" extension.
#[derive(Clone, Copy, Debug)]
pub struct SmoothedHinge {
    pub gamma: f64,
}

impl Default for SmoothedHinge {
    fn default() -> Self {
        Self { gamma: 1.0 }
    }
}

impl Loss for SmoothedHinge {
    #[inline]
    fn value(&self, y: f64, t: f64) -> f64 {
        let m = y * t;
        if m >= 1.0 {
            0.0
        } else if m <= 1.0 - self.gamma {
            1.0 - m - self.gamma / 2.0
        } else {
            (1.0 - m) * (1.0 - m) / (2.0 * self.gamma)
        }
    }

    #[inline]
    fn deriv(&self, y: f64, t: f64) -> f64 {
        let m = y * t;
        if m >= 1.0 {
            0.0
        } else if m <= 1.0 - self.gamma {
            -y
        } else {
            -y * (1.0 - m) / self.gamma
        }
    }

    #[inline]
    fn beta(&self) -> f64 {
        1.0 / self.gamma
    }

    fn clone_box(&self) -> Box<dyn Loss> {
        Box::new(*self)
    }

    fn name(&self) -> &'static str {
        "smoothed_hinge"
    }
}

/// Look up a loss by name.
pub fn by_name(name: &str) -> anyhow::Result<Box<dyn Loss>> {
    match name {
        "squared" => Ok(Box::new(Squared)),
        "logistic" => Ok(Box::new(Logistic)),
        "smoothed_hinge" => Ok(Box::new(SmoothedHinge::default())),
        other => anyhow::bail!("unknown loss '{other}'"),
    }
}

/// The full objective (Eq. 1): `F(w) + lam * |w|_1` with
/// `F(w) = (1/n) sum_i ell(y_i, z_i)` evaluated from fitted values `z`.
pub fn objective(loss: &dyn Loss, y: &[f64], z: &[f64], w: &[f64], lam: f64) -> f64 {
    smooth_part(loss, y, z) + lam * l1_norm(w)
}

/// `F(w)` from fitted values.
pub fn smooth_part(loss: &dyn Loss, y: &[f64], z: &[f64]) -> f64 {
    debug_assert_eq!(y.len(), z.len());
    let n = y.len().max(1);
    y.iter()
        .zip(z)
        .map(|(&yi, &zi)| loss.value(yi, zi))
        .sum::<f64>()
        / n as f64
}

/// `|w|_1`.
pub fn l1_norm(w: &[f64]) -> f64 {
    w.iter().map(|x| x.abs()).sum()
}

/// Count of nonzero weights (the paper's NNZ convergence metric).
pub fn nnz(w: &[f64]) -> usize {
    w.iter().filter(|x| **x != 0.0).count()
}

/// Full gradient `grad F(w) = X^T ell'(y, z) / n` (reference/tests).
pub fn full_gradient(loss: &dyn Loss, x: &CscMatrix, y: &[f64], z: &[f64]) -> Vec<f64> {
    let n = x.n_rows() as f64;
    let d: Vec<f64> = y.iter().zip(z).map(|(&yi, &zi)| loss.deriv(yi, zi)).collect();
    let mut g = x.matvec_t(&d);
    for gj in &mut g {
        *gj /= n;
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn losses() -> Vec<Box<dyn Loss>> {
        vec![
            Box::new(Squared),
            Box::new(Logistic),
            Box::new(SmoothedHinge::default()),
            Box::new(SmoothedHinge { gamma: 0.5 }),
        ]
    }

    #[test]
    fn logistic_values() {
        let l = Logistic;
        assert!((l.value(1.0, 0.0) - (2f64).ln()).abs() < 1e-12);
        assert!((l.deriv(1.0, 0.0) + 0.5).abs() < 1e-12);
        // tails are finite and stable
        assert!(l.value(1.0, -1000.0).is_finite());
        assert!(l.value(1.0, 1000.0) >= 0.0);
        assert!(l.deriv(-1.0, -1000.0).abs() < 1e-10);
    }

    #[test]
    fn squared_values() {
        let l = Squared;
        assert_eq!(l.value(2.0, 0.5), 1.125);
        assert_eq!(l.deriv(2.0, 0.5), -1.5);
    }

    #[test]
    fn prop_deriv_matches_finite_difference() {
        prop::check("deriv ~ fd", 100, |rng, _| {
            let y = if rng.next_f64() < 0.5 { -1.0 } else { 1.0 };
            let t = rng.range_f64(-5.0, 5.0);
            let h = 1e-6;
            for l in losses() {
                let fd = (l.value(y, t + h) - l.value(y, t - h)) / (2.0 * h);
                let d = l.deriv(y, t);
                if (fd - d).abs() > 1e-4 * (1.0 + d.abs()) {
                    return Err(format!("{}: y={y} t={t}: fd={fd} d={d}", l.name()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_beta_bounds_curvature() {
        prop::check("beta >= ell'' (fd)", 100, |rng, _| {
            let y = if rng.next_f64() < 0.5 { -1.0 } else { 1.0 };
            let t = rng.range_f64(-5.0, 5.0);
            let h = 1e-4;
            for l in losses() {
                let dd = (l.deriv(y, t + h) - l.deriv(y, t - h)) / (2.0 * h);
                if dd > l.beta() + 1e-2 {
                    return Err(format!("{}: y={y} t={t}: ell''={dd} beta={}", l.name(), l.beta()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_convexity() {
        prop::check("losses convex in t", 100, |rng, _| {
            let y = if rng.next_f64() < 0.5 { -1.0 } else { 1.0 };
            let a = rng.range_f64(-4.0, 4.0);
            let b = rng.range_f64(-4.0, 4.0);
            let th = rng.next_f64();
            for l in losses() {
                let lhs = l.value(y, th * a + (1.0 - th) * b);
                let rhs = th * l.value(y, a) + (1.0 - th) * l.value(y, b);
                if lhs > rhs + 1e-9 {
                    return Err(format!("{}: {lhs} > {rhs}", l.name()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn objective_composes() {
        let m = crate::sparse::csc::small_fixture();
        let w = vec![0.5, -1.0, 0.0];
        let y = vec![1.0, -1.0, 1.0, -1.0];
        let z = m.matvec(&w);
        let obj = objective(&Squared, &y, &z, &w, 0.1);
        let f = smooth_part(&Squared, &y, &z);
        assert!((obj - (f + 0.1 * 1.5)).abs() < 1e-12);
        assert_eq!(nnz(&w), 2);
    }

    #[test]
    fn full_gradient_matches_dense() {
        let m = crate::sparse::csc::small_fixture();
        let y = vec![1.0, -1.0, 1.0, -1.0];
        let w = vec![0.1, 0.2, -0.3];
        let z = m.matvec(&w);
        let g = full_gradient(&Logistic, &m, &y, &z);
        let dense = m.to_dense();
        for j in 0..3 {
            let want: f64 = (0..4)
                .map(|i| Logistic.deriv(y[i], z[i]) * dense[i][j])
                .sum::<f64>()
                / 4.0;
            assert!((g[j] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(by_name("logistic").unwrap().name(), "logistic");
        assert!(by_name("nope").is_err());
    }

    #[test]
    fn clone_box_preserves_identity_and_params() {
        for l in losses() {
            let c = l.clone_box();
            assert_eq!(c.name(), l.name());
            assert_eq!(c.beta(), l.beta(), "{}", l.name());
            assert_eq!(c.value(1.0, 0.3), l.value(1.0, 0.3));
        }
    }
}
