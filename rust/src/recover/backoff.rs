//! Bounded exponential backoff for peer reconnects.
//!
//! The policy is deliberately tiny and fully deterministic: delay for
//! attempt `a` is `min(cap, base << a)` plus seeded jitter in
//! `[0, base/2]`. Determinism matters twice — tests can pin the exact
//! schedule, and the worst-case total (`worst_case_ms`) is a closed
//! form the "never a hang" acceptance bound leans on: with the default
//! policy a peer that never comes back costs well under a second of
//! dialing before the link degrades to `ShardFailed`.

use crate::util::rng::Pcg64;

/// RNG stream tag for backoff jitter — distinct from every solver
/// stream so reconnect timing can never perturb policy randomness.
const JITTER_STREAM: u64 = 0xB0FF;

/// Per-peer reconnect policy for [`TcpLink`](crate::net::tcp::TcpLink).
/// `max_attempts == 0` disables reconnection entirely (the pre-recover
/// behavior: first socket error poisons the link).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReconnectPolicy {
    /// Redial attempts before the peer is declared dead. 0 = disabled.
    pub max_attempts: u32,
    /// Base delay before the first redial, in milliseconds.
    pub base_ms: u64,
    /// Ceiling on any single delay, in milliseconds.
    pub cap_ms: u64,
    /// Jitter seed (deterministic per `(seed, attempt)` pair).
    pub seed: u64,
}

impl Default for ReconnectPolicy {
    fn default() -> Self {
        ReconnectPolicy { max_attempts: 0, base_ms: 50, cap_ms: 1000, seed: 1 }
    }
}

impl ReconnectPolicy {
    /// A policy with `attempts` redials and the default delay shape.
    pub fn with_attempts(attempts: u32, seed: u64) -> ReconnectPolicy {
        ReconnectPolicy { max_attempts: attempts, seed, ..ReconnectPolicy::default() }
    }

    /// Whether reconnection is enabled at all.
    pub fn enabled(&self) -> bool {
        self.max_attempts > 0
    }

    /// Delay before redial `attempt` (0-based):
    /// `min(cap, base << attempt) + jitter`, jitter in `[0, base/2]`.
    pub fn delay_ms(&self, attempt: u32) -> u64 {
        let base = self.base_ms.max(1);
        let exp = base.checked_shl(attempt).unwrap_or(u64::MAX).min(self.cap_ms.max(base));
        let jitter_span = base / 2;
        let jitter = if jitter_span == 0 {
            0
        } else {
            // one draw per (seed, attempt): reproducible without state
            Pcg64::new(self.seed ^ attempt as u64, JITTER_STREAM).below(jitter_span + 1)
        };
        exp + jitter
    }

    /// Upper bound on the total time spent sleeping between redials if
    /// every attempt fails — the budget the <30 s degrade bound is
    /// checked against.
    pub fn worst_case_ms(&self) -> u64 {
        (0..self.max_attempts)
            .map(|a| self.delay_ms(a))
            .fold(0u64, |acc, d| acc.saturating_add(d))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_by_default() {
        assert!(!ReconnectPolicy::default().enabled());
        assert!(ReconnectPolicy::with_attempts(3, 1).enabled());
    }

    #[test]
    fn delays_grow_then_cap() {
        let p = ReconnectPolicy { max_attempts: 8, base_ms: 50, cap_ms: 400, seed: 9 };
        for a in 0..8 {
            let d = p.delay_ms(a);
            let exp = (50u64 << a).min(400);
            assert!(d >= exp, "attempt {a}: {d} < {exp}");
            assert!(d <= exp + 25, "attempt {a}: {d} > {exp} + jitter span");
        }
    }

    #[test]
    fn schedule_is_deterministic() {
        let p = ReconnectPolicy { max_attempts: 5, base_ms: 50, cap_ms: 1000, seed: 42 };
        let a: Vec<u64> = (0..5).map(|i| p.delay_ms(i)).collect();
        let b: Vec<u64> = (0..5).map(|i| p.delay_ms(i)).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn worst_case_fits_the_degrade_bound() {
        // the default shape at 5 attempts must sit far inside the 30 s
        // acceptance ceiling even before socket timeouts are added
        let p = ReconnectPolicy::with_attempts(5, 7);
        assert!(p.worst_case_ms() < 5_000, "worst case {} ms", p.worst_case_ms());
    }

    #[test]
    fn huge_attempt_does_not_overflow() {
        let p = ReconnectPolicy { max_attempts: u32::MAX, base_ms: 50, cap_ms: 1000, seed: 1 };
        assert!(p.delay_ms(63) <= 1000 + 25);
        assert!(p.delay_ms(200) <= 1000 + 25);
    }
}
