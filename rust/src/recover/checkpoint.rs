//! Versioned, CRC-guarded solve checkpoints.
//!
//! A checkpoint is the coordinator's reconciled view of a sharded solve
//! frozen at a round boundary: the iterate `w`, the canonical residual
//! replica `z`, and the handful of scalars the adaptive machinery needs
//! to pick up where it left off (round count, published reconcile gap,
//! reconcile cadence state, tolerance streak, last logged objective).
//! The codec reuses [`net::codec`](crate::net::codec)'s
//! `EncoderValue`/`DecoderValue` discipline — every read of untrusted
//! bytes goes through the checked [`DecoderBuffer`] cursor, every
//! failure is a typed [`CheckpointError`], and malformed, truncated, or
//! bit-flipped files can never panic (pinned by the 100-seed fuzz in
//! `rust/tests/recover.rs`).
//!
//! # File layout (version 1, all little-endian)
//!
//! ```text
//! magic      u32   "GCKP"
//! version    u16   1
//! flags      u16   0 (reserved)
//! round      u64   completed global iterations at the snapshot
//! next_gap   u64   reconcile gap published with the snapshot round
//! seed       u64   builder seed (resume validates against it)
//! shards     u32   shard count (resume validates against it)
//! n_features u64   len(w)
//! n_samples  u64   len(z)
//! lambda     f64   the λ the snapshot was taken at
//! updates    u64   cumulative coordinate updates
//! r_cur      u64   adaptive reconcile cadence state
//! div_ewma   f64   divergence EWMA (objective tripwire state)
//! tol_hits   u32   consecutive tolerance hits
//! last_obj   f64   last logged objective (NaN encodes "none")
//! w          f64 × n_features
//! z          f64 × n_samples
//! crc        u32   CRC-32 (IEEE) over every preceding byte
//! ```
//!
//! Writes are atomic: the file is staged to `<path>.tmp` and renamed
//! into place, so a crash mid-write (the exact fault the harness's
//! `kill -9` drill injects) leaves either the previous checkpoint or a
//! complete new one — never a torn file. A torn *read* is still safe:
//! the trailing CRC rejects it as [`CheckpointError::Crc`].

use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::net::codec::{DecodeError, DecoderBuffer, EncoderBuffer};

/// File magic: `"GCKP"` as a little-endian `u32`.
pub const CHECKPOINT_MAGIC: u32 = u32::from_le_bytes(*b"GCKP");

/// Current (and only) checkpoint format version.
pub const CHECKPOINT_VERSION: u16 = 1;

/// Fixed-size header byte count: everything before `w` in the layout.
const HEADER_LEN: usize = 4 + 2 + 2 + 8 + 8 + 8 + 4 + 8 + 8 + 8 + 8 + 8 + 8 + 4 + 8;

/// Why loading a checkpoint failed. Mirrors the wire codec's rule:
/// untrusted bytes produce typed errors, never panics.
#[derive(Debug)]
pub enum CheckpointError {
    /// The file could not be read or written.
    Io(std::io::Error),
    /// The byte stream was structurally malformed (truncated field,
    /// wrong magic, inconsistent lengths) — the underlying codec error
    /// says which.
    Malformed(DecodeError),
    /// The file declares a format version this build does not speak.
    Version(u16),
    /// The trailing CRC-32 disagrees with the bytes — a torn write or
    /// bit rot.
    Crc { stored: u32, computed: u32 },
    /// The checkpoint is well-formed but does not match the solve it
    /// was offered to (wrong shape, seed, shard count, or λ).
    Mismatch(String),
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "checkpoint io: {e}"),
            CheckpointError::Malformed(e) => write!(f, "checkpoint malformed: {e}"),
            CheckpointError::Version(v) => {
                write!(f, "checkpoint version {v} unsupported (expected {CHECKPOINT_VERSION})")
            }
            CheckpointError::Crc { stored, computed } => write!(
                f,
                "checkpoint crc mismatch: stored {stored:#010x}, computed {computed:#010x}"
            ),
            CheckpointError::Mismatch(what) => write!(f, "checkpoint mismatch: {what}"),
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            CheckpointError::Malformed(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

impl From<DecodeError> for CheckpointError {
    fn from(e: DecodeError) -> Self {
        CheckpointError::Malformed(e)
    }
}

/// CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) with a
/// compile-time table — no external crate, matches the checksum every
/// standard tool (`cksum -a crc32`, zlib) computes.
const CRC32_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
};

/// CRC-32 (IEEE) of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &b in bytes {
        crc = (crc >> 8) ^ CRC32_TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

/// A decoded checkpoint: the reconciled solve state at a round
/// boundary. See the module docs for the byte layout.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Completed global iterations when the snapshot was taken.
    pub round: u64,
    /// The reconcile gap the coordinator published with this round —
    /// resume seeds its schedule from it so the reconcile cadence of a
    /// resumed run lines up with the uninterrupted one.
    pub next_gap: u64,
    /// The builder seed of the originating solve. Select policies are
    /// deterministic streams of this seed, so matching it is what makes
    /// bit-exact resume possible.
    pub seed: u64,
    /// Shard count of the originating solve.
    pub shards: u32,
    /// λ at the snapshot.
    pub lambda: f64,
    /// Cumulative coordinate updates at the snapshot.
    pub updates: u64,
    /// Adaptive reconcile cadence state (`r_cur`).
    pub r_cur: u64,
    /// Objective-tripwire divergence EWMA.
    pub div_ewma: f64,
    /// Consecutive tolerance hits toward `StopReason::Tolerance`.
    pub tol_hits: u32,
    /// Last logged objective, if any round had been logged.
    pub last_objective: Option<f64>,
    /// The reconciled iterate (length = features).
    pub w: Vec<f64>,
    /// The canonical residual replica (length = samples).
    pub z: Vec<f64>,
}

impl Checkpoint {
    /// Exact encoded size in bytes (header + payload + CRC).
    pub fn encoded_len(&self) -> usize {
        HEADER_LEN + 8 * self.w.len() + 8 * self.z.len() + 4
    }

    /// Serialize to the version-1 layout, CRC appended.
    pub fn encode(&self) -> Vec<u8> {
        let mut bytes = Vec::with_capacity(self.encoded_len());
        let mut e = EncoderBuffer::new(&mut bytes);
        e.u32(CHECKPOINT_MAGIC);
        e.u16(CHECKPOINT_VERSION);
        e.u16(0); // flags, reserved
        e.u64(self.round);
        e.u64(self.next_gap);
        e.u64(self.seed);
        e.u32(self.shards);
        e.u64(self.w.len() as u64);
        e.u64(self.z.len() as u64);
        e.f64(self.lambda);
        e.u64(self.updates);
        e.u64(self.r_cur);
        e.f64(self.div_ewma);
        e.u32(self.tol_hits);
        e.f64(self.last_objective.unwrap_or(f64::NAN));
        for &v in &self.w {
            e.f64(v);
        }
        for &v in &self.z {
            e.f64(v);
        }
        let crc = crc32(&bytes);
        EncoderBuffer::new(&mut bytes).u32(crc);
        bytes
    }

    /// Decode a checkpoint from raw bytes. Every failure mode of a
    /// hostile input — truncation anywhere, wrong magic, a version this
    /// build does not speak, declared lengths that overrun the file,
    /// any flipped bit — is a typed [`CheckpointError`]; this function
    /// never panics and never allocates proportionally to a *declared*
    /// (as opposed to actually present) length.
    pub fn decode(bytes: &[u8]) -> Result<Checkpoint, CheckpointError> {
        // CRC first: it guards every later field, so a torn tail can't
        // masquerade as a short-but-valid checkpoint.
        if bytes.len() < HEADER_LEN + 4 {
            return Err(DecodeError::Truncated {
                needed: HEADER_LEN + 4 - bytes.len(),
                have: bytes.len(),
            }
            .into());
        }
        let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
        let stored = u32::from_le_bytes(crc_bytes.try_into().unwrap());
        let computed = crc32(body);

        let mut d = DecoderBuffer::new(body);
        let magic = d.u32()?;
        if magic != CHECKPOINT_MAGIC {
            return Err(DecodeError::BadMagic(magic).into());
        }
        let version = d.u16()?;
        if version != CHECKPOINT_VERSION {
            return Err(CheckpointError::Version(version));
        }
        // The CRC verdict comes after magic/version so a different file
        // type or a future format reads as what it is, but before any
        // field is trusted.
        if stored != computed {
            return Err(CheckpointError::Crc { stored, computed });
        }
        let _flags = d.u16()?;
        let round = d.u64()?;
        let next_gap = d.u64()?;
        let seed = d.u64()?;
        let shards = d.u32()?;
        let n_features = d.u64()?;
        let n_samples = d.u64()?;
        let lambda = d.f64()?;
        let updates = d.u64()?;
        let r_cur = d.u64()?;
        let div_ewma = d.f64()?;
        let tol_hits = d.u32()?;
        let last_obj = d.f64()?;

        // Bound the declared lengths against the bytes actually present
        // *before* allocating: `take` is the allocation guard — a bogus
        // header can only produce a Truncated error, never an
        // attacker-sized Vec.
        let w_len = usize::try_from(n_features)
            .ok()
            .and_then(|n| n.checked_mul(8))
            .ok_or(DecodeError::BadLength)?;
        let w_bytes = d.take(w_len)?;
        let z_len = usize::try_from(n_samples)
            .ok()
            .and_then(|n| n.checked_mul(8))
            .ok_or(DecodeError::BadLength)?;
        let z_bytes = d.take(z_len)?;
        if !d.is_empty() {
            return Err(DecodeError::BadLength.into());
        }

        let decode_f64s = |raw: &[u8]| {
            raw.chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .collect::<Vec<f64>>()
        };
        Ok(Checkpoint {
            round,
            next_gap,
            seed,
            shards,
            lambda,
            updates,
            r_cur,
            div_ewma,
            tol_hits,
            last_objective: if last_obj.is_nan() { None } else { Some(last_obj) },
            w: decode_f64s(w_bytes),
            z: decode_f64s(z_bytes),
        })
    }

    /// Read and decode a checkpoint file.
    pub fn load(path: &Path) -> Result<Checkpoint, CheckpointError> {
        let bytes = std::fs::read(path)?;
        Checkpoint::decode(&bytes)
    }

    /// Write atomically: stage to `<path>.tmp`, fsync, rename into
    /// place. Returns the byte count written (for the
    /// `CheckpointWritten` event).
    pub fn write_atomic(&self, path: &Path) -> Result<u64, CheckpointError> {
        let bytes = self.encode();
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(".tmp");
        let tmp = PathBuf::from(tmp);
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&bytes)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(bytes.len() as u64)
    }
}

/// Where and how often the coordinator writes checkpoints. Carried in
/// [`ShardedConfig`](crate::shard::engine::ShardedConfig).
#[derive(Clone, Debug)]
pub struct CheckpointSpec {
    /// Destination file. Written atomically (`<path>.tmp` + rename).
    pub path: PathBuf,
    /// Write every N reconciled rounds (a terminal checkpoint is always
    /// written when the solve stops, whatever the cadence).
    pub every_rounds: usize,
    /// The builder seed, stamped into the header so resume can refuse a
    /// checkpoint from a differently-seeded run (Select policies are
    /// seed-deterministic — mixing seeds would silently break parity).
    pub seed: u64,
}

/// A validated checkpoint turned into engine-resume form. Built by
/// `SolverBuilder::resume_from` after shape/seed/λ validation; consumed
/// by `solve_sharded_linked`, which continues the schedule exactly
/// where the checkpoint left it.
#[derive(Clone, Debug)]
pub struct ResumeState {
    /// Completed global iterations — the resumed round counter starts
    /// here.
    pub round: usize,
    /// Reconcile gap published at the snapshot; the first resumed
    /// reconcile lands `next_gap` global rounds after the snapshot.
    pub next_gap: usize,
    /// Adaptive cadence state to restore.
    pub r_cur: usize,
    /// Objective-tripwire EWMA to restore.
    pub div_ewma: f64,
    /// Tolerance streak to restore.
    pub tol_hits: u32,
    /// Last logged objective (seeds the tripwire/history baseline).
    pub last_objective: Option<f64>,
    /// Cumulative updates before the resume (offsets this run's count).
    pub updates: u64,
    /// The reconciled iterate to restart from.
    pub w: Vec<f64>,
    /// The canonical residual replica. Restored directly instead of
    /// recomputing `X·w`: the checkpointed `z` is the reconciled fold
    /// state, and a fresh matvec would differ from it in last-bit
    /// rounding — breaking bit-exact resume.
    pub z: Vec<f64>,
}

impl ResumeState {
    /// Convert a decoded checkpoint (already validated against the
    /// solve by the builder) into resume form.
    pub fn from_checkpoint(ckpt: Checkpoint) -> ResumeState {
        ResumeState {
            round: ckpt.round as usize,
            next_gap: (ckpt.next_gap as usize).max(1),
            r_cur: ckpt.r_cur as usize,
            div_ewma: ckpt.div_ewma,
            tol_hits: ckpt.tol_hits,
            last_objective: ckpt.last_objective,
            updates: ckpt.updates,
            w: ckpt.w,
            z: ckpt.z,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            round: 40,
            next_gap: 2,
            seed: 7,
            shards: 2,
            lambda: 0.125,
            updates: 640,
            r_cur: 4,
            div_ewma: 0.5,
            tol_hits: 1,
            last_objective: Some(3.25),
            w: vec![0.0, -1.5, 2.25, 0.0],
            z: vec![0.5; 6],
        }
    }

    #[test]
    fn crc32_matches_known_vector() {
        // The canonical IEEE check value: crc32("123456789") = 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn round_trips_bit_exact() {
        let ckpt = sample();
        let bytes = ckpt.encode();
        assert_eq!(bytes.len(), ckpt.encoded_len());
        let back = Checkpoint::decode(&bytes).unwrap();
        assert_eq!(back, ckpt);
        // NaN objective encodes "none"
        let mut none = sample();
        none.last_objective = None;
        assert_eq!(Checkpoint::decode(&none.encode()).unwrap().last_objective, None);
    }

    #[test]
    fn version_bump_is_rejected() {
        let mut bytes = sample().encode();
        bytes[4] = CHECKPOINT_VERSION as u8 + 1; // version lives after the 4-byte magic
        let body_len = bytes.len() - 4;
        let crc = crc32(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&crc.to_le_bytes());
        match Checkpoint::decode(&bytes) {
            Err(CheckpointError::Version(v)) => assert_eq!(v, CHECKPOINT_VERSION + 1),
            other => panic!("expected Version error, got {other:?}"),
        }
    }

    #[test]
    fn bad_magic_reads_as_not_a_checkpoint() {
        let mut bytes = sample().encode();
        bytes[0] ^= 0xFF;
        assert!(matches!(
            Checkpoint::decode(&bytes),
            Err(CheckpointError::Malformed(DecodeError::BadMagic(_)))
        ));
    }

    #[test]
    fn flipped_bit_is_a_crc_error() {
        let bytes = sample().encode();
        // flip one bit somewhere in the payload (past magic+version so
        // the failure is attributed to the CRC, not structure)
        let mut bad = bytes.clone();
        let at = HEADER_LEN + 3;
        bad[at] ^= 0x10;
        assert!(matches!(Checkpoint::decode(&bad), Err(CheckpointError::Crc { .. })));
    }

    #[test]
    fn trailing_garbage_is_bad_length() {
        let mut bytes = sample().encode();
        // splice extra bytes before the CRC and restamp it: structure
        // check (not CRC) must catch the length drift
        let body_len = bytes.len() - 4;
        bytes.splice(body_len..body_len, [0u8; 8]);
        let body_len = bytes.len() - 4;
        let crc = crc32(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&crc.to_le_bytes());
        assert!(matches!(
            Checkpoint::decode(&bytes),
            Err(CheckpointError::Malformed(DecodeError::BadLength))
        ));
    }

    #[test]
    fn write_atomic_then_load() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("gencd-ckpt-test-{}.ckpt", std::process::id()));
        let ckpt = sample();
        let bytes = ckpt.write_atomic(&path).unwrap();
        assert_eq!(bytes, ckpt.encoded_len() as u64);
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ckpt);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_is_io_error() {
        let err = Checkpoint::load(Path::new("/nonexistent/gencd.ckpt")).unwrap_err();
        assert!(matches!(err, CheckpointError::Io(_)));
    }
}
