//! Multi-process crash drills: the `gencd harness` subcommand.
//!
//! Everything else in the test surface runs faults *in process* (the
//! virtual-time simulator, the loopback wire, the in-process TCP
//! tests). This module is the missing rung: real processes, real
//! sockets, real `SIGKILL`. Three roles, all dispatched from the same
//! binary (`std::env::current_exe`):
//!
//! * **worker** (`--worker`) — one complete sharded solve over the
//!   localhost TCP transport, with optional checkpointing, resume, and
//!   per-round pacing (so a parent can reliably interrupt it
//!   mid-solve). The outcome is written to `--out` as a `key=value`
//!   file whose `w_bits` line carries the full iterate as hex `f64`
//!   bits — the parent grades on bit patterns, not formatted floats.
//! * **proxy** (`--proxy`) — a byte-counting TCP forwarder placed
//!   between a shard's dial address and the relay. After
//!   `--sever-after-bytes` forwarded bytes it hard-closes the active
//!   connection mid-stream (a real half-transferred frame, which no
//!   in-process fault injector can produce), then keeps serving new
//!   dials; with `--heal-after-ms` it additionally drops its listener
//!   for that window, so redials see connection-refused — a partition,
//!   then a heal.
//! * **parent** (`--smoke` / `--plan DIR`) — spawns the other two,
//!   kills workers with `SIGKILL` at checkpoint boundaries, restarts
//!   them with `--resume`, and grades the outcome (bit-parity against
//!   a fault-free reference run, reconnects observed, clean degraded
//!   stops) into the same verdict table `gencd sim` renders.
//!
//! The drills assert the two recovery invariants end to end:
//! kill-9-then-resume reproduces the fault-free iterate bit for bit
//! (exact wire precision), and a severed peer either rejoins under its
//! backoff budget or the solve degrades to `shard-failed` — never a
//! hang (every child is waited on under a deadline).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::ops::ControlFlow;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, ExitStatus, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::toml::parse;
use crate::coordinator::algorithms::Algorithm;
use crate::coordinator::engine::SolveOutput;
use crate::coordinator::observer::IterationInfo;
use crate::event::MetricsAggregator;
use crate::net::{Transport, WirePrecision};
use crate::sim::report::Verdict;
use crate::solver::Solver;
use crate::util::Pcg64;

/// Fixed drill workload size: small enough that a full solve is
/// sub-second unpaced, large enough that every round moves real delta
/// frames across the wire.
const WORKLOAD_N: usize = 120;
const WORKLOAD_K: usize = 48;
const WORKLOAD_NNZ: usize = 8;
const WORKLOAD_LAM: f64 = 1e-3;

/// Deadline for any spawned child to finish; a child that outlives it
/// is killed and the drill fails. This is the harness-level "degrade,
/// never hang" backstop.
const CHILD_DEADLINE: Duration = Duration::from_secs(60);

/// How long the parent polls for a checkpoint file to appear before
/// declaring the victim worker stuck.
const CHECKPOINT_WAIT: Duration = Duration::from_secs(30);

// ---------------------------------------------------------------------------
// worker
// ---------------------------------------------------------------------------

/// One worker invocation: a full sharded TCP solve in this process.
#[derive(Clone, Debug)]
pub struct WorkerOpts {
    pub seed: u64,
    /// Round cap (`max_iters`); with `tol = 0` every run stops here,
    /// which is what makes reference and resumed runs comparable.
    pub rounds: usize,
    pub shards: usize,
    /// Sleep per reconciled round. Zero = run flat out; the kill-9
    /// victim paces so the parent can interrupt mid-solve.
    pub pace_ms: u64,
    pub listen: String,
    /// Per-shard dial override (see [`crate::net::TcpLink`]); empty =
    /// every shard dials the relay directly.
    pub peers: Vec<String>,
    pub checkpoint: Option<PathBuf>,
    pub checkpoint_every: usize,
    pub resume: Option<PathBuf>,
    pub reconnect_attempts: usize,
    /// Where the `key=value` outcome report is written.
    pub out: PathBuf,
}

impl Default for WorkerOpts {
    fn default() -> Self {
        WorkerOpts {
            seed: 7,
            rounds: 40,
            shards: 2,
            pace_ms: 0,
            listen: "127.0.0.1:0".to_string(),
            peers: Vec::new(),
            checkpoint: None,
            checkpoint_every: 4,
            resume: None,
            reconnect_attempts: 0,
            out: PathBuf::from("harness-worker.kv"),
        }
    }
}

/// Regenerate the drill workload from the seed — same construction on
/// every process, so a worker never needs a dataset shipped to it.
pub fn workload(seed: u64) -> (crate::sparse::CscMatrix, Vec<f64>) {
    let mut rng = Pcg64::new(seed, 0x4A55);
    let mut x =
        crate::data::synth::power_law_by_columns(WORKLOAD_N, WORKLOAD_K, 1.1, WORKLOAD_NNZ, &mut rng);
    x.normalize_columns();
    let y = (0..WORKLOAD_N)
        .map(|_| if rng.next_f64() < 0.5 { 1.0 } else { -1.0 })
        .collect();
    (x, y)
}

/// Run one worker solve and write its report. The solve itself never
/// bails: a degraded outcome (`shard-failed`) is a *reportable* result
/// the parent grades, not a worker error.
pub fn run_worker(opts: &WorkerOpts) -> anyhow::Result<()> {
    let (x, y) = workload(opts.seed);
    let agg = MetricsAggregator::new();
    let mut b = Solver::builder()
        .matrix(x)
        .labels(y)
        .lambda(WORKLOAD_LAM)
        .algorithm(Algorithm::Shotgun)
        .shards(opts.shards)
        // one thread per pool: within-pool update order stays
        // deterministic, which the bit-parity grade depends on
        .threads(opts.shards)
        .seed(opts.seed)
        .tol(0.0)
        .max_iters(opts.rounds)
        .max_seconds(CHILD_DEADLINE.as_secs_f64())
        .barrier_timeout_secs(20.0)
        .reconnect_max_attempts(opts.reconnect_attempts)
        .transport(Transport::Tcp {
            listen: opts.listen.clone(),
            peers: opts.peers.clone(),
            precision: WirePrecision::Exact,
        })
        .subscriber(agg.clone());
    if let Some(path) = &opts.checkpoint {
        b = b
            .checkpoint_path(path.clone())
            .checkpoint_every_rounds(opts.checkpoint_every);
    }
    if let Some(path) = &opts.resume {
        b = b.resume_from(path.clone());
    }
    if opts.pace_ms > 0 {
        let pace = Duration::from_millis(opts.pace_ms);
        b = b.observer(move |_info: &IterationInfo<'_>| -> ControlFlow<()> {
            std::thread::sleep(pace);
            ControlFlow::Continue(())
        });
    }
    let out = b.build()?.solve();
    std::fs::write(&opts.out, render_report(&out, &agg))
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", opts.out.display()))?;
    Ok(())
}

/// Serialize a worker outcome as sorted-stable `key=value` lines.
fn render_report(out: &SolveOutput, agg: &MetricsAggregator) -> String {
    let rec = agg.recover_columns();
    let bits: Vec<String> = out.w.iter().map(|v| format!("{:016x}", v.to_bits())).collect();
    format!(
        "stop={}\nfailed={}\nfailure_kind={}\nobjective={:.17e}\nnnz={}\nrounds={}\n\
         reconnect_attempts={}\ncheckpoints_written={}\nresume_round={}\nw_bits={}\n",
        out.stop,
        u8::from(out.failure.is_some()),
        out.failure
            .as_ref()
            .map(|f| f.kind.to_string())
            .unwrap_or_else(|| "-".to_string()),
        out.objective,
        out.nnz,
        out.metrics.iterations,
        rec.reconnect_attempts,
        rec.checkpoints_written,
        rec.resume_round,
        bits.join(","),
    )
}

/// A parsed worker report.
#[derive(Clone, Debug, Default)]
pub struct WorkerReport {
    fields: BTreeMap<String, String>,
    pub w: Vec<f64>,
}

impl WorkerReport {
    pub fn parse(text: &str) -> anyhow::Result<WorkerReport> {
        let mut fields = BTreeMap::new();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("worker report line without '=': {line:?}"))?;
            fields.insert(k.to_string(), v.to_string());
        }
        let w = match fields.get("w_bits").map(String::as_str) {
            None | Some("") => Vec::new(),
            Some(bits) => bits
                .split(',')
                .map(|h| {
                    u64::from_str_radix(h, 16)
                        .map(f64::from_bits)
                        .map_err(|e| anyhow::anyhow!("bad w_bits entry {h:?}: {e}"))
                })
                .collect::<anyhow::Result<_>>()?,
        };
        Ok(WorkerReport { fields, w })
    }

    pub fn load(path: &Path) -> anyhow::Result<WorkerReport> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading worker report {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn str_field(&self, key: &str) -> &str {
        self.fields.get(key).map(String::as_str).unwrap_or("")
    }

    pub fn u64_field(&self, key: &str) -> u64 {
        self.str_field(key).parse().unwrap_or(0)
    }

    pub fn objective(&self) -> f64 {
        self.str_field("objective").parse().unwrap_or(f64::NAN)
    }

    pub fn failed(&self) -> bool {
        self.str_field("failed") == "1"
    }
}

/// Largest absolute component difference between two iterates (infinite
/// if the lengths disagree).
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    if a.len() != b.len() {
        return f64::INFINITY;
    }
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

// ---------------------------------------------------------------------------
// proxy
// ---------------------------------------------------------------------------

/// One forwarding proxy: listen, forward to target, sever once.
#[derive(Clone, Debug)]
pub struct ProxyOpts {
    pub listen: String,
    pub target: String,
    /// Hard-close the connection that crosses this cumulative forwarded
    /// byte count (0 = never sever).
    pub sever_after_bytes: u64,
    /// After the sever, drop the listener for this long so redials get
    /// connection-refused (0 = stay accepting — a transient drop, not a
    /// partition).
    pub heal_after_ms: u64,
}

/// Shared sever state across pump threads: the remaining byte budget
/// and whether the one sever already fired.
struct SeverState {
    budget: AtomicU64,
    armed: bool,
    fired: AtomicBool,
}

impl SeverState {
    fn new(budget: u64) -> Self {
        SeverState {
            budget: AtomicU64::new(budget),
            armed: budget > 0,
            fired: AtomicBool::new(false),
        }
    }

    /// Consume `n` forwarded bytes; returns true when this consumption
    /// crossed the budget and this caller should sever its connection.
    fn consume(&self, n: u64) -> bool {
        if !self.armed || self.fired.load(Ordering::Acquire) {
            return false;
        }
        let before = self
            .budget
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |v| {
                Some(v.saturating_sub(n))
            })
            .unwrap_or(0);
        before > 0 && before <= n && !self.fired.swap(true, Ordering::AcqRel)
    }
}

/// Copy bytes one way, charging the sever budget; on crossing it, shut
/// both sockets down mid-stream (the peer sees a half-delivered frame).
fn pump(mut from: TcpStream, mut to: TcpStream, sever: Arc<SeverState>) {
    let mut buf = [0u8; 4096];
    loop {
        let n = match from.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => n,
        };
        if sever.consume(n as u64) {
            let _ = from.shutdown(std::net::Shutdown::Both);
            let _ = to.shutdown(std::net::Shutdown::Both);
            break;
        }
        if to.write_all(&buf[..n]).is_err() {
            break;
        }
    }
    // a closed direction closes the pair: the other pump's read fails
    let _ = from.shutdown(std::net::Shutdown::Both);
    let _ = to.shutdown(std::net::Shutdown::Both);
}

/// Run the proxy until the process is killed (the parent owns its
/// lifetime). Target-connect failures drop the client and continue —
/// the relay may simply not be up yet.
pub fn run_proxy(opts: &ProxyOpts) -> anyhow::Result<()> {
    let mut listener = TcpListener::bind(&opts.listen)
        .map_err(|e| anyhow::anyhow!("proxy bind {}: {e}", opts.listen))?;
    let bound = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let sever = Arc::new(SeverState::new(opts.sever_after_bytes));
    let mut partitioned = false;
    loop {
        // partition window: once the sever fired, optionally go dark so
        // redials fail at dial time (each refused dial burns one
        // backoff attempt), then resurface on the same port
        if !partitioned && opts.heal_after_ms > 0 && sever.fired.load(Ordering::Acquire) {
            partitioned = true;
            drop(listener);
            std::thread::sleep(Duration::from_millis(opts.heal_after_ms));
            listener = TcpListener::bind(bound)
                .map_err(|e| anyhow::anyhow!("proxy re-bind {bound}: {e}"))?;
            listener.set_nonblocking(true)?;
        }
        match listener.accept() {
            Ok((client, _)) => {
                let server = match TcpStream::connect(&opts.target) {
                    Ok(s) => s,
                    Err(_) => continue, // drops `client`
                };
                let _ = client.set_nodelay(true);
                let _ = server.set_nodelay(true);
                let (c2, s2) = match (client.try_clone(), server.try_clone()) {
                    (Ok(c), Ok(s)) => (c, s),
                    _ => continue,
                };
                let up = Arc::clone(&sever);
                let down = Arc::clone(&sever);
                std::thread::spawn(move || pump(client, s2, up));
                std::thread::spawn(move || pump(server, c2, down));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => anyhow::bail!("proxy accept: {e}"),
        }
    }
}

// ---------------------------------------------------------------------------
// parent: drills
// ---------------------------------------------------------------------------

/// What a drill does to the worker(s) it spawns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DrillMode {
    /// Reference run, then `SIGKILL` a paced worker after its first
    /// checkpoint, restart with `--resume`, grade bit-parity.
    Kill9Resume,
    /// Route one shard through the proxy and sever its connection
    /// mid-stream once; the worker must rejoin and finish clean.
    TransientDrop,
    /// Like `TransientDrop`, but the proxy also goes dark after the
    /// sever, so early redials are refused before the heal.
    PartitionHeal,
}

impl DrillMode {
    pub fn by_name(s: &str) -> anyhow::Result<DrillMode> {
        Ok(match s {
            "kill9-resume" => DrillMode::Kill9Resume,
            "transient-drop" => DrillMode::TransientDrop,
            "partition-heal" => DrillMode::PartitionHeal,
            other => anyhow::bail!(
                "unknown harness mode {other:?} (expected kill9-resume | transient-drop | partition-heal)"
            ),
        })
    }
}

/// One graded drill, parameterized by a plan file or the smoke
/// defaults.
#[derive(Clone, Debug)]
pub struct DrillSpec {
    pub name: String,
    pub mode: DrillMode,
    pub seed: u64,
    pub rounds: usize,
    pub shards: usize,
    pub pace_ms: u64,
    pub checkpoint_every: usize,
    pub sever_after_bytes: u64,
    pub heal_after_ms: u64,
    pub reconnect_attempts: usize,
    /// Max allowed |Δw| / |Δobjective| against the fault-free
    /// reference.
    pub tolerance: f64,
    /// Minimum redial attempts the drill must observe (drop drills).
    pub min_reconnect_attempts: u64,
}

impl DrillSpec {
    pub fn defaults(name: &str, mode: DrillMode) -> DrillSpec {
        DrillSpec {
            name: name.to_string(),
            mode,
            seed: 7,
            rounds: 40,
            shards: 2,
            pace_ms: 25,
            checkpoint_every: 4,
            sever_after_bytes: 6000,
            heal_after_ms: if mode == DrillMode::PartitionHeal { 250 } else { 0 },
            reconnect_attempts: 8,
            tolerance: 1e-12,
            min_reconnect_attempts: 1,
        }
    }

    /// Parse one `scenarios/harness/*.toml` plan file:
    ///
    /// ```toml
    /// name = "kill9-resume"          # (file stem)
    /// [harness]
    /// mode = "kill9-resume"          # kill9-resume | transient-drop | partition-heal
    /// seed = 7                       # (7)
    /// rounds = 40                    # (40)
    /// shards = 2                     # (2)
    /// pace_ms = 25                   # (25)
    /// checkpoint_every = 4           # (4)
    /// sever_after_bytes = 6000       # (6000)
    /// heal_after_ms = 250            # (mode default)
    /// reconnect_attempts = 8         # (8)
    /// [expect]
    /// tolerance = 1e-12              # (1e-12) vs the fault-free reference
    /// min_reconnect_attempts = 1     # (1; drop drills only)
    /// ```
    pub fn from_toml_str(src: &str, fallback_name: &str) -> anyhow::Result<DrillSpec> {
        let doc = parse(src)?;
        let str_of = |table: &str, key: &str, default: &str| -> anyhow::Result<String> {
            match doc.get(table, key) {
                None => Ok(default.to_string()),
                Some(v) => v
                    .as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow::anyhow!("harness plan: [{table}] {key} must be a string")),
            }
        };
        let int_of = |table: &str, key: &str, default: i64| -> anyhow::Result<i64> {
            match doc.get(table, key) {
                None => Ok(default),
                Some(v) => v.as_int().ok_or_else(|| {
                    anyhow::anyhow!("harness plan: [{table}] {key} must be an integer")
                }),
            }
        };
        let name = str_of("", "name", fallback_name)?;
        let mode = DrillMode::by_name(&str_of("harness", "mode", "kill9-resume")?)?;
        let d = DrillSpec::defaults(&name, mode);
        let tolerance = match doc.get("expect", "tolerance") {
            None => d.tolerance,
            Some(v) => v
                .as_float()
                .ok_or_else(|| anyhow::anyhow!("harness plan: [expect] tolerance must be a number"))?,
        };
        let nonneg = |v: i64, what: &str| -> anyhow::Result<u64> {
            anyhow::ensure!(v >= 0, "harness plan: {what} must be >= 0, got {v}");
            Ok(v as u64)
        };
        Ok(DrillSpec {
            name,
            mode,
            seed: nonneg(int_of("harness", "seed", d.seed as i64)?, "seed")?,
            rounds: nonneg(int_of("harness", "rounds", d.rounds as i64)?, "rounds")?.max(1) as usize,
            shards: nonneg(int_of("harness", "shards", d.shards as i64)?, "shards")?.max(2) as usize,
            pace_ms: nonneg(int_of("harness", "pace_ms", d.pace_ms as i64)?, "pace_ms")?,
            checkpoint_every: nonneg(
                int_of("harness", "checkpoint_every", d.checkpoint_every as i64)?,
                "checkpoint_every",
            )?
            .max(1) as usize,
            sever_after_bytes: nonneg(
                int_of("harness", "sever_after_bytes", d.sever_after_bytes as i64)?,
                "sever_after_bytes",
            )?,
            heal_after_ms: nonneg(
                int_of("harness", "heal_after_ms", d.heal_after_ms as i64)?,
                "heal_after_ms",
            )?,
            reconnect_attempts: nonneg(
                int_of("harness", "reconnect_attempts", d.reconnect_attempts as i64)?,
                "reconnect_attempts",
            )? as usize,
            tolerance,
            min_reconnect_attempts: nonneg(
                int_of("expect", "min_reconnect_attempts", d.min_reconnect_attempts as i64)?,
                "min_reconnect_attempts",
            )?,
        })
    }
}

/// A scratch directory per drill, removed on drop (best effort).
struct ScratchDir(PathBuf);

impl ScratchDir {
    fn new(tag: &str) -> anyhow::Result<ScratchDir> {
        let dir = std::env::temp_dir().join(format!(
            "gencd-harness-{}-{tag}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir)
            .map_err(|e| anyhow::anyhow!("creating {}: {e}", dir.display()))?;
        Ok(ScratchDir(dir))
    }

    fn path(&self, file: &str) -> PathBuf {
        self.0.join(file)
    }
}

impl Drop for ScratchDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// A child that is SIGKILLed if still alive when the guard drops, so a
/// failed drill never leaks worker or proxy processes.
struct Reaped(Child);

impl Drop for Reaped {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// Poll-wait a child under [`CHILD_DEADLINE`].
fn wait_deadline(child: &mut Child, what: &str) -> anyhow::Result<ExitStatus> {
    let deadline = Instant::now() + CHILD_DEADLINE;
    loop {
        if let Some(status) = child.try_wait()? {
            return Ok(status);
        }
        anyhow::ensure!(
            Instant::now() < deadline,
            "{what} still running after {}s — killed",
            CHILD_DEADLINE.as_secs()
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Bind an ephemeral port, remember it, release it. Racy in principle;
/// on a CI loopback the window is negligible, and a collision fails the
/// drill loudly rather than corrupting it.
fn free_port() -> anyhow::Result<u16> {
    Ok(TcpListener::bind("127.0.0.1:0")?.local_addr()?.port())
}

fn spawn_worker(exe: &Path, opts: &WorkerOpts) -> anyhow::Result<Reaped> {
    let mut cmd = Command::new(exe);
    cmd.arg("harness")
        .arg("--worker")
        .args(["--out", &opts.out.display().to_string()])
        .args(["--seed", &opts.seed.to_string()])
        .args(["--rounds", &opts.rounds.to_string()])
        .args(["--shards", &opts.shards.to_string()])
        .args(["--pace-ms", &opts.pace_ms.to_string()])
        .args(["--listen", &opts.listen])
        .stdout(Stdio::null());
    if !opts.peers.is_empty() {
        cmd.args(["--peers", &opts.peers.join(",")]);
    }
    if let Some(ck) = &opts.checkpoint {
        cmd.args(["--checkpoint", &ck.display().to_string()])
            .args(["--checkpoint-every", &opts.checkpoint_every.to_string()]);
    }
    if let Some(r) = &opts.resume {
        cmd.args(["--resume", &r.display().to_string()]);
    }
    if opts.reconnect_attempts > 0 {
        cmd.args(["--reconnect-attempts", &opts.reconnect_attempts.to_string()]);
    }
    Ok(Reaped(cmd.spawn().map_err(|e| {
        anyhow::anyhow!("spawning worker {}: {e}", exe.display())
    })?))
}

fn spawn_proxy(exe: &Path, opts: &ProxyOpts) -> anyhow::Result<Reaped> {
    let child = Command::new(exe)
        .arg("harness")
        .arg("--proxy")
        .args(["--listen", &opts.listen])
        .args(["--target", &opts.target])
        .args(["--sever-after-bytes", &opts.sever_after_bytes.to_string()])
        .args(["--heal-after-ms", &opts.heal_after_ms.to_string()])
        .stdout(Stdio::null())
        .spawn()
        .map_err(|e| anyhow::anyhow!("spawning proxy {}: {e}", exe.display()))?;
    Ok(Reaped(child))
}

/// Wait until `addr` accepts a TCP connection (proxy readiness).
fn wait_listening(addr: &str) -> anyhow::Result<()> {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if TcpStream::connect(addr).is_ok() {
            return Ok(());
        }
        anyhow::ensure!(
            Instant::now() < deadline,
            "nothing listening on {addr} after 10s"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Spawn a worker, wait it out, load its report.
fn run_worker_to_report(exe: &Path, opts: &WorkerOpts) -> anyhow::Result<WorkerReport> {
    let mut child = spawn_worker(exe, opts)?;
    let status = wait_deadline(&mut child.0, "worker")?;
    anyhow::ensure!(status.success(), "worker exited with {status}");
    WorkerReport::load(&opts.out)
}

/// The kill-9 drill: reference solve, victim killed after its first
/// checkpoint, resume, bit-parity grade.
fn drill_kill9(exe: &Path, spec: &DrillSpec) -> anyhow::Result<String> {
    let scratch = ScratchDir::new(&spec.name)?;
    let ck = scratch.path("checkpoint.bin");

    let reference = run_worker_to_report(
        exe,
        &WorkerOpts {
            seed: spec.seed,
            rounds: spec.rounds,
            shards: spec.shards,
            out: scratch.path("reference.kv"),
            ..WorkerOpts::default()
        },
    )?;
    anyhow::ensure!(!reference.failed(), "reference run failed: stop={}", reference.str_field("stop"));

    // victim: paced so SIGKILL lands mid-solve, checkpointing as it goes
    let victim_opts = WorkerOpts {
        seed: spec.seed,
        rounds: spec.rounds,
        shards: spec.shards,
        pace_ms: spec.pace_ms.max(1),
        checkpoint: Some(ck.clone()),
        checkpoint_every: spec.checkpoint_every,
        out: scratch.path("victim.kv"),
        ..WorkerOpts::default()
    };
    let mut victim = spawn_worker(exe, &victim_opts)?;
    let deadline = Instant::now() + CHECKPOINT_WAIT;
    while !ck.exists() {
        anyhow::ensure!(
            Instant::now() < deadline,
            "victim wrote no checkpoint within {}s",
            CHECKPOINT_WAIT.as_secs()
        );
        if victim.0.try_wait()?.is_some() {
            anyhow::bail!("victim exited before the parent could kill it (pace too fast?)");
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    victim.0.kill().map_err(|e| anyhow::anyhow!("SIGKILL victim: {e}"))?;
    let _ = victim.0.wait();

    let resumed = run_worker_to_report(
        exe,
        &WorkerOpts {
            seed: spec.seed,
            rounds: spec.rounds,
            shards: spec.shards,
            checkpoint: Some(ck.clone()),
            checkpoint_every: spec.checkpoint_every,
            resume: Some(ck),
            out: scratch.path("resumed.kv"),
            ..WorkerOpts::default()
        },
    )?;
    anyhow::ensure!(!resumed.failed(), "resumed run failed: stop={}", resumed.str_field("stop"));
    anyhow::ensure!(
        resumed.u64_field("resume_round") > 0,
        "resumed run reports resume_round=0 — it did not actually resume"
    );
    let dw = max_abs_diff(&reference.w, &resumed.w);
    anyhow::ensure!(
        dw <= spec.tolerance,
        "resumed iterate diverged: max|dw|={dw:.3e} > {:.1e}",
        spec.tolerance
    );
    let dobj = (reference.objective() - resumed.objective()).abs();
    anyhow::ensure!(
        dobj <= spec.tolerance,
        "resumed objective diverged: |dobj|={dobj:.3e} > {:.1e}",
        spec.tolerance
    );
    let exact = reference
        .w
        .iter()
        .zip(&resumed.w)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    Ok(format!(
        "resume_round={} max|dw|={dw:.1e} bit_exact={exact} objective={:.6e}",
        resumed.u64_field("resume_round"),
        resumed.objective()
    ))
}

/// The drop drills: one shard dials through the severing proxy; the
/// worker must reconnect under its budget and finish clean.
fn drill_drop(exe: &Path, spec: &DrillSpec) -> anyhow::Result<String> {
    let scratch = ScratchDir::new(&spec.name)?;
    let relay_port = free_port()?;
    let proxy_port = free_port()?;
    let relay_addr = format!("127.0.0.1:{relay_port}");
    let proxy_addr = format!("127.0.0.1:{proxy_port}");
    let heal_after_ms = match spec.mode {
        DrillMode::PartitionHeal => spec.heal_after_ms.max(1),
        _ => 0,
    };
    let _proxy = spawn_proxy(
        exe,
        &ProxyOpts {
            listen: proxy_addr.clone(),
            target: relay_addr.clone(),
            sever_after_bytes: spec.sever_after_bytes,
            heal_after_ms,
        },
    )?;
    wait_listening(&proxy_addr)?;

    let report = run_worker_to_report(
        exe,
        &WorkerOpts {
            seed: spec.seed,
            rounds: spec.rounds,
            shards: spec.shards,
            // modest pacing spreads the wire traffic so the sever lands
            // mid-solve instead of inside the startup burst
            pace_ms: spec.pace_ms.min(10),
            listen: relay_addr.clone(),
            // shard 0 dials through the proxy; everyone else goes direct
            peers: vec![proxy_addr, relay_addr],
            reconnect_attempts: spec.reconnect_attempts,
            out: scratch.path("drop.kv"),
            ..WorkerOpts::default()
        },
    )?;
    anyhow::ensure!(
        !report.failed(),
        "worker degraded instead of reconnecting: stop={} kind={}",
        report.str_field("stop"),
        report.str_field("failure_kind")
    );
    let attempts = report.u64_field("reconnect_attempts");
    anyhow::ensure!(
        attempts >= spec.min_reconnect_attempts,
        "observed {attempts} reconnect attempts, expected >= {}",
        spec.min_reconnect_attempts
    );
    Ok(format!(
        "reconnect_attempts={attempts} objective={:.6e} stop={}",
        report.objective(),
        report.str_field("stop")
    ))
}

/// Run one drill to a verdict (errors become FAIL verdicts, matching
/// the `run_corpus` contract: a broken drill fails the sweep, it does
/// not abort it).
pub fn run_drill(exe: &Path, spec: &DrillSpec) -> Verdict {
    let graded = match spec.mode {
        DrillMode::Kill9Resume => drill_kill9(exe, spec),
        DrillMode::TransientDrop | DrillMode::PartitionHeal => drill_drop(exe, spec),
    };
    match graded {
        Ok(detail) => Verdict { name: spec.name.clone(), pass: true, detail, sim_events: 0 },
        Err(e) => Verdict {
            name: spec.name.clone(),
            pass: false,
            detail: format!("error: {e}"),
            sim_events: 0,
        },
    }
}

/// The smoke sweep: the kill-9 and transient-drop drills with default
/// parameters — the CI front door (`gencd harness --smoke`).
pub fn run_smoke(exe: &Path) -> Vec<Verdict> {
    [
        DrillSpec::defaults("smoke-kill9-resume", DrillMode::Kill9Resume),
        DrillSpec::defaults("smoke-transient-drop", DrillMode::TransientDrop),
    ]
    .iter()
    .map(|spec| run_drill(exe, spec))
    .collect()
}

/// Run every `*.toml` plan under `dir` (sorted), optionally filtered by
/// file-stem substring.
pub fn run_plan_dir(exe: &Path, dir: &Path, filter: Option<&str>) -> anyhow::Result<Vec<Verdict>> {
    let mut files: Vec<_> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("reading harness plan dir {}: {e}", dir.display()))?
        .filter_map(|entry| {
            let path = entry.ok()?.path();
            (path.extension().and_then(|e| e.to_str()) == Some("toml")).then_some(path)
        })
        .collect();
    files.sort();
    let mut verdicts = Vec::new();
    for path in files {
        let stem = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        if let Some(f) = filter {
            if !stem.contains(f) {
                continue;
            }
        }
        match std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))
            .and_then(|src| DrillSpec::from_toml_str(&src, &stem))
        {
            Ok(spec) => verdicts.push(run_drill(exe, &spec)),
            Err(e) => verdicts.push(Verdict {
                name: stem,
                pass: false,
                detail: format!("error: {e}"),
                sim_events: 0,
            }),
        }
    }
    Ok(verdicts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_round_trips_bit_exact() {
        let w = vec![0.1, -2.5e-9, f64::MIN_POSITIVE, 0.0, -0.0];
        let bits: Vec<String> = w.iter().map(|v| format!("{:016x}", v.to_bits())).collect();
        let text = format!(
            "stop=max-iters\nfailed=0\nfailure_kind=-\nobjective=1.25000000000000000e0\n\
             nnz=3\nrounds=40\nreconnect_attempts=2\ncheckpoints_written=5\n\
             resume_round=8\nw_bits={}\n",
            bits.join(",")
        );
        let rep = WorkerReport::parse(&text).unwrap();
        assert_eq!(rep.str_field("stop"), "max-iters");
        assert!(!rep.failed());
        assert_eq!(rep.u64_field("reconnect_attempts"), 2);
        assert_eq!(rep.u64_field("resume_round"), 8);
        assert_eq!(rep.w.len(), w.len());
        for (a, b) in rep.w.iter().zip(&w) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!((rep.objective() - 1.25).abs() < 1e-15);
    }

    #[test]
    fn report_rejects_malformed_lines() {
        assert!(WorkerReport::parse("no equals sign").is_err());
        assert!(WorkerReport::parse("w_bits=zz").is_err());
        // empty w_bits is a valid (failed-early) report
        let rep = WorkerReport::parse("failed=1\nw_bits=\n").unwrap();
        assert!(rep.failed());
        assert!(rep.w.is_empty());
    }

    #[test]
    fn max_abs_diff_flags_length_mismatch() {
        assert_eq!(max_abs_diff(&[1.0], &[1.0, 2.0]), f64::INFINITY);
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.0, 2.5]), 0.5);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }

    #[test]
    fn workload_is_deterministic_per_seed() {
        let (xa, ya) = workload(9);
        let (xb, yb) = workload(9);
        let (xc, _) = workload(10);
        assert_eq!(ya, yb);
        assert_eq!(xa.n_cols(), xb.n_cols());
        assert_eq!(xa.nnz(), xb.nnz());
        // different seed, different support
        assert!(xa.nnz() != xc.nnz() || ya != workload(10).1);
    }

    #[test]
    fn sever_budget_fires_exactly_once() {
        let s = SeverState::new(100);
        assert!(!s.consume(40));
        assert!(!s.consume(40));
        assert!(s.consume(40)); // crosses the budget
        assert!(!s.consume(40)); // already fired
        let off = SeverState::new(0);
        assert!(!off.consume(1_000_000)); // disarmed
    }

    #[test]
    fn drill_plan_parses_defaults_and_overrides() {
        let spec = DrillSpec::from_toml_str(
            "name = \"p\"\n[harness]\nmode = \"partition-heal\"\nrounds = 12\n\
             heal_after_ms = 99\n[expect]\ntolerance = 1e-9\nmin_reconnect_attempts = 3\n",
            "fb",
        )
        .unwrap();
        assert_eq!(spec.name, "p");
        assert_eq!(spec.mode, DrillMode::PartitionHeal);
        assert_eq!(spec.rounds, 12);
        assert_eq!(spec.heal_after_ms, 99);
        assert_eq!(spec.tolerance, 1e-9);
        assert_eq!(spec.min_reconnect_attempts, 3);
        // defaults fill the rest
        assert_eq!(spec.shards, 2);
        assert_eq!(spec.checkpoint_every, 4);
        // fallback name + default mode
        let d = DrillSpec::from_toml_str("", "stem").unwrap();
        assert_eq!(d.name, "stem");
        assert_eq!(d.mode, DrillMode::Kill9Resume);
        // bad mode is a typed parse error
        assert!(DrillSpec::from_toml_str("[harness]\nmode = \"nope\"", "x").is_err());
    }
}
