//! # gencd::recover — crash-recoverable distributed solves
//!
//! PR 6 ([`crate::sim`]) and PR 7 ([`crate::net`]) made shard failure
//! *clean*: any dead peer, timeout, or malformed frame lands as a
//! structured `SolveError` instead of a hang. This layer makes failure
//! *survivable*, in three rungs:
//!
//! 1. **Checkpointing** ([`checkpoint`]) — a versioned, CRC-guarded
//!    codec for the coordinator's reconciled state (`w`, `z`, completed
//!    rounds, cadence state, policy-stream seed), written atomically at
//!    reconciled rounds on a configurable cadence and consumed by
//!    `SolverBuilder::resume_from`. Every decode of a truncated or
//!    corrupted file is a typed [`CheckpointError`] — never a panic.
//!    Under exact wire precision a resumed solve is bit-identical to
//!    the uninterrupted one (see `shard/engine.rs` §Failure semantics
//!    for why: policies are feedback-free call streams, the residual is
//!    restored verbatim, and the reconcile schedule re-aligns to the
//!    stored gap).
//! 2. **Reconnect with bounded backoff** ([`backoff`]) — the retry
//!    policy [`crate::net::tcp::TcpLink`] runs per peer when a socket
//!    dies mid-round: bounded exponential delays with seeded jitter, a
//!    closed-form worst case, and the pre-recover degrade path
//!    (`StopReason::ShardFailed` + `SolveErrorKind::Link`) when
//!    attempts are exhausted.
//! 3. **Multi-process harness** ([`harness`]) — the `gencd harness`
//!    subcommand spawns real shard *processes* over `TcpLink` on
//!    localhost, injects `kill -9`, transient disconnects, and
//!    partition-then-heal (through a byte-forwarding proxy process),
//!    restarts victims with `--resume`, and grades outcomes like the
//!    loopback corpus — closing the loopback-vs-real-socket fidelity
//!    gap.
//!
//! The module is deliberately dependency-free: checkpoint files reuse
//! the [`crate::net::codec`] encode/decode discipline, the harness uses
//! only `std::process`, and all randomness flows through the repo's own
//! [`Pcg64`](crate::util::rng::Pcg64) streams.
//!
//! [`CheckpointError`]: checkpoint::CheckpointError

pub mod backoff;
pub mod checkpoint;
pub mod harness;

pub use backoff::ReconnectPolicy;
pub use checkpoint::{Checkpoint, CheckpointError, CheckpointSpec, ResumeState};
