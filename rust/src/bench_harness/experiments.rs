//! The paper's evaluation, regenerated: Table 3, Figure 1, Figure 2.
//! Called both by the `gencd` CLI subcommands and by `benches/*`
//! (cargo bench) so the numbers in EXPERIMENTS.md are one command away.

use super::{bench_budget, bench_config, bench_scale, paper_datasets, Table};
use crate::coloring::{color_features, Strategy};
use crate::coordinator::driver::{run_on, SolveResult};
use crate::coordinator::Algorithm;
use crate::event::phases::phase_secs;
use crate::linalg::{shotgun_pstar, spectral_radius_xtx};
use crate::simulate::{self, accepted, AcceptShape, CostModel, IterProfile};
use crate::sparse::io::Dataset;

/// Table 3: dataset summary statistics.
pub fn print_table3() {
    let scale = bench_scale();
    println!("# Table 3 (scale {scale}; paper values at scale 1.0 in EXPERIMENTS.md)\n");
    let mut table = Table::new(&[
        "",
        "samples",
        "features",
        "nnz/feature",
        "P*",
        "feat/color",
        "colors",
        "color secs",
        "lambda",
        "min objective",
        "best-fit nnz",
    ]);
    for (mut ds, lam) in paper_datasets() {
        ds.x.normalize_columns();
        let est = spectral_radius_xtx(&ds.x, 200, 1e-8, 1);
        let pstar = shotgun_pstar(ds.n_features(), est.rho);
        let coloring = color_features(&ds.x, Strategy::Greedy, 1);

        // "min F(w) + lam |w|_1" and "Best-fit NNZ": best solution a
        // long-ish refined run finds (the paper reports its best-known).
        let name = ds.name.clone();
        let mut cfg = bench_config(&name, lam, Algorithm::ThreadGreedy);
        cfg.solver.line_search_steps = 20;
        cfg.solver.max_seconds = bench_budget() * 2.0;
        cfg.solver.threads = 2;
        let res = run_on(&cfg, ds.clone(), None).expect("solve");

        table.row(vec![
            name,
            ds.n_samples().to_string(),
            ds.n_features().to_string(),
            format!("{:.1}", ds.x.mean_col_nnz()),
            pstar.to_string(),
            format!("{:.1}", coloring.mean_class_size()),
            coloring.n_colors().to_string(),
            format!("{:.3}", coloring.elapsed_secs),
            format!("{lam:.0e}"),
            format!("{:.6}", res.history.best_objective()),
            res.nnz.to_string(),
        ]);
    }
    println!("{}", table.render());
}

/// Figure 1: convergence (objective + NNZ vs time) for the four paper
/// algorithms on both datasets. Optionally writes per-run history CSVs.
pub fn print_fig1(csv_dir: Option<&str>) {
    let scale = bench_scale();
    let budget = bench_budget();
    println!("# Figure 1 (scale {scale}, {budget}s/run, threads=4, 20-step line search)\n");
    for (ds, lam) in paper_datasets() {
        println!("## {} (lambda = {lam:.0e})\n", ds.name);
        let mut table = Table::new(&[
            "algorithm",
            "obj@25%",
            "obj@50%",
            "obj@final",
            "nnz@25%",
            "nnz@final",
            "updates",
            "stop",
        ]);
        let mut obj_series = Vec::new();
        let mut nnz_series = Vec::new();
        for alg in Algorithm::paper_set() {
            let mut cfg = bench_config(&ds.name, lam, alg);
            cfg.solver.line_search_steps = 20;
            let res = run_on(&cfg, ds.clone(), None).expect("solve");
            obj_series.push(super::plot::Series {
                label: alg.name().into(),
                points: res
                    .history
                    .records
                    .iter()
                    .map(|r| (r.elapsed_secs, r.objective))
                    .collect(),
            });
            nnz_series.push(super::plot::Series {
                label: alg.name().into(),
                points: res
                    .history
                    .records
                    .iter()
                    .map(|r| (r.elapsed_secs, r.nnz as f64))
                    .collect(),
            });
            if let Some(dir) = csv_dir {
                std::fs::create_dir_all(dir).ok();
                let path = format!("{dir}/fig1_{}_{}.csv", ds.name, alg.name());
                std::fs::write(&path, res.history.to_csv()).expect("csv");
            }
            let at = |frac: f64| -> (f64, usize) {
                let t = frac * budget;
                res.history
                    .records
                    .iter()
                    .take_while(|r| r.elapsed_secs <= t)
                    .last()
                    .or(res.history.records.first())
                    .map(|r| (r.objective, r.nnz))
                    .unwrap_or((f64::NAN, 0))
            };
            let (o25, n25) = at(0.25);
            let (o50, _) = at(0.50);
            table.row(vec![
                alg.name().into(),
                format!("{o25:.6}"),
                format!("{o50:.6}"),
                format!("{:.6}", res.objective),
                n25.to_string(),
                res.nnz.to_string(),
                res.metrics.updates.to_string(),
                res.stop.to_string(),
            ]);
        }
        println!("{}", table.render());
        if let Some(dir) = csv_dir {
            for (suffix, ylab, series) in [
                ("objective", "F(w) + lam|w|_1", obj_series),
                ("nnz", "nonzero weights", nnz_series),
            ] {
                let chart = super::plot::Chart {
                    title: format!("Figure 1 — {} ({suffix})", ds.name),
                    x_label: "seconds".into(),
                    y_label: ylab.into(),
                    log_y: false,
                    series,
                };
                let path = format!("{dir}/fig1_{}_{suffix}.svg", ds.name);
                if chart.write_svg(&path).unwrap_or(false) {
                    println!("(plot: {path})");
                }
            }
        }
    }
}

/// Extract the simulator profile from a measured run.
fn profile_for(
    alg: Algorithm,
    ds: &Dataset,
    res: &SolveResult,
    overlap: f64,
) -> IterProfile {
    let iters = res.metrics.iterations.max(1) as f64;
    let selected = res.metrics.proposals as f64 / iters;
    let (acceptor, accepted_of_t): (AcceptShape, fn(f64, usize) -> f64) = match alg {
        Algorithm::Greedy => (AcceptShape::Single, accepted::one),
        Algorithm::ThreadGreedy => (AcceptShape::PerThread, accepted::per_thread),
        // TopK's default budget is `threads`, so |J'| ~ T like
        // thread-greedy, but the leader pays the selection pass
        Algorithm::TopK => (AcceptShape::TopK, accepted::per_thread),
        _ => (AcceptShape::All, accepted::all),
    };
    IterProfile {
        selected,
        accepted_of_t,
        acceptor,
        mean_col_nnz: ds.x.mean_col_nnz(),
        n_samples: ds.n_samples(),
        // COLORING's classes are conflict-free by construction
        pairwise_overlap: if alg == Algorithm::Coloring { 0.0 } else { overlap },
        barriers: 5.0,
    }
}

/// Figure 2: updates/second vs thread count. T=1 is *measured* with the
/// real engine; T>1 extrapolates with the calibrated cost model
/// anchored at the measured point (DESIGN.md §4 substitution — this
/// container has one core).
pub fn print_fig2(threads_list: &[usize]) {
    let scale = bench_scale();
    println!(
        "# Figure 2 (scale {scale}; T=1 measured, T>1 cost-model extrapolation)\n"
    );
    for (ds, lam) in paper_datasets() {
        println!("## {} — updates/second\n", ds.name);
        let overlap = {
            let mut d = ds.clone();
            d.x.normalize_columns();
            simulate::expected_pairwise_overlap(&d.x)
        };
        let mut headers: Vec<String> = vec!["algorithm".into()];
        headers.extend(threads_list.iter().map(|t| format!("T={t}")));
        let mut table = Table::new(&headers.iter().map(String::as_str).collect::<Vec<_>>());
        let mut fig2_series = Vec::new();
        for alg in Algorithm::paper_set() {
            let mut cfg = bench_config(&ds.name, lam, alg);
            cfg.solver.threads = 1;
            let res = run_on(&cfg, ds.clone(), None).expect("solve");
            let measured_1 = res.metrics.updates_per_sec(res.elapsed_secs);

            let model = CostModel::calibrated(
                res.metrics.propose_secs,
                res.metrics.propose_nnz,
                res.metrics.proposals,
                res.metrics.update_secs,
                res.metrics.updates,
                ds.x.mean_col_nnz(),
            );
            let prof = profile_for(alg, &ds, &res, overlap);
            let model_1 = simulate::updates_per_sec(&model, &prof, 1).max(1e-12);

            let mut row = vec![alg.name().to_string()];
            let mut points = Vec::new();
            for &t in threads_list {
                let ups = if t == 1 {
                    measured_1
                } else {
                    measured_1 * simulate::updates_per_sec(&model, &prof, t) / model_1
                };
                points.push((t as f64, ups));
                row.push(format!("{ups:.2e}"));
            }
            fig2_series.push(super::plot::Series {
                label: alg.name().into(),
                points,
            });
            table.row(row);
        }
        println!("{}", table.render());
        let chart = super::plot::Chart {
            title: format!("Figure 2 — {} (updates/sec vs threads)", ds.name),
            x_label: "threads".into(),
            y_label: "updates/sec (log)".into(),
            log_y: true,
            series: fig2_series,
        };
        std::fs::create_dir_all("target").ok();
        let path = format!("target/fig2_{}.svg", ds.name);
        if chart.write_svg(&path).unwrap_or(false) {
            println!("(plot: {path})");
        }
    }
}

/// Shard-scaling experiment (the `shards` dimension of the evaluation):
/// the sharded execution layer vs the single pool at equal total thread
/// budget, for both partitioning extremes. Everything here is
/// *measured* (the sharded layer runs for real on one box; the
/// cross-socket win it is built for shows up as reduced reconcile
/// corrections under min-overlap partitioning).
pub fn print_shard_scaling(shards_list: &[usize], threads: usize) {
    let scale = bench_scale();
    let budget = bench_budget();
    println!(
        "# Shard scaling (scale {scale}, {budget}s/run, {threads} total threads, shotgun)\n"
    );
    for (ds, lam) in paper_datasets() {
        println!("## {}\n", ds.name);
        let mut table = Table::new(&[
            "shards",
            "strategy",
            "objective",
            "nnz",
            "updates/s",
            "reconcile s",
            "divergence",
        ]);
        for &s in shards_list {
            let strategies: &[&str] = if s <= 1 {
                &["contiguous"]
            } else {
                &["contiguous", "min-overlap"]
            };
            for strategy in strategies {
                let mut cfg = bench_config(&ds.name, lam, Algorithm::Shotgun);
                cfg.solver.threads = threads;
                cfg.solver.shards = s;
                cfg.solver.shard_strategy = (*strategy).into();
                let res = run_on(&cfg, ds.clone(), None).expect("solve");
                table.row(vec![
                    s.to_string(),
                    (*strategy).into(),
                    format!("{:.6}", res.objective),
                    res.nnz.to_string(),
                    format!("{:.2e}", res.metrics.updates_per_sec(res.elapsed_secs)),
                    format!("{:.3}", phase_secs(&res.metrics, "reconcile")),
                    format!("{:.3e}", res.metrics.replica_divergence),
                ]);
            }
        }
        println!("{}", table.render());
    }
}

/// NUMA/cadence experiment (the `gencd numa` subcommand): the PR-5
/// shard-layer perf levers A/B'd at an equal time budget — thread
/// pinning + first-touch replicas on vs off, and the reconcile cadence
/// fixed-every-round vs adaptive (max 8 rounds between reconciles).
/// Reported per run: objective (the correctness anchor — every row must
/// land on the same optimum), updates/s, reconcile seconds, the
/// dirty-chunk fold fraction, rounds skipped, and the node spread
/// (`numa_nodes`: 1 on a single-domain host means pinning degraded to
/// its documented no-op — expected in CI, meaningful on real iron).
pub fn print_numa_ab(shards: usize, threads: usize) {
    let scale = bench_scale();
    let budget = bench_budget();
    let topo = crate::util::topo::Topology::detect();
    println!(
        "# NUMA / reconcile cadence (scale {scale}, {budget}s/run, {shards} shards x \
         {threads} total threads, shotgun; host: {} NUMA node(s))\n",
        topo.n_nodes()
    );
    for (ds, lam) in paper_datasets() {
        println!("## {} (lambda = {lam:.0e})\n", ds.name);
        let mut table = Table::new(&[
            "pin",
            "cadence",
            "objective",
            "updates/s",
            "reconcile s",
            "dirty frac",
            "skipped",
            "nodes",
        ]);
        for (pin, adaptive) in [(false, false), (true, false), (false, true), (true, true)]
        {
            let mut cfg = bench_config(&ds.name, lam, Algorithm::Shotgun);
            cfg.solver.threads = threads;
            cfg.solver.shards = shards;
            cfg.solver.numa_pin = pin;
            cfg.solver.reconcile_every = 1;
            cfg.solver.reconcile_max_rounds = if adaptive { 8 } else { 0 };
            let res = run_on(&cfg, ds.clone(), None).expect("solve");
            table.row(vec![
                if pin { "on" } else { "off" }.into(),
                if adaptive { "adaptive<=8" } else { "every round" }.into(),
                format!("{:.6}", res.objective),
                format!("{:.2e}", res.metrics.updates_per_sec(res.elapsed_secs)),
                format!("{:.3}", phase_secs(&res.metrics, "reconcile")),
                format!("{:.3}", res.metrics.dirty_chunk_frac),
                res.metrics.reconcile_rounds_skipped.to_string(),
                res.metrics.numa_nodes.to_string(),
            ]);
        }
        println!("{}", table.render());
    }
}

/// Wire-transport experiment (the `gencd net` subcommand): the same
/// sharded solve over the in-memory barrier vs the loopback wire
/// (every reconcile exchange through full encode→frame→decode), exact
/// and f32 precision. Reported per run: the final objective (loopback
/// exact must match barrier to ~1e-12 — it is the same float sequence),
/// throughput, reconcile and codec time, and the wire volume the delta
/// frames would have cost a real network.
pub fn print_net_ab(shards: usize, threads: usize) {
    let scale = bench_scale();
    let budget = bench_budget();
    println!(
        "# Wire transport A/B (scale {scale}, {budget}s/run, {shards} shards x \
         {threads} total threads, shotgun)\n"
    );
    for (ds, lam) in paper_datasets() {
        println!("## {} (lambda = {lam:.0e})\n", ds.name);
        let mut table = Table::new(&[
            "transport",
            "objective",
            "updates/s",
            "reconcile s",
            "codec ms",
            "wire MB tx",
            "wire MB rx",
        ]);
        for (label, transport, precision) in [
            ("barrier", "barrier", "exact"),
            ("loopback exact", "loopback", "exact"),
            ("loopback f32", "loopback", "f32"),
        ] {
            let mut cfg = bench_config(&ds.name, lam, Algorithm::Shotgun);
            cfg.solver.threads = threads;
            cfg.solver.shards = shards;
            cfg.solver.transport = transport.into();
            cfg.solver.wire_precision = precision.into();
            let res = run_on(&cfg, ds.clone(), None).expect("solve");
            table.row(vec![
                label.into(),
                format!("{:.6}", res.objective),
                format!("{:.2e}", res.metrics.updates_per_sec(res.elapsed_secs)),
                format!("{:.3}", phase_secs(&res.metrics, "reconcile")),
                format!("{:.2}", phase_secs(&res.metrics, "codec") * 1e3),
                format!("{:.2}", res.metrics.wire_bytes_tx as f64 / 1e6),
                format!("{:.2}", res.metrics.wire_bytes_rx as f64 / 1e6),
            ]);
        }
        println!("{}", table.render());
    }
}

/// Screening experiment (the `gencd screen` subcommand): active-set
/// KKT screening on vs off at an equal time budget, for a
/// full-selection algorithm (GREEDY — where screened proposal work is
/// directly visible) and the paper's workhorse (SHOTGUN). Reported per
/// run: the final objective, the surviving active set, the number of
/// safety sweeps/reactivations, and the total Propose-phase work
/// (nonzeros traversed) that screening saved.
pub fn print_screening(threads: usize) {
    let scale = bench_scale();
    let budget = bench_budget();
    let kkt_every = crate::config::SolverConfig::default().kkt_every;
    println!(
        "# Screening (scale {scale}, {budget}s/run, {threads} threads, \
         kkt_every = {kkt_every})\n"
    );
    for (ds, lam) in paper_datasets() {
        println!("## {} (lambda = {lam:.0e})\n", ds.name);
        let mut table = Table::new(&[
            "algorithm",
            "screening",
            "objective",
            "nnz",
            "updates/s",
            "propose Mnnz",
            "active cols",
            "kkt passes",
            "reactivations",
            "stop",
        ]);
        for alg in [Algorithm::Greedy, Algorithm::Shotgun] {
            for screening in [false, true] {
                let mut cfg = bench_config(&ds.name, lam, alg);
                cfg.solver.threads = threads;
                cfg.solver.screening = screening;
                let res = run_on(&cfg, ds.clone(), None).expect("solve");
                table.row(vec![
                    alg.name().into(),
                    if screening { "on" } else { "off" }.into(),
                    format!("{:.6}", res.objective),
                    res.nnz.to_string(),
                    format!("{:.2e}", res.metrics.updates_per_sec(res.elapsed_secs)),
                    format!("{:.1}", res.metrics.propose_nnz as f64 / 1e6),
                    if screening {
                        format!("{} / {}", res.metrics.active_cols, ds.n_features())
                    } else {
                        "-".into()
                    },
                    res.metrics.kkt_passes.to_string(),
                    res.metrics.reactivations.to_string(),
                    res.stop.to_string(),
                ]);
            }
        }
        println!("{}", table.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::driver::run;

    #[test]
    fn fig2_profile_extraction() {
        let mut cfg = bench_config("dorothea@0.02", 1e-3, Algorithm::Shotgun);
        cfg.solver.threads = 1;
        cfg.solver.max_iters = 50;
        let res = run(&cfg).unwrap();
        let ds = crate::data::by_name("dorothea@0.02").unwrap();
        let p = profile_for(Algorithm::Shotgun, &ds, &res, 0.01);
        assert!(p.selected >= 1.0);
        assert_eq!(p.acceptor, AcceptShape::All);
        let pc = profile_for(Algorithm::Coloring, &ds, &res, 0.01);
        assert_eq!(pc.pairwise_overlap, 0.0);
    }
}
