//! Shared machinery for `benches/`: dataset/config setup and the
//! table/figure printers that regenerate the paper's evaluation outputs
//! (see DESIGN.md §6 for the experiment index).

pub mod experiments;
pub mod plot;

use crate::config::RunConfig;
use crate::coordinator::driver::SolveResult;
use crate::coordinator::Algorithm;
use crate::data;
use crate::sparse::io::Dataset;

/// Scale used by default for bench runs. The paper's full-size matrices
/// run too (set `GENCD_BENCH_SCALE=1.0`), they just take longer; CI-ish
/// runs use a fraction that keeps every figure's *shape* intact.
pub fn bench_scale() -> f64 {
    std::env::var("GENCD_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1)
}

/// Per-figure time budget (seconds per algorithm run).
pub fn bench_budget() -> f64 {
    std::env::var("GENCD_BENCH_SECONDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3.0)
}

/// The two evaluation datasets at bench scale, with the paper's lambda.
pub fn paper_datasets() -> Vec<(Dataset, f64)> {
    let scale = bench_scale();
    vec![
        (
            data::by_name(&format!("dorothea@{scale}")).expect("dorothea"),
            crate::data::dorothea::PAPER_LAMBDA,
        ),
        (
            data::by_name(&format!("reuters@{scale}")).expect("reuters"),
            crate::data::reuters::PAPER_LAMBDA,
        ),
    ]
}

/// Baseline RunConfig for a (dataset, algorithm) pair.
pub fn bench_config(dataset_name: &str, lam: f64, alg: Algorithm) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.dataset.name = dataset_name.into();
    cfg.problem.loss = "logistic".into();
    cfg.problem.lam = lam;
    cfg.solver.algorithm = alg.name().into();
    cfg.solver.threads = 4;
    cfg.solver.max_seconds = bench_budget();
    cfg.solver.max_iters = usize::MAX;
    cfg.solver.seed = 7;
    cfg
}

/// Markdown-ish table printer (fixed-width columns).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:>w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<1$}|", "", w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Convergence summary line for Figure 1-style reporting.
pub fn convergence_row(res: &SolveResult) -> Vec<String> {
    vec![
        res.algorithm.name().to_string(),
        format!("{:.6}", res.objective),
        format!("{}", res.nnz),
        format!("{}", res.metrics.updates),
        format!("{:.2e}", res.metrics.updates_per_sec(res.elapsed_secs)),
        format!("{:.2}", res.elapsed_secs),
        res.stop.to_string(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["alg", "obj"]);
        t.row(vec!["shotgun".into(), "0.5".into()]);
        t.row(vec!["x".into(), "0.25".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(s.contains("shotgun"));
    }

    #[test]
    fn bench_config_resolves() {
        let cfg = bench_config("dorothea@0.02", 1e-4, Algorithm::Shotgun);
        assert_eq!(cfg.solver.algorithm, "shotgun");
        assert_eq!(cfg.problem.lam, 1e-4);
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
