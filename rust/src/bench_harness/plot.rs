//! Dependency-free SVG line charts — renders the Figure 1 / Figure 2
//! curves the paper prints, straight from solver histories.

/// One line series.
#[derive(Clone, Debug)]
pub struct Series {
    pub label: String,
    pub points: Vec<(f64, f64)>,
}

/// Chart configuration.
#[derive(Clone, Debug)]
pub struct Chart {
    pub title: String,
    pub x_label: String,
    pub y_label: String,
    /// log10-scale the y axis (Figure 2 style).
    pub log_y: bool,
    pub series: Vec<Series>,
}

const W: f64 = 640.0;
const H: f64 = 420.0;
const ML: f64 = 70.0; // margins
const MR: f64 = 150.0;
const MT: f64 = 40.0;
const MB: f64 = 50.0;
const COLORS: [&str; 6] = [
    "#d62728", "#1f77b4", "#2ca02c", "#9467bd", "#ff7f0e", "#8c564b",
];

impl Chart {
    /// Render to an SVG string. Returns None if there is nothing finite
    /// to plot.
    pub fn to_svg(&self) -> Option<String> {
        let tx = |v: f64| v;
        let ty = |v: f64| if self.log_y { v.max(1e-300).log10() } else { v };
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for s in &self.series {
            for &(x, y) in &s.points {
                if x.is_finite() && y.is_finite() && (!self.log_y || y > 0.0) {
                    xs.push(tx(x));
                    ys.push(ty(y));
                }
            }
        }
        if xs.is_empty() {
            return None;
        }
        let (x0, x1) = bounds(&xs);
        let (y0, y1) = bounds(&ys);
        let px = |x: f64| ML + (tx(x) - x0) / (x1 - x0).max(1e-300) * (W - ML - MR);
        let py = |y: f64| H - MB - (ty(y) - y0) / (y1 - y0).max(1e-300) * (H - MT - MB);

        let mut svg = format!(
            r#"<svg xmlns="http://www.w3.org/2000/svg" width="{W}" height="{H}" viewBox="0 0 {W} {H}">
<style>text{{font-family:monospace;font-size:12px}}.t{{font-size:14px;font-weight:bold}}</style>
<rect width="{W}" height="{H}" fill="white"/>
<text class="t" x="{}" y="20" text-anchor="middle">{}</text>
"#,
            ML + (W - ML - MR) / 2.0,
            xml(&self.title)
        );
        // axes
        svg.push_str(&format!(
            r#"<line x1="{ML}" y1="{MT}" x2="{ML}" y2="{}" stroke="black"/>
<line x1="{ML}" y1="{}" x2="{}" y2="{}" stroke="black"/>
"#,
            H - MB,
            H - MB,
            W - MR,
            H - MB
        ));
        // ticks (5 per axis)
        for i in 0..=4 {
            let fx = x0 + (x1 - x0) * i as f64 / 4.0;
            let fy = y0 + (y1 - y0) * i as f64 / 4.0;
            let sx = ML + (W - ML - MR) * i as f64 / 4.0;
            let sy = H - MB - (H - MT - MB) * i as f64 / 4.0;
            let ylab = if self.log_y {
                format!("1e{fy:.1}")
            } else {
                format!("{fy:.4}")
            };
            svg.push_str(&format!(
                r#"<line x1="{sx}" y1="{}" x2="{sx}" y2="{}" stroke="black"/>
<text x="{sx}" y="{}" text-anchor="middle">{fx:.1}</text>
<line x1="{}" y1="{sy}" x2="{ML}" y2="{sy}" stroke="black"/>
<text x="{}" y="{}" text-anchor="end">{ylab}</text>
"#,
                H - MB,
                H - MB + 5.0,
                H - MB + 18.0,
                ML - 5.0,
                ML - 8.0,
                sy + 4.0
            ));
        }
        // axis labels
        svg.push_str(&format!(
            r#"<text x="{}" y="{}" text-anchor="middle">{}</text>
<text x="18" y="{}" text-anchor="middle" transform="rotate(-90 18 {})">{}</text>
"#,
            ML + (W - ML - MR) / 2.0,
            H - 10.0,
            xml(&self.x_label),
            H / 2.0,
            H / 2.0,
            xml(&self.y_label)
        ));
        // series
        for (i, s) in self.series.iter().enumerate() {
            let color = COLORS[i % COLORS.len()];
            let pts: Vec<String> = s
                .points
                .iter()
                .filter(|(x, y)| x.is_finite() && y.is_finite() && (!self.log_y || *y > 0.0))
                .map(|&(x, y)| format!("{:.1},{:.1}", px(x), py(y)))
                .collect();
            if pts.is_empty() {
                continue;
            }
            svg.push_str(&format!(
                r#"<polyline fill="none" stroke="{color}" stroke-width="1.8" points="{}"/>
<text x="{}" y="{}" fill="{color}">{}</text>
"#,
                pts.join(" "),
                W - MR + 8.0,
                MT + 16.0 * i as f64 + 10.0,
                xml(&s.label)
            ));
        }
        svg.push_str("</svg>\n");
        Some(svg)
    }

    /// Render and write to a file; returns whether anything was drawn.
    pub fn write_svg(&self, path: &str) -> anyhow::Result<bool> {
        match self.to_svg() {
            Some(svg) => {
                std::fs::write(path, svg)?;
                Ok(true)
            }
            None => Ok(false),
        }
    }
}

fn bounds(v: &[f64]) -> (f64, f64) {
    let lo = v.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if (hi - lo).abs() < 1e-300 {
        (lo - 0.5, hi + 0.5)
    } else {
        (lo, hi)
    }
}

fn xml(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chart(log_y: bool) -> Chart {
        Chart {
            title: "test <chart>".into(),
            x_label: "seconds".into(),
            y_label: "objective".into(),
            log_y,
            series: vec![
                Series {
                    label: "a".into(),
                    points: vec![(0.0, 1.0), (1.0, 0.5), (2.0, 0.25)],
                },
                Series {
                    label: "b".into(),
                    points: vec![(0.0, 0.9), (2.0, 0.8)],
                },
            ],
        }
    }

    #[test]
    fn renders_valid_svg() {
        let svg = chart(false).to_svg().unwrap();
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>\n"));
        assert_eq!(svg.matches("<polyline").count(), 2);
        assert!(svg.contains("&lt;chart&gt;"), "title must be escaped");
        // balanced tags
        assert_eq!(svg.matches("<text").count(), svg.matches("</text>").count());
    }

    #[test]
    fn log_scale_drops_nonpositive() {
        let mut c = chart(true);
        c.series[0].points.push((3.0, 0.0)); // dropped on log axis
        let svg = c.to_svg().unwrap();
        assert!(svg.contains("1e"));
    }

    #[test]
    fn empty_chart_is_none() {
        let c = Chart {
            title: "t".into(),
            x_label: "x".into(),
            y_label: "y".into(),
            log_y: false,
            series: vec![],
        };
        assert!(c.to_svg().is_none());
    }

    #[test]
    fn degenerate_single_point() {
        let c = Chart {
            title: "t".into(),
            x_label: "x".into(),
            y_label: "y".into(),
            log_y: false,
            series: vec![Series {
                label: "p".into(),
                points: vec![(1.0, 1.0)],
            }],
        };
        assert!(c.to_svg().is_some());
    }

    #[test]
    fn write_svg_creates_file() {
        let dir = std::env::temp_dir().join("gencd_plot_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.svg");
        assert!(chart(false).write_svg(path.to_str().unwrap()).unwrap());
        assert!(std::fs::read_to_string(&path).unwrap().contains("<svg"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
