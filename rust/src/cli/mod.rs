//! Command-line argument parsing (offline stand-in for `clap`).
//!
//! Grammar: `gencd <subcommand> [positionals] [--flag] [--key value]
//! [--key=value]`. Flags may repeat (`--set a=1 --set b=2`). Unknown
//! flags are an error at `finish()` so typos fail fast.

use std::collections::BTreeMap;

/// Parsed arguments for one subcommand.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positionals: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
    consumed: std::collections::BTreeSet<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> anyhow::Result<Self> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next().unwrap();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(flag) = arg.strip_prefix("--") {
                anyhow::ensure!(!flag.is_empty(), "bare '--' not supported");
                if let Some((k, v)) = flag.split_once('=') {
                    out.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    let v = it.next().unwrap();
                    out.flags.entry(flag.to_string()).or_default().push(v);
                } else {
                    // boolean flag
                    out.flags.entry(flag.to_string()).or_default().push(String::new());
                }
            } else {
                out.positionals.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment.
    pub fn from_env() -> anyhow::Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// All values of a repeatable flag.
    pub fn values(&mut self, name: &str) -> Vec<String> {
        self.consumed.insert(name.to_string());
        self.flags.get(name).cloned().unwrap_or_default()
    }

    /// Last value of a flag, if present.
    pub fn value(&mut self, name: &str) -> Option<String> {
        self.consumed.insert(name.to_string());
        self.flags.get(name).and_then(|v| v.last().cloned())
    }

    /// Boolean flag (present with no value, or `=true`).
    pub fn flag(&mut self, name: &str) -> bool {
        self.consumed.insert(name.to_string());
        match self.flags.get(name) {
            None => false,
            Some(vals) => vals
                .last()
                .map(|v| v.is_empty() || v == "true" || v == "1")
                .unwrap_or(true),
        }
    }

    /// Typed flag with default.
    pub fn get<T: std::str::FromStr>(&mut self, name: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.value(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name} {v}: {e}")),
        }
    }

    /// Error on any flag that was never consumed (typo detection).
    pub fn finish(&self) -> anyhow::Result<()> {
        for key in self.flags.keys() {
            if !self.consumed.contains(key) {
                anyhow::bail!("unknown flag --{key}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let mut a = parse(&[
            "train", "--config", "c.toml", "--set", "a=1", "--set=b=2", "--verbose",
        ]);
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.value("config").as_deref(), Some("c.toml"));
        assert_eq!(a.values("set"), vec!["a=1", "b=2"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn typed_get_with_default() {
        let mut a = parse(&["bench", "--threads", "8"]);
        assert_eq!(a.get("threads", 1usize).unwrap(), 8);
        assert_eq!(a.get("seed", 42u64).unwrap(), 42);
        assert!(a.get::<usize>("threads", 0).is_ok());
        let mut b = parse(&["bench", "--threads", "x"]);
        assert!(b.get("threads", 1usize).is_err());
    }

    #[test]
    fn unknown_flag_detected() {
        let mut a = parse(&["run", "--oops", "1"]);
        let _ = a.value("config");
        assert!(a.finish().is_err());
    }

    #[test]
    fn positionals() {
        let a = parse(&["color", "dorothea", "reuters"]);
        assert_eq!(a.positionals, vec!["dorothea", "reuters"]);
    }

    #[test]
    fn no_subcommand() {
        let a = parse(&["--help"]);
        assert_eq!(a.subcommand, "");
    }
}
