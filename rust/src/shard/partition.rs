//! Topology-aware column partitioning: which shard owns which features.
//!
//! A [`ShardPlan`] assigns every column of the design matrix to exactly
//! one shard. Three strategies are provided:
//!
//! * [`ShardStrategy::Contiguous`] — shard `s` of `S` owns the static
//!   chunk `k·s/S .. k·(s+1)/S` (the engine's `schedule(static)`
//!   division, via the shared [`crate::util::par::chunk`] helper).
//!   Zero-copy views need no column permutation, and columns that are
//!   adjacent on disk stay adjacent in a shard.
//! * [`ShardStrategy::RoundRobin`] — column `j` goes to shard `j % S`.
//!   Balances pathological column orderings (e.g. nnz sorted) at the
//!   cost of scattering locality.
//! * [`ShardStrategy::MinOverlap`] — greedy feature clustering in the
//!   spirit of Scherrer et al. 2013: columns are placed (heaviest
//!   first) on the shard whose already-touched sample set they overlap
//!   **most**, under a per-shard column-count cap that keeps the
//!   partition balanced — maximizing within-shard sample sharing is
//!   what minimizes it *between* shards. Shards that rarely touch the
//!   same samples make per-shard residual replicas cheap to reconcile —
//!   a reconcile conflict on sample `i` happens only when two shards
//!   both updated `i` in the same round.
//!
//! All strategies are deterministic (no RNG): a given matrix and shard
//! count always produce the same plan, which the differential tests
//! rely on.

use crate::sparse::CscMatrix;
use crate::util::par::chunk;

/// Column-partitioning strategy for [`partition`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardStrategy {
    /// Static contiguous ranges (default; identity permutation).
    Contiguous,
    /// Column `j` to shard `j % shards`.
    RoundRobin,
    /// Greedy sample-overlap minimization (feature-clustering style).
    MinOverlap,
}

impl ShardStrategy {
    /// Every strategy, in catalogue order (name lists derive from this).
    pub const ALL: [ShardStrategy; 3] = [
        ShardStrategy::Contiguous,
        ShardStrategy::RoundRobin,
        ShardStrategy::MinOverlap,
    ];

    /// Resolve a CLI/TOML name (dashed or underscored).
    pub fn by_name(s: &str) -> anyhow::Result<Self> {
        let canon = s.replace('_', "-");
        ShardStrategy::ALL
            .iter()
            .copied()
            .find(|st| st.name() == canon)
            .ok_or_else(|| {
                let names: Vec<&str> =
                    ShardStrategy::ALL.iter().map(|st| st.name()).collect();
                anyhow::anyhow!("unknown shard strategy '{s}' ({})", names.join("|"))
            })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ShardStrategy::Contiguous => "contiguous",
            ShardStrategy::RoundRobin => "round-robin",
            ShardStrategy::MinOverlap => "min-overlap",
        }
    }
}

impl std::str::FromStr for ShardStrategy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        ShardStrategy::by_name(s)
    }
}

/// A complete column-to-shard assignment: `shards[s]` lists the global
/// column ids shard `s` owns, in ascending order; concatenated they are
/// a permutation of `0..n_cols`. Shards may be empty when
/// `n_cols < shards` (callers typically drop those).
#[derive(Clone, Debug)]
pub struct ShardPlan {
    pub n_cols: usize,
    pub strategy: ShardStrategy,
    pub shards: Vec<Vec<u32>>,
}

impl ShardPlan {
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The concatenated assignment — a permutation of `0..n_cols` that
    /// makes every shard a contiguous range of the permuted matrix
    /// (feed to [`CscMatrix::select_columns`]).
    pub fn permutation(&self) -> Vec<u32> {
        let mut p = Vec::with_capacity(self.n_cols);
        for sh in &self.shards {
            p.extend_from_slice(sh);
        }
        p
    }

    /// Whether the permutation is the identity (true for every
    /// contiguous plan) — the zero-copy fast path needs no
    /// column-gather copy at all.
    pub fn is_identity(&self) -> bool {
        let mut expect = 0u32;
        for sh in &self.shards {
            for &j in sh {
                if j != expect {
                    return false;
                }
                expect += 1;
            }
        }
        expect as usize == self.n_cols
    }

    /// Check the exact-cover invariant: every column in exactly one
    /// shard. Partitions are constructed correct; this is the cheap
    /// guard external plans go through.
    pub fn validate(&self) -> anyhow::Result<()> {
        let mut seen = vec![false; self.n_cols];
        let mut total = 0usize;
        for (s, sh) in self.shards.iter().enumerate() {
            for &j in sh {
                let j = j as usize;
                anyhow::ensure!(
                    j < self.n_cols,
                    "shard {s}: column {j} out of range ({} columns)",
                    self.n_cols
                );
                anyhow::ensure!(!seen[j], "column {j} assigned to two shards");
                seen[j] = true;
                total += 1;
            }
        }
        anyhow::ensure!(
            total == self.n_cols,
            "{total} columns assigned, expected {}",
            self.n_cols
        );
        Ok(())
    }

    /// Mean number of *shards touching each nonempty sample* — the
    /// replica-reconcile cost proxy (1.0 is perfect: no sample is
    /// shared, reconcile corrections are all zero). Diagnostics for the
    /// bench harness and the partitioner tests.
    pub fn sample_overlap(&self, x: &CscMatrix) -> f64 {
        let words = x.n_rows().div_ceil(64);
        let mut counts = vec![0u32; x.n_rows()];
        let mut touched = vec![0u64; words];
        for sh in &self.shards {
            touched.iter_mut().for_each(|w| *w = 0);
            for &j in sh {
                let (rows, _) = x.col(j as usize);
                for &i in rows {
                    let (w, b) = (i as usize / 64, i as usize % 64);
                    if touched[w] >> b & 1 == 0 {
                        touched[w] |= 1 << b;
                        counts[i as usize] += 1;
                    }
                }
            }
        }
        let (mut sum, mut nonempty) = (0u64, 0u64);
        for &c in &counts {
            if c > 0 {
                sum += c as u64;
                nonempty += 1;
            }
        }
        if nonempty == 0 {
            0.0
        } else {
            sum as f64 / nonempty as f64
        }
    }
}

/// Partition the columns of `x` into `shards` shards with the given
/// strategy. `shards` must be >= 1; plans for `shards > n_cols` contain
/// empty shards.
pub fn partition(x: &CscMatrix, shards: usize, strategy: ShardStrategy) -> ShardPlan {
    assert!(shards >= 1, "need at least one shard");
    let k = x.n_cols();
    let shard_cols = match strategy {
        ShardStrategy::Contiguous => (0..shards)
            .map(|s| chunk(k, s, shards).map(|j| j as u32).collect())
            .collect(),
        ShardStrategy::RoundRobin => {
            let mut out = vec![Vec::with_capacity(k.div_ceil(shards)); shards];
            for j in 0..k {
                out[j % shards].push(j as u32);
            }
            out
        }
        ShardStrategy::MinOverlap => min_overlap(x, shards),
    };
    ShardPlan {
        n_cols: k,
        strategy,
        shards: shard_cols,
    }
}

/// Greedy sample-affinity clustering: minimizing the sample overlap
/// *between* shards is the same as maximizing it *within* them, so each
/// column (heaviest first — a heavy column constrains the clustering
/// most, so it picks while there is still freedom) joins the non-full
/// shard whose touched-sample set it overlaps **most**; shards thereby
/// internalize sample sharing, which is exactly what makes their
/// residual replicas cheap to reconcile. Ties go to the lighter shard
/// (by nnz), then the lower shard index — fully deterministic. The
/// per-shard cap `ceil(k / shards)` guarantees cover (sum of caps >= k)
/// and column-count balance.
fn min_overlap(x: &CscMatrix, shards: usize) -> Vec<Vec<u32>> {
    let k = x.n_cols();
    let cap = k.div_ceil(shards.max(1)).max(1);
    let words = x.n_rows().div_ceil(64);
    let mut order: Vec<u32> = (0..k as u32).collect();
    order.sort_by_key(|&j| (std::cmp::Reverse(x.col_nnz(j as usize)), j));

    let mut touched = vec![vec![0u64; words]; shards];
    let mut load = vec![0usize; shards];
    let mut out = vec![Vec::with_capacity(cap); shards];
    for &j in &order {
        let (rows, _) = x.col(j as usize);
        let mut best = usize::MAX;
        let mut best_overlap = 0usize;
        for (s, bits) in touched.iter().enumerate() {
            if out[s].len() >= cap {
                continue;
            }
            let overlap = rows
                .iter()
                .filter(|&&i| bits[i as usize / 64] >> (i as usize % 64) & 1 == 1)
                .count();
            let better = best == usize::MAX
                || overlap > best_overlap
                || (overlap == best_overlap && load[s] < load[best]);
            if better {
                best = s;
                best_overlap = overlap;
            }
        }
        debug_assert!(best != usize::MAX, "cap guarantees a non-full shard");
        out[best].push(j);
        for &i in rows {
            touched[best][i as usize / 64] |= 1 << (i as usize % 64);
        }
        load[best] += rows.len();
    }
    // ascending column order within a shard: deterministic views and
    // monotone slab access in the permuted matrix
    for sh in &mut out {
        sh.sort_unstable();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooBuilder;
    use crate::util::Pcg64;

    fn random_matrix(seed: u64, n: usize, k: usize, density: f64) -> CscMatrix {
        let mut rng = Pcg64::seeded(seed);
        let mut b = CooBuilder::new(n, k);
        for j in 0..k {
            for i in 0..n {
                if rng.next_f64() < density {
                    b.push(i, j, rng.range_f64(-1.0, 1.0));
                }
            }
        }
        b.build()
    }

    #[test]
    fn strategy_names_roundtrip() {
        for s in ShardStrategy::ALL {
            assert_eq!(ShardStrategy::by_name(s.name()).unwrap(), s);
        }
        assert_eq!(
            "min_overlap".parse::<ShardStrategy>().unwrap(),
            ShardStrategy::MinOverlap
        );
        assert!(ShardStrategy::by_name("magic").is_err());
    }

    #[test]
    fn every_strategy_exactly_covers() {
        // the partitioner invariant, incl. the k < shards edge case
        for (n, k) in [(30usize, 17usize), (10, 3), (8, 1), (12, 40)] {
            let x = random_matrix(k as u64, n, k, 0.3);
            for shards in [1usize, 2, 3, 5, 8] {
                for strategy in ShardStrategy::ALL {
                    let plan = partition(&x, shards, strategy);
                    assert_eq!(plan.n_shards(), shards);
                    plan.validate().unwrap_or_else(|e| {
                        panic!("{} k={k} S={shards}: {e}", strategy.name())
                    });
                    let mut perm = plan.permutation();
                    perm.sort_unstable();
                    assert_eq!(perm, (0..k as u32).collect::<Vec<_>>());
                    // ascending within each shard
                    for sh in &plan.shards {
                        assert!(sh.windows(2).all(|w| w[0] < w[1]));
                    }
                }
            }
        }
    }

    #[test]
    fn contiguous_is_identity_and_matches_chunk() {
        let x = random_matrix(1, 20, 23, 0.2);
        let plan = partition(&x, 4, ShardStrategy::Contiguous);
        assert!(plan.is_identity());
        for s in 0..4 {
            let want: Vec<u32> =
                crate::util::par::chunk(23, s, 4).map(|j| j as u32).collect();
            assert_eq!(plan.shards[s], want);
        }
        let rr = partition(&x, 4, ShardStrategy::RoundRobin);
        assert!(!rr.is_identity());
        assert_eq!(rr.shards[1][0], 1);
        assert_eq!(rr.shards[1][1], 5);
    }

    #[test]
    fn min_overlap_balanced_and_capped() {
        let x = random_matrix(7, 40, 30, 0.25);
        for shards in [2usize, 3, 7] {
            let plan = partition(&x, shards, ShardStrategy::MinOverlap);
            plan.validate().unwrap();
            let cap = 30usize.div_ceil(shards);
            for sh in &plan.shards {
                assert!(sh.len() <= cap, "shard over cap: {} > {cap}", sh.len());
            }
        }
    }

    #[test]
    fn min_overlap_separates_block_diagonal() {
        // two independent feature blocks touching disjoint sample
        // halves: min-overlap must recover the blocks (sample_overlap
        // 1.0) where round-robin mixes them (overlap ~2.0). Sliding
        // 9-row windows (stride 3) guarantee every consecutive
        // same-block column overlaps, so the greedy has no ambiguity.
        let mut b = CooBuilder::new(40, 20);
        for j in 0..20 {
            let (base, jloc) = if j < 10 { (0, j) } else { (20, j - 10) };
            for t in 0..9 {
                b.push(base + (3 * jloc + t) % 20, j, 1.0 + j as f64);
            }
        }
        let x = b.build();
        let mo = partition(&x, 2, ShardStrategy::MinOverlap);
        let rr = partition(&x, 2, ShardStrategy::RoundRobin);
        let (o_mo, o_rr) = (mo.sample_overlap(&x), rr.sample_overlap(&x));
        assert!(
            (o_mo - 1.0).abs() < 1e-9,
            "min-overlap should separate the blocks: overlap {o_mo}"
        );
        assert!(o_rr > 1.5, "round-robin should mix the blocks: {o_rr}");
        // and each recovered shard is one block
        for sh in &mo.shards {
            let halves: std::collections::HashSet<bool> =
                sh.iter().map(|&j| j < 10).collect();
            assert_eq!(halves.len(), 1, "shard mixes blocks: {sh:?}");
        }
    }

    #[test]
    fn shard_plan_property_exact_cover() {
        // 100 seeded adversarial shapes: p < shards, p == 1, entirely
        // empty columns, empty matrices of columns, dense and
        // near-empty densities — every strategy must hold the
        // exact-cover invariant (validate()), keep shards ascending,
        // and produce a permutation of 0..k
        let mut rng = Pcg64::seeded(0x5AAD);
        for case in 0..100 {
            let n = 1 + rng.below(40);
            let k = 1 + rng.below(50);
            let density = [0.0, 0.02, 0.3, 0.9][rng.below(4)];
            // a random subset of columns left entirely empty
            let mut b = CooBuilder::new(n, k);
            for j in 0..k {
                if rng.next_f64() < 0.2 {
                    continue; // empty column
                }
                for i in 0..n {
                    if rng.next_f64() < density {
                        b.push(i, j, rng.range_f64(-1.0, 1.0));
                    }
                }
            }
            let x = b.build();
            // shard counts bracketing k: 1, below, equal, above
            for shards in [1, (k / 2).max(1), k, k + 1 + rng.below(8)] {
                for strategy in ShardStrategy::ALL {
                    let plan = partition(&x, shards, strategy);
                    assert_eq!(plan.n_shards(), shards);
                    plan.validate().unwrap_or_else(|e| {
                        panic!(
                            "case {case} {} n={n} k={k} S={shards}: {e}",
                            strategy.name()
                        )
                    });
                    let mut perm = plan.permutation();
                    perm.sort_unstable();
                    assert_eq!(perm, (0..k as u32).collect::<Vec<_>>());
                    for sh in &plan.shards {
                        assert!(
                            sh.windows(2).all(|w| w[0] < w[1]),
                            "case {case}: shard not ascending"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn validate_rejects_broken_plans() {
        let mk = |shards: Vec<Vec<u32>>| ShardPlan {
            n_cols: 4,
            strategy: ShardStrategy::Contiguous,
            shards,
        };
        assert!(mk(vec![vec![0, 1], vec![2, 3]]).validate().is_ok());
        assert!(mk(vec![vec![0, 1], vec![1, 2, 3]]).validate().is_err());
        assert!(mk(vec![vec![0, 1], vec![3]]).validate().is_err());
        assert!(mk(vec![vec![0, 1, 9], vec![2, 3]]).validate().is_err());
    }
}
