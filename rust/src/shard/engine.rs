//! The sharded execution layer: one GenCD worker pool per shard, each
//! against a **shard-local residual replica**, reconciled at iteration
//! boundaries — NUMA-pinned, delta-folded, and adaptively cadenced.
//!
//! # Why replicas
//!
//! The single-engine hot path already minimizes synchronization *within*
//! one coherent memory domain (spin barriers, buffered scatters), but
//! every worker still writes the same `z` array — across sockets that
//! cross-domain traffic, not arithmetic, is the wall (the Shotgun
//! shared-memory contention of Bradley et al. 2011, one level up).
//! Sharding removes it structurally: shard `s` owns a column subset
//! (a [`ShardPlan`](super::partition::ShardPlan)) and runs a complete,
//! unmodified [`engine::solve_from`] pool against its own full-length
//! `z` replica, so **no cache line is ever shared between shards inside
//! a round**.
//!
//! # §NUMA: pinning and first-touch
//!
//! With [`ShardedConfig::numa_pin`] on, the layer makes "one shard per
//! memory domain" literal:
//!
//! 1. the host topology is read once
//!    ([`Topology::detect`](crate::util::topo::Topology::detect):
//!    `/sys/devices/system/node` on Linux, a single-node fallback
//!    elsewhere) and shard `s` is assigned node `s mod nodes`;
//! 2. each shard's spawned thread pins **itself** to its node's CPUs
//!    (`sched_setaffinity`) *before allocating anything* — spawned
//!    threads inherit the affinity mask, so the whole pool lands on the
//!    node without the engine knowing pinning exists;
//! 3. only then does the thread construct its [`SharedState`] replica
//!    (zero-fill is the first touch, so the pages land in node-local
//!    DRAM) and call [`engine::solve_from`], whose buffered-reduce
//!    accumulators and spill maps are likewise allocated — first-touched
//!    — on the pinned pool threads.
//!
//! The replica slots are [`OnceLock`]s filled by the shard threads and
//! published by one extra *init* barrier crossing before round 0, so
//! every shard (and the coordinator) can still read every replica during
//! reconcile. Pinning degrades gracefully: on a single-node host it is
//! skipped, on non-Linux (or when every `sched_setaffinity` is refused,
//! e.g. by a cgroup) it becomes a no-op — either way the solve is
//! bit-identical to the unpinned one and
//! [`MetricsSnapshot::numa_nodes`] reports `1` as the warning value
//! (`0` = pinning off, `>= 2` = real multi-node spread).
//!
//! # Bulk-synchronous rounds
//!
//! Every pool runs exactly one GenCD iteration per *round*. At a
//! reconcile boundary — delivered through the engine's own [`Observer`]
//! hook, which runs on each pool's leader while that pool's workers are
//! parked — the shards meet at a reconcile barrier and fold their
//! replicas, buffered-reduce style (disjoint cache-aligned sample
//! chunks, one owner per element, exactly the machinery of
//! [`crate::util::par::aligned_chunk`]):
//!
//! ```text
//!   z[i]  <-  z[i] + sum_s (z_s[i] - z[i])     (one owner per chunk)
//!   z_s[i] <- z[i]                             (replicas refreshed)
//! ```
//!
//! Between reconciles a shard sees only its *own* updates on top of the
//! last reconciled residual — the same frozen-residual semantics the
//! accept/line-search phases already assume for the buffered update
//! path, now at shard granularity. Cross-shard corrections surface as
//! [`MetricsSnapshot::replica_divergence`]; reconcile time as
//! [`MetricsSnapshot::reconcile_secs`].
//!
//! ## Dirty-chunk delta fold
//!
//! The dense fold costs O(n · shards) per reconcile whether anything
//! moved or not. With [`ShardedConfig::delta_reconcile`] (the default),
//! each pool's Update scatter marks a per-shard
//! [`DirtyChunks`](crate::util::par::DirtyChunks) bitmap — one bit per
//! 128-byte chunk of z, the same granularity as the fold's aligned
//! chunks, so no chunk straddles two fold owners — and the fold visits
//! only chunks dirty in *some* shard since the last reconcile. The
//! contract that makes the delta fold **byte-identical** to the dense
//! one: every z write inside a round goes through the engine's Update
//! phase (all four disciplines mark), and after a reconcile every
//! replica equals the canonical residual, so a clean chunk has zero
//! delta in every shard and the dense fold would not have written it
//! either. On screened runs with a few percent of columns active, most
//! of z never moves and the fold collapses to O(touched)
//! ([`MetricsSnapshot::dirty_chunk_frac`]). Each shard clears its own
//! bitmap between the fold-publish and decision-publish crossings,
//! while every pool's writers are parked.
//!
//! # §Reconcile cadence
//!
//! Reconciling every round is the safest schedule and the most
//! synchronization-hungry one. [`ShardedConfig::reconcile_every`] (R)
//! reconciles every R rounds instead; rounds in between return from the
//! observer *without touching the barrier at all* — the pools run fully
//! decoupled and re-synchronize at the next reconcile round, counted by
//! [`MetricsSnapshot::reconcile_rounds_skipped`].
//!
//! With [`ShardedConfig::reconcile_max_rounds`] > R the cadence becomes
//! **adaptive**, driven by the measured per-reconcile conflict
//! magnitude (the `replica_divergence` trend):
//!
//! * a conflict-free reconcile (no shard needed a correction on a
//!   sample it wrote itself) doubles R, up to `reconcile_max_rounds`;
//! * a conflict **spike** — this reconcile's max correction above 4x
//!   the running EWMA, or the first conflict ever seen — snaps R back
//!   to `reconcile_every`;
//! * in between, R holds.
//!
//! The next gap is decided by the coordinator between barrier
//! crossings and published with the stop decision, so every pool
//! computes the *same* next reconcile round — lockstep is preserved
//! exactly at the rounds where it matters. All stopping decisions
//! (round cap, wall clock, tolerance, divergence, screening gate,
//! observers) are taken **only at reconciled rounds**, and the gap is
//! clamped so the final reconcile lands exactly on `max_rounds` — the
//! convergence-gate semantics are unchanged from the every-round
//! schedule.
//!
//! # Lockstep stopping
//!
//! A pool that stopped on its own (time, iteration cap, divergence)
//! would strand the other shards at the reconcile barrier, so the
//! per-shard engines are configured to never stop themselves: all
//! stopping decisions are taken once per reconcile by the shard-0
//! *coordinator* between barrier crossings and delivered to every pool
//! simultaneously through the observer's `ControlFlow::Break`. The
//! coordinator also owns the global convergence [`History`]: it gathers
//! `w` across shards and evaluates the true global objective at the
//! usual log cadence. A caller-supplied [`Observer`] (see
//! [`solve_sharded_with`]) runs on the coordinator at every reconciled
//! round, against the reconciled global iterate.
//!
//! # Single-shard exactness
//!
//! With one shard the reconcile degenerates to nothing — the replica
//! *is* the canonical residual and is never rewritten — so a one-shard
//! sharded solve replays the unsharded engine's floating-point sequence
//! bit-exactly at T = 1 (pinned by `rust/tests/sharding.rs`).
//!
//! # The reconcile link
//!
//! The three barrier crossings of a reconcile round (plus the init
//! crossing before round 0) are the *only* cross-shard synchronization
//! in the layer, and they are abstracted behind [`ReconcileLink`] — a
//! fallible transport seam. [`BarrierLink`], the default, is the
//! original SpinBarrier protocol (identity fold order, so it is
//! bit-exact with the pre-seam engine); `sim::SimLink`
//! ([`crate::sim`]) drives the same pool code under deterministic
//! virtual time with injected delay, reordering, stragglers, and
//! panics; the [`crate::net`] transports (loopback, TCP) speak the same
//! four-crossing contract over serialized frames (§Wire format below).
//! A link crossing can *fail* ([`LinkFault`]), which is what makes the
//! failure semantics below expressible at all.
//!
//! # §Failure semantics
//!
//! A shard pool can die mid-solve (a panic in policy code, an injected
//! fault, a wedged peer). The layer's contract is **degrade, never
//! hang**:
//!
//! * **Barrier timeout** — every [`BarrierLink`] crossing waits at most
//!   [`ShardedConfig::barrier_timeout_secs`] (default 30 s; `<= 0`
//!   means effectively forever). A timed-out waiter poisons the barrier
//!   on its way out, so *all* surviving shards unblock — the timed-out
//!   ones with [`LinkFault::TimedOut`], the rest with
//!   [`LinkFault::Poisoned`] — record their fault, and stop their pools
//!   gracefully via `ControlFlow::Break`.
//! * **Pool panic** — a panicking pool poisons the link from a drop
//!   guard before unwinding (so its peers escape immediately rather
//!   than after the timeout) and surfaces through the join as a
//!   captured panic payload.
//! * **`StopReason::ShardFailed` contract** — any of the above turns
//!   the whole solve into a *structured* failure: the output carries
//!   `stop == ShardFailed`, `failure == Some(SolveError)` (first cause:
//!   panic payload or link fault, with the observing shard's index),
//!   and [`MetricsSnapshot::shard_failures`] counts the dead pools. The
//!   returned iterate is best-effort — the surviving shards' `w` as of
//!   their last completed round, zeros for a shard that died before
//!   publishing its replica. Healthy solves are completely unaffected:
//!   the happy-path crossing is the same spin protocol with one extra
//!   deadline check every 1024 spins.
//! * **Bounded staleness** — [`ShardedConfig::max_staleness_rounds`]
//!   (> 0) clamps the adaptive cadence: whenever the doubling wants a
//!   reconcile gap above the bound, the gap is forced down to it (and
//!   counted in [`MetricsSnapshot::staleness_forced_reconciles`]), so
//!   no shard's replica is ever more than that many rounds stale — the
//!   divergence bound of Bradley et al. 2011 stays finite by
//!   construction.
//! * **Objective tripwire** — an objective *increase* between
//!   consecutive reconciled log records snaps the adaptive cadence back
//!   to its floor (the EWMA conflict-spike tripwire already does this
//!   for replica conflicts), so decoupled rounds cannot compound a
//!   divergence trend.
//! * **Reconnect** ([`crate::recover`]) — a wire link may *heal* a
//!   transient socket fault before it becomes a [`LinkFault`]. Per
//!   peer, the TCP transport runs this state machine:
//!
//!   ```text
//!              disconnect-class socket error
//!   Connected ────────────────────────────────► Degraded(backoff)
//!       ▲                                          │          │
//!       │  re-handshake (HELLO carries the         │          │ attempts
//!       │  parked round) + idempotent replay       │          │ exhausted
//!       └──────────────────── Rejoined ◄───────────┘          ▼
//!                                                           Failed
//!                                                 (poison → ShardFailed)
//!   ```
//!
//!   *Degraded* sleeps the bounded-exponential schedule
//!   ([`ReconnectPolicy`](crate::recover::backoff::ReconnectPolicy)),
//!   redials, and re-handshakes with a HELLO that carries the parked
//!   round, so the relay can replay a lost release or dedupe a re-sent
//!   arrival; the delta frame carries absolute values, so replaying it
//!   is a no-op (§Wire format). *Rejoined* resumes the round exactly
//!   where it parked. *Failed* is precisely the pre-recover contract:
//!   [`LinkFault::Poisoned`] → `StopReason::ShardFailed` +
//!   `SolveErrorKind::Link` — bounded time, never a hang.
//! * **Checkpoint / resume** ([`crate::recover::checkpoint`]) — with
//!   [`ShardedConfig::checkpoint`] set, the shard-0 coordinator
//!   serializes the reconciled iterate (`w`, `z`, completed rounds,
//!   cadence state, policy-stream seed) through the CRC-guarded
//!   checkpoint codec every `every_rounds` reconciles and at the
//!   stopping round, via write-to-temp + atomic rename — a crash never
//!   leaves a torn file where a resume would read it.
//!   [`ShardedConfig::resume`] seeds a fresh solve from such a
//!   checkpoint: replicas start from the checkpointed `w`/`z` (no
//!   warm-start matvec — the reconciled residual is restored verbatim),
//!   every shard's selection policy is fast-forwarded by the completed
//!   round count (policies are feedback-free streams — state is a pure
//!   function of the call count), and the reconcile schedule re-aligns
//!   to the stored gap. Under exact wire precision the resumed
//!   trajectory is bit-identical to the uninterrupted solve (pinned by
//!   `rust/tests/recover.rs`).
//!
//! # §Wire format
//!
//! When the link is a wire transport ([`crate::net`]), the reconcile
//! exchange is serialized into length-prefixed frames. This section is
//! the authoritative byte-level specification; `net::frame` implements
//! it and the codec round-trip tests in `rust/tests/net_link.rs` cite
//! it. All multi-byte integers and floats are **little-endian**.
//!
//! Every frame opens with a fixed 20-byte header:
//!
//! | offset | size | field | meaning |
//! |-------:|-----:|-------|---------|
//! | 0 | 4 | magic | ASCII `GCD1` (`0x47 0x43 0x44 0x31`) |
//! | 4 | 1 | tag | 1 delta · 2 decision · 3 arrive · 4 release · 5 poison |
//! | 5 | 1 | flags | bit 0: 0 = exact f64 values, 1 = f32-quantized; bits 1–7 must be 0 |
//! | 6 | 2 | shard | u16, sender's shard index |
//! | 8 | 8 | round | u64, reconcile round (crossing counter for control frames) |
//! | 16 | 4 | payload_len | u32, byte count following this field |
//!
//! **Delta payload** (tag 1) — one shard's touched replica state for
//! the round:
//!
//! | offset | size | field |
//! |-------:|-----:|-------|
//! | 0 | 8 | `n` — replica length in f64 elements (u64) |
//! | 8 | 4 | `n_chunks` — must equal `ceil(n / 16)` (u32) |
//! | 12 | 4 | `n_dirty` — carried chunk count; must equal the bitmap popcount (u32) |
//! | 16 | `ceil(n_chunks/64) * 8` | dirty bitmap: u64 words, chunk `c` = word `c/64` bit `c%64`; bits ≥ `n_chunks` must be 0 |
//! | … | — | carried chunks in **ascending** chunk order: 16 values each (8 B exact / 4 B f32), the last chunk truncated to `n − 16·c` values |
//!
//! A chunk is [`DIRTY_CHUNK_ELEMS`](crate::util::par::DIRTY_CHUNK_ELEMS)
//! = 16 consecutive f64s — one 128-byte cache-line pair, the same
//! granularity the in-memory delta fold tracks. Chunk values are
//! **absolute** replica contents, not increments: re-applying a frame
//! is a no-op, so duplicate delivery is idempotent by construction
//! (pinned by `scenarios/net/01-duplicate-delivery.toml`).
//!
//! **Decision payload** (tag 2) — the coordinator's fold verdict:
//! round echo (u64), `next_gap` (u64), then one stop-code byte
//! (0 none · 1 max-iters · 2 max-seconds · 3 tolerance · 4 diverged ·
//! 5 observer · 6 converged · 7 shard-failed).
//!
//! **Control frames** (tags 3–5) have `payload_len = 0` and exist only
//! on the TCP transport's control plane: `arrive` announces a shard at
//! a crossing (`round` holds the crossing counter), `release` is the
//! coordinator-relay's broadcast that all parties arrived, `poison`
//! broadcasts a dying peer.
//!
//! Any malformed frame — short read, bad magic, unknown tag or flag,
//! length or popcount mismatch, bitmap bits past `n_chunks`, trailing
//! bytes — decodes to a clean `net::codec::DecodeError`, surfaces as
//! [`LinkFault::Protocol`], and lands the solve in
//! `StopReason::ShardFailed` like every other link fault. Never a
//! panic, never a hang.
//!
//! [`OnceLock`]: std::sync::OnceLock

use std::ops::ControlFlow;
use std::sync::OnceLock;
use std::time::Duration;

use crate::coordinator::accept::Accept;
use crate::coordinator::convergence::{History, Record, SolveError, StopReason};
use crate::coordinator::engine::{self, EngineConfig, EngineHooks, SolveOutput, UpdatePath};
use crate::coordinator::metrics::MetricsSnapshot;
use crate::coordinator::observer::{IterationInfo, Observer};
use crate::coordinator::problem::{Problem, SharedState};
use crate::coordinator::select::Select;
use crate::event::{
    self, emit, CheckpointWritten, CodecError, EventSink, IterationCompleted, Meta,
    MetricsAggregator, PeerReconnected, ReconcileRound, ResumeLoaded, ShardFailed,
    WireFrameReceived, WireFrameSent,
};
use crate::loss;
use crate::recover::checkpoint::{Checkpoint, CheckpointSpec, ResumeState};
use crate::util::atomic::{SyncCell, SyncF64Vec};
use crate::util::par::{
    aligned_chunk, CachePadded, DirtyChunks, SpinBarrier, WaitOutcome, DEFAULT_SPIN,
    DIRTY_CHUNK_ELEMS,
};
use crate::util::topo::Topology;
use crate::util::Timer;

/// A conflict reading above this multiple of the running EWMA snaps the
/// adaptive reconcile cadence back to its floor (module docs
/// §Reconcile cadence).
const CONFLICT_SPIKE: f64 = 4.0;

/// Effectively-infinite barrier timeout (`barrier_timeout_secs <= 0`):
/// one year, large enough to never fire, small enough that
/// `Instant::now() + timeout` cannot overflow.
const FOREVER: Duration = Duration::from_secs(365 * 24 * 3600);

/// Why a [`ReconcileLink`] crossing failed (module docs §Failure
/// semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkFault {
    /// The link was poisoned — a peer died (panicked, or timed out and
    /// poisoned on its way out).
    Poisoned,
    /// This shard's own wait exceeded the timeout with peers missing;
    /// the waiter poisoned the link before returning so its peers
    /// escape too.
    TimedOut,
    /// A wire transport received bytes that violate the frame protocol
    /// (§Wire format) — truncated frame, bad magic, inconsistent
    /// lengths. Carries the decoder's static reason
    /// ([`DecodeError::reason`](crate::net::codec::DecodeError::reason)).
    /// Only wire links ([`crate::net`]) produce this; the observing
    /// shard poisons the link on its way out, so peers see
    /// [`Poisoned`](Self::Poisoned).
    Protocol(&'static str),
}

impl LinkFault {
    /// The human-readable cause carried into [`SolveError::message`].
    pub(crate) fn message(self) -> &'static str {
        match self {
            LinkFault::Poisoned => "reconcile link poisoned by a dying peer",
            LinkFault::TimedOut => "reconcile barrier timed out (peer missing)",
            LinkFault::Protocol(reason) => reason,
        }
    }

    /// The failure class carried into [`SolveError::kind`].
    pub(crate) fn kind(self) -> crate::coordinator::convergence::SolveErrorKind {
        use crate::coordinator::convergence::SolveErrorKind;
        match self {
            LinkFault::Poisoned => SolveErrorKind::Link,
            LinkFault::TimedOut => SolveErrorKind::Timeout,
            LinkFault::Protocol(_) => SolveErrorKind::Protocol,
        }
    }
}

/// What a wire link ships at a delta exchange: a borrowed view of one
/// shard's replica plus its dirty-chunk map, handed to
/// [`ReconcileLink::wire_delta`] right before the `arrive` crossing.
///
/// A wire transport reads the dirty chunks out of `z`, encodes them
/// (engine §Wire format), routes the bytes, decodes, and writes the
/// decoded values *back into `z`* — identity under
/// `wire_precision = exact`, an f32 round-trip under `f32`. Writing
/// back before the crossing means every peer's fold then reads exactly
/// the values that survived the wire, reproducing a real lossy
/// transport inside one process. In-memory links never touch it.
pub struct DeltaPayload<'a> {
    /// Reconcile round (the engine's iteration counter at the exchange).
    pub round: usize,
    /// This shard's dirty-chunk map for the round; `None` means the
    /// exchange is dense (delta tracking off) — every chunk is
    /// implicitly dirty.
    pub dirty: Option<&'a DirtyChunks>,
    /// This shard's full-length replica (atomic view — the pool is
    /// quiescent at the exchange, so plain-speed reads/writes are safe).
    pub z: &'a SyncF64Vec,
    /// Replica length in elements.
    pub n: usize,
}

/// The coordinator's fold decision as it crosses the wire, handed to
/// [`ReconcileLink::wire_decision`] between `plan_round` and the
/// `publish_decision` crossing. A wire link encodes it, routes the
/// bytes, decodes, and writes the decoded record back — so the gap and
/// stop verdict every pool acts on are exactly the bytes that crossed.
pub struct DecisionPayload {
    /// Reconcile round the decision belongs to.
    pub round: usize,
    /// Iterations until the next reconcile (adaptive cadence output).
    pub next_gap: usize,
    /// Stop verdict, if the coordinator called the solve.
    pub stop: Option<StopReason>,
}

/// Wire accounting for one [`ReconcileLink::wire_delta`] /
/// [`ReconcileLink::wire_decision`] call, summed into
/// [`MetricsSnapshot::wire_bytes_tx`]/[`wire_bytes_rx`]/[`codec_secs`].
///
/// [`wire_bytes_rx`]: MetricsSnapshot::wire_bytes_rx
/// [`codec_secs`]: MetricsSnapshot::codec_secs
#[derive(Clone, Copy, Debug, Default)]
pub struct WireCost {
    /// Bytes encoded and sent.
    pub bytes_tx: u64,
    /// Bytes received and decoded.
    pub bytes_rx: u64,
    /// Nanoseconds spent encoding + decoding (codec work only, not
    /// blocking waits — those are reconcile time).
    pub nanos: u64,
}

impl WireCost {
    /// The in-memory links' answer: nothing crossed a wire.
    pub const NONE: WireCost = WireCost {
        bytes_tx: 0,
        bytes_rx: 0,
        nanos: 0,
    };
}

/// The cross-shard transport seam (module docs §The reconcile link):
/// the four crossings of the reconcile protocol, each fallible, plus
/// the fold order the delta sum walks replicas in. All methods are
/// called concurrently by every shard's pool leader; an implementation
/// must be a *barrier* in each crossing (no shard proceeds until all
/// arrived, or the crossing fails for everyone it can still reach).
///
/// [`BarrierLink`] is the production impl — the original SpinBarrier
/// protocol, bit-exact with the pre-seam engine. `sim::SimLink`
/// ([`crate::sim`]) layers deterministic virtual time and fault
/// injection over it without the pool code knowing; the wire links
/// ([`crate::net`]) additionally move the exchanged state through the
/// frame codec via the two `wire_*` hooks below.
pub trait ReconcileLink: Sync {
    /// The init crossing: every shard has published its replica slot;
    /// crossing it makes all replicas readable everywhere (round -1).
    fn init(&self, s: usize) -> Result<(), LinkFault>;
    /// Crossing 1 of reconcile `round`: every shard finished the round,
    /// all replica updates are visible.
    fn arrive(&self, s: usize, round: usize) -> Result<(), LinkFault>;
    /// Crossing 2: every shard's fold finished — the reconciled
    /// residual is published everywhere.
    fn publish_fold(&self, s: usize, round: usize) -> Result<(), LinkFault>;
    /// Crossing 3: the coordinator's stop decision and next gap are
    /// published.
    fn publish_decision(&self, s: usize, round: usize) -> Result<(), LinkFault>;
    /// Wire hook, called by shard `s` immediately **before** the
    /// `arrive` crossing of a reconcile round: ship this shard's dirty
    /// replica chunks through the transport and write what survived
    /// back into `payload.z` (see [`DeltaPayload`]). In-memory links
    /// keep the default no-op — the replica is already shared memory.
    /// A decode failure must surface as [`LinkFault::Protocol`] (and
    /// poison the link), never a panic.
    fn wire_delta(&self, s: usize, payload: &DeltaPayload<'_>) -> Result<WireCost, LinkFault> {
        let _ = (s, payload);
        Ok(WireCost::NONE)
    }
    /// Wire hook, called by the coordinator (shard 0) **after**
    /// `plan_round` and before the `publish_decision` crossing: ship
    /// the fold decision through the transport and overwrite `payload`
    /// with the decoded record — the gap/stop every pool acts on are
    /// then exactly the bytes that crossed. Default: no-op.
    fn wire_decision(&self, s: usize, payload: &mut DecisionPayload) -> Result<WireCost, LinkFault> {
        let _ = (s, payload);
        Ok(WireCost::NONE)
    }
    /// Order in which shard `s`'s fold sums the replica deltas at
    /// `round`. The identity (the default) reproduces the pre-seam
    /// arithmetic bit-exactly; a permutation models in-flight delta
    /// reordering (FP summation order — the only thing reordering *can*
    /// change in a BSP exchange, which is exactly what the simulator
    /// measures).
    fn fold_order(&self, s: usize, round: usize, shards: usize) -> Vec<usize> {
        let _ = (s, round);
        (0..shards).collect()
    }
    /// Precision tag carried by `WireFrameSent`/`WireFrameReceived`
    /// events ([`crate::event`]): `Some("exact")`/`Some("f32")` for
    /// transports that serialize frames, `None` (the default) for
    /// in-memory links — which then emit no wire events at all.
    fn wire_precision(&self) -> Option<&'static str> {
        None
    }
    /// Cumulative `(reconnects, attempts)` this link has performed for
    /// peer `s` — successful re-handshakes and redial attempts (module
    /// docs §Failure semantics, *Reconnect*). The coordinator diffs
    /// these per reconciled round to emit
    /// [`PeerReconnected`](crate::event::PeerReconnected) events.
    /// In-memory links and transports without reconnection keep the
    /// all-zero default.
    fn reconnect_stats(&self, s: usize) -> (u64, u64) {
        let _ = s;
        (0, 0)
    }
    /// Mark the link dead and unblock every current and future waiter
    /// (they fail with [`LinkFault::Poisoned`]). Called from the panic
    /// drop guard and by shards that observed a fault, so one dead pool
    /// never strands the rest.
    fn poison(&self);
}

/// The default [`ReconcileLink`]: the original 3-crossing SpinBarrier
/// protocol plus the init crossing, with a per-crossing timeout
/// (module docs §Failure semantics). Identity fold order — bit-exact
/// with the pre-seam engine, pinned by the differential tests.
pub struct BarrierLink {
    barrier: SpinBarrier,
    timeout: Duration,
}

impl BarrierLink {
    /// Link for `parties` shards with the given spin budget and
    /// per-crossing timeout (`None` = effectively forever).
    pub fn new(parties: usize, spin: u32, timeout: Option<Duration>) -> Self {
        Self {
            barrier: SpinBarrier::with_spin(parties, spin),
            timeout: timeout.unwrap_or(FOREVER),
        }
    }

    fn cross(&self) -> Result<(), LinkFault> {
        match self.barrier.wait_timeout(self.timeout) {
            WaitOutcome::Released(_) => Ok(()),
            WaitOutcome::Poisoned => Err(LinkFault::Poisoned),
            WaitOutcome::TimedOut => Err(LinkFault::TimedOut),
        }
    }
}

impl ReconcileLink for BarrierLink {
    fn init(&self, _s: usize) -> Result<(), LinkFault> {
        self.cross()
    }

    fn arrive(&self, _s: usize, _round: usize) -> Result<(), LinkFault> {
        self.cross()
    }

    fn publish_fold(&self, _s: usize, _round: usize) -> Result<(), LinkFault> {
        self.cross()
    }

    fn publish_decision(&self, _s: usize, _round: usize) -> Result<(), LinkFault> {
        self.cross()
    }

    fn poison(&self) {
        self.barrier.poison();
    }
}

/// Everything one shard's pool runs with: a sub-problem over the
/// shard's columns (built on a zero-copy
/// [`col_range_view`](crate::sparse::CscMatrix::col_range_view)), the
/// local→global column map, and the shard-local policy pair
/// (instantiated over the *local* column space, so all presets run
/// sharded unchanged — their union is the effective global selection).
pub struct ShardSpec {
    /// Sub-problem: the shard's columns against the full sample space.
    pub problem: Problem,
    /// `cols[local] = global` column id (ascending).
    pub cols: Vec<u32>,
    /// Shard-local selection policy.
    pub select: Box<dyn Select>,
    /// Shard-local accept policy.
    pub accept: Box<dyn Accept>,
    /// Update discipline for this shard's pool (COLORING shards run
    /// conflict-free: colorings only need to be valid *within* a shard,
    /// since cross-shard writes land on different replicas).
    pub update_path: UpdatePath,
    /// Worker threads for this shard's pool (the shard's leader is
    /// worker 0 of its pool; 0 is treated as 1). Per-spec so a total
    /// thread budget can be split unevenly — the builder hands the
    /// first `total % shards` pools one extra worker each.
    pub threads: usize,
}

/// Knobs of a sharded solve (the cross-shard analogue of
/// [`EngineConfig`]; per-pool knobs are derived from it — pool thread
/// counts live on each [`ShardSpec`]).
#[derive(Clone, Debug)]
pub struct ShardedConfig {
    pub line_search_steps: usize,
    /// Round cap (a round is one lockstep GenCD iteration per shard).
    pub max_rounds: usize,
    pub max_seconds: f64,
    /// Relative-improvement stop over the *global* objective log
    /// (0 disables; three consecutive hits, like the engine).
    pub tol: f64,
    /// Global-objective log cadence in rounds; 0 = time-based (~50 ms).
    /// Under an adaptive cadence a log point falling between reconciles
    /// fires at the next reconciled round.
    pub log_every: usize,
    /// Total buffered-update memory budget, divided across the shard
    /// pools so the whole sharded solve honors one figure.
    pub buffer_budget_mb: usize,
    pub barrier_spin: u32,
    /// Active-set KKT screening, **one active set per shard pool**:
    /// each pool wraps its own Select policy and runs its own full-set
    /// sweeps over its own columns ([`crate::screen`]). Sweeps judge
    /// the pool's replica (reconciled at reconcile boundaries); the
    /// coordinator gates its tolerance stop with a **global** KKT check
    /// on the reconciled iterate (a zero-weight coordinate with
    /// `|g| > lam` refuses the stop until the pools' sweeps repair it),
    /// so a sharded screened solve also only converges as
    /// [`StopReason::Converged`], certified.
    pub screening: bool,
    /// Per-pool full-set KKT sweep cadence in rounds.
    pub kkt_every: usize,
    /// Per-pool adaptive sweep cadence (see
    /// [`EngineConfig::kkt_adaptive`]).
    pub kkt_adaptive: bool,
    /// Unrolled gather kernels in every pool (see
    /// `EngineConfig::fast_kernels`).
    pub fast_kernels: bool,
    /// SIMD tier ceiling for the fast kernels in every pool (see
    /// `EngineConfig::kernel`; all pools run the same process, so they
    /// resolve the same tier).
    pub kernel: crate::kernel::KernelChoice,
    /// Pin each shard pool to a NUMA node and first-touch its replica
    /// there (module docs §NUMA). Graceful no-op on single-node or
    /// non-Linux hosts; default off.
    pub numa_pin: bool,
    /// Reconcile every R rounds (module docs §Reconcile cadence;
    /// min 1 — values of 0 are treated as 1). Default 1: the PR-3
    /// every-round schedule, bit-exact with it.
    pub reconcile_every: usize,
    /// Upper bound of the *adaptive* reconcile cadence; values at or
    /// below `reconcile_every` (including the default) disable
    /// adaptation and keep the fixed cadence.
    pub reconcile_max_rounds: usize,
    /// Fold only dirty chunks at reconcile (module docs §Dirty-chunk
    /// delta fold; byte-identical to the dense fold, default on).
    /// `false` keeps the PR-3 dense full-scan fold as the reference —
    /// the differential tests and the hotpath bench A/B use it.
    pub delta_reconcile: bool,
    /// Per-crossing reconcile barrier timeout in seconds (module docs
    /// §Failure semantics): a shard waiting longer than this for its
    /// peers concludes a pool died, poisons the link, and the solve
    /// terminates with [`StopReason::ShardFailed`] instead of hanging.
    /// `<= 0` disables the timeout (waits effectively forever — the
    /// pre-hardening behavior, minus the hang-on-death). Default 30 s:
    /// far above any healthy round, far below a stuck CI job.
    pub barrier_timeout_secs: f64,
    /// Bounded staleness (module docs §Failure semantics): with a value
    /// > 0, the adaptive cadence may never schedule a reconcile gap
    /// above this many rounds — the doubling is clamped and each
    /// clamped reconcile is counted in
    /// [`MetricsSnapshot::staleness_forced_reconciles`]. 0 (default)
    /// leaves the cadence bounded only by `reconcile_max_rounds`.
    pub max_staleness_rounds: usize,
    /// Checkpoint the reconciled iterate (module docs §Failure
    /// semantics, *Checkpoint / resume*): the shard-0 coordinator
    /// writes the CRC-guarded [`Checkpoint`] file on the spec's
    /// `every_rounds` cadence and at the stopping round, with atomic
    /// rename. `None` (default) disables checkpointing entirely.
    pub checkpoint: Option<CheckpointSpec>,
    /// Resume from a previously written checkpoint: replicas start from
    /// the checkpointed `w`/`z`, selection policies fast-forward by the
    /// completed round count, and the reconcile schedule re-aligns to
    /// the stored gap. The caller (the [`Solver`](crate::solver)
    /// builder) is responsible for validating the checkpoint against
    /// the problem before handing it over. `None` (default): fresh
    /// solve.
    pub resume: Option<ResumeState>,
}

impl Default for ShardedConfig {
    fn default() -> Self {
        let ecfg = EngineConfig::default();
        Self {
            line_search_steps: 0,
            max_rounds: usize::MAX,
            max_seconds: 10.0,
            tol: 0.0,
            log_every: 0,
            buffer_budget_mb: 1024,
            barrier_spin: DEFAULT_SPIN,
            screening: ecfg.screening,
            kkt_every: ecfg.kkt_every,
            kkt_adaptive: ecfg.kkt_adaptive,
            fast_kernels: ecfg.fast_kernels,
            kernel: ecfg.kernel,
            numa_pin: false,
            reconcile_every: 1,
            reconcile_max_rounds: 1,
            delta_reconcile: true,
            barrier_timeout_secs: 30.0,
            max_staleness_rounds: 0,
            checkpoint: None,
            resume: None,
        }
    }
}

/// Cross-shard shared state: the replica slots, the canonical residual,
/// the stop/cadence decisions, and per-shard padded metric slots
/// (unique writer per slot, read by the coordinator after a barrier).
/// The reconcile *transport* — the barrier itself — lives behind the
/// [`ReconcileLink`] seam, not here.
struct ReconcileShared {
    /// Replica slots, filled by each shard's *own* thread (after NUMA
    /// pinning, so zero-fill first-touches node-local pages) and
    /// published to every shard by the init barrier crossing.
    states: Vec<OnceLock<SharedState>>,
    /// Canonical reconciled residual (untouched in single-shard runs —
    /// there the replica itself is canonical).
    z_canon: SyncF64Vec,
    /// Written by the coordinator between the 2nd and 3rd crossings of
    /// a reconcile, read by every shard after the 3rd.
    stop: SyncCell<Option<StopReason>>,
    /// Rounds until the next reconcile, published with the stop
    /// decision (same writer, same crossings).
    next_gap: SyncCell<usize>,
    /// Per-shard cumulative update counts (published each reconcile for
    /// the coordinator's history records).
    updates: Vec<CachePadded<SyncCell<u64>>>,
    /// Per-shard running max of reconcile corrections ever applied.
    divergence: Vec<CachePadded<SyncCell<f64>>>,
    /// Per-shard max conflict correction of the *latest* reconcile —
    /// the adaptive cadence's input signal.
    round_div: Vec<CachePadded<SyncCell<f64>>>,
    /// Per-shard nanoseconds spent in the reconcile fold.
    reconcile_nanos: Vec<CachePadded<SyncCell<u64>>>,
    /// Per-shard dirty-chunk bitmaps (empty when the dense fold is
    /// forced or for single-shard runs). Written by shard s's pool
    /// workers during rounds, read by every shard's fold between
    /// crossings 1 and 2, cleared by the owner between 2 and 3.
    dirty: Vec<DirtyChunks>,
    /// Per-shard cumulative dirty chunks folded / chunks considered
    /// (the `dirty_chunk_frac` numerator and denominator).
    dirty_folded: Vec<CachePadded<SyncCell<u64>>>,
    chunks_seen: Vec<CachePadded<SyncCell<u64>>>,
    /// Per-shard rounds skipped between reconciles (equal across
    /// shards by construction; aggregated as the max).
    skipped: Vec<CachePadded<SyncCell<u64>>>,
    /// Per-shard link-fault slots (unique writer: the shard itself,
    /// just before it breaks out of its pool; read after the join).
    failures: Vec<CachePadded<SyncCell<Option<LinkFault>>>>,
    /// Per-shard wire accounting ([`ReconcileLink::wire_delta`] /
    /// [`wire_decision`](ReconcileLink::wire_decision) costs): bytes
    /// sent, bytes received, codec nanoseconds. All-zero on in-memory
    /// links.
    wire_tx: Vec<CachePadded<SyncCell<u64>>>,
    wire_rx: Vec<CachePadded<SyncCell<u64>>>,
    codec_nanos: Vec<CachePadded<SyncCell<u64>>>,
    /// Reconciles the staleness bound forced (written only by the
    /// shard-0 coordinator between crossings 2 and 3).
    staleness_forced: CachePadded<SyncCell<u64>>,
    n: usize,
}

impl ReconcileShared {
    /// Shard s's replica; only callable after the init barrier.
    #[inline]
    fn state(&self, s: usize) -> &SharedState {
        self.states[s].get().expect("replica published by init barrier")
    }
}

/// The canonical residual: the reconciled array, or the lone replica in
/// single-shard runs.
fn canonical_z(sh: &ReconcileShared) -> &SyncF64Vec {
    if sh.states.len() == 1 {
        &sh.state(0).z
    } else {
        &sh.z_canon
    }
}

/// Leader-side bookkeeping owned by shard 0: the global objective log,
/// every stopping decision, the adaptive reconcile cadence, and the
/// caller's observer.
struct Coordinator<'a, 'o> {
    global: &'a Problem,
    cols: &'a [Vec<u32>],
    /// `owned[j]`: some shard's column map covers global column j. The
    /// screening gate only judges owned columns — an uncovered column
    /// is structurally frozen at zero by the caller's partition (legal
    /// per [`solve_sharded`]'s contract), so no pool could ever repair
    /// a "violation" there and the unscreened solve would not move it
    /// either.
    owned: &'a [bool],
    timer: &'a Timer,
    cfg: &'a ShardedConfig,
    history: History,
    scratch_w: Vec<f64>,
    last_log_at: f64,
    /// Next round an iteration-cadence log is due at (rounds can skip
    /// under the adaptive cadence, so a modulo test would miss).
    next_log_round: usize,
    tol_hits: u32,
    /// Adaptive cadence state machine (module docs §Reconcile cadence).
    r_cur: usize,
    r_min: usize,
    r_max: usize,
    div_ewma: f64,
    /// Completed global rounds carried in from a resumed checkpoint
    /// (0 on fresh solves). Local round r of this process is global
    /// round `r + round_base` — the round every log record, event, stop
    /// check, and checkpoint speaks in.
    round_base: usize,
    /// Cumulative update count at the resume point (0 on fresh solves),
    /// added to the pools' published counts so resumed history lines up
    /// with the uninterrupted run's.
    updates_base: u64,
    /// Reconciled rounds planned by *this process* — the checkpoint
    /// cadence counter.
    reconciles_done: u64,
    /// Per-peer reconnect counters as of the previous reconciled round
    /// ([`ReconcileLink::reconnect_stats`]), diffed to emit each heal
    /// exactly once.
    last_reconnects: Vec<u64>,
    last_attempts: Vec<u64>,
    /// Caller-supplied observer, invoked at every reconciled round on
    /// the reconciled global iterate.
    observer: Option<&'o mut (dyn Observer + 'o)>,
    /// Lazily-built global-dims state backing the observer's
    /// [`IterationInfo::state`] (only allocated when an observer is
    /// attached).
    obs_state: Option<SharedState>,
    /// Caller-supplied event sink: [`IterationCompleted`] at the log
    /// cadence, [`ReconcileRound`] (plus wire-frame events when the
    /// link reports a wire precision) at every reconciled round.
    events: Option<&'o mut (dyn EventSink + 'o)>,
}

impl Coordinator<'_, '_> {
    /// Runs between the reconcile-publish and decision-publish barrier
    /// crossings: every replica equals the reconciled residual, every
    /// pool's workers are parked, every `w` is quiescent — so gathering
    /// the global iterate is plain reads. Returns the stop decision and
    /// the gap to the next reconcile round.
    fn plan_round(
        &mut self,
        sh: &ReconcileShared,
        round: usize,
    ) -> (Option<StopReason>, usize) {
        let elapsed = self.timer.elapsed_secs();
        // the global round this local round corresponds to — resumed
        // solves carry the completed rounds of the interrupted run in
        // round_base, so logs/events/stops/checkpoints line up with the
        // uninterrupted trajectory
        let ground = round + self.round_base;
        let mut stop = None;
        let should_log = match self.cfg.log_every {
            0 => elapsed - self.last_log_at >= 0.05 || round == 0,
            _ => ground >= self.next_log_round,
        };
        if should_log && self.cfg.log_every > 0 {
            self.next_log_round = ground + self.cfg.log_every;
        }
        // the observer contract needs the global iterate at every
        // reconciled round; the log only at its cadence. Checkpointing
        // gathers unconditionally so the stopping-round checkpoint
        // always has the iterate in hand.
        let gather =
            should_log || self.observer.is_some() || self.cfg.checkpoint.is_some();
        let mut z_snap: Option<Vec<f64>> = None;
        let mut updates = 0u64;
        if gather {
            for (cols, s) in self.cols.iter().zip(0..) {
                let st = sh.state(s);
                for (local, &g) in cols.iter().enumerate() {
                    self.scratch_w[g as usize] = st.w.get(local);
                }
            }
            z_snap = Some(canonical_z(sh).snapshot());
            updates = self.updates_base + sh.updates.iter().map(|u| u.get()).sum::<u64>();
        }
        let mut objective = None;
        let mut nnz_now = None;
        if should_log {
            let z = z_snap.as_deref().expect("gathered above");
            let obj = loss::objective(
                self.global.loss.as_ref(),
                &self.global.y,
                z,
                &self.scratch_w,
                self.global.lam,
            );
            objective = Some(obj);
            nnz_now = Some(loss::nnz(&self.scratch_w));
            // objective-increase tripwire (module docs §Failure
            // semantics): the objective rising between reconciled log
            // records means the decoupled rounds overshot — snap the
            // adaptive cadence to its floor before it compounds. The
            // relative margin ignores ulp-level reassociation noise.
            if let Some(prev) = self.history.last().map(|r| r.objective) {
                if obj > prev + prev.abs().max(1e-300) * 1e-12 {
                    self.r_cur = self.r_min;
                }
            }
            self.history.push(Record {
                elapsed_secs: elapsed,
                iter: ground,
                updates,
                objective: obj,
                nnz: nnz_now.unwrap(),
            });
            self.last_log_at = elapsed;
            if let Some(events) = self.events.as_deref_mut() {
                emit!(
                    events,
                    Meta {
                        timestamp_ticks: ground as u64,
                        shard: 0,
                        thread: 0,
                    },
                    IterationCompleted {
                        iter: ground as u64,
                        updates,
                        // per-pool selection sizes are not published
                        // cross-shard (same convention as the observer)
                        selected: 0,
                        objective,
                        nnz: nnz_now.map(|n| n as u64),
                    }
                );
            }
            if !obj.is_finite() || obj > 1e12 {
                stop = Some(StopReason::Diverged);
            }
            if stop.is_none() && self.cfg.tol > 0.0 {
                if self.history.last_rel_improvement().abs() < self.cfg.tol {
                    self.tol_hits += 1;
                } else {
                    self.tol_hits = 0;
                }
                if self.tol_hits >= 3 {
                    if self.cfg.screening {
                        // Cross-shard convergence gate: per-pool active
                        // sets are pool-internal, so certify the frozen
                        // coordinates directly on the *global* iterate —
                        // one O(nnz) full gradient at the reconciled
                        // residual, only on gate attempts. A zero-weight
                        // coordinate with |g| > lam is either screened
                        // out or simply unvisited; either way the solve
                        // is not done, so refuse the stop and let the
                        // pools' periodic sweeps reactivate it. A clean
                        // pass certifies the screened solution as the
                        // unscreened optimum's: report Converged.
                        let g = loss::full_gradient(
                            self.global.loss.as_ref(),
                            &self.global.x,
                            &self.global.y,
                            z,
                        );
                        // Margined test (screen::GATE_MARGIN): this
                        // gradient is computed with different summation
                        // order than the pools' dot_col gradients, so a
                        // strict |g| > lam test could flag an ulp-level
                        // "violation" the owning pool measures as
                        // satisfied and will never repair — refusing
                        // the stop forever.
                        let lam = self.global.lam;
                        let violated = self
                            .scratch_w
                            .iter()
                            .zip(&g)
                            .zip(self.owned)
                            .any(|((&wj, &gj), &owned)| {
                                // only shard-owned columns: an uncovered
                                // column is frozen by the partition, not
                                // by screening — no sweep can repair it
                                owned
                                    && wj == 0.0
                                    && crate::screen::violates_at_zero(gj, lam)
                            });
                        if violated {
                            self.tol_hits = 0;
                        } else {
                            stop = Some(StopReason::Converged);
                        }
                    } else {
                        stop = Some(StopReason::Tolerance);
                    }
                }
            }
        }
        // caller observer: every reconciled round, on the reconciled
        // iterate (workers parked — plain reads are the contract)
        if let Some(obs) = self.observer.as_deref_mut() {
            let st = self.obs_state.get_or_insert_with(|| {
                SharedState::new(self.global.n_samples(), self.global.n_features())
            });
            st.w.copy_from(&self.scratch_w);
            st.z.copy_from(z_snap.as_deref().expect("gathered above"));
            let info = IterationInfo {
                iter: ground,
                elapsed_secs: elapsed,
                updates,
                // per-pool selection sizes are not published
                // cross-shard; 0 by documented convention
                selected: 0,
                objective,
                nnz: nnz_now,
                state: st,
            };
            if obs.on_iteration(&info).is_break() && stop.is_none() {
                stop = Some(StopReason::Observer);
            }
        }
        if stop.is_none() {
            if ground >= self.cfg.max_rounds {
                stop = Some(StopReason::MaxIters);
            } else if elapsed >= self.cfg.max_seconds {
                stop = Some(StopReason::MaxSeconds);
            }
        }
        let gap = if stop.is_some() {
            1
        } else {
            self.next_reconcile_gap(sh, ground)
        };
        // checkpoint (module docs §Failure semantics): on the cadence
        // and at the stopping round, after the gap is known — the file
        // stores the *next* gap so a resume re-aligns the reconcile
        // schedule. A write failure is logged into the void (the solve
        // is healthier than the disk; keep going).
        if let Some(spec) = self.cfg.checkpoint.as_ref() {
            self.reconciles_done += 1;
            let due = spec.every_rounds > 0
                && self.reconciles_done % spec.every_rounds as u64 == 0;
            if due || stop.is_some() {
                let ckpt = Checkpoint {
                    // completed global rounds: this one counts
                    round: (ground + 1) as u64,
                    next_gap: gap as u64,
                    seed: spec.seed,
                    shards: self.cols.len() as u32,
                    lambda: self.global.lam,
                    updates,
                    r_cur: self.r_cur as u64,
                    div_ewma: self.div_ewma,
                    tol_hits: self.tol_hits,
                    last_objective: self.history.last().map(|r| r.objective),
                    w: self.scratch_w.clone(),
                    z: z_snap.clone().expect("checkpointing forces the gather"),
                };
                if let Ok(bytes) = ckpt.write_atomic(&spec.path) {
                    if let Some(events) = self.events.as_deref_mut() {
                        emit!(
                            events,
                            Meta {
                                timestamp_ticks: ground as u64,
                                shard: 0,
                                thread: 0,
                            },
                            CheckpointWritten {
                                round: (ground + 1) as u64,
                                bytes,
                            }
                        );
                    }
                }
            }
        }
        if let Some(events) = self.events.as_deref_mut() {
            let folded: u64 = sh.dirty_folded.iter().map(|c| c.get()).sum();
            let seen: u64 = sh.chunks_seen.iter().map(|c| c.get()).sum();
            emit!(
                events,
                Meta {
                    timestamp_ticks: ground as u64,
                    shard: 0,
                    thread: 0,
                },
                ReconcileRound {
                    round: ground as u64,
                    // cumulative, same ratio MetricsSnapshot reports;
                    // 1.0 = dense fold (no dirty maps)
                    dirty_frac: if seen > 0 {
                        folded as f64 / seen as f64
                    } else {
                        1.0
                    },
                    divergence: sh.round_div.iter().map(|c| c.get()).fold(0.0, f64::max),
                    gap: gap as u64,
                }
            );
        }
        (stop, gap)
    }

    /// The adaptive cadence state machine (module docs §Reconcile
    /// cadence): double on conflict-free reconciles, snap back on a
    /// spike, clamp so stops can only land on reconciled rounds.
    fn next_reconcile_gap(&mut self, sh: &ReconcileShared, round: usize) -> usize {
        if self.r_max > self.r_min {
            let div = sh.round_div.iter().map(|c| c.get()).fold(0.0, f64::max);
            if div <= 0.0 {
                self.r_cur = self.r_cur.saturating_mul(2).clamp(self.r_min, self.r_max);
            } else {
                if self.div_ewma == 0.0 || div > CONFLICT_SPIKE * self.div_ewma {
                    // first conflict ever, or a spike over the trend:
                    // resynchronize every round until it calms down
                    self.r_cur = self.r_min;
                }
                self.div_ewma = if self.div_ewma == 0.0 {
                    div
                } else {
                    0.75 * self.div_ewma + 0.25 * div
                };
            }
        }
        let mut gap = self.r_cur.max(1);
        // bounded staleness (module docs §Failure semantics): the
        // cadence may never schedule a gap above the budget — replica
        // age stays provably bounded no matter what the doubling wants
        let max_stale = self.cfg.max_staleness_rounds;
        if max_stale > 0 && gap > max_stale {
            gap = max_stale;
            let sf = &sh.staleness_forced;
            sf.set(sf.get() + 1);
        }
        // stops only happen at reconciled rounds: never skip past the
        // round cap (time stops may overshoot by < gap rounds, bounded
        // by r_max — documented)
        if self.cfg.max_rounds == usize::MAX {
            gap
        } else {
            gap.min(self.cfg.max_rounds.saturating_sub(round).max(1))
        }
    }
}

/// The per-shard observer: runs on each pool's leader at every round
/// boundary; at reconcile rounds it drives the three-crossing protocol
/// (arrive → fold chunks → publish → decide → publish → read decision)
/// over the [`ReconcileLink`], at skipped rounds it returns immediately
/// without touching the link.
struct ShardObserver<'a, 'o> {
    s: usize,
    shared: &'a ReconcileShared,
    /// The cross-shard transport (module docs §The reconcile link).
    link: &'a dyn ReconcileLink,
    /// Replica refs hoisted once after the init barrier, so the fold's
    /// inner loop never pays the `OnceLock` re-check.
    replicas: Vec<&'a SharedState>,
    coordinator: Option<Coordinator<'a, 'o>>,
    /// First round at (or after) which the next reconcile runs.
    next_reconcile_at: usize,
}

impl ShardObserver<'_, '_> {
    /// Fold every replica's delta into the canonical residual over this
    /// shard's cache-aligned sample chunk, then refresh all replicas —
    /// disjoint chunks across shards, one writer per element, the
    /// buffered-reduce discipline of `util::par`. With dirty maps, only
    /// chunks some shard touched since the last reconcile are visited.
    /// The delta sum walks replicas in the link's fold order (identity
    /// on [`BarrierLink`] — bit-exact with the pre-seam fold).
    fn reconcile(&mut self, round: usize) {
        let sh = self.shared;
        let shards = self.replicas.len();
        if shards == 1 {
            // the replica is canonical; rewriting it (even with an
            // a + (b - a) identity) would perturb bit-exactness
            return;
        }
        let t0 = std::time::Instant::now();
        let order = self.link.fold_order(self.s, round, shards);
        debug_assert_eq!(
            {
                let mut o = order.clone();
                o.sort_unstable();
                o
            },
            (0..shards).collect::<Vec<_>>(),
            "fold order must be a permutation of the shards"
        );
        let mut round_div = 0.0f64;
        let range = aligned_chunk(sh.n, self.s, shards);
        if sh.dirty.is_empty() {
            // dense reference fold: every element of my chunk
            self.fold_elems(range.start, range.end, &order, &mut round_div);
        } else {
            // delta fold: aligned_chunk boundaries are multiples of
            // DIRTY_CHUNK_ELEMS, so chunk ownership never straddles
            // shards; visit only chunks dirty in some shard
            let c_lo = range.start / DIRTY_CHUNK_ELEMS;
            let c_hi = range.end.div_ceil(DIRTY_CHUNK_ELEMS);
            let mut folded = 0u64;
            for c in c_lo..c_hi {
                if !sh.dirty.iter().any(|d| d.is_dirty(c)) {
                    continue;
                }
                folded += 1;
                let lo = c * DIRTY_CHUNK_ELEMS;
                let hi = ((c + 1) * DIRTY_CHUNK_ELEMS).min(range.end);
                self.fold_elems(lo, hi, &order, &mut round_div);
            }
            let df = &sh.dirty_folded[self.s];
            df.set(df.get() + folded);
            let cs = &sh.chunks_seen[self.s];
            cs.set(cs.get() + (c_hi - c_lo) as u64);
        }
        sh.round_div[self.s].set(round_div);
        if round_div > sh.divergence[self.s].get() {
            sh.divergence[self.s].set(round_div);
        }
        let prev = sh.reconcile_nanos[self.s].get();
        sh.reconcile_nanos[self.s].set(prev + t0.elapsed().as_nanos() as u64);
    }

    /// The per-element fold over `lo..hi` (shared by the dense and
    /// delta paths, so they are the same arithmetic by construction).
    /// `order` is the link's replica walk order for the delta sum; the
    /// refresh loop below it is order-insensitive (every replica gets
    /// the same `acc`) and stays in natural order.
    #[inline]
    fn fold_elems(&self, lo: usize, hi: usize, order: &[usize], round_div: &mut f64) {
        let sh = self.shared;
        for i in lo..hi {
            let base = sh.z_canon.get(i);
            let mut acc = base;
            for &r in order {
                let d = self.replicas[r].z.get(i) - base;
                if d != 0.0 {
                    acc += d;
                }
            }
            for st in &self.replicas {
                let cur = st.z.get(i);
                if cur != acc {
                    // a replica that updated i itself (cur != base) and
                    // still needs a correction saw a *conflicting*
                    // cross-shard write — the divergence the
                    // partitioner exists to minimize. Replicas merely
                    // *learning* another shard's update (cur == base)
                    // are the mechanism working as designed.
                    if cur != base {
                        let corr = (acc - cur).abs();
                        if corr > *round_div {
                            *round_div = corr;
                        }
                    }
                    st.z.set(i, acc);
                }
            }
            if acc != base {
                sh.z_canon.set(i, acc);
            }
        }
    }
}

impl ShardObserver<'_, '_> {
    /// Sum one wire hook's accounting into this shard's padded slots.
    fn note_wire(&self, cost: WireCost) {
        let sh = self.shared;
        let tx = &sh.wire_tx[self.s];
        tx.set(tx.get() + cost.bytes_tx);
        let rx = &sh.wire_rx[self.s];
        rx.set(rx.get() + cost.bytes_rx);
        let cn = &sh.codec_nanos[self.s];
        cn.set(cn.get() + cost.nanos);
    }

    /// One reconcile round over the link; `Err` means a crossing failed
    /// (peer dead or timed out) and the pool must stop.
    fn reconcile_round(&mut self, info: &IterationInfo<'_>) -> Result<ControlFlow<()>, LinkFault> {
        let sh = self.shared;
        // own padded slot; published to the coordinator by the crossing
        // chain below
        sh.updates[self.s].set(info.updates);
        // wire hook: ship my dirty chunks through the transport and
        // keep only what survived the codec (§Wire format). Runs
        // *before* crossing 1 so every peer's fold reads post-wire
        // values; my own workers are parked, so the writes are safe.
        let cost = self.link.wire_delta(
            self.s,
            &DeltaPayload {
                round: info.iter,
                dirty: (!sh.dirty.is_empty()).then(|| &sh.dirty[self.s]),
                z: &self.replicas[self.s].z,
                n: sh.n,
            },
        )?;
        self.note_wire(cost);
        // wire-frame events: only when the link actually crosses a wire
        // (wire_precision() is Some) — in-memory links stay silent, so
        // loopback and barrier streams are byte-identical
        if let Some(prec) = self.link.wire_precision() {
            if let Some(events) = self
                .coordinator
                .as_mut()
                .and_then(|c| c.events.as_deref_mut())
            {
                let meta = Meta {
                    timestamp_ticks: info.iter as u64,
                    shard: self.s as u32,
                    thread: 0,
                };
                emit!(
                    events,
                    meta,
                    WireFrameSent {
                        bytes: cost.bytes_tx,
                        precision: prec,
                    }
                );
                emit!(
                    events,
                    meta,
                    WireFrameReceived {
                        bytes: cost.bytes_rx,
                        precision: prec,
                    }
                );
            }
        }
        // crossing 1: every shard finished the round; all replica
        // updates are visible (each pool's end-of-update barrier chains
        // into this one)
        self.link.arrive(self.s, info.iter)?;
        self.reconcile(info.iter);
        // crossing 2: the reconciled residual is published everywhere
        self.link.publish_fold(self.s, info.iter)?;
        // clear my dirty map while every pool's writers are parked (the
        // other shards' folds finished at crossing 2; scatters resume
        // only after crossing 3)
        if !sh.dirty.is_empty() {
            sh.dirty[self.s].clear();
        }
        if let Some(c) = self.coordinator.as_mut() {
            let (stop, gap) = c.plan_round(sh, info.iter);
            // reconnect accounting: diff the link's cumulative per-peer
            // counters so each heal is emitted exactly once, at the
            // first reconciled round after it happened
            for s in 0..self.replicas.len() {
                let (reconnects, attempts) = self.link.reconnect_stats(s);
                let new_reconnects = reconnects.saturating_sub(c.last_reconnects[s]);
                let new_attempts = attempts.saturating_sub(c.last_attempts[s]);
                if new_reconnects > 0 {
                    if let Some(events) = c.events.as_deref_mut() {
                        emit!(
                            events,
                            Meta {
                                timestamp_ticks: (info.iter + c.round_base) as u64,
                                shard: s as u32,
                                thread: 0,
                            },
                            PeerReconnected {
                                attempts: new_attempts,
                            }
                        );
                    }
                }
                c.last_reconnects[s] = reconnects;
                c.last_attempts[s] = attempts;
            }
            // wire hook: route the decision through the transport — the
            // gap/stop every pool acts on are the decoded bytes
            let mut decision = DecisionPayload {
                round: info.iter,
                next_gap: gap,
                stop,
            };
            let cost = self.link.wire_decision(self.s, &mut decision)?;
            self.note_wire(cost);
            sh.next_gap.set(decision.next_gap);
            sh.stop.set(decision.stop);
        }
        // crossing 3: the stop decision and the next gap are published
        self.link.publish_decision(self.s, info.iter)?;
        self.next_reconcile_at = info.iter.saturating_add(sh.next_gap.get());
        Ok(if sh.stop.get().is_some() {
            ControlFlow::Break(())
        } else {
            ControlFlow::Continue(())
        })
    }
}

impl Observer for ShardObserver<'_, '_> {
    fn on_iteration(&mut self, info: &IterationInfo<'_>) -> ControlFlow<()> {
        let sh = self.shared;
        if info.iter < self.next_reconcile_at {
            // skipped round: no barrier, no fold — the pools run
            // decoupled until the next reconcile round they all agreed
            // on at the previous one
            let sk = &sh.skipped[self.s];
            sk.set(sk.get() + 1);
            return ControlFlow::Continue(());
        }
        match self.reconcile_round(info) {
            Ok(flow) => flow,
            Err(fault) => {
                // degrade, never hang (module docs §Failure semantics):
                // record the fault, make sure every peer escapes too,
                // and stop this pool gracefully at the round boundary
                sh.failures[self.s].set(Some(fault));
                self.link.poison();
                ControlFlow::Break(())
            }
        }
    }
}

/// Poisons the reconcile link if a shard pool unwinds, so the other
/// pools fail out of their crossings with [`LinkFault::Poisoned`]
/// instead of deadlocking on a shard that will never arrive (the
/// cross-shard analogue of the engine's internal poison guard).
struct PoisonReconcileOnPanic<'a>(&'a dyn ReconcileLink);

impl Drop for PoisonReconcileOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.poison();
        }
    }
}

/// Run a sharded GenCD solve: one engine pool per [`ShardSpec`], each
/// with that spec's worker count, reconciled per the configured cadence.
/// Equivalent to [`solve_sharded_with`] without an observer.
pub fn solve_sharded(
    global: &Problem,
    specs: Vec<ShardSpec>,
    warm_start: Option<&[f64]>,
    cfg: &ShardedConfig,
) -> SolveOutput {
    solve_sharded_with(global, specs, warm_start, cfg, None, None)
}

/// [`solve_sharded`] with a caller observer: invoked on the shard-0
/// coordinator at **every reconciled round**, against the reconciled
/// global iterate (`IterationInfo::state` holds a coordinator-owned
/// global-dims snapshot; `selected` is 0 — per-pool selection sizes are
/// not aggregated). `ControlFlow::Break` stops every pool at that round
/// with [`StopReason::Observer`]. Under an adaptive cadence the
/// observer consequently fires only at reconciled rounds — the rounds
/// at which a consistent global iterate exists at all.
///
/// `global` supplies the objective's loss/labels/lambda and the full
/// design matrix (used once for the warm-start residual); the per-shard
/// math runs entirely on the specs' sub-problems. The output is shaped
/// exactly like an unsharded [`SolveOutput`]: global `w`, global
/// objective and history, aggregated metrics (plus the shard fields of
/// [`MetricsSnapshot`]).
///
/// # Panics
///
/// If `specs` is empty, a spec's dimensions disagree with `global`, a
/// column map holds an out-of-range or *duplicated* global column (two
/// shards owning one column would silently double-count its residual
/// contribution at every reconcile), screening is enabled with
/// `kkt_every == 0` (pools never gate, so no sweep would ever repair a
/// deactivation), or a warm start has the wrong length — programming
/// errors, all caught before any threads spawn.
/// The maps need not cover every column: uncovered columns simply stay
/// at zero (the builder always produces an exact cover).
pub fn solve_sharded_with(
    global: &Problem,
    specs: Vec<ShardSpec>,
    warm_start: Option<&[f64]>,
    cfg: &ShardedConfig,
    observer: Option<&mut dyn Observer>,
    events: Option<&mut dyn EventSink>,
) -> SolveOutput {
    let timeout = (cfg.barrier_timeout_secs > 0.0)
        .then(|| Duration::from_secs_f64(cfg.barrier_timeout_secs));
    let link = BarrierLink::new(specs.len().max(1), cfg.barrier_spin, timeout);
    solve_sharded_linked(global, specs, warm_start, cfg, observer, events, &link)
}

/// [`solve_sharded_with`] over an explicit [`ReconcileLink`] — the seam
/// the simulator ([`crate::sim`]) and any future distributed backend
/// plug into. The link's party count must equal `specs.len()`.
pub fn solve_sharded_linked(
    global: &Problem,
    specs: Vec<ShardSpec>,
    warm_start: Option<&[f64]>,
    cfg: &ShardedConfig,
    mut observer: Option<&mut dyn Observer>,
    mut events: Option<&mut dyn EventSink>,
    link: &dyn ReconcileLink,
) -> SolveOutput {
    let s_count = specs.len();
    assert!(s_count >= 1, "solve_sharded: need at least one shard");
    // The engine tolerates kkt_every = 0 as an ablation (the gate sweep
    // still reactivates), but sharded pools run with tol = 0 and never
    // gate — periodic sweeps are their ONLY reactivation path, so
    // screening without them would freeze fused deactivations forever.
    assert!(
        !cfg.screening || cfg.kkt_every >= 1,
        "solve_sharded: screening requires kkt_every >= 1 (pool engines \
         never run gate sweeps; the periodic cadence is the only \
         reactivation path)"
    );
    let r_min = cfg.reconcile_every.max(1);
    let r_max = cfg.reconcile_max_rounds.max(r_min);
    let n = global.n_samples();
    let k = global.n_features();

    // resume bookkeeping (module docs §Failure semantics, *Checkpoint /
    // resume*): validate the restored iterate against the problem, and
    // short-circuit a checkpoint that already satisfies the round
    // budget — a job killed at its final checkpoint must not run extra
    // rounds on restart
    if let Some(res) = cfg.resume.as_ref() {
        assert_eq!(
            res.w.len(),
            k,
            "resume checkpoint has {} weights for a {k}-feature problem",
            res.w.len()
        );
        assert_eq!(
            res.z.len(),
            n,
            "resume checkpoint has {} residuals for {n} samples",
            res.z.len()
        );
        if let Some(sink) = events.as_deref_mut() {
            emit!(
                sink,
                Meta {
                    timestamp_ticks: res.round as u64,
                    shard: 0,
                    thread: 0,
                },
                ResumeLoaded {
                    round: res.round as u64,
                    n: k as u64,
                }
            );
        }
        if res.round >= cfg.max_rounds {
            let objective = global.objective(&res.w, &res.z);
            return SolveOutput {
                nnz: loss::nnz(&res.w),
                w: res.w.clone(),
                objective,
                history: History::default(),
                metrics: MetricsSnapshot {
                    iterations: res.round as u64,
                    shards: s_count as u64,
                    ..Default::default()
                },
                stop: StopReason::MaxIters,
                elapsed_secs: 0.0,
                failure: None,
            };
        }
    }

    // split the specs: column maps stay with the coordinator, the
    // (problem, policies) move into the shard threads
    let mut owned = vec![false; k];
    let mut cols_all = Vec::with_capacity(s_count);
    let mut runs = Vec::with_capacity(s_count);
    for mut spec in specs {
        assert_eq!(
            spec.problem.n_features(),
            spec.cols.len(),
            "shard sub-problem columns != column map"
        );
        assert_eq!(spec.problem.n_samples(), n, "shard sample space mismatch");
        for &g in &spec.cols {
            let g = g as usize;
            assert!(g < k, "shard column map holds column {g}, problem has {k}");
            assert!(
                !owned[g],
                "column {g} appears in two shards' column maps — every column \
                 must have exactly one owning shard"
            );
            owned[g] = true;
        }
        // resume: fast-forward the selection stream. Policies are
        // feedback-free call streams (state is a pure function of the
        // call count, one call per pool-leader round), so replaying the
        // completed rounds reproduces the interrupted run's remaining
        // stream exactly.
        if let Some(res) = cfg.resume.as_ref() {
            let mut scratch = Vec::new();
            for _ in 0..res.round {
                spec.select.select(&mut scratch);
            }
        }
        cols_all.push(spec.cols);
        runs.push((
            spec.problem,
            spec.select,
            spec.accept,
            spec.update_path,
            spec.threads.max(1),
        ));
    }

    // warm-start residual, computed once; each shard copies it into its
    // own replica on its own (pinned) thread. Resumed solves restore
    // the reconciled residual verbatim — recomputing matvec(w) would
    // bitwise-diverge from the folded z the checkpoint captured.
    let warm_w: Option<&[f64]> = cfg.resume.as_ref().map(|r| r.w.as_slice()).or(warm_start);
    let z0: Option<Vec<f64>> = match cfg.resume.as_ref() {
        Some(res) => Some(res.z.clone()),
        None => warm_start.map(|w0| {
            assert_eq!(w0.len(), k, "warm start has {} weights for {k}", w0.len());
            global.x.matvec(w0)
        }),
    };

    // NUMA plan: shard s -> topology node index (s mod nodes), skipped
    // entirely when pinning is off or the host has one node (no-op)
    let topo = cfg.numa_pin.then(Topology::detect);
    let pin_idx: Vec<Option<usize>> = (0..s_count)
        .map(|s| {
            topo.as_ref()
                .and_then(|t| (t.n_nodes() >= 2).then_some(s % t.n_nodes()))
        })
        .collect();
    let pinned_ok: Vec<CachePadded<SyncCell<bool>>> = (0..s_count)
        .map(|_| CachePadded::new(SyncCell::new(false)))
        .collect();

    let pad_slots_u64 = || -> Vec<CachePadded<SyncCell<u64>>> {
        (0..s_count)
            .map(|_| CachePadded::new(SyncCell::new(0u64)))
            .collect()
    };
    let shared = ReconcileShared {
        states: (0..s_count).map(|_| OnceLock::new()).collect(),
        z_canon: SyncF64Vec::zeros(n),
        stop: SyncCell::new(None),
        next_gap: SyncCell::new(1),
        updates: pad_slots_u64(),
        divergence: (0..s_count)
            .map(|_| CachePadded::new(SyncCell::new(0.0f64)))
            .collect(),
        round_div: (0..s_count)
            .map(|_| CachePadded::new(SyncCell::new(0.0f64)))
            .collect(),
        reconcile_nanos: pad_slots_u64(),
        dirty: if s_count > 1 && cfg.delta_reconcile {
            (0..s_count).map(|_| DirtyChunks::new(n)).collect()
        } else {
            Vec::new()
        },
        dirty_folded: pad_slots_u64(),
        chunks_seen: pad_slots_u64(),
        skipped: pad_slots_u64(),
        failures: (0..s_count)
            .map(|_| CachePadded::new(SyncCell::new(None)))
            .collect(),
        wire_tx: pad_slots_u64(),
        wire_rx: pad_slots_u64(),
        codec_nanos: pad_slots_u64(),
        staleness_forced: CachePadded::new(SyncCell::new(0u64)),
        n,
    };
    if let Some(z0) = &z0 {
        shared.z_canon.copy_from(z0);
    }
    let timer = Timer::start();

    // Per-pool engine config: pools never stop on their own — every
    // stop (rounds, time, tolerance, divergence) is decided by the
    // coordinator and delivered through the observer, keeping all pools
    // on the same reconcile schedule (lockstep at reconciled rounds;
    // see module docs). log_every = MAX confines each pool's private
    // objective log to round 0.
    let engine_cfg = |update_path: UpdatePath, threads: usize| EngineConfig {
        threads,
        line_search_steps: cfg.line_search_steps,
        max_iters: usize::MAX,
        max_seconds: f64::INFINITY,
        tol: 0.0,
        log_every: usize::MAX,
        force_dloss: None,
        update_path,
        buffer_budget_mb: cfg.buffer_budget_mb / s_count,
        barrier_spin: cfg.barrier_spin,
        screening: cfg.screening,
        kkt_every: cfg.kkt_every,
        kkt_adaptive: cfg.kkt_adaptive,
        fast_kernels: cfg.fast_kernels,
        kernel: cfg.kernel,
    };

    let mut outs: Vec<SolveOutput> = Vec::with_capacity(s_count);
    let mut coord_history: Option<History> = None;
    let mut failures: Vec<SolveError> = Vec::new();
    // reborrow so the sink comes back after the scope for the post-join
    // ShardFailed/phase emission (the coordinator thread only holds it
    // for the solve)
    let mut coord_events = events.as_deref_mut();
    std::thread::scope(|scope| {
        let shared = &shared;
        let cols_all = &cols_all;
        let owned = &owned;
        let topo = &topo;
        let pin_idx = &pin_idx;
        let pinned_ok = &pinned_ok;
        let timer = &timer;
        let z0 = z0.as_deref();
        let mut handles = Vec::with_capacity(s_count);
        for (s, (problem, select, accept, update_path, threads)) in
            runs.into_iter().enumerate()
        {
            let ecfg = engine_cfg(update_path, threads);
            let coordinator_obs = (s == 0).then(|| observer.take()).flatten();
            let coordinator_events = (s == 0).then(|| coord_events.take()).flatten();
            handles.push(scope.spawn(move || {
                let _guard = PoisonReconcileOnPanic(link);
                // §NUMA step 2: pin *before* any allocation, so the
                // replica below and everything solve_from allocates
                // (buffered-reduce accumulators, spill maps, pool
                // worker stacks) first-touches node-local memory
                if let Some(idx) = pin_idx[s] {
                    pinned_ok[s]
                        .set(topo.as_ref().is_some_and(|t| t.pin_thread_to_node(idx)));
                }
                // §NUMA step 3: first-touch replica construction on the
                // pinned thread (zero-fill is the first write)
                let cols = &cols_all[s];
                let st = SharedState::new(n, cols.len());
                if let Some(z0) = z0 {
                    let w0 = warm_w.expect("z0 implies warm start");
                    for (local, &g) in cols.iter().enumerate() {
                        st.w.set(local, w0[g as usize]);
                    }
                    st.z.copy_from(z0);
                }
                if shared.states[s].set(st).is_err() {
                    unreachable!("replica slot {s} filled twice");
                }
                // init crossing: every replica published before round 0
                if let Err(fault) = link.init(s) {
                    // a peer died before round 0: record, make sure the
                    // rest escape, run nothing
                    shared.failures[s].set(Some(fault));
                    link.poison();
                    return (None, None);
                }
                let replicas: Vec<&SharedState> =
                    (0..s_count).map(|i| shared.state(i)).collect();
                let coordinator = (s == 0).then(|| {
                    let res = cfg.resume.as_ref();
                    let mut history = History::default();
                    if let Some(res) = res {
                        if let Some(obj) = res.last_objective {
                            // seed the tripwire / tolerance baselines
                            // with the interrupted run's last log record
                            history.push(Record {
                                elapsed_secs: 0.0,
                                iter: res.round.saturating_sub(1),
                                updates: res.updates,
                                objective: obj,
                                nnz: loss::nnz(&res.w),
                            });
                        }
                    }
                    Coordinator {
                        global,
                        cols: cols_all,
                        owned,
                        timer,
                        cfg,
                        history,
                        scratch_w: vec![0.0; k],
                        last_log_at: -1.0,
                        next_log_round: 0,
                        tol_hits: res.map_or(0, |r| r.tol_hits),
                        r_cur: res.map_or(r_min, |r| r.r_cur.clamp(r_min, r_max)),
                        r_min,
                        r_max,
                        div_ewma: res.map_or(0.0, |r| r.div_ewma),
                        round_base: res.map_or(0, |r| r.round),
                        updates_base: res.map_or(0, |r| r.updates),
                        reconciles_done: 0,
                        last_reconnects: vec![0; s_count],
                        last_attempts: vec![0; s_count],
                        observer: coordinator_obs,
                        obs_state: None,
                        events: coordinator_events,
                    }
                });
                let mut obs = ShardObserver {
                    s,
                    shared,
                    link,
                    replicas,
                    coordinator,
                    // resumed solves re-align to the checkpoint's stored
                    // gap: the next reconcile falls next_gap rounds after
                    // the checkpointed one, i.e. local round next_gap - 1
                    next_reconcile_at: cfg
                        .resume
                        .as_ref()
                        .map_or(0, |r| r.next_gap.saturating_sub(1)),
                };
                let st = shared.state(s);
                let out = engine::solve_from(
                    &problem,
                    st,
                    select,
                    accept,
                    &ecfg,
                    EngineHooks {
                        observer: Some(&mut obs),
                        block_proposer: None,
                        dirty: shared.dirty.get(s),
                        // pool engines stay silent: sharded emission is
                        // coordinator-only, so the stream has one writer
                        events: None,
                    },
                );
                (Some(out), obs.coordinator.map(|c| c.history))
            }));
        }
        // the join IS the catch_unwind: scoped-thread panics surface
        // here as Err payloads, not re-raised — turn them into
        // structured SolveErrors instead of aborting the caller
        for (s, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok((out, hist)) => {
                    if let Some(hist) = hist {
                        coord_history = Some(hist);
                    }
                    if let Some(out) = out {
                        outs.push(out);
                    }
                }
                Err(payload) => {
                    let message = payload
                        .downcast_ref::<&str>()
                        .map(|m| (*m).to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "shard pool panicked".to_string());
                    failures.push(SolveError {
                        shard: Some(s),
                        kind: crate::coordinator::convergence::SolveErrorKind::Panic,
                        message: format!("pool panicked: {message}"),
                    });
                }
            }
        }
    });
    // link faults recorded by shards that stopped gracefully (timeouts,
    // poisoned peers). A pool that both panicked and poisoned shows up
    // once, via its join error above.
    for (s, slot) in shared.failures.iter().enumerate() {
        if let Some(fault) = slot.get() {
            failures.push(SolveError {
                shard: Some(s),
                kind: fault.kind(),
                message: fault.message().to_string(),
            });
        }
    }

    // global iterate: shard-owned w entries mapped back through the
    // column maps; the reconciled residual is already global. A pool
    // that died before publishing its replica leaves its columns at
    // zero (the best-effort iterate of §Failure semantics).
    let mut w = vec![0.0; k];
    for (s, cols) in cols_all.iter().enumerate() {
        let Some(st) = shared.states[s].get() else {
            continue;
        };
        for (local, &g) in cols.iter().enumerate() {
            w[g as usize] = st.w.get(local);
        }
    }
    let z = if shared.states.iter().all(|s| s.get().is_some()) {
        canonical_z(&shared).snapshot()
    } else {
        // some replica never existed: recompute the residual that
        // matches the partial w instead of reading half-built state
        global.x.matvec(&w)
    };
    let objective = global.objective(&w, &z);

    // numa_nodes: distinct nodes actually pinned; 1 = requested but
    // degraded (single node / non-Linux / refused), 0 = off
    let numa_nodes = if cfg.numa_pin {
        let mut nodes: Vec<usize> = (0..s_count)
            .filter(|&s| pinned_ok[s].get())
            .filter_map(|s| pin_idx[s])
            .collect();
        nodes.sort_unstable();
        nodes.dedup();
        (nodes.len() as u64).max(1)
    } else {
        0
    };
    let dirty_folded: u64 = shared.dirty_folded.iter().map(|c| c.get()).sum();
    let chunks_seen: u64 = shared.chunks_seen.iter().map(|c| c.get()).sum();

    // aggregate metrics: counts sum across pools, phase seconds are
    // summed leader CPU time, reconcile is the slowest leader's
    // wall-clock share, iterations = completed rounds (identical on
    // every pool by lockstep)
    let mut agg = MetricsSnapshot {
        // resumed solves report global rounds: the pools' local count
        // plus the rounds the interrupted run already completed
        iterations: outs.first().map(|o| o.metrics.iterations).unwrap_or(0)
            + cfg.resume.as_ref().map_or(0, |r| r.round as u64),
        shards: s_count as u64,
        reconcile_secs: shared
            .reconcile_nanos
            .iter()
            .map(|c| c.get())
            .max()
            .unwrap_or(0) as f64
            * 1e-9,
        replica_divergence: shared
            .divergence
            .iter()
            .map(|c| c.get())
            .fold(0.0, f64::max),
        numa_nodes,
        dirty_chunk_frac: if chunks_seen > 0 {
            dirty_folded as f64 / chunks_seen as f64
        } else {
            0.0
        },
        reconcile_rounds_skipped: shared
            .skipped
            .iter()
            .map(|c| c.get())
            .max()
            .unwrap_or(0),
        staleness_forced_reconciles: shared.staleness_forced.get(),
        shard_failures: failures.len() as u64,
        wire_bytes_tx: shared.wire_tx.iter().map(|c| c.get()).sum(),
        wire_bytes_rx: shared.wire_rx.iter().map(|c| c.get()).sum(),
        // codec time is concurrent across pools; report the slowest
        // leader's share (same convention as reconcile_secs)
        codec_secs: shared
            .codec_nanos
            .iter()
            .map(|c| c.get())
            .max()
            .unwrap_or(0) as f64
            * 1e-9,
        ..Default::default()
    };
    for o in &outs {
        // per-pool counts and phase seconds fold with the one canonical
        // merge rule (event::metrics) — no second hand-maintained copy
        MetricsAggregator::absorb(&mut agg, &o.metrics);
    }

    // post-join event tail: structured failures, then the canonical
    // phase table — the same end-of-solve rows the single-process
    // engine emits, projected from the aggregated snapshot
    if let Some(mut sink) = events.as_deref_mut() {
        let meta = Meta {
            timestamp_ticks: agg.iterations,
            shard: 0,
            thread: 0,
        };
        for f in &failures {
            let fmeta = Meta {
                shard: f.shard.unwrap_or(0) as u32,
                ..meta
            };
            emit!(sink, fmeta, ShardFailed { kind: f.kind.name() });
            if f.kind == crate::coordinator::convergence::SolveErrorKind::Protocol {
                emit!(sink, fmeta, CodecError { kind: "protocol" });
            }
        }
        event::phases::emit_rows(&mut sink, meta, &agg);
    }

    let stop = if failures.is_empty() {
        shared.stop.get().unwrap_or(StopReason::MaxIters)
    } else {
        StopReason::ShardFailed
    };
    SolveOutput {
        nnz: loss::nnz(&w),
        w,
        objective,
        history: coord_history.unwrap_or_default(),
        metrics: agg,
        stop,
        elapsed_secs: timer.elapsed_secs(),
        failure: failures.into_iter().next(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::accept;
    use crate::coordinator::select::Cyclic;
    use crate::loss::Squared;
    use crate::shard::partition::{partition, ShardStrategy};
    use crate::sparse::io::Dataset;
    use crate::sparse::CooBuilder;
    use crate::util::Pcg64;

    fn make_problem(seed: u64, n: usize, k: usize) -> Problem {
        let mut rng = Pcg64::seeded(seed);
        let mut b = CooBuilder::new(n, k);
        for j in 0..k {
            for i in 0..n {
                if rng.next_f64() < 0.3 {
                    b.push(i, j, rng.range_f64(-1.0, 1.0));
                }
            }
        }
        let mut x = b.build();
        x.normalize_columns();
        let wstar: Vec<f64> = (0..k).map(|j| if j < 3 { 1.0 } else { 0.0 }).collect();
        let y = x.matvec(&wstar);
        Problem::new(
            Dataset {
                x,
                y,
                name: "shard-t".into(),
            },
            Box::new(Squared),
            1e-3,
        )
    }

    /// Cyclic-per-shard specs over a contiguous plan.
    fn cyclic_specs(problem: &Problem, shards: usize) -> Vec<ShardSpec> {
        let plan = partition(&problem.x, shards, ShardStrategy::Contiguous);
        plan.shards
            .iter()
            .filter(|cols| !cols.is_empty())
            .map(|cols| {
                let lo = cols[0] as usize;
                let hi = cols[cols.len() - 1] as usize + 1;
                let view = problem.x.col_range_view(lo, hi);
                let k_s = view.n_cols();
                ShardSpec {
                    problem: Problem::new(
                        Dataset {
                            x: view,
                            y: problem.y.clone(),
                            name: String::new(),
                        },
                        problem.loss.clone_box(),
                        problem.lam,
                    ),
                    cols: cols.clone(),
                    select: Box::new(Cyclic { next: 0, k: k_s }),
                    accept: accept::all(),
                    update_path: UpdatePath::Auto,
                    threads: 1,
                }
            })
            .collect()
    }

    fn sharded_cfg(rounds: usize) -> ShardedConfig {
        ShardedConfig {
            max_rounds: rounds,
            max_seconds: 60.0,
            log_every: 50,
            ..Default::default()
        }
    }

    #[test]
    fn single_shard_descends_and_is_consistent() {
        let p = make_problem(1, 30, 12);
        let out = solve_sharded(&p, cyclic_specs(&p, 1), None, &sharded_cfg(240));
        let first = out.history.records.first().unwrap().objective;
        assert!(out.objective < first, "{first} -> {}", out.objective);
        assert_eq!(out.stop, StopReason::MaxIters);
        assert_eq!(out.metrics.iterations, 240);
        assert_eq!(out.metrics.shards, 1);
        assert_eq!(out.metrics.replica_divergence, 0.0);
        assert_eq!(out.metrics.numa_nodes, 0, "pinning off => 0");
        assert_eq!(out.metrics.reconcile_rounds_skipped, 0);
        // w and the reported objective agree with a from-scratch z (up
        // to incremental-z accumulation noise)
        let z = p.x.matvec(&out.w);
        assert!((p.objective(&out.w, &z) - out.objective).abs() < 1e-10);
    }

    #[test]
    fn multi_shard_descends_and_reconciles() {
        let p = make_problem(2, 40, 18);
        let out = solve_sharded(&p, cyclic_specs(&p, 3), None, &sharded_cfg(300));
        let first = out.history.records.first().unwrap().objective;
        assert!(out.objective < first, "{first} -> {}", out.objective);
        assert_eq!(out.metrics.shards, 3);
        // the delta fold actually engaged and measured its sparsity
        assert!(
            out.metrics.dirty_chunk_frac > 0.0,
            "default delta reconcile must report a dirty fraction"
        );
        // the reconciled residual must be exactly consistent with w (up
        // to fp reassociation across rounds)
        let z = p.x.matvec(&out.w);
        assert!(
            (p.objective(&out.w, &z) - out.objective).abs() < 1e-9,
            "reconciled z inconsistent with w"
        );
        assert!(out.metrics.reconcile_secs >= 0.0);
    }

    #[test]
    fn delta_fold_bitwise_matches_dense_fold() {
        // the §Dirty-chunk contract, end to end: the same multi-shard
        // solve with the delta fold and the dense reference fold must
        // produce bit-identical iterates (T = 1 pools are deterministic
        // and the fold order is fixed, so equality is exact)
        let p = make_problem(7, 50, 21);
        let run = |delta: bool| {
            let mut cfg = sharded_cfg(400);
            cfg.delta_reconcile = delta;
            solve_sharded(&p, cyclic_specs(&p, 3), None, &cfg)
        };
        let dense = run(false);
        let delta = run(true);
        assert_eq!(dense.w, delta.w, "delta fold diverged from dense fold");
        assert_eq!(dense.objective, delta.objective);
        assert_eq!(dense.metrics.dirty_chunk_frac, 0.0, "dense path has no map");
        assert!(delta.metrics.dirty_chunk_frac > 0.0);
    }

    #[test]
    fn fixed_cadence_skips_rounds_and_still_converges() {
        // reconcile_every = 4: three of four rounds skip the barrier
        let p = make_problem(8, 40, 16);
        let mut cfg = sharded_cfg(200);
        cfg.reconcile_every = 4;
        let out = solve_sharded(&p, cyclic_specs(&p, 2), None, &cfg);
        assert_eq!(out.stop, StopReason::MaxIters);
        assert_eq!(out.metrics.iterations, 200, "cap must land on a reconcile");
        assert!(
            out.metrics.reconcile_rounds_skipped > 100,
            "~3/4 of rounds should skip, got {}",
            out.metrics.reconcile_rounds_skipped
        );
        let first = out.history.records.first().unwrap().objective;
        assert!(out.objective < first);
        // reported objective consistent with the reconciled iterate
        let z = p.x.matvec(&out.w);
        assert!((p.objective(&out.w, &z) - out.objective).abs() < 1e-9);
    }

    #[test]
    fn warm_start_resumes_sharded() {
        let p = make_problem(3, 30, 12);
        let first = solve_sharded(&p, cyclic_specs(&p, 2), None, &sharded_cfg(200));
        let resumed = solve_sharded(
            &p,
            cyclic_specs(&p, 2),
            Some(&first.w),
            &sharded_cfg(50),
        );
        assert!(resumed.objective <= first.objective + 1e-12);
    }

    #[test]
    fn round_cap_and_timeouts_stop_lockstep() {
        let p = make_problem(4, 24, 10);
        let out = solve_sharded(&p, cyclic_specs(&p, 2), None, &sharded_cfg(0));
        assert_eq!(out.stop, StopReason::MaxIters);
        assert_eq!(out.metrics.iterations, 0);
        let mut cfg = sharded_cfg(usize::MAX);
        cfg.max_seconds = 0.2;
        let out = solve_sharded(&p, cyclic_specs(&p, 2), None, &cfg);
        assert_eq!(out.stop, StopReason::MaxSeconds);
        let mut cfg = sharded_cfg(usize::MAX);
        cfg.max_seconds = 30.0;
        cfg.tol = 1e-9;
        cfg.log_every = 10;
        let out = solve_sharded(&p, cyclic_specs(&p, 2), None, &cfg);
        assert_eq!(out.stop, StopReason::Tolerance);
    }

    #[test]
    fn observer_fires_at_reconciled_rounds_and_stops() {
        let p = make_problem(5, 30, 12);
        let mut calls = 0usize;
        let mut saw_state = false;
        let mut obs = |info: &IterationInfo<'_>| {
            calls += 1;
            saw_state |= info.state.w_snapshot().len() == p.n_features();
            if info.iter >= 10 {
                ControlFlow::Break(())
            } else {
                ControlFlow::Continue(())
            }
        };
        let out = solve_sharded_with(
            &p,
            cyclic_specs(&p, 2),
            None,
            &sharded_cfg(1000),
            Some(&mut obs),
            None,
        );
        assert_eq!(out.stop, StopReason::Observer);
        assert_eq!(out.metrics.iterations, 10);
        assert_eq!(calls, 11, "one call per reconciled round incl. round 0");
        assert!(saw_state, "observer must see the global-dims iterate");
    }

    /// Block-diagonal problem: contiguous shard `s` of `shards` touches
    /// only its own row block, so reconciles are conflict-free by
    /// construction (the adaptive cadence doubles every time).
    fn make_block_problem(seed: u64, n: usize, k: usize, shards: usize) -> Problem {
        let mut rng = Pcg64::seeded(seed);
        let mut b = CooBuilder::new(n, k);
        for j in 0..k {
            let s = j * shards / k;
            let (r_lo, r_hi) = (n * s / shards, n * (s + 1) / shards);
            for i in r_lo..r_hi {
                if rng.next_f64() < 0.5 {
                    b.push(i, j, rng.range_f64(-1.0, 1.0));
                }
            }
        }
        let mut x = b.build();
        x.normalize_columns();
        let wstar: Vec<f64> = (0..k).map(|j| if j % 5 == 0 { 1.0 } else { 0.0 }).collect();
        let y = x.matvec(&wstar);
        Problem::new(
            Dataset {
                x,
                y,
                name: "shard-block".into(),
            },
            Box::new(Squared),
            1e-3,
        )
    }

    /// A Select that panics after `fuse` calls — a pool death injected
    /// in policy code, the §Failure-semantics panic path.
    struct PanicAfter {
        inner: Cyclic,
        fuse: usize,
    }
    impl crate::coordinator::select::Select for PanicAfter {
        fn select(&mut self, out: &mut Vec<u32>) {
            if self.fuse == 0 {
                panic!("injected select panic");
            }
            self.fuse -= 1;
            self.inner.select(out);
        }
        fn expected_size(&self) -> f64 {
            1.0
        }
    }

    /// A Select that sleeps long enough to trip the reconcile barrier
    /// timeout on its peers.
    struct SlowSelect {
        inner: Cyclic,
        sleep: std::time::Duration,
    }
    impl crate::coordinator::select::Select for SlowSelect {
        fn select(&mut self, out: &mut Vec<u32>) {
            std::thread::sleep(self.sleep);
            self.inner.select(out);
        }
        fn expected_size(&self) -> f64 {
            1.0
        }
    }

    #[test]
    fn pool_panic_becomes_shard_failed() {
        // a pool that panics mid-solve must degrade the solve into
        // StopReason::ShardFailed + a structured SolveError — never a
        // hang, never an unwinding panic out of solve_sharded
        let p = make_problem(11, 30, 12);
        let mut specs = cyclic_specs(&p, 2);
        let k_s = specs[1].cols.len();
        specs[1].select = Box::new(PanicAfter {
            inner: Cyclic { next: 0, k: k_s },
            fuse: 5,
        });
        let out = solve_sharded(&p, specs, None, &sharded_cfg(1000));
        assert_eq!(out.stop, StopReason::ShardFailed);
        let failure = out.failure.expect("structured error must be carried");
        assert!(
            failure.message.contains("injected select panic"),
            "panic payload should surface: {failure}"
        );
        assert_eq!(failure.shard, Some(1));
        assert!(out.metrics.shard_failures >= 1);
        // the survivor's iterate is still reported and finite
        assert!(out.objective.is_finite());
    }

    #[test]
    fn barrier_timeout_becomes_shard_failed() {
        // one straggler pool sleeping far past the timeout: the healthy
        // shard must give up with TimedOut (poisoning the link so the
        // straggler escapes too) instead of waiting forever
        let p = make_problem(12, 30, 12);
        let mut specs = cyclic_specs(&p, 2);
        let k_s = specs[1].cols.len();
        specs[1].select = Box::new(SlowSelect {
            inner: Cyclic { next: 0, k: k_s },
            sleep: std::time::Duration::from_millis(800),
        });
        let mut cfg = sharded_cfg(1000);
        cfg.barrier_timeout_secs = 0.15;
        let t0 = std::time::Instant::now();
        let out = solve_sharded(&p, specs, None, &cfg);
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(30),
            "timed-out solve must terminate promptly"
        );
        assert_eq!(out.stop, StopReason::ShardFailed);
        let failure = out.failure.expect("structured error must be carried");
        assert!(
            failure.message.contains("timed out"),
            "first failure should be the timeout: {failure}"
        );
        assert!(out.metrics.shard_failures >= 1);
    }

    #[test]
    fn staleness_bound_clamps_adaptive_cadence() {
        // conflict-free block-diagonal data: the adaptive cadence
        // doubles unboundedly; max_staleness_rounds must clamp it and
        // count the forced reconciles
        let p = make_block_problem(13, 64, 16, 2);
        let run = |max_stale: usize| {
            let mut cfg = sharded_cfg(120);
            cfg.reconcile_every = 1;
            cfg.reconcile_max_rounds = 64;
            cfg.max_staleness_rounds = max_stale;
            solve_sharded(&p, cyclic_specs(&p, 2), None, &cfg)
        };
        let unbounded = run(0);
        assert_eq!(unbounded.metrics.staleness_forced_reconciles, 0);
        let bounded = run(4);
        assert_eq!(bounded.stop, StopReason::MaxIters);
        assert!(
            bounded.metrics.staleness_forced_reconciles > 0,
            "the doubling must have hit the staleness bound"
        );
        // replica age never exceeded the bound: with gap <= 4 at least
        // a quarter of rounds reconcile (skipped <= 3/4)
        assert!(
            bounded.metrics.reconcile_rounds_skipped
                <= 90,
            "bounded cadence must reconcile at least every 4 rounds, skipped {}",
            bounded.metrics.reconcile_rounds_skipped
        );
        assert!(
            unbounded.metrics.reconcile_rounds_skipped
                > bounded.metrics.reconcile_rounds_skipped,
            "the bound must actually force more reconciles than the free cadence"
        );
    }

    #[test]
    fn numa_pin_is_a_graceful_noop_and_bit_exact() {
        // whatever the host topology, pinning must not change a single
        // FP operation — and on single-node/non-Linux hosts it must
        // degrade to the warning metric rather than fail
        let p = make_problem(6, 40, 16);
        let run = |pin: bool| {
            let mut cfg = sharded_cfg(150);
            cfg.numa_pin = pin;
            solve_sharded(&p, cyclic_specs(&p, 2), None, &cfg)
        };
        let plain = run(false);
        let pinned = run(true);
        assert_eq!(plain.w, pinned.w, "pinning changed the math");
        assert_eq!(plain.objective, pinned.objective);
        assert_eq!(plain.metrics.numa_nodes, 0);
        assert!(
            pinned.metrics.numa_nodes >= 1,
            "numa_pin on must report at least the degraded value"
        );
    }
}
