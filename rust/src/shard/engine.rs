//! The sharded execution layer: one GenCD worker pool per shard, each
//! against a **shard-local residual replica**, reconciled at iteration
//! boundaries.
//!
//! # Why replicas
//!
//! The single-engine hot path already minimizes synchronization *within*
//! one coherent memory domain (spin barriers, buffered scatters), but
//! every worker still writes the same `z` array — across sockets that
//! cross-domain traffic, not arithmetic, is the wall (the Shotgun
//! shared-memory contention of Bradley et al. 2011, one level up).
//! Sharding removes it structurally: shard `s` owns a column subset
//! (a [`ShardPlan`](super::partition::ShardPlan)) and runs a complete,
//! unmodified [`engine::solve_from`] pool against its own full-length
//! `z` replica, so **no cache line is ever shared between shards inside
//! a round**.
//!
//! # Bulk-synchronous rounds
//!
//! Every pool runs exactly one GenCD iteration per *round*. At the
//! round boundary — delivered through the engine's own
//! [`Observer`] hook, which runs on each pool's leader while that
//! pool's workers are parked — the shards meet at a reconcile barrier
//! and fold their replicas, buffered-reduce style (disjoint
//! cache-aligned sample chunks, one owner per element, exactly the
//! machinery of [`crate::util::par::aligned_chunk`]):
//!
//! ```text
//!   z[i]  <-  z[i] + sum_s (z_s[i] - z[i])     (one owner per chunk)
//!   z_s[i] <- z[i]                             (replicas refreshed)
//! ```
//!
//! Within a round a shard sees only its *own* updates on top of the
//! last reconciled residual — the same frozen-residual semantics the
//! accept/line-search phases already assume for the buffered update
//! path, now at shard granularity. Cross-shard corrections surface as
//! [`MetricsSnapshot::replica_divergence`]; reconcile time as
//! [`MetricsSnapshot::reconcile_secs`].
//!
//! # Lockstep stopping
//!
//! A pool that stopped on its own (time, iteration cap, divergence)
//! would strand the other shards at the reconcile barrier, so the
//! per-shard engines are configured to never stop themselves: all
//! stopping decisions (round cap, wall clock, tolerance, divergence)
//! are taken once per round by the shard-0 *coordinator* between
//! barrier crossings and delivered to every pool simultaneously through
//! the observer's `ControlFlow::Break`. The coordinator also owns the
//! global convergence [`History`]: it gathers `w` across shards and
//! evaluates the true global objective at the usual log cadence.
//!
//! # Single-shard exactness
//!
//! With one shard the reconcile degenerates to nothing — the replica
//! *is* the canonical residual and is never rewritten — so a one-shard
//! sharded solve replays the unsharded engine's floating-point sequence
//! bit-exactly at T = 1 (pinned by `rust/tests/sharding.rs`).

use std::ops::ControlFlow;

use crate::coordinator::accept::Accept;
use crate::coordinator::convergence::{History, Record, StopReason};
use crate::coordinator::engine::{self, EngineConfig, EngineHooks, SolveOutput, UpdatePath};
use crate::coordinator::metrics::MetricsSnapshot;
use crate::coordinator::observer::{IterationInfo, Observer};
use crate::coordinator::problem::{Problem, SharedState};
use crate::coordinator::select::Select;
use crate::loss;
use crate::util::atomic::{SyncCell, SyncF64Vec};
use crate::util::par::{aligned_chunk, CachePadded, SpinBarrier, DEFAULT_SPIN};
use crate::util::Timer;

/// Everything one shard's pool runs with: a sub-problem over the
/// shard's columns (built on a zero-copy
/// [`col_range_view`](crate::sparse::CscMatrix::col_range_view)), the
/// local→global column map, and the shard-local policy pair
/// (instantiated over the *local* column space, so all presets run
/// sharded unchanged — their union is the effective global selection).
pub struct ShardSpec {
    /// Sub-problem: the shard's columns against the full sample space.
    pub problem: Problem,
    /// `cols[local] = global` column id (ascending).
    pub cols: Vec<u32>,
    /// Shard-local selection policy.
    pub select: Box<dyn Select>,
    /// Shard-local accept policy.
    pub accept: Box<dyn Accept>,
    /// Update discipline for this shard's pool (COLORING shards run
    /// conflict-free: colorings only need to be valid *within* a shard,
    /// since cross-shard writes land on different replicas).
    pub update_path: UpdatePath,
    /// Worker threads for this shard's pool (the shard's leader is
    /// worker 0 of its pool; 0 is treated as 1). Per-spec so a total
    /// thread budget can be split unevenly — the builder hands the
    /// first `total % shards` pools one extra worker each.
    pub threads: usize,
}

/// Knobs of a sharded solve (the cross-shard analogue of
/// [`EngineConfig`]; per-pool knobs are derived from it — pool thread
/// counts live on each [`ShardSpec`]).
#[derive(Clone, Debug)]
pub struct ShardedConfig {
    pub line_search_steps: usize,
    /// Round cap (a round is one lockstep GenCD iteration per shard).
    pub max_rounds: usize,
    pub max_seconds: f64,
    /// Relative-improvement stop over the *global* objective log
    /// (0 disables; three consecutive hits, like the engine).
    pub tol: f64,
    /// Global-objective log cadence in rounds; 0 = time-based (~50 ms).
    pub log_every: usize,
    /// Total buffered-update memory budget, divided across the shard
    /// pools so the whole sharded solve honors one figure.
    pub buffer_budget_mb: usize,
    pub barrier_spin: u32,
    /// Active-set KKT screening, **one active set per shard pool**:
    /// each pool wraps its own Select policy and runs its own full-set
    /// sweeps over its own columns ([`crate::screen`]). Sweeps land at
    /// round boundaries by construction (one engine iteration == one
    /// round), i.e. right after the reconcile refreshed the replicas,
    /// so reactivation always judges the reconciled residual. The
    /// coordinator gates its tolerance stop with a **global** KKT check
    /// on the reconciled iterate (a zero-weight coordinate with
    /// `|g| > lam` refuses the stop until the pools' sweeps repair it),
    /// so a sharded screened solve also only converges as
    /// [`StopReason::Converged`], certified.
    pub screening: bool,
    /// Per-pool full-set KKT sweep cadence in rounds.
    pub kkt_every: usize,
    /// Unrolled gather kernels in every pool (see
    /// `EngineConfig::fast_kernels`).
    pub fast_kernels: bool,
}

impl Default for ShardedConfig {
    fn default() -> Self {
        let ecfg = EngineConfig::default();
        Self {
            line_search_steps: 0,
            max_rounds: usize::MAX,
            max_seconds: 10.0,
            tol: 0.0,
            log_every: 0,
            buffer_budget_mb: 1024,
            barrier_spin: DEFAULT_SPIN,
            screening: ecfg.screening,
            kkt_every: ecfg.kkt_every,
            fast_kernels: ecfg.fast_kernels,
        }
    }
}

/// Cross-shard shared state: the reconcile barrier, the canonical
/// residual, the stop decision, and per-shard padded metric slots
/// (unique writer per slot, read by the coordinator after a barrier).
struct ReconcileShared<'a> {
    barrier: SpinBarrier,
    states: &'a [SharedState],
    /// Canonical reconciled residual (untouched in single-shard runs —
    /// there the replica itself is canonical).
    z_canon: SyncF64Vec,
    /// Written by the coordinator between the 2nd and 3rd crossings of
    /// a round, read by every shard after the 3rd.
    stop: SyncCell<Option<StopReason>>,
    /// Per-shard cumulative update counts (published each round for the
    /// coordinator's history records).
    updates: Vec<CachePadded<SyncCell<u64>>>,
    /// Per-shard running max of reconcile corrections ever applied.
    divergence: Vec<CachePadded<SyncCell<f64>>>,
    /// Per-shard nanoseconds spent in the reconcile fold.
    reconcile_nanos: Vec<CachePadded<SyncCell<u64>>>,
    n: usize,
}

/// The canonical residual: the reconciled array, or the lone replica in
/// single-shard runs.
fn canonical_z(sh: &ReconcileShared<'_>) -> &SyncF64Vec {
    if sh.states.len() == 1 {
        &sh.states[0].z
    } else {
        &sh.z_canon
    }
}

/// Leader-side bookkeeping owned by shard 0: the global objective log
/// and every stopping decision.
struct Coordinator<'a> {
    global: &'a Problem,
    cols: &'a [Vec<u32>],
    /// `owned[j]`: some shard's column map covers global column j. The
    /// screening gate only judges owned columns — an uncovered column
    /// is structurally frozen at zero by the caller's partition (legal
    /// per [`solve_sharded`]'s contract), so no pool could ever repair
    /// a "violation" there and the unscreened solve would not move it
    /// either.
    owned: &'a [bool],
    timer: &'a Timer,
    cfg: &'a ShardedConfig,
    history: History,
    scratch_w: Vec<f64>,
    last_log_at: f64,
    tol_hits: u32,
}

impl Coordinator<'_> {
    /// Runs between the reconcile-publish and decision-publish barrier
    /// crossings: every replica equals the reconciled residual, every
    /// pool's workers are parked, every `w` is quiescent — so gathering
    /// the global iterate is plain reads.
    fn plan_round(&mut self, sh: &ReconcileShared<'_>, round: usize) -> Option<StopReason> {
        let elapsed = self.timer.elapsed_secs();
        let mut stop = None;
        let should_log = match self.cfg.log_every {
            0 => elapsed - self.last_log_at >= 0.05 || round == 0,
            every => round % every == 0,
        };
        if should_log {
            for (cols, st) in self.cols.iter().zip(sh.states) {
                for (local, &g) in cols.iter().enumerate() {
                    self.scratch_w[g as usize] = st.w.get(local);
                }
            }
            let z = canonical_z(sh).snapshot();
            let obj = loss::objective(
                self.global.loss.as_ref(),
                &self.global.y,
                &z,
                &self.scratch_w,
                self.global.lam,
            );
            let updates: u64 = sh.updates.iter().map(|u| u.get()).sum();
            self.history.push(Record {
                elapsed_secs: elapsed,
                iter: round,
                updates,
                objective: obj,
                nnz: loss::nnz(&self.scratch_w),
            });
            self.last_log_at = elapsed;
            if !obj.is_finite() || obj > 1e12 {
                stop = Some(StopReason::Diverged);
            }
            if stop.is_none() && self.cfg.tol > 0.0 {
                if self.history.last_rel_improvement().abs() < self.cfg.tol {
                    self.tol_hits += 1;
                } else {
                    self.tol_hits = 0;
                }
                if self.tol_hits >= 3 {
                    if self.cfg.screening {
                        // Cross-shard convergence gate: per-pool active
                        // sets are pool-internal, so certify the frozen
                        // coordinates directly on the *global* iterate —
                        // one O(nnz) full gradient at the reconciled
                        // residual, only on gate attempts. A zero-weight
                        // coordinate with |g| > lam is either screened
                        // out or simply unvisited; either way the solve
                        // is not done, so refuse the stop and let the
                        // pools' periodic sweeps reactivate it. A clean
                        // pass certifies the screened solution as the
                        // unscreened optimum's: report Converged.
                        let g = loss::full_gradient(
                            self.global.loss.as_ref(),
                            &self.global.x,
                            &self.global.y,
                            &z,
                        );
                        // Margined test (screen::GATE_MARGIN): this
                        // gradient is computed with different summation
                        // order than the pools' dot_col gradients, so a
                        // strict |g| > lam test could flag an ulp-level
                        // "violation" the owning pool measures as
                        // satisfied and will never repair — refusing
                        // the stop forever.
                        let lam = self.global.lam;
                        let violated = self
                            .scratch_w
                            .iter()
                            .zip(&g)
                            .zip(self.owned)
                            .any(|((&wj, &gj), &owned)| {
                                // only shard-owned columns: an uncovered
                                // column is frozen by the partition, not
                                // by screening — no sweep can repair it
                                owned
                                    && wj == 0.0
                                    && crate::screen::violates_at_zero(gj, lam)
                            });
                        if violated {
                            self.tol_hits = 0;
                        } else {
                            stop = Some(StopReason::Converged);
                        }
                    } else {
                        stop = Some(StopReason::Tolerance);
                    }
                }
            }
        }
        if stop.is_none() {
            if round >= self.cfg.max_rounds {
                stop = Some(StopReason::MaxIters);
            } else if elapsed >= self.cfg.max_seconds {
                stop = Some(StopReason::MaxSeconds);
            }
        }
        stop
    }
}

/// The per-shard observer: runs on each pool's leader at every round
/// boundary and implements the three-crossing reconcile protocol
/// (arrive → fold chunks → publish → decide → publish → read decision).
struct ShardObserver<'a> {
    s: usize,
    shared: &'a ReconcileShared<'a>,
    coordinator: Option<Coordinator<'a>>,
}

impl ShardObserver<'_> {
    /// Fold every replica's round delta into the canonical residual
    /// over this shard's cache-aligned sample chunk, then refresh all
    /// replicas — disjoint chunks across shards, one writer per
    /// element, the buffered-reduce discipline of `util::par`.
    fn reconcile(&mut self) {
        let sh = self.shared;
        let shards = sh.states.len();
        if shards == 1 {
            // the replica is canonical; rewriting it (even with an
            // a + (b - a) identity) would perturb bit-exactness
            return;
        }
        let t0 = std::time::Instant::now();
        let mut div = sh.divergence[self.s].get();
        for i in aligned_chunk(sh.n, self.s, shards) {
            let base = sh.z_canon.get(i);
            let mut acc = base;
            for st in sh.states {
                let d = st.z.get(i) - base;
                if d != 0.0 {
                    acc += d;
                }
            }
            for st in sh.states {
                let cur = st.z.get(i);
                if cur != acc {
                    // a replica that updated i itself (cur != base) and
                    // still needs a correction saw a *conflicting*
                    // cross-shard write — the divergence the
                    // partitioner exists to minimize. Replicas merely
                    // *learning* another shard's update (cur == base)
                    // are the mechanism working as designed.
                    if cur != base {
                        let corr = (acc - cur).abs();
                        if corr > div {
                            div = corr;
                        }
                    }
                    st.z.set(i, acc);
                }
            }
            if acc != base {
                sh.z_canon.set(i, acc);
            }
        }
        sh.divergence[self.s].set(div);
        let prev = sh.reconcile_nanos[self.s].get();
        sh.reconcile_nanos[self.s].set(prev + t0.elapsed().as_nanos() as u64);
    }
}

impl Observer for ShardObserver<'_> {
    fn on_iteration(&mut self, info: &IterationInfo<'_>) -> ControlFlow<()> {
        let sh = self.shared;
        // own padded slot; published to the coordinator by the barrier
        // chain below
        sh.updates[self.s].set(info.updates);
        // crossing 1: every shard finished the round; all replica
        // updates are visible (each pool's end-of-update barrier chains
        // into this one)
        sh.barrier.wait();
        self.reconcile();
        // crossing 2: the reconciled residual is published everywhere
        sh.barrier.wait();
        if let Some(c) = self.coordinator.as_mut() {
            let stop = c.plan_round(sh, info.iter);
            sh.stop.set(stop);
        }
        // crossing 3: the stop decision is published
        sh.barrier.wait();
        if sh.stop.get().is_some() {
            ControlFlow::Break(())
        } else {
            ControlFlow::Continue(())
        }
    }
}

/// Poisons the reconcile barrier if a shard pool unwinds, so the other
/// pools panic out of their crossings instead of deadlocking on a shard
/// that will never arrive (the cross-shard analogue of the engine's
/// internal poison guard).
struct PoisonReconcileOnPanic<'a>(&'a SpinBarrier);

impl Drop for PoisonReconcileOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.poison();
        }
    }
}

/// Run a sharded GenCD solve: one engine pool per [`ShardSpec`], each
/// with that spec's worker count, reconciled every round.
///
/// `global` supplies the objective's loss/labels/lambda and the full
/// design matrix (used once for the warm-start residual); the per-shard
/// math runs entirely on the specs' sub-problems. The output is shaped
/// exactly like an unsharded [`SolveOutput`]: global `w`, global
/// objective and history, aggregated metrics (plus the shard fields of
/// [`MetricsSnapshot`]).
///
/// # Panics
///
/// If `specs` is empty, a spec's dimensions disagree with `global`, a
/// column map holds an out-of-range or *duplicated* global column (two
/// shards owning one column would silently double-count its residual
/// contribution at every reconcile), screening is enabled with
/// `kkt_every == 0` (pools never gate, so no sweep would ever repair a
/// deactivation), or a warm start has the wrong length — programming
/// errors, all caught before any threads spawn.
/// The maps need not cover every column: uncovered columns simply stay
/// at zero (the builder always produces an exact cover).
pub fn solve_sharded(
    global: &Problem,
    specs: Vec<ShardSpec>,
    warm_start: Option<&[f64]>,
    cfg: &ShardedConfig,
) -> SolveOutput {
    let s_count = specs.len();
    assert!(s_count >= 1, "solve_sharded: need at least one shard");
    // The engine tolerates kkt_every = 0 as an ablation (the gate sweep
    // still reactivates), but sharded pools run with tol = 0 and never
    // gate — periodic sweeps are their ONLY reactivation path, so
    // screening without them would freeze fused deactivations forever.
    assert!(
        !cfg.screening || cfg.kkt_every >= 1,
        "solve_sharded: screening requires kkt_every >= 1 (pool engines \
         never run gate sweeps; the periodic cadence is the only \
         reactivation path)"
    );
    let n = global.n_samples();
    let k = global.n_features();

    // split the specs: column maps stay with the coordinator, the
    // (problem, policies) move into the shard threads
    let mut owned = vec![false; k];
    let mut cols_all = Vec::with_capacity(s_count);
    let mut runs = Vec::with_capacity(s_count);
    for spec in specs {
        assert_eq!(
            spec.problem.n_features(),
            spec.cols.len(),
            "shard sub-problem columns != column map"
        );
        assert_eq!(spec.problem.n_samples(), n, "shard sample space mismatch");
        for &g in &spec.cols {
            let g = g as usize;
            assert!(g < k, "shard column map holds column {g}, problem has {k}");
            assert!(
                !owned[g],
                "column {g} appears in two shards' column maps — every column \
                 must have exactly one owning shard"
            );
            owned[g] = true;
        }
        cols_all.push(spec.cols);
        runs.push((
            spec.problem,
            spec.select,
            spec.accept,
            spec.update_path,
            spec.threads.max(1),
        ));
    }

    // one full-length residual replica per shard
    let states: Vec<SharedState> = cols_all
        .iter()
        .map(|c| SharedState::new(n, c.len()))
        .collect();
    let z_canon = SyncF64Vec::zeros(n);
    if let Some(w0) = warm_start {
        assert_eq!(w0.len(), k, "warm start has {} weights for {k}", w0.len());
        let z0 = global.x.matvec(w0);
        z_canon.copy_from(&z0);
        for (cols, st) in cols_all.iter().zip(&states) {
            for (local, &g) in cols.iter().enumerate() {
                st.w.set(local, w0[g as usize]);
            }
            st.z.copy_from(&z0);
        }
    }

    let shared = ReconcileShared {
        barrier: SpinBarrier::with_spin(s_count, cfg.barrier_spin),
        states: &states,
        z_canon,
        stop: SyncCell::new(None),
        updates: (0..s_count)
            .map(|_| CachePadded::new(SyncCell::new(0u64)))
            .collect(),
        divergence: (0..s_count)
            .map(|_| CachePadded::new(SyncCell::new(0.0f64)))
            .collect(),
        reconcile_nanos: (0..s_count)
            .map(|_| CachePadded::new(SyncCell::new(0u64)))
            .collect(),
        n,
    };
    let timer = Timer::start();

    // Per-pool engine config: pools never stop on their own — every
    // stop (rounds, time, tolerance, divergence) is decided by the
    // coordinator and delivered through the observer, keeping all pools
    // on the same round (lockstep; see module docs). log_every = MAX
    // confines each pool's private objective log to round 0.
    let engine_cfg = |update_path: UpdatePath, threads: usize| EngineConfig {
        threads,
        line_search_steps: cfg.line_search_steps,
        max_iters: usize::MAX,
        max_seconds: f64::INFINITY,
        tol: 0.0,
        log_every: usize::MAX,
        force_dloss: None,
        update_path,
        buffer_budget_mb: cfg.buffer_budget_mb / s_count,
        barrier_spin: cfg.barrier_spin,
        screening: cfg.screening,
        kkt_every: cfg.kkt_every,
        fast_kernels: cfg.fast_kernels,
    };

    let mut outs: Vec<SolveOutput> = Vec::with_capacity(s_count);
    let mut coord_history: Option<History> = None;
    std::thread::scope(|scope| {
        let shared = &shared;
        let mut handles = Vec::with_capacity(s_count);
        for (s, (problem, select, accept, update_path, threads)) in
            runs.into_iter().enumerate()
        {
            let ecfg = engine_cfg(update_path, threads);
            let coordinator = (s == 0).then(|| Coordinator {
                global,
                cols: &cols_all,
                owned: &owned,
                timer: &timer,
                cfg,
                history: History::default(),
                scratch_w: vec![0.0; k],
                last_log_at: -1.0,
                tol_hits: 0,
            });
            let st = &states[s];
            handles.push(scope.spawn(move || {
                let _guard = PoisonReconcileOnPanic(&shared.barrier);
                let mut obs = ShardObserver {
                    s,
                    shared,
                    coordinator,
                };
                let out = engine::solve_from(
                    &problem,
                    st,
                    select,
                    accept,
                    &ecfg,
                    EngineHooks::with_observer(&mut obs),
                );
                (out, obs.coordinator.map(|c| c.history))
            }));
        }
        for h in handles {
            let (out, hist) = h.join().expect("shard pool panicked");
            if let Some(hist) = hist {
                coord_history = Some(hist);
            }
            outs.push(out);
        }
    });

    // global iterate: shard-owned w entries mapped back through the
    // column maps; the reconciled residual is already global
    let mut w = vec![0.0; k];
    for (cols, st) in cols_all.iter().zip(&states) {
        for (local, &g) in cols.iter().enumerate() {
            w[g as usize] = st.w.get(local);
        }
    }
    let z = canonical_z(&shared).snapshot();
    let objective = global.objective(&w, &z);

    // aggregate metrics: counts sum across pools, phase seconds are
    // summed leader CPU time, reconcile is the slowest leader's
    // wall-clock share, iterations = completed rounds (identical on
    // every pool by lockstep)
    let mut agg = MetricsSnapshot {
        iterations: outs[0].metrics.iterations,
        shards: s_count as u64,
        reconcile_secs: shared
            .reconcile_nanos
            .iter()
            .map(|c| c.get())
            .max()
            .unwrap_or(0) as f64
            * 1e-9,
        replica_divergence: shared
            .divergence
            .iter()
            .map(|c| c.get())
            .fold(0.0, f64::max),
        ..Default::default()
    };
    for o in &outs {
        agg.updates += o.metrics.updates;
        agg.proposals += o.metrics.proposals;
        agg.propose_nnz += o.metrics.propose_nnz;
        agg.spill_iters += o.metrics.spill_iters;
        // screening: per-shard active sets — totals sum across pools
        agg.kkt_passes += o.metrics.kkt_passes;
        agg.reactivations += o.metrics.reactivations;
        agg.active_cols += o.metrics.active_cols;
        agg.select_secs += o.metrics.select_secs;
        agg.propose_secs += o.metrics.propose_secs;
        agg.accept_secs += o.metrics.accept_secs;
        agg.update_secs += o.metrics.update_secs;
        agg.screen_secs += o.metrics.screen_secs;
        agg.log_secs += o.metrics.log_secs;
        agg.auto_cas_ratio = agg.auto_cas_ratio.max(o.metrics.auto_cas_ratio);
        agg.auto_switch_factor = agg.auto_switch_factor.max(o.metrics.auto_switch_factor);
    }

    SolveOutput {
        nnz: loss::nnz(&w),
        w,
        objective,
        history: coord_history.unwrap_or_default(),
        metrics: agg,
        stop: shared.stop.get().unwrap_or(StopReason::MaxIters),
        elapsed_secs: timer.elapsed_secs(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::accept;
    use crate::coordinator::select::Cyclic;
    use crate::loss::Squared;
    use crate::shard::partition::{partition, ShardStrategy};
    use crate::sparse::io::Dataset;
    use crate::sparse::CooBuilder;
    use crate::util::Pcg64;

    fn make_problem(seed: u64, n: usize, k: usize) -> Problem {
        let mut rng = Pcg64::seeded(seed);
        let mut b = CooBuilder::new(n, k);
        for j in 0..k {
            for i in 0..n {
                if rng.next_f64() < 0.3 {
                    b.push(i, j, rng.range_f64(-1.0, 1.0));
                }
            }
        }
        let mut x = b.build();
        x.normalize_columns();
        let wstar: Vec<f64> = (0..k).map(|j| if j < 3 { 1.0 } else { 0.0 }).collect();
        let y = x.matvec(&wstar);
        Problem::new(
            Dataset {
                x,
                y,
                name: "shard-t".into(),
            },
            Box::new(Squared),
            1e-3,
        )
    }

    /// Cyclic-per-shard specs over a contiguous plan.
    fn cyclic_specs(problem: &Problem, shards: usize) -> Vec<ShardSpec> {
        let plan = partition(&problem.x, shards, ShardStrategy::Contiguous);
        plan.shards
            .iter()
            .filter(|cols| !cols.is_empty())
            .map(|cols| {
                let lo = cols[0] as usize;
                let hi = cols[cols.len() - 1] as usize + 1;
                let view = problem.x.col_range_view(lo, hi);
                let k_s = view.n_cols();
                ShardSpec {
                    problem: Problem::new(
                        Dataset {
                            x: view,
                            y: problem.y.clone(),
                            name: String::new(),
                        },
                        problem.loss.clone_box(),
                        problem.lam,
                    ),
                    cols: cols.clone(),
                    select: Box::new(Cyclic { next: 0, k: k_s }),
                    accept: accept::all(),
                    update_path: UpdatePath::Auto,
                    threads: 1,
                }
            })
            .collect()
    }

    fn sharded_cfg(rounds: usize) -> ShardedConfig {
        ShardedConfig {
            max_rounds: rounds,
            max_seconds: 60.0,
            log_every: 50,
            ..Default::default()
        }
    }

    #[test]
    fn single_shard_descends_and_is_consistent() {
        let p = make_problem(1, 30, 12);
        let out = solve_sharded(&p, cyclic_specs(&p, 1), None, &sharded_cfg(240));
        let first = out.history.records.first().unwrap().objective;
        assert!(out.objective < first, "{first} -> {}", out.objective);
        assert_eq!(out.stop, StopReason::MaxIters);
        assert_eq!(out.metrics.iterations, 240);
        assert_eq!(out.metrics.shards, 1);
        assert_eq!(out.metrics.replica_divergence, 0.0);
        // w and the reported objective agree with a from-scratch z (up
        // to incremental-z accumulation noise)
        let z = p.x.matvec(&out.w);
        assert!((p.objective(&out.w, &z) - out.objective).abs() < 1e-10);
    }

    #[test]
    fn multi_shard_descends_and_reconciles() {
        let p = make_problem(2, 40, 18);
        let out = solve_sharded(&p, cyclic_specs(&p, 3), None, &sharded_cfg(300));
        let first = out.history.records.first().unwrap().objective;
        assert!(out.objective < first, "{first} -> {}", out.objective);
        assert_eq!(out.metrics.shards, 3);
        // the reconciled residual must be exactly consistent with w (up
        // to fp reassociation across rounds)
        let z = p.x.matvec(&out.w);
        assert!(
            (p.objective(&out.w, &z) - out.objective).abs() < 1e-9,
            "reconciled z inconsistent with w"
        );
        assert!(out.metrics.reconcile_secs >= 0.0);
    }

    #[test]
    fn warm_start_resumes_sharded() {
        let p = make_problem(3, 30, 12);
        let first = solve_sharded(&p, cyclic_specs(&p, 2), None, &sharded_cfg(200));
        let resumed = solve_sharded(
            &p,
            cyclic_specs(&p, 2),
            Some(&first.w),
            &sharded_cfg(50),
        );
        assert!(resumed.objective <= first.objective + 1e-12);
    }

    #[test]
    fn round_cap_and_timeouts_stop_lockstep() {
        let p = make_problem(4, 24, 10);
        let out = solve_sharded(&p, cyclic_specs(&p, 2), None, &sharded_cfg(0));
        assert_eq!(out.stop, StopReason::MaxIters);
        assert_eq!(out.metrics.iterations, 0);
        let mut cfg = sharded_cfg(usize::MAX);
        cfg.max_seconds = 0.2;
        let out = solve_sharded(&p, cyclic_specs(&p, 2), None, &cfg);
        assert_eq!(out.stop, StopReason::MaxSeconds);
        let mut cfg = sharded_cfg(usize::MAX);
        cfg.max_seconds = 30.0;
        cfg.tol = 1e-9;
        cfg.log_every = 10;
        let out = solve_sharded(&p, cyclic_specs(&p, 2), None, &cfg);
        assert_eq!(out.stop, StopReason::Tolerance);
    }
}
