//! Sharded execution: multi-socket scaling through per-shard residual
//! replicas.
//!
//! The layer between one shared-memory engine pool and a distributed
//! backend (see the "Execution layers" section of the crate docs):
//!
//! * [`mod@partition`] — topology-aware column partitioning
//!   ([`ShardStrategy`]: contiguous / round-robin / greedy
//!   sample-overlap minimization à la Scherrer et al. 2013's feature
//!   clustering), producing a [`ShardPlan`] that covers every column
//!   exactly once.
//! * [`engine`] — the bulk-synchronous orchestration
//!   ([`engine::solve_sharded`]): one unmodified GenCD worker pool per
//!   shard against a shard-local `z` replica (zero-copy column-range
//!   views of the design matrix), NUMA-pinned with first-touch replica
//!   allocation when asked ([`engine`] §NUMA), reconciled at round
//!   boundaries — every R rounds under the adaptive cadence, folding
//!   only dirty chunks ([`engine`] §Reconcile cadence) — with the
//!   buffered-reduce machinery of [`crate::util::par`].
//!
//! Entry points: [`SolverBuilder::shards`](crate::solver::SolverBuilder::shards)
//! / [`shard_strategy`](crate::solver::SolverBuilder::shard_strategy) /
//! [`numa_pin`](crate::solver::SolverBuilder::numa_pin) /
//! [`reconcile_every`](crate::solver::SolverBuilder::reconcile_every) /
//! [`reconcile_max_rounds`](crate::solver::SolverBuilder::reconcile_max_rounds)
//! for the builder surface, the same names under `solver.*` in TOML,
//! `--shards` / `--shard-strategy` / `--numa-pin` / `--reconcile-every`
//! / `--reconcile-max-rounds` on the CLI; or call
//! [`engine::solve_sharded`] (or [`engine::solve_sharded_with`], which
//! adds a coordinator-side observer) directly with hand-built
//! [`engine::ShardSpec`]s.

pub mod engine;
pub mod partition;

pub use engine::{
    solve_sharded, solve_sharded_linked, solve_sharded_with, BarrierLink, LinkFault,
    ReconcileLink, ShardSpec, ShardedConfig,
};
pub use partition::{partition, ShardPlan, ShardStrategy};
