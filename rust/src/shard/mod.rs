//! Sharded execution: multi-socket scaling through per-shard residual
//! replicas.
//!
//! The layer between one shared-memory engine pool and a distributed
//! backend (see the "Execution layers" section of the crate docs):
//!
//! * [`mod@partition`] — topology-aware column partitioning
//!   ([`ShardStrategy`]: contiguous / round-robin / greedy
//!   sample-overlap minimization à la Scherrer et al. 2013's feature
//!   clustering), producing a [`ShardPlan`] that covers every column
//!   exactly once.
//! * [`engine`] — the bulk-synchronous orchestration
//!   ([`engine::solve_sharded`]): one unmodified GenCD worker pool per
//!   shard against a shard-local `z` replica (zero-copy column-range
//!   views of the design matrix), reconciled at round boundaries with
//!   the buffered-reduce machinery of [`crate::util::par`].
//!
//! Entry points: [`SolverBuilder::shards`](crate::solver::SolverBuilder::shards)
//! / [`shard_strategy`](crate::solver::SolverBuilder::shard_strategy)
//! for the builder surface, `solver.shards` / `solver.shard_strategy`
//! in TOML, `--shards` / `--shard-strategy` on the CLI; or call
//! [`engine::solve_sharded`] directly with hand-built
//! [`engine::ShardSpec`]s.

pub mod engine;
pub mod partition;

pub use engine::{solve_sharded, ShardSpec, ShardedConfig};
pub use partition::{partition, ShardPlan, ShardStrategy};
