//! Minimal TOML-subset parser (offline stand-in for the `toml` crate).
//!
//! Supported: `[section]` headers, `key = value` with string / integer /
//! float / boolean / flat array values, `#` comments, bare and quoted
//! keys. Unsupported (rejected, never silently misparsed): nested
//! tables-in-arrays, multi-line strings, datetimes.

use std::collections::BTreeMap;

/// A parsed scalar or flat array.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    String(String),
    Integer(i64),
    Float(f64),
    Boolean(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Integer(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`lam = 1` == `1.0`).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Integer(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Boolean(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// A parsed document: `table -> key -> value`. Top-level keys live under
/// the empty-string table.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Document {
    pub tables: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Document {
    /// Get `key` in `table` ("" for top level).
    pub fn get(&self, table: &str, key: &str) -> Option<&Value> {
        self.tables.get(table).and_then(|t| t.get(key))
    }

    pub fn table(&self, table: &str) -> Option<&BTreeMap<String, Value>> {
        self.tables.get(table)
    }
}

/// Parse a TOML-subset document.
pub fn parse(input: &str) -> anyhow::Result<Document> {
    let mut doc = Document::default();
    let mut current = String::new();
    doc.tables.entry(current.clone()).or_default();

    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow::anyhow!("line {}: unterminated table header", lineno + 1))?
                .trim();
            anyhow::ensure!(
                !name.is_empty() && !name.starts_with('['),
                "line {}: unsupported table header '{line}'",
                lineno + 1
            );
            current = name.to_string();
            doc.tables.entry(current.clone()).or_default();
            continue;
        }
        let (key, rest) = line
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim().trim_matches('"').to_string();
        anyhow::ensure!(!key.is_empty(), "line {}: empty key", lineno + 1);
        let value = parse_value(rest.trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        doc.tables.get_mut(&current).unwrap().insert(key, value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // a '#' outside quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> anyhow::Result<Value> {
    anyhow::ensure!(!s.is_empty(), "empty value");
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
        anyhow::ensure!(!inner.contains('"'), "embedded quote unsupported");
        return Ok(Value::String(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow::anyhow!("unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let mut items = Vec::new();
        for part in split_top_level(inner)? {
            items.push(parse_value(part.trim())?);
        }
        return Ok(Value::Array(items));
    }
    match s {
        "true" => return Ok(Value::Boolean(true)),
        "false" => return Ok(Value::Boolean(false)),
        _ => {}
    }
    // integer before float so `3` parses as Integer
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(Value::Integer(i));
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(Value::Float(f));
    }
    anyhow::bail!("cannot parse value '{s}'")
}

/// Split an array body on commas, respecting quotes (flat arrays only).
fn split_top_level(s: &str) -> anyhow::Result<Vec<&str>> {
    let mut parts = Vec::new();
    let mut start = 0usize;
    let mut in_str = false;
    let mut depth = 0i32;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    anyhow::ensure!(depth == 0 && !in_str, "unbalanced array");
    parts.push(&s[start..]);
    Ok(parts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typed_values() {
        let doc = parse(
            r#"
            # experiment config
            name = "fig1"          # trailing comment
            threads = 32
            lam = 1e-4
            verbose = true
            sizes = [1, 2, 4]
            tags = ["a", "b"]

            [dataset]
            kind = "dorothea"
            scale = 0.5
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "name").unwrap().as_str(), Some("fig1"));
        assert_eq!(doc.get("", "threads").unwrap().as_int(), Some(32));
        assert_eq!(doc.get("", "lam").unwrap().as_float(), Some(1e-4));
        assert_eq!(doc.get("", "verbose").unwrap().as_bool(), Some(true));
        assert_eq!(
            doc.get("", "sizes").unwrap().as_array().unwrap().len(),
            3
        );
        assert_eq!(doc.get("dataset", "kind").unwrap().as_str(), Some("dorothea"));
        assert_eq!(doc.get("dataset", "scale").unwrap().as_float(), Some(0.5));
    }

    #[test]
    fn integer_coerces_to_float() {
        let doc = parse("x = 3\n").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_float(), Some(3.0));
        assert_eq!(doc.get("", "x").unwrap().as_int(), Some(3));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("[unclosed\n").is_err());
        assert!(parse("novalue\n").is_err());
        assert!(parse("x = \"open\n").is_err());
        assert!(parse("x = [1, 2\n").is_err());
        assert!(parse("x = what\n").is_err());
        assert!(parse("[[array_of_tables]]\n").is_err());
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = parse("x = \"a#b\" # real comment\n").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn underscored_numbers() {
        let doc = parse("n = 100_000\n").unwrap();
        assert_eq!(doc.get("", "n").unwrap().as_int(), Some(100_000));
    }
}
