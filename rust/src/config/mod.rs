//! Typed experiment configuration.
//!
//! Experiments are described by a TOML-subset file (see `configs/`) with
//! three tables — `[dataset]`, `[problem]`, `[solver]` — plus optional
//! `[output]`. Every field has a default, and any field can be
//! overridden from the CLI with `--set table.key=value`, so a config file
//! is a starting point, not a straitjacket.

pub mod toml;

use toml::{parse, Document, Value};

/// Which Propose backend executes the per-block math (DESIGN.md §2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust sparse column traversal (the paper's OpenMP analogue).
    SparseRust,
    /// AOT-compiled JAX/Pallas artifact via PJRT (dense panel per block).
    DenseBlockHlo,
}

impl Backend {
    pub fn by_name(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "sparse" | "rust" => Backend::SparseRust,
            "hlo" | "pjrt" => Backend::DenseBlockHlo,
            other => anyhow::bail!("unknown backend '{other}' (sparse|hlo)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::SparseRust => "sparse",
            Backend::DenseBlockHlo => "hlo",
        }
    }
}

/// `[dataset]` table.
#[derive(Clone, Debug)]
pub struct DatasetConfig {
    /// Registry name (`dorothea`, `reuters`, optionally `@scale`) or a
    /// path to a libsvm/binary file when `path` is set.
    pub name: String,
    /// Load from file instead of generating.
    pub path: Option<String>,
    /// Column-normalize (paper Sec. 4.4; algorithmic assumption for
    /// beta-based steps).
    pub normalize: bool,
}

impl Default for DatasetConfig {
    fn default() -> Self {
        Self {
            name: "dorothea@0.1".into(),
            path: None,
            normalize: true,
        }
    }
}

/// `[problem]` table.
#[derive(Clone, Debug)]
pub struct ProblemConfig {
    pub loss: String,
    pub lam: f64,
}

impl Default for ProblemConfig {
    fn default() -> Self {
        Self {
            loss: "logistic".into(),
            lam: 1e-4,
        }
    }
}

/// `[solver]` table.
#[derive(Clone, Debug)]
pub struct SolverConfig {
    /// Algorithm preset: ccd, scd, shotgun, thread-greedy, greedy,
    /// coloring, topk, block-shotgun.
    pub algorithm: String,
    pub threads: usize,
    pub max_iters: usize,
    pub max_seconds: f64,
    /// Stop when the objective improves by less than `tol` (relative)
    /// over a log interval. 0 disables.
    pub tol: f64,
    pub seed: u64,
    /// Sec. 4.1 refinement steps applied to accepted proposals.
    pub line_search_steps: usize,
    /// Selection size (0 = algorithm default, e.g. P* for shotgun).
    pub select_size: usize,
    /// TopK accept budget (0 = algorithm default).
    pub accept_k: usize,
    /// Objective/NNZ logging cadence in iterations (0 = auto).
    pub log_every: usize,
    pub coloring_strategy: String,
    pub backend: Backend,
    /// Update-phase z discipline: auto | atomic | buffered |
    /// conflict-free (resolved by the driver; COLORING defaults to
    /// conflict-free under `auto`). See `engine::UpdatePath`.
    pub update_path: String,
    /// Memory budget (MiB) for the buffered update path's dense
    /// per-thread accumulators (`n * threads` doubles); past it,
    /// buffered iterations spill to sparse per-thread maps. See
    /// `engine::EngineConfig::buffer_budget_mb`.
    pub buffer_budget_mb: usize,
    /// Shard count for the sharded execution layer (1 = single engine
    /// pool). See `shard` and `SolverBuilder::shards`.
    pub shards: usize,
    /// Column partitioning strategy for `shards > 1`:
    /// contiguous | round-robin | min-overlap. See
    /// `shard::ShardStrategy`.
    pub shard_strategy: String,
    /// Pin shard pools to NUMA nodes with first-touch replica
    /// allocation (`shard::engine` §NUMA; graceful no-op on
    /// single-node / non-Linux hosts). See `SolverBuilder::numa_pin`.
    pub numa_pin: bool,
    /// Reconcile shard replicas every R rounds (`shard::engine`
    /// §Reconcile cadence; min 1). See `SolverBuilder::reconcile_every`.
    pub reconcile_every: usize,
    /// Adaptive reconcile-cadence ceiling; 0 = fixed cadence. See
    /// `SolverBuilder::reconcile_max_rounds`.
    pub reconcile_max_rounds: usize,
    /// Bounded replica staleness under the adaptive cadence; 0 =
    /// unbounded. See `SolverBuilder::max_staleness_rounds`.
    pub max_staleness_rounds: usize,
    /// Reconcile-barrier timeout in seconds before a missing peer fails
    /// the solve (`shard::engine` §Failure semantics); <= 0 disables.
    /// See `SolverBuilder::barrier_timeout_secs`.
    pub barrier_timeout_secs: f64,
    /// Active-set KKT screening (`screen` module; default off).
    /// Requires lam > 0; validated by the builder.
    pub screening: bool,
    /// Full-set KKT sweep cadence in iterations when screening is on
    /// (the reactivation safety net). See `SolverBuilder::kkt_every`.
    pub kkt_every: usize,
    /// Reactivation-rate-driven sweep cadence (stretch when quiet,
    /// halve on bursts). See `SolverBuilder::kkt_adaptive`.
    pub kkt_adaptive: bool,
    /// Route hot gathers through the unrolled prefetching kernels
    /// (`CscMatrix::dot_col_fast`; off by default so the scalar path
    /// stays the bit-exactness reference).
    pub fast_kernels: bool,
    /// SIMD tier ceiling for the fast kernels:
    /// auto | scalar | avx2 | avx512 (`kernel::KernelChoice`; requested
    /// tiers clamp to what the CPU supports, inert unless
    /// `fast_kernels` is on).
    pub kernel: String,
    /// Reconcile backend for `shards > 1`:
    /// barrier | loopback | tcp. See `net::Transport` and
    /// `SolverBuilder::transport`.
    pub transport: String,
    /// Listen address for `transport = "tcp"` (the coordinator relay
    /// binds here; `:0` picks an ephemeral port).
    pub listen: String,
    /// Comma-separated relay addresses the shard peers dial for
    /// `transport = "tcp"`; empty = everyone dials `listen`'s bound
    /// address (single-process loop-TCP).
    pub peers: String,
    /// Wire value precision: exact (f64, bit-exact with the barrier) |
    /// f32 (half the delta bytes). See `net::WirePrecision`.
    pub wire_precision: String,
    /// Structured event log rendering: text | json (line-JSON, one
    /// event per line — `gencd events --check` validates it). See
    /// `event::LogFormat`.
    pub log_format: String,
    /// Crash-recovery checkpoint path for sharded solves (empty = no
    /// checkpointing). See `SolverBuilder::checkpoint_path` and
    /// `recover::checkpoint`.
    pub checkpoint_path: String,
    /// Reconciled rounds between checkpoint writes. See
    /// `SolverBuilder::checkpoint_every_rounds`.
    pub checkpoint_every_rounds: usize,
    /// Checkpoint to resume from (empty = fresh solve). See
    /// `SolverBuilder::resume_from`.
    pub resume_from: String,
    /// Per-peer TCP redial budget after a disconnect (0 = reconnection
    /// disabled). See `SolverBuilder::reconnect_max_attempts`.
    pub reconnect_max_attempts: usize,
}

impl Default for SolverConfig {
    fn default() -> Self {
        Self {
            algorithm: "shotgun".into(),
            threads: 4,
            max_iters: usize::MAX,
            max_seconds: 30.0,
            tol: 0.0,
            seed: 1,
            line_search_steps: 0,
            select_size: 0,
            accept_k: 0,
            log_every: 0,
            coloring_strategy: "greedy".into(),
            backend: Backend::SparseRust,
            update_path: "auto".into(),
            buffer_budget_mb: 1024,
            shards: 1,
            shard_strategy: "contiguous".into(),
            numa_pin: false,
            reconcile_every: 1,
            reconcile_max_rounds: 0,
            max_staleness_rounds: 0,
            barrier_timeout_secs: 30.0,
            screening: false,
            kkt_every: 16,
            kkt_adaptive: false,
            fast_kernels: false,
            kernel: "auto".into(),
            transport: "barrier".into(),
            listen: "127.0.0.1:0".into(),
            peers: String::new(),
            wire_precision: "exact".into(),
            log_format: "text".into(),
            checkpoint_path: String::new(),
            checkpoint_every_rounds: 16,
            resume_from: String::new(),
            reconnect_max_attempts: 0,
        }
    }
}

/// Full run description.
#[derive(Clone, Debug, Default)]
pub struct RunConfig {
    pub dataset: DatasetConfig,
    pub problem: ProblemConfig,
    pub solver: SolverConfig,
    /// Optional CSV path for the convergence history.
    pub csv: Option<String>,
}

impl RunConfig {
    /// Parse from TOML-subset text.
    pub fn from_toml(text: &str) -> anyhow::Result<Self> {
        let doc = parse(text)?;
        let mut cfg = Self::default();
        cfg.apply_doc(&doc)?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        Self::from_toml(&text)
    }

    fn apply_doc(&mut self, doc: &Document) -> anyhow::Result<()> {
        for (table, kv) in &doc.tables {
            for (key, value) in kv {
                self.set_value(table, key, value)?;
            }
        }
        Ok(())
    }

    /// Apply one `table.key=value` override (CLI `--set`).
    pub fn set(&mut self, dotted: &str, raw: &str) -> anyhow::Result<()> {
        let (table, key) = dotted
            .split_once('.')
            .ok_or_else(|| anyhow::anyhow!("override '{dotted}' must be table.key"))?;
        // parse the raw string through the TOML value grammar; fall back
        // to a bare string for unquoted names.
        let value = toml::parse(&format!("x = {raw}\n"))
            .ok()
            .and_then(|d| d.get("", "x").cloned())
            .unwrap_or_else(|| Value::String(raw.to_string()));
        self.set_value(table, key, &value)
    }

    fn set_value(&mut self, table: &str, key: &str, value: &Value) -> anyhow::Result<()> {
        let bad_type = || anyhow::anyhow!("{table}.{key}: wrong type {value:?}");
        let as_str = |v: &Value| v.as_str().map(str::to_string).ok_or_else(bad_type);
        let as_f64 = |v: &Value| v.as_float().ok_or_else(bad_type);
        let as_usize = |v: &Value| {
            v.as_int()
                .filter(|&i| i >= 0)
                .map(|i| i as usize)
                .ok_or_else(bad_type)
        };
        match (table, key) {
            ("dataset", "name") => self.dataset.name = as_str(value)?,
            ("dataset", "path") => self.dataset.path = Some(as_str(value)?),
            ("dataset", "normalize") => {
                self.dataset.normalize = value.as_bool().ok_or_else(bad_type)?
            }
            ("problem", "loss") => self.problem.loss = as_str(value)?,
            ("problem", "lam") => self.problem.lam = as_f64(value)?,
            ("solver", "algorithm") => self.solver.algorithm = as_str(value)?,
            ("solver", "threads") => self.solver.threads = as_usize(value)?.max(1),
            ("solver", "max_iters") => self.solver.max_iters = as_usize(value)?,
            ("solver", "max_seconds") => self.solver.max_seconds = as_f64(value)?,
            ("solver", "tol") => self.solver.tol = as_f64(value)?,
            ("solver", "seed") => self.solver.seed = as_usize(value)? as u64,
            ("solver", "line_search_steps") => {
                self.solver.line_search_steps = as_usize(value)?
            }
            ("solver", "select_size") => self.solver.select_size = as_usize(value)?,
            ("solver", "accept_k") => self.solver.accept_k = as_usize(value)?,
            ("solver", "log_every") => self.solver.log_every = as_usize(value)?,
            ("solver", "coloring_strategy") => {
                self.solver.coloring_strategy = as_str(value)?
            }
            ("solver", "backend") => {
                self.solver.backend = Backend::by_name(&as_str(value)?)?
            }
            ("solver", "update_path") => self.solver.update_path = as_str(value)?,
            ("solver", "buffer_budget_mb") => {
                self.solver.buffer_budget_mb = as_usize(value)?
            }
            ("solver", "shards") => self.solver.shards = as_usize(value)?.max(1),
            ("solver", "shard_strategy") => {
                self.solver.shard_strategy = as_str(value)?
            }
            ("solver", "numa_pin") => {
                self.solver.numa_pin = value.as_bool().ok_or_else(bad_type)?
            }
            ("solver", "reconcile_every") => {
                self.solver.reconcile_every = as_usize(value)?.max(1)
            }
            ("solver", "reconcile_max_rounds") => {
                self.solver.reconcile_max_rounds = as_usize(value)?
            }
            ("solver", "max_staleness_rounds") => {
                self.solver.max_staleness_rounds = as_usize(value)?
            }
            ("solver", "barrier_timeout_secs") => {
                self.solver.barrier_timeout_secs = as_f64(value)?
            }
            ("solver", "screening") => {
                self.solver.screening = value.as_bool().ok_or_else(bad_type)?
            }
            ("solver", "kkt_every") => self.solver.kkt_every = as_usize(value)?,
            ("solver", "kkt_adaptive") => {
                self.solver.kkt_adaptive = value.as_bool().ok_or_else(bad_type)?
            }
            ("solver", "fast_kernels") => {
                self.solver.fast_kernels = value.as_bool().ok_or_else(bad_type)?
            }
            ("solver", "kernel") => self.solver.kernel = as_str(value)?,
            ("solver", "transport") => self.solver.transport = as_str(value)?,
            ("solver", "listen") => self.solver.listen = as_str(value)?,
            ("solver", "peers") => self.solver.peers = as_str(value)?,
            ("solver", "wire_precision") => {
                self.solver.wire_precision = as_str(value)?
            }
            ("solver", "checkpoint_path") => self.solver.checkpoint_path = as_str(value)?,
            ("solver", "checkpoint_every_rounds") => {
                self.solver.checkpoint_every_rounds = as_usize(value)?
            }
            ("solver", "resume_from") => self.solver.resume_from = as_str(value)?,
            ("solver", "reconnect_max_attempts") => {
                self.solver.reconnect_max_attempts = as_usize(value)?
            }
            ("solver", "log_format") => self.solver.log_format = as_str(value)?,
            ("output", "csv") => self.csv = Some(as_str(value)?),
            ("", _) => anyhow::bail!("top-level key '{key}' not recognized"),
            _ => anyhow::bail!("unknown config key {table}.{key}"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_then_file_then_override() {
        let mut cfg = RunConfig::from_toml(
            r#"
            [dataset]
            name = "reuters@0.05"
            [problem]
            loss = "logistic"
            lam = 1e-5
            [solver]
            algorithm = "coloring"
            threads = 8
            max_seconds = 2.5
            "#,
        )
        .unwrap();
        assert_eq!(cfg.dataset.name, "reuters@0.05");
        assert_eq!(cfg.problem.lam, 1e-5);
        assert_eq!(cfg.solver.algorithm, "coloring");
        assert_eq!(cfg.solver.threads, 8);
        // defaults survive for unset fields
        assert!(cfg.dataset.normalize);
        cfg.set("solver.threads", "2").unwrap();
        cfg.set("solver.algorithm", "\"shotgun\"").unwrap();
        cfg.set("solver.backend", "hlo").unwrap();
        assert_eq!(cfg.solver.threads, 2);
        assert_eq!(cfg.solver.algorithm, "shotgun");
        assert_eq!(cfg.solver.backend, Backend::DenseBlockHlo);
        // update path: default, TOML, and --set override
        assert_eq!(cfg.solver.update_path, "auto");
        let cfg2 = RunConfig::from_toml("[solver]\nupdate_path = \"buffered\"\n").unwrap();
        assert_eq!(cfg2.solver.update_path, "buffered");
        cfg.set("solver.update_path", "conflict-free").unwrap();
        assert_eq!(cfg.solver.update_path, "conflict-free");
        // buffer budget: default, TOML, and --set override
        assert_eq!(cfg.solver.buffer_budget_mb, 1024);
        let cfg3 = RunConfig::from_toml("[solver]\nbuffer_budget_mb = 64\n").unwrap();
        assert_eq!(cfg3.solver.buffer_budget_mb, 64);
        cfg.set("solver.buffer_budget_mb", "0").unwrap();
        assert_eq!(cfg.solver.buffer_budget_mb, 0);
        // sharding knobs: defaults, TOML, and --set override
        assert_eq!(cfg.solver.shards, 1);
        assert_eq!(cfg.solver.shard_strategy, "contiguous");
        let cfg4 = RunConfig::from_toml(
            "[solver]\nshards = 4\nshard_strategy = \"min-overlap\"\n",
        )
        .unwrap();
        assert_eq!(cfg4.solver.shards, 4);
        assert_eq!(cfg4.solver.shard_strategy, "min-overlap");
        cfg.set("solver.shards", "2").unwrap();
        cfg.set("solver.shard_strategy", "round-robin").unwrap();
        assert_eq!(cfg.solver.shards, 2);
        assert_eq!(cfg.solver.shard_strategy, "round-robin");
        // shards = 0 clamps to 1 (like threads)
        cfg.set("solver.shards", "0").unwrap();
        assert_eq!(cfg.solver.shards, 1);
        // screening knobs: defaults, TOML, and --set override
        assert!(!cfg.solver.screening);
        assert_eq!(cfg.solver.kkt_every, 16);
        assert!(!cfg.solver.fast_kernels);
        let cfg5 = RunConfig::from_toml(
            "[solver]\nscreening = true\nkkt_every = 8\nfast_kernels = true\n",
        )
        .unwrap();
        assert!(cfg5.solver.screening);
        assert_eq!(cfg5.solver.kkt_every, 8);
        assert!(cfg5.solver.fast_kernels);
        // kernel tier: default, TOML, and --set override
        assert_eq!(cfg.solver.kernel, "auto");
        let cfg5b = RunConfig::from_toml("[solver]\nkernel = \"avx2\"\n").unwrap();
        assert_eq!(cfg5b.solver.kernel, "avx2");
        cfg.set("solver.kernel", "scalar").unwrap();
        assert_eq!(cfg.solver.kernel, "scalar");
        cfg.set("solver.screening", "true").unwrap();
        cfg.set("solver.kkt_every", "32").unwrap();
        assert!(cfg.solver.screening);
        assert_eq!(cfg.solver.kkt_every, 32);
        assert!(RunConfig::from_toml("[solver]\nscreening = 3\n").is_err());
        // NUMA / reconcile-cadence / adaptive-kkt knobs: defaults,
        // TOML, and --set override
        assert!(!cfg.solver.numa_pin);
        assert_eq!(cfg.solver.reconcile_every, 1);
        assert_eq!(cfg.solver.reconcile_max_rounds, 0);
        assert!(!cfg.solver.kkt_adaptive);
        let cfg6 = RunConfig::from_toml(
            "[solver]\nnuma_pin = true\nreconcile_every = 2\n\
             reconcile_max_rounds = 32\nkkt_adaptive = true\n",
        )
        .unwrap();
        assert!(cfg6.solver.numa_pin);
        assert_eq!(cfg6.solver.reconcile_every, 2);
        assert_eq!(cfg6.solver.reconcile_max_rounds, 32);
        assert!(cfg6.solver.kkt_adaptive);
        cfg.set("solver.numa_pin", "true").unwrap();
        cfg.set("solver.reconcile_every", "0").unwrap(); // clamps like threads
        cfg.set("solver.reconcile_max_rounds", "8").unwrap();
        cfg.set("solver.kkt_adaptive", "true").unwrap();
        assert!(cfg.solver.numa_pin);
        assert_eq!(cfg.solver.reconcile_every, 1);
        assert_eq!(cfg.solver.reconcile_max_rounds, 8);
        assert!(cfg.solver.kkt_adaptive);
        assert!(RunConfig::from_toml("[solver]\nnuma_pin = 2\n").is_err());
        // hardening knobs: defaults, TOML, and --set override
        assert_eq!(cfg.solver.max_staleness_rounds, 0);
        assert_eq!(cfg.solver.barrier_timeout_secs, 30.0);
        let cfg7 = RunConfig::from_toml(
            "[solver]\nmax_staleness_rounds = 6\nbarrier_timeout_secs = 1.5\n",
        )
        .unwrap();
        assert_eq!(cfg7.solver.max_staleness_rounds, 6);
        assert_eq!(cfg7.solver.barrier_timeout_secs, 1.5);
        cfg.set("solver.max_staleness_rounds", "12").unwrap();
        cfg.set("solver.barrier_timeout_secs", "0.25").unwrap();
        assert_eq!(cfg.solver.max_staleness_rounds, 12);
        assert_eq!(cfg.solver.barrier_timeout_secs, 0.25);
        assert!(RunConfig::from_toml("[solver]\nmax_staleness_rounds = -3\n").is_err());
        // wire-transport knobs: defaults, TOML, and --set override
        assert_eq!(cfg.solver.transport, "barrier");
        assert_eq!(cfg.solver.listen, "127.0.0.1:0");
        assert_eq!(cfg.solver.peers, "");
        assert_eq!(cfg.solver.wire_precision, "exact");
        let cfg8 = RunConfig::from_toml(
            "[solver]\ntransport = \"tcp\"\nlisten = \"0.0.0.0:7070\"\n\
             peers = \"10.0.0.1:7070,10.0.0.2:7070\"\nwire_precision = \"f32\"\n",
        )
        .unwrap();
        assert_eq!(cfg8.solver.transport, "tcp");
        assert_eq!(cfg8.solver.listen, "0.0.0.0:7070");
        assert_eq!(cfg8.solver.peers, "10.0.0.1:7070,10.0.0.2:7070");
        assert_eq!(cfg8.solver.wire_precision, "f32");
        cfg.set("solver.transport", "loopback").unwrap();
        cfg.set("solver.wire_precision", "f32").unwrap();
        assert_eq!(cfg.solver.transport, "loopback");
        assert_eq!(cfg.solver.wire_precision, "f32");
        assert!(RunConfig::from_toml("[solver]\ntransport = 5\n").is_err());
        // recovery knobs: defaults, TOML, and --set override
        assert_eq!(cfg.solver.checkpoint_path, "");
        assert_eq!(cfg.solver.checkpoint_every_rounds, 16);
        assert_eq!(cfg.solver.resume_from, "");
        assert_eq!(cfg.solver.reconnect_max_attempts, 0);
        let cfg9 = RunConfig::from_toml(
            "[solver]\ncheckpoint_path = \"/tmp/ck.bin\"\ncheckpoint_every_rounds = 8\n\
             resume_from = \"/tmp/ck.bin\"\nreconnect_max_attempts = 5\n",
        )
        .unwrap();
        assert_eq!(cfg9.solver.checkpoint_path, "/tmp/ck.bin");
        assert_eq!(cfg9.solver.checkpoint_every_rounds, 8);
        assert_eq!(cfg9.solver.resume_from, "/tmp/ck.bin");
        assert_eq!(cfg9.solver.reconnect_max_attempts, 5);
        cfg.set("solver.reconnect_max_attempts", "3").unwrap();
        assert_eq!(cfg.solver.reconnect_max_attempts, 3);
        assert!(RunConfig::from_toml("[solver]\nreconnect_max_attempts = -1\n").is_err());
    }

    #[test]
    fn rejects_unknown_keys_and_types() {
        assert!(RunConfig::from_toml("[solver]\nwhat = 1\n").is_err());
        assert!(RunConfig::from_toml("[solver]\nthreads = \"four\"\n").is_err());
        assert!(RunConfig::from_toml("[solver]\nthreads = -2\n").is_err());
        assert!(RunConfig::from_toml("stray = 1\n").is_err());
    }

    #[test]
    fn bare_string_override() {
        let mut cfg = RunConfig::default();
        cfg.set("dataset.name", "dorothea@0.2").unwrap();
        assert_eq!(cfg.dataset.name, "dorothea@0.2");
        assert!(cfg.set("nodot", "x").is_err());
    }

    #[test]
    fn backend_names() {
        assert_eq!(Backend::by_name("sparse").unwrap(), Backend::SparseRust);
        assert_eq!(Backend::by_name("hlo").unwrap(), Backend::DenseBlockHlo);
        assert!(Backend::by_name("gpu").is_err());
        assert_eq!(Backend::SparseRust.name(), "sparse");
    }
}
