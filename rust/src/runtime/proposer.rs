//! The DenseBlockHlo Propose backend: GenCD's Propose step executed by
//! the AOT-compiled JAX/Pallas artifact instead of the sparse Rust loop
//! (DESIGN.md §2).
//!
//! Per selected block of up to `b` coordinates, the leader gathers the
//! columns into a dense `n_pad x b` panel, invokes the compiled
//! `propose` module — which fuses `ell'(y, z)`, the panel mat-vec
//! (Pallas MXU kernel) and the Eq. 7/9 epilogue — and scatters
//! `delta`/`phi` back into the shared state. Numerics are f32 inside the
//! artifact and f64 in the solver; the integration test bounds the
//! difference against the sparse path.

use super::client::{Executable, Runtime};
use crate::coordinator::engine::BlockProposer;
use crate::coordinator::problem::{Problem, SharedState};

/// BlockProposer running the AOT `propose` artifact. Holds prebuilt
/// padded `y`/`mask` buffers and scratch space; construction validates
/// the column-normalization assumption the scalar `beta` encodes.
pub struct HloProposer {
    exe: Executable,
    n_real: usize,
    n_pad: usize,
    b: usize,
    /// [lam, beta_eff, inv_n] — runtime scalars of the artifact.
    scalars: [f32; 3],
    y_pad: Vec<f32>,
    mask: Vec<f32>,
    // scratch (reused across calls; propose_block is leader-only)
    panel: Vec<f32>,
    z_pad: Vec<f32>,
    w_blk: Vec<f32>,
    /// Executions performed (perf accounting).
    pub calls: u64,
}

impl HloProposer {
    /// Build from a runtime + problem. Fails when no artifact variant
    /// fits the sample count or when columns are not unit-normalized
    /// (the artifact's scalar `beta` assumes `||X_j|| = 1`; see
    /// `Problem::beta_j`).
    pub fn new(rt: &Runtime, problem: &Problem) -> anyhow::Result<Self> {
        let n_real = problem.n_samples();
        let loss = problem.loss.name();
        let exe = rt.compile_kind("propose", loss, n_real)?;
        let (n_pad, b) = (exe.entry.n, exe.entry.b);

        for (j, &sq) in problem.col_sq_norms.iter().enumerate() {
            anyhow::ensure!(
                sq == 0.0 || (sq - 1.0).abs() < 1e-6,
                "HLO propose backend requires unit-normalized columns \
                 (column {j} has ||X_j||^2 = {sq}); set dataset.normalize = true"
            );
        }

        let mut y_pad = vec![1.0f32; n_pad]; // padded labels: any finite value
        for (i, &yi) in problem.y.iter().enumerate() {
            y_pad[i] = yi as f32;
        }
        let mut mask = vec![0.0f32; n_pad];
        mask[..n_real].fill(1.0);

        let beta_eff = problem.loss.beta() / n_real as f64;
        Ok(Self {
            exe,
            n_real,
            n_pad,
            b,
            scalars: [
                problem.lam as f32,
                beta_eff as f32,
                (1.0 / n_real as f64) as f32,
            ],
            y_pad,
            mask,
            panel: vec![0.0; n_pad * b],
            z_pad: vec![0.0; n_pad],
            w_blk: vec![0.0; b],
            calls: 0,
        })
    }

    /// Padded sample count of the bound artifact.
    pub fn n_pad(&self) -> usize {
        self.n_pad
    }

    /// Panel width of the bound artifact.
    pub fn block_width(&self) -> usize {
        self.b
    }

    /// Run one block (<= b coordinates); returns (g, delta, phi) rows
    /// for exactly `js.len()` coordinates.
    pub fn run_block(
        &mut self,
        problem: &Problem,
        state: &SharedState,
        js: &[u32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(js.len() <= self.b, "block too wide: {}", js.len());
        // gather panel (row-major: XLA literal layout for f32[n, b])
        self.panel.fill(0.0);
        for (col, &j) in js.iter().enumerate() {
            let (rows, vals) = problem.x.col(j as usize);
            for (&i, &v) in rows.iter().zip(vals) {
                self.panel[i as usize * self.b + col] = v as f32;
            }
        }
        // snapshot z (padded region stays 0; mask kills its dloss).
        // Plain reads: propose_block runs on the leader while workers
        // are parked at a barrier (see BlockProposer's contract).
        for i in 0..self.n_real {
            self.z_pad[i] = state.z.get(i) as f32;
        }
        self.w_blk.fill(0.0);
        for (col, &j) in js.iter().enumerate() {
            self.w_blk[col] = state.w.get(j as usize) as f32;
        }
        let outs = self.exe.run_f32(&[
            &self.panel,
            &self.y_pad,
            &self.z_pad,
            &self.mask,
            &self.w_blk,
            &self.scalars,
        ])?;
        self.calls += 1;
        let take = |v: &Vec<f32>| v[..js.len()].to_vec();
        Ok((take(&outs[0]), take(&outs[1]), take(&outs[2])))
    }
}

impl BlockProposer for HloProposer {
    fn propose_block(
        &mut self,
        problem: &Problem,
        state: &SharedState,
        selected: &[u32],
    ) -> anyhow::Result<()> {
        let width = self.b;
        for blk in selected.chunks(width) {
            let (_, delta, phi) = self.run_block(problem, state, blk)?;
            for (col, &j) in blk.iter().enumerate() {
                state.delta.set(j as usize, delta[col] as f64);
                state.phi.set(j as usize, phi[col] as f64);
            }
        }
        Ok(())
    }

    fn name(&self) -> &str {
        "dense-block-hlo"
    }
}

/// Objective evaluation via the AOT `objective` artifact: `F(w)` from
/// fitted values (the l1 term is added on the Rust side).
pub struct HloObjective {
    exe: Executable,
    n_real: usize,
    n_pad: usize,
    scalars: [f32; 3],
    y_pad: Vec<f32>,
    mask: Vec<f32>,
    z_pad: Vec<f32>,
}

impl HloObjective {
    pub fn new(rt: &Runtime, problem: &Problem) -> anyhow::Result<Self> {
        let n_real = problem.n_samples();
        let exe = rt.compile_kind("objective", problem.loss.name(), n_real)?;
        let n_pad = exe.entry.n;
        let mut y_pad = vec![1.0f32; n_pad];
        for (i, &yi) in problem.y.iter().enumerate() {
            y_pad[i] = yi as f32;
        }
        let mut mask = vec![0.0f32; n_pad];
        mask[..n_real].fill(1.0);
        Ok(Self {
            exe,
            n_real,
            n_pad,
            scalars: [0.0, 0.0, (1.0 / n_real as f64) as f32],
            y_pad,
            mask,
            z_pad: vec![0.0; n_pad],
        })
    }

    /// Smooth part `F(w)` from fitted values `z` (length = real n).
    pub fn smooth(&mut self, z: &[f64]) -> anyhow::Result<f64> {
        anyhow::ensure!(z.len() == self.n_real, "z length");
        for i in 0..self.n_real {
            self.z_pad[i] = z[i] as f32;
        }
        for v in &mut self.z_pad[self.n_real..self.n_pad] {
            *v = 0.0;
        }
        let outs = self
            .exe
            .run_f32(&[&self.y_pad, &self.z_pad, &self.mask, &self.scalars])?;
        Ok(outs[0][0] as f64)
    }
}
