//! PJRT client wrapper: load an HLO-text artifact, compile it once,
//! execute it many times from the solve path.
//!
//! Mirrors /opt/xla-example/load_hlo: the interchange format is HLO
//! *text* (`HloModuleProto::from_text_file`) because serialized
//! jax >= 0.5 protos carry 64-bit instruction ids that this XLA rejects.

use std::path::Path;

use super::manifest::{Entry, Manifest};

/// Stub standing in for the `xla` PJRT bindings when gencd is built
/// without the `pjrt` cargo feature (the default, fully-offline build —
/// see Cargo.toml). The client constructs and the manifest loads, but
/// compiling any artifact reports the missing backend, so the HLO
/// integration tests skip cleanly and every sparse-path workload is
/// unaffected. Enable the feature (and supply the real `xla` crate, see
/// Cargo.toml) to execute the AOT artifacts.
#[cfg(not(feature = "pjrt"))]
mod xla {
    const UNAVAILABLE: &str =
        "gencd was built without the `pjrt` feature; the PJRT/XLA runtime is unavailable";

    pub struct Error(pub String);

    impl std::fmt::Debug for Error {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "{}", self.0)
        }
    }

    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<Self, Error> {
            Ok(PjRtClient)
        }

        pub fn platform_name(&self) -> String {
            "stub (no pjrt feature)".to_string()
        }

        pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
            Err(Error(UNAVAILABLE.to_string()))
        }
    }

    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file(_path: &str) -> Result<Self, Error> {
            Err(Error(UNAVAILABLE.to_string()))
        }
    }

    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_p: &HloModuleProto) -> Self {
            XlaComputation
        }
    }

    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<Buffer>>, Error> {
            Err(Error(UNAVAILABLE.to_string()))
        }
    }

    pub struct Buffer;

    impl Buffer {
        pub fn to_literal_sync(&self) -> Result<Literal, Error> {
            Err(Error(UNAVAILABLE.to_string()))
        }
    }

    pub struct Literal;

    impl Literal {
        pub fn vec1(_data: &[f32]) -> Literal {
            Literal
        }

        pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
            Ok(Literal)
        }

        pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
            Err(Error(UNAVAILABLE.to_string()))
        }

        pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
            Err(Error(UNAVAILABLE.to_string()))
        }
    }
}

/// A PJRT CPU session. One per process is plenty; executables borrow it.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
}

impl Runtime {
    /// Create a CPU client and load the manifest from `dir`.
    pub fn new(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, manifest })
    }

    /// Load + manifest from the default artifacts directory.
    pub fn from_default_dir() -> anyhow::Result<Self> {
        Self::new(Manifest::default_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one manifest entry into an executable.
    pub fn compile(&self, entry: &Entry) -> anyhow::Result<Executable> {
        let path = self.manifest.path_of(entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path {}", path.display()))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))?;
        Ok(Executable {
            exe,
            entry: entry.clone(),
        })
    }

    /// Convenience: find + compile.
    pub fn compile_kind(
        &self,
        kind: &str,
        loss: &str,
        n_real: usize,
    ) -> anyhow::Result<Executable> {
        let entry = self.manifest.find(kind, loss, n_real)?.clone();
        self.compile(&entry)
    }
}

/// A compiled artifact plus its manifest entry (shapes).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub entry: Entry,
}

impl Executable {
    /// Execute with f32 inputs matching the manifest's `input_shapes`.
    /// Returns the flattened f32 outputs in manifest order.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            inputs.len() == self.entry.input_shapes.len(),
            "{}: expected {} inputs, got {}",
            self.entry.file,
            self.entry.input_shapes.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs.iter().zip(&self.entry.input_shapes) {
            let numel: usize = shape.iter().product();
            anyhow::ensure!(
                data.len() == numel,
                "{}: input length {} != shape {:?}",
                self.entry.file,
                data.len(),
                shape
            );
            let lit = xla::Literal::vec1(data);
            let lit = if shape.len() == 1 {
                lit
            } else {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims)
                    .map_err(|e| anyhow::anyhow!("reshape {shape:?}: {e:?}"))?
            };
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.entry.file))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {}: {e:?}", self.entry.file))?;
        // lowered with return_tuple=True: always a tuple
        let parts = out
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {}: {e:?}", self.entry.file))?;
        anyhow::ensure!(
            parts.len() == self.entry.outputs.len(),
            "{}: expected {} outputs, got {}",
            self.entry.file,
            self.entry.outputs.len(),
            parts.len()
        );
        parts
            .into_iter()
            .map(|p| {
                p.to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("read output: {e:?}"))
            })
            .collect()
    }
}
