//! The AOT artifact manifest: what `python/compile/aot.py` produced and
//! how to call it. Source of truth for shapes — the Rust side never
//! guesses padding.

use std::path::{Path, PathBuf};

use crate::util::json::{parse, Json};

/// One lowered HLO module.
#[derive(Clone, Debug)]
pub struct Entry {
    pub variant: String,
    /// `propose` | `objective` | `linesearch`.
    pub kind: String,
    pub loss: String,
    /// Padded sample count baked into the module.
    pub n: usize,
    /// Panel width baked into the module.
    pub b: usize,
    /// File name inside the artifacts directory.
    pub file: String,
    pub inputs: Vec<String>,
    pub input_shapes: Vec<Vec<usize>>,
    pub outputs: Vec<String>,
    /// Line-search step count (linesearch kind only).
    pub ls_steps: Option<usize>,
}

/// Parsed manifest + its directory (file paths resolve against it).
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<Entry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let doc = parse(&text)?;
        let format = doc
            .get("format")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("manifest missing format"))?;
        anyhow::ensure!(format == 1, "unsupported manifest format {format}");

        let scalars: Vec<&str> = doc
            .get("scalars")
            .and_then(Json::as_array)
            .map(|a| a.iter().filter_map(Json::as_str).collect())
            .unwrap_or_default();
        anyhow::ensure!(
            scalars == ["lam", "beta", "inv_n"],
            "unexpected scalar layout {scalars:?} (rust expects [lam, beta, inv_n])"
        );

        let mut entries = Vec::new();
        for e in doc
            .get("entries")
            .and_then(Json::as_array)
            .ok_or_else(|| anyhow::anyhow!("manifest missing entries"))?
        {
            let get_str = |k: &str| -> anyhow::Result<String> {
                Ok(e.get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("entry missing {k}"))?
                    .to_string())
            };
            let get_usize = |k: &str| -> anyhow::Result<usize> {
                e.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("entry missing {k}"))
            };
            let strings = |k: &str| -> Vec<String> {
                e.get(k)
                    .and_then(Json::as_array)
                    .map(|a| {
                        a.iter()
                            .filter_map(Json::as_str)
                            .map(str::to_string)
                            .collect()
                    })
                    .unwrap_or_default()
            };
            let input_shapes = e
                .get("input_shapes")
                .and_then(Json::as_array)
                .map(|a| {
                    a.iter()
                        .map(|s| {
                            s.as_array()
                                .map(|d| d.iter().filter_map(Json::as_usize).collect())
                                .unwrap_or_default()
                        })
                        .collect()
                })
                .unwrap_or_default();
            entries.push(Entry {
                variant: get_str("variant")?,
                kind: get_str("kind")?,
                loss: get_str("loss")?,
                n: get_usize("n")?,
                b: get_usize("b")?,
                file: get_str("file")?,
                inputs: strings("inputs"),
                input_shapes,
                outputs: strings("outputs"),
                ls_steps: e.get("ls_steps").and_then(Json::as_usize),
            });
        }
        Ok(Self { dir, entries })
    }

    /// Default artifacts directory: `$GENCD_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("GENCD_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Find the entry of `kind`/`loss` with the smallest padded `n`
    /// that fits `n_real` samples. Among equal `n`, prefers the widest
    /// panel and the deepest line search (the "production" variant over
    /// the small test one).
    pub fn find(&self, kind: &str, loss: &str, n_real: usize) -> anyhow::Result<&Entry> {
        self.entries
            .iter()
            .filter(|e| e.kind == kind && e.loss == loss && e.n >= n_real)
            .min_by_key(|e| {
                (
                    e.n,
                    std::cmp::Reverse(e.b),
                    std::cmp::Reverse(e.ls_steps.unwrap_or(0)),
                )
            })
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no {kind}/{loss} artifact with n >= {n_real} in {} \
                     (run `make artifacts`, or add a variant in python/compile/aot.py)",
                    self.dir.display()
                )
            })
    }

    /// Absolute path of an entry's HLO text.
    pub fn path_of(&self, e: &Entry) -> PathBuf {
        self.dir.join(&e.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "format": 1,
              "scalars": ["lam", "beta", "inv_n"],
              "entries": [
               {"variant": "t", "kind": "propose", "loss": "logistic",
                "n": 1024, "b": 16, "file": "a.hlo.txt",
                "inputs": ["x_panel","y","z","mask","w","scalars"],
                "input_shapes": [[1024,16],[1024],[1024],[1024],[16],[3]],
                "outputs": ["g","delta","phi"]},
               {"variant": "r", "kind": "propose", "loss": "logistic",
                "n": 24576, "b": 64, "file": "b.hlo.txt",
                "inputs": [], "input_shapes": [], "outputs": []},
               {"variant": "t", "kind": "linesearch", "loss": "logistic",
                "n": 1024, "b": 16, "file": "c.hlo.txt",
                "inputs": [], "input_shapes": [], "outputs": [],
                "ls_steps": 8}
              ]
            }"#,
        )
        .unwrap();
    }

    #[test]
    fn load_and_find() {
        let dir = std::env::temp_dir().join("gencd_manifest_test");
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 3);
        // picks the smallest fitting n
        assert_eq!(m.find("propose", "logistic", 800).unwrap().n, 1024);
        assert_eq!(m.find("propose", "logistic", 2000).unwrap().n, 24576);
        assert!(m.find("propose", "logistic", 99999).is_err());
        assert!(m.find("propose", "squared", 100).is_err());
        assert_eq!(
            m.find("linesearch", "logistic", 100).unwrap().ls_steps,
            Some(8)
        );
        let e = m.find("propose", "logistic", 800).unwrap();
        assert!(m.path_of(e).ends_with("a.hlo.txt"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn real_manifest_if_built() {
        // integration: the repo's own artifacts (skipped when absent)
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: {} not built", dir.display());
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.find("propose", "logistic", 800).is_ok());
        for e in &m.entries {
            assert!(m.path_of(e).exists(), "missing {}", e.file);
            assert_eq!(*e.input_shapes.last().unwrap(), vec![3]);
        }
    }
}
