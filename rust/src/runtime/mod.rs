//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` + the
//! manifest) built by `make artifacts` and executes them from the Rust
//! solve path. Python never runs here — the artifacts are the only
//! contract between the layers (DESIGN.md §2).

pub mod client;
pub mod manifest;
pub mod proposer;

pub use client::{Executable, Runtime};
pub use manifest::{Entry, Manifest};
pub use proposer::{HloObjective, HloProposer};
