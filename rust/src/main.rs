//! `gencd` — the GenCD launcher.
//!
//! Subcommands:
//!   train     run one experiment from a config file / CLI overrides
//!   datagen   generate a synthetic dataset twin and write it to disk
//!   color     run the coloring preprocessing and print statistics
//!   spectral  estimate rho(X^T X) and Shotgun's P*
//!   table3    regenerate the paper's Table 3
//!   fig1      regenerate Figure 1 (convergence, 4 algorithms)
//!   fig2      regenerate Figure 2 (scalability, measured + simulated)
//!   events    validate a line-JSON event log (--log-format json)
//!   artifacts inspect the AOT artifact manifest and smoke-run one
//!
//! Examples:
//!   gencd train --dataset reuters@0.1 --algorithm coloring --seconds 10
//!   gencd train --config configs/dorothea.toml --set solver.threads=8
//!   gencd table3 --scale 0.1

use gencd::cli::Args;
use gencd::coloring::{color_features, Strategy};
use gencd::config::RunConfig;
use gencd::coordinator::driver;
use gencd::linalg::{shotgun_pstar, spectral_radius_xtx};
use gencd::sparse::io as sio;
use gencd::util::Timer;

fn main() {
    let mut args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&mut args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn run(args: &mut Args) -> anyhow::Result<()> {
    match args.subcommand.as_str() {
        "train" => cmd_train(args),
        "path" => cmd_path(args),
        "eval" => cmd_eval(args),
        "datagen" => cmd_datagen(args),
        "color" => cmd_color(args),
        "spectral" => cmd_spectral(args),
        "table3" => cmd_table3(args),
        "fig1" => cmd_fig1(args),
        "fig2" => cmd_fig2(args),
        "shards" => cmd_shards(args),
        "screen" => cmd_screen(args),
        "numa" => cmd_numa(args),
        "sim" => cmd_sim(args),
        "net" => cmd_net(args),
        "harness" => cmd_harness(args),
        "events" => cmd_events(args),
        "artifacts" => cmd_artifacts(args),
        "" | "help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}' (try `gencd help`)"),
    }
}

const HELP: &str = "\
gencd — GenCD parallel coordinate descent (Scherrer et al., ICML 2012)

USAGE: gencd <subcommand> [flags]

SUBCOMMANDS
  train      --config FILE | --dataset NAME --algorithm ALG [--lam X]
             [--threads N] [--seconds S] [--line-search N] [--csv FILE]
             [--update-path auto|atomic|buffered|conflict-free|blocked]
             [--shards N] [--shard-strategy contiguous|round-robin|min-overlap]
             [--numa-pin] [--reconcile-every N] [--reconcile-max-rounds N]
             [--max-staleness-rounds N] [--barrier-timeout S]
             [--transport barrier|loopback|tcp] [--listen ADDR]
             [--peers ADDR,ADDR,...] [--wire-precision exact|f32]
             [--checkpoint PATH] [--checkpoint-every N] [--resume PATH]
             [--reconnect-attempts N]   (crash recovery; sharded solves)
             [--screening] [--kkt-every N] [--kkt-adaptive] [--fast-kernels]
             [--kernel auto|scalar|avx2|avx512]  (SIMD tier ceiling)
             [--log-format text|json]     (json: line-JSON event stream)
             [--set table.key=value]...   (e.g. solver.buffer_budget_mb=512)
  path       --dataset NAME [--algorithm ALG] [--points N] [--min-ratio F]
             [--seconds S] [--threads N]     (warm-started lambda path)
  eval       --dataset NAME [--test-frac F] [--model FILE | train flags]
             [--save FILE]                   (train/test split + metrics)
  datagen    NAME --out FILE[.bin|.libsvm] [--scale F] [--seed N]
  color      --dataset NAME [--strategy greedy|balanced|largest-first]
  spectral   --dataset NAME [--iters N]
  table3     [--scale F] [--seconds S]     (paper Table 3)
  fig1       [--scale F] [--seconds S]     (paper Figure 1)
  fig2       [--scale F] [--seconds S] [--threads-list 1,2,4,...]
  shards     [--scale F] [--seconds S] [--shards-list 1,2,4] [--threads N]
             (sharded-layer scaling: per-shard replicas vs one pool)
  screen     [--scale F] [--seconds S] [--threads N]
             (screening on/off A-B: active set, KKT passes, saved work)
  numa       [--scale F] [--seconds S] [--shards N] [--threads N]
             (NUMA A/B: pinned vs unpinned pools, fixed vs adaptive
              reconcile cadence, dirty-chunk fold fraction)
  sim        [--dir PATH] [--filter SUBSTR] [--events]
             (replay the deterministic fault-injection scenario corpus
              [default scenarios/]; nonzero exit if any scenario fails)
  net        [--shards N] [--threads N] [--scale F] [--seconds S]
             (barrier vs loopback-wire A/B: objective parity, codec
              time, wire bytes)
             --corpus [--dir PATH] [--filter SUBSTR]
             (replay the scenario corpus — including scenarios/net —
              over the loopback wire transport; nonzero exit on FAIL)
             --smoke   (2-shard localhost-TCP solve; asserts clean
              convergence and shutdown)
  harness    --smoke | --plan DIR [--filter SUBSTR]
             (multi-process crash drills over real localhost TCP:
              kill -9 mid-solve + --resume bit-parity, proxy-severed
              connections + reconnect; nonzero exit on any FAIL)
             --worker --out FILE [--seed N] [--rounds N] [--shards N]
             [--pace-ms N] [--listen ADDR] [--peers A,B]
             [--checkpoint PATH] [--checkpoint-every N] [--resume PATH]
             [--reconnect-attempts N]   (one drill worker; spawned by
              the parent, usable standalone for debugging)
             --proxy --listen ADDR --target ADDR
             [--sever-after-bytes N] [--heal-after-ms N]
  events     --check FILE   (validate a `--log-format json` event log:
              well-formed line-JSON, required keys, kind coverage;
              nonzero exit on any malformed line)
  artifacts  [--dir PATH] [--smoke]

Datasets: dorothea, reuters, optionally suffixed @scale (reuters@0.1),
or any libsvm/binary file via --set dataset.path=FILE.
Algorithms: ccd scd shotgun thread-greedy greedy coloring topk block-shotgun
";

/// Build a RunConfig from --config + shortcut flags + --set overrides.
fn config_from_args(args: &mut Args) -> anyhow::Result<RunConfig> {
    let mut cfg = match args.value("config") {
        Some(path) => RunConfig::from_file(&path)?,
        None => RunConfig::default(),
    };
    if let Some(v) = args.value("dataset") {
        cfg.dataset.name = v;
    }
    if let Some(v) = args.value("algorithm") {
        cfg.solver.algorithm = v;
    }
    if let Some(v) = args.value("lam") {
        cfg.problem.lam = v.parse()?;
    }
    if let Some(v) = args.value("loss") {
        cfg.problem.loss = v;
    }
    if let Some(v) = args.value("threads") {
        cfg.solver.threads = v.parse()?;
    }
    if let Some(v) = args.value("seconds") {
        cfg.solver.max_seconds = v.parse()?;
    }
    if let Some(v) = args.value("iters") {
        cfg.solver.max_iters = v.parse()?;
    }
    if let Some(v) = args.value("line-search") {
        cfg.solver.line_search_steps = v.parse()?;
    }
    if let Some(v) = args.value("seed") {
        cfg.solver.seed = v.parse()?;
    }
    if let Some(v) = args.value("update-path") {
        cfg.solver.update_path = v;
    }
    if let Some(v) = args.value("shards") {
        cfg.solver.shards = v.parse::<usize>()?.max(1);
    }
    if let Some(v) = args.value("shard-strategy") {
        cfg.solver.shard_strategy = v;
    }
    if args.flag("numa-pin") {
        cfg.solver.numa_pin = true;
    }
    if let Some(v) = args.value("reconcile-every") {
        cfg.solver.reconcile_every = v.parse::<usize>()?.max(1);
    }
    if let Some(v) = args.value("reconcile-max-rounds") {
        cfg.solver.reconcile_max_rounds = v.parse()?;
    }
    if let Some(v) = args.value("max-staleness-rounds") {
        cfg.solver.max_staleness_rounds = v.parse()?;
    }
    if let Some(v) = args.value("barrier-timeout") {
        cfg.solver.barrier_timeout_secs = v.parse()?;
    }
    if let Some(v) = args.value("transport") {
        cfg.solver.transport = v;
    }
    if let Some(v) = args.value("listen") {
        cfg.solver.listen = v;
    }
    if let Some(v) = args.value("peers") {
        cfg.solver.peers = v;
    }
    if let Some(v) = args.value("wire-precision") {
        cfg.solver.wire_precision = v;
    }
    if let Some(v) = args.value("checkpoint") {
        cfg.solver.checkpoint_path = v;
    }
    if let Some(v) = args.value("checkpoint-every") {
        cfg.solver.checkpoint_every_rounds = v.parse()?;
    }
    if let Some(v) = args.value("resume") {
        cfg.solver.resume_from = v;
    }
    if let Some(v) = args.value("reconnect-attempts") {
        cfg.solver.reconnect_max_attempts = v.parse()?;
    }
    if let Some(v) = args.value("log-format") {
        cfg.solver.log_format = v;
    }
    if args.flag("screening") {
        cfg.solver.screening = true;
    }
    if let Some(v) = args.value("kkt-every") {
        cfg.solver.kkt_every = v.parse()?;
    }
    if args.flag("kkt-adaptive") {
        cfg.solver.kkt_adaptive = true;
    }
    if args.flag("fast-kernels") {
        cfg.solver.fast_kernels = true;
    }
    if let Some(v) = args.value("kernel") {
        cfg.solver.kernel = v;
    }
    if let Some(v) = args.value("csv") {
        cfg.csv = Some(v);
    }
    for kv in args.values("set") {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("--set expects key=value, got '{kv}'"))?;
        cfg.set(k, v)?;
    }
    Ok(cfg)
}

fn cmd_train(args: &mut Args) -> anyhow::Result<()> {
    let profile = args.flag("profile");
    let kkt = args.flag("kkt");
    let cfg = config_from_args(args)?;
    args.finish()?;
    println!(
        "dataset={} loss={} lam={:.1e} algorithm={} threads={} backend={}",
        cfg.dataset.name,
        cfg.problem.loss,
        cfg.problem.lam,
        cfg.solver.algorithm,
        cfg.solver.threads,
        cfg.solver.backend.name(),
    );
    let res = if cfg.solver.backend == gencd::config::Backend::DenseBlockHlo {
        let ds = driver::load_dataset(&cfg)?;
        let loss = gencd::loss::by_name(&cfg.problem.loss)?;
        let problem =
            gencd::coordinator::Problem::new(ds, loss, cfg.problem.lam);
        let rt = gencd::runtime::Runtime::from_default_dir()?;
        let mut proposer = gencd::runtime::HloProposer::new(&rt, &problem)?;
        // reload raw (problem consumed the first copy); run_on applies
        // cfg.dataset.normalize exactly once
        let mut raw = cfg.clone();
        raw.dataset.normalize = false;
        let ds = driver::load_dataset(&raw)?;
        driver::run_on(&cfg, ds, Some(&mut proposer))?
    } else {
        driver::run(&cfg)?
    };
    if let Some(p) = res.pstar {
        println!("P* = {p} (rho = {:.2})", res.rho.unwrap_or(f64::NAN));
    }
    if let Some(c) = res.coloring_colors {
        println!(
            "coloring: {c} colors, {:.1} features/color, {:.2}s",
            res.coloring_mean_size.unwrap_or(0.0),
            res.coloring_secs.unwrap_or(0.0)
        );
    }
    println!("{}", res.summary());
    if cfg.solver.shards > 1 {
        println!(
            "shards: {} | numa nodes {} | reconcile {:.3}s | dirty frac {:.3} | rounds skipped {} | divergence {:.2e}",
            res.metrics.shards,
            res.metrics.numa_nodes,
            res.metrics.reconcile_secs,
            res.metrics.dirty_chunk_frac,
            res.metrics.reconcile_rounds_skipped,
            res.metrics.replica_divergence,
        );
    }
    if cfg.solver.screening {
        // gate on the config, not the metric: active_cols == 0 is a
        // legitimate outcome (lambda >= lambda_max prunes everything)
        // and is exactly when the user most wants to see this line
        println!(
            "screening: {} of {} columns active | {} KKT sweeps | {} reactivations",
            res.metrics.active_cols,
            res.w.len(),
            res.metrics.kkt_passes,
            res.metrics.reactivations,
        );
    }
    if kkt {
        // load_dataset already applied cfg.dataset.normalize
        let ds = driver::load_dataset(&cfg)?;
        let problem = gencd::coordinator::Problem::new(
            ds,
            gencd::loss::by_name(&cfg.problem.loss)?,
            cfg.problem.lam,
        );
        let r = gencd::coordinator::kkt::check(&problem, &res.w, 1e-6);
        println!(
            "KKT: max violation {:.3e} (coord {}), mean {:.3e}, {} coords > {:.0e}",
            r.max_violation, r.argmax, r.mean_violation, r.n_violating, r.tol
        );
    }
    if profile {
        // the same PhaseTimed rows every other consumer sees: the
        // profile table, experiment columns, and BENCH emitters all
        // read event::phases::rows, so they can never disagree
        let m = &res.metrics;
        let total = res.elapsed_secs.max(1e-12);
        let rows = gencd::event::phases::rows(m);
        println!("phase breakdown (leader wall-clock):");
        for r in &rows {
            println!(
                "  {:<11} {:>8.3}s  {:>5.1}%",
                r.label,
                r.secs,
                100.0 * r.secs / total
            );
        }
        let sum: f64 = rows.iter().map(|r| r.secs).sum();
        println!(
            "  {:<11} {:>8.3}s  {:>5.1}%  (barriers + worker wait)",
            "other",
            total - sum,
            100.0 * (total - sum) / total
        );
        println!(
            "  propose traversed {:.1}M nnz ({:.2} ns/nnz incl. barrier overlap)",
            m.propose_nnz as f64 / 1e6,
            m.propose_secs * 1e9 / m.propose_nnz.max(1) as f64
        );
    }
    for line in &res.event_log {
        println!("{line}");
    }
    Ok(())
}

fn cmd_path(args: &mut Args) -> anyhow::Result<()> {
    let dataset = args
        .value("dataset")
        .unwrap_or_else(|| "reuters@0.05".into());
    let loss = args.value("loss").unwrap_or_else(|| "logistic".into());
    let cfg = gencd::coordinator::path::PathConfig {
        algorithm: args
            .value("algorithm")
            .unwrap_or_else(|| "shotgun".into())
            .parse()?,
        n_points: args.get("points", 10usize)?,
        min_ratio: args.get("min-ratio", 1e-3f64)?,
        threads: args.get("threads", 4usize)?,
        max_seconds: args.get("seconds", 3.0f64)?,
        tol: args.get("tol", 1e-7f64)?,
        line_search_steps: args.get("line-search", 0usize)?,
        seed: args.get("seed", 1u64)?,
        ..Default::default()
    };
    args.finish()?;
    let mut ds = gencd::data::by_name(&dataset)?;
    ds.x.normalize_columns();
    println!(
        "{dataset}: {} x {}, loss {loss}, {} path points",
        ds.n_samples(),
        ds.n_features(),
        cfg.n_points
    );
    println!(
        "{:>11} {:>12} {:>8} {:>10} {:>7}",
        "lambda", "objective", "nnz", "updates", "secs"
    );
    for p in gencd::coordinator::path::solve_path(&ds, &loss, &cfg)? {
        println!(
            "{:>11.3e} {:>12.6} {:>8} {:>10} {:>7.2}",
            p.lam, p.objective, p.nnz, p.updates, p.elapsed_secs
        );
    }
    Ok(())
}

fn cmd_eval(args: &mut Args) -> anyhow::Result<()> {
    let test_frac: f64 = args.get("test-frac", 0.25)?;
    let split_seed: u64 = args.get("split-seed", 11)?;
    let model_path = args.value("model");
    let save_path = args.value("save");
    let cfg = config_from_args(args)?;
    args.finish()?;

    // load_dataset already applied cfg.dataset.normalize
    let ds = driver::load_dataset(&cfg)?;
    let (train, test) = gencd::eval::train_test_split(&ds, test_frac, split_seed);
    println!(
        "{}: {} train / {} test x {} features",
        cfg.dataset.name,
        train.n_samples(),
        test.n_samples(),
        ds.n_features()
    );

    let w = match model_path {
        Some(path) => {
            let w = gencd::eval::model_io::read_model(std::fs::File::open(&path)?)?;
            anyhow::ensure!(
                w.len() == ds.n_features(),
                "model has {} features, dataset {}",
                w.len(),
                ds.n_features()
            );
            println!("loaded model from {path}");
            w
        }
        None => {
            let mut train_cfg = cfg.clone();
            train_cfg.dataset.normalize = false; // already applied
            let res = driver::run_on(&train_cfg, train, None)?;
            println!("{}", res.summary());
            res.w
        }
    };
    if let Some(path) = save_path {
        gencd::eval::model_io::write_model(&w, std::fs::File::create(&path)?)?;
        println!("saved model to {path}");
    }
    let m = gencd::eval::classification_metrics(
        &test.y,
        &gencd::eval::scores(&test.x, &w),
    );
    println!(
        "held-out ({} samples): accuracy {:.4} | precision {:.4} | recall {:.4} | F1 {:.4} | AUC {:.4}",
        m.n, m.accuracy, m.precision, m.recall, m.f1, m.auc
    );
    Ok(())
}

fn cmd_datagen(args: &mut Args) -> anyhow::Result<()> {
    let name = args
        .positionals
        .first()
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("datagen needs a dataset name"))?;
    let scale: f64 = args.get("scale", 1.0)?;
    let seed: u64 = args.get("seed", gencd::data::GenOptions::default().seed)?;
    let out = args
        .value("out")
        .ok_or_else(|| anyhow::anyhow!("--out required"))?;
    args.finish()?;
    let mut opts = gencd::data::GenOptions::with_scale(scale);
    opts.seed = seed;
    let (ds, secs) = gencd::util::timer::timed(|| match name.as_str() {
        "dorothea" => Ok(gencd::data::dorothea_like(&opts)),
        "reuters" => Ok(gencd::data::reuters_like(&opts)),
        other => Err(anyhow::anyhow!("unknown dataset '{other}'")),
    });
    let ds = ds?;
    println!(
        "{}: {} samples x {} features, {} nnz ({:.1}/feature) in {secs:.2}s",
        ds.name,
        ds.n_samples(),
        ds.n_features(),
        ds.x.nnz(),
        ds.x.mean_col_nnz()
    );
    if out.ends_with(".bin") {
        sio::write_binary(&ds, std::path::Path::new(&out))?;
    } else {
        sio::write_libsvm(&ds, std::fs::File::create(&out)?)?;
    }
    println!("wrote {out}");
    Ok(())
}

fn cmd_color(args: &mut Args) -> anyhow::Result<()> {
    let dataset = args
        .value("dataset")
        .unwrap_or_else(|| "dorothea@0.1".into());
    let strategy =
        Strategy::by_name(&args.value("strategy").unwrap_or_else(|| "greedy".into()))?;
    args.finish()?;
    let mut ds = gencd::data::by_name(&dataset)?;
    ds.x.normalize_columns();
    let c = color_features(&ds.x, strategy, 1);
    gencd::coloring::verify::verify_coloring(&ds.x, &c)
        .map_err(|e| anyhow::anyhow!("INVALID COLORING: {e}"))?;
    println!(
        "{dataset}: {} colors | features/color mean {:.1} min {} max {} | imbalance {:.2} | {:.3}s [{}]",
        c.n_colors(),
        c.mean_class_size(),
        c.min_class_size(),
        c.max_class_size(),
        c.imbalance(),
        c.elapsed_secs,
        strategy.name(),
    );
    Ok(())
}

fn cmd_spectral(args: &mut Args) -> anyhow::Result<()> {
    let dataset = args
        .value("dataset")
        .unwrap_or_else(|| "dorothea@0.1".into());
    let iters: usize = args.get("iters", 200)?;
    args.finish()?;
    let mut ds = gencd::data::by_name(&dataset)?;
    ds.x.normalize_columns();
    let t = Timer::start();
    let est = spectral_radius_xtx(&ds.x, iters, 1e-8, 1);
    println!(
        "{dataset}: rho(X^T X) = {:.3} ({} iters, rel change {:.1e}, {:.2}s) => P* = {}",
        est.rho,
        est.iters,
        est.rel_change,
        t.elapsed_secs(),
        shotgun_pstar(ds.n_features(), est.rho)
    );
    Ok(())
}

fn bench_env(args: &mut Args, default_secs: f64) -> anyhow::Result<()> {
    let scale: f64 = args.get("scale", 0.1)?;
    let seconds: f64 = args.get("seconds", default_secs)?;
    std::env::set_var("GENCD_BENCH_SCALE", scale.to_string());
    std::env::set_var("GENCD_BENCH_SECONDS", seconds.to_string());
    Ok(())
}

fn cmd_table3(args: &mut Args) -> anyhow::Result<()> {
    bench_env(args, 5.0)?;
    args.finish()?;
    gencd::bench_harness::experiments::print_table3();
    Ok(())
}

fn cmd_fig1(args: &mut Args) -> anyhow::Result<()> {
    bench_env(args, 5.0)?;
    let csv_dir = args.value("csv-dir");
    args.finish()?;
    gencd::bench_harness::experiments::print_fig1(csv_dir.as_deref());
    Ok(())
}

fn cmd_fig2(args: &mut Args) -> anyhow::Result<()> {
    bench_env(args, 2.0)?;
    let threads: Vec<usize> = args
        .value("threads-list")
        .unwrap_or_else(|| "1,2,4,8,16,32".into())
        .split(',')
        .map(|s| s.trim().parse())
        .collect::<Result<_, _>>()?;
    args.finish()?;
    gencd::bench_harness::experiments::print_fig2(&threads);
    Ok(())
}

fn cmd_shards(args: &mut Args) -> anyhow::Result<()> {
    bench_env(args, 2.0)?;
    let shards: Vec<usize> = args
        .value("shards-list")
        .unwrap_or_else(|| "1,2,4".into())
        .split(',')
        .map(|s| s.trim().parse())
        .collect::<Result<_, _>>()?;
    let threads: usize = args.get("threads", 4)?;
    args.finish()?;
    gencd::bench_harness::experiments::print_shard_scaling(&shards, threads);
    Ok(())
}

fn cmd_screen(args: &mut Args) -> anyhow::Result<()> {
    bench_env(args, 2.0)?;
    let threads: usize = args.get("threads", 4)?;
    args.finish()?;
    gencd::bench_harness::experiments::print_screening(threads);
    Ok(())
}

fn cmd_numa(args: &mut Args) -> anyhow::Result<()> {
    bench_env(args, 2.0)?;
    let shards: usize = args.get("shards", 2)?;
    let threads: usize = args.get("threads", 4)?;
    args.finish()?;
    gencd::bench_harness::experiments::print_numa_ab(shards, threads);
    Ok(())
}

fn cmd_net(args: &mut Args) -> anyhow::Result<()> {
    if args.flag("corpus") {
        let dir = args
            .value("dir")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|| std::path::PathBuf::from("scenarios"));
        let filter = args.value("filter");
        let show_events = args.flag("events");
        args.finish()?;
        let runs = gencd::sim::run_corpus_loopback(&dir, filter.as_deref())?;
        anyhow::ensure!(
            !runs.is_empty(),
            "no scenarios matched under {} (expected *.toml files)",
            dir.display()
        );
        if show_events {
            for run in &runs {
                println!("=== {} ===", run.verdict.name);
                print!("{}", run.event_log);
            }
        }
        let verdicts: Vec<_> = runs.iter().map(|r| r.verdict.clone()).collect();
        let (report, all_pass) = gencd::sim::render_verdicts(&verdicts);
        print!("{report}");
        anyhow::ensure!(all_pass, "scenario corpus has failures over the loopback wire");
        return Ok(());
    }
    if args.flag("smoke") {
        args.finish()?;
        let ds = gencd::data::by_name("dorothea@0.02")?;
        let out = gencd::Solver::builder()
            .dataset(ds)
            .normalize(true)
            .lambda(1e-3)
            .algorithm("shotgun".parse()?)
            .threads(2)
            .shards(2)
            .max_seconds(5.0)
            .transport(gencd::net::Transport::Tcp {
                listen: "127.0.0.1:0".into(),
                peers: vec![],
                precision: gencd::net::WirePrecision::Exact,
            })
            .build()?
            .solve();
        println!(
            "tcp smoke: stop {} | obj {:.6} | wire tx {} rx {} | codec {:.4}s",
            out.stop,
            out.objective,
            out.metrics.wire_bytes_tx,
            out.metrics.wire_bytes_rx,
            out.metrics.codec_secs,
        );
        anyhow::ensure!(
            out.failure.is_none(),
            "tcp smoke failed: {}",
            out.failure.map(|f| f.to_string()).unwrap_or_default()
        );
        anyhow::ensure!(out.objective.is_finite(), "tcp smoke: non-finite objective");
        anyhow::ensure!(out.metrics.wire_bytes_tx > 0, "tcp smoke: no wire traffic");
        println!("tcp smoke OK");
        return Ok(());
    }
    bench_env(args, 2.0)?;
    let shards: usize = args.get("shards", 2)?;
    let threads: usize = args.get("threads", 4)?;
    args.finish()?;
    gencd::bench_harness::experiments::print_net_ab(shards, threads);
    Ok(())
}

fn cmd_harness(args: &mut Args) -> anyhow::Result<()> {
    use gencd::recover::harness;
    if args.flag("worker") {
        let out = args
            .value("out")
            .ok_or_else(|| anyhow::anyhow!("harness --worker needs --out FILE"))?;
        let opts = harness::WorkerOpts {
            seed: args.get("seed", 7u64)?,
            rounds: args.get("rounds", 40usize)?,
            shards: args.get("shards", 2usize)?.max(2),
            pace_ms: args.get("pace-ms", 0u64)?,
            listen: args.value("listen").unwrap_or_else(|| "127.0.0.1:0".into()),
            peers: args
                .value("peers")
                .map(|p| {
                    p.split(',')
                        .map(|s| s.trim().to_string())
                        .filter(|s| !s.is_empty())
                        .collect()
                })
                .unwrap_or_default(),
            checkpoint: args.value("checkpoint").map(Into::into),
            checkpoint_every: args.get("checkpoint-every", 4usize)?.max(1),
            resume: args.value("resume").map(Into::into),
            reconnect_attempts: args.get("reconnect-attempts", 0usize)?,
            out: out.into(),
        };
        args.finish()?;
        return harness::run_worker(&opts);
    }
    if args.flag("proxy") {
        let opts = harness::ProxyOpts {
            listen: args
                .value("listen")
                .ok_or_else(|| anyhow::anyhow!("harness --proxy needs --listen ADDR"))?,
            target: args
                .value("target")
                .ok_or_else(|| anyhow::anyhow!("harness --proxy needs --target ADDR"))?,
            sever_after_bytes: args.get("sever-after-bytes", 0u64)?,
            heal_after_ms: args.get("heal-after-ms", 0u64)?,
        };
        args.finish()?;
        return harness::run_proxy(&opts);
    }
    let exe = std::env::current_exe()?;
    let verdicts = if args.flag("smoke") {
        args.finish()?;
        harness::run_smoke(&exe)
    } else {
        let dir = args
            .value("plan")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|| std::path::PathBuf::from("scenarios/harness"));
        let filter = args.value("filter");
        args.finish()?;
        harness::run_plan_dir(&exe, &dir, filter.as_deref())?
    };
    anyhow::ensure!(!verdicts.is_empty(), "no harness drills matched");
    let (report, all_pass) = gencd::sim::render_verdicts(&verdicts);
    print!("{report}");
    anyhow::ensure!(all_pass, "harness drills have failures");
    Ok(())
}

fn cmd_sim(args: &mut Args) -> anyhow::Result<()> {
    let dir = args
        .value("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("scenarios"));
    let filter = args.value("filter");
    let show_events = args.flag("events");
    args.finish()?;
    let runs = gencd::sim::run_corpus(&dir, filter.as_deref())?;
    anyhow::ensure!(
        !runs.is_empty(),
        "no scenarios matched under {} (expected *.toml files)",
        dir.display()
    );
    if show_events {
        for run in &runs {
            println!("=== {} ===", run.verdict.name);
            print!("{}", run.event_log);
        }
    }
    let verdicts: Vec<_> = runs.iter().map(|r| r.verdict.clone()).collect();
    let (report, all_pass) = gencd::sim::render_verdicts(&verdicts);
    print!("{report}");
    anyhow::ensure!(all_pass, "scenario corpus has failures");
    Ok(())
}

fn cmd_events(args: &mut Args) -> anyhow::Result<()> {
    let path = args
        .value("check")
        .ok_or_else(|| anyhow::anyhow!("usage: gencd events --check FILE"))?;
    args.finish()?;
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("cannot read {path}: {e}"))?;
    let report = gencd::event::check::check_lines(text.lines())
        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    gencd::event::check::verify_coverage(&report)
        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    print!("{}", report.render());
    Ok(())
}

fn cmd_artifacts(args: &mut Args) -> anyhow::Result<()> {
    let dir = args
        .value("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(gencd::runtime::Manifest::default_dir);
    let smoke = args.flag("smoke");
    args.finish()?;
    let m = gencd::runtime::Manifest::load(&dir)?;
    println!("{} entries in {}", m.entries.len(), dir.display());
    for e in &m.entries {
        println!(
            "  {:<12} {:<9} n={:<6} b={:<3} {} {}",
            e.kind,
            e.loss,
            e.n,
            e.b,
            e.file,
            e.ls_steps.map(|s| format!("steps={s}")).unwrap_or_default()
        );
    }
    if smoke {
        let rt = gencd::runtime::Runtime::new(&dir)?;
        println!("platform: {}", rt.platform());
        let entry = m.find("objective", "logistic", 1)?.clone();
        let exe = rt.compile(&entry)?;
        let n = entry.n;
        let y = vec![1.0f32; n];
        let z = vec![0.0f32; n];
        let mask = vec![1.0f32; n];
        let scalars = [0.0f32, 0.0, 1.0 / n as f32];
        let out = exe.run_f32(&[&y, &z, &mask, &scalars])?;
        let want = (2f32).ln();
        println!("smoke objective(0) = {} (expect ~{want})", out[0][0]);
        anyhow::ensure!((out[0][0] - want).abs() < 1e-4, "smoke mismatch");
        println!("smoke OK");
    }
    Ok(())
}
