//! Planted-model labeling shared by the synthetic dataset generators.
//!
//! A sparse ground-truth weight vector is drawn over a chosen support,
//! samples are scored through the design matrix, and labels are assigned
//! by thresholding the scores so a *target number* of positives comes out
//! exactly (matching the published class balances), with a small flip
//! noise so the problem is not perfectly separable.

use crate::sparse::CscMatrix;
use crate::util::Pcg64;

/// A planted sparse linear model.
#[derive(Clone, Debug)]
pub struct PlantedModel {
    /// Feature indices carrying true signal.
    pub support: Vec<usize>,
    /// Weights on the support (same order).
    pub weights: Vec<f64>,
}

impl PlantedModel {
    /// Draw a model over `support_size` features sampled *by popularity*
    /// (columns with more nonzeros are preferred — signal on features
    /// that never fire would be unlearnable).
    pub fn draw(x: &CscMatrix, support_size: usize, rng: &mut Pcg64) -> Self {
        let k = x.n_cols();
        let support_size = support_size.min(k);
        // popularity-weighted sampling without replacement: take the
        // top 4*support_size by nnz, sample the support among them.
        let mut by_nnz: Vec<usize> = (0..k).collect();
        by_nnz.sort_by_key(|&j| std::cmp::Reverse(x.col_nnz(j)));
        let pool = &by_nnz[..(4 * support_size).min(k)];
        let picks = rng.sample_distinct(pool.len(), support_size);
        let support: Vec<usize> = picks.iter().map(|&p| pool[p]).collect();
        let weights = support
            .iter()
            .map(|_| {
                let sign = if rng.next_f64() < 0.5 { -1.0 } else { 1.0 };
                sign * (1.0 + 0.5 * rng.next_normal()).abs().max(0.2)
            })
            .collect();
        Self { support, weights }
    }

    /// Scores `X w*` (sparse accumulation over the support only).
    pub fn scores(&self, x: &CscMatrix) -> Vec<f64> {
        let mut s = vec![0.0; x.n_rows()];
        for (&j, &w) in self.support.iter().zip(&self.weights) {
            x.axpy_col(j, w, &mut s);
        }
        s
    }
}

/// Threshold `scores` so exactly `n_pos` samples are labeled +1, then
/// flip each label independently with probability `noise`.
pub fn labels_with_positive_count(
    scores: &[f64],
    n_pos: usize,
    noise: f64,
    rng: &mut Pcg64,
) -> Vec<f64> {
    let n = scores.len();
    let n_pos = n_pos.min(n);
    // threshold = n_pos-th largest score (stable under ties via index)
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut y = vec![-1.0; n];
    for &i in &order[..n_pos] {
        y[i] = 1.0;
    }
    if noise > 0.0 {
        for yi in &mut y {
            if rng.next_f64() < noise {
                *yi = -*yi;
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooBuilder;

    fn fixture() -> CscMatrix {
        let mut rng = Pcg64::seeded(1);
        let mut b = CooBuilder::new(50, 30);
        for j in 0..30 {
            for i in 0..50 {
                if rng.next_f64() < 0.2 {
                    b.push(i, j, 1.0);
                }
            }
        }
        b.build()
    }

    #[test]
    fn planted_model_has_requested_support() {
        let x = fixture();
        let mut rng = Pcg64::seeded(2);
        let m = PlantedModel::draw(&x, 5, &mut rng);
        assert_eq!(m.support.len(), 5);
        assert_eq!(m.weights.len(), 5);
        let set: std::collections::HashSet<_> = m.support.iter().collect();
        assert_eq!(set.len(), 5, "support must be distinct");
        assert!(m.weights.iter().all(|w| w.abs() >= 0.2));
    }

    #[test]
    fn scores_match_matvec() {
        let x = fixture();
        let mut rng = Pcg64::seeded(3);
        let m = PlantedModel::draw(&x, 4, &mut rng);
        let mut w = vec![0.0; x.n_cols()];
        for (&j, &v) in m.support.iter().zip(&m.weights) {
            w[j] = v;
        }
        let a = m.scores(&x);
        let b = x.matvec(&w);
        for (p, q) in a.iter().zip(&b) {
            assert!((p - q).abs() < 1e-12);
        }
    }

    #[test]
    fn exact_positive_count_without_noise() {
        let scores: Vec<f64> = (0..100).map(|i| (i as f64) * 0.1).collect();
        let mut rng = Pcg64::seeded(4);
        let y = labels_with_positive_count(&scores, 17, 0.0, &mut rng);
        assert_eq!(y.iter().filter(|&&v| v > 0.0).count(), 17);
        // the positives are the top-17 scores
        assert!(y[99] > 0.0 && y[82] < 0.0 && y[83] > 0.0);
    }

    #[test]
    fn noise_flips_some() {
        let scores = vec![0.0; 1000];
        let mut rng = Pcg64::seeded(5);
        let y = labels_with_positive_count(&scores, 500, 0.1, &mut rng);
        let pos = y.iter().filter(|&&v| v > 0.0).count();
        assert!(pos > 400 && pos < 600, "pos={pos}");
        assert_ne!(pos, 500); // overwhelmingly likely under the seed
    }
}
