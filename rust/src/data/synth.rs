//! Generic synthetic sparse-matrix generation primitives used by the
//! dataset twins: skewed discrete sampling (alias-free cumulative table)
//! and per-column/per-row support drawing.

use crate::sparse::{CooBuilder, CscMatrix};
use crate::util::Pcg64;

/// Cumulative-weight sampler over `0..weights.len()` (binary search on
/// the CDF). Deterministic given the RNG; O(log n) per draw.
pub struct WeightedSampler {
    cdf: Vec<f64>,
}

impl WeightedSampler {
    pub fn new(weights: &[f64]) -> Self {
        assert!(!weights.is_empty());
        let mut cdf = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for &w in weights {
            assert!(w >= 0.0, "negative weight");
            acc += w;
            cdf.push(acc);
        }
        assert!(acc > 0.0, "all-zero weights");
        Self { cdf }
    }

    /// Zipf-like popularity weights: weight(i) ~ 1 / (i + offset)^s.
    pub fn zipf(n: usize, s: f64, offset: f64) -> Self {
        let w: Vec<f64> = (0..n).map(|i| 1.0 / (i as f64 + offset).powf(s)).collect();
        Self::new(&w)
    }

    /// Log-normal popularity weights.
    pub fn lognormal(n: usize, sigma: f64, rng: &mut Pcg64) -> Self {
        let w: Vec<f64> = (0..n).map(|_| (sigma * rng.next_normal()).exp()).collect();
        Self::new(&w)
    }

    #[inline]
    pub fn draw(&self, rng: &mut Pcg64) -> usize {
        let total = *self.cdf.last().unwrap();
        let u = rng.next_f64() * total;
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => (i + 1).min(self.cdf.len() - 1),
            Err(i) => i,
        }
    }

    /// Draw `m` *distinct* indices (rejection; m must be << n for speed).
    pub fn draw_distinct(&self, m: usize, rng: &mut Pcg64) -> Vec<usize> {
        let n = self.cdf.len();
        let m = m.min(n);
        let mut seen = std::collections::HashSet::with_capacity(m * 2);
        let mut out = Vec::with_capacity(m);
        let mut attempts = 0usize;
        while out.len() < m {
            let i = self.draw(rng);
            if seen.insert(i) {
                out.push(i);
            }
            attempts += 1;
            if attempts > 50 * m + 1000 {
                // pathological skew: fall back to filling uniformly
                for j in 0..n {
                    if out.len() == m {
                        break;
                    }
                    if seen.insert(j) {
                        out.push(j);
                    }
                }
            }
        }
        out
    }
}

/// Build a binary matrix column-by-column: column j gets
/// `nnz_of(j, rng)` distinct rows drawn from `row_sampler`.
pub fn binary_by_columns(
    n_rows: usize,
    n_cols: usize,
    row_sampler: &WeightedSampler,
    rng: &mut Pcg64,
    mut nnz_of: impl FnMut(usize, &mut Pcg64) -> usize,
) -> CscMatrix {
    let mut b = CooBuilder::new(n_rows, n_cols);
    for j in 0..n_cols {
        let nnz = nnz_of(j, rng).clamp(1, n_rows);
        for i in row_sampler.draw_distinct(nnz, rng) {
            b.push(i, j, 1.0);
        }
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_sampler_respects_weights() {
        let s = WeightedSampler::new(&[1.0, 0.0, 3.0]);
        let mut rng = Pcg64::seeded(1);
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[s.draw(&mut rng)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio {ratio}");
    }

    #[test]
    fn draw_distinct_distinct() {
        let s = WeightedSampler::zipf(100, 1.2, 2.0);
        let mut rng = Pcg64::seeded(2);
        for _ in 0..50 {
            let v = s.draw_distinct(20, &mut rng);
            let set: std::collections::HashSet<_> = v.iter().collect();
            assert_eq!(set.len(), 20);
        }
    }

    #[test]
    fn draw_distinct_handles_m_equals_n() {
        let s = WeightedSampler::new(&[5.0, 1.0, 1.0]);
        let mut rng = Pcg64::seeded(3);
        let mut v = s.draw_distinct(3, &mut rng);
        v.sort_unstable();
        assert_eq!(v, vec![0, 1, 2]);
    }

    #[test]
    fn binary_by_columns_shape() {
        let mut rng = Pcg64::seeded(4);
        let s = WeightedSampler::lognormal(30, 1.0, &mut rng);
        let m = binary_by_columns(30, 10, &s, &mut rng, |_, r| 1 + r.next_poisson(3.0) as usize);
        assert_eq!(m.n_rows(), 30);
        assert_eq!(m.n_cols(), 10);
        for j in 0..10 {
            assert!(m.col_nnz(j) >= 1);
            let (_, vals) = m.col(j);
            assert!(vals.iter().all(|&v| v == 1.0));
        }
    }
}
