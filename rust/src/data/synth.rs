//! Generic synthetic sparse-matrix generation primitives used by the
//! dataset twins: skewed discrete sampling (alias-free cumulative table)
//! and per-column/per-row support drawing.

use crate::sparse::{CooBuilder, CscMatrix};
use crate::util::Pcg64;

/// Cumulative-weight sampler over `0..weights.len()` (binary search on
/// the CDF). Deterministic given the RNG; O(log n) per draw.
pub struct WeightedSampler {
    cdf: Vec<f64>,
}

impl WeightedSampler {
    pub fn new(weights: &[f64]) -> Self {
        assert!(!weights.is_empty());
        let mut cdf = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for &w in weights {
            assert!(w >= 0.0, "negative weight");
            acc += w;
            cdf.push(acc);
        }
        assert!(acc > 0.0, "all-zero weights");
        Self { cdf }
    }

    /// Zipf-like popularity weights: weight(i) ~ 1 / (i + offset)^s.
    pub fn zipf(n: usize, s: f64, offset: f64) -> Self {
        let w: Vec<f64> = (0..n).map(|i| 1.0 / (i as f64 + offset).powf(s)).collect();
        Self::new(&w)
    }

    /// Log-normal popularity weights.
    pub fn lognormal(n: usize, sigma: f64, rng: &mut Pcg64) -> Self {
        let w: Vec<f64> = (0..n).map(|_| (sigma * rng.next_normal()).exp()).collect();
        Self::new(&w)
    }

    #[inline]
    pub fn draw(&self, rng: &mut Pcg64) -> usize {
        let total = *self.cdf.last().unwrap();
        let u = rng.next_f64() * total;
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => (i + 1).min(self.cdf.len() - 1),
            Err(i) => i,
        }
    }

    /// Draw `m` *distinct* indices (rejection; m must be << n for speed).
    pub fn draw_distinct(&self, m: usize, rng: &mut Pcg64) -> Vec<usize> {
        let n = self.cdf.len();
        let m = m.min(n);
        let mut seen = std::collections::HashSet::with_capacity(m * 2);
        let mut out = Vec::with_capacity(m);
        let mut attempts = 0usize;
        while out.len() < m {
            let i = self.draw(rng);
            if seen.insert(i) {
                out.push(i);
            }
            attempts += 1;
            if attempts > 50 * m + 1000 {
                // pathological skew: fall back to filling uniformly
                for j in 0..n {
                    if out.len() == m {
                        break;
                    }
                    if seen.insert(j) {
                        out.push(j);
                    }
                }
            }
        }
        out
    }
}

/// Build a binary matrix column-by-column: column j gets
/// `nnz_of(j, rng)` distinct rows drawn from `row_sampler`.
pub fn binary_by_columns(
    n_rows: usize,
    n_cols: usize,
    row_sampler: &WeightedSampler,
    rng: &mut Pcg64,
    mut nnz_of: impl FnMut(usize, &mut Pcg64) -> usize,
) -> CscMatrix {
    let mut b = CooBuilder::new(n_rows, n_cols);
    for j in 0..n_cols {
        let nnz = nnz_of(j, rng).clamp(1, n_rows);
        for i in row_sampler.draw_distinct(nnz, rng) {
            b.push(i, j, 1.0);
        }
    }
    b.build()
}

/// Power-law column sparsity: column `j`'s support size decays as
/// `max_nnz / (j + 1)^alpha` (clamped to `[1, n_rows]`), rows drawn
/// uniformly, values in ±1. The head columns are dense and
/// high-leverage, the tail is a long fringe of near-singleton columns —
/// the document-frequency shape of real text/click matrices, and the
/// regime where shard load balance and KKT screening are stressed.
/// Deterministic given the RNG ([`crate::sim`] workload `powerlaw`).
pub fn power_law_by_columns(
    n_rows: usize,
    n_cols: usize,
    alpha: f64,
    max_nnz: usize,
    rng: &mut Pcg64,
) -> CscMatrix {
    let mut b = CooBuilder::new(n_rows, n_cols);
    for j in 0..n_cols {
        let nnz = ((max_nnz as f64 / (j as f64 + 1.0).powf(alpha)) as usize).clamp(1, n_rows);
        for i in rng.sample_distinct(n_rows, nnz) {
            b.push(i, j, rng.range_f64(-1.0, 1.0));
        }
    }
    b.build()
}

/// Adversarial cross-shard conflict blocks: columns are split into
/// `groups` contiguous groups (matching a contiguous
/// [`ShardPlan`](crate::shard::ShardPlan) over `groups` shards), each
/// column touching `hot_nnz` rows of a **shared hot row block** (rows
/// `0..hot_rows`, hit by every group) plus `private_nnz` rows of its own
/// group's private block. Every shard updates the hot rows every round,
/// so reconcile conflicts are maximal by construction — the worst case
/// for replica divergence, and the workload the simulator's reordering
/// and staleness faults bite hardest ([`crate::sim`] workload
/// `conflict`).
pub fn conflict_blocks(
    n_rows: usize,
    n_cols: usize,
    groups: usize,
    hot_nnz: usize,
    private_nnz: usize,
    rng: &mut Pcg64,
) -> CscMatrix {
    let groups = groups.max(1);
    let hot_rows = (n_rows / 4).max(1);
    let priv_rows = n_rows - hot_rows;
    let mut b = CooBuilder::new(n_rows, n_cols);
    for j in 0..n_cols {
        let g = j * groups / n_cols.max(1);
        for i in rng.sample_distinct(hot_rows, hot_nnz.clamp(1, hot_rows)) {
            b.push(i, j, rng.range_f64(-1.0, 1.0));
        }
        if priv_rows > 0 && groups > 0 {
            // group g's private slice of the non-hot rows
            let lo = hot_rows + priv_rows * g / groups;
            let hi = hot_rows + priv_rows * (g + 1) / groups;
            if hi > lo {
                for i in rng.sample_distinct(hi - lo, private_nnz.clamp(1, hi - lo)) {
                    b.push(lo + i, j, rng.range_f64(-1.0, 1.0));
                }
            }
        }
    }
    b.build()
}

/// Cartesian `(n, k, nnz)` grid for sweep-style scenario generation:
/// every combination of the three axes, in row-major order (n slowest).
pub fn grid(ns: &[usize], ks: &[usize], nnzs: &[usize]) -> Vec<(usize, usize, usize)> {
    let mut out = Vec::with_capacity(ns.len() * ks.len() * nnzs.len());
    for &n in ns {
        for &k in ks {
            for &nnz in nnzs {
                out.push((n, k, nnz));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_sampler_respects_weights() {
        let s = WeightedSampler::new(&[1.0, 0.0, 3.0]);
        let mut rng = Pcg64::seeded(1);
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[s.draw(&mut rng)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio {ratio}");
    }

    #[test]
    fn draw_distinct_distinct() {
        let s = WeightedSampler::zipf(100, 1.2, 2.0);
        let mut rng = Pcg64::seeded(2);
        for _ in 0..50 {
            let v = s.draw_distinct(20, &mut rng);
            let set: std::collections::HashSet<_> = v.iter().collect();
            assert_eq!(set.len(), 20);
        }
    }

    #[test]
    fn draw_distinct_handles_m_equals_n() {
        let s = WeightedSampler::new(&[5.0, 1.0, 1.0]);
        let mut rng = Pcg64::seeded(3);
        let mut v = s.draw_distinct(3, &mut rng);
        v.sort_unstable();
        assert_eq!(v, vec![0, 1, 2]);
    }

    #[test]
    fn power_law_head_dominates_tail() {
        let mut rng = Pcg64::seeded(5);
        let m = power_law_by_columns(200, 50, 1.2, 120, &mut rng);
        assert_eq!(m.n_rows(), 200);
        assert_eq!(m.n_cols(), 50);
        assert!(m.col_nnz(0) > 10 * m.col_nnz(49).max(1) / 2, "no decay");
        for j in 0..50 {
            assert!(m.col_nnz(j) >= 1);
        }
        // determinism: same seed, same matrix
        let mut rng2 = Pcg64::seeded(5);
        let m2 = power_law_by_columns(200, 50, 1.2, 120, &mut rng2);
        for j in 0..50 {
            assert_eq!(m.col(j), m2.col(j));
        }
    }

    #[test]
    fn conflict_blocks_share_hot_rows() {
        let mut rng = Pcg64::seeded(6);
        let (n, k, groups) = (80usize, 20usize, 2usize);
        let m = conflict_blocks(n, k, groups, 5, 4, &mut rng);
        let hot_rows = n / 4;
        // every column hits the hot block; private rows stay in-group
        for j in 0..k {
            let g = j * groups / k;
            let (rows, _) = m.col(j);
            assert!(
                rows.iter().any(|&i| (i as usize) < hot_rows),
                "col {j} misses the hot block"
            );
            let priv_rows = n - hot_rows;
            let (lo, hi) = (
                hot_rows + priv_rows * g / groups,
                hot_rows + priv_rows * (g + 1) / groups,
            );
            for &i in rows {
                let i = i as usize;
                assert!(
                    i < hot_rows || (lo..hi).contains(&i),
                    "col {j} leaked into another group's private block"
                );
            }
        }
    }

    #[test]
    fn grid_is_full_cartesian() {
        let g = grid(&[10, 20], &[3], &[5, 7, 9]);
        assert_eq!(g.len(), 6);
        assert_eq!(g[0], (10, 3, 5));
        assert_eq!(g[5], (20, 3, 9));
    }

    #[test]
    fn binary_by_columns_shape() {
        let mut rng = Pcg64::seeded(4);
        let s = WeightedSampler::lognormal(30, 1.0, &mut rng);
        let m = binary_by_columns(30, 10, &s, &mut rng, |_, r| 1 + r.next_poisson(3.0) as usize);
        assert_eq!(m.n_rows(), 30);
        assert_eq!(m.n_cols(), 10);
        for j in 0..10 {
            assert!(m.col_nnz(j) >= 1);
            let (_, vals) = m.col(j);
            assert!(vals.iter().all(|&v| v == 1.0));
        }
    }
}
