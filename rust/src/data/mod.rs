//! Dataset substrate.
//!
//! The paper evaluates on DOROTHEA (NIPS'03 drug discovery) and REUTERS
//! (RCV1-v2, CCAT topic). Neither is fetchable in this offline
//! environment, so `dorothea.rs` / `reuters.rs` generate *synthetic
//! twins*: matrices matching the published shape, sparsity, value
//! distribution and label balance, with labels from a planted sparse
//! linear model so that an l1-regularized logistic fit has a meaningful
//! sparse optimum (see DESIGN.md §4, Substitutions).

pub mod dorothea;
pub mod planted;
pub mod reuters;
pub mod synth;

pub use dorothea::dorothea_like;
pub use reuters::reuters_like;

use crate::sparse::io::Dataset;

/// Shape/scale knobs common to the generators. `scale` shrinks both
/// dimensions (and the planted support) proportionally for tests and
/// quick benches; 1.0 reproduces the paper's dimensions.
#[derive(Clone, Copy, Debug)]
pub struct GenOptions {
    pub seed: u64,
    pub scale: f64,
    /// Fraction of labels flipped after thresholding (realism noise).
    pub label_noise: f64,
}

impl Default for GenOptions {
    fn default() -> Self {
        Self {
            seed: 20120626, // ICML 2012 started June 26
            scale: 1.0,
            label_noise: 0.02,
        }
    }
}

impl GenOptions {
    pub fn with_scale(scale: f64) -> Self {
        Self {
            scale,
            ..Default::default()
        }
    }

    pub(crate) fn scaled(&self, full: usize) -> usize {
        ((full as f64 * self.scale).round() as usize).max(4)
    }
}

/// Registry lookup used by the CLI and bench harness.
/// Names: `dorothea`, `reuters`, optionally suffixed `@<scale>`
/// (e.g. `reuters@0.05`).
pub fn by_name(name: &str) -> anyhow::Result<Dataset> {
    let (base, scale) = match name.split_once('@') {
        Some((b, s)) => (b, s.parse::<f64>()?),
        None => (name, 1.0),
    };
    anyhow::ensure!(
        scale > 0.0 && scale <= 1.0,
        "scale must be in (0, 1], got {scale}"
    );
    let opts = GenOptions::with_scale(scale);
    match base {
        "dorothea" => Ok(dorothea_like(&opts)),
        "reuters" => Ok(reuters_like(&opts)),
        other => anyhow::bail!("unknown dataset '{other}' (try dorothea, reuters)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves() {
        let ds = by_name("dorothea@0.02").unwrap();
        assert_eq!(ds.name, "dorothea-like");
        assert!(by_name("nope").is_err());
        assert!(by_name("reuters@0.0").is_err());
        assert!(by_name("reuters@1.5").is_err());
    }
}
