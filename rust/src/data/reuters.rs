//! Synthetic twin of the REUTERS RCV1-v2 / LYRL2004 text data (Lewis et
//! al. 2004) with the CCAT ("Corporate-Industrial") topic as the target,
//! as used in the paper's evaluation.
//!
//! Published statistics reproduced at scale 1.0 (paper Sec. 4.4/Table 3):
//!   * 23 865 training documents, 47 237 terms
//!   * ~1.7M nonzeros, mean 37.2 nonzeros per feature (term)
//!   * tf-idf transformed, cosine (row) normalized — the LYRL2004 recipe
//!   * 10 786 / 23 865 documents in CCAT (45.2% positive)
//!
//! Construction: Zipfian term popularity, log-normal document lengths,
//! per-occurrence term counts 1+Poisson, `(1 + ln tf) * ln(n/df)` tf-idf,
//! L2 row normalization; labels from a planted sparse logistic model over
//! mid-frequency terms with 2% flip noise (DESIGN.md §4).

use super::planted::{labels_with_positive_count, PlantedModel};
use super::synth::WeightedSampler;
use super::GenOptions;
use crate::sparse::io::Dataset;
use crate::sparse::{CooBuilder, CsrMatrix};
use crate::util::Pcg64;

/// Full-scale dimensions (paper Table 3).
pub const N_SAMPLES: usize = 23_865;
pub const N_FEATURES: usize = 47_237;
pub const MEAN_NNZ_PER_FEATURE: f64 = 37.2;
pub const N_POSITIVE: usize = 10_786;
/// The paper's chosen regularization for this dataset.
pub const PAPER_LAMBDA: f64 = 1e-5;

/// Generate the REUTERS twin. `opts.scale` shrinks both dimensions.
pub fn reuters_like(opts: &GenOptions) -> Dataset {
    let n = opts.scaled(N_SAMPLES);
    let k = opts.scaled(N_FEATURES);
    let mut rng = Pcg64::new(opts.seed, 0x2E07E25);

    // Zipfian term popularity (s ~ 1.05, classic for text).
    let term_sampler = WeightedSampler::zipf(k, 1.05, 2.0);

    // Document lengths: log-normal with mean matched so the total nnz
    // hits ~ mean_nnz_per_feature * k.
    let target_nnz = (MEAN_NNZ_PER_FEATURE * k as f64) as usize;
    let mean_len = target_nnz as f64 / n as f64;
    let sigma: f64 = 0.6;
    let mu = mean_len.ln() - sigma * sigma / 2.0;

    let mut builder = CooBuilder::with_capacity(n, k, target_nnz + n);
    let mut df = vec![0u32; k]; // document frequency per term
    let mut doc_terms: Vec<(u32, u32)> = Vec::new(); // (term, tf) scratch

    // First pass: choose term sets + raw term frequencies per document.
    let mut all_docs: Vec<Vec<(u32, u32)>> = Vec::with_capacity(n);
    for _ in 0..n {
        let len = ((mu + sigma * rng.next_normal()).exp().round() as usize).clamp(3, k);
        doc_terms.clear();
        let terms = term_sampler.draw_distinct(len, &mut rng);
        for t in terms {
            let tf = 1 + rng.next_poisson(0.6) as u32;
            doc_terms.push((t as u32, tf));
            df[t] += 1;
        }
        all_docs.push(doc_terms.clone());
    }

    // Second pass: tf-idf values, then cosine-normalize each row.
    for (i, terms) in all_docs.iter().enumerate() {
        let mut vals: Vec<f64> = terms
            .iter()
            .map(|&(t, tf)| {
                let idf = (n as f64 / df[t as usize].max(1) as f64).ln().max(1e-3);
                (1.0 + (tf as f64).ln()) * idf
            })
            .collect();
        let norm = vals.iter().map(|v| v * v).sum::<f64>().sqrt();
        if norm > 0.0 {
            for v in &mut vals {
                *v /= norm;
            }
        }
        for (&(t, _), &v) in terms.iter().zip(&vals) {
            builder.push(i, t as usize, v);
        }
    }
    let x = builder.build();

    // Planted model over mid-frequency terms (~0.4% of vocabulary).
    let support = (k / 250).max(16);
    let model = PlantedModel::draw(&x, support, &mut rng);
    let scores = model.scores(&x);
    let n_pos = ((N_POSITIVE as f64 / N_SAMPLES as f64) * n as f64).round() as usize;
    let y = labels_with_positive_count(&scores, n_pos.max(1), opts.label_noise, &mut rng);

    Dataset {
        x,
        y,
        name: "reuters-like".into(),
    }
}

/// Row (document) L2 norms — 1.0 after cosine normalization; exported
/// for dataset-statistics checks.
pub fn row_norms(ds: &Dataset) -> Vec<f64> {
    let csr = CsrMatrix::from_csc(&ds.x);
    (0..ds.n_samples())
        .map(|i| {
            let (_, vals) = csr.row(i);
            vals.iter().map(|v| v * v).sum::<f64>().sqrt()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_scale_statistics() {
        let opts = GenOptions {
            scale: 0.02,
            ..Default::default()
        };
        let ds = reuters_like(&opts);
        assert_eq!(ds.n_samples(), 477);
        assert_eq!(ds.n_features(), 945);
        // mean nnz per feature in the right regime (Zipf tail leaves some
        // terms rare; the mean is what Table 3 reports)
        let mean = ds.x.mean_col_nnz();
        assert!(
            (mean - MEAN_NNZ_PER_FEATURE).abs() < MEAN_NNZ_PER_FEATURE * 0.35,
            "mean {mean}"
        );
        // rows cosine-normalized
        for nrm in row_norms(&ds) {
            assert!(nrm == 0.0 || (nrm - 1.0).abs() < 1e-9, "row norm {nrm}");
        }
        // label balance ~45%
        let pos = ds.y.iter().filter(|&&v| v > 0.0).count();
        let frac = pos as f64 / ds.n_samples() as f64;
        assert!((frac - 0.452).abs() < 0.1, "frac {frac}");
    }

    #[test]
    fn values_positive_and_bounded() {
        let ds = reuters_like(&GenOptions {
            scale: 0.01,
            ..Default::default()
        });
        for j in 0..ds.n_features() {
            let (_, vals) = ds.x.col(j);
            assert!(vals.iter().all(|&v| v > 0.0 && v <= 1.0 + 1e-12));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let opts = GenOptions {
            scale: 0.01,
            ..Default::default()
        };
        let a = reuters_like(&opts);
        let b = reuters_like(&opts);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn zipf_popularity_is_skewed() {
        let ds = reuters_like(&GenOptions {
            scale: 0.02,
            ..Default::default()
        });
        let mut nnz: Vec<usize> = (0..ds.n_features()).map(|j| ds.x.col_nnz(j)).collect();
        nnz.sort_unstable_by(|a, b| b.cmp(a));
        let head: usize = nnz[..20].iter().sum();
        let tail: usize = nnz[nnz.len() - 20..].iter().sum();
        assert!(head > 5 * (tail + 1), "head {head} tail {tail}");
    }
}
