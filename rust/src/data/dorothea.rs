//! Synthetic twin of DOROTHEA (Guyon et al. 2004), the NIPS'03 drug
//! discovery set used in the paper's evaluation.
//!
//! Published statistics reproduced at scale 1.0 (paper Table 3):
//!   * 800 samples (compounds), 100 000 features (molecular fragments)
//!   * binary feature matrix, mean 7.3 nonzeros per feature
//!   * 78 / 800 positive labels (binds to thrombin)
//!
//! Construction: compound "promiscuity" (how many fragments a compound
//! contains) is log-normally skewed; each fragment fires on
//! `1 + Poisson(6.3)` compounds drawn by promiscuity; labels come from a
//! planted sparse logistic model over ~100 informative fragments with 2%
//! flip noise (DESIGN.md §4).

use super::planted::{labels_with_positive_count, PlantedModel};
use super::synth::{binary_by_columns, WeightedSampler};
use super::GenOptions;
use crate::sparse::io::Dataset;
use crate::util::Pcg64;

/// Full-scale dimensions (paper Table 3).
pub const N_SAMPLES: usize = 800;
pub const N_FEATURES: usize = 100_000;
pub const MEAN_NNZ_PER_FEATURE: f64 = 7.3;
pub const N_POSITIVE: usize = 78;
/// The paper's chosen regularization for this dataset.
pub const PAPER_LAMBDA: f64 = 1e-4;

/// Generate the DOROTHEA twin. `opts.scale` shrinks both dimensions.
pub fn dorothea_like(opts: &GenOptions) -> Dataset {
    let n = opts.scaled(N_SAMPLES);
    let k = opts.scaled(N_FEATURES);
    let mut rng = Pcg64::new(opts.seed, 0xD0107);

    // Compound promiscuity: moderately heavy-tailed, like real fragment
    // data (sigma tuned so the full-scale coloring lands near the
    // paper's ~16 features/color — see EXPERIMENTS.md Table 3).
    let row_sampler = WeightedSampler::lognormal(n, 0.7, &mut rng);

    // Column support: 1 + Poisson(mean - 1) keeps every fragment alive
    // and the mean at 7.3.
    let mean = MEAN_NNZ_PER_FEATURE;
    let x = binary_by_columns(n, k, &row_sampler, &mut rng, |_, r| {
        1 + r.next_poisson(mean - 1.0) as usize
    });

    // Planted model on ~0.1% of fragments (about 100 at full scale).
    let support = (k / 1000).max(8);
    let model = PlantedModel::draw(&x, support, &mut rng);
    let scores = model.scores(&x);
    let n_pos = ((N_POSITIVE as f64 / N_SAMPLES as f64) * n as f64).round() as usize;
    let y = labels_with_positive_count(&scores, n_pos.max(1), opts.label_noise, &mut rng);

    Dataset {
        x,
        y,
        name: "dorothea-like".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_scale_statistics() {
        let opts = GenOptions {
            scale: 0.05,
            ..Default::default()
        };
        let ds = dorothea_like(&opts);
        assert_eq!(ds.n_samples(), 40);
        assert_eq!(ds.n_features(), 5000);
        // binary values
        for j in 0..ds.n_features() {
            let (_, vals) = ds.x.col(j);
            assert!(vals.iter().all(|&v| v == 1.0));
        }
        // mean nnz per feature close to 7.3 (Poisson sampling noise)
        let mean = ds.x.mean_col_nnz();
        assert!((mean - MEAN_NNZ_PER_FEATURE).abs() < 0.8, "mean {mean}");
        // label balance ~9.75% positive
        let pos = ds.y.iter().filter(|&&v| v > 0.0).count();
        let frac = pos as f64 / ds.n_samples() as f64;
        assert!((frac - 0.0975).abs() < 0.08, "frac {frac}");
    }

    #[test]
    fn deterministic_given_seed() {
        let opts = GenOptions {
            scale: 0.02,
            ..Default::default()
        };
        let a = dorothea_like(&opts);
        let b = dorothea_like(&opts);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let other = dorothea_like(&GenOptions {
            seed: 1,
            ..opts
        });
        assert_ne!(a.x, other.x);
    }

    #[test]
    fn labels_are_signs() {
        let ds = dorothea_like(&GenOptions {
            scale: 0.02,
            ..Default::default()
        });
        assert!(ds.y.iter().all(|&v| v == 1.0 || v == -1.0));
    }
}
