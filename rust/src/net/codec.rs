//! Zero-copy encode/decode primitives for the reconcile wire protocol.
//!
//! Modeled on s2n-codec's `EncoderValue`/`DecoderValue` discipline:
//! encoding appends into a caller-owned, reusable byte buffer (no
//! intermediate allocation per value), decoding walks a **borrowed**
//! input slice through a checked cursor and hands multi-byte regions
//! back as sub-slices of the input (`DecoderBuffer::take`) — a decoded
//! frame never copies its payload. Every read is bounds-checked and
//! every failure is a typed [`DecodeError`]; malformed or truncated
//! input can never panic (pinned by the adversarial property tests in
//! `rust/tests/net_link.rs`).
//!
//! All integers and floats are little-endian, the native order of every
//! target this crate ships on — `to_le_bytes`/`from_le_bytes` make the
//! layout explicit without paying a swap anywhere it matters.

/// Why a decode failed. Carried into
/// [`LinkFault::Protocol`](crate::shard::engine::LinkFault::Protocol)
/// via [`DecodeError::reason`] when a wire link hits malformed bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeError {
    /// Input ended before a declared or implied field: `needed` more
    /// bytes than the `have` remaining.
    Truncated { needed: usize, have: usize },
    /// The 4-byte frame magic was wrong — not a GenCD frame at all.
    BadMagic(u32),
    /// Unknown frame tag byte.
    BadTag(u8),
    /// A declared length or count is inconsistent with the payload
    /// (e.g. the length prefix disagrees with the actual byte count, or
    /// a dirty-chunk count exceeds the chunk total).
    BadLength,
    /// A field held an out-of-domain value (named by the codec site).
    BadValue(&'static str),
}

impl DecodeError {
    /// Static one-line cause, suitable for
    /// [`LinkFault::Protocol`](crate::shard::engine::LinkFault::Protocol)
    /// (which carries `&'static str` so [`LinkFault`] stays `Copy`).
    ///
    /// [`LinkFault`]: crate::shard::engine::LinkFault
    pub fn reason(&self) -> &'static str {
        match self {
            DecodeError::Truncated { .. } => "wire frame truncated",
            DecodeError::BadMagic(_) => "wire frame has bad magic",
            DecodeError::BadTag(_) => "wire frame has unknown tag",
            DecodeError::BadLength => "wire frame length mismatch",
            DecodeError::BadValue(what) => what,
        }
    }
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::Truncated { needed, have } => {
                write!(f, "truncated frame: needed {needed} more bytes, have {have}")
            }
            DecodeError::BadMagic(m) => write!(f, "bad frame magic {m:#010x}"),
            DecodeError::BadTag(t) => write!(f, "unknown frame tag {t}"),
            DecodeError::BadLength => write!(f, "frame length prefix disagrees with payload"),
            DecodeError::BadValue(what) => write!(f, "{what}"),
        }
    }
}

impl std::error::Error for DecodeError {}

/// Append-only encoder over a caller-owned `Vec<u8>`. The buffer is
/// reused across rounds by the wire links (`clear()` + re-encode), so
/// steady-state encoding allocates nothing.
pub struct EncoderBuffer<'a> {
    buf: &'a mut Vec<u8>,
}

impl<'a> EncoderBuffer<'a> {
    pub fn new(buf: &'a mut Vec<u8>) -> Self {
        Self { buf }
    }

    /// Bytes written so far (the underlying buffer's length).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    /// Overwrite a previously written little-endian `u32` at `at` —
    /// how length prefixes are backpatched after the payload is known.
    pub fn patch_u32(&mut self, at: usize, v: u32) {
        self.buf[at..at + 4].copy_from_slice(&v.to_le_bytes());
    }
}

/// Checked cursor over a borrowed input slice. Multi-byte regions come
/// back as sub-slices of the input (`take`), so decoding is zero-copy;
/// scalar reads copy the handful of bytes they decode.
#[derive(Clone, Copy, Debug)]
pub struct DecoderBuffer<'a> {
    bytes: &'a [u8],
}

impl<'a> DecoderBuffer<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        Self { bytes }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    /// Consume `len` bytes, returning them as a sub-slice of the input.
    pub fn take(&mut self, len: usize) -> Result<&'a [u8], DecodeError> {
        if self.bytes.len() < len {
            return Err(DecodeError::Truncated {
                needed: len - self.bytes.len(),
                have: self.bytes.len(),
            });
        }
        let (head, tail) = self.bytes.split_at(len);
        self.bytes = tail;
        Ok(head)
    }

    pub fn u8(&mut self) -> Result<u8, DecodeError> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16, DecodeError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32, DecodeError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, DecodeError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32, DecodeError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, DecodeError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// A value that knows how to append itself to an [`EncoderBuffer`]
/// (s2n-codec's `EncoderValue` shape).
pub trait EncoderValue {
    fn encode(&self, buf: &mut EncoderBuffer<'_>);

    /// Exact byte count `encode` will append — used to pre-size buffers
    /// and to write length prefixes without backpatching where the size
    /// is known up front.
    fn encoded_len(&self) -> usize;
}

/// A value that decodes itself off a [`DecoderBuffer`], borrowing any
/// bulk regions from the input (s2n-codec's `DecoderValue` shape).
pub trait DecoderValue<'a>: Sized {
    fn decode(buf: &mut DecoderBuffer<'a>) -> Result<Self, DecodeError>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_round_trip() {
        let mut bytes = Vec::new();
        let mut e = EncoderBuffer::new(&mut bytes);
        e.u8(7);
        e.u16(0xBEEF);
        e.u32(0xDEAD_BEEF);
        e.u64(u64::MAX - 3);
        e.f32(1.5);
        e.f64(-std::f64::consts::PI);
        e.bytes(&[1, 2, 3]);
        assert_eq!(e.len(), 1 + 2 + 4 + 8 + 4 + 8 + 3);
        let mut d = DecoderBuffer::new(&bytes);
        assert_eq!(d.u8().unwrap(), 7);
        assert_eq!(d.u16().unwrap(), 0xBEEF);
        assert_eq!(d.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(d.u64().unwrap(), u64::MAX - 3);
        assert_eq!(d.f32().unwrap(), 1.5);
        assert_eq!(d.f64().unwrap().to_bits(), (-std::f64::consts::PI).to_bits());
        assert_eq!(d.take(3).unwrap(), &[1, 2, 3]);
        assert!(d.is_empty());
    }

    #[test]
    fn take_is_zero_copy() {
        let bytes = vec![9u8; 32];
        let mut d = DecoderBuffer::new(&bytes);
        let head = d.take(16).unwrap();
        // same allocation: the decoded region is a sub-slice, not a copy
        assert_eq!(head.as_ptr(), bytes.as_ptr());
        assert_eq!(d.remaining(), 16);
    }

    #[test]
    fn truncation_is_a_clean_error() {
        let bytes = [1u8, 2, 3];
        let mut d = DecoderBuffer::new(&bytes);
        assert_eq!(
            d.u64(),
            Err(DecodeError::Truncated { needed: 5, have: 3 })
        );
        // a failed take consumes nothing
        assert_eq!(d.remaining(), 3);
        assert_eq!(d.u16().unwrap(), 0x0201);
    }

    #[test]
    fn patch_u32_backpatches() {
        let mut bytes = Vec::new();
        let mut e = EncoderBuffer::new(&mut bytes);
        e.u32(0); // placeholder
        e.bytes(b"abc");
        let len = (e.len() - 4) as u32;
        e.patch_u32(0, len);
        let mut d = DecoderBuffer::new(&bytes);
        assert_eq!(d.u32().unwrap(), 3);
    }

    #[test]
    fn reasons_are_static_and_stable() {
        assert_eq!(
            DecodeError::Truncated { needed: 1, have: 0 }.reason(),
            "wire frame truncated"
        );
        assert_eq!(DecodeError::BadMagic(1).reason(), "wire frame has bad magic");
        assert_eq!(DecodeError::BadTag(9).reason(), "wire frame has unknown tag");
    }
}
