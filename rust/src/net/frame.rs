//! Reconcile frame layout: the concrete bytes that cross the wire.
//!
//! The authoritative byte-by-byte specification lives in
//! [`crate::shard::engine`] §Wire format — this module implements it
//! and the round-trip property tests in `rust/tests/net_link.rs` cite
//! it. Summary:
//!
//! ```text
//! offset  size  field
//! 0       4     magic  = 0x47434431 ("GCD1", little-endian u32)
//! 4       1     tag    (1 delta, 2 decision, 3 arrive, 4 release, 5 poison)
//! 5       1     flags  (bit 0: 0 = exact f64 values, 1 = f32-quantized)
//! 6       2     shard  (u16, sender's shard index)
//! 8       8     round  (u64, reconcile round / crossing counter)
//! 16      4     payload_len (u32, bytes after this field)
//! 20      ...   payload
//! ```
//!
//! A **delta** payload carries absolute dirty-chunk values (see
//! §Wire format for why absolute, not incremental: redelivery is then
//! idempotent). A **decision** payload carries the coordinator's fold
//! verdict. The control tags (arrive/release/poison) have empty
//! payloads and only exist on the TCP transport's control plane.

use crate::coordinator::convergence::StopReason;
use crate::net::codec::{DecodeError, DecoderBuffer, DecoderValue, EncoderBuffer, EncoderValue};
use crate::util::par::DIRTY_CHUNK_ELEMS;

/// Frame magic: `b"GCD1"` read as a little-endian u32. First bytes on
/// the wire of every frame; anything else is not speaking our protocol.
pub const MAGIC: u32 = u32::from_le_bytes(*b"GCD1");

/// Fixed header size: magic + tag + flags + shard + round + payload_len.
pub const HEADER_LEN: usize = 20;

/// Wire representation of the z-replica values inside delta frames.
///
/// `Exact` ships every f64 bit-for-bit, so a loopback solve is
/// bit-identical to the in-memory `BarrierLink` protocol. `F32`
/// quantizes each value through `f32` (half the delta bytes) at the
/// cost of ~1e-7 relative error per crossing — an escape hatch from
/// bit-exactness that trades reproducibility for bandwidth.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WirePrecision {
    #[default]
    Exact,
    F32,
}

impl WirePrecision {
    /// Config-file / CLI spelling (`wire_precision = "exact" | "f32"`).
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "exact" => Some(WirePrecision::Exact),
            "f32" => Some(WirePrecision::F32),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            WirePrecision::Exact => "exact",
            WirePrecision::F32 => "f32",
        }
    }

    /// Bytes per encoded value.
    pub fn elem_len(self) -> usize {
        match self {
            WirePrecision::Exact => 8,
            WirePrecision::F32 => 4,
        }
    }

    fn flags(self) -> u8 {
        match self {
            WirePrecision::Exact => 0,
            WirePrecision::F32 => 1,
        }
    }
}

/// Frame discriminator (header byte 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameTag {
    /// Dirty-chunk delta payload (shard → peers).
    Delta = 1,
    /// Coordinator fold decision (shard 0 → peers).
    Decision = 2,
    /// Control plane: "I reached crossing `round`" (TCP only).
    Arrive = 3,
    /// Control plane: "all parties arrived, proceed" (TCP only).
    Release = 4,
    /// Control plane: "a peer is dying, poison the exchange" (TCP only).
    Poison = 5,
}

impl FrameTag {
    fn from_u8(v: u8) -> Result<Self, DecodeError> {
        match v {
            1 => Ok(FrameTag::Delta),
            2 => Ok(FrameTag::Decision),
            3 => Ok(FrameTag::Arrive),
            4 => Ok(FrameTag::Release),
            5 => Ok(FrameTag::Poison),
            other => Err(DecodeError::BadTag(other)),
        }
    }
}

/// Decoded frame header (bytes 0..20).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameHeader {
    pub tag: FrameTag,
    pub precision: WirePrecision,
    pub shard: u16,
    pub round: u64,
    pub payload_len: u32,
}

impl<'a> DecoderValue<'a> for FrameHeader {
    fn decode(buf: &mut DecoderBuffer<'a>) -> Result<Self, DecodeError> {
        let magic = buf.u32()?;
        if magic != MAGIC {
            return Err(DecodeError::BadMagic(magic));
        }
        let tag = FrameTag::from_u8(buf.u8()?)?;
        let flags = buf.u8()?;
        let precision = if flags & 1 == 0 {
            WirePrecision::Exact
        } else {
            WirePrecision::F32
        };
        if flags & !1 != 0 {
            return Err(DecodeError::BadValue("wire frame has unknown flag bits"));
        }
        let shard = buf.u16()?;
        let round = buf.u64()?;
        let payload_len = buf.u32()?;
        Ok(FrameHeader {
            tag,
            precision,
            shard,
            round,
            payload_len,
        })
    }
}

fn encode_header(
    e: &mut EncoderBuffer<'_>,
    tag: FrameTag,
    precision: WirePrecision,
    shard: usize,
    round: u64,
) -> usize {
    assert!(shard <= u16::MAX as usize, "shard index exceeds wire u16");
    e.u32(MAGIC);
    e.u8(tag as u8);
    e.u8(precision.flags());
    e.u16(shard as u16);
    e.u64(round);
    let patch_at = e.len();
    e.u32(0); // payload_len, backpatched by the caller
    patch_at
}

/// Encode a control frame (empty payload) into `out`. Returns the
/// frame's total byte length.
pub fn encode_control(out: &mut Vec<u8>, tag: FrameTag, shard: usize, round: u64) -> usize {
    debug_assert!(matches!(
        tag,
        FrameTag::Arrive | FrameTag::Release | FrameTag::Poison
    ));
    let start = out.len();
    let mut e = EncoderBuffer::new(out);
    let patch_at = encode_header(&mut e, tag, WirePrecision::Exact, shard, round);
    e.patch_u32(patch_at, 0);
    out.len() - start
}

/// Encode a delta frame: the dirty chunks of an `n`-element replica,
/// absolute values, ascending chunk order.
///
/// `is_dirty(c)` answers for chunks `0..n_chunks` (chunk = 16
/// consecutive f64s, [`DIRTY_CHUNK_ELEMS`]); `value(i)` reads element
/// `i` of the replica. A dense exchange (no dirty tracking) passes
/// `|_| true`. Returns the frame's total byte length.
pub fn encode_delta(
    out: &mut Vec<u8>,
    shard: usize,
    round: u64,
    precision: WirePrecision,
    n: usize,
    is_dirty: impl Fn(usize) -> bool,
    value: impl Fn(usize) -> f64,
) -> usize {
    let start = out.len();
    let n_chunks = n.div_ceil(DIRTY_CHUNK_ELEMS);
    assert!(n_chunks <= u32::MAX as usize, "replica exceeds wire chunk count");
    let mut e = EncoderBuffer::new(out);
    let patch_at = encode_header(&mut e, FrameTag::Delta, precision, shard, round);
    let payload_start = e.len();
    e.u64(n as u64);
    e.u32(n_chunks as u32);
    let n_dirty_at = e.len();
    e.u32(0); // n_dirty, backpatched below
    // bitmap: one bit per chunk, little-endian u64 words, trailing bits 0
    let words = n_chunks.div_ceil(64);
    let mut n_dirty = 0u32;
    for w in 0..words {
        let mut bits = 0u64;
        for b in 0..64 {
            let c = w * 64 + b;
            if c < n_chunks && is_dirty(c) {
                bits |= 1 << b;
                n_dirty += 1;
            }
        }
        e.u64(bits);
    }
    e.patch_u32(n_dirty_at, n_dirty);
    // packed chunks, ascending; the last chunk truncates to n
    for c in 0..n_chunks {
        if !is_dirty(c) {
            continue;
        }
        let base = c * DIRTY_CHUNK_ELEMS;
        let end = (base + DIRTY_CHUNK_ELEMS).min(n);
        for i in base..end {
            match precision {
                WirePrecision::Exact => e.f64(value(i)),
                WirePrecision::F32 => e.f32(value(i) as f32),
            }
        }
    }
    let payload_len = e.len() - payload_start;
    assert!(payload_len <= u32::MAX as usize, "delta payload exceeds wire u32");
    e.patch_u32(patch_at, payload_len as u32);
    out.len() - start
}

/// A decoded delta frame, borrowing its bitmap and chunk bytes from the
/// input buffer (zero-copy; values are only materialized by [`apply`]).
///
/// [`apply`]: DeltaFrameRef::apply
#[derive(Clone, Copy, Debug)]
pub struct DeltaFrameRef<'a> {
    pub shard: u16,
    pub round: u64,
    pub precision: WirePrecision,
    /// Replica length in elements.
    pub n: usize,
    /// Total chunks (`ceil(n / 16)`).
    pub n_chunks: usize,
    /// Dirty chunks actually carried.
    pub n_dirty: usize,
    bitmap: &'a [u8],
    chunks: &'a [u8],
}

impl<'a> DeltaFrameRef<'a> {
    /// Whether chunk `c` is present in this frame.
    pub fn is_dirty(&self, c: usize) -> bool {
        if c >= self.n_chunks {
            return false;
        }
        let word = u64::from_le_bytes(self.bitmap[c / 64 * 8..c / 64 * 8 + 8].try_into().unwrap());
        word >> (c % 64) & 1 == 1
    }

    /// Invoke `set(i, v)` for every element of every carried chunk, in
    /// ascending element order. Values are absolute replica contents —
    /// applying the same frame twice is a no-op the second time, which
    /// is what makes duplicate delivery harmless.
    pub fn apply(&self, mut set: impl FnMut(usize, f64)) {
        let elem = self.precision.elem_len();
        let mut off = 0usize;
        for c in 0..self.n_chunks {
            if !self.is_dirty(c) {
                continue;
            }
            let base = c * DIRTY_CHUNK_ELEMS;
            let end = (base + DIRTY_CHUNK_ELEMS).min(self.n);
            for i in base..end {
                let v = match self.precision {
                    WirePrecision::Exact => {
                        f64::from_le_bytes(self.chunks[off..off + 8].try_into().unwrap())
                    }
                    WirePrecision::F32 => {
                        f32::from_le_bytes(self.chunks[off..off + 4].try_into().unwrap()) as f64
                    }
                };
                set(i, v);
                off += elem;
            }
        }
        debug_assert_eq!(off, self.chunks.len());
    }

    fn decode_payload(
        header: &FrameHeader,
        buf: &mut DecoderBuffer<'a>,
    ) -> Result<Self, DecodeError> {
        let n64 = buf.u64()?;
        let n: usize = n64
            .try_into()
            .map_err(|_| DecodeError::BadValue("delta replica length overflows usize"))?;
        let n_chunks = buf.u32()? as usize;
        if n_chunks != n.div_ceil(DIRTY_CHUNK_ELEMS) {
            return Err(DecodeError::BadLength);
        }
        let n_dirty = buf.u32()? as usize;
        if n_dirty > n_chunks {
            return Err(DecodeError::BadLength);
        }
        let words = n_chunks.div_ceil(64);
        let bitmap = buf.take(words * 8)?;
        // validate: popcount matches n_dirty, no bits past n_chunks
        let mut pop = 0usize;
        for (w, word_bytes) in bitmap.chunks_exact(8).enumerate() {
            let word = u64::from_le_bytes(word_bytes.try_into().unwrap());
            let valid = n_chunks - (w * 64).min(n_chunks);
            let mask = if valid >= 64 { u64::MAX } else { (1u64 << valid) - 1 };
            if word & !mask != 0 {
                return Err(DecodeError::BadValue("delta bitmap has bits past chunk count"));
            }
            pop += word.count_ones() as usize;
        }
        if pop != n_dirty {
            return Err(DecodeError::BadLength);
        }
        // total carried elements: full chunks, except a possibly short tail
        let frame = DeltaFrameRef {
            shard: header.shard,
            round: header.round,
            precision: header.precision,
            n,
            n_chunks,
            n_dirty,
            bitmap,
            chunks: &[],
        };
        let mut elems = 0usize;
        for c in 0..n_chunks {
            if frame.is_dirty(c) {
                let base = c * DIRTY_CHUNK_ELEMS;
                elems += (base + DIRTY_CHUNK_ELEMS).min(n) - base;
            }
        }
        let chunks = buf.take(elems * header.precision.elem_len())?;
        if !buf.is_empty() {
            return Err(DecodeError::BadLength);
        }
        Ok(DeltaFrameRef { chunks, ..frame })
    }
}

/// The coordinator's fold decision, mirrored onto the wire so every
/// pool acts on exactly the bytes that crossed (not on shared memory
/// the wire never saw).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecisionRecord {
    /// Reconcile round the decision belongs to (echoes the header round).
    pub round: u64,
    /// Iterations until the next reconcile (adaptive cadence output).
    pub next_gap: u64,
    /// Stop verdict, if the coordinator called the solve.
    pub stop: Option<StopReason>,
}

// StopReason wire codes (§Wire format): 0 reserved for "no stop".
fn stop_to_code(stop: Option<StopReason>) -> u8 {
    match stop {
        None => 0,
        Some(StopReason::MaxIters) => 1,
        Some(StopReason::MaxSeconds) => 2,
        Some(StopReason::Tolerance) => 3,
        Some(StopReason::Diverged) => 4,
        Some(StopReason::Observer) => 5,
        Some(StopReason::Converged) => 6,
        Some(StopReason::ShardFailed) => 7,
    }
}

fn stop_from_code(code: u8) -> Result<Option<StopReason>, DecodeError> {
    Ok(match code {
        0 => None,
        1 => Some(StopReason::MaxIters),
        2 => Some(StopReason::MaxSeconds),
        3 => Some(StopReason::Tolerance),
        4 => Some(StopReason::Diverged),
        5 => Some(StopReason::Observer),
        6 => Some(StopReason::Converged),
        7 => Some(StopReason::ShardFailed),
        _ => return Err(DecodeError::BadValue("decision frame has unknown stop code")),
    })
}

impl EncoderValue for DecisionRecord {
    fn encode(&self, buf: &mut EncoderBuffer<'_>) {
        buf.u64(self.round);
        buf.u64(self.next_gap);
        buf.u8(stop_to_code(self.stop));
    }

    fn encoded_len(&self) -> usize {
        8 + 8 + 1
    }
}

impl<'a> DecoderValue<'a> for DecisionRecord {
    fn decode(buf: &mut DecoderBuffer<'a>) -> Result<Self, DecodeError> {
        let round = buf.u64()?;
        let next_gap = buf.u64()?;
        let stop = stop_from_code(buf.u8()?)?;
        Ok(DecisionRecord {
            round,
            next_gap,
            stop,
        })
    }
}

/// Encode a decision frame. Returns the frame's total byte length.
pub fn encode_decision(out: &mut Vec<u8>, shard: usize, rec: &DecisionRecord) -> usize {
    let start = out.len();
    let mut e = EncoderBuffer::new(out);
    let patch_at = encode_header(&mut e, FrameTag::Decision, WirePrecision::Exact, shard, rec.round);
    rec.encode(&mut e);
    e.patch_u32(patch_at, rec.encoded_len() as u32);
    out.len() - start
}

/// A fully decoded frame, payload borrowed from the input.
#[derive(Clone, Copy, Debug)]
pub enum Frame<'a> {
    Delta(DeltaFrameRef<'a>),
    Decision { shard: u16, record: DecisionRecord },
    Control { tag: FrameTag, shard: u16, round: u64 },
}

/// Decode one complete frame from `bytes`. The slice must contain
/// exactly one frame (header + declared payload, nothing after) — the
/// transports read the 20-byte header first, then `payload_len` more
/// bytes, and hand the whole region here. Any malformation is a clean
/// [`DecodeError`]; this function never panics on untrusted input.
pub fn decode_frame(bytes: &[u8]) -> Result<Frame<'_>, DecodeError> {
    let mut buf = DecoderBuffer::new(bytes);
    let header = FrameHeader::decode(&mut buf)?;
    if buf.remaining() != header.payload_len as usize {
        return Err(DecodeError::BadLength);
    }
    match header.tag {
        FrameTag::Delta => Ok(Frame::Delta(DeltaFrameRef::decode_payload(&header, &mut buf)?)),
        FrameTag::Decision => {
            let record = DecisionRecord::decode(&mut buf)?;
            if !buf.is_empty() {
                return Err(DecodeError::BadLength);
            }
            Ok(Frame::Decision {
                shard: header.shard,
                record,
            })
        }
        tag @ (FrameTag::Arrive | FrameTag::Release | FrameTag::Poison) => {
            if header.payload_len != 0 {
                return Err(DecodeError::BadLength);
            }
            Ok(Frame::Control {
                tag,
                shard: header.shard,
                round: header.round,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_delta(
        n: usize,
        dirty: &[usize],
        precision: WirePrecision,
    ) -> (Vec<u8>, Vec<(usize, f64)>) {
        let mut out = Vec::new();
        let len = encode_delta(
            &mut out,
            3,
            41,
            precision,
            n,
            |c| dirty.contains(&c),
            |i| i as f64 * 0.5 - 7.0,
        );
        assert_eq!(len, out.len());
        let frame = match decode_frame(&out).unwrap() {
            Frame::Delta(d) => d,
            other => panic!("expected delta, got {other:?}"),
        };
        assert_eq!(frame.shard, 3);
        assert_eq!(frame.round, 41);
        assert_eq!(frame.n, n);
        assert_eq!(frame.n_dirty, dirty.len());
        let mut got = Vec::new();
        frame.apply(|i, v| got.push((i, v)));
        (out, got)
    }

    #[test]
    fn delta_round_trip_exact() {
        let (_, got) = roundtrip_delta(40, &[0, 2], WirePrecision::Exact);
        // chunk 0 = elems 0..16, chunk 2 = elems 32..40 (short tail)
        assert_eq!(got.len(), 16 + 8);
        assert_eq!(got[0], (0, -7.0));
        assert_eq!(got[16], (32, 32.0 * 0.5 - 7.0));
        assert_eq!(got.last().unwrap().0, 39);
    }

    #[test]
    fn delta_round_trip_empty_and_dense() {
        let (_, got) = roundtrip_delta(33, &[], WirePrecision::Exact);
        assert!(got.is_empty());
        let (_, got) = roundtrip_delta(33, &[0, 1, 2], WirePrecision::Exact);
        assert_eq!(got.len(), 33);
    }

    #[test]
    fn delta_f32_quantizes() {
        let mut out = Vec::new();
        encode_delta(&mut out, 0, 0, WirePrecision::F32, 4, |_| true, |_| {
            std::f64::consts::PI
        });
        let frame = match decode_frame(&out).unwrap() {
            Frame::Delta(d) => d,
            _ => unreachable!(),
        };
        let mut v = 0.0;
        frame.apply(|_, x| v = x);
        assert_eq!(v, std::f64::consts::PI as f32 as f64);
        assert_ne!(v, std::f64::consts::PI);
    }

    #[test]
    fn decision_round_trip() {
        for stop in [
            None,
            Some(StopReason::Converged),
            Some(StopReason::ShardFailed),
            Some(StopReason::MaxIters),
        ] {
            let rec = DecisionRecord {
                round: 9,
                next_gap: 128,
                stop,
            };
            let mut out = Vec::new();
            encode_decision(&mut out, 0, &rec);
            match decode_frame(&out).unwrap() {
                Frame::Decision { shard: 0, record } => assert_eq!(record, rec),
                other => panic!("expected decision, got {other:?}"),
            }
        }
    }

    #[test]
    fn control_round_trip() {
        let mut out = Vec::new();
        encode_control(&mut out, FrameTag::Arrive, 7, 1234);
        assert_eq!(out.len(), HEADER_LEN);
        match decode_frame(&out).unwrap() {
            Frame::Control {
                tag: FrameTag::Arrive,
                shard: 7,
                round: 1234,
            } => {}
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn every_truncation_is_a_clean_error() {
        let mut out = Vec::new();
        encode_delta(&mut out, 1, 5, WirePrecision::Exact, 40, |c| c != 1, |i| i as f64);
        for cut in 0..out.len() {
            let err = decode_frame(&out[..cut]).unwrap_err();
            // any prefix decodes to an error, never a panic
            let _ = err.reason();
        }
    }

    #[test]
    fn corrupted_fields_are_rejected() {
        let mut out = Vec::new();
        encode_delta(&mut out, 0, 1, WirePrecision::Exact, 32, |_| true, |i| i as f64);

        let mut bad = out.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(decode_frame(&bad), Err(DecodeError::BadMagic(_))));

        let mut bad = out.clone();
        bad[4] = 99;
        assert!(matches!(decode_frame(&bad), Err(DecodeError::BadTag(99))));

        let mut bad = out.clone();
        bad[5] = 0x80; // unknown flag bit
        assert!(matches!(decode_frame(&bad), Err(DecodeError::BadValue(_))));

        // declared n_dirty disagrees with the bitmap popcount
        let mut bad = out.clone();
        bad[HEADER_LEN + 12] ^= 1;
        assert!(matches!(decode_frame(&bad), Err(DecodeError::BadLength)));

        // trailing garbage after a complete frame
        let mut bad = out.clone();
        bad.push(0);
        assert!(matches!(decode_frame(&bad), Err(DecodeError::BadLength)));
    }

    #[test]
    fn bitmap_bits_past_chunk_count_rejected() {
        let mut out = Vec::new();
        encode_delta(&mut out, 0, 0, WirePrecision::Exact, 20, |_| false, |_| 0.0);
        // n=20 → 2 chunks, 1 bitmap word at payload offset 16; set bit 2
        let bm_at = HEADER_LEN + 16;
        let mut bad = out.clone();
        bad[bm_at] |= 0b100;
        assert!(matches!(decode_frame(&bad), Err(DecodeError::BadValue(_))));
    }

    #[test]
    fn precision_names() {
        assert_eq!(WirePrecision::by_name("exact"), Some(WirePrecision::Exact));
        assert_eq!(WirePrecision::by_name("f32"), Some(WirePrecision::F32));
        assert_eq!(WirePrecision::by_name("f16"), None);
        assert_eq!(WirePrecision::Exact.name(), "exact");
        assert_eq!(WirePrecision::F32.name(), "f32");
    }
}
