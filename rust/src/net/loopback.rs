//! In-process wire transport: the full frame protocol with zero sockets.
//!
//! [`LoopbackLink`] layers the wire codec over any inner
//! [`ReconcileLink`] (the production [`BarrierLink`] by default, or
//! [`SimLink`](crate::sim::SimLink) to compose message-level faults
//! with link-level ones). Every reconcile exchange is routed through a
//! full **encode → frame → decode → apply** round trip on real bytes —
//! exactly what [`TcpLink`](crate::net::tcp::TcpLink) ships over a
//! socket — so `cargo test -q` exercises the complete protocol
//! deterministically and with no network.
//!
//! Under `wire_precision = exact` the round trip writes back the same
//! f64 bits it read, so a loopback solve is **bit-identical** to the
//! same solve on the inner link (pinned by `rust/tests/net_link.rs`).
//! Under `f32` the values every fold sees are quantized through the
//! wire format, reproducing a bandwidth-saving lossy transport inside
//! one process.
//!
//! A [`NetFaultPlan`] injects the failures only bytes can have —
//! truncated frames, duplicate delivery, mid-round disconnects — at
//! exact `(shard, round)` coordinates, with the same degrade-never-hang
//! contract as every other link fault. Setting the plan's
//! `heal_after_attempts` plus a link [`reconnect budget`] models the
//! recovery path deterministically: the dropped party "re-dials"
//! (burning budget attempts), and either heals — the frame is delivered
//! after all, exactly like [`TcpLink`]'s idempotent replay — or
//! exhausts its budget and poisons, reproducing retries-exhausted
//! without a socket or a clock.
//!
//! [`reconnect budget`]: LoopbackLink::with_reconnect_budget
//! [`TcpLink`]: crate::net::tcp::TcpLink

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::net::fault::NetFaultPlan;
use crate::net::frame::{self, DecisionRecord, Frame, WirePrecision};
use crate::shard::engine::{
    BarrierLink, DecisionPayload, DeltaPayload, LinkFault, ReconcileLink, WireCost,
};
use crate::util::par::CachePadded;

/// A [`ReconcileLink`] that serializes every exchange through the wire
/// codec while delegating the barrier crossings to an inner link. See
/// the module docs.
pub struct LoopbackLink<L: ReconcileLink = BarrierLink> {
    inner: L,
    precision: WirePrecision,
    faults: NetFaultPlan,
    /// Redial attempts each disconnected party may burn before the
    /// link gives up (0 = no reconnection, the pre-recover default).
    reconnect_budget: u32,
    /// Per-shard `(reconnects, attempts)` counters backing
    /// [`ReconcileLink::reconnect_stats`].
    reconnects: Vec<CachePadded<(AtomicU64, AtomicU64)>>,
    /// Per-shard encode buffers (padded: each shard's leader reuses its
    /// own lane every round, no cross-shard contention).
    lanes: Vec<CachePadded<Mutex<Vec<u8>>>>,
}

impl LoopbackLink<BarrierLink> {
    /// Loopback over the production barrier protocol: `parties` shards,
    /// the given spin budget and per-crossing timeout (`None` =
    /// effectively forever) — the same signature as
    /// [`BarrierLink::new`].
    pub fn new(
        parties: usize,
        spin: u32,
        timeout: Option<Duration>,
        precision: WirePrecision,
    ) -> Self {
        Self::over(BarrierLink::new(parties, spin, timeout), parties, precision)
    }
}

impl<L: ReconcileLink> LoopbackLink<L> {
    /// Loopback over an arbitrary inner link (e.g.
    /// [`SimLink`](crate::sim::SimLink), composing the scenario corpus
    /// with the wire protocol).
    pub fn over(inner: L, parties: usize, precision: WirePrecision) -> Self {
        Self {
            inner,
            precision,
            faults: NetFaultPlan::default(),
            reconnect_budget: 0,
            reconnects: (0..parties.max(1))
                .map(|_| CachePadded::new((AtomicU64::new(0), AtomicU64::new(0))))
                .collect(),
            lanes: (0..parties.max(1))
                .map(|_| CachePadded::new(Mutex::new(Vec::new())))
                .collect(),
        }
    }

    /// Attach a message-fault schedule.
    pub fn with_faults(mut self, faults: NetFaultPlan) -> Self {
        self.faults = faults;
        self
    }

    /// Grant each party up to `budget` redial attempts after a
    /// scheduled disconnect. A drop with `heal_after_attempts <= budget`
    /// heals (the frame is delivered after the simulated re-handshake);
    /// a drop needing more attempts than the budget burns the whole
    /// budget and poisons — the deterministic twin of
    /// [`TcpLink`](crate::net::tcp::TcpLink)'s retries-exhausted path.
    pub fn with_reconnect_budget(mut self, budget: u32) -> Self {
        self.reconnect_budget = budget;
        self
    }

    /// The inner link (e.g. to read a [`SimLink`](crate::sim::SimLink)
    /// event log after the solve).
    pub fn inner(&self) -> &L {
        &self.inner
    }

    fn lane(&self, s: usize) -> std::sync::MutexGuard<'_, Vec<u8>> {
        self.lanes[s].lock().unwrap_or_else(|e| e.into_inner())
    }

    fn protocol_fault(&self, reason: &'static str) -> LinkFault {
        // a malformed frame dooms the exchange for everyone: poison so
        // peers escape their crossings instead of waiting on us
        self.inner.poison();
        LinkFault::Protocol(reason)
    }
}

impl<L: ReconcileLink> ReconcileLink for LoopbackLink<L> {
    fn init(&self, s: usize) -> Result<(), LinkFault> {
        self.inner.init(s)
    }

    fn arrive(&self, s: usize, round: usize) -> Result<(), LinkFault> {
        self.inner.arrive(s, round)
    }

    fn publish_fold(&self, s: usize, round: usize) -> Result<(), LinkFault> {
        self.inner.publish_fold(s, round)
    }

    fn publish_decision(&self, s: usize, round: usize) -> Result<(), LinkFault> {
        self.inner.publish_decision(s, round)
    }

    fn fold_order(&self, s: usize, round: usize, shards: usize) -> Vec<usize> {
        self.inner.fold_order(s, round, shards)
    }

    fn wire_precision(&self) -> Option<&'static str> {
        Some(self.precision.name())
    }

    fn reconnect_stats(&self, s: usize) -> (u64, u64) {
        match self.reconnects.get(s) {
            Some(cell) => (cell.0.load(Ordering::Relaxed), cell.1.load(Ordering::Relaxed)),
            None => (0, 0),
        }
    }

    fn poison(&self) {
        self.inner.poison();
    }

    fn wire_delta(&self, s: usize, payload: &DeltaPayload<'_>) -> Result<WireCost, LinkFault> {
        let t0 = Instant::now();
        let z = payload.z;
        let mut lane = self.lane(s);
        lane.clear();
        let tx = match payload.dirty {
            Some(d) => frame::encode_delta(
                &mut lane,
                s,
                payload.round as u64,
                self.precision,
                payload.n,
                |c| d.is_dirty(c),
                |i| z.get(i),
            ),
            // dense exchange: every chunk is implicitly dirty
            None => frame::encode_delta(
                &mut lane,
                s,
                payload.round as u64,
                self.precision,
                payload.n,
                |_| true,
                |i| z.get(i),
            ),
        };
        if self.faults.disconnects(s, payload.round) {
            let need = self.faults.heal_after_attempts;
            if need > 0 && need <= self.reconnect_budget {
                // the drop heals within budget: burn the redial
                // attempts, count one successful reconnect, and fall
                // through — the frame is (re)delivered below, which is
                // safe because delta frames carry absolute values
                let cell = &self.reconnects[s];
                cell.1.fetch_add(need as u64, Ordering::Relaxed);
                cell.0.fetch_add(1, Ordering::Relaxed);
            } else {
                // permanent drop, or a heal point beyond the budget:
                // burn whatever budget existed, then peers see a dead
                // link — we report it as such
                self.reconnects[s]
                    .1
                    .fetch_add(self.reconnect_budget as u64, Ordering::Relaxed);
                self.inner.poison();
                return Err(LinkFault::Poisoned);
            }
        }
        let wire: &[u8] = if self.faults.truncates(s, payload.round) {
            &lane[..tx / 2]
        } else {
            &lane
        };
        let deliveries = if self.faults.duplicates(payload.round) {
            2 // absolute chunk values make the second apply a no-op
        } else {
            1
        };
        let mut rx = 0u64;
        for _ in 0..deliveries {
            match frame::decode_frame(wire) {
                Ok(Frame::Delta(d)) => {
                    debug_assert_eq!(d.shard as usize, s);
                    debug_assert_eq!(d.round, payload.round as u64);
                    d.apply(|i, v| z.set(i, v));
                    rx += wire.len() as u64;
                }
                Ok(_) => return Err(self.protocol_fault("delta exchange received a non-delta frame")),
                Err(e) => return Err(self.protocol_fault(e.reason())),
            }
        }
        Ok(WireCost {
            bytes_tx: tx as u64,
            bytes_rx: rx,
            nanos: t0.elapsed().as_nanos() as u64,
        })
    }

    fn wire_decision(&self, s: usize, payload: &mut DecisionPayload) -> Result<WireCost, LinkFault> {
        let t0 = Instant::now();
        let mut lane = self.lane(s);
        lane.clear();
        let rec = DecisionRecord {
            round: payload.round as u64,
            next_gap: payload.next_gap as u64,
            stop: payload.stop,
        };
        let tx = frame::encode_decision(&mut lane, s, &rec);
        match frame::decode_frame(&lane) {
            Ok(Frame::Decision { record, .. }) => {
                payload.next_gap = record.next_gap as usize;
                payload.stop = record.stop;
                Ok(WireCost {
                    bytes_tx: tx as u64,
                    bytes_rx: tx as u64,
                    nanos: t0.elapsed().as_nanos() as u64,
                })
            }
            Ok(_) => Err(self.protocol_fault("decision exchange received a non-decision frame")),
            Err(e) => Err(self.protocol_fault(e.reason())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::atomic::SyncF64Vec;
    use crate::util::par::{DirtyChunks, DEFAULT_SPIN};

    fn payload_of<'a>(
        z: &'a SyncF64Vec,
        dirty: Option<&'a DirtyChunks>,
        round: usize,
    ) -> DeltaPayload<'a> {
        DeltaPayload {
            round,
            dirty,
            z,
            n: z.len(),
        }
    }

    #[test]
    fn exact_round_trip_is_bit_identical() {
        let link = LoopbackLink::new(1, DEFAULT_SPIN, None, WirePrecision::Exact);
        let z = SyncF64Vec::zeros(40);
        for i in 0..40 {
            z.set(i, (i as f64).sin() * 1e-3);
        }
        let before: Vec<u64> = (0..40).map(|i| z.get(i).to_bits()).collect();
        let cost = link.wire_delta(0, &payload_of(&z, None, 0)).unwrap();
        let after: Vec<u64> = (0..40).map(|i| z.get(i).to_bits()).collect();
        assert_eq!(before, after);
        assert!(cost.bytes_tx > 0);
        assert_eq!(cost.bytes_rx, cost.bytes_tx);
    }

    #[test]
    fn dirty_map_limits_the_frame() {
        let link = LoopbackLink::new(1, DEFAULT_SPIN, None, WirePrecision::Exact);
        let z = SyncF64Vec::zeros(64);
        let dirty = DirtyChunks::new(64);
        dirty.mark(3); // element 3 → chunk 0 only
        z.set(3, 2.5);
        let sparse = link.wire_delta(0, &payload_of(&z, Some(&dirty), 0)).unwrap();
        let dense = link.wire_delta(0, &payload_of(&z, None, 0)).unwrap();
        assert!(sparse.bytes_tx < dense.bytes_tx);
        assert_eq!(z.get(3), 2.5);
    }

    #[test]
    fn f32_round_trip_quantizes() {
        let link = LoopbackLink::new(1, DEFAULT_SPIN, None, WirePrecision::F32);
        let z = SyncF64Vec::zeros(8);
        z.set(0, std::f64::consts::PI);
        link.wire_delta(0, &payload_of(&z, None, 0)).unwrap();
        assert_eq!(z.get(0), std::f64::consts::PI as f32 as f64);
    }

    #[test]
    fn truncation_fault_is_a_protocol_error() {
        let link = LoopbackLink::new(1, DEFAULT_SPIN, None, WirePrecision::Exact)
            .with_faults(NetFaultPlan {
                truncate_at: Some((0, 4)),
                ..Default::default()
            });
        let z = SyncF64Vec::zeros(8);
        assert!(link.wire_delta(0, &payload_of(&z, None, 3)).is_ok());
        match link.wire_delta(0, &payload_of(&z, None, 4)) {
            Err(LinkFault::Protocol(_)) => {}
            other => panic!("expected protocol fault, got {other:?}"),
        }
    }

    #[test]
    fn duplicate_delivery_is_idempotent() {
        let link = LoopbackLink::new(1, DEFAULT_SPIN, None, WirePrecision::Exact)
            .with_faults(NetFaultPlan {
                duplicate_round: Some(0),
                ..Default::default()
            });
        let z = SyncF64Vec::zeros(8);
        z.set(1, -4.25);
        let cost = link.wire_delta(0, &payload_of(&z, None, 0)).unwrap();
        assert_eq!(cost.bytes_rx, 2 * cost.bytes_tx);
        assert_eq!(z.get(1), -4.25);
    }

    #[test]
    fn disconnect_fault_poisons() {
        let link = LoopbackLink::new(2, DEFAULT_SPIN, None, WirePrecision::Exact)
            .with_faults(NetFaultPlan {
                disconnect_at: Some((1, 2)),
                ..Default::default()
            });
        let z = SyncF64Vec::zeros(8);
        assert!(matches!(
            link.wire_delta(1, &payload_of(&z, None, 2)),
            Err(LinkFault::Poisoned)
        ));
        // the inner barrier is now poisoned: the healthy peer escapes
        assert_eq!(link.arrive(0, 2), Err(LinkFault::Poisoned));
    }

    #[test]
    fn disconnect_heals_within_reconnect_budget() {
        let link = LoopbackLink::new(2, DEFAULT_SPIN, None, WirePrecision::Exact)
            .with_faults(NetFaultPlan {
                disconnect_at: Some((1, 2)),
                heal_after_attempts: 3,
                ..Default::default()
            })
            .with_reconnect_budget(4);
        let z = SyncF64Vec::zeros(8);
        z.set(2, 1.5);
        // the drop heals: the frame is delivered and the solve goes on
        assert!(link.wire_delta(1, &payload_of(&z, None, 2)).is_ok());
        assert_eq!(z.get(2), 1.5);
        assert_eq!(link.reconnect_stats(1), (1, 3));
        assert_eq!(link.reconnect_stats(0), (0, 0));
        // the healthy peer never saw a poisoned link
        assert!(link.arrive(0, 2).is_ok());
    }

    #[test]
    fn heal_beyond_budget_burns_attempts_and_poisons() {
        let link = LoopbackLink::new(2, DEFAULT_SPIN, None, WirePrecision::Exact)
            .with_faults(NetFaultPlan {
                disconnect_at: Some((1, 2)),
                heal_after_attempts: 9,
                ..Default::default()
            })
            .with_reconnect_budget(4);
        let z = SyncF64Vec::zeros(8);
        assert!(matches!(
            link.wire_delta(1, &payload_of(&z, None, 2)),
            Err(LinkFault::Poisoned)
        ));
        // all four budgeted attempts were burned, no reconnect succeeded
        assert_eq!(link.reconnect_stats(1), (0, 4));
        assert_eq!(link.arrive(0, 2), Err(LinkFault::Poisoned));
    }

    #[test]
    fn decision_round_trip() {
        let link = LoopbackLink::new(1, DEFAULT_SPIN, None, WirePrecision::Exact);
        let mut payload = DecisionPayload {
            round: 7,
            next_gap: 32,
            stop: None,
        };
        let cost = link.wire_decision(0, &mut payload).unwrap();
        assert_eq!(payload.next_gap, 32);
        assert_eq!(payload.stop, None);
        assert_eq!(cost.bytes_tx, cost.bytes_rx);
    }
}
