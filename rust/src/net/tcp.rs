//! Blocking TCP transport for the reconcile protocol.
//!
//! Topology: one **coordinator relay** (a listener plus one handler
//! thread per connection) and N shard peers, each holding one
//! `std::net::TcpStream`. The relay is the barrier: shards announce a
//! crossing with an `arrive` control frame, the relay counts arrivals
//! per crossing id and broadcasts `release` when all parties are in;
//! data frames (delta, decision) are routed through the relay and
//! echoed back decoded-side. Read/write deadlines map the engine's
//! `barrier_timeout_secs` onto socket timeouts — including the relay's
//! own accept loop and hello-handshake reads, so a half-open or silent
//! dialer cannot stall coordinator startup. **Every** failure mode —
//! peer gone, connection reset, deadline exceeded, malformed bytes —
//! lands as a [`LinkFault`] (`TimedOut`, `Poisoned`, or `Protocol`)
//! and from there as `StopReason::ShardFailed` + a structured
//! `SolveError`. Never a hang: a faulted shard shuts its socket down
//! on the way out, the relay sees the close and broadcasts `poison`,
//! and every blocked peer unblocks.
//!
//! # Reconnect (recover layer)
//!
//! With a [`ReconnectPolicy`] (`TcpLink::connect_with`), a transient
//! disconnect no longer dooms the solve. The peer side redials its
//! original address under bounded exponential backoff and re-handshakes
//! with a hello that **carries its crossing number**; the relay side
//! keeps accepting for the life of the link and re-registers the
//! rejoining writer. Two races the re-handshake heals:
//!
//! * **Lost release** — the relay released crossing `c` but the frame
//!   died with the connection. The relay tracks its released frontier
//!   and replays `release(c)` to a rejoiner whose hello crossing is
//!   already released. Peers skip stale (lower-numbered) releases, so a
//!   double delivery is harmless.
//! * **Lost arrive** — the peer's `arrive(c)` died in flight. The
//!   rejoin hello doubles as the arrival; a per-shard last-arrive
//!   watermark dedupes the retransmit, so the barrier never
//!   double-counts.
//!
//! Data frames are retransmitted whole after a reconnect: delta frames
//! carry **absolute** chunk values, so replaying one is idempotent by
//! construction. Retries exhausted degrades exactly like the
//! no-reconnect link: poison, `LinkFault::Poisoned`,
//! `StopReason::ShardFailed` + `SolveErrorKind::Link` — never a hang.
//!
//! **v1 scope, stated honestly:** this link runs the shard pools in one
//! process with TCP as the *message plane* — every crossing and every
//! exchanged byte really traverses localhost sockets through the relay,
//! which is what the protocol, deadline, and failure machinery need
//! exercised — but the fold itself still reads replicas through shared
//! memory after the decoded bytes are written back. Splitting the data
//! plane across processes (replica state living only behind the wire)
//! is the recorded follow-on; `gencd harness` covers the multi-process
//! axis by spawning whole solves as child processes and killing them.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::net::frame::{
    self, decode_frame, DecisionRecord, Frame, FrameTag, WirePrecision, HEADER_LEN,
};
use crate::recover::backoff::ReconnectPolicy;
use crate::shard::engine::{
    DecisionPayload, DeltaPayload, LinkFault, ReconcileLink, WireCost,
};

/// Hello sentinel: the first frame on a new connection is an `arrive`
/// control frame with this round value, identifying the sender's shard.
const HELLO_ROUND: u64 = u64::MAX;

/// Rejoin sentinel: a reconnect hello that is *not* parked at any
/// crossing (the failure hit a data exchange, not a barrier wait).
/// Registers the writer without touching arrival accounting.
const REJOIN_NONE: u64 = u64::MAX - 1;

/// Upper bound on a declared payload length. A garbage length prefix
/// must not drive an allocation: anything above this decodes to a
/// protocol fault instead. 2 GiB covers a dense f64 delta for ~268M
/// coordinates — far past anything one box folds.
const MAX_WIRE_PAYLOAD: usize = 1 << 31;

/// Poll interval for the non-blocking accept loop.
const ACCEPT_POLL: Duration = Duration::from_millis(2);

/// Read one length-prefixed frame into `buf` (header + declared
/// payload). `InvalidData` marks an implausible length prefix; other
/// errors are genuine socket conditions (timeout, reset, EOF).
fn read_exact_frame(stream: &mut TcpStream, buf: &mut Vec<u8>) -> io::Result<()> {
    buf.resize(HEADER_LEN, 0);
    stream.read_exact(buf)?;
    let payload_len = u32::from_le_bytes(buf[16..20].try_into().unwrap()) as usize;
    if payload_len > MAX_WIRE_PAYLOAD {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "wire frame length prefix implausible",
        ));
    }
    buf.resize(HEADER_LEN + payload_len, 0);
    stream.read_exact(&mut buf[HEADER_LEN..])?;
    Ok(())
}

/// Socket errors that mean "the connection is gone" — the only class a
/// [`ReconnectPolicy`] applies to. Timeouts are *not* here on purpose:
/// a deadline at a barrier means a peer is slow or dead, and redialing
/// our own healthy socket cannot fix that.
fn is_disconnect(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::ConnectionReset
            | io::ErrorKind::ConnectionAborted
            | io::ErrorKind::BrokenPipe
            | io::ErrorKind::UnexpectedEof
            | io::ErrorKind::NotConnected
    )
}

/// Relay-side shared state: registered writer halves, the arrival
/// counts per crossing id, and the rejoin bookkeeping (released
/// frontier + per-shard arrive watermarks).
struct RelayShared {
    parties: usize,
    /// Whether peers may rejoin after a disconnect. When false, a
    /// handler seeing EOF poisons the link (the pre-recover behavior);
    /// when true it only clears its writer slot and lets the accept
    /// loop re-register the peer.
    reconnectable: bool,
    /// Set by the link on shutdown/poison: suppresses the poison
    /// broadcast a handler would otherwise emit on EOF, so a clean
    /// teardown doesn't read as a fault.
    closed: Arc<AtomicBool>,
    /// Writer half per shard, each behind its own lock so an echo only
    /// serializes against broadcasts touching the same peer.
    writers: Mutex<Vec<Option<Arc<Mutex<TcpStream>>>>>,
    arrivals: Mutex<HashMap<u64, usize>>,
    /// Released frontier, stored as `last released crossing + 1`
    /// (0 = nothing released). A rejoiner whose hello crossing sits
    /// below the frontier gets its release replayed — the lost-release
    /// race.
    released: AtomicU64,
    /// Per-shard watermark of the last crossing counted as arrived
    /// (`u64::MAX` = none yet). Dedupes the arrive a rejoining peer
    /// retransmits — the lost-arrive race.
    last_arrive: Vec<AtomicU64>,
    handlers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl RelayShared {
    fn writer_arcs(&self) -> Vec<Arc<Mutex<TcpStream>>> {
        self.writers
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .flatten()
            .cloned()
            .collect()
    }

    /// Send a control frame to every registered peer; write errors are
    /// ignored (a peer that can't be reached is already dying, and its
    /// handler will notice).
    fn broadcast(&self, tag: FrameTag, round: u64) {
        let mut buf = Vec::with_capacity(HEADER_LEN);
        frame::encode_control(&mut buf, tag, 0, round);
        for w in self.writer_arcs() {
            let mut stream = w.lock().unwrap_or_else(|e| e.into_inner());
            let _ = stream.write_all(&buf);
        }
    }

    /// Count an arrival of shard `s` for crossing `c`; the Nth arrival
    /// releases all. Re-sent arrives (reconnect retransmits) are
    /// deduped against the shard's watermark.
    fn on_arrive(&self, s: usize, c: u64) {
        let last = self.last_arrive[s].load(Ordering::Relaxed);
        if last != u64::MAX && last >= c {
            return; // already counted before the reconnect
        }
        self.last_arrive[s].store(c, Ordering::Relaxed);
        let release = {
            let mut arrivals = self.arrivals.lock().unwrap_or_else(|e| e.into_inner());
            let count = arrivals.entry(c).or_insert(0);
            *count += 1;
            let full = *count == self.parties;
            if full {
                arrivals.remove(&c);
            }
            full
        };
        if release {
            // frontier before broadcast: a rejoiner must never observe
            // the release gone from `arrivals` without the frontier
            // covering it, or the lost-release replay misses
            self.released.fetch_max(c + 1, Ordering::AcqRel);
            self.broadcast(FrameTag::Release, c);
        }
    }

    /// Whether crossing `c` has already been released.
    fn already_released(&self, c: u64) -> bool {
        self.released.load(Ordering::Acquire) > c
    }

    fn poison_all(&self) {
        self.broadcast(FrameTag::Poison, 0);
    }
}

/// Per-connection relay handler: counts arrivals, echoes data frames
/// back to the sender, and broadcasts poison on any read failure or
/// protocol violation. Under a reconnectable link, a plain disconnect
/// instead clears this connection's writer slot (guarded by pointer
/// identity so a rejoiner's fresh writer is never wiped) and lets the
/// peer rejoin.
fn relay_handler(
    shared: Arc<RelayShared>,
    shard: usize,
    mut read: TcpStream,
    writer: Arc<Mutex<TcpStream>>,
) {
    let mut buf = Vec::new();
    loop {
        match read_exact_frame(&mut read, &mut buf) {
            Ok(()) => match decode_frame(&buf) {
                Ok(Frame::Control {
                    tag: FrameTag::Arrive,
                    shard: s,
                    round,
                }) if (s as usize) < shared.parties => shared.on_arrive(s as usize, round),
                Ok(Frame::Delta(_) | Frame::Decision { .. }) => {
                    let ok = {
                        let mut stream = writer.lock().unwrap_or_else(|e| e.into_inner());
                        stream.write_all(&buf).is_ok()
                    };
                    if !ok {
                        // our peer is unreachable; under reconnect it
                        // will retransmit the exchange on a fresh
                        // connection, so only a frozen link poisons
                        if shared.reconnectable {
                            continue;
                        }
                        shared.poison_all();
                        return;
                    }
                }
                // shards never send release/poison; anything else is a
                // protocol violation and dooms the exchange
                Ok(Frame::Control { .. }) | Err(_) => {
                    shared.poison_all();
                    return;
                }
            },
            Err(e) => {
                // EOF or reset: a peer is gone. On a clean link
                // teardown that is expected; under reconnect the peer
                // may come back, so step aside; otherwise tell everyone.
                if !shared.closed.load(Ordering::Acquire) {
                    if shared.reconnectable && is_disconnect(&e) {
                        let mut writers =
                            shared.writers.lock().unwrap_or_else(|p| p.into_inner());
                        if let Some(w) = &writers[shard] {
                            if Arc::ptr_eq(w, &writer) {
                                writers[shard] = None;
                            }
                        }
                    } else {
                        shared.poison_all();
                    }
                }
                return;
            }
        }
    }
}

/// Register one accepted connection: handshake-read its hello (under
/// the caller's deadline — a silent dialer cannot stall the relay),
/// install the writer, spawn the handler. Returns `Ok(true)` when the
/// hello was an *initial* registration (counts toward startup).
fn register_conn(
    shared: &Arc<RelayShared>,
    mut conn: TcpStream,
    hello_timeout: Option<Duration>,
    startup: bool,
) -> io::Result<bool> {
    conn.set_nodelay(true)?;
    // the listener is non-blocking; the handshake must not be
    conn.set_nonblocking(false)?;
    conn.set_read_timeout(hello_timeout)?;
    conn.set_write_timeout(hello_timeout)?;
    let mut hello = Vec::new();
    read_exact_frame(&mut conn, &mut hello)?;
    let (shard, round) = match decode_frame(&hello) {
        Ok(Frame::Control {
            tag: FrameTag::Arrive,
            shard,
            round,
        }) if (shard as usize) < shared.parties => (shard as usize, round),
        _ => {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "connection did not open with a valid hello frame",
            ))
        }
    };
    if shared.closed.load(Ordering::Acquire) {
        // stale rejoin against a dead link: tell the dialer, don't hang it
        let mut poison = Vec::with_capacity(HEADER_LEN);
        frame::encode_control(&mut poison, FrameTag::Poison, 0, 0);
        let _ = conn.write_all(&poison);
        return Ok(false);
    }
    // established-stream reads are bounded by the peer side's socket
    // deadlines; the relay side blocks until data or close
    conn.set_read_timeout(None)?;
    conn.set_write_timeout(None)?;
    let initial = round == HELLO_ROUND;
    if initial && startup {
        let occupied = shared.writers.lock().unwrap_or_else(|e| e.into_inner())[shard].is_some();
        if occupied {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "duplicate shard hello"));
        }
    } else if !shared.reconnectable {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "rejoin hello on a link without a reconnect policy",
        ));
    }
    let writer = Arc::new(Mutex::new(conn.try_clone()?));
    shared.writers.lock().unwrap_or_else(|e| e.into_inner())[shard] = Some(Arc::clone(&writer));
    if !initial && round != REJOIN_NONE {
        // the rejoiner is parked at crossing `round`: either its
        // release died with the old connection (replay it) or its
        // arrive did (the hello doubles as the arrive, deduped)
        if shared.already_released(round) {
            let mut rel = Vec::with_capacity(HEADER_LEN);
            frame::encode_control(&mut rel, FrameTag::Release, 0, round);
            let mut stream = writer.lock().unwrap_or_else(|e| e.into_inner());
            let _ = stream.write_all(&rel);
        } else {
            shared.on_arrive(shard, round);
        }
    }
    let handler_shared = Arc::clone(shared);
    let handle = std::thread::spawn(move || relay_handler(handler_shared, shard, conn, writer));
    shared
        .handlers
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .push(handle);
    Ok(initial)
}

/// Shard-side endpoint: one connection to the relay, used only by that
/// shard's pool leader (the locks exist for `Sync` soundness, not
/// contention).
struct Peer {
    /// The address this peer dialed — redialed on reconnect.
    addr: String,
    read: Mutex<TcpStream>,
    write: Mutex<TcpStream>,
    /// Reused encode/receive buffer.
    scratch: Mutex<Vec<u8>>,
    /// Local crossing counter; all shards cross in lockstep, so equal
    /// counts name the same crossing — the relay's barrier key.
    crossings: AtomicU64,
    /// Successful reconnects (for `reconnect_stats`).
    reconnects: AtomicU64,
    /// Cumulative redial attempts, successful or not.
    attempts: AtomicU64,
}

/// An op failure the retry layer can classify: a socket-level error
/// (maybe healable by reconnect) or an already-classified link fault.
enum OpError {
    Io(io::Error),
    Fault(LinkFault),
}

/// The TCP [`ReconcileLink`]. See the module docs for topology, the
/// reconnect protocol, and the v1 scope statement; construction is
/// [`TcpLink::connect`] / [`TcpLink::connect_with`].
pub struct TcpLink {
    peers: Vec<Peer>,
    precision: WirePrecision,
    policy: ReconnectPolicy,
    timeout: Option<Duration>,
    closed: Arc<AtomicBool>,
    relay: Arc<RelayShared>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    local_addr: SocketAddr,
}

impl TcpLink {
    /// [`connect_with`](TcpLink::connect_with) under a disabled
    /// reconnect policy — the first socket error poisons the link.
    pub fn connect(
        shards: usize,
        listen: &str,
        peers: &[String],
        timeout: Option<Duration>,
        precision: WirePrecision,
    ) -> io::Result<Self> {
        Self::connect_with(shards, listen, peers, timeout, precision, ReconnectPolicy::default())
    }

    /// Bind the relay on `listen` (use port 0 for an ephemeral port),
    /// dial one connection per shard, and wait until the relay has
    /// registered all of them. `peers` optionally overrides the dial
    /// address per shard (shard `s` dials `peers[min(s, len-1)]`; an
    /// empty slice dials the relay's own bound address — the
    /// single-box default). `timeout` (`None` = effectively forever)
    /// becomes every socket's read/write deadline — including the
    /// relay's accept loop and hello reads, mapping
    /// `barrier_timeout_secs` onto the wire end to end. `policy`
    /// governs peer redials after a disconnect; see the module docs.
    pub fn connect_with(
        shards: usize,
        listen: &str,
        peers: &[String],
        timeout: Option<Duration>,
        precision: WirePrecision,
        policy: ReconnectPolicy,
    ) -> io::Result<Self> {
        let parties = shards.max(1);
        let listener = TcpListener::bind(listen)?;
        let local_addr = listener.local_addr()?;
        let closed = Arc::new(AtomicBool::new(false));
        let relay = Arc::new(RelayShared {
            parties,
            reconnectable: policy.enabled(),
            closed: Arc::clone(&closed),
            writers: Mutex::new(vec![None; parties]),
            arrivals: Mutex::new(HashMap::new()),
            released: AtomicU64::new(0),
            last_arrive: (0..parties).map(|_| AtomicU64::new(u64::MAX)).collect(),
            handlers: Mutex::new(Vec::new()),
        });

        // accept thread: register `parties` initial connections (hello
        // frame identifies the shard) under the startup deadline, then
        // signal readiness. A reconnectable relay keeps accepting
        // rejoin dials for the life of the link; otherwise the loop
        // ends with startup, as before the recover layer.
        let accept_relay = Arc::clone(&relay);
        let (ready_tx, ready_rx) = mpsc::channel::<io::Result<()>>();
        let accept_timeout = timeout;
        let accept_thread = std::thread::spawn(move || {
            if listener.set_nonblocking(true).is_err() {
                let _ = ready_tx.send(Err(io::Error::new(
                    io::ErrorKind::Other,
                    "relay listener could not enter non-blocking mode",
                )));
                return;
            }
            let deadline = Instant::now() + accept_timeout.unwrap_or(Duration::from_secs(30));
            let mut registered = 0usize;
            let mut ready = false;
            loop {
                if accept_relay.closed.load(Ordering::Acquire) {
                    return;
                }
                match listener.accept() {
                    Ok((conn, _)) => {
                        match register_conn(&accept_relay, conn, accept_timeout, !ready) {
                            Ok(true) if !ready => {
                                registered += 1;
                                if registered == accept_relay.parties {
                                    ready = true;
                                    let _ = ready_tx.send(Ok(()));
                                    if !accept_relay.reconnectable {
                                        return;
                                    }
                                }
                            }
                            Ok(_) => {}
                            Err(e) => {
                                if !ready {
                                    let _ = ready_tx.send(Err(e));
                                    accept_relay.poison_all();
                                    return;
                                }
                                // post-startup: a garbage or stale dial
                                // must not take down a healthy link
                            }
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        if !ready && Instant::now() >= deadline {
                            let _ = ready_tx.send(Err(io::Error::new(
                                io::ErrorKind::TimedOut,
                                "relay accept loop deadline before all shards registered",
                            )));
                            accept_relay.poison_all();
                            return;
                        }
                        std::thread::sleep(ACCEPT_POLL);
                    }
                    Err(e) => {
                        if !ready {
                            let _ = ready_tx.send(Err(e));
                            accept_relay.poison_all();
                            return;
                        }
                        std::thread::sleep(ACCEPT_POLL);
                    }
                }
            }
        });

        // dial one connection per shard and say hello
        let connect_result = (|| -> io::Result<Vec<Peer>> {
            let mut endpoints = Vec::with_capacity(parties);
            for s in 0..parties {
                let addr = peers
                    .get(s.min(peers.len().wrapping_sub(1)))
                    .map(String::as_str)
                    .map(str::to_string)
                    .unwrap_or_else(|| local_addr.to_string());
                let stream = TcpStream::connect(addr.as_str())?;
                stream.set_nodelay(true)?;
                stream.set_read_timeout(timeout)?;
                stream.set_write_timeout(timeout)?;
                let mut hello = Vec::new();
                frame::encode_control(&mut hello, FrameTag::Arrive, s, HELLO_ROUND);
                let mut write = stream.try_clone()?;
                write.write_all(&hello)?;
                endpoints.push(Peer {
                    addr,
                    read: Mutex::new(stream),
                    write: Mutex::new(write),
                    scratch: Mutex::new(Vec::new()),
                    crossings: AtomicU64::new(0),
                    reconnects: AtomicU64::new(0),
                    attempts: AtomicU64::new(0),
                });
            }
            // all connections must be registered before any crossing,
            // or an early arrive could release before a writer exists
            match ready_rx.recv_timeout(timeout.unwrap_or(Duration::from_secs(30))) {
                Ok(Ok(())) => Ok(endpoints),
                Ok(Err(e)) => Err(e),
                Err(_) => Err(io::Error::new(
                    io::ErrorKind::TimedOut,
                    "relay did not register all shard connections in time",
                )),
            }
        })();

        match connect_result {
            Ok(endpoints) => Ok(Self {
                peers: endpoints,
                precision,
                policy,
                timeout,
                closed,
                relay,
                accept_thread: Some(accept_thread),
                local_addr,
            }),
            Err(e) => {
                closed.store(true, Ordering::Release);
                let _ = accept_thread.join();
                for h in relay
                    .handlers
                    .lock()
                    .unwrap_or_else(|p| p.into_inner())
                    .drain(..)
                {
                    let _ = h.join();
                }
                Err(e)
            }
        }
    }

    /// The relay's bound address (useful with `listen = "…:0"`).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    fn check_open(&self) -> Result<(), LinkFault> {
        if self.closed.load(Ordering::Acquire) {
            Err(LinkFault::Poisoned)
        } else {
            Ok(())
        }
    }

    fn io_fault(&self, e: &io::Error) -> LinkFault {
        let fault = match e.kind() {
            io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock => LinkFault::TimedOut,
            io::ErrorKind::InvalidData => LinkFault::Protocol("wire frame length prefix implausible"),
            _ => LinkFault::Poisoned,
        };
        // shut our socket down on the way out: the relay sees the close
        // and poisons the peers, so nobody waits for us (§Failure
        // semantics: the faulted waiter unblocks everyone else)
        self.poison();
        fault
    }

    fn protocol_fault(&self, reason: &'static str) -> LinkFault {
        self.poison();
        LinkFault::Protocol(reason)
    }

    fn send(&self, s: usize, bytes: &[u8]) -> Result<(), OpError> {
        let mut stream = self.peers[s].write.lock().unwrap_or_else(|e| e.into_inner());
        stream.write_all(bytes).map_err(OpError::Io)
    }

    /// Classify an op failure: disconnects under an enabled policy go
    /// to the redial loop (`Ok(())` = healed, retry the op); everything
    /// else degrades through [`io_fault`](TcpLink::io_fault). `retried`
    /// caps each op at one heal so a flapping connection cannot loop.
    fn heal_or_fault(
        &self,
        s: usize,
        hello_round: u64,
        retried: &mut bool,
        e: &io::Error,
    ) -> Result<(), LinkFault> {
        if *retried || !self.policy.enabled() || !is_disconnect(e) {
            return Err(self.io_fault(e));
        }
        *retried = true;
        self.reconnect(s, hello_round)
    }

    /// Redial the peer's original address under the backoff policy and
    /// re-handshake with a hello carrying `hello_round` (the parked
    /// crossing, or [`REJOIN_NONE`] from a data exchange). Exhausted
    /// attempts poison the link — degrade, never hang.
    fn reconnect(&self, s: usize, hello_round: u64) -> Result<(), LinkFault> {
        let peer = &self.peers[s];
        for attempt in 0..self.policy.max_attempts {
            if self.closed.load(Ordering::Acquire) {
                return Err(LinkFault::Poisoned);
            }
            std::thread::sleep(Duration::from_millis(self.policy.delay_ms(attempt)));
            peer.attempts.fetch_add(1, Ordering::Relaxed);
            let stream = match TcpStream::connect(peer.addr.as_str()) {
                Ok(st) => st,
                Err(_) => continue,
            };
            let healthy = stream.set_nodelay(true).is_ok()
                && stream.set_read_timeout(self.timeout).is_ok()
                && stream.set_write_timeout(self.timeout).is_ok();
            if !healthy {
                continue;
            }
            let mut hello = Vec::with_capacity(HEADER_LEN);
            frame::encode_control(&mut hello, FrameTag::Arrive, s, hello_round);
            let mut write = match stream.try_clone() {
                Ok(w) => w,
                Err(_) => continue,
            };
            if write.write_all(&hello).is_err() {
                continue;
            }
            *peer.write.lock().unwrap_or_else(|e| e.into_inner()) = write;
            *peer.read.lock().unwrap_or_else(|e| e.into_inner()) = stream;
            peer.reconnects.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
        self.poison();
        Err(LinkFault::Poisoned)
    }

    /// One crossing attempt: announce arrival, wait for the release.
    /// Stale releases (replays for already-passed crossings after a
    /// rejoin) are skipped, not faulted.
    fn try_cross(&self, s: usize, c: u64) -> Result<(), OpError> {
        let peer = &self.peers[s];
        {
            let mut buf = peer.scratch.lock().unwrap_or_else(|e| e.into_inner());
            buf.clear();
            frame::encode_control(&mut buf, FrameTag::Arrive, s, c);
            self.send(s, &buf)?;
        }
        let mut stream = peer.read.lock().unwrap_or_else(|e| e.into_inner());
        let mut buf = peer.scratch.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            read_exact_frame(&mut stream, &mut buf).map_err(OpError::Io)?;
            match decode_frame(&buf) {
                Ok(Frame::Control {
                    tag: FrameTag::Release,
                    round,
                    ..
                }) if round == c => return Ok(()),
                Ok(Frame::Control {
                    tag: FrameTag::Release,
                    round,
                    ..
                }) if round < c => continue,
                Ok(Frame::Control {
                    tag: FrameTag::Poison,
                    ..
                }) => {
                    self.poison();
                    return Err(OpError::Fault(LinkFault::Poisoned));
                }
                Ok(_) => {
                    return Err(OpError::Fault(
                        self.protocol_fault("unexpected frame at a crossing"),
                    ))
                }
                Err(e) => return Err(OpError::Fault(self.protocol_fault(e.reason()))),
            }
        }
    }

    /// One barrier crossing: announce arrival, block until the relay's
    /// release (or fail cleanly on poison/timeout/disconnect). A
    /// disconnect mid-crossing parks here, redials under the policy,
    /// and replays the arrive — the relay's watermark and released
    /// frontier make both directions idempotent.
    fn cross(&self, s: usize) -> Result<(), LinkFault> {
        self.check_open()?;
        let peer = &self.peers[s];
        let c = peer.crossings.fetch_add(1, Ordering::Relaxed);
        let mut retried = false;
        loop {
            match self.try_cross(s, c) {
                Ok(()) => return Ok(()),
                Err(OpError::Io(e)) => self.heal_or_fault(s, c, &mut retried, &e)?,
                Err(OpError::Fault(f)) => return Err(f),
            }
        }
    }

    fn try_wire_delta(&self, s: usize, payload: &DeltaPayload<'_>) -> Result<WireCost, OpError> {
        let t0 = Instant::now();
        let z = payload.z;
        let peer = &self.peers[s];
        let tx = {
            let mut buf = peer.scratch.lock().unwrap_or_else(|e| e.into_inner());
            buf.clear();
            let tx = match payload.dirty {
                Some(d) => frame::encode_delta(
                    &mut buf,
                    s,
                    payload.round as u64,
                    self.precision,
                    payload.n,
                    |c| d.is_dirty(c),
                    |i| z.get(i),
                ),
                None => frame::encode_delta(
                    &mut buf,
                    s,
                    payload.round as u64,
                    self.precision,
                    payload.n,
                    |_| true,
                    |i| z.get(i),
                ),
            };
            self.send(s, &buf)?;
            tx
        };
        // the relay echoes the frame back; what we apply is what was on
        // the wire
        let mut stream = peer.read.lock().unwrap_or_else(|e| e.into_inner());
        let mut buf = peer.scratch.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            read_exact_frame(&mut stream, &mut buf).map_err(OpError::Io)?;
            match decode_frame(&buf) {
                Ok(Frame::Delta(d)) if d.shard as usize == s && d.round == payload.round as u64 => {
                    d.apply(|i, v| z.set(i, v));
                    return Ok(WireCost {
                        bytes_tx: tx as u64,
                        bytes_rx: buf.len() as u64,
                        nanos: t0.elapsed().as_nanos() as u64,
                    });
                }
                // a stale release replayed after a rejoin is not part
                // of this exchange; skip it
                Ok(Frame::Control {
                    tag: FrameTag::Release,
                    ..
                }) => continue,
                Ok(Frame::Control {
                    tag: FrameTag::Poison,
                    ..
                }) => {
                    self.poison();
                    return Err(OpError::Fault(LinkFault::Poisoned));
                }
                Ok(_) => {
                    return Err(OpError::Fault(
                        self.protocol_fault("delta exchange received a non-delta frame"),
                    ))
                }
                Err(e) => return Err(OpError::Fault(self.protocol_fault(e.reason()))),
            }
        }
    }

    fn try_wire_decision(&self, s: usize, payload: &mut DecisionPayload) -> Result<WireCost, OpError> {
        let t0 = Instant::now();
        let peer = &self.peers[s];
        let rec = DecisionRecord {
            round: payload.round as u64,
            next_gap: payload.next_gap as u64,
            stop: payload.stop,
        };
        let tx = {
            let mut buf = peer.scratch.lock().unwrap_or_else(|e| e.into_inner());
            buf.clear();
            let tx = frame::encode_decision(&mut buf, s, &rec);
            self.send(s, &buf)?;
            tx
        };
        let mut stream = peer.read.lock().unwrap_or_else(|e| e.into_inner());
        let mut buf = peer.scratch.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            read_exact_frame(&mut stream, &mut buf).map_err(OpError::Io)?;
            match decode_frame(&buf) {
                Ok(Frame::Decision { record, .. }) => {
                    payload.next_gap = record.next_gap as usize;
                    payload.stop = record.stop;
                    return Ok(WireCost {
                        bytes_tx: tx as u64,
                        bytes_rx: buf.len() as u64,
                        nanos: t0.elapsed().as_nanos() as u64,
                    });
                }
                Ok(Frame::Control {
                    tag: FrameTag::Release,
                    ..
                }) => continue,
                Ok(Frame::Control {
                    tag: FrameTag::Poison,
                    ..
                }) => {
                    self.poison();
                    return Err(OpError::Fault(LinkFault::Poisoned));
                }
                Ok(_) => {
                    return Err(OpError::Fault(
                        self.protocol_fault("decision exchange received a non-decision frame"),
                    ))
                }
                Err(e) => return Err(OpError::Fault(self.protocol_fault(e.reason()))),
            }
        }
    }
}

impl ReconcileLink for TcpLink {
    fn init(&self, s: usize) -> Result<(), LinkFault> {
        self.cross(s)
    }

    fn arrive(&self, s: usize, _round: usize) -> Result<(), LinkFault> {
        self.cross(s)
    }

    fn publish_fold(&self, s: usize, _round: usize) -> Result<(), LinkFault> {
        self.cross(s)
    }

    fn publish_decision(&self, s: usize, _round: usize) -> Result<(), LinkFault> {
        self.cross(s)
    }

    fn wire_precision(&self) -> Option<&'static str> {
        Some(self.precision.name())
    }

    fn reconnect_stats(&self, s: usize) -> (u64, u64) {
        let peer = &self.peers[s];
        (
            peer.reconnects.load(Ordering::Relaxed),
            peer.attempts.load(Ordering::Relaxed),
        )
    }

    fn poison(&self) {
        self.closed.store(true, Ordering::Release);
        for peer in &self.peers {
            if let Ok(stream) = peer.read.try_lock() {
                let _ = stream.shutdown(Shutdown::Both);
            } else if let Ok(stream) = peer.write.try_lock() {
                let _ = stream.shutdown(Shutdown::Both);
            }
        }
    }

    fn wire_delta(&self, s: usize, payload: &DeltaPayload<'_>) -> Result<WireCost, LinkFault> {
        self.check_open()?;
        let mut retried = false;
        loop {
            match self.try_wire_delta(s, payload) {
                Ok(cost) => return Ok(cost),
                // delta frames carry absolute chunk values, so the
                // post-reconnect retransmit is idempotent
                Err(OpError::Io(e)) => self.heal_or_fault(s, REJOIN_NONE, &mut retried, &e)?,
                Err(OpError::Fault(f)) => return Err(f),
            }
        }
    }

    fn wire_decision(&self, s: usize, payload: &mut DecisionPayload) -> Result<WireCost, LinkFault> {
        self.check_open()?;
        let mut retried = false;
        loop {
            match self.try_wire_decision(s, payload) {
                Ok(cost) => return Ok(cost),
                Err(OpError::Io(e)) => self.heal_or_fault(s, REJOIN_NONE, &mut retried, &e)?,
                Err(OpError::Fault(f)) => return Err(f),
            }
        }
    }
}

impl Drop for TcpLink {
    fn drop(&mut self) {
        self.closed.store(true, Ordering::Release);
        for peer in &self.peers {
            let stream = peer.read.lock().unwrap_or_else(|e| e.into_inner());
            let _ = stream.shutdown(Shutdown::Both);
        }
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        for h in self
            .relay
            .handlers
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .drain(..)
        {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn link(shards: usize, timeout_ms: u64) -> TcpLink {
        TcpLink::connect(
            shards,
            "127.0.0.1:0",
            &[],
            Some(Duration::from_millis(timeout_ms)),
            WirePrecision::Exact,
        )
        .expect("localhost bind + connect")
    }

    fn link_with_reconnect(shards: usize, timeout_ms: u64, attempts: u32) -> TcpLink {
        TcpLink::connect_with(
            shards,
            "127.0.0.1:0",
            &[],
            Some(Duration::from_millis(timeout_ms)),
            WirePrecision::Exact,
            ReconnectPolicy {
                max_attempts: attempts,
                base_ms: 5,
                cap_ms: 40,
                seed: 9,
            },
        )
        .expect("localhost bind + connect")
    }

    #[test]
    fn crossings_release_all_parties() {
        let l = Arc::new(link(3, 5_000));
        let released = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|scope| {
            for s in 0..3 {
                let l = Arc::clone(&l);
                let released = Arc::clone(&released);
                scope.spawn(move || {
                    for round in 0..4 {
                        l.arrive(s, round).expect("healthy crossing");
                        released.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert_eq!(released.load(Ordering::Relaxed), 12);
    }

    #[test]
    fn missing_peer_times_out_not_hangs() {
        let l = link(2, 200);
        // only shard 0 ever arrives; its wait must deadline cleanly
        let start = Instant::now();
        assert_eq!(l.arrive(0, 0), Err(LinkFault::TimedOut));
        assert!(start.elapsed() < Duration::from_secs(5));
        // after the fault the link is poisoned for everyone
        assert_eq!(l.arrive(1, 0), Err(LinkFault::Poisoned));
    }

    #[test]
    fn delta_and_decision_echo_through_the_relay() {
        use crate::util::atomic::SyncF64Vec;
        let l = link(1, 5_000);
        let z = SyncF64Vec::zeros(24);
        z.set(5, 1.25);
        z.set(17, -3.5);
        let before = z.snapshot();
        let cost = l
            .wire_delta(
                0,
                &DeltaPayload {
                    round: 0,
                    dirty: None,
                    z: &z,
                    n: 24,
                },
            )
            .expect("delta echo");
        assert_eq!(z.snapshot(), before);
        assert!(cost.bytes_tx > 0 && cost.bytes_rx == cost.bytes_tx);

        let mut decision = DecisionPayload {
            round: 0,
            next_gap: 8,
            stop: None,
        };
        l.wire_decision(0, &mut decision).expect("decision echo");
        assert_eq!(decision.next_gap, 8);
        assert_eq!(decision.stop, None);
    }

    #[test]
    fn severed_peer_reconnects_and_completes() {
        let l = Arc::new(link_with_reconnect(2, 5_000, 4));
        // sever shard 1's connection out from under it: the next op
        // sees a dead socket and must heal through the redial path
        {
            let stream = l.peers[1].read.lock().unwrap();
            stream.shutdown(Shutdown::Both).expect("sever");
        }
        let released = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|scope| {
            for s in 0..2 {
                let l = Arc::clone(&l);
                let released = Arc::clone(&released);
                scope.spawn(move || {
                    for round in 0..4 {
                        l.arrive(s, round).expect("crossing heals through reconnect");
                        released.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert_eq!(released.load(Ordering::Relaxed), 8);
        let (reconnects, attempts) = l.reconnect_stats(1);
        assert!(reconnects >= 1, "severed peer must have reconnected");
        assert!(attempts >= reconnects);
        assert_eq!(l.reconnect_stats(0), (0, 0));
    }

    #[test]
    fn reconnect_stats_are_zero_on_a_healthy_link() {
        let l = link_with_reconnect(2, 2_000, 3);
        std::thread::scope(|scope| {
            for s in 0..2 {
                let l = &l;
                scope.spawn(move || l.arrive(s, 0).expect("healthy crossing"));
            }
        });
        assert_eq!(l.reconnect_stats(0), (0, 0));
        assert_eq!(l.reconnect_stats(1), (0, 0));
    }

    #[test]
    fn garbage_dialer_after_startup_is_ignored() {
        let l = Arc::new(link_with_reconnect(2, 2_000, 3));
        // a stranger connects and sends bytes that are not a hello;
        // the relay must drop it without disturbing the healthy link
        let mut stranger = TcpStream::connect(l.local_addr()).expect("dial relay");
        stranger.write_all(b"not a gencd frame at all....").expect("write garbage");
        drop(stranger);
        std::thread::sleep(Duration::from_millis(50));
        std::thread::scope(|scope| {
            for s in 0..2 {
                let l = Arc::clone(&l);
                scope.spawn(move || l.arrive(s, 0).expect("crossing survives stranger"));
            }
        });
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let l = link(2, 1_000);
        drop(l); // must not hang joining relay threads
    }

    #[test]
    fn drop_shuts_down_cleanly_with_reconnect() {
        let l = link_with_reconnect(2, 1_000, 3);
        drop(l); // the lifetime accept loop must exit on the closed flag
    }
}
