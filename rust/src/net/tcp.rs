//! Blocking TCP transport for the reconcile protocol.
//!
//! Topology: one **coordinator relay** (a listener plus one handler
//! thread per connection) and N shard peers, each holding one
//! `std::net::TcpStream`. The relay is the barrier: shards announce a
//! crossing with an `arrive` control frame, the relay counts arrivals
//! per crossing id and broadcasts `release` when all parties are in;
//! data frames (delta, decision) are routed through the relay and
//! echoed back decoded-side. Read/write deadlines map the engine's
//! `barrier_timeout_secs` onto socket timeouts, so **every** failure
//! mode — peer gone, connection reset, deadline exceeded, malformed
//! bytes — lands as a [`LinkFault`] (`TimedOut`, `Poisoned`, or
//! `Protocol`) and from there as `StopReason::ShardFailed` + a
//! structured `SolveError`. Never a hang: a faulted shard shuts its
//! socket down on the way out, the relay sees the close and broadcasts
//! `poison`, and every blocked peer unblocks.
//!
//! **v1 scope, stated honestly:** this link runs the shard pools in one
//! process with TCP as the *message plane* — every crossing and every
//! exchanged byte really traverses localhost sockets through the relay,
//! which is what the protocol, deadline, and failure machinery need
//! exercised — but the fold itself still reads replicas through shared
//! memory after the decoded bytes are written back. Splitting the data
//! plane across processes (replica state living only behind the wire)
//! is the recorded follow-on, along with double-buffered
//! compute/exchange overlap.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::net::frame::{
    self, decode_frame, DecisionRecord, Frame, FrameTag, WirePrecision, HEADER_LEN,
};
use crate::shard::engine::{
    DecisionPayload, DeltaPayload, LinkFault, ReconcileLink, WireCost,
};

/// Hello sentinel: the first frame on a new connection is an `arrive`
/// control frame with this round value, identifying the sender's shard.
const HELLO_ROUND: u64 = u64::MAX;

/// Upper bound on a declared payload length. A garbage length prefix
/// must not drive an allocation: anything above this decodes to a
/// protocol fault instead. 2 GiB covers a dense f64 delta for ~268M
/// coordinates — far past anything one box folds.
const MAX_WIRE_PAYLOAD: usize = 1 << 31;

/// Read one length-prefixed frame into `buf` (header + declared
/// payload). `InvalidData` marks an implausible length prefix; other
/// errors are genuine socket conditions (timeout, reset, EOF).
fn read_exact_frame(stream: &mut TcpStream, buf: &mut Vec<u8>) -> io::Result<()> {
    buf.resize(HEADER_LEN, 0);
    stream.read_exact(buf)?;
    let payload_len = u32::from_le_bytes(buf[16..20].try_into().unwrap()) as usize;
    if payload_len > MAX_WIRE_PAYLOAD {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "wire frame length prefix implausible",
        ));
    }
    buf.resize(HEADER_LEN + payload_len, 0);
    stream.read_exact(&mut buf[HEADER_LEN..])?;
    Ok(())
}

/// Relay-side shared state: registered writer halves and the arrival
/// counts per crossing id.
struct RelayShared {
    parties: usize,
    /// Set by the link on shutdown/poison: suppresses the poison
    /// broadcast a handler would otherwise emit on EOF, so a clean
    /// teardown doesn't read as a fault.
    closed: Arc<AtomicBool>,
    /// Writer half per shard, each behind its own lock so an echo only
    /// serializes against broadcasts touching the same peer.
    writers: Mutex<Vec<Option<Arc<Mutex<TcpStream>>>>>,
    arrivals: Mutex<HashMap<u64, usize>>,
    handlers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl RelayShared {
    fn writer_arcs(&self) -> Vec<Arc<Mutex<TcpStream>>> {
        self.writers
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .flatten()
            .cloned()
            .collect()
    }

    /// Send a control frame to every registered peer; write errors are
    /// ignored (a peer that can't be reached is already dying, and its
    /// handler will notice).
    fn broadcast(&self, tag: FrameTag, round: u64) {
        let mut buf = Vec::with_capacity(HEADER_LEN);
        frame::encode_control(&mut buf, tag, 0, round);
        for w in self.writer_arcs() {
            let mut stream = w.lock().unwrap_or_else(|e| e.into_inner());
            let _ = stream.write_all(&buf);
        }
    }

    /// Count an arrival for crossing `c`; the Nth arrival releases all.
    fn on_arrive(&self, c: u64) {
        let release = {
            let mut arrivals = self.arrivals.lock().unwrap_or_else(|e| e.into_inner());
            let count = arrivals.entry(c).or_insert(0);
            *count += 1;
            let full = *count == self.parties;
            if full {
                arrivals.remove(&c);
            }
            full
        };
        if release {
            self.broadcast(FrameTag::Release, c);
        }
    }

    fn poison_all(&self) {
        self.broadcast(FrameTag::Poison, 0);
    }
}

/// Per-connection relay handler: counts arrivals, echoes data frames
/// back to the sender, and broadcasts poison on any read failure or
/// protocol violation.
fn relay_handler(shared: Arc<RelayShared>, mut read: TcpStream, writer: Arc<Mutex<TcpStream>>) {
    let mut buf = Vec::new();
    loop {
        match read_exact_frame(&mut read, &mut buf) {
            Ok(()) => match decode_frame(&buf) {
                Ok(Frame::Control {
                    tag: FrameTag::Arrive,
                    round,
                    ..
                }) => shared.on_arrive(round),
                Ok(Frame::Delta(_) | Frame::Decision { .. }) => {
                    let ok = {
                        let mut stream = writer.lock().unwrap_or_else(|e| e.into_inner());
                        stream.write_all(&buf).is_ok()
                    };
                    if !ok {
                        shared.poison_all();
                        return;
                    }
                }
                // shards never send release/poison; anything else is a
                // protocol violation and dooms the exchange
                Ok(Frame::Control { .. }) | Err(_) => {
                    shared.poison_all();
                    return;
                }
            },
            Err(_) => {
                // EOF or reset: a peer is gone. On a clean link
                // teardown that is expected; otherwise tell everyone.
                if !shared.closed.load(Ordering::Acquire) {
                    shared.poison_all();
                }
                return;
            }
        }
    }
}

/// Shard-side endpoint: one connection to the relay, used only by that
/// shard's pool leader (the locks exist for `Sync` soundness, not
/// contention).
struct Peer {
    read: Mutex<TcpStream>,
    write: Mutex<TcpStream>,
    /// Reused encode/receive buffer.
    scratch: Mutex<Vec<u8>>,
    /// Local crossing counter; all shards cross in lockstep, so equal
    /// counts name the same crossing — the relay's barrier key.
    crossings: AtomicU64,
}

/// The TCP [`ReconcileLink`]. See the module docs for topology and the
/// v1 scope statement; construction is [`TcpLink::connect`].
pub struct TcpLink {
    peers: Vec<Peer>,
    precision: WirePrecision,
    closed: Arc<AtomicBool>,
    relay: Arc<RelayShared>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    local_addr: SocketAddr,
}

impl TcpLink {
    /// Bind the relay on `listen` (use port 0 for an ephemeral port),
    /// dial one connection per shard, and wait until the relay has
    /// registered all of them. `peers` optionally overrides the dial
    /// address per shard (shard `s` dials `peers[min(s, len-1)]`; an
    /// empty slice dials the relay's own bound address — the
    /// single-box default). `timeout` (`None` = effectively forever)
    /// becomes every socket's read/write deadline, mapping
    /// `barrier_timeout_secs` onto the wire.
    pub fn connect(
        shards: usize,
        listen: &str,
        peers: &[String],
        timeout: Option<Duration>,
        precision: WirePrecision,
    ) -> io::Result<Self> {
        let parties = shards.max(1);
        let listener = TcpListener::bind(listen)?;
        let local_addr = listener.local_addr()?;
        let closed = Arc::new(AtomicBool::new(false));
        let relay = Arc::new(RelayShared {
            parties,
            closed: Arc::clone(&closed),
            writers: Mutex::new(vec![None; parties]),
            arrivals: Mutex::new(HashMap::new()),
            handlers: Mutex::new(Vec::new()),
        });

        // accept thread: register exactly `parties` connections (hello
        // frame identifies the shard), spawn a handler for each, then
        // signal readiness and stop listening
        let accept_relay = Arc::clone(&relay);
        let (ready_tx, ready_rx) = mpsc::channel::<io::Result<()>>();
        let accept_thread = std::thread::spawn(move || {
            let result = (|| -> io::Result<()> {
                for _ in 0..parties {
                    let (mut conn, _) = listener.accept()?;
                    conn.set_nodelay(true)?;
                    let mut hello = Vec::new();
                    read_exact_frame(&mut conn, &mut hello)?;
                    let shard = match decode_frame(&hello) {
                        Ok(Frame::Control {
                            tag: FrameTag::Arrive,
                            shard,
                            round: HELLO_ROUND,
                        }) if (shard as usize) < parties => shard as usize,
                        _ => {
                            return Err(io::Error::new(
                                io::ErrorKind::InvalidData,
                                "connection did not open with a valid hello frame",
                            ))
                        }
                    };
                    let writer = Arc::new(Mutex::new(conn.try_clone()?));
                    {
                        let mut writers = accept_relay
                            .writers
                            .lock()
                            .unwrap_or_else(|e| e.into_inner());
                        if writers[shard].is_some() {
                            return Err(io::Error::new(
                                io::ErrorKind::InvalidData,
                                "duplicate shard hello",
                            ));
                        }
                        writers[shard] = Some(Arc::clone(&writer));
                    }
                    let handler_relay = Arc::clone(&accept_relay);
                    let handle =
                        std::thread::spawn(move || relay_handler(handler_relay, conn, writer));
                    accept_relay
                        .handlers
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .push(handle);
                }
                Ok(())
            })();
            let failed = result.is_err();
            let _ = ready_tx.send(result);
            if failed {
                accept_relay.poison_all();
            }
        });

        // dial one connection per shard and say hello
        let connect_result = (|| -> io::Result<Vec<Peer>> {
            let mut endpoints = Vec::with_capacity(parties);
            for s in 0..parties {
                let addr = peers
                    .get(s.min(peers.len().wrapping_sub(1)))
                    .map(String::as_str)
                    .map(str::to_string)
                    .unwrap_or_else(|| local_addr.to_string());
                let stream = TcpStream::connect(addr.as_str())?;
                stream.set_nodelay(true)?;
                stream.set_read_timeout(timeout)?;
                stream.set_write_timeout(timeout)?;
                let mut hello = Vec::new();
                frame::encode_control(&mut hello, FrameTag::Arrive, s, HELLO_ROUND);
                let mut write = stream.try_clone()?;
                write.write_all(&hello)?;
                endpoints.push(Peer {
                    read: Mutex::new(stream),
                    write: Mutex::new(write),
                    scratch: Mutex::new(Vec::new()),
                    crossings: AtomicU64::new(0),
                });
            }
            // all connections must be registered before any crossing,
            // or an early arrive could release before a writer exists
            match ready_rx.recv_timeout(timeout.unwrap_or(Duration::from_secs(30))) {
                Ok(Ok(())) => Ok(endpoints),
                Ok(Err(e)) => Err(e),
                Err(_) => Err(io::Error::new(
                    io::ErrorKind::TimedOut,
                    "relay did not register all shard connections in time",
                )),
            }
        })();

        match connect_result {
            Ok(endpoints) => Ok(Self {
                peers: endpoints,
                precision,
                closed,
                relay,
                accept_thread: Some(accept_thread),
                local_addr,
            }),
            Err(e) => {
                closed.store(true, Ordering::Release);
                // unblock the accept thread if it is still waiting
                let _ = TcpStream::connect(local_addr);
                let _ = accept_thread.join();
                for h in relay
                    .handlers
                    .lock()
                    .unwrap_or_else(|p| p.into_inner())
                    .drain(..)
                {
                    let _ = h.join();
                }
                Err(e)
            }
        }
    }

    /// The relay's bound address (useful with `listen = "…:0"`).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    fn check_open(&self) -> Result<(), LinkFault> {
        if self.closed.load(Ordering::Acquire) {
            Err(LinkFault::Poisoned)
        } else {
            Ok(())
        }
    }

    fn io_fault(&self, e: &io::Error) -> LinkFault {
        let fault = match e.kind() {
            io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock => LinkFault::TimedOut,
            io::ErrorKind::InvalidData => LinkFault::Protocol("wire frame length prefix implausible"),
            _ => LinkFault::Poisoned,
        };
        // shut our socket down on the way out: the relay sees the close
        // and poisons the peers, so nobody waits for us (§Failure
        // semantics: the faulted waiter unblocks everyone else)
        self.poison();
        fault
    }

    fn protocol_fault(&self, reason: &'static str) -> LinkFault {
        self.poison();
        LinkFault::Protocol(reason)
    }

    fn send(&self, s: usize, bytes: &[u8]) -> Result<(), LinkFault> {
        let mut stream = self.peers[s].write.lock().unwrap_or_else(|e| e.into_inner());
        stream.write_all(bytes).map_err(|e| self.io_fault(&e))
    }

    /// One barrier crossing: announce arrival, block until the relay's
    /// release (or fail cleanly on poison/timeout/disconnect).
    fn cross(&self, s: usize) -> Result<(), LinkFault> {
        self.check_open()?;
        let peer = &self.peers[s];
        let c = peer.crossings.fetch_add(1, Ordering::Relaxed);
        {
            let mut buf = peer.scratch.lock().unwrap_or_else(|e| e.into_inner());
            buf.clear();
            frame::encode_control(&mut buf, FrameTag::Arrive, s, c);
            self.send(s, &buf)?;
        }
        let mut stream = peer.read.lock().unwrap_or_else(|e| e.into_inner());
        let mut buf = peer.scratch.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            read_exact_frame(&mut stream, &mut buf).map_err(|e| self.io_fault(&e))?;
            match decode_frame(&buf) {
                Ok(Frame::Control {
                    tag: FrameTag::Release,
                    round,
                    ..
                }) if round == c => return Ok(()),
                Ok(Frame::Control {
                    tag: FrameTag::Poison,
                    ..
                }) => {
                    self.poison();
                    return Err(LinkFault::Poisoned);
                }
                Ok(_) => return Err(self.protocol_fault("unexpected frame at a crossing")),
                Err(e) => return Err(self.protocol_fault(e.reason())),
            }
        }
    }
}

impl ReconcileLink for TcpLink {
    fn init(&self, s: usize) -> Result<(), LinkFault> {
        self.cross(s)
    }

    fn arrive(&self, s: usize, _round: usize) -> Result<(), LinkFault> {
        self.cross(s)
    }

    fn publish_fold(&self, s: usize, _round: usize) -> Result<(), LinkFault> {
        self.cross(s)
    }

    fn publish_decision(&self, s: usize, _round: usize) -> Result<(), LinkFault> {
        self.cross(s)
    }

    fn wire_precision(&self) -> Option<&'static str> {
        Some(self.precision.name())
    }

    fn poison(&self) {
        self.closed.store(true, Ordering::Release);
        for peer in &self.peers {
            if let Ok(stream) = peer.read.try_lock() {
                let _ = stream.shutdown(Shutdown::Both);
            } else if let Ok(stream) = peer.write.try_lock() {
                let _ = stream.shutdown(Shutdown::Both);
            }
        }
    }

    fn wire_delta(&self, s: usize, payload: &DeltaPayload<'_>) -> Result<WireCost, LinkFault> {
        self.check_open()?;
        let t0 = Instant::now();
        let z = payload.z;
        let peer = &self.peers[s];
        let tx = {
            let mut buf = peer.scratch.lock().unwrap_or_else(|e| e.into_inner());
            buf.clear();
            let tx = match payload.dirty {
                Some(d) => frame::encode_delta(
                    &mut buf,
                    s,
                    payload.round as u64,
                    self.precision,
                    payload.n,
                    |c| d.is_dirty(c),
                    |i| z.get(i),
                ),
                None => frame::encode_delta(
                    &mut buf,
                    s,
                    payload.round as u64,
                    self.precision,
                    payload.n,
                    |_| true,
                    |i| z.get(i),
                ),
            };
            self.send(s, &buf)?;
            tx
        };
        // the relay echoes the frame back; what we apply is what was on
        // the wire
        let mut stream = peer.read.lock().unwrap_or_else(|e| e.into_inner());
        let mut buf = peer.scratch.lock().unwrap_or_else(|e| e.into_inner());
        read_exact_frame(&mut stream, &mut buf).map_err(|e| self.io_fault(&e))?;
        match decode_frame(&buf) {
            Ok(Frame::Delta(d)) if d.shard as usize == s && d.round == payload.round as u64 => {
                d.apply(|i, v| z.set(i, v));
                Ok(WireCost {
                    bytes_tx: tx as u64,
                    bytes_rx: buf.len() as u64,
                    nanos: t0.elapsed().as_nanos() as u64,
                })
            }
            Ok(Frame::Control {
                tag: FrameTag::Poison,
                ..
            }) => {
                self.poison();
                Err(LinkFault::Poisoned)
            }
            Ok(_) => Err(self.protocol_fault("delta exchange received a non-delta frame")),
            Err(e) => Err(self.protocol_fault(e.reason())),
        }
    }

    fn wire_decision(&self, s: usize, payload: &mut DecisionPayload) -> Result<WireCost, LinkFault> {
        self.check_open()?;
        let t0 = Instant::now();
        let peer = &self.peers[s];
        let rec = DecisionRecord {
            round: payload.round as u64,
            next_gap: payload.next_gap as u64,
            stop: payload.stop,
        };
        let tx = {
            let mut buf = peer.scratch.lock().unwrap_or_else(|e| e.into_inner());
            buf.clear();
            let tx = frame::encode_decision(&mut buf, s, &rec);
            self.send(s, &buf)?;
            tx
        };
        let mut stream = peer.read.lock().unwrap_or_else(|e| e.into_inner());
        let mut buf = peer.scratch.lock().unwrap_or_else(|e| e.into_inner());
        read_exact_frame(&mut stream, &mut buf).map_err(|e| self.io_fault(&e))?;
        match decode_frame(&buf) {
            Ok(Frame::Decision { record, .. }) => {
                payload.next_gap = record.next_gap as usize;
                payload.stop = record.stop;
                Ok(WireCost {
                    bytes_tx: tx as u64,
                    bytes_rx: buf.len() as u64,
                    nanos: t0.elapsed().as_nanos() as u64,
                })
            }
            Ok(Frame::Control {
                tag: FrameTag::Poison,
                ..
            }) => {
                self.poison();
                Err(LinkFault::Poisoned)
            }
            Ok(_) => Err(self.protocol_fault("decision exchange received a non-decision frame")),
            Err(e) => Err(self.protocol_fault(e.reason())),
        }
    }
}

impl Drop for TcpLink {
    fn drop(&mut self) {
        self.closed.store(true, Ordering::Release);
        for peer in &self.peers {
            let stream = peer.read.lock().unwrap_or_else(|e| e.into_inner());
            let _ = stream.shutdown(Shutdown::Both);
        }
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        for h in self
            .relay
            .handlers
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .drain(..)
        {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn link(shards: usize, timeout_ms: u64) -> TcpLink {
        TcpLink::connect(
            shards,
            "127.0.0.1:0",
            &[],
            Some(Duration::from_millis(timeout_ms)),
            WirePrecision::Exact,
        )
        .expect("localhost bind + connect")
    }

    #[test]
    fn crossings_release_all_parties() {
        let l = Arc::new(link(3, 5_000));
        let released = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|scope| {
            for s in 0..3 {
                let l = Arc::clone(&l);
                let released = Arc::clone(&released);
                scope.spawn(move || {
                    for round in 0..4 {
                        l.arrive(s, round).expect("healthy crossing");
                        released.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert_eq!(released.load(Ordering::Relaxed), 12);
    }

    #[test]
    fn missing_peer_times_out_not_hangs() {
        let l = link(2, 200);
        // only shard 0 ever arrives; its wait must deadline cleanly
        let start = Instant::now();
        assert_eq!(l.arrive(0, 0), Err(LinkFault::TimedOut));
        assert!(start.elapsed() < Duration::from_secs(5));
        // after the fault the link is poisoned for everyone
        assert_eq!(l.arrive(1, 0), Err(LinkFault::Poisoned));
    }

    #[test]
    fn delta_and_decision_echo_through_the_relay() {
        use crate::util::atomic::SyncF64Vec;
        let l = link(1, 5_000);
        let z = SyncF64Vec::zeros(24);
        z.set(5, 1.25);
        z.set(17, -3.5);
        let before = z.snapshot();
        let cost = l
            .wire_delta(
                0,
                &DeltaPayload {
                    round: 0,
                    dirty: None,
                    z: &z,
                    n: 24,
                },
            )
            .expect("delta echo");
        assert_eq!(z.snapshot(), before);
        assert!(cost.bytes_tx > 0 && cost.bytes_rx == cost.bytes_tx);

        let mut decision = DecisionPayload {
            round: 0,
            next_gap: 8,
            stop: None,
        };
        l.wire_decision(0, &mut decision).expect("decision echo");
        assert_eq!(decision.next_gap, 8);
        assert_eq!(decision.stop, None);
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let l = link(2, 1_000);
        drop(l); // must not hang joining relay threads
    }
}
