//! Message-level fault plans for the wire transports.
//!
//! [`crate::sim`] injects *link-level* faults (delays, stragglers, pool
//! kills, virtual timeouts) below the unmodified pool code. This module
//! adds the faults that only exist once there are actual bytes: a
//! truncated frame, a duplicated delivery, a peer whose connection
//! drops mid-round. Like [`crate::sim::faults::FaultPlan`], a
//! [`NetFaultPlan`] is pure data — the [`LoopbackLink`] consults it at
//! each crossing with no RNG and no wall clock, so a faulted run
//! replays identically everywhere.
//!
//! [`LoopbackLink`]: crate::net::loopback::LoopbackLink

/// Deterministic message-fault schedule, consulted by
/// [`LoopbackLink`](crate::net::loopback::LoopbackLink) as frames cross.
/// `Default` is fault-free.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NetFaultPlan {
    /// Truncate the delta frame sent by `(shard, round)` to half its
    /// length before decode — the receiver must surface a clean
    /// [`DecodeError`](crate::net::codec::DecodeError), which the link
    /// converts to `LinkFault::Protocol` → `StopReason::ShardFailed`.
    pub truncate_at: Option<(usize, usize)>,
    /// Deliver every delta frame of this round **twice**. Because delta
    /// frames carry absolute chunk values (engine §Wire format), the
    /// second apply must be a no-op: the solve stays bit-exact.
    pub duplicate_round: Option<usize>,
    /// Drop `(shard, round)`'s connection before its delta is sent —
    /// the peer observes a dead link (`LinkFault::Poisoned`), and the
    /// solve must end `ShardFailed`, never hang.
    pub disconnect_at: Option<(usize, usize)>,
    /// Recovery twist on `disconnect_at`: `0` keeps the drop permanent
    /// (the pre-recover behavior above). `N > 0` means the dropped
    /// party re-dials and the drop **heals after N redial attempts** —
    /// provided the link grants it a reconnect budget of at least `N`
    /// ([`LoopbackLink::with_reconnect_budget`]). With a smaller budget
    /// the retries exhaust and the drop degrades to the permanent case.
    ///
    /// [`LoopbackLink::with_reconnect_budget`]:
    ///     crate::net::loopback::LoopbackLink::with_reconnect_budget
    pub heal_after_attempts: u32,
}

impl NetFaultPlan {
    pub fn is_fault_free(&self) -> bool {
        *self == NetFaultPlan::default()
    }

    /// Does `(shard, round)`'s outgoing delta frame get truncated?
    pub fn truncates(&self, shard: usize, round: usize) -> bool {
        self.truncate_at == Some((shard, round))
    }

    /// Are this round's delta frames delivered twice?
    pub fn duplicates(&self, round: usize) -> bool {
        self.duplicate_round == Some(round)
    }

    /// Does `(shard, round)` lose its connection at this crossing?
    pub fn disconnects(&self, shard: usize, round: usize) -> bool {
        self.disconnect_at == Some((shard, round))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_fault_free() {
        let plan = NetFaultPlan::default();
        assert!(plan.is_fault_free());
        assert!(!plan.truncates(0, 0));
        assert!(!plan.duplicates(0));
        assert!(!plan.disconnects(0, 0));
    }

    #[test]
    fn lookups_match_exact_coordinates() {
        let plan = NetFaultPlan {
            truncate_at: Some((1, 64)),
            duplicate_round: Some(32),
            disconnect_at: Some((0, 128)),
            heal_after_attempts: 0,
        };
        assert!(!plan.is_fault_free());
        assert!(plan.truncates(1, 64));
        assert!(!plan.truncates(1, 65));
        assert!(!plan.truncates(0, 64));
        assert!(plan.duplicates(32));
        assert!(!plan.duplicates(33));
        assert!(plan.disconnects(0, 128));
        assert!(!plan.disconnects(1, 128));
    }

    #[test]
    fn healable_plan_is_not_fault_free() {
        let plan = NetFaultPlan {
            disconnect_at: Some((0, 4)),
            heal_after_attempts: 2,
            ..Default::default()
        };
        assert!(!plan.is_fault_free());
        assert!(plan.disconnects(0, 4));
    }
}
