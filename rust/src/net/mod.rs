//! Distributed reconcile backends speaking the
//! [`ReconcileLink`](crate::shard::engine::ReconcileLink) contract.
//!
//! PR 5 made the dirty-chunk delta exchange literally the wire payload;
//! PR 6 put the exchange behind the `ReconcileLink` seam and gave it a
//! fault-scenario corpus. This module is the wire itself:
//!
//! * [`codec`] — zero-copy encode/decode primitives in the style of
//!   s2n-codec's `EncoderValue`/`DecoderValue`: borrowed buffers, typed
//!   [`DecodeError`](codec::DecodeError)s, no panics on untrusted
//!   bytes.
//! * [`frame`] — the length-prefixed reconcile frames (delta with
//!   dirty-chunk bitmap, fold-decision record, control plane), byte
//!   layout specified in [`crate::shard::engine`] §Wire format, with
//!   an f32-quantized mode behind the
//!   [`WirePrecision`](frame::WirePrecision) bit-exactness escape
//!   hatch.
//! * [`fault`] — deterministic message-level fault plans (frame
//!   truncation, duplicate delivery, mid-round disconnect).
//! * [`loopback`] — [`LoopbackLink`]: the full encode→frame→decode
//!   protocol in-process, so `cargo test -q` exercises every wire path
//!   with zero sockets; composes over
//!   [`SimLink`](crate::sim::SimLink) to run the scenario corpus
//!   through the codec.
//! * [`tcp`] — [`TcpLink`]: blocking `std::net` transport (coordinator
//!   relay + N shard peers) with `barrier_timeout_secs` mapped onto
//!   socket deadlines; every failure mode is a clean
//!   [`LinkFault`](crate::shard::engine::LinkFault), never a hang.
//!
//! Select a backend with [`Transport`] —
//! [`SolverBuilder::transport`](crate::solver::SolverBuilder::transport),
//! `solver.transport` in TOML, or `--transport` on the CLI.

pub mod codec;
pub mod fault;
pub mod frame;
pub mod loopback;
pub mod tcp;

pub use codec::{DecodeError, DecoderBuffer, DecoderValue, EncoderBuffer, EncoderValue};
pub use fault::NetFaultPlan;
pub use frame::{decode_frame, DecisionRecord, DeltaFrameRef, Frame, FrameTag, WirePrecision};
pub use loopback::LoopbackLink;
pub use tcp::TcpLink;

/// Which reconcile backend a sharded solve runs over. Configured via
/// [`SolverBuilder::transport`](crate::solver::SolverBuilder::transport)
/// (validated at `build()`), `solver.transport` in TOML, or
/// `--transport` on the CLI.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum Transport {
    /// The in-memory SpinBarrier protocol
    /// ([`BarrierLink`](crate::shard::engine::BarrierLink)) — the
    /// production default, bit-exact with the pre-seam engine.
    #[default]
    Barrier,
    /// The in-process wire protocol ([`LoopbackLink`]): every exchange
    /// through full encode→frame→decode, zero sockets. Bit-exact with
    /// `Barrier` under [`WirePrecision::Exact`].
    Loopback { precision: WirePrecision },
    /// Localhost/LAN TCP ([`TcpLink`]): coordinator relay at `listen`,
    /// shard `s` dialing `peers[min(s, len-1)]` (or `listen`'s bound
    /// address when `peers` is empty).
    Tcp {
        listen: String,
        peers: Vec<String>,
        precision: WirePrecision,
    },
}

impl Transport {
    /// Canonical name, as accepted by `solver.transport`.
    pub fn name(&self) -> &'static str {
        match self {
            Transport::Barrier => "barrier",
            Transport::Loopback { .. } => "loopback",
            Transport::Tcp { .. } => "tcp",
        }
    }

    /// Build a transport from the config-file string knobs
    /// (`solver.{transport, listen, peers, wire_precision}`). `peers`
    /// is comma-separated; empty entries are dropped. Returns `None`
    /// for an unknown transport or precision name.
    pub fn from_config(
        transport: &str,
        listen: &str,
        peers: &str,
        wire_precision: &str,
    ) -> Option<Self> {
        let precision = WirePrecision::by_name(wire_precision)?;
        match transport {
            "barrier" => Some(Transport::Barrier),
            "loopback" => Some(Transport::Loopback { precision }),
            "tcp" => Some(Transport::Tcp {
                listen: listen.to_string(),
                peers: peers
                    .split(',')
                    .map(str::trim)
                    .filter(|p| !p.is_empty())
                    .map(str::to_string)
                    .collect(),
                precision,
            }),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_from_config() {
        assert_eq!(
            Transport::from_config("barrier", "", "", "exact"),
            Some(Transport::Barrier)
        );
        assert_eq!(
            Transport::from_config("loopback", "", "", "f32"),
            Some(Transport::Loopback {
                precision: WirePrecision::F32
            })
        );
        assert_eq!(
            Transport::from_config("tcp", "127.0.0.1:0", " a:1, ,b:2 ", "exact"),
            Some(Transport::Tcp {
                listen: "127.0.0.1:0".into(),
                peers: vec!["a:1".into(), "b:2".into()],
                precision: WirePrecision::Exact
            })
        );
        assert_eq!(Transport::from_config("udp", "", "", "exact"), None);
        assert_eq!(Transport::from_config("barrier", "", "", "f16"), None);
        assert_eq!(Transport::default().name(), "barrier");
    }
}
