//! One-stop imports for embedding GenCD:
//! `use gencd::prelude::*;`
//!
//! Brings in the builder surface ([`Solver`], [`SolverBuilder`]), the
//! extension-point traits ([`Select`], [`Accept`], [`Observer`]), the
//! preset catalogue ([`Algorithm`]), the engine knobs most callers
//! touch ([`UpdatePath`], [`EngineConfig`]), the sharded execution
//! layer's surface ([`ShardStrategy`], [`ShardPlan`], the NUMA
//! [`Topology`]), the reconcile transports ([`Transport`],
//! [`WirePrecision`]), the screening layer's surface ([`ActiveSet`],
//! [`ScreenedSelect`]), the losses, and the result types (including
//! the structured failure [`SolveError`]/[`SolveErrorKind`]), and the
//! observability surface ([`Subscriber`], [`Events`], the provided
//! [`MetricsAggregator`]/[`StructuredLog`] subscribers) — plus
//! [`ControlFlow`], which observers return.

pub use crate::coordinator::accept::{Accept, AcceptContext, ThreadBest};
pub use crate::coordinator::algorithms::{Algorithm, Preprocessed};
pub use crate::coordinator::convergence::{
    History, Record, SolveError, SolveErrorKind, StopReason,
};
pub use crate::coordinator::engine::{
    EngineConfig, EngineHooks, SolveOutput, UpdatePath,
};
pub use crate::coordinator::metrics::MetricsSnapshot;
pub use crate::coordinator::observer::{IterationInfo, Observer};
pub use crate::coordinator::problem::{Problem, SharedState};
pub use crate::coordinator::select::Select;
pub use crate::event::{
    Events, Meta, MetricsAggregator, NoopSubscriber, StructuredLog, Subscriber,
};
pub use crate::loss::{Logistic, Loss, SmoothedHinge, Squared};
pub use crate::net::{Transport, WirePrecision};
pub use crate::screen::{ActiveSet, ScreenedSelect};
pub use crate::shard::{ShardPlan, ShardStrategy};
pub use crate::solver::{Solver, SolverBuilder};
pub use crate::sparse::{CooBuilder, CscMatrix};
pub use crate::util::topo::Topology;
pub use std::ops::ControlFlow;
