//! `gencd events --check`: validate a line-JSON event log. Every line
//! must parse with the vendored JSON parser and carry the envelope keys
//! (`ev`, `t`, `shard`); the stream as a whole must cover the kinds any
//! real solve produces. CI runs this against a `--log-format json` solve
//! and fails the `events` job on any malformed or missing-kind stream.

use std::collections::BTreeMap;

use crate::util::json;

/// Event kinds every successful logged solve must produce.
pub const EXPECTED_KINDS: &[&str] = &["iteration", "proposal", "update"];

/// Summary of a validated log.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct CheckReport {
    pub lines: usize,
    /// kind -> occurrences
    pub kinds: BTreeMap<String, usize>,
}

impl CheckReport {
    /// Human summary, one kind per line.
    pub fn render(&self) -> String {
        let mut out = format!("{} lines ok\n", self.lines);
        for (kind, count) in &self.kinds {
            out.push_str(&format!("  {kind:<12} {count}\n"));
        }
        out
    }
}

/// Validate every (non-empty) line: well-formed JSON object, envelope
/// keys present and typed. Returns the kind census on success, or the
/// first offending line's error.
pub fn check_lines<'a, I: IntoIterator<Item = &'a str>>(lines: I) -> Result<CheckReport, String> {
    let mut report = CheckReport::default();
    for (idx, raw) in lines.into_iter().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let v = json::parse(line).map_err(|e| format!("line {}: not valid JSON: {e}", idx + 1))?;
        let kind = v
            .get("ev")
            .and_then(|j| j.as_str())
            .ok_or_else(|| format!("line {}: missing string key \"ev\"", idx + 1))?;
        for key in ["t", "shard"] {
            v.get(key)
                .and_then(|j| j.as_f64())
                .ok_or_else(|| format!("line {}: missing numeric key \"{key}\"", idx + 1))?;
        }
        report.lines += 1;
        *report.kinds.entry(kind.to_string()).or_insert(0) += 1;
    }
    Ok(report)
}

/// Check that the stream covers the kinds a real solve must emit.
pub fn verify_coverage(report: &CheckReport) -> Result<(), String> {
    let missing: Vec<&str> = EXPECTED_KINDS
        .iter()
        .copied()
        .filter(|k| !report.kinds.contains_key(*k))
        .collect();
    if report.lines == 0 {
        return Err("event log is empty".to_string());
    }
    if missing.is_empty() {
        Ok(())
    } else {
        Err(format!("missing expected event kinds: {}", missing.join(", ")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::event::log::{event_fields, format_line, LogFormat};
    use crate::event::{Events, IterationCompleted, Meta, ProposalBatch, UpdateApplied};

    fn line(ev: Events) -> String {
        format_line(
            LogFormat::Json,
            &Meta::default(),
            ev.kind(),
            &event_fields(&ev),
        )
    }

    #[test]
    fn real_lines_pass_and_cover() {
        let lines = vec![
            line(Events::from(IterationCompleted {
                iter: 0,
                updates: 4,
                selected: 4,
                objective: Some(0.5),
                nnz: Some(2),
            })),
            line(Events::from(ProposalBatch {
                proposed: 4,
                deduped: 4,
            })),
            line(Events::from(UpdateApplied {
                path: "buffered",
                cols: 4,
            })),
        ];
        let report = check_lines(lines.iter().map(|s| s.as_str())).unwrap();
        assert_eq!(report.lines, 3);
        verify_coverage(&report).unwrap();
        assert!(report.render().contains("iteration"));
    }

    #[test]
    fn malformed_line_is_rejected() {
        let err = check_lines(["{\"ev\":\"iteration\",\"t\":}"]).unwrap_err();
        assert!(err.contains("line 1"), "{err}");
    }

    #[test]
    fn missing_envelope_is_rejected() {
        let err = check_lines(["{\"t\":1,\"shard\":0}"]).unwrap_err();
        assert!(err.contains("\"ev\""), "{err}");
        let err = check_lines(["{\"ev\":\"iteration\",\"shard\":0}"]).unwrap_err();
        assert!(err.contains("\"t\""), "{err}");
    }

    #[test]
    fn coverage_requires_expected_kinds() {
        let lines = vec![line(Events::from(UpdateApplied {
            path: "atomic",
            cols: 1,
        }))];
        let report = check_lines(lines.iter().map(|s| s.as_str())).unwrap();
        let err = verify_coverage(&report).unwrap_err();
        assert!(err.contains("iteration"), "{err}");
        let err = verify_coverage(&CheckReport::default()).unwrap_err();
        assert!(err.contains("empty"), "{err}");
    }
}
