//! [`MetricsAggregator`]: builds a [`MetricsSnapshot`] from the event
//! stream, and owns the cross-pool merge semantics ([`absorb`]) that the
//! sharded engine previously hand-maintained inline.
//!
//! The aggregator is `Clone` over shared state so the caller keeps a
//! handle after `SolverBuilder::subscriber` consumes one clone:
//!
//! ```ignore
//! let agg = MetricsAggregator::new();
//! let out = builder.subscriber(agg.clone()).build()?.run()?;
//! let m = agg.snapshot(); // same shape as out.metrics
//! ```
//!
//! [`absorb`]: MetricsAggregator::absorb

use std::sync::{Arc, Mutex};

use super::{
    CheckpointWritten, IterationCompleted, KktSweep, Meta, PeerReconnected, PhaseTimed,
    ProposalBatch, ReconcileRound, ResumeLoaded, ShardFailed, SolveInfo, SpillDrained,
    Subscriber, WireFrameReceived, WireFrameSent,
};
use crate::coordinator::metrics::MetricsSnapshot;

/// Recovery columns ([`crate::recover`]) accumulated from the event
/// stream. Kept beside — not inside — [`MetricsSnapshot`], per the
/// metrics-migration rule: new observability lands as events plus
/// aggregator columns, never as new hand-maintained snapshot fields.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RecoverColumns {
    /// Total redial attempts reported by `PeerReconnected` events.
    pub reconnect_attempts: u64,
    /// Checkpoint files written this solve.
    pub checkpoints_written: u64,
    /// Round the solve resumed from (0 = fresh solve).
    pub resume_round: u64,
}

/// Event-fed metrics accumulator. Counts arrive per event; end-of-solve
/// [`PhaseTimed`] rows fill in the phase seconds. The result mirrors the
/// engine's own `MetricsSnapshot` (the public struct is unchanged —
/// embedders that read `SolveOutput::metrics` see no difference).
#[derive(Clone, Default)]
pub struct MetricsAggregator {
    inner: Arc<Mutex<MetricsSnapshot>>,
    recover: Arc<Mutex<RecoverColumns>>,
}

impl MetricsAggregator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current accumulated snapshot (complete once the solve returns).
    pub fn snapshot(&self) -> MetricsSnapshot {
        *self.inner.lock().unwrap()
    }

    /// Recovery columns accumulated so far (reconnects, checkpoints,
    /// resume round) — the event-era siblings of the snapshot.
    pub fn recover_columns(&self) -> RecoverColumns {
        *self.recover.lock().unwrap()
    }

    /// Merge one pool's engine snapshot into a sharded aggregate: work
    /// counts and leader-CPU phase seconds sum across pools; the `Auto`
    /// update-path calibrations take the most-calibrated pool's value.
    ///
    /// This is the single home of the per-pool merge semantics — the
    /// sharded engine calls it instead of open-coding the field list.
    pub fn absorb(agg: &mut MetricsSnapshot, m: &MetricsSnapshot) {
        agg.updates += m.updates;
        agg.proposals += m.proposals;
        agg.propose_nnz += m.propose_nnz;
        agg.spill_iters += m.spill_iters;
        // screening: per-shard active sets — totals sum across pools
        agg.kkt_passes += m.kkt_passes;
        agg.reactivations += m.reactivations;
        agg.active_cols += m.active_cols;
        agg.select_secs += m.select_secs;
        agg.propose_secs += m.propose_secs;
        agg.accept_secs += m.accept_secs;
        agg.update_secs += m.update_secs;
        agg.screen_secs += m.screen_secs;
        agg.log_secs += m.log_secs;
        agg.auto_cas_ratio = agg.auto_cas_ratio.max(m.auto_cas_ratio);
        agg.auto_switch_factor = agg.auto_switch_factor.max(m.auto_switch_factor);
        // every pool resolves the same kernel mode (one config), so
        // keep the first non-empty report rather than inventing a merge
        if agg.kernel_tier.is_empty() {
            agg.kernel_tier = m.kernel_tier;
        }
    }
}

impl Subscriber for MetricsAggregator {
    type SolveContext = ();

    fn create_solve_context(&mut self, info: &SolveInfo) -> Self::SolveContext {
        if !info.kernel.is_empty() {
            self.inner.lock().unwrap().kernel_tier = info.kernel;
        }
    }

    fn on_iteration_completed(&mut self, _ctx: &mut (), _meta: &Meta, ev: &IterationCompleted) {
        let mut m = self.inner.lock().unwrap();
        // IterationCompleted arrives at the log cadence; counts it
        // carries are cumulative, so store-not-add.
        m.iterations = m.iterations.max(ev.iter + 1);
        m.updates = m.updates.max(ev.updates);
    }

    fn on_proposal_batch(&mut self, _ctx: &mut (), _meta: &Meta, ev: &ProposalBatch) {
        let mut m = self.inner.lock().unwrap();
        m.iterations += 1;
        m.proposals += ev.deduped;
    }

    fn on_spill_drained(&mut self, _ctx: &mut (), _meta: &Meta, _ev: &SpillDrained) {
        self.inner.lock().unwrap().spill_iters += 1;
    }

    fn on_kkt_sweep(&mut self, _ctx: &mut (), _meta: &Meta, ev: &KktSweep) {
        let mut m = self.inner.lock().unwrap();
        m.kkt_passes += 1;
        m.reactivations += ev.reactivations;
        m.active_cols = ev.active;
    }

    fn on_phase_timed(&mut self, _ctx: &mut (), _meta: &Meta, ev: &PhaseTimed) {
        let mut m = self.inner.lock().unwrap();
        match ev.key {
            "select" => m.select_secs = ev.secs,
            "propose" => m.propose_secs = ev.secs,
            "accept" => m.accept_secs = ev.secs,
            "update" => m.update_secs = ev.secs,
            "screen" => m.screen_secs = ev.secs,
            "log" => m.log_secs = ev.secs,
            "reconcile" => m.reconcile_secs = ev.secs,
            "codec" => m.codec_secs = ev.secs,
            _ => {}
        }
    }

    fn on_reconcile_round(&mut self, _ctx: &mut (), _meta: &Meta, ev: &ReconcileRound) {
        let mut m = self.inner.lock().unwrap();
        m.replica_divergence = m.replica_divergence.max(ev.divergence);
        m.dirty_chunk_frac = ev.dirty_frac;
    }

    fn on_shard_failed(&mut self, _ctx: &mut (), _meta: &Meta, _ev: &ShardFailed) {
        self.inner.lock().unwrap().shard_failures += 1;
    }

    fn on_wire_frame_sent(&mut self, _ctx: &mut (), _meta: &Meta, ev: &WireFrameSent) {
        self.inner.lock().unwrap().wire_bytes_tx += ev.bytes;
    }

    fn on_wire_frame_received(&mut self, _ctx: &mut (), _meta: &Meta, ev: &WireFrameReceived) {
        self.inner.lock().unwrap().wire_bytes_rx += ev.bytes;
    }

    fn on_checkpoint_written(&mut self, _ctx: &mut (), _meta: &Meta, _ev: &CheckpointWritten) {
        self.recover.lock().unwrap().checkpoints_written += 1;
    }

    fn on_peer_reconnected(&mut self, _ctx: &mut (), _meta: &Meta, ev: &PeerReconnected) {
        self.recover.lock().unwrap().reconnect_attempts += ev.attempts;
    }

    fn on_resume_loaded(&mut self, _ctx: &mut (), _meta: &Meta, ev: &ResumeLoaded) {
        self.recover.lock().unwrap().resume_round = ev.round;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::event::{Events, EventSink, Subscribed};

    #[test]
    fn absorb_sums_counts_and_maxes_calibrations() {
        let mut agg = MetricsSnapshot::default();
        let a = MetricsSnapshot {
            updates: 10,
            proposals: 12,
            propose_nnz: 100,
            spill_iters: 1,
            kkt_passes: 2,
            reactivations: 3,
            active_cols: 4,
            select_secs: 0.5,
            auto_cas_ratio: 2.0,
            auto_switch_factor: 1.5,
            ..Default::default()
        };
        MetricsAggregator::absorb(&mut agg, &a);
        MetricsAggregator::absorb(&mut agg, &a);
        assert_eq!(agg.updates, 20);
        assert_eq!(agg.proposals, 24);
        assert_eq!(agg.propose_nnz, 200);
        assert_eq!(agg.spill_iters, 2);
        assert_eq!(agg.kkt_passes, 4);
        assert_eq!(agg.reactivations, 6);
        assert_eq!(agg.active_cols, 8);
        assert!((agg.select_secs - 1.0).abs() < 1e-12);
        assert_eq!(agg.auto_cas_ratio, 2.0);
        assert_eq!(agg.auto_switch_factor, 1.5);
    }

    #[test]
    fn aggregates_from_events() {
        let agg = MetricsAggregator::new();
        let mut sub = Subscribed::new(agg.clone(), &SolveInfo::default());
        let meta = Meta::default();
        for i in 0..3u64 {
            sub.emit(
                &meta,
                &Events::from(ProposalBatch {
                    proposed: 5,
                    deduped: 4,
                }),
            );
            sub.emit(
                &meta,
                &Events::from(IterationCompleted {
                    iter: i,
                    updates: (i + 1) * 4,
                    selected: 4,
                    objective: Some(1.0),
                    nnz: Some(2),
                }),
            );
        }
        sub.emit(
            &meta,
            &Events::from(KktSweep {
                violators: 2,
                reactivations: 1,
                active: 7,
            }),
        );
        sub.emit(
            &meta,
            &Events::from(PhaseTimed {
                key: "update",
                label: "update",
                secs: 0.25,
            }),
        );
        sub.emit(&meta, &Events::from(WireFrameSent { bytes: 64, precision: "f32" }));
        let m = agg.snapshot();
        assert_eq!(m.iterations, 3);
        assert_eq!(m.proposals, 12);
        assert_eq!(m.updates, 12);
        assert_eq!(m.kkt_passes, 1);
        assert_eq!(m.reactivations, 1);
        assert_eq!(m.active_cols, 7);
        assert!((m.update_secs - 0.25).abs() < 1e-12);
        assert_eq!(m.wire_bytes_tx, 64);
    }

    #[test]
    fn recover_columns_accumulate_from_events() {
        use crate::event::{CheckpointWritten, PeerReconnected, ResumeLoaded};
        let agg = MetricsAggregator::new();
        let mut sub = Subscribed::new(agg.clone(), &SolveInfo::default());
        let meta = Meta::default();
        sub.emit(&meta, &Events::from(ResumeLoaded { round: 12, n: 40 }));
        sub.emit(&meta, &Events::from(CheckpointWritten { round: 16, bytes: 512 }));
        sub.emit(&meta, &Events::from(CheckpointWritten { round: 32, bytes: 512 }));
        sub.emit(&meta, &Events::from(PeerReconnected { attempts: 2 }));
        sub.emit(&meta, &Events::from(PeerReconnected { attempts: 1 }));
        let r = agg.recover_columns();
        assert_eq!(r.resume_round, 12);
        assert_eq!(r.checkpoints_written, 2);
        assert_eq!(r.reconnect_attempts, 3);
        // no MetricsSnapshot field involved — the snapshot is untouched
        let m = agg.snapshot();
        assert_eq!(m.iterations, 0);
        assert_eq!(m.updates, 0);
        assert_eq!(m.shard_failures, 0);
    }
}
