//! Typed event stream for the GenCD engine — the s2n-quic "events" design.
//!
//! Every engine phase and subsystem announces what it did through one
//! vocabulary of plain-data event structs, wrapped in the [`Events`] enum.
//! Emission is guarded by [`EventSink::enabled`]: the static [`NoopSink`]
//! returns `false` from an `#[inline]` method, so every emit site — the
//! branch *and* the event construction inside it — monomorphizes to nothing
//! when no subscriber is attached (pinned by the `event_emit_disabled`
//! hot-path bench row). Attaching a subscriber costs one dynamic dispatch
//! per event, and events are only emitted from leader/coordinator threads,
//! never from pool workers.
//!
//! Consumers implement [`Subscriber`] (one default-no-op `on_*` method per
//! event plus a per-solve context) and compose with tuples; the provided
//! subscribers are [`MetricsAggregator`] (builds a `MetricsSnapshot`),
//! [`StructuredLog`] (bounded line-JSON/text ring), and [`PhaseTable`]
//! (collects end-of-solve `PhaseTimed` rows for `--profile`).
//!
//! ## Determinism contract
//!
//! [`Meta::timestamp_ticks`] is *logical* time — iteration index in the
//! single-process engine, reconcile round in the sharded engine — never
//! wall-clock. The only wall-clock-bearing event is [`PhaseTimed`], which
//! [`StructuredLog`] excludes by default so two identical runs produce
//! byte-identical logs (exercised under `SimLink` in rust/tests/sim_faults.rs).

pub mod check;
pub mod log;
pub mod metrics;
pub mod phases;
pub mod subscriber;

pub use log::{LogFormat, StructuredLog};
pub use metrics::{MetricsAggregator, RecoverColumns};
pub use phases::PhaseTable;
pub use subscriber::{NoopSubscriber, Subscribed, Subscriber};

/// Where and when an event happened, in logical time.
///
/// `timestamp_ticks` is the engine's own clock (iteration index, or
/// reconcile round in the sharded engine) so event streams replay
/// deterministically; wall-clock never appears here.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Meta {
    pub timestamp_ticks: u64,
    pub shard: u32,
    pub thread: u32,
}

/// Per-solve shape handed to [`Subscriber::create_solve_context`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SolveInfo {
    /// rows (samples) in the design matrix
    pub n: u64,
    /// columns (features)
    pub k: u64,
    pub threads: u32,
    pub shards: u32,
    /// Kernel mode the solver will resolve
    /// ([`crate::kernel::KernelMode::name`]): `"reference"` or a
    /// dispatched SIMD tier name. Empty when the caller predates the
    /// kernel layer (e.g. [`Default`]).
    pub kernel: &'static str,
}

/// One engine iteration, emitted at the objective-log cadence (where the
/// objective is actually computed — same contract as `Observer`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IterationCompleted {
    pub iter: u64,
    /// cumulative coordinate updates so far
    pub updates: u64,
    /// coordinates selected this iteration
    pub selected: u64,
    pub objective: Option<f64>,
    pub nnz: Option<u64>,
}

/// A Select step produced a batch of candidate coordinates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProposalBatch {
    /// coordinates the selector yielded
    pub proposed: u64,
    /// survivors after the epoch-stamped duplicate filter
    pub deduped: u64,
}

/// The Update phase committed a batch through one of the write paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UpdateApplied {
    /// `UpdatePath::name()` of the mode actually chosen this iteration
    pub path: &'static str,
    pub cols: u64,
}

/// The buffered-update path drained its spill reservoir this iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpillDrained {
    pub iter: u64,
}

/// A KKT sweep over screened-out coordinates finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KktSweep {
    pub violators: u64,
    pub reactivations: u64,
    /// active-set size after the sweep
    pub active: u64,
}

/// Convergence was gated pending a full KKT sweep of the screened set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScreenGate {
    pub active: u64,
}

/// End-of-solve phase timing row — the only wall-clock-bearing event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseTimed {
    pub key: &'static str,
    pub label: &'static str,
    pub secs: f64,
}

/// A sharded reconcile round completed (coordinator only).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReconcileRound {
    pub round: u64,
    /// cumulative dirty-chunk fraction (folded / seen)
    pub dirty_frac: f64,
    /// max cross-replica divergence observed this round
    pub divergence: f64,
    /// reconcile gap chosen for the next round
    pub gap: u64,
}

/// A shard pool died: panic, link fault, or timeout.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardFailed {
    pub kind: &'static str,
}

/// A wire frame shipped to peers during reconcile.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireFrameSent {
    pub bytes: u64,
    pub precision: &'static str,
}

/// A wire frame arrived from peers during reconcile.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireFrameReceived {
    pub bytes: u64,
    pub precision: &'static str,
}

/// The wire codec rejected a frame (protocol-level fault).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CodecError {
    pub kind: &'static str,
}

/// One step of a regularization path solve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PathStep {
    pub step: u64,
    pub lambda: f64,
    pub nnz: u64,
    pub objective: f64,
}

/// The coordinator wrote a recovery checkpoint ([`crate::recover`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CheckpointWritten {
    /// completed global rounds captured by the file
    pub round: u64,
    /// encoded file size, CRC included
    pub bytes: u64,
}

/// A wire link healed a dead peer connection (`Meta::shard` is the
/// peer); emitted by the coordinator at the first reconciled round
/// after the heal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PeerReconnected {
    /// redial attempts spent since the last reconciled round
    pub attempts: u64,
}

/// A solve started from a recovery checkpoint instead of from zero.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResumeLoaded {
    /// completed global rounds restored from the file
    pub round: u64,
    /// feature count of the restored iterate
    pub n: u64,
}

/// The full event vocabulary; one variant per event struct.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Events {
    IterationCompleted(IterationCompleted),
    ProposalBatch(ProposalBatch),
    UpdateApplied(UpdateApplied),
    SpillDrained(SpillDrained),
    KktSweep(KktSweep),
    ScreenGate(ScreenGate),
    PhaseTimed(PhaseTimed),
    ReconcileRound(ReconcileRound),
    ShardFailed(ShardFailed),
    WireFrameSent(WireFrameSent),
    WireFrameReceived(WireFrameReceived),
    CodecError(CodecError),
    PathStep(PathStep),
    CheckpointWritten(CheckpointWritten),
    PeerReconnected(PeerReconnected),
    ResumeLoaded(ResumeLoaded),
}

macro_rules! impl_from {
    ($($ty:ident),* $(,)?) => {
        $(impl From<$ty> for Events {
            #[inline]
            fn from(ev: $ty) -> Events {
                Events::$ty(ev)
            }
        })*
    };
}
impl_from!(
    IterationCompleted,
    ProposalBatch,
    UpdateApplied,
    SpillDrained,
    KktSweep,
    ScreenGate,
    PhaseTimed,
    ReconcileRound,
    ShardFailed,
    WireFrameSent,
    WireFrameReceived,
    CodecError,
    PathStep,
    CheckpointWritten,
    PeerReconnected,
    ResumeLoaded,
);

impl Events {
    /// Stable short name used by the structured log and `events --check`.
    pub fn kind(&self) -> &'static str {
        match self {
            Events::IterationCompleted(_) => "iteration",
            Events::ProposalBatch(_) => "proposal",
            Events::UpdateApplied(_) => "update",
            Events::SpillDrained(_) => "spill",
            Events::KktSweep(_) => "kkt",
            Events::ScreenGate(_) => "screen_gate",
            Events::PhaseTimed(_) => "phase",
            Events::ReconcileRound(_) => "reconcile",
            Events::ShardFailed(_) => "shard_failed",
            Events::WireFrameSent(_) => "wire_tx",
            Events::WireFrameReceived(_) => "wire_rx",
            Events::CodecError(_) => "codec_error",
            Events::PathStep(_) => "path",
            Events::CheckpointWritten(_) => "checkpoint_written",
            Events::PeerReconnected(_) => "peer_reconnected",
            Events::ResumeLoaded(_) => "resume_loaded",
        }
    }
}

/// Receiver end of the stream, as seen by emit sites.
///
/// The engine is generic over `E: EventSink`; [`NoopSink`] (the default)
/// returns `false` from `enabled()` so emit sites fold away entirely.
/// An attached [`Subscribed`] subscriber is threaded as `&mut dyn EventSink`
/// — one virtual call per event, only on the path that asked for it.
pub trait EventSink: Send {
    /// Emit sites check this before constructing the event.
    #[inline]
    fn enabled(&self) -> bool {
        true
    }
    fn emit(&mut self, meta: &Meta, event: &Events);
}

/// The statically-dispatched "nobody listening" sink: `enabled()` is a
/// constant `false`, so every `emit!` site monomorphizes to nothing.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoopSink;

impl EventSink for NoopSink {
    #[inline]
    fn enabled(&self) -> bool {
        false
    }
    #[inline]
    fn emit(&mut self, _meta: &Meta, _event: &Events) {}
}

impl<T: EventSink + ?Sized> EventSink for &mut T {
    #[inline]
    fn enabled(&self) -> bool {
        (**self).enabled()
    }
    #[inline]
    fn emit(&mut self, meta: &Meta, event: &Events) {
        (**self).emit(meta, event)
    }
}

/// Emit an event through a sink, constructing it only if somebody listens.
///
/// `$ev` is any event struct (converted via `Events::from`); the whole
/// expression sits inside the `enabled()` branch so the disabled path pays
/// nothing — not even the field loads.
macro_rules! emit {
    ($sink:expr, $meta:expr, $ev:expr) => {
        if $sink.enabled() {
            let __meta = $meta;
            let __event = $crate::event::Events::from($ev);
            $sink.emit(&__meta, &__event);
        }
    };
}
pub(crate) use emit;

#[cfg(test)]
mod tests {
    use super::*;

    struct Counter(usize);
    impl EventSink for Counter {
        fn emit(&mut self, _meta: &Meta, _event: &Events) {
            self.0 += 1;
        }
    }

    #[test]
    fn noop_sink_is_disabled() {
        let sink = NoopSink;
        assert!(!sink.enabled());
    }

    #[test]
    fn emit_macro_respects_enabled() {
        let mut noop = NoopSink;
        emit!(noop, Meta::default(), SpillDrained { iter: 1 });
        let mut c = Counter(0);
        emit!(c, Meta::default(), SpillDrained { iter: 1 });
        emit!(
            c,
            Meta {
                timestamp_ticks: 2,
                shard: 0,
                thread: 0
            },
            UpdateApplied {
                path: "atomic",
                cols: 8
            }
        );
        assert_eq!(c.0, 2);
    }

    #[test]
    fn mut_ref_sink_forwards() {
        let mut c = Counter(0);
        {
            let mut r: &mut dyn EventSink = &mut c;
            assert!(r.enabled());
            emit!(r, Meta::default(), ScreenGate { active: 3 });
        }
        assert_eq!(c.0, 1);
    }

    #[test]
    fn kinds_are_stable() {
        assert_eq!(
            Events::from(IterationCompleted {
                iter: 0,
                updates: 0,
                selected: 0,
                objective: None,
                nnz: None
            })
            .kind(),
            "iteration"
        );
        assert_eq!(Events::from(CodecError { kind: "protocol" }).kind(), "codec_error");
    }
}
