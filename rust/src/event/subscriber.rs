//! The [`Subscriber`] trait: typed, composable event consumers.
//!
//! One default-no-op `on_*` method per event in the vocabulary, plus a
//! per-solve context created at attach time (the s2n-quic
//! `ConnectionContext` pattern): state that belongs to *this solve* lives
//! in `SolveContext`, state that outlives solves lives in the subscriber.
//!
//! Composition is structural: `(A, B)` is a subscriber that fans every
//! event out to both (nest tuples for more). [`NoopSubscriber`] is the
//! do-nothing anchor. [`Subscribed`] pairs a subscriber with its context
//! and adapts it to the erased [`EventSink`] the engine threads through —
//! and, the other way, to the legacy [`Observer`] callback, so anything
//! expecting an observer can be fed from the event stream.

use std::ops::ControlFlow;

use super::{
    CheckpointWritten, CodecError, Events, EventSink, IterationCompleted, KktSweep, Meta,
    PathStep, PeerReconnected, PhaseTimed, ProposalBatch, ReconcileRound, ResumeLoaded,
    ScreenGate, ShardFailed, SolveInfo, SpillDrained, UpdateApplied, WireFrameReceived,
    WireFrameSent,
};
use crate::coordinator::observer::{IterationInfo, Observer};

/// Generates the trait, the tuple composition, and the `Subscribed`
/// dispatch from one list, so the three can never drift apart.
macro_rules! subscriber_vocabulary {
    ($(($method:ident, $variant:ident)),* $(,)?) => {
        /// A typed event consumer. Every method defaults to a no-op, so
        /// implementors name only the events they care about.
        pub trait Subscriber: Send + 'static {
            /// Per-solve state; created once per solve at attach time.
            type SolveContext: Send;

            fn create_solve_context(&mut self, info: &SolveInfo) -> Self::SolveContext;

            $(
                #[allow(unused_variables)]
                #[inline]
                fn $method(
                    &mut self,
                    ctx: &mut Self::SolveContext,
                    meta: &Meta,
                    event: &super::$variant,
                ) {
                }
            )*
        }

        /// Subscribers compose structurally: `(A, B)` fans each event out
        /// to `A` then `B`, each with its own solve context.
        impl<A: Subscriber, B: Subscriber> Subscriber for (A, B) {
            type SolveContext = (A::SolveContext, B::SolveContext);

            fn create_solve_context(&mut self, info: &SolveInfo) -> Self::SolveContext {
                (self.0.create_solve_context(info), self.1.create_solve_context(info))
            }

            $(
                #[inline]
                fn $method(
                    &mut self,
                    ctx: &mut Self::SolveContext,
                    meta: &Meta,
                    event: &super::$variant,
                ) {
                    self.0.$method(&mut ctx.0, meta, event);
                    self.1.$method(&mut ctx.1, meta, event);
                }
            )*
        }

        impl<S: Subscriber> EventSink for Subscribed<S> {
            fn emit(&mut self, meta: &Meta, event: &Events) {
                match event {
                    $(Events::$variant(ev) => {
                        self.subscriber.$method(&mut self.ctx, meta, ev)
                    })*
                }
            }
        }
    };
}

subscriber_vocabulary!(
    (on_iteration_completed, IterationCompleted),
    (on_proposal_batch, ProposalBatch),
    (on_update_applied, UpdateApplied),
    (on_spill_drained, SpillDrained),
    (on_kkt_sweep, KktSweep),
    (on_screen_gate, ScreenGate),
    (on_phase_timed, PhaseTimed),
    (on_reconcile_round, ReconcileRound),
    (on_shard_failed, ShardFailed),
    (on_wire_frame_sent, WireFrameSent),
    (on_wire_frame_received, WireFrameReceived),
    (on_codec_error, CodecError),
    (on_path_step, PathStep),
    (on_checkpoint_written, CheckpointWritten),
    (on_peer_reconnected, PeerReconnected),
    (on_resume_loaded, ResumeLoaded),
);

/// The subscriber that hears nothing. With it (or with no subscriber at
/// all) every emit site in the engine compiles to nothing — the
/// transparency tests in rust/tests/events.rs pin bit-identical output
/// across `NoopSubscriber` / no subscriber / `MetricsAggregator`.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoopSubscriber;

impl Subscriber for NoopSubscriber {
    type SolveContext = ();
    fn create_solve_context(&mut self, _info: &SolveInfo) -> Self::SolveContext {}
}

/// A subscriber bound to its per-solve context; this is what the engine
/// actually drives (via its [`EventSink`] impl, generated above).
pub struct Subscribed<S: Subscriber> {
    subscriber: S,
    ctx: S::SolveContext,
}

impl<S: Subscriber> Subscribed<S> {
    pub fn new(mut subscriber: S, info: &SolveInfo) -> Self {
        let ctx = subscriber.create_solve_context(info);
        Subscribed { subscriber, ctx }
    }

    pub fn into_inner(self) -> S {
        self.subscriber
    }
}

/// The legacy [`Observer`] hook is a view of the event stream: any
/// subscribed subscriber can stand wherever an observer was expected,
/// receiving each logged iteration as an [`IterationCompleted`].
impl<S: Subscriber> Observer for Subscribed<S> {
    fn on_iteration(&mut self, info: &IterationInfo<'_>) -> ControlFlow<()> {
        let meta = Meta {
            timestamp_ticks: info.iter as u64,
            shard: 0,
            thread: 0,
        };
        let ev = IterationCompleted {
            iter: info.iter as u64,
            updates: info.updates,
            selected: info.selected as u64,
            objective: info.objective,
            nnz: info.nnz.map(|v| v as u64),
        };
        self.subscriber.on_iteration_completed(&mut self.ctx, &meta, &ev);
        ControlFlow::Continue(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Default)]
    struct CountingSub;
    #[derive(Default)]
    struct Counts {
        iterations: usize,
        spills: usize,
    }
    impl Subscriber for CountingSub {
        type SolveContext = Counts;
        fn create_solve_context(&mut self, _info: &SolveInfo) -> Counts {
            Counts::default()
        }
        fn on_iteration_completed(
            &mut self,
            ctx: &mut Counts,
            _meta: &Meta,
            _ev: &IterationCompleted,
        ) {
            ctx.iterations += 1;
        }
        fn on_spill_drained(&mut self, ctx: &mut Counts, _meta: &Meta, _ev: &SpillDrained) {
            ctx.spills += 1;
        }
    }

    fn iteration(iter: u64) -> Events {
        Events::from(IterationCompleted {
            iter,
            updates: 0,
            selected: 0,
            objective: None,
            nnz: None,
        })
    }

    #[test]
    fn subscribed_dispatches_by_variant() {
        let mut sub = Subscribed::new(CountingSub, &SolveInfo::default());
        let meta = Meta::default();
        sub.emit(&meta, &iteration(0));
        sub.emit(&meta, &Events::from(SpillDrained { iter: 1 }));
        sub.emit(&meta, &Events::from(ScreenGate { active: 2 }));
        let counts = &sub.ctx;
        assert_eq!(counts.iterations, 1);
        assert_eq!(counts.spills, 1);
    }

    #[test]
    fn tuples_fan_out_with_independent_contexts() {
        let mut sub = Subscribed::new((CountingSub, CountingSub), &SolveInfo::default());
        let meta = Meta::default();
        sub.emit(&meta, &iteration(0));
        sub.emit(&meta, &iteration(1));
        assert_eq!(sub.ctx.0.iterations, 2);
        assert_eq!(sub.ctx.1.iterations, 2);
    }

    #[test]
    fn noop_composes() {
        let mut sub = Subscribed::new((NoopSubscriber, CountingSub), &SolveInfo::default());
        sub.emit(&Meta::default(), &iteration(0));
        assert_eq!(sub.ctx.1.iterations, 1);
    }

    #[test]
    fn subscribed_adapts_to_observer() {
        use crate::coordinator::problem::SharedState;
        let state = SharedState::new(2, 2);
        let mut sub = Subscribed::new(CountingSub, &SolveInfo::default());
        let flow = sub.on_iteration(&IterationInfo {
            iter: 3,
            elapsed_secs: 0.1,
            updates: 9,
            selected: 2,
            objective: Some(1.0),
            nnz: Some(1),
            state: &state,
        });
        assert!(flow.is_continue());
        assert_eq!(sub.ctx.iterations, 1);
    }
}
