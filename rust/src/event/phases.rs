//! The canonical phase-timing table: one (key, label) list and one
//! snapshot-to-rows projection shared by `--profile`, the `gencd
//! screen/numa/net` experiment columns, and the BENCH emitters — phase
//! naming can no longer drift between them.
//!
//! The engine emits one [`PhaseTimed`] event per row at end-of-solve
//! (the only wall-clock-bearing events in the stream); [`PhaseTable`] is
//! the subscriber that collects them back into a table.

use std::sync::{Arc, Mutex};

use super::{emit, EventSink, Meta, PhaseTimed, SolveInfo, Subscriber};
use crate::coordinator::metrics::MetricsSnapshot;

/// One timed phase of a solve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseRow {
    /// stable machine key (`events --check`, bench emitter keys)
    pub key: &'static str,
    /// human label (`--profile` rows, experiment columns)
    pub label: &'static str,
    pub secs: f64,
}

/// Project a metrics snapshot onto the canonical phase table. Engine
/// phases always appear; the sharded-only rows (`reconcile`, `codec`)
/// appear when the snapshot came from a sharded solve.
pub fn rows(m: &MetricsSnapshot) -> Vec<PhaseRow> {
    let mut rows = vec![
        PhaseRow {
            key: "select",
            label: "select+log",
            secs: m.select_secs + m.log_secs,
        },
        PhaseRow {
            key: "propose",
            label: "propose",
            secs: m.propose_secs,
        },
        PhaseRow {
            key: "accept",
            label: "accept",
            secs: m.accept_secs,
        },
        PhaseRow {
            key: "update",
            label: "update",
            secs: m.update_secs,
        },
        PhaseRow {
            key: "screen",
            label: "screen",
            secs: m.screen_secs,
        },
    ];
    if m.shards > 0 {
        rows.push(PhaseRow {
            key: "reconcile",
            label: "reconcile",
            secs: m.reconcile_secs,
        });
        rows.push(PhaseRow {
            key: "codec",
            label: "codec",
            secs: m.codec_secs,
        });
    }
    rows
}

/// Seconds for one phase key, 0.0 if the key is absent from this
/// snapshot's table — the lookup the experiment columns use, so their
/// numbers come from the same projection as `--profile`.
pub fn phase_secs(m: &MetricsSnapshot, key: &str) -> f64 {
    rows(m).iter().find(|r| r.key == key).map_or(0.0, |r| r.secs)
}

/// Emit the canonical table as [`PhaseTimed`] events (end-of-solve; both
/// the single-process and sharded engines call this exactly once).
pub fn emit_rows<E: EventSink>(sink: &mut E, meta: Meta, m: &MetricsSnapshot) {
    for row in rows(m) {
        emit!(
            sink,
            meta,
            PhaseTimed {
                key: row.key,
                label: row.label,
                secs: row.secs,
            }
        );
    }
}

/// Subscriber that collects [`PhaseTimed`] rows — the consumer side of
/// the `--profile` table. `Clone` shares the row store.
#[derive(Clone, Default)]
pub struct PhaseTable {
    rows: Arc<Mutex<Vec<PhaseRow>>>,
}

impl PhaseTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn rows(&self) -> Vec<PhaseRow> {
        self.rows.lock().unwrap().clone()
    }

    /// Sum of all collected phase seconds (for the `--profile` "other"
    /// remainder row).
    pub fn total_secs(&self) -> f64 {
        self.rows.lock().unwrap().iter().map(|r| r.secs).sum()
    }
}

impl Subscriber for PhaseTable {
    type SolveContext = ();

    fn create_solve_context(&mut self, _info: &SolveInfo) -> Self::SolveContext {}

    fn on_phase_timed(&mut self, _ctx: &mut (), _meta: &Meta, ev: &PhaseTimed) {
        self.rows.lock().unwrap().push(PhaseRow {
            key: ev.key,
            label: ev.label,
            secs: ev.secs,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::event::Subscribed;

    #[test]
    fn unsharded_rows_have_engine_phases_only() {
        let m = MetricsSnapshot {
            select_secs: 0.1,
            log_secs: 0.05,
            propose_secs: 0.2,
            ..Default::default()
        };
        let rows = rows(&m);
        assert_eq!(rows.len(), 5);
        assert_eq!(rows[0].key, "select");
        assert!((rows[0].secs - 0.15).abs() < 1e-12);
        assert!(rows.iter().all(|r| r.key != "reconcile"));
    }

    #[test]
    fn sharded_rows_add_reconcile_and_codec() {
        let m = MetricsSnapshot {
            shards: 4,
            reconcile_secs: 0.3,
            codec_secs: 0.01,
            ..Default::default()
        };
        let keys: Vec<_> = rows(&m).iter().map(|r| r.key).collect();
        assert!(keys.contains(&"reconcile"));
        assert!(keys.contains(&"codec"));
        assert_eq!(phase_secs(&m, "reconcile"), 0.3);
        // unsharded snapshot has no codec row
        assert_eq!(phase_secs(&MetricsSnapshot::default(), "codec"), 0.0);
    }

    #[test]
    fn emitted_rows_round_trip_through_phase_table() {
        let m = MetricsSnapshot {
            shards: 2,
            update_secs: 0.5,
            reconcile_secs: 0.25,
            ..Default::default()
        };
        let table = PhaseTable::new();
        let mut sink = Subscribed::new(table.clone(), &SolveInfo::default());
        emit_rows(&mut sink, Meta::default(), &m);
        let collected = table.rows();
        assert_eq!(collected, rows(&m));
        assert!((table.total_secs() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn noop_sink_collects_nothing() {
        // compile-and-run proof that emit_rows is free when disabled
        let mut sink = crate::event::NoopSink;
        emit_rows(&mut sink, Meta::default(), &MetricsSnapshot::default());
    }
}
