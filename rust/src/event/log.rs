//! [`StructuredLog`]: line-oriented event log (text or line-JSON) behind a
//! bounded per-solve ring buffer, plus the one shared line formatter
//! ([`format_line`]) that the sim report renderer uses too — so sim
//! verdict logs and production logs are byte-for-byte the same format.

use std::collections::VecDeque;
use std::fmt::Write as _;
use std::sync::{Arc, Mutex};

use super::{Events, Meta, SolveInfo, Subscriber};

/// Output syntax of the structured log (`--log-format json|text`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LogFormat {
    #[default]
    Text,
    Json,
}

impl LogFormat {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "text" => Some(LogFormat::Text),
            "json" => Some(LogFormat::Json),
            _ => None,
        }
    }
}

/// One typed field of a log line. `None`-ish values are simply omitted
/// by the caller; non-finite floats render as `null` / `nan`.
#[derive(Debug, Clone, Copy)]
pub enum Field {
    U64(u64),
    F64(f64),
    Str(&'static str),
}

/// Render one event line. This is THE log syntax — both the structured
/// log and `gencd sim --events` go through here, so the two streams stay
/// byte-compatible:
///
/// - text: `t=00000012 shard=01 kind key=value ...`
/// - json: `{"ev":"kind","t":12,"shard":1,"thread":0,"key":value,...}`
///
/// Formatting is deterministic (logical timestamps, shortest-roundtrip
/// floats), which the two-run byte-identity test in sim_faults.rs pins.
pub fn format_line(format: LogFormat, meta: &Meta, kind: &str, fields: &[(&str, Field)]) -> String {
    let mut s = String::with_capacity(64);
    match format {
        LogFormat::Text => {
            let _ = write!(s, "t={:08} shard={:02} {}", meta.timestamp_ticks, meta.shard, kind);
            for (key, value) in fields {
                let _ = match value {
                    Field::U64(v) => write!(s, " {key}={v}"),
                    Field::F64(v) if v.is_finite() => write!(s, " {key}={v}"),
                    Field::F64(_) => write!(s, " {key}=nan"),
                    Field::Str(v) => write!(s, " {key}={v}"),
                };
            }
        }
        LogFormat::Json => {
            let _ = write!(
                s,
                "{{\"ev\":\"{}\",\"t\":{},\"shard\":{},\"thread\":{}",
                kind, meta.timestamp_ticks, meta.shard, meta.thread
            );
            for (key, value) in fields {
                let _ = match value {
                    Field::U64(v) => write!(s, ",\"{key}\":{v}"),
                    Field::F64(v) if v.is_finite() => write!(s, ",\"{key}\":{v}"),
                    Field::F64(_) => write!(s, ",\"{key}\":null"),
                    Field::Str(v) => write!(s, ",\"{key}\":\"{v}\""),
                };
            }
            s.push('}');
        }
    }
    s
}

/// Decompose an event into its log fields (name/value pairs, in a fixed
/// order). Shared by the structured log and anything else that needs a
/// flat view of the vocabulary.
pub fn event_fields(ev: &Events) -> Vec<(&'static str, Field)> {
    match ev {
        Events::IterationCompleted(e) => {
            let mut f = vec![
                ("iter", Field::U64(e.iter)),
                ("updates", Field::U64(e.updates)),
                ("selected", Field::U64(e.selected)),
            ];
            if let Some(obj) = e.objective {
                f.push(("objective", Field::F64(obj)));
            }
            if let Some(nnz) = e.nnz {
                f.push(("nnz", Field::U64(nnz)));
            }
            f
        }
        Events::ProposalBatch(e) => vec![
            ("proposed", Field::U64(e.proposed)),
            ("deduped", Field::U64(e.deduped)),
        ],
        Events::UpdateApplied(e) => vec![
            ("path", Field::Str(e.path)),
            ("cols", Field::U64(e.cols)),
        ],
        Events::SpillDrained(e) => vec![("iter", Field::U64(e.iter))],
        Events::KktSweep(e) => vec![
            ("violators", Field::U64(e.violators)),
            ("reactivations", Field::U64(e.reactivations)),
            ("active", Field::U64(e.active)),
        ],
        Events::ScreenGate(e) => vec![("active", Field::U64(e.active))],
        Events::PhaseTimed(e) => vec![
            ("key", Field::Str(e.key)),
            ("label", Field::Str(e.label)),
            ("secs", Field::F64(e.secs)),
        ],
        Events::ReconcileRound(e) => vec![
            ("round", Field::U64(e.round)),
            ("dirty_frac", Field::F64(e.dirty_frac)),
            ("divergence", Field::F64(e.divergence)),
            ("gap", Field::U64(e.gap)),
        ],
        Events::ShardFailed(e) => vec![("kind", Field::Str(e.kind))],
        Events::WireFrameSent(e) => vec![
            ("bytes", Field::U64(e.bytes)),
            ("precision", Field::Str(e.precision)),
        ],
        Events::WireFrameReceived(e) => vec![
            ("bytes", Field::U64(e.bytes)),
            ("precision", Field::Str(e.precision)),
        ],
        Events::CodecError(e) => vec![("kind", Field::Str(e.kind))],
        Events::PathStep(e) => vec![
            ("step", Field::U64(e.step)),
            ("lambda", Field::F64(e.lambda)),
            ("nnz", Field::U64(e.nnz)),
            ("objective", Field::F64(e.objective)),
        ],
        Events::CheckpointWritten(e) => vec![
            ("round", Field::U64(e.round)),
            ("bytes", Field::U64(e.bytes)),
        ],
        Events::PeerReconnected(e) => vec![("attempts", Field::U64(e.attempts))],
        Events::ResumeLoaded(e) => vec![
            ("round", Field::U64(e.round)),
            ("n", Field::U64(e.n)),
        ],
    }
}

struct Inner {
    format: LogFormat,
    lines: VecDeque<String>,
    cap: usize,
    dropped: u64,
    /// `PhaseTimed` carries wall-clock seconds — excluded by default so
    /// identical runs log byte-identically; opt in for human profiling.
    include_timing: bool,
}

/// Subscriber that renders every event into a bounded in-memory line
/// ring. `Clone` shares the ring, so keep a handle to read lines after
/// the builder consumed the other clone.
#[derive(Clone)]
pub struct StructuredLog {
    inner: Arc<Mutex<Inner>>,
}

/// Default ring capacity: enough for any log-cadence stream while
/// bounding memory on pathological per-iteration floods.
const DEFAULT_CAP: usize = 4096;

impl StructuredLog {
    pub fn new(format: LogFormat) -> Self {
        Self::with_capacity(format, DEFAULT_CAP)
    }

    pub fn with_capacity(format: LogFormat, cap: usize) -> Self {
        StructuredLog {
            inner: Arc::new(Mutex::new(Inner {
                format,
                lines: VecDeque::new(),
                cap: cap.max(1),
                dropped: 0,
                include_timing: false,
            })),
        }
    }

    pub fn json() -> Self {
        Self::new(LogFormat::Json)
    }

    pub fn text() -> Self {
        Self::new(LogFormat::Text)
    }

    /// Also log `PhaseTimed` rows (wall-clock — breaks byte-identical
    /// replay, fine for interactive use).
    pub fn with_timing(self) -> Self {
        self.inner.lock().unwrap().include_timing = true;
        self
    }

    /// Lines currently in the ring, oldest first.
    pub fn lines(&self) -> Vec<String> {
        self.inner.lock().unwrap().lines.iter().cloned().collect()
    }

    /// Lines evicted because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    fn push(&self, meta: &Meta, ev: &Events) {
        let mut inner = self.inner.lock().unwrap();
        if matches!(ev, Events::PhaseTimed(_)) && !inner.include_timing {
            return;
        }
        let line = format_line(inner.format, meta, ev.kind(), &event_fields(ev));
        if inner.lines.len() == inner.cap {
            inner.lines.pop_front();
            inner.dropped += 1;
        }
        inner.lines.push_back(line);
    }
}

macro_rules! log_all {
    ($(($method:ident, $variant:ident)),* $(,)?) => {
        impl Subscriber for StructuredLog {
            type SolveContext = ();
            fn create_solve_context(&mut self, _info: &SolveInfo) -> Self::SolveContext {}
            $(
                fn $method(
                    &mut self,
                    _ctx: &mut (),
                    meta: &Meta,
                    event: &super::$variant,
                ) {
                    self.push(meta, &Events::from(*event));
                }
            )*
        }
    };
}

log_all!(
    (on_iteration_completed, IterationCompleted),
    (on_proposal_batch, ProposalBatch),
    (on_update_applied, UpdateApplied),
    (on_spill_drained, SpillDrained),
    (on_kkt_sweep, KktSweep),
    (on_screen_gate, ScreenGate),
    (on_phase_timed, PhaseTimed),
    (on_reconcile_round, ReconcileRound),
    (on_shard_failed, ShardFailed),
    (on_wire_frame_sent, WireFrameSent),
    (on_wire_frame_received, WireFrameReceived),
    (on_codec_error, CodecError),
    (on_path_step, PathStep),
    (on_checkpoint_written, CheckpointWritten),
    (on_peer_reconnected, PeerReconnected),
    (on_resume_loaded, ResumeLoaded),
);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::event::{EventSink, IterationCompleted, PhaseTimed, Subscribed, UpdateApplied};

    fn meta(t: u64, shard: u32) -> Meta {
        Meta {
            timestamp_ticks: t,
            shard,
            thread: 0,
        }
    }

    #[test]
    fn text_lines_are_fixed_width_prefixed() {
        let line = format_line(
            LogFormat::Text,
            &meta(12, 1),
            "arrive",
            &[("round", Field::U64(3))],
        );
        assert_eq!(line, "t=00000012 shard=01 arrive round=3");
    }

    #[test]
    fn json_lines_parse_with_vendored_parser() {
        let line = format_line(
            LogFormat::Json,
            &meta(5, 0),
            "iteration",
            &[
                ("iter", Field::U64(5)),
                ("objective", Field::F64(0.125)),
                ("path", Field::Str("buffered")),
            ],
        );
        let v = crate::util::json::parse(&line).expect("line must be valid JSON");
        assert_eq!(v.get("ev").and_then(|j| j.as_str()), Some("iteration"));
        assert_eq!(v.get("t").and_then(|j| j.as_f64()), Some(5.0));
        assert_eq!(v.get("objective").and_then(|j| j.as_f64()), Some(0.125));
        assert_eq!(v.get("path").and_then(|j| j.as_str()), Some("buffered"));
    }

    #[test]
    fn ring_is_bounded() {
        let log = StructuredLog::with_capacity(LogFormat::Text, 2);
        let mut sub = Subscribed::new(log.clone(), &SolveInfo::default());
        for i in 0..5u64 {
            sub.emit(
                &meta(i, 0),
                &Events::from(UpdateApplied {
                    path: "atomic",
                    cols: i,
                }),
            );
        }
        let lines = log.lines();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("cols=3"));
        assert!(lines[1].contains("cols=4"));
        assert_eq!(log.dropped(), 3);
    }

    #[test]
    fn phase_timing_excluded_by_default() {
        let log = StructuredLog::json();
        let mut sub = Subscribed::new(log.clone(), &SolveInfo::default());
        sub.emit(
            &meta(0, 0),
            &Events::from(PhaseTimed {
                key: "update",
                label: "update",
                secs: 1.0,
            }),
        );
        assert!(log.lines().is_empty());

        let timed = StructuredLog::json().with_timing();
        let mut sub = Subscribed::new(timed.clone(), &SolveInfo::default());
        sub.emit(
            &meta(0, 0),
            &Events::from(PhaseTimed {
                key: "update",
                label: "update",
                secs: 1.0,
            }),
        );
        assert_eq!(timed.lines().len(), 1);
    }

    #[test]
    fn optional_fields_omitted() {
        let ev = Events::from(IterationCompleted {
            iter: 1,
            updates: 2,
            selected: 3,
            objective: None,
            nnz: None,
        });
        let fields = event_fields(&ev);
        assert!(fields.iter().all(|(k, _)| *k != "objective" && *k != "nnz"));
    }
}
