//! Deterministic rendering of simulator results: the virtual event log
//! (byte-identical across replays of the same plan — the format is part
//! of that contract: fixed-width envelope, no wall-clock, no floats) and
//! per-scenario verdict tables for `gencd sim`.
//!
//! Event lines are rendered through the one shared formatter
//! ([`format_line`](crate::event::log::format_line)), so `gencd sim
//! --events` output and a production `StructuredLog` text stream are
//! byte-for-byte the same syntax.

use crate::event::log::{format_line, Field, LogFormat};
use crate::event::Meta;
use crate::sim::clock::Event;

/// Outcome of grading one scenario against its `[expect]` table.
#[derive(Clone, Debug)]
pub struct Verdict {
    pub name: String,
    pub pass: bool,
    /// Grading detail: `stop=... objective=...` on PASS, the list of
    /// violated expectations (or the load error) on FAIL.
    pub detail: String,
    /// Virtual events the run recorded.
    pub sim_events: u64,
}

/// Render the event log, one line per event in virtual-time order, in
/// the shared [`format_line`] text syntax:
///
/// ```text
/// t=00000012 shard=01 arrive round=3
/// ```
pub fn render_events(events: &[Event]) -> String {
    let mut out = String::with_capacity(events.len() * 40);
    for e in events {
        let meta = Meta {
            timestamp_ticks: e.tick,
            shard: e.shard as u32,
            thread: 0,
        };
        out.push_str(&format_line(
            LogFormat::Text,
            &meta,
            e.kind.name(),
            &[("round", Field::U64(e.round as u64))],
        ));
        out.push('\n');
    }
    out
}

/// Render the corpus verdict table plus a one-line summary; returns the
/// text and whether every scenario passed.
pub fn render_verdicts(verdicts: &[Verdict]) -> (String, bool) {
    let mut out = String::new();
    let mut passed = 0usize;
    for v in verdicts {
        let tag = if v.pass { "PASS" } else { "FAIL" };
        passed += usize::from(v.pass);
        out.push_str(&format!(
            "{tag}  {:<28} events={:<6} {}\n",
            v.name, v.sim_events, v.detail
        ));
    }
    out.push_str(&format!("{passed}/{} scenarios passed\n", verdicts.len()));
    (out, passed == verdicts.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::clock::EventKind;

    #[test]
    fn event_lines_are_fixed_width_and_stable() {
        let events = vec![
            Event { tick: 12, round: 3, shard: 1, kind: EventKind::Arrive },
            Event { tick: 999_999, round: 42, shard: 11, kind: EventKind::Timeout },
        ];
        let a = render_events(&events);
        let b = render_events(&events);
        assert_eq!(a, b);
        assert_eq!(
            a,
            "t=00000012 shard=01 arrive round=3\n\
             t=00999999 shard=11 timeout round=42\n"
        );
    }

    #[test]
    fn verdict_summary_counts() {
        let vs = vec![
            Verdict { name: "a".into(), pass: true, detail: "ok".into(), sim_events: 4 },
            Verdict { name: "b".into(), pass: false, detail: "boom".into(), sim_events: 0 },
        ];
        let (text, all) = render_verdicts(&vs);
        assert!(!all);
        assert!(text.contains("PASS  a"));
        assert!(text.contains("FAIL  b"));
        assert!(text.contains("1/2 scenarios passed"));
        let (_, all_ok) = render_verdicts(&vs[..1]);
        assert!(all_ok);
    }
}
