//! Deterministic fault-injection simulation for the sharded execution
//! layer.
//!
//! The sharded engine's failure semantics ([`crate::shard::engine`]
//! §Failure semantics) promise *degrade, never hang*: a dead pool, a
//! stuck peer, or an out-of-order reconcile must end in a clean
//! [`StopReason::ShardFailed`] (or a correct solve), not a wedged
//! process. Those promises are worthless untested, and the interesting
//! failures are exactly the ones wall-clock tests can't reproduce on
//! demand. This module makes them reproducible:
//!
//! * [`clock`] — virtual time: an integer-tick discrete-event queue
//!   with no wall-clock reads, so a schedule replays identically on any
//!   machine.
//! * [`faults`] — seeded [`FaultPlan`](faults::FaultPlan)s pregenerated
//!   as pure data: per-round delta delays, fold reorderings, straggler
//!   lag, one-shot pool kills, virtual barrier timeouts. Same spec +
//!   seed ⇒ same plan, bit for bit.
//! * [`link`] — [`SimLink`](link::SimLink): a
//!   [`ReconcileLink`](crate::shard::engine::ReconcileLink) that runs
//!   the *unmodified* pool code under a plan. Fault-free plans are
//!   bit-exact with the production
//!   [`BarrierLink`](crate::shard::engine::BarrierLink); injected kills
//!   take the real panic/poison path.
//! * [`scenario`] — TOML scenario files (workload + shard plan + fault
//!   plan + expected outcome) and the [`run_corpus`](scenario::run_corpus)
//!   driver behind `gencd sim`; the committed corpus under `scenarios/`
//!   is the regression gate.
//! * [`report`] — byte-stable event-log and verdict rendering.
//!
//! Not to be confused with [`crate::simulate`], the paper's Figure-2
//! *performance model*: that module predicts convergence trajectories;
//! this one attacks the runtime's fault tolerance.
//!
//! [`StopReason::ShardFailed`]: crate::coordinator::convergence::StopReason::ShardFailed

pub mod clock;
pub mod faults;
pub mod link;
pub mod report;
pub mod scenario;

pub use clock::{Event, EventKind, EventQueue, Tick};
pub use faults::{FaultPlan, FaultSpec};
pub use link::SimLink;
pub use report::{render_events, render_verdicts, Verdict};
pub use scenario::{
    run_baseline, run_corpus, run_corpus_loopback, run_scenario, run_scenario_logged,
    run_scenario_loopback, Scenario, ScenarioRun, WorkloadKind,
};
