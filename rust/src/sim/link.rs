//! [`SimLink`]: the reconcile transport under simulation.
//!
//! Runs the *unmodified* sharded pool code ([`solve_sharded_linked`])
//! over the real [`SpinBarrier`], adding a deterministic virtual layer
//! on top:
//!
//! * **Per-round predicates are pure plan lookups.** Whether a round
//!   times out, which pool panics, and the delta fold order are all
//!   functions of the pregenerated [`FaultPlan`] — every shard computes
//!   them independently and identically, so a virtual timeout makes
//!   *all* shards abandon the exchange *before* touching the real
//!   barrier (nobody is left waiting), and a fold reorder perturbs only
//!   floating-point summation order.
//! * **Injected panics take the real failure path.** A planned kill is
//!   a genuine `panic!` inside the pool leader: it unwinds through the
//!   engine, poisons the link via the panic guard, and surfaces as
//!   `StopReason::ShardFailed` exactly like an organic crash would.
//! * **Only shard 0 records.** The event log is written by a single
//!   shard simulating each round through the virtual
//!   [`EventQueue`](crate::sim::clock::EventQueue) — one writer, no
//!   wall-clock reads, so the log is byte-identical across runs of the
//!   same plan.
//!
//! [`solve_sharded_linked`]: crate::shard::engine::solve_sharded_linked
//! [`SpinBarrier`]: crate::util::par::SpinBarrier

use std::sync::Mutex;
use std::time::Duration;

use crate::shard::engine::{LinkFault, ReconcileLink};
use crate::sim::clock::{Event, EventKind, EventQueue};
use crate::sim::faults::FaultPlan;
use crate::util::par::{SpinBarrier, WaitOutcome};

/// Single-writer event recorder (locked only by shard 0).
#[derive(Debug, Default)]
struct Recorder {
    queue: EventQueue,
    log: Vec<Event>,
}

/// Deterministic fault-injecting [`ReconcileLink`]. Construct with a
/// pregenerated [`FaultPlan`]; hand to
/// [`solve_sharded_linked`](crate::shard::engine::solve_sharded_linked).
pub struct SimLink {
    plan: FaultPlan,
    barrier: SpinBarrier,
    /// Real-time backstop for the underlying barrier: generous (it only
    /// fires if an *injected* kill left peers waiting and the poison
    /// propagation itself wedged, which the tests never expect).
    real_timeout: Duration,
    recorder: Mutex<Recorder>,
}

impl SimLink {
    pub fn new(plan: FaultPlan, spin: u32, real_timeout: Duration) -> Self {
        let parties = plan.shards.max(1);
        Self {
            plan,
            barrier: SpinBarrier::with_spin(parties, spin),
            real_timeout,
            recorder: Mutex::new(Recorder::default()),
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// The recorded event log so far (complete once the solve returned).
    pub fn events(&self) -> Vec<Event> {
        self.recorder
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .log
            .clone()
    }

    pub fn event_count(&self) -> usize {
        self.recorder
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .log
            .len()
    }

    /// Shard 0 only: replay `round` through the virtual clock and append
    /// to the log. Virtual time resumes from the previous round's
    /// frontier, so ticks are globally monotone regardless of how large
    /// the injected delays are.
    fn record_round(&self, round: usize) {
        let mut rec = self.recorder.lock().unwrap_or_else(|e| e.into_inner());
        let base = rec.queue.now();
        let mut latest = (base, 0usize);
        for s in 0..self.plan.shards {
            let tick = base + self.plan.delay(round, s);
            if tick >= latest.0 {
                latest = (tick, s);
            }
            rec.queue.schedule(Event { tick, round, shard: s, kind: EventKind::Arrive });
        }
        if let Some((ps, pr)) = self.plan.panic_at {
            if pr == round && ps < self.plan.shards {
                let tick = base + self.plan.delay(round, ps);
                rec.queue.schedule(Event { tick, round, shard: ps, kind: EventKind::Panic });
            }
        }
        if self.plan.times_out(round) {
            // the exchange is abandoned while the latest shard is still
            // in flight; the timeout is charged to the shard being
            // waited for
            let tick = base + self.plan.virtual_timeout_ticks;
            rec.queue
                .schedule(Event { tick, round, shard: latest.1, kind: EventKind::Timeout });
        } else if !self.plan.panics_in_round(round) {
            rec.queue
                .schedule(Event { tick: latest.0, round, shard: 0, kind: EventKind::Reconcile });
        }
        let drained = rec.queue.drain_ordered();
        rec.log.extend(drained);
    }

    fn cross(&self) -> Result<(), LinkFault> {
        match self.barrier.wait_timeout(self.real_timeout) {
            WaitOutcome::Released(_) => Ok(()),
            WaitOutcome::Poisoned => Err(LinkFault::Poisoned),
            WaitOutcome::TimedOut => Err(LinkFault::TimedOut),
        }
    }
}

impl ReconcileLink for SimLink {
    fn init(&self, _shard: usize) -> Result<(), LinkFault> {
        self.cross()
    }

    fn arrive(&self, shard: usize, round: usize) -> Result<(), LinkFault> {
        if shard == 0 {
            self.record_round(round);
        }
        if self.plan.panics(shard, round) {
            panic!("injected fault: pool killed by plan (shard {shard}, round {round})");
        }
        if self.plan.times_out(round) {
            // pure plan lookup: every shard bails identically, before
            // the real barrier — a virtual timeout never strands a peer
            return Err(LinkFault::TimedOut);
        }
        self.cross()
    }

    fn publish_fold(&self, _shard: usize, _round: usize) -> Result<(), LinkFault> {
        self.cross()
    }

    fn publish_decision(&self, _shard: usize, _round: usize) -> Result<(), LinkFault> {
        self.cross()
    }

    fn fold_order(&self, _shard: usize, round: usize, shards: usize) -> Vec<usize> {
        self.plan.fold_order(round, shards)
    }

    fn poison(&self) {
        self.barrier.poison();
    }
}

impl FaultPlan {
    /// Does any shard's pool die at `round`?
    fn panics_in_round(&self, round: usize) -> bool {
        matches!(self.panic_at, Some((_, r)) if r == round)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::faults::FaultSpec;

    fn single_shard_link(spec: &FaultSpec, rounds: usize, seed: u64) -> SimLink {
        SimLink::new(
            FaultPlan::generate(spec, 1, rounds, seed),
            64,
            Duration::from_secs(5),
        )
    }

    #[test]
    fn event_log_is_deterministic() {
        let spec = FaultSpec { delay_ticks_max: 9, reorder: true, ..FaultSpec::default() };
        let drive = || {
            let link = single_shard_link(&spec, 6, 42);
            for r in 0..6 {
                assert!(link.arrive(0, r).is_ok());
                assert!(link.publish_fold(0, r).is_ok());
                assert!(link.publish_decision(0, r).is_ok());
            }
            link.events()
        };
        let (a, b) = (drive(), drive());
        assert!(!a.is_empty());
        assert_eq!(a, b, "same plan must replay the identical log");
    }

    #[test]
    fn virtual_ticks_are_monotone() {
        let spec = FaultSpec {
            delay_ticks_max: 1000,
            straggler_shard: Some(0),
            straggler_mult: 7,
            ..FaultSpec::default()
        };
        let link = single_shard_link(&spec, 10, 3);
        for r in 0..10 {
            link.arrive(0, r).unwrap();
        }
        let events = link.events();
        for w in events.windows(2) {
            assert!(w[0].tick <= w[1].tick, "virtual time ran backwards: {w:?}");
        }
    }

    #[test]
    fn virtual_timeout_fails_before_the_barrier() {
        // 2-party barrier, but only one caller: a real crossing would
        // block — the virtual timeout must fail fast instead
        let spec = FaultSpec {
            straggler_shard: Some(1),
            straggler_mult: 100,
            virtual_timeout_ticks: 5,
            ..FaultSpec::default()
        };
        let link = SimLink::new(
            FaultPlan::generate(&spec, 2, 4, 9),
            64,
            Duration::from_secs(60),
        );
        let t0 = std::time::Instant::now();
        assert_eq!(link.arrive(0, 0), Err(LinkFault::TimedOut));
        assert!(t0.elapsed() < Duration::from_secs(1), "must not wait in real time");
        let events = link.events();
        assert!(events.iter().any(|e| e.kind == EventKind::Timeout));
        assert!(events.iter().all(|e| e.kind != EventKind::Reconcile));
    }

    #[test]
    fn planned_panic_is_a_real_panic() {
        let spec = FaultSpec { panic_at: Some((0, 2)), ..FaultSpec::default() };
        let link = single_shard_link(&spec, 4, 11);
        link.arrive(0, 0).unwrap();
        link.arrive(0, 1).unwrap();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = link.arrive(0, 2);
        }));
        let msg = *caught.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("injected fault"), "unexpected message: {msg}");
        assert!(link.events().iter().any(|e| e.kind == EventKind::Panic));
    }

    #[test]
    fn fault_free_link_is_identity() {
        let link = single_shard_link(&FaultSpec::default(), 3, 1);
        assert_eq!(link.fold_order(0, 1, 4), vec![0, 1, 2, 3]);
        link.init(0).unwrap();
        for r in 0..3 {
            link.arrive(0, r).unwrap();
        }
        // fault-free rounds: one arrive + one reconcile per round, all
        // at tick 0
        let events = link.events();
        assert_eq!(events.len(), 6);
        assert!(events.iter().all(|e| e.tick == 0));
    }
}
