//! Virtual time: a deterministic discrete-event queue.
//!
//! The simulator never reads a wall clock. Time is an integer tick
//! counter advanced only by popping scheduled events, so the same
//! schedule replays identically on any machine at any load — the
//! property the byte-identical event logs of [`crate::sim`] rest on.
//! Ties (same tick) break by insertion order, making the queue a stable
//! FIFO within a tick.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Virtual time, in abstract integer ticks.
pub type Tick = u64;

/// What happened at a point in virtual time. The discriminant order is
/// meaningless; events at the same tick replay in insertion order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    /// A shard's delta reached the reconcile exchange.
    Arrive,
    /// The round's fold completed (all deltas merged).
    Reconcile,
    /// The round's virtual arrival spread exceeded the timeout budget —
    /// every shard abandons the exchange.
    Timeout,
    /// The fault plan killed a shard's pool at this round.
    Panic,
}

impl EventKind {
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Arrive => "arrive",
            EventKind::Reconcile => "reconcile",
            EventKind::Timeout => "timeout",
            EventKind::Panic => "panic",
        }
    }
}

/// One simulated occurrence: a kind, where (shard), when (round, tick).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Event {
    pub tick: Tick,
    pub round: usize,
    pub shard: usize,
    pub kind: EventKind,
}

/// Min-heap of events ordered by `(tick, insertion order)`.
///
/// `pop` advances [`now`](Self::now) to the popped event's tick; the
/// queue never runs backwards (scheduling before `now` is a logic error,
/// caught in debug builds).
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<(Tick, u64, Event)>>,
    seq: u64,
    now: Tick,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time: the tick of the last popped event.
    pub fn now(&self) -> Tick {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule an event at its `tick` (must be >= `now`).
    pub fn schedule(&mut self, ev: Event) {
        debug_assert!(ev.tick >= self.now, "scheduling into the past");
        self.heap.push(Reverse((ev.tick, self.seq, ev)));
        self.seq += 1;
    }

    /// Pop the earliest event (FIFO within a tick) and advance `now`.
    pub fn pop(&mut self) -> Option<Event> {
        let Reverse((tick, _, ev)) = self.heap.pop()?;
        self.now = tick;
        Some(ev)
    }

    /// Drain everything in virtual-time order.
    pub fn drain_ordered(&mut self) -> Vec<Event> {
        let mut out = Vec::with_capacity(self.heap.len());
        while let Some(ev) = self.pop() {
            out.push(ev);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(tick: Tick, shard: usize) -> Event {
        Event { tick, round: 0, shard, kind: EventKind::Arrive }
    }

    #[test]
    fn pops_in_tick_order() {
        let mut q = EventQueue::new();
        q.schedule(ev(30, 0));
        q.schedule(ev(10, 1));
        q.schedule(ev(20, 2));
        let order: Vec<_> = q.drain_ordered().iter().map(|e| e.shard).collect();
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn same_tick_is_fifo() {
        let mut q = EventQueue::new();
        for s in 0..8 {
            q.schedule(ev(5, s));
        }
        let order: Vec<_> = q.drain_ordered().iter().map(|e| e.shard).collect();
        assert_eq!(order, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn now_tracks_pops() {
        let mut q = EventQueue::new();
        assert_eq!(q.now(), 0);
        q.schedule(ev(7, 0));
        q.schedule(ev(3, 1));
        q.pop();
        assert_eq!(q.now(), 3);
        q.pop();
        assert_eq!(q.now(), 7);
        assert!(q.pop().is_none());
        assert_eq!(q.now(), 7, "now unchanged on empty pop");
    }

    #[test]
    fn interleaved_schedule_and_pop() {
        let mut q = EventQueue::new();
        q.schedule(ev(2, 0));
        q.schedule(ev(9, 1));
        assert_eq!(q.pop().unwrap().shard, 0);
        // schedule at the current frontier: legal, pops before tick 9
        q.schedule(ev(2, 2));
        assert_eq!(q.pop().unwrap().shard, 2);
        assert_eq!(q.pop().unwrap().shard, 1);
        assert!(q.is_empty());
    }
}
