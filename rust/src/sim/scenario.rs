//! Replayable scenario files: workload + shard plan + fault plan +
//! expected outcome, one TOML file each (the committed corpus under
//! `scenarios/`).
//!
//! Schema (all tables optional except `[workload]`; defaults in
//! parentheses):
//!
//! ```toml
//! name = "straggler-timeout"      # (file stem)
//! seed = 7                        # (1) workload + fault RNG seed
//!
//! [workload]
//! kind = "conflict"               # uniform | powerlaw | conflict
//! n = 200                         # rows
//! k = 64                          # columns
//! nnz = 12                        # per-column support budget
//! lam = 0.01                      # (1e-3) l1 strength
//!
//! [shards]
//! count = 2                       # (2)
//! strategy = "contiguous"         # (contiguous) ShardStrategy::by_name
//!
//! [solve]
//! algorithm = "shotgun"           # (shotgun) Algorithm::by_name
//! rounds = 60                     # (50) round cap
//! reconcile_every = 1             # (1)
//! reconcile_max_rounds = 0        # (0 = fixed cadence)
//! max_staleness_rounds = 0        # (0 = unbounded)
//! resume_at_round = 0             # (0 = off) checkpoint/resume drill:
//!                                 # [`run_scenario_loopback`] solves to
//!                                 # this round with a checkpoint, then
//!                                 # resumes to `rounds`; the resumed
//!                                 # objective must land within 1e-12 of
//!                                 # the uninterrupted reference
//!
//! [faults]                        # (all off)
//! delay_ticks_max = 8
//! reorder = true
//! straggler_shard = 1             # -1 = none
//! straggler_mult = 4
//! panic_shard = -1                # -1 = none
//! panic_round = 0
//! virtual_timeout_ticks = 0       # 0 = off
//! # message-level wire faults — only consulted by
//! # [`run_scenario_loopback`] (the barrier path has no frames):
//! net_truncate_shard = -1         # -1 = none; with net_truncate_round
//! net_truncate_round = 0
//! net_duplicate_round = -1        # -1 = none; delivers twice
//! net_disconnect_shard = -1       # -1 = none; with net_disconnect_round
//! net_disconnect_round = 0
//! net_heal_after_attempts = 0     # 0 = the drop is permanent; N = it
//!                                 # heals after N redial attempts
//! net_reconnect_attempts = 0      # loopback redial budget granted to a
//!                                 # dropped party (0 = no reconnection)
//!
//! [expect]
//! stop = "max-iters"              # StopReason display string
//! failure_contains = ""           # substring of SolveError::message
//! kind = ""                       # SolveErrorKind display (panic |
//!                                 # timeout | link | protocol); "" = any
//! min_forced_reconciles = 0
//! ```
//!
//! [`run_scenario`] rebuilds everything from the seed (matrix, labels,
//! shard specs, fault plan), solves through a [`SimLink`], and grades
//! the outcome against `[expect]` — same file ⇒ same verdict and a
//! byte-identical event log, which is what makes the corpus a
//! regression gate rather than a demo.

use std::path::Path;

use crate::config::toml::{parse, Document, Value};
use crate::coordinator::algorithms::Algorithm;
use crate::coordinator::engine::{SolveOutput, UpdatePath};
use crate::event::{SolveInfo, StructuredLog, Subscribed};
use crate::coordinator::problem::Problem;
use crate::data::synth;
use crate::loss::Logistic;
use crate::net::{LoopbackLink, NetFaultPlan, WirePrecision};
use crate::recover::{Checkpoint, CheckpointSpec, ResumeState};
use crate::shard::engine::{solve_sharded_linked, BarrierLink, ShardSpec};
use crate::shard::{ShardStrategy, ShardedConfig};
use crate::sim::faults::{FaultPlan, FaultSpec};
use crate::sim::link::SimLink;
use crate::sim::report::{render_events, Verdict};
use crate::sparse::io::Dataset;
use crate::sparse::CscMatrix;
use crate::util::Pcg64;

/// Synthetic workload families (see [`crate::data::synth`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    /// Uniform column support (`power_law_by_columns` with alpha 0).
    Uniform,
    /// Power-law column sparsity (alpha 1.1): dense head, long tail.
    PowerLaw,
    /// Cross-shard conflict blocks: every shard fights over a shared
    /// hot row block.
    Conflict,
}

impl WorkloadKind {
    pub fn by_name(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "uniform" => WorkloadKind::Uniform,
            "powerlaw" | "power-law" => WorkloadKind::PowerLaw,
            "conflict" => WorkloadKind::Conflict,
            other => anyhow::bail!(
                "unknown workload kind {other:?} (expected uniform | powerlaw | conflict)"
            ),
        })
    }
}

/// Expected outcome, graded by [`run_scenario`].
#[derive(Clone, Debug, Default)]
pub struct Expectation {
    /// Required [`StopReason`](crate::coordinator::convergence::StopReason)
    /// display string (empty = any).
    pub stop: String,
    /// Required substring of the surfaced
    /// [`SolveError`](crate::coordinator::convergence::SolveError)
    /// message (empty = no failure required; a failure is then a FAIL
    /// unless `stop` says otherwise).
    pub failure_contains: String,
    /// Required
    /// [`SolveErrorKind`](crate::coordinator::convergence::SolveErrorKind)
    /// display string of the surfaced failure (empty = any kind).
    pub kind: String,
    /// Minimum `staleness_forced_reconciles` metric.
    pub min_forced_reconciles: u64,
}

/// One parsed scenario file.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub seed: u64,
    pub kind: WorkloadKind,
    pub n: usize,
    pub k: usize,
    pub nnz: usize,
    pub lam: f64,
    pub shards: usize,
    pub strategy: ShardStrategy,
    pub algorithm: Algorithm,
    pub rounds: usize,
    pub reconcile_every: usize,
    pub reconcile_max_rounds: usize,
    pub max_staleness_rounds: usize,
    pub faults: FaultSpec,
    /// Message-level wire faults, applied only when the scenario runs
    /// over the loopback wire ([`run_scenario_loopback`]); the barrier
    /// path has no frames to corrupt.
    pub net: NetFaultPlan,
    /// Redial budget the loopback link grants a disconnected party
    /// (`net_reconnect_attempts`; 0 = no reconnection).
    pub net_reconnect_attempts: u32,
    /// When > 0, [`run_scenario_loopback`] runs the checkpoint/resume
    /// drill (schema docs, `resume_at_round`).
    pub resume_at_round: usize,
    pub expect: Expectation,
}

fn opt_int(doc: &Document, table: &str, key: &str, default: i64) -> anyhow::Result<i64> {
    match doc.get(table, key) {
        None => Ok(default),
        Some(v) => v.as_int().ok_or_else(|| {
            anyhow::anyhow!("scenario: [{table}] {key} must be an integer, got {v:?}")
        }),
    }
}

fn opt_float(doc: &Document, table: &str, key: &str, default: f64) -> anyhow::Result<f64> {
    match doc.get(table, key) {
        None => Ok(default),
        Some(v) => v.as_float().ok_or_else(|| {
            anyhow::anyhow!("scenario: [{table}] {key} must be a number, got {v:?}")
        }),
    }
}

fn opt_bool(doc: &Document, table: &str, key: &str, default: bool) -> anyhow::Result<bool> {
    match doc.get(table, key) {
        None => Ok(default),
        Some(v) => v.as_bool().ok_or_else(|| {
            anyhow::anyhow!("scenario: [{table}] {key} must be a boolean, got {v:?}")
        }),
    }
}

fn opt_str<'d>(
    doc: &'d Document,
    table: &str,
    key: &str,
    default: &'d str,
) -> anyhow::Result<&'d str> {
    match doc.get(table, key) {
        None => Ok(default),
        Some(v) => v.as_str().ok_or_else(|| {
            anyhow::anyhow!("scenario: [{table}] {key} must be a string, got {v:?}")
        }),
    }
}

fn usize_knob(doc: &Document, table: &str, key: &str, default: i64) -> anyhow::Result<usize> {
    let v = opt_int(doc, table, key, default)?;
    anyhow::ensure!(v >= 0, "scenario: [{table}] {key} must be >= 0, got {v}");
    Ok(v as usize)
}

/// Optional shard index encoded as `-1 = none`.
fn shard_index(doc: &Document, table: &str, key: &str) -> anyhow::Result<Option<usize>> {
    let v = opt_int(doc, table, key, -1)?;
    Ok(if v < 0 { None } else { Some(v as usize) })
}

impl Scenario {
    /// Parse a scenario from TOML source. `fallback_name` (usually the
    /// file stem) names scenarios that omit `name`.
    pub fn from_toml_str(src: &str, fallback_name: &str) -> anyhow::Result<Scenario> {
        let doc = parse(src)?;
        let name = opt_str(&doc, "", "name", fallback_name)?.to_string();
        let seed = opt_int(&doc, "", "seed", 1)? as u64;

        let kind = WorkloadKind::by_name(opt_str(&doc, "workload", "kind", "uniform")?)?;
        let n = usize_knob(&doc, "workload", "n", 120)?.max(2);
        let k = usize_knob(&doc, "workload", "k", 40)?.max(2);
        let nnz = usize_knob(&doc, "workload", "nnz", 8)?.max(1);
        let lam = opt_float(&doc, "workload", "lam", 1e-3)?;
        anyhow::ensure!(
            lam.is_finite() && lam >= 0.0,
            "scenario {name}: lam must be finite and >= 0"
        );

        let shards = usize_knob(&doc, "shards", "count", 2)?.max(1);
        let strategy = ShardStrategy::by_name(opt_str(&doc, "shards", "strategy", "contiguous")?)?;

        let algorithm = Algorithm::by_name(opt_str(&doc, "solve", "algorithm", "shotgun")?)?;
        let rounds = usize_knob(&doc, "solve", "rounds", 50)?.max(1);
        let reconcile_every = usize_knob(&doc, "solve", "reconcile_every", 1)?.max(1);
        let reconcile_max_rounds = usize_knob(&doc, "solve", "reconcile_max_rounds", 0)?;
        let max_staleness_rounds = usize_knob(&doc, "solve", "max_staleness_rounds", 0)?;
        let resume_at_round = usize_knob(&doc, "solve", "resume_at_round", 0)?;
        anyhow::ensure!(
            resume_at_round == 0 || resume_at_round < rounds,
            "scenario {name}: resume_at_round ({resume_at_round}) must be < rounds ({rounds})"
        );

        let faults = FaultSpec {
            delay_ticks_max: usize_knob(&doc, "faults", "delay_ticks_max", 0)? as u64,
            reorder: opt_bool(&doc, "faults", "reorder", false)?,
            straggler_shard: shard_index(&doc, "faults", "straggler_shard")?,
            straggler_mult: usize_knob(&doc, "faults", "straggler_mult", 1)?.max(1) as u64,
            panic_at: match shard_index(&doc, "faults", "panic_shard")? {
                Some(s) => Some((s, usize_knob(&doc, "faults", "panic_round", 0)?)),
                None => None,
            },
            virtual_timeout_ticks: usize_knob(&doc, "faults", "virtual_timeout_ticks", 0)? as u64,
        };

        let net = NetFaultPlan {
            truncate_at: match shard_index(&doc, "faults", "net_truncate_shard")? {
                Some(s) => Some((s, usize_knob(&doc, "faults", "net_truncate_round", 0)?)),
                None => None,
            },
            duplicate_round: shard_index(&doc, "faults", "net_duplicate_round")?,
            disconnect_at: match shard_index(&doc, "faults", "net_disconnect_shard")? {
                Some(s) => Some((s, usize_knob(&doc, "faults", "net_disconnect_round", 0)?)),
                None => None,
            },
            heal_after_attempts: usize_knob(&doc, "faults", "net_heal_after_attempts", 0)? as u32,
        };
        let net_reconnect_attempts =
            usize_knob(&doc, "faults", "net_reconnect_attempts", 0)? as u32;

        let expect = Expectation {
            stop: opt_str(&doc, "expect", "stop", "")?.to_string(),
            failure_contains: opt_str(&doc, "expect", "failure_contains", "")?.to_string(),
            kind: opt_str(&doc, "expect", "kind", "")?.to_string(),
            min_forced_reconciles: usize_knob(&doc, "expect", "min_forced_reconciles", 0)? as u64,
        };

        Ok(Scenario {
            name,
            seed,
            kind,
            n,
            k,
            nnz,
            lam,
            shards,
            strategy,
            algorithm,
            rounds,
            reconcile_every,
            reconcile_max_rounds,
            max_staleness_rounds,
            faults,
            net,
            net_reconnect_attempts,
            resume_at_round,
            expect,
        })
    }

    /// Load one `.toml` scenario file.
    pub fn load(path: &Path) -> anyhow::Result<Scenario> {
        let stem = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "scenario".to_string());
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::from_toml_str(&src, &stem)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
    }

    /// Regenerate the scenario's workload from its seed: the design
    /// matrix (column-normalized) and ±1 labels.
    pub fn workload(&self) -> (CscMatrix, Vec<f64>) {
        let mut rng = Pcg64::new(self.seed, 0x10AD);
        let mut x = match self.kind {
            WorkloadKind::Uniform => {
                synth::power_law_by_columns(self.n, self.k, 0.0, self.nnz, &mut rng)
            }
            WorkloadKind::PowerLaw => {
                synth::power_law_by_columns(self.n, self.k, 1.1, self.nnz, &mut rng)
            }
            WorkloadKind::Conflict => synth::conflict_blocks(
                self.n,
                self.k,
                self.shards,
                self.nnz.div_ceil(2).max(1),
                self.nnz.div_ceil(2).max(1),
                &mut rng,
            ),
        };
        x.normalize_columns();
        let y = (0..self.n)
            .map(|_| if rng.next_f64() < 0.5 { 1.0 } else { -1.0 })
            .collect();
        (x, y)
    }
}

/// Everything one scenario execution produced. `output` is `None` only
/// when the scenario failed to parse or build (the verdict carries the
/// error).
pub struct ScenarioRun {
    pub verdict: Verdict,
    pub output: Option<SolveOutput>,
    /// Rendered virtual event log (byte-identical across replays of the
    /// same scenario).
    pub event_log: String,
}

/// The shared solve setup both links run: shard specs (one worker per
/// pool, for replay determinism), the sharded config, and the global
/// problem, all regenerated from the scenario's seed.
fn build_solve(sc: &Scenario) -> anyhow::Result<(Vec<ShardSpec>, ShardedConfig, Problem)> {
    let (x, y) = sc.workload();
    let loss = Logistic;
    // one worker per shard pool: policy streams and pool schedules stay
    // deterministic, which the byte-identical-replay contract needs
    let specs = crate::solver::build_shard_specs(
        &x,
        &y,
        &loss,
        sc.lam,
        sc.algorithm,
        sc.shards,
        sc.strategy,
        sc.shards,
        0,
        0,
        crate::coloring::Strategy::Greedy,
        UpdatePath::Auto,
        sc.seed,
    )?;
    let cfg = ShardedConfig {
        max_rounds: sc.rounds,
        max_seconds: 60.0,
        reconcile_every: sc.reconcile_every,
        reconcile_max_rounds: if sc.reconcile_max_rounds == 0 {
            sc.reconcile_every
        } else {
            sc.reconcile_max_rounds
        },
        max_staleness_rounds: sc.max_staleness_rounds,
        // the *virtual* timeout injects timeouts; the real one is only
        // the anti-hang backstop behind an injected kill
        barrier_timeout_secs: 20.0,
        ..ShardedConfig::default()
    };
    let global = Problem::new(
        Dataset { x, y, name: sc.name.clone() },
        Box::new(loss),
        sc.lam,
    );
    Ok((specs, cfg, global))
}

/// Solve `sc`'s workload through the production [`BarrierLink`] — no
/// virtual time, no fault plan. The transparency baseline: a fault-free
/// [`run_scenario`] must land within 1e-12 of this objective (pinned by
/// `rust/tests/sim_faults.rs`).
pub fn run_baseline(sc: &Scenario) -> anyhow::Result<SolveOutput> {
    let (specs, cfg, global) = build_solve(sc)?;
    let link = BarrierLink::new(
        specs.len().max(1),
        cfg.barrier_spin,
        Some(std::time::Duration::from_secs(20)),
    );
    Ok(solve_sharded_linked(&global, specs, None, &cfg, None, None, &link))
}

/// Solve `sc` under its fault plan and grade the outcome.
pub fn run_scenario(sc: &Scenario) -> anyhow::Result<ScenarioRun> {
    let (specs, cfg, global) = build_solve(sc)?;
    let active = specs.len().max(1);
    let plan = FaultPlan::generate(&sc.faults, active, sc.rounds, sc.seed);
    let link = SimLink::new(plan, cfg.barrier_spin, std::time::Duration::from_secs(20));
    let mut output = solve_sharded_linked(&global, specs, None, &cfg, None, None, &link);
    output.metrics.sim_events = link.event_count() as u64;
    let event_log = render_events(&link.events());
    let verdict = grade(sc, &output);
    Ok(ScenarioRun { verdict, output: Some(output), event_log })
}

fn grade(sc: &Scenario, out: &SolveOutput) -> Verdict {
    let stop = out.stop.to_string();
    let mut problems = Vec::new();
    if !sc.expect.stop.is_empty() && stop != sc.expect.stop {
        problems.push(format!("stop {stop:?}, expected {:?}", sc.expect.stop));
    }
    match (&out.failure, sc.expect.failure_contains.as_str()) {
        (None, "") => {}
        (None, want) => problems.push(format!("no failure surfaced, expected one containing {want:?}")),
        (Some(f), "") => {
            // an unexpected failure is only acceptable if the expected
            // stop reason explicitly says shard-failed
            if sc.expect.stop != "shard-failed" {
                problems.push(format!("unexpected failure: {f}"));
            }
        }
        (Some(f), want) => {
            if !f.message.contains(want) {
                problems.push(format!("failure {:?} does not contain {want:?}", f.message));
            }
        }
    }
    if !sc.expect.kind.is_empty() {
        match &out.failure {
            None => problems.push(format!(
                "no failure surfaced, expected kind {:?}",
                sc.expect.kind
            )),
            Some(f) => {
                let kind = f.kind.to_string();
                if kind != sc.expect.kind {
                    problems.push(format!("failure kind {kind:?}, expected {:?}", sc.expect.kind));
                }
            }
        }
    }
    if out.metrics.staleness_forced_reconciles < sc.expect.min_forced_reconciles {
        problems.push(format!(
            "forced reconciles {} < expected {}",
            out.metrics.staleness_forced_reconciles, sc.expect.min_forced_reconciles
        ));
    }
    if out.failure.is_none() && !out.objective.is_finite() {
        problems.push(format!("non-finite objective {}", out.objective));
    }
    let pass = problems.is_empty();
    let detail = if pass {
        format!("stop={stop} objective={:.6e}", out.objective)
    } else {
        problems.join("; ")
    };
    Verdict { name: sc.name.clone(), pass, detail, sim_events: out.metrics.sim_events }
}

/// [`run_scenario`] with a [`StructuredLog`] text subscriber attached
/// and a deterministic per-round log cadence (`log_every = 1` — the
/// default wall-clock cadence would break byte-identity). Returns the
/// run plus the structured event lines; two runs of the same scenario
/// yield byte-identical lines (pinned by `rust/tests/sim_faults.rs`).
pub fn run_scenario_logged(sc: &Scenario) -> anyhow::Result<(ScenarioRun, Vec<String>)> {
    let (specs, mut cfg, global) = build_solve(sc)?;
    cfg.log_every = 1;
    let active = specs.len().max(1);
    let plan = FaultPlan::generate(&sc.faults, active, sc.rounds, sc.seed);
    let link = SimLink::new(plan, cfg.barrier_spin, std::time::Duration::from_secs(20));
    let log = StructuredLog::text();
    let info = SolveInfo {
        n: global.n_samples() as u64,
        k: global.n_features() as u64,
        threads: specs.iter().map(|s| s.threads.max(1) as u32).sum(),
        shards: active as u32,
        kernel: crate::kernel::resolve(cfg.fast_kernels, cfg.kernel).name(),
    };
    let mut sub = Subscribed::new(log.clone(), &info);
    let mut output = solve_sharded_linked(&global, specs, None, &cfg, None, Some(&mut sub), &link);
    output.metrics.sim_events = link.event_count() as u64;
    let event_log = render_events(&link.events());
    let verdict = grade(sc, &output);
    Ok((
        ScenarioRun { verdict, output: Some(output), event_log },
        log.lines(),
    ))
}

/// Solve `sc` under its fault plan with every reconcile exchange routed
/// through the loopback wire transport ([`crate::net::LoopbackLink`]
/// composed over the [`SimLink`]): virtual-time faults from `[faults]`
/// *and* message-level wire faults from the `net_*` keys, full
/// encode→frame→decode on every delta. The graded contract is the same
/// as [`run_scenario`]'s — a wire fault must land as a clean
/// `shard-failed`, never a hang.
pub fn run_scenario_loopback(sc: &Scenario) -> anyhow::Result<ScenarioRun> {
    if sc.resume_at_round > 0 {
        return run_resume_drill(sc);
    }
    let (output, event_log) = loopback_solve(sc, None)?;
    let verdict = grade(sc, &output);
    Ok(ScenarioRun { verdict, output: Some(output), event_log })
}

/// One loopback solve of `sc`'s workload. `reshape` edits the sharded
/// config after the scenario defaults are applied (the resume drill's
/// hook for the cut/continue phases).
fn loopback_solve(
    sc: &Scenario,
    reshape: Option<&dyn Fn(&mut ShardedConfig)>,
) -> anyhow::Result<(SolveOutput, String)> {
    let (specs, mut cfg, global) = build_solve(sc)?;
    if let Some(f) = reshape {
        f(&mut cfg);
    }
    let active = specs.len().max(1);
    let plan = FaultPlan::generate(&sc.faults, active, sc.rounds, sc.seed);
    let sim = SimLink::new(plan, cfg.barrier_spin, std::time::Duration::from_secs(20));
    let link = LoopbackLink::over(sim, active, WirePrecision::Exact)
        .with_faults(sc.net)
        .with_reconnect_budget(sc.net_reconnect_attempts);
    let mut output = solve_sharded_linked(&global, specs, None, &cfg, None, None, &link);
    output.metrics.sim_events = link.inner().event_count() as u64;
    let event_log = render_events(&link.inner().events());
    Ok((output, event_log))
}

/// The checkpoint/resume drill behind `resume_at_round` (schema docs):
/// three loopback solves of the same seed-regenerated workload —
///
/// 1. **reference**: uninterrupted, to the scenario's round cap;
/// 2. **interrupted**: stopped at `resume_at_round`, checkpointing every
///    reconciled round to a scratch file;
/// 3. **resumed**: a fresh solve continuing from the written checkpoint
///    to the full cap.
///
/// The resumed run is graded against `[expect]` like any scenario, and
/// additionally its objective must land within 1e-12 of the reference —
/// the crash-window equivalent of the fault-transparency contract.
fn run_resume_drill(sc: &Scenario) -> anyhow::Result<ScenarioRun> {
    let (reference, _) = loopback_solve(sc, None)?;
    let ckpt_path = std::env::temp_dir().join(format!(
        "gencd-scenario-{}-{}.ckpt",
        std::process::id(),
        sc.name
    ));
    let cut = sc.resume_at_round;
    let spec = CheckpointSpec { path: ckpt_path.clone(), every_rounds: 1, seed: sc.seed };
    let interrupted = loopback_solve(
        sc,
        Some(&|cfg: &mut ShardedConfig| {
            cfg.max_rounds = cut;
            cfg.checkpoint = Some(spec.clone());
        }),
    );
    let resumed = interrupted.and_then(|_| {
        let ckpt = Checkpoint::load(&ckpt_path)
            .map_err(|e| anyhow::anyhow!("loading the drill checkpoint: {e}"))?;
        let resume = ResumeState::from_checkpoint(ckpt);
        loopback_solve(
            sc,
            Some(&move |cfg: &mut ShardedConfig| {
                cfg.resume = Some(resume.clone());
            }),
        )
    });
    let _ = std::fs::remove_file(&ckpt_path);
    let (output, event_log) = resumed?;
    let mut verdict = grade(sc, &output);
    let gap = (output.objective - reference.objective).abs();
    if verdict.pass && !(gap <= 1e-12) {
        verdict.pass = false;
        verdict.detail = format!(
            "resumed objective {:.17e} vs reference {:.17e}: gap {gap:.3e} > 1e-12",
            output.objective, reference.objective
        );
    } else if verdict.pass {
        verdict.detail.push_str(&format!(" resume_gap={gap:.1e}"));
    }
    Ok(ScenarioRun { verdict, output: Some(output), event_log })
}

/// `*.toml` files directly under `dir`, sorted by file name.
fn scenario_files(dir: &Path) -> anyhow::Result<Vec<std::path::PathBuf>> {
    let mut files: Vec<_> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("reading scenario dir {}: {e}", dir.display()))?
        .filter_map(|entry| {
            let path = entry.ok()?.path();
            (path.extension().and_then(|e| e.to_str()) == Some("toml")).then_some(path)
        })
        .collect();
    files.sort();
    Ok(files)
}

fn run_files(
    files: &[std::path::PathBuf],
    filter: Option<&str>,
    runner: fn(&Scenario) -> anyhow::Result<ScenarioRun>,
) -> Vec<ScenarioRun> {
    let mut runs = Vec::new();
    for path in files {
        let stem = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        if let Some(f) = filter {
            if !stem.contains(f) {
                continue;
            }
        }
        match Scenario::load(path).and_then(|sc| runner(&sc)) {
            Ok(run) => runs.push(run),
            Err(e) => runs.push(ScenarioRun {
                verdict: Verdict {
                    name: stem,
                    pass: false,
                    detail: format!("error: {e}"),
                    sim_events: 0,
                },
                output: None,
                event_log: String::new(),
            }),
        }
    }
    runs
}

/// Load and run every `*.toml` under `dir` (sorted by file name),
/// optionally keeping only names containing `filter`. Parse/run errors
/// become failed verdicts rather than aborting the sweep.
pub fn run_corpus(dir: &Path, filter: Option<&str>) -> anyhow::Result<Vec<ScenarioRun>> {
    Ok(run_files(&scenario_files(dir)?, filter, run_scenario))
}

/// [`run_corpus`] over the loopback wire transport: every scenario
/// directly under `dir` *plus* the message-fault scenarios under
/// `dir/net` (when present — `run_corpus` itself never recurses, so the
/// `net_*` scenarios stay invisible to the plain `gencd sim` sweep,
/// whose barrier link has no frames to corrupt).
pub fn run_corpus_loopback(dir: &Path, filter: Option<&str>) -> anyhow::Result<Vec<ScenarioRun>> {
    let mut files = scenario_files(dir)?;
    let net_dir = dir.join("net");
    if net_dir.is_dir() {
        files.extend(scenario_files(&net_dir)?);
    }
    Ok(run_files(&files, filter, run_scenario_loopback))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::convergence::StopReason;

    const BASE: &str = r#"
        name = "unit-base"
        seed = 3
        [workload]
        kind = "uniform"
        n = 60
        k = 24
        nnz = 6
        lam = 0.001
        [shards]
        count = 2
        [solve]
        rounds = 12
    "#;

    #[test]
    fn parses_defaults_and_overrides() {
        let sc = Scenario::from_toml_str(BASE, "fallback").unwrap();
        assert_eq!(sc.name, "unit-base");
        assert_eq!(sc.seed, 3);
        assert_eq!(sc.kind, WorkloadKind::Uniform);
        assert_eq!((sc.n, sc.k, sc.nnz), (60, 24, 6));
        assert_eq!(sc.shards, 2);
        assert_eq!(sc.algorithm, Algorithm::Shotgun);
        assert_eq!(sc.rounds, 12);
        assert!(sc.faults.is_fault_free());
        assert!(sc.expect.stop.is_empty());
        // fallback name only when the file omits one
        let unnamed = Scenario::from_toml_str("[workload]\nkind = \"uniform\"", "fb").unwrap();
        assert_eq!(unnamed.name, "fb");
    }

    #[test]
    fn rejects_bad_kinds_and_types() {
        assert!(Scenario::from_toml_str("[workload]\nkind = \"nope\"", "x").is_err());
        assert!(Scenario::from_toml_str("[workload]\nn = \"forty\"", "x").is_err());
        assert!(Scenario::from_toml_str("[faults]\nreorder = 3", "x").is_err());
    }

    #[test]
    fn workload_is_seed_deterministic() {
        let sc = Scenario::from_toml_str(BASE, "x").unwrap();
        let (xa, ya) = sc.workload();
        let (xb, yb) = sc.workload();
        assert_eq!(ya, yb);
        for j in 0..xa.n_cols() {
            assert_eq!(xa.col(j), xb.col(j));
        }
    }

    #[test]
    fn fault_free_scenario_passes() {
        let sc = Scenario::from_toml_str(BASE, "x").unwrap();
        let run = run_scenario(&sc).unwrap();
        assert!(run.verdict.pass, "detail: {}", run.verdict.detail);
        let out = run.output.as_ref().unwrap();
        assert_eq!(out.stop, StopReason::MaxIters);
        assert!(out.metrics.sim_events > 0);
        assert!(!run.event_log.is_empty());
    }

    #[test]
    fn net_faults_parse_and_loopback_runner_grades() {
        // shard 0 so the protocol fault is the first failure slot (the
        // peer's poisoned-barrier escape is surfaced behind it)
        let src = format!(
            "{BASE}\n[faults]\nnet_truncate_shard = 0\nnet_truncate_round = 3\n\
             [expect]\nstop = \"shard-failed\"\nkind = \"protocol\"\n"
        );
        let sc = Scenario::from_toml_str(&src, "x").unwrap();
        assert_eq!(sc.net.truncate_at, Some((0, 3)));
        let run = run_scenario_loopback(&sc).unwrap();
        assert!(run.verdict.pass, "detail: {}", run.verdict.detail);
        // a fault-free scenario passes over the wire too — and with
        // exact precision the decoded frames reproduce the barrier
        // path's objective bit-for-bit
        let clean = Scenario::from_toml_str(BASE, "x").unwrap();
        let wire = run_scenario_loopback(&clean).unwrap();
        assert!(wire.verdict.pass, "detail: {}", wire.verdict.detail);
        let base = run_scenario(&clean).unwrap();
        assert_eq!(
            wire.output.unwrap().objective.to_bits(),
            base.output.unwrap().objective.to_bits()
        );
    }

    #[test]
    fn heal_and_resume_keys_parse() {
        let src = format!(
            "{BASE}\n[faults]\nnet_disconnect_shard = 1\nnet_disconnect_round = 4\n\
             net_heal_after_attempts = 2\nnet_reconnect_attempts = 5\n"
        );
        let sc = Scenario::from_toml_str(&src, "x").unwrap();
        assert_eq!(sc.net.disconnect_at, Some((1, 4)));
        assert_eq!(sc.net.heal_after_attempts, 2);
        assert_eq!(sc.net_reconnect_attempts, 5);
        // resume_at_round must sit inside the round budget
        let bad = format!("{BASE}\n[solve]\nresume_at_round = 12\n");
        assert!(Scenario::from_toml_str(&bad, "x").is_err());
    }

    #[test]
    fn healed_disconnect_scenario_passes_and_stays_transparent() {
        // the drop heals within budget: the solve finishes cleanly and
        // the delivered-after-heal frame (absolute values) keeps it
        // bit-identical to the fault-free wire run
        let src = format!(
            "{BASE}\n[faults]\nnet_disconnect_shard = 1\nnet_disconnect_round = 4\n\
             net_heal_after_attempts = 2\nnet_reconnect_attempts = 4\n\
             [expect]\nstop = \"max-iters\"\n"
        );
        let sc = Scenario::from_toml_str(&src, "x").unwrap();
        let run = run_scenario_loopback(&sc).unwrap();
        assert!(run.verdict.pass, "detail: {}", run.verdict.detail);
        let clean = Scenario::from_toml_str(BASE, "x").unwrap();
        let base = run_scenario_loopback(&clean).unwrap();
        assert_eq!(
            run.output.unwrap().objective.to_bits(),
            base.output.unwrap().objective.to_bits()
        );
    }

    #[test]
    fn resume_drill_matches_reference_objective() {
        let src = format!(
            "{BASE}\n[solve]\nrounds = 12\nresume_at_round = 5\n\
             [expect]\nstop = \"max-iters\"\n"
        );
        let sc = Scenario::from_toml_str(&src, "x").unwrap();
        assert_eq!(sc.resume_at_round, 5);
        let run = run_scenario_loopback(&sc).unwrap();
        assert!(run.verdict.pass, "detail: {}", run.verdict.detail);
        assert!(run.verdict.detail.contains("resume_gap"));
    }

    #[test]
    fn expectation_mismatch_fails() {
        let src = format!("{BASE}\n[expect]\nstop = \"shard-failed\"");
        let sc = Scenario::from_toml_str(&src, "x").unwrap();
        let run = run_scenario(&sc).unwrap();
        assert!(!run.verdict.pass);
        assert!(run.verdict.detail.contains("expected"));
    }
}
