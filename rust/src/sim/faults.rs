//! Seeded fault plans: everything a simulated run will inject,
//! pregenerated as pure data.
//!
//! A [`FaultPlan`] is built once from a [`FaultSpec`] + seed, *before*
//! any thread spawns, and never mutated. Every shard consults the same
//! plan with pure lookups, so per-round decisions that must be agreed on
//! by all shards (does this round time out? what fold order?) are
//! computed independently-but-identically — no cross-thread
//! communication, no races, no divergent views. That is what makes the
//! injected faults replayable: same spec + seed ⇒ same plan ⇒ same
//! failure, byte for byte.

use crate::util::Pcg64;

/// Declarative description of what to inject (the `[faults]` table of a
/// scenario file; see [`crate::sim::scenario`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    /// Per-round, per-shard delta delivery jitter: each shard's virtual
    /// arrival at the reconcile exchange is delayed by a uniform draw
    /// from `0..=delay_ticks_max`. 0 = no jitter.
    pub delay_ticks_max: u64,
    /// Shuffle the per-round delta fold order (a fresh seeded
    /// permutation each round). Models deltas arriving out of shard
    /// order: the fold result differs only by floating-point summation
    /// order, which is exactly the perturbation a real network
    /// introduces.
    pub reorder: bool,
    /// One shard that lags every round (a slow NUMA node, a noisy
    /// neighbor): its virtual arrival delay becomes
    /// `straggler_mult * max(delay_ticks_max, 1)` plus its jitter draw.
    pub straggler_shard: Option<usize>,
    /// Lag multiplier for `straggler_shard` (ignored without one).
    pub straggler_mult: u64,
    /// Kill one pool: `(shard, round)` panics inside the reconcile
    /// arrival of that round, exercising the real poison/unwind path.
    pub panic_at: Option<(usize, usize)>,
    /// Virtual barrier timeout: a round whose arrival spread
    /// (max - min virtual arrival tick) exceeds this budget times out —
    /// every shard abandons the exchange and the solve fails with
    /// `ShardFailed`. 0 = no virtual timeout.
    pub virtual_timeout_ticks: u64,
}

impl Default for FaultSpec {
    /// No faults at all: the plan this produces makes a simulated run
    /// bit-exact with the real barrier protocol.
    fn default() -> Self {
        Self {
            delay_ticks_max: 0,
            reorder: false,
            straggler_shard: None,
            straggler_mult: 1,
            panic_at: None,
            virtual_timeout_ticks: 0,
        }
    }
}

impl FaultSpec {
    /// True when the spec injects nothing (identity fold order, zero
    /// delays, no kills, no timeout).
    pub fn is_fault_free(&self) -> bool {
        self.delay_ticks_max == 0
            && !self.reorder
            && self.straggler_shard.is_none()
            && self.panic_at.is_none()
            && self.virtual_timeout_ticks == 0
    }
}

/// Pregenerated injection schedule: per-round per-shard arrival delays
/// and per-round fold permutations for `rounds` rounds. Rounds past the
/// pregenerated horizon are fault-free (zero delay, identity order) —
/// a solve running longer than planned degrades to faithful execution,
/// never to unseeded randomness.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    pub shards: usize,
    pub rounds: usize,
    /// `rounds * shards`, row-major by round.
    delays: Vec<u64>,
    /// `rounds * shards`, row-major by round; each row a permutation of
    /// `0..shards`.
    orders: Vec<usize>,
    pub panic_at: Option<(usize, usize)>,
    pub virtual_timeout_ticks: u64,
}

impl FaultPlan {
    /// Materialize `spec` for `shards` shards over `rounds` rounds.
    /// Deterministic: same `(spec, shards, rounds, seed)` ⇒ identical
    /// plan.
    pub fn generate(spec: &FaultSpec, shards: usize, rounds: usize, seed: u64) -> Self {
        let shards = shards.max(1);
        let mut rng = Pcg64::new(seed, 0x5117_FA17);
        let straggler_lag = spec
            .straggler_shard
            .map(|_| spec.straggler_mult.max(1) * spec.delay_ticks_max.max(1))
            .unwrap_or(0);
        let mut delays = Vec::with_capacity(rounds * shards);
        let mut orders = Vec::with_capacity(rounds * shards);
        for _ in 0..rounds {
            for s in 0..shards {
                let jitter = if spec.delay_ticks_max > 0 {
                    rng.below(spec.delay_ticks_max as usize + 1) as u64
                } else {
                    0
                };
                let lag = if spec.straggler_shard == Some(s) { straggler_lag } else { 0 };
                delays.push(jitter + lag);
            }
            let base = orders.len();
            orders.extend(0..shards);
            if spec.reorder {
                rng.shuffle(&mut orders[base..]);
            }
        }
        Self {
            shards,
            rounds,
            delays,
            orders,
            panic_at: spec.panic_at,
            virtual_timeout_ticks: spec.virtual_timeout_ticks,
        }
    }

    /// Virtual arrival delay of `shard` at `round` (0 past the horizon).
    pub fn delay(&self, round: usize, shard: usize) -> u64 {
        if round < self.rounds && shard < self.shards {
            self.delays[round * self.shards + shard]
        } else {
            0
        }
    }

    /// Arrival spread of a round: latest minus earliest virtual arrival.
    pub fn arrival_spread(&self, round: usize) -> u64 {
        if round >= self.rounds || self.shards == 0 {
            return 0;
        }
        let row = &self.delays[round * self.shards..(round + 1) * self.shards];
        let (mut lo, mut hi) = (u64::MAX, 0u64);
        for &d in row {
            lo = lo.min(d);
            hi = hi.max(d);
        }
        hi - lo
    }

    /// Does `round`'s exchange exceed the virtual timeout budget?
    /// A pure function of the plan: every shard computes the same
    /// answer without communicating.
    pub fn times_out(&self, round: usize) -> bool {
        self.virtual_timeout_ticks > 0 && self.arrival_spread(round) > self.virtual_timeout_ticks
    }

    /// The round's delta fold order (identity past the horizon or on a
    /// shard-count mismatch, so it is always a valid permutation of
    /// `0..shards`).
    pub fn fold_order(&self, round: usize, shards: usize) -> Vec<usize> {
        if round < self.rounds && shards == self.shards {
            self.orders[round * self.shards..(round + 1) * self.shards].to_vec()
        } else {
            (0..shards).collect()
        }
    }

    /// Does the plan kill `shard`'s pool at `round`?
    pub fn panics(&self, shard: usize, round: usize) -> bool {
        self.panic_at == Some((shard, round))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jittery() -> FaultSpec {
        FaultSpec {
            delay_ticks_max: 10,
            reorder: true,
            straggler_shard: Some(2),
            straggler_mult: 5,
            panic_at: Some((1, 7)),
            virtual_timeout_ticks: 40,
            ..FaultSpec::default()
        }
    }

    #[test]
    fn same_seed_same_plan() {
        let a = FaultPlan::generate(&jittery(), 4, 20, 99);
        let b = FaultPlan::generate(&jittery(), 4, 20, 99);
        assert_eq!(a.delays, b.delays);
        assert_eq!(a.orders, b.orders);
        let c = FaultPlan::generate(&jittery(), 4, 20, 100);
        assert_ne!(a.delays, c.delays, "different seed should differ");
    }

    #[test]
    fn fault_free_plan_is_identity() {
        let p = FaultPlan::generate(&FaultSpec::default(), 3, 5, 1);
        assert!(FaultSpec::default().is_fault_free());
        for r in 0..5 {
            assert_eq!(p.fold_order(r, 3), vec![0, 1, 2]);
            assert_eq!(p.arrival_spread(r), 0);
            assert!(!p.times_out(r));
            for s in 0..3 {
                assert_eq!(p.delay(r, s), 0);
                assert!(!p.panics(s, r));
            }
        }
    }

    #[test]
    fn fold_orders_are_permutations() {
        let p = FaultPlan::generate(&jittery(), 5, 30, 7);
        for r in 0..30 {
            let mut o = p.fold_order(r, 5);
            o.sort_unstable();
            assert_eq!(o, vec![0, 1, 2, 3, 4], "round {r} not a permutation");
        }
    }

    #[test]
    fn straggler_dominates_and_trips_timeout() {
        let spec = FaultSpec {
            delay_ticks_max: 3,
            straggler_shard: Some(1),
            straggler_mult: 50,
            virtual_timeout_ticks: 20,
            ..FaultSpec::default()
        };
        let p = FaultPlan::generate(&spec, 3, 10, 5);
        for r in 0..10 {
            assert!(p.delay(r, 1) >= 150, "straggler lag missing at round {r}");
            assert!(p.times_out(r), "spread should exceed budget at round {r}");
        }
        // without the timeout budget, the same lag merely stretches time
        let lag_only = FaultSpec { virtual_timeout_ticks: 0, ..spec };
        let q = FaultPlan::generate(&lag_only, 3, 10, 5);
        for r in 0..10 {
            assert!(!q.times_out(r));
        }
    }

    #[test]
    fn beyond_horizon_is_fault_free() {
        let p = FaultPlan::generate(&jittery(), 4, 6, 3);
        assert_eq!(p.delay(6, 0), 0);
        assert_eq!(p.fold_order(99, 4), vec![0, 1, 2, 3]);
        assert_eq!(p.arrival_spread(100), 0);
        assert!(!p.times_out(100));
        // shard-count mismatch also degrades to identity
        assert_eq!(p.fold_order(2, 7), (0..7).collect::<Vec<_>>());
    }
}
