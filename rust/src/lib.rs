//! # GenCD — Generic Parallel Coordinate Descent
//!
//! A production-oriented reproduction of *Scaling Up Coordinate Descent
//! Algorithms for Large ℓ1 Regularization Problems* (Scherrer,
//! Halappanavar, Tewari, Haglin; ICML 2012): the GenCD
//! Select/Propose/Accept/Update framework and its instantiations
//! (CCD/SCD, SHOTGUN, THREAD-GREEDY, GREEDY, COLORING), built as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the shared-memory coordinator: selection
//!   policies, parallel propose workers, accept policies, atomic
//!   updates, coloring preprocessing, datasets, metrics, CLI.
//! * **L2/L1 (python/, build-time only)** — the dense-block Propose /
//!   objective / line-search compute graph in JAX calling Pallas
//!   kernels, AOT-lowered to HLO text.
//! * **runtime** — PJRT CPU client loading `artifacts/*.hlo.txt` so the
//!   solve path never touches Python.
//!
//! Start with [`coordinator::driver`] or the `gencd` binary; see
//! `examples/quickstart.rs`.

pub mod bench_harness;
pub mod cli;
pub mod coloring;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod linalg;
pub mod loss;
pub mod runtime;
pub mod simulate;
pub mod sparse;
pub mod util;
