//! # GenCD — Generic Parallel Coordinate Descent
//!
//! A production-oriented reproduction of *Scaling Up Coordinate Descent
//! Algorithms for Large ℓ1 Regularization Problems* (Scherrer,
//! Halappanavar, Tewari, Haglin; ICML 2012): the GenCD
//! Select/Propose/Accept/Update framework and its instantiations
//! (CCD/SCD, SHOTGUN, THREAD-GREEDY, GREEDY, COLORING), built as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the shared-memory coordinator: trait-based
//!   selection/accept policies, parallel propose workers, atomic /
//!   buffered / conflict-free updates, coloring preprocessing, datasets,
//!   metrics, CLI.
//! * **L2/L1 (python/, build-time only)** — the dense-block Propose /
//!   objective / line-search compute graph in JAX calling Pallas
//!   kernels, AOT-lowered to HLO text.
//! * **runtime** — PJRT CPU client loading `artifacts/*.hlo.txt` so the
//!   solve path never touches Python.
//!
//! ## Embedding the solver
//!
//! The paper's point is that GenCD is *generic*: the named algorithms
//! are just (Select, Accept) policy pairs. The crate exposes exactly
//! that genericity — [`Select`](coordinator::select::Select) and
//! [`Accept`](coordinator::accept::Accept) are open traits, the eight
//! presets are a thin catalogue over them
//! ([`Algorithm`](coordinator::Algorithm)), and the typed
//! [`Solver::builder`] is the front door:
//!
//! ```
//! use gencd::prelude::*;
//!
//! # fn main() -> anyhow::Result<()> {
//! let ds = gencd::data::by_name("dorothea@0.01")?;
//! let out = Solver::builder()
//!     .dataset(ds)
//!     .normalize(true)           // the paper's column normalization
//!     .loss(Logistic)
//!     .lambda(1e-4)
//!     .algorithm(Algorithm::ThreadGreedy)
//!     .threads(2)
//!     .max_iters(100)
//!     .build()?
//!     .solve();
//! assert!(out.objective.is_finite());
//! # Ok(())
//! # }
//! ```
//!
//! Custom policies implement the traits; per-iteration
//! [`Observer`](coordinator::observer::Observer) hooks give early
//! stopping, checkpointing and metric streaming. See
//! [`solver`] and `examples/quickstart.rs`.
//!
//! ## Execution layers
//!
//! The solve path is a stack of execution layers, each wrapping the one
//! below and owning one scale of parallelism:
//!
//! | layer | unit of parallelism | shared state | synchronization |
//! |-------|---------------------|--------------|-----------------|
//! | [`kernel`] (`SolverBuilder::kernel`, `--kernel`) | SIMD lanes inside one column dot/axpy (AVX2/AVX-512, runtime-dispatched, scalar fallback) | — (pure compute; per-thread [`kernel::BlockedScatter`] strips under `UpdatePath::Blocked`) | none — tier resolved once per solve, reported in `SolveInfo::kernel` |
//! | [`screen`] (`SolverBuilder::screening(true)`) | — (shrinks the *work*, not the workers) | per-pool [`ActiveSet`](screen::ActiveSet) bitmask | rides the engine's barriers (one extra crossing per KKT sweep) |
//! | [`coordinator::engine`] | worker threads in one pool | one `z`/`w` ([`SharedState`](coordinator::problem::SharedState)) | phase spin barriers |
//! | [`shard`] (`SolverBuilder::shards(n)`) | one NUMA-pinnable engine pool per column shard | per-shard `z` *replica*, first-touched node-local | reconcile barrier, every R rounds (adaptive), dirty-chunk delta fold |
//! | [`sim`] (`gencd sim`, [`sim::SimLink`]) | the shard layer, unmodified, under virtual time | a seeded [`sim::FaultPlan`] (pure data, consulted identically by every shard) | deterministic fault injection over the [`shard::ReconcileLink`] seam: delays, reorders, stragglers, kills, timeouts |
//! | [`net`] (`SolverBuilder::transport`, `gencd net`) | shard peers behind a wire ([`net::LoopbackLink`] in-process, [`net::TcpLink`] over sockets) | replicas refreshed from decoded frames (absolute dirty-chunk values, exact or f32) | the same four reconcile crossings, serialized per [`shard::engine`] §Wire format; deadlines map `barrier_timeout_secs` onto the socket |
//! | [`recover`] (`SolverBuilder::{checkpoint_path, resume_from, reconnect_max_attempts}`, `gencd harness`) | — (survives the layers above across crashes, never adds workers) | the CRC-guarded [`recover::Checkpoint`] file (reconciled `w`/`z` + round/λ/RNG state, atomic rename) | checkpoint writes at reconciled rounds by the shard-0 coordinator; [`net::TcpLink`] redials with bounded exponential backoff ([`recover::ReconnectPolicy`]), exhausted retries degrade to `ShardFailed` |
//! | [`event`] (`SolverBuilder::subscriber`) | — (observes every layer above, never synchronizes) | per-solve `SolveContext` per [`Subscriber`](event::Subscriber) | none — events are emitted from leader/coordinator threads only, and disabled emit sites compile to nothing |
//!
//! The engine scales until every worker hammering the same residual
//! vector saturates one coherent memory domain; the shard layer
//! ([`shard::engine::solve_sharded`]) removes that wall by giving each
//! shard — a column subset chosen by a topology-aware partitioner
//! ([`shard::ShardStrategy`]: contiguous, round-robin, or greedy
//! sample-overlap minimization) — its own full engine pool and its own
//! residual replica over a **zero-copy column-range view**
//! ([`sparse::CscMatrix::col_range_view`]) of the design matrix,
//! reconciled at round boundaries. On multi-socket hardware the layer
//! goes the rest of the way (`SolverBuilder::numa_pin`): each pool is
//! pinned to a NUMA node and its replica + engine scratch are
//! first-touch-allocated on the pinned threads, so per-round traffic is
//! node-local by construction; the reconcile itself folds only
//! **dirty chunks** (an engine-maintained bitmap of touched 128-byte
//! z chunks — byte-identical to the dense fold, O(touched) instead of
//! O(n·shards)) and runs on an **adaptive cadence**
//! (`SolverBuilder::{reconcile_every, reconcile_max_rounds}`: back off
//! while replicas agree, snap back on a conflict spike), with all
//! stopping decisions taken at reconciled rounds so convergence
//! semantics are unchanged ([`shard::engine`] §NUMA, §Reconcile
//! cadence). The distributed backends ([`net`]) plug in at exactly that
//! seam: the dirty-chunk delta exchange is already the only cross-shard
//! traffic, so a wire transport only has to speak the reconcile
//! contract — four crossings plus the frame codec — not the engine's
//! phase protocol. [`net::LoopbackLink`] runs the full wire protocol
//! in-process (bit-exact with the barrier under
//! `wire_precision = exact`); [`net::TcpLink`] ships the same frames
//! over blocking sockets with every failure mode landing as a clean
//! `ShardFailed`, never a hang.
//!
//! Orthogonal to both, the **screening layer** ([`screen`],
//! `SolverBuilder::screening(true)`) attacks the *work per iteration*
//! instead of its distribution: on l1 paths most coordinates sit at
//! zero with slack subgradients forever, and KKT screening deactivates
//! them so selection only draws from a shrinking active set
//! ([`MetricsSnapshot::active_cols`](coordinator::metrics::MetricsSnapshot::active_cols)).
//! Periodic full-set KKT sweeps reactivate any violator and gate every
//! [`StopReason::Converged`](coordinator::convergence::StopReason::Converged),
//! so the converged solution is provably the unscreened one. It wraps
//! any [`Select`](coordinator::select::Select) policy — presets and
//! custom ones screen for free — and composes with sharding (one active
//! set per shard pool).
//!
//! ```no_run
//! use gencd::prelude::*;
//!
//! # fn main() -> anyhow::Result<()> {
//! let ds = gencd::data::by_name("reuters@0.1")?;
//! let out = Solver::builder()
//!     .dataset(ds)
//!     .normalize(true)
//!     .algorithm(Algorithm::Shotgun)
//!     .threads(8)                      // total, split across pools
//!     .shards(2)                       // one pool + z replica each
//!     .shard_strategy(ShardStrategy::MinOverlap)
//!     .max_seconds(5.0)
//!     .build()?
//!     .solve();
//! println!("divergence {:.2e}", out.metrics.replica_divergence);
//! # Ok(())
//! # }
//! ```
//!
//! ## Observability: the typed event stream
//!
//! Every layer reports what it did through one typed vocabulary
//! ([`event::Events`]) instead of private plumbing — the [`Observer`]
//! callback, the metrics aggregation, the structured log, the sim
//! report, and the `--profile` table are all consumers of the same
//! stream:
//!
//! | event | emitted by | carries |
//! |-------|------------|---------|
//! | [`IterationCompleted`](event::IterationCompleted) | engine leader / shard coordinator, at the log cadence | iter, cumulative updates, selected, objective, nnz |
//! | [`ProposalBatch`](event::ProposalBatch) | engine leader, every iteration | proposed vs. deduplicated coordinates |
//! | [`UpdateApplied`](event::UpdateApplied) / [`SpillDrained`](event::SpillDrained) | engine leader | chosen update path, batch size; buffer spills |
//! | [`KktSweep`](event::KktSweep) / [`ScreenGate`](event::ScreenGate) | screening layer via the leader | violators, reactivations, active-set size; gated convergence |
//! | [`ReconcileRound`](event::ReconcileRound) | shard coordinator, per reconciled round | dirty fraction, divergence, adaptive gap |
//! | [`WireFrameSent`](event::WireFrameSent)/[`Received`](event::WireFrameReceived), [`CodecError`](event::CodecError) | wire transports via the coordinator | bytes, precision tag |
//! | [`ShardFailed`](event::ShardFailed) | sharded engine, post-join | failure kind |
//! | [`PhaseTimed`](event::PhaseTimed) | both engines, end-of-solve | canonical phase rows ([`event::phases`]) — the only wall-clock events |
//! | [`PathStep`](event::PathStep) | regularization-path driver | lambda, nnz, objective per step |
//!
//! **Composition contract:** implement [`Subscriber`](event::Subscriber)
//! (every `on_*` defaults to a no-op; per-solve state lives in an
//! associated `SolveContext`), attach with `SolverBuilder::subscriber`,
//! and compose structurally — `(A, B)` fans each event out to both.
//! Provided subscribers: [`MetricsAggregator`](event::MetricsAggregator)
//! (rebuilds a [`MetricsSnapshot`](coordinator::metrics::MetricsSnapshot)),
//! [`StructuredLog`](event::StructuredLog) (bounded line-JSON/text ring,
//! `--log-format json`), [`PhaseTable`](event::PhaseTable) (`--profile`).
//!
//! **Zero-cost emit discipline:** the engine is generic over
//! [`event::EventSink`]; with nothing attached it is instantiated with
//! [`event::NoopSink`], whose `enabled()` is a constant `false` — every
//! emit site (branch *and* event construction) monomorphizes away, pinned
//! by the `event_emit_disabled` bench row and the bit-exactness tests in
//! rust/tests/events.rs. Events carry logical timestamps only
//! ([`event::Meta`]), so attached subscribers never perturb determinism.
//!
//! ## Migration from the config-driven surface
//!
//! The TOML/CLI surface ([`coordinator::driver`], the `gencd` binary)
//! is unchanged and now routes through the builder. For library use,
//! migrate like this:
//!
//! | pre-0.2 (config-shaped)                              | 0.2 (builder)                                          |
//! |------------------------------------------------------|--------------------------------------------------------|
//! | `cfg.solver.algorithm = "shotgun".into()`            | `.algorithm(Algorithm::Shotgun)`                       |
//! | `cfg.problem.lam = 1e-4`                             | `.lambda(1e-4)`                                        |
//! | `cfg.problem.loss = "logistic".into()`               | `.loss(Logistic)`                                      |
//! | `cfg.solver.threads = 8`                             | `.threads(8)`                                          |
//! | `cfg.solver.update_path = "buffered".into()`         | `.update_path(UpdatePath::Buffered)`                   |
//! | `driver::run(&cfg)?`                                 | `Solver::builder()…build()?.solve()`                   |
//! | `engine::solve_from(&p, &s, Selector::Cyclic{..}, &ecfg, None)` | `.select(select::Cyclic{..})` or `engine::solve_from(&p, &s, sel, acc, &ecfg, EngineHooks::none())` |
//! | `Algorithm::by_name("ccd")?` *(deprecated)*          | `"ccd".parse::<Algorithm>()?`                          |
//! | history hardwired in the engine                      | `History` is the default [`Observer`](coordinator::observer::Observer); add your own with `.observer(..)` |
//!
//! Start with [`Solver::builder`], [`coordinator::driver`] for the
//! config surface, or the `gencd` binary; see `examples/quickstart.rs`.

pub mod bench_harness;
pub mod cli;
pub mod coloring;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod event;
pub mod kernel;
pub mod linalg;
pub mod loss;
pub mod net;
pub mod prelude;
pub mod recover;
pub mod runtime;
pub mod screen;
pub mod shard;
pub mod sim;
pub mod simulate;
pub mod solver;
pub mod sparse;
pub mod util;

pub use solver::{Solver, SolverBuilder};
