//! Active-set screening: stop paying for coordinates that stay at zero.
//!
//! On l1 paths the vast majority of coordinates sit at `w_j = 0` with a
//! *slack* subgradient (`|g_j| < lam`) for the entire run — every
//! proposal computed for them is a guaranteed no-op (`delta_j = 0`), yet
//! an unscreened solver keeps drawing them and traversing their columns.
//! Shrinking the working set via KKT screening is the classic
//! order-of-magnitude CD speedup (Wright 2015, §5; Bradley et al. 2011
//! report Shotgun wall-clock dominated by exactly this wasted proposal
//! work), and it composes multiplicatively with the engine's update-path
//! disciplines and the sharded execution layer.
//!
//! # The active set
//!
//! [`ActiveSet`] is a bitmask (one `AtomicU64` word per 64 coordinates)
//! plus a leader-maintained dense index list and cache-padded per-thread
//! cursors for round-robin draws from that list. Coordinates are:
//!
//! * **deactivated** when their KKT slack clears a *decaying threshold*:
//!   `w_j == 0` and `lam - |g_j| >= thresh`, where `thresh` starts at
//!   [`THRESH0_FRAC`]` * lam` and decays by [`THRESH_DECAY`] after every
//!   full sweep (floored at [`THRESH_MIN_FRAC`]` * lam`) — conservative
//!   early, when gradients still move, aggressive late, when they have
//!   settled. The test is *fused* into work the solver already does: the
//!   engine's Propose phase checks it on every proposal it computes
//!   (the gradient is already in hand — the screen costs two flops), and
//!   [`sweep_range`] fuses the per-column `dot_col` with the violation
//!   test in one pass.
//! * **reactivated** by periodic full-set KKT sweeps (every
//!   `kkt_every` iterations, and always before the engine declares
//!   [`StopReason::Converged`]): any inactive coordinate whose
//!   violation turned positive (`|g_j| > lam`) rejoins the active set.
//!
//! # Convergence safety
//!
//! Deactivation is a *heuristic*; the sweeps make it safe. A full-set
//! sweep gates every `Converged` stop: the engine only reports
//! convergence after a sweep that reactivated **zero** violators, i.e.
//! every inactive coordinate satisfies its KKT condition *exactly*
//! (`w_j = 0`, `|g_j| <= lam`) at the final iterate. The screened fixed
//! point is therefore identical to the unscreened one — screening can
//! delay, but never redirect, convergence (pinned to 1e-12 across all
//! presets by `rust/tests/screening.rs`).
//!
//! # Plugging into selection
//!
//! [`ScreenedSelect`] wraps any [`Select`] implementation — the six
//! built-in policies and external custom ones screen for free. It draws
//! from the inner policy and keeps only active coordinates, redrawing a
//! bounded number of times when the filter empties the selection
//! (rejection sampling from the active set; for `Cyclic` the redraws
//! *are* the skip-ahead over inactive coordinates). If every redraw
//! comes back empty it falls back to a single coordinate from the dense
//! active list via the leader cursor, so progress is guaranteed while
//! anything is active — and a single coordinate can never violate the
//! conflict-free update invariant, so COLORING screens safely too.
//!
//! Entry points: [`SolverBuilder::screening`] /
//! [`kkt_every`](crate::solver::SolverBuilder::kkt_every), TOML
//! `solver.screening` / `solver.kkt_every`, CLI `--screening` /
//! `--kkt-every`; sharded solves keep one active set per shard pool.
//!
//! [`StopReason::Converged`]: crate::coordinator::convergence::StopReason::Converged
//! [`SolverBuilder::screening`]: crate::solver::SolverBuilder::screening

use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, RwLock};

use crate::coordinator::problem::{Problem, SharedState};
use crate::coordinator::propose;
use crate::coordinator::select::Select;
use crate::kernel::KernelMode;
use crate::util::atomic::SyncCell;
use crate::util::par::CachePadded;

/// Initial deactivation threshold, as a fraction of `lam`.
pub const THRESH0_FRAC: f64 = 0.5;
/// Multiplicative threshold decay applied after every full KKT sweep.
pub const THRESH_DECAY: f64 = 0.5;
/// Threshold floor, as a fraction of `lam` (never fully trusts a
/// gradient to machine precision).
pub const THRESH_MIN_FRAC: f64 = 1e-3;
/// Relative slack margin for the sweep's *violation count* (what gates
/// `Converged`): a zero-weight coordinate only counts as violating when
/// `|g| - lam > GATE_MARGIN * max(lam, |g|)`. Different gradient
/// arithmetic co-exists in one solve (scalar vs `fast_kernels` dots,
/// on-the-fly vs cached-dloss proposals, the coordinator's global
/// gradient in sharded mode), so a strict `|g| > lam` test could flag
/// a noise-level "violation" the proposal path measures as satisfied
/// and will therefore never repair — refusing the gate forever. 1e-9
/// covers mixed-arithmetic reassociation noise even on wide,
/// heavily-cancelling columns (which can exceed 1e-12 relative) while
/// staying six orders of magnitude below the smallest violation a
/// sweep acts on (the reactivation *threshold* floors at
/// `THRESH_MIN_FRAC * lam` = 1e-3 relative).
pub const GATE_MARGIN: f64 = 1e-9;

/// The margined violation test for a zero-weight coordinate (see
/// [`GATE_MARGIN`]); shared by [`sweep_range`] and the sharded
/// coordinator's global gate.
#[inline]
pub fn violates_at_zero(g: f64, lam: f64) -> bool {
    g.abs() - lam > GATE_MARGIN * lam.max(g.abs())
}

/// Starting deactivation threshold for a problem with this `lam`.
#[inline]
pub fn initial_threshold(lam: f64) -> f64 {
    THRESH0_FRAC * lam
}

/// One decay step (applied by the engine after every full sweep).
#[inline]
pub fn decay_threshold(thresh: f64, lam: f64) -> f64 {
    (thresh * THRESH_DECAY).max(THRESH_MIN_FRAC * lam)
}

/// Why the engine scheduled a full-set KKT sweep this iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SweepKind {
    /// The `kkt_every` safety cadence.
    Periodic,
    /// A tolerance stop is pending: Converged is declared only if this
    /// sweep reactivates nothing.
    Gate,
}

/// Per-thread result of one full-set sweep chunk (written into a
/// cache-padded slot, folded by the engine leader).
#[derive(Clone, Copy, Debug, Default)]
pub struct SweepStats {
    /// Inactive coordinates whose KKT violation turned positive
    /// (`|g_j| > lam` at `w_j = 0`) — genuine screening mistakes, now
    /// repaired (reported as `MetricsSnapshot::reactivations`).
    pub reactivated: u64,
    /// *All* zero-weight coordinates the sweep measured in violation,
    /// whether they were frozen or merely active-but-undrawn. This is
    /// what gates `Converged`: zero across all threads certifies the
    /// swept iterate as a KKT point of the unscreened problem on every
    /// zero coordinate — `reactivated` alone would miss an active
    /// violator a sparse selection policy simply had not drawn yet.
    pub violators: u64,
    /// Coordinates active after the sweep (in this chunk).
    pub active: u64,
}

/// The screened working set: a bitmask over coordinates, a dense index
/// list of the active ones, and per-thread round-robin cursors into
/// that list.
///
/// Concurrency contract (the engine's phase protocol, see
/// [`crate::coordinator::engine`]):
///
/// * bit *reads* ([`is_active`](Self::is_active)) happen in phases with
///   no concurrent writer of the queried coordinate (Select on the
///   leader, post-barrier);
/// * per-bit *writes* ([`deactivate`](Self::deactivate) /
///   [`activate`](Self::activate)) are atomic RMWs, so concurrent
///   Propose workers deactivating different coordinates of the same
///   word never lose updates;
/// * whole-word *stores* ([`store_word`](Self::store_word)) are used by
///   the sweep phase, where each worker owns a disjoint word range;
/// * the dense list is rebuilt by the leader between barriers
///   ([`rebuild_dense`](Self::rebuild_dense)) and may lag the bitmask —
///   consumers re-check the bitmask ([`cursor_next`](Self::cursor_next)
///   does).
pub struct ActiveSet {
    words: Box<[AtomicU64]>,
    k: usize,
    /// Dense list of active coordinate ids, leader-rebuilt after sweeps
    /// (uncontended: written and read on the leader only; the lock is
    /// for soundness, not arbitration).
    dense: RwLock<Vec<u32>>,
    /// Per-thread positions into `dense` for round-robin draws; padded
    /// so draws from different threads never share a line. Today only
    /// slot 0 (the leader, via [`ScreenedSelect`]'s fallback) draws in
    /// the engine — the per-thread slots serve parallel draw patterns
    /// (worker-side candidate generation, the screened bench sweeps)
    /// without a layout change.
    cursors: Box<[CachePadded<SyncCell<usize>>]>,
}

impl ActiveSet {
    /// All `k` coordinates active, with `threads` draw cursors.
    pub fn new_full(k: usize, threads: usize) -> Self {
        let n_words = k.div_ceil(64);
        let words: Box<[AtomicU64]> = (0..n_words)
            .map(|w| {
                let bits = (k - w * 64).min(64);
                AtomicU64::new(if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 })
            })
            .collect();
        Self {
            words,
            k,
            dense: RwLock::new((0..k as u32).collect()),
            cursors: (0..threads.max(1))
                .map(|_| CachePadded::new(SyncCell::new(0usize)))
                .collect(),
        }
    }

    /// Total coordinate count (active or not).
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Bitmask words backing the set (`ceil(k / 64)`).
    #[inline]
    pub fn n_words(&self) -> usize {
        self.words.len()
    }

    #[inline]
    pub fn is_active(&self, j: usize) -> bool {
        debug_assert!(j < self.k);
        (self.words[j / 64].load(Relaxed) >> (j % 64)) & 1 == 1
    }

    /// Atomically clear coordinate `j` (safe under concurrent writers
    /// of *other* bits in the same word — the fused Propose-phase path).
    #[inline]
    pub fn deactivate(&self, j: usize) {
        debug_assert!(j < self.k);
        self.words[j / 64].fetch_and(!(1u64 << (j % 64)), Relaxed);
    }

    /// Atomically set coordinate `j`.
    #[inline]
    pub fn activate(&self, j: usize) {
        debug_assert!(j < self.k);
        self.words[j / 64].fetch_or(1u64 << (j % 64), Relaxed);
    }

    /// Read word `w` of the bitmask.
    #[inline]
    pub fn word(&self, w: usize) -> u64 {
        self.words[w].load(Relaxed)
    }

    /// Overwrite word `w`. Caller must be the word's unique writer for
    /// the current phase (the sweep chunks words disjointly).
    #[inline]
    pub fn store_word(&self, w: usize, bits: u64) {
        debug_assert!(
            w + 1 < self.words.len() || self.k % 64 == 0 || bits >> (self.k % 64) == 0,
            "store_word: bits beyond coordinate {} set",
            self.k
        );
        self.words[w].store(bits, Relaxed);
    }

    /// Number of active coordinates (O(k/64) popcount scan).
    pub fn popcount(&self) -> usize {
        self.words
            .iter()
            .map(|w| w.load(Relaxed).count_ones() as usize)
            .sum()
    }

    /// Rebuild the dense active list from the bitmask (leader-only,
    /// between barriers — after every sweep).
    pub fn rebuild_dense(&self) {
        let mut dense = self.dense.write().unwrap();
        dense.clear();
        // for_each_active only reads the atomic words, so holding the
        // dense write lock across it cannot deadlock
        self.for_each_active(|j| dense.push(j));
    }

    /// Length of the dense list (may lag the bitmask between rebuilds).
    pub fn dense_len(&self) -> usize {
        self.dense.read().unwrap().len()
    }

    /// Next active coordinate in round-robin order for thread `tid`,
    /// re-checking the bitmask (the dense list may be stale). `None`
    /// when nothing in the list is still active.
    pub fn cursor_next(&self, tid: usize) -> Option<u32> {
        let dense = self.dense.read().unwrap();
        if dense.is_empty() {
            return None;
        }
        let mut pos = self.cursors[tid].get() % dense.len();
        for _ in 0..dense.len() {
            let j = dense[pos];
            pos = (pos + 1) % dense.len();
            if self.is_active(j as usize) {
                self.cursors[tid].set(pos);
                return Some(j);
            }
        }
        self.cursors[tid].set(pos);
        None
    }

    /// Visit every active coordinate in ascending order (word scan with
    /// bit tricks — the screened proposal sweep of the hotpath bench).
    pub fn for_each_active(&self, mut f: impl FnMut(u32)) {
        for (w, word) in self.words.iter().enumerate() {
            let mut bits = word.load(Relaxed);
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                f((w * 64 + b) as u32);
                bits &= bits - 1;
            }
        }
    }
}

/// Full-set KKT pass over the word range `words` — the engine's screen
/// phase, callable directly for tests and benches.
///
/// For every coordinate in the range the activity flag is recomputed
/// from scratch: active iff `w_j != 0` or the slack `lam - |g_j|` is
/// below `thresh`. The gradient dot (`dot_col` over the cached dloss)
/// and the violation test run fused in one pass per column, and the
/// dot is skipped entirely for coordinates with `w_j != 0` (they stay
/// active unconditionally). The dot runs at the solve's [`KernelMode`]
/// — under a dispatched SIMD tier the sweep inner product is the
/// hardware-gather kernel ([`crate::kernel`]). Caller must have
/// refreshed `state.dloss` at the current iterate; the engine forces
/// the dloss-refresh phase on sweep iterations.
pub fn sweep_range(
    problem: &Problem,
    state: &SharedState,
    active: &ActiveSet,
    thresh: f64,
    words: Range<usize>,
    kmode: KernelMode,
) -> SweepStats {
    let lam = problem.lam;
    let k = active.k();
    let mut stats = SweepStats::default();
    for w in words {
        let old = active.word(w);
        let mut new = 0u64;
        let base = w * 64;
        for b in 0..64.min(k - base) {
            let j = base + b;
            let wj = state.w.get(j);
            if wj != 0.0 {
                // support coordinates are always active; no dot needed
                new |= 1 << b;
                continue;
            }
            let g = propose::gradient_from_dloss_mode(problem, state, j, kmode);
            if lam - g.abs() < thresh {
                new |= 1 << b;
                if violates_at_zero(g, lam) {
                    // a violator always has negative slack, so it is
                    // always kept active by the branch above
                    stats.violators += 1;
                    if (old >> b) & 1 == 0 {
                        stats.reactivated += 1;
                    }
                }
            }
        }
        active.store_word(w, new);
        stats.active += new.count_ones() as u64;
    }
    stats
}

/// Maximum inner redraws before the cursor fallback: bounds the work a
/// mostly-inactive selection can waste per iteration while letting
/// stateful policies (cyclic pointers, RNG streams) skip ahead.
const MAX_REDRAWS: usize = 4;

/// [`Select`] adapter that restricts any inner policy to the active
/// set (module docs). Built by the engine when
/// `EngineConfig::screening` is on, so every policy — preset or custom
/// — screens without knowing the active set exists.
///
/// # Relaxed inner contract
///
/// Under screening the inner policy's "`select` is called exactly once
/// per iteration" guarantee (see [`Select`]) is relaxed: the wrapper
/// may call it up to `MAX_REDRAWS` times in one engine iteration (when
/// draws land entirely on inactive coordinates — for `Cyclic` the
/// redraws *are* the skip-ahead) and zero times on a convergence-gate
/// iteration (the engine freezes the iterate and skips selection). An
/// inner policy that returns an **empty** selection is respected as a
/// deliberate no-op; only a *non-empty* selection that the active-set
/// filter empties triggers redraws and, past the redraw budget, the
/// single-coordinate cursor fallback. Policies whose internal state
/// must advance in lockstep with engine iterations (epoch counters
/// synced to an Observer, iteration-indexed schedules) should count
/// their own `select` calls rather than assume one call per iteration.
pub struct ScreenedSelect {
    inner: Box<dyn Select>,
    active: Arc<ActiveSet>,
    scratch: Vec<u32>,
}

impl ScreenedSelect {
    pub fn new(inner: Box<dyn Select>, active: Arc<ActiveSet>) -> Self {
        Self {
            inner,
            active,
            scratch: Vec::new(),
        }
    }
}

impl Select for ScreenedSelect {
    fn select(&mut self, out: &mut Vec<u32>) {
        for attempt in 0..MAX_REDRAWS {
            self.scratch.clear();
            self.inner.select(&mut self.scratch);
            if self.scratch.is_empty() {
                // a deliberately empty inner selection is a legal no-op
                // iteration — respect it rather than forcing a draw the
                // policy never made
                return;
            }
            out.extend(
                self.scratch
                    .iter()
                    .copied()
                    .filter(|&j| self.active.is_active(j as usize)),
            );
            if !out.is_empty() {
                return;
            }
            // the first draw came back fully inactive: if the whole set
            // is empty, further redraws (and the fallback) cannot help —
            // pay the O(k/64) popcount only on this already-slow path
            if attempt == 0 && self.active.popcount() == 0 {
                return;
            }
        }
        // Progress guarantee: one coordinate from the dense active list
        // via the leader cursor. A singleton selection is trivially
        // conflict-free, so this is safe for every update discipline.
        if let Some(j) = self.active.cursor_next(0) {
            out.push(j);
        }
    }

    fn expected_size(&self) -> f64 {
        // conservative (the filter only shrinks selections): sizing
        // hints must not under-provision the buffered-update heuristic
        self.inner.expected_size()
    }

    fn name(&self) -> String {
        format!("screened({})", self.inner.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::select::Cyclic;
    use crate::loss::Squared;
    use crate::sparse::io::Dataset;
    use crate::sparse::CooBuilder;
    use crate::util::Pcg64;

    #[test]
    fn new_full_sets_only_valid_bits() {
        for k in [1usize, 63, 64, 65, 130] {
            let a = ActiveSet::new_full(k, 2);
            assert_eq!(a.k(), k);
            assert_eq!(a.n_words(), k.div_ceil(64));
            assert_eq!(a.popcount(), k);
            assert_eq!(a.dense_len(), k);
            for j in 0..k {
                assert!(a.is_active(j), "k={k} j={j}");
            }
            // no stray bits past k in the tail word
            let tail = a.word(a.n_words() - 1);
            let bits = if k % 64 == 0 { 64 } else { k % 64 };
            assert_eq!(tail.count_ones() as usize, bits);
        }
    }

    #[test]
    fn deactivate_activate_roundtrip() {
        let a = ActiveSet::new_full(100, 1);
        a.deactivate(7);
        a.deactivate(64);
        assert!(!a.is_active(7));
        assert!(!a.is_active(64));
        assert!(a.is_active(8));
        assert_eq!(a.popcount(), 98);
        a.activate(7);
        assert!(a.is_active(7));
        assert_eq!(a.popcount(), 99);
    }

    #[test]
    fn rebuild_dense_and_iteration_agree() {
        let a = ActiveSet::new_full(130, 1);
        for j in 0..130 {
            if j % 3 != 0 {
                a.deactivate(j);
            }
        }
        a.rebuild_dense();
        let mut seen = Vec::new();
        a.for_each_active(|j| seen.push(j));
        let want: Vec<u32> = (0..130).filter(|j| j % 3 == 0).collect();
        assert_eq!(seen, want);
        assert_eq!(a.dense_len(), want.len());
        assert_eq!(a.popcount(), want.len());
    }

    #[test]
    fn cursor_round_robins_and_skips_stale_entries() {
        let a = ActiveSet::new_full(12, 2);
        for j in 0..12 {
            if j % 4 != 0 {
                a.deactivate(j);
            }
        }
        a.rebuild_dense(); // dense = [0, 4, 8]
        let drawn: Vec<u32> = (0..6).filter_map(|_| a.cursor_next(0)).collect();
        assert_eq!(drawn, vec![0, 4, 8, 0, 4, 8]);
        // per-thread cursors are independent
        assert_eq!(a.cursor_next(1), Some(0));
        // deactivating without a rebuild: the cursor re-checks the mask
        a.deactivate(4);
        let drawn: Vec<u32> = (0..4).filter_map(|_| a.cursor_next(0)).collect();
        assert_eq!(drawn, vec![0, 8, 0, 8]);
        // nothing active at all
        a.deactivate(0);
        a.deactivate(8);
        assert_eq!(a.cursor_next(0), None);
    }

    #[test]
    fn threshold_decays_to_floor() {
        let lam = 0.1;
        let mut t = initial_threshold(lam);
        assert_eq!(t, THRESH0_FRAC * lam);
        for _ in 0..60 {
            let next = decay_threshold(t, lam);
            assert!(next <= t, "threshold must be non-increasing");
            t = next;
        }
        assert_eq!(t, THRESH_MIN_FRAC * lam, "decay must stop at the floor");
    }

    /// Small problem with a planted support on columns 0..2.
    fn planted_problem(lam: f64) -> Problem {
        let mut rng = Pcg64::seeded(11);
        let mut b = CooBuilder::new(40, 12);
        for j in 0..12 {
            for i in 0..40 {
                if rng.next_f64() < 0.3 {
                    b.push(i, j, rng.range_f64(-1.0, 1.0));
                }
            }
        }
        let mut x = b.build();
        x.normalize_columns();
        let wstar: Vec<f64> = (0..12).map(|j| if j < 2 { 1.0 } else { 0.0 }).collect();
        let y = x.matvec(&wstar);
        Problem::new(
            Dataset {
                x,
                y,
                name: "screen-t".into(),
            },
            Box::new(Squared),
            lam,
        )
    }

    #[test]
    fn sweep_reactivates_planted_violator_and_keeps_support() {
        let p = planted_problem(1e-3);
        // at w = 0 the support columns correlate strongly with y, so
        // their gradients violate KKT; slack columns do not
        let state = SharedState::new(p.n_samples(), p.n_features());
        propose::refresh_dloss(&p, &state, 0, p.n_samples());
        let active = ActiveSet::new_full(p.n_features(), 1);
        // wrongly deactivate everything, including the violators
        for j in 0..p.n_features() {
            active.deactivate(j);
        }
        let stats = sweep_range(
            &p,
            &state,
            &active,
            1e-6,
            0..active.n_words(),
            KernelMode::Reference,
        );
        assert!(
            stats.reactivated >= 2,
            "the planted support must be reactivated, got {}",
            stats.reactivated
        );
        assert!(
            stats.violators >= stats.reactivated,
            "every reactivation is a measured violation"
        );
        assert!(active.is_active(0) && active.is_active(1));
        assert_eq!(stats.active as usize, active.popcount());
        // a second sweep re-measures the same violators, but none are
        // reactivations any more (they are already active) — the gate
        // counts `violators`, not `reactivated`, for exactly this case
        let again = sweep_range(
            &p,
            &state,
            &active,
            1e-6,
            0..active.n_words(),
            KernelMode::Reference,
        );
        assert_eq!(again.reactivated, 0);
        assert!(again.violators >= 2, "active violators still counted");
    }

    #[test]
    fn sweep_deactivates_slack_coordinates_under_large_threshold() {
        let p = planted_problem(1e-2);
        // warm-start at the planted model: the squared-loss residual is
        // exactly zero, so every zero-weight coordinate has g = 0 (full
        // slack) and a threshold of lam deactivates all of them, while
        // the nonzero support weights always stay active
        let w0: Vec<f64> = (0..p.n_features())
            .map(|j| if j < 2 { 1.0 } else { 0.0 })
            .collect();
        let state = SharedState::from_warm_start(&p, &w0);
        propose::refresh_dloss(&p, &state, 0, p.n_samples());
        let active = ActiveSet::new_full(p.n_features(), 1);
        let stats = sweep_range(
            &p,
            &state,
            &active,
            p.lam, // deactivate iff slack lam - |g| >= lam, i.e. g == 0
            0..active.n_words(),
            KernelMode::Reference,
        );
        assert!(active.is_active(0) && active.is_active(1), "support stays");
        assert!(
            (stats.active as usize) < p.n_features(),
            "a permissive threshold must prune something: {} of {}",
            stats.active,
            p.n_features()
        );
        // scalar and unrolled sweeps agree on the resulting set
        let scalar: Vec<bool> = (0..p.n_features()).map(|j| active.is_active(j)).collect();
        for tier in [
            crate::kernel::KernelTier::Scalar,
            crate::kernel::KernelTier::Avx2,
            crate::kernel::KernelTier::Avx512,
        ] {
            let active2 = ActiveSet::new_full(p.n_features(), 1);
            sweep_range(
                &p,
                &state,
                &active2,
                p.lam,
                0..active2.n_words(),
                KernelMode::Fast(tier),
            );
            let fast: Vec<bool> =
                (0..p.n_features()).map(|j| active2.is_active(j)).collect();
            assert_eq!(scalar, fast, "{tier:?} sweep must match scalar");
        }
    }

    #[test]
    fn screened_select_filters_redraws_and_falls_back() {
        let active = Arc::new(ActiveSet::new_full(9, 1));
        for j in [1usize, 2, 4, 5, 7, 8] {
            active.deactivate(j);
        }
        active.rebuild_dense(); // stale: rebuilt below where needed
        let mut s = ScreenedSelect::new(
            Box::new(Cyclic { next: 0, k: 9 }),
            Arc::clone(&active),
        );
        // cyclic singles: inactive draws are redrawn (the cursor skips
        // ahead), so consecutive selections walk the active coords
        let mut out = Vec::new();
        let mut picks = Vec::new();
        for _ in 0..3 {
            out.clear();
            s.select(&mut out);
            assert_eq!(out.len(), 1);
            assert!(active.is_active(out[0] as usize));
            picks.push(out[0]);
        }
        assert_eq!(picks, vec![0, 3, 6]);
        assert_eq!(s.name(), "screened(cyclic)");
        assert_eq!(s.expected_size(), 1.0);
        // everything inactive except coordinate 4, which the cyclic
        // pointer (now at 7) cannot reach within MAX_REDRAWS = 4 draws
        // (7, 8, 0, 1): the dense-list cursor fallback must find it
        for j in [0usize, 3, 6] {
            active.deactivate(j);
        }
        active.activate(4);
        active.rebuild_dense();
        out.clear();
        s.select(&mut out);
        assert_eq!(out, vec![4], "cursor fallback must guarantee progress");
        // nothing active: empty selection (a legal no-op iteration)
        active.deactivate(4);
        out.clear();
        s.select(&mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn screened_select_respects_deliberately_empty_inner_selection() {
        // a policy that emits a no-op must not have a coordinate forced
        // on it by the cursor fallback, and must be drawn exactly once
        struct CountedEmpty {
            calls: std::sync::Arc<std::sync::atomic::AtomicU64>,
        }
        impl Select for CountedEmpty {
            fn select(&mut self, _out: &mut Vec<u32>) {
                self.calls.fetch_add(1, Relaxed);
            }
            fn expected_size(&self) -> f64 {
                0.0
            }
        }
        let calls = Arc::new(AtomicU64::new(0));
        let active = Arc::new(ActiveSet::new_full(8, 1));
        let mut s = ScreenedSelect::new(
            Box::new(CountedEmpty {
                calls: Arc::clone(&calls),
            }),
            active,
        );
        let mut out = Vec::new();
        s.select(&mut out);
        assert!(out.is_empty(), "no-op selections must stay no-ops");
        assert_eq!(calls.load(Relaxed), 1, "empty draw must not be retried");
    }

    #[test]
    fn screened_select_passes_full_selections_through() {
        let active = Arc::new(ActiveSet::new_full(6, 1));
        let mut s = ScreenedSelect::new(
            Box::new(crate::coordinator::select::FullSet { k: 6 }),
            Arc::clone(&active),
        );
        let mut out = Vec::new();
        s.select(&mut out);
        assert_eq!(out, (0..6).collect::<Vec<u32>>());
        active.deactivate(2);
        active.deactivate(5);
        out.clear();
        s.select(&mut out);
        assert_eq!(out, vec![0, 1, 3, 4]);
    }
}
