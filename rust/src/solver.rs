//! The embeddable solver surface: [`Solver`] / [`SolverBuilder`].
//!
//! This is the front door for using GenCD as a *library* — no config
//! files, no dataset registry, no CLI. Hand the builder a sparse design
//! matrix and labels, pick either a named [`Algorithm`] preset or your
//! own [`Select`]/[`Accept`] policies, and `build()` validates the
//! combination before anything runs:
//!
//! ```
//! use gencd::prelude::*;
//!
//! # fn main() -> anyhow::Result<()> {
//! // a toy 4x3 problem; real callers load/generate something bigger
//! let mut b = gencd::sparse::CooBuilder::new(4, 3);
//! for (i, j, v) in [(0, 0, 1.0), (1, 0, -1.0), (2, 1, 1.0), (3, 2, -1.0)] {
//!     b.push(i, j, v);
//! }
//! let out = Solver::builder()
//!     .matrix(b.build())
//!     .labels(vec![1.0, -1.0, 1.0, -1.0])
//!     .loss(Logistic)
//!     .lambda(1e-4)
//!     .algorithm(Algorithm::Scd)
//!     .update_path(UpdatePath::Auto)
//!     .max_iters(50)
//!     .build()?
//!     .solve();
//! assert!(out.objective.is_finite());
//! # Ok(())
//! # }
//! ```
//!
//! Custom policies and per-iteration observers are first-class:
//!
//! ```
//! use gencd::prelude::*;
//!
//! struct EveryThird { k: usize }
//! impl Select for EveryThird {
//!     fn select(&mut self, out: &mut Vec<u32>) {
//!         out.extend((0..self.k as u32).step_by(3));
//!     }
//!     fn expected_size(&self) -> f64 { (self.k as f64 / 3.0).ceil() }
//! }
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut b = gencd::sparse::CooBuilder::new(4, 6);
//! for j in 0..6 { b.push(j % 4, j, 1.0); }
//! let out = Solver::builder()
//!     .matrix(b.build())
//!     .labels(vec![1.0, -1.0, 1.0, -1.0])
//!     .select(EveryThird { k: 6 })
//!     .accept(gencd::coordinator::accept::AcceptAll)
//!     .observer(|info: &IterationInfo<'_>| {
//!         if info.iter >= 5 { ControlFlow::Break(()) } else { ControlFlow::Continue(()) }
//!     })
//!     .build()?
//!     .solve();
//! assert_eq!(out.stop, StopReason::Observer);
//! # Ok(())
//! # }
//! ```
//!
//! Validation happens at [`SolverBuilder::build`]: missing matrix or
//! labels, label/row count mismatches, a preset combined with custom
//! policies, conflict-free updates without a coloring guarantee, preset
//! sizing knobs applied to custom policies, and malformed lambda /
//! thread counts are all rejected with actionable messages *before* any
//! threads spawn.

use std::sync::Arc;

use crate::coloring::Strategy;
use crate::coordinator::accept::{self, Accept};
use crate::coordinator::algorithms::{instantiate, Algorithm, Preprocessed};
use crate::coordinator::engine::{
    self, BlockProposer, EngineConfig, EngineHooks, SolveOutput, UpdatePath,
};
use crate::coordinator::observer::Observer;
use crate::coordinator::problem::{Problem, SharedState};
use crate::coordinator::select::Select;
use crate::event::{EventSink, SolveInfo, Subscribed, Subscriber};
use crate::kernel::{self, KernelChoice};
use crate::loss::{Logistic, Loss};
use crate::net::{LoopbackLink, TcpLink, Transport};
use crate::recover::{Checkpoint, CheckpointSpec, ReconnectPolicy, ResumeState};
use crate::shard::engine::{
    solve_sharded_linked, solve_sharded_with, ShardSpec, ShardedConfig,
};
use crate::shard::{partition, ShardStrategy};
use crate::sparse::io::Dataset;
use crate::sparse::CscMatrix;

/// A fully validated, ready-to-run GenCD solve. Construct with
/// [`Solver::builder`]; run with [`Solver::solve`].
pub struct Solver {
    problem: Problem,
    select: Box<dyn Select>,
    accept: Box<dyn Accept>,
    cfg: EngineConfig,
    observer: Option<Box<dyn Observer>>,
    /// Deferred event-sink constructor: the subscriber is wrapped in a
    /// [`Subscribed`] at solve time, when the [`SolveInfo`] dimensions
    /// are known. `None` (the default) runs the engine on the
    /// statically-dispatched no-op sink — zero emit cost.
    events: Option<SinkFactory>,
    pre: Arc<Preprocessed>,
    algorithm: Option<Algorithm>,
    warm_start: Option<Vec<f64>>,
    /// Present for `shards > 1`: the per-shard sub-problems and
    /// policies the sharded execution layer runs instead of the single
    /// engine pool.
    sharded: Option<ShardedSetup>,
}

/// How the builder stores a [`Subscriber`] without naming its concrete
/// type: a one-shot constructor invoked with the per-solve shape.
type SinkFactory = Box<dyn FnOnce(&SolveInfo) -> Box<dyn EventSink> + Send>;

/// Build-time output of the shard partitioning: everything
/// [`crate::shard::engine::solve_sharded`] needs, plus the cross-shard
/// knobs that have no [`EngineConfig`] home.
struct ShardedSetup {
    specs: Vec<ShardSpec>,
    numa_pin: bool,
    reconcile_every: usize,
    reconcile_max_rounds: usize,
    max_staleness_rounds: usize,
    barrier_timeout_secs: f64,
    transport: Transport,
    /// Coordinator checkpoint cadence + path ([`crate::recover`]).
    checkpoint: Option<CheckpointSpec>,
    /// Validated resume state loaded by `resume_from` at build time.
    resume: Option<ResumeState>,
    /// Per-peer TCP redial budget (0 = reconnection disabled).
    reconnect_max_attempts: u32,
    /// Builder seed, reused for deterministic reconnect jitter.
    seed: u64,
}

impl Solver {
    /// Start describing a solve.
    pub fn builder() -> SolverBuilder {
        SolverBuilder::default()
    }

    /// The preset this solver was built from (`None` for custom
    /// policies).
    pub fn algorithm(&self) -> Option<Algorithm> {
        self.algorithm
    }

    /// Preprocessing outputs (P*, spectral radius, coloring) computed —
    /// or injected — at build time.
    pub fn preprocessing(&self) -> &Preprocessed {
        &self.pre
    }

    /// The problem instance the solve will run on.
    pub fn problem(&self) -> &Problem {
        &self.problem
    }

    /// The resolved engine configuration.
    pub fn engine_config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Whether this solve runs through the sharded execution layer
    /// (`shards > 1` at build time).
    pub fn is_sharded(&self) -> bool {
        self.sharded.is_some()
    }

    /// Run the solve to completion.
    pub fn solve(self) -> SolveOutput {
        self.solve_with(None)
    }

    /// Run with an optional custom Propose backend (the PJRT/HLO path).
    ///
    /// # Panics
    ///
    /// If a block proposer is supplied for a sharded solve (`shards >
    /// 1`): the HLO backend binds to a single engine pool. A
    /// programming error, caught before any threads spawn.
    pub fn solve_with(
        mut self,
        block_proposer: Option<&mut dyn BlockProposer>,
    ) -> SolveOutput {
        if let Some(setup) = self.sharded.take() {
            assert!(
                block_proposer.is_none(),
                "sharded solves do not support a custom block proposer yet \
                 (backend = hlo requires shards = 1)"
            );
            return self.run_sharded(setup);
        }
        let state = SharedState::new(self.problem.n_samples(), self.problem.n_features());
        self.run(&state, block_proposer)
    }

    /// Like [`solve_with`](Self::solve_with) but writes into
    /// caller-owned [`SharedState`] (drift diagnostics, incremental
    /// re-solves), optionally with a custom Propose backend.
    ///
    /// # Panics
    ///
    /// If the state's dimensions don't match the problem's, or the
    /// solver is sharded (`shards > 1` — per-shard state is managed
    /// internally; use [`solve`](Self::solve)). Programming errors,
    /// caught before any threads spawn.
    pub fn solve_into(
        self,
        state: &SharedState,
        block_proposer: Option<&mut dyn BlockProposer>,
    ) -> SolveOutput {
        assert!(
            self.sharded.is_none(),
            "solve_into: sharded solves manage per-shard state internally — use solve()"
        );
        assert_eq!(
            state.z.len(),
            self.problem.n_samples(),
            "solve_into: state built for {} samples, problem has {}",
            state.z.len(),
            self.problem.n_samples()
        );
        assert_eq!(
            state.w.len(),
            self.problem.n_features(),
            "solve_into: state built for {} features, problem has {}",
            state.w.len(),
            self.problem.n_features()
        );
        self.run(state, block_proposer)
    }

    /// Shared tail of every `solve*` entry point: apply the warm start,
    /// assemble the hooks, run the engine.
    fn run(
        mut self,
        state: &SharedState,
        block_proposer: Option<&mut dyn BlockProposer>,
    ) -> SolveOutput {
        if let Some(w0) = &self.warm_start {
            state.apply_warm_start(&self.problem, w0);
        }
        let mut sink = self.events.take().map(|make| {
            make(&SolveInfo {
                n: self.problem.n_samples() as u64,
                k: self.problem.n_features() as u64,
                threads: self.cfg.threads as u32,
                shards: 0,
                kernel: kernel::resolve(self.cfg.fast_kernels, self.cfg.kernel).name(),
            })
        });
        let hooks = EngineHooks {
            observer: self.observer.as_deref_mut(),
            block_proposer,
            dirty: None,
            events: sink.as_deref_mut(),
        };
        engine::solve_from(&self.problem, state, self.select, self.accept, &self.cfg, hooks)
    }

    /// Sharded tail: hand the build-time shard setup to the sharded
    /// execution layer, mapping the engine knobs onto round-level ones.
    /// A caller observer runs on the shard-0 coordinator at every
    /// reconciled round, against the reconciled global iterate.
    fn run_sharded(mut self, setup: ShardedSetup) -> SolveOutput {
        let scfg = ShardedConfig {
            line_search_steps: self.cfg.line_search_steps,
            max_rounds: self.cfg.max_iters,
            max_seconds: self.cfg.max_seconds,
            tol: self.cfg.tol,
            log_every: self.cfg.log_every,
            buffer_budget_mb: self.cfg.buffer_budget_mb,
            barrier_spin: self.cfg.barrier_spin,
            screening: self.cfg.screening,
            kkt_every: self.cfg.kkt_every,
            kkt_adaptive: self.cfg.kkt_adaptive,
            fast_kernels: self.cfg.fast_kernels,
            kernel: self.cfg.kernel,
            numa_pin: setup.numa_pin,
            reconcile_every: setup.reconcile_every,
            reconcile_max_rounds: setup.reconcile_max_rounds,
            max_staleness_rounds: setup.max_staleness_rounds,
            barrier_timeout_secs: setup.barrier_timeout_secs,
            delta_reconcile: true,
            checkpoint: setup.checkpoint.clone(),
            resume: setup.resume.clone(),
        };
        let timeout = (scfg.barrier_timeout_secs > 0.0)
            .then(|| std::time::Duration::from_secs_f64(scfg.barrier_timeout_secs));
        let mut sink = self.events.take().map(|make| {
            make(&SolveInfo {
                n: self.problem.n_samples() as u64,
                k: self.problem.n_features() as u64,
                threads: setup.specs.iter().map(|s| s.threads.max(1) as u32).sum(),
                shards: setup.specs.len() as u32,
                kernel: kernel::resolve(self.cfg.fast_kernels, self.cfg.kernel).name(),
            })
        });
        match setup.transport {
            Transport::Barrier => solve_sharded_with(
                &self.problem,
                setup.specs,
                self.warm_start.as_deref(),
                &scfg,
                self.observer.as_deref_mut(),
                sink.as_deref_mut(),
            ),
            Transport::Loopback { precision } => {
                let link = LoopbackLink::new(
                    setup.specs.len(),
                    scfg.barrier_spin,
                    timeout,
                    precision,
                );
                solve_sharded_linked(
                    &self.problem,
                    setup.specs,
                    self.warm_start.as_deref(),
                    &scfg,
                    self.observer.as_deref_mut(),
                    sink.as_deref_mut(),
                    &link,
                )
            }
            Transport::Tcp {
                ref listen,
                ref peers,
                precision,
            } => {
                let link = match TcpLink::connect_with(
                    setup.specs.len(),
                    listen,
                    peers,
                    timeout,
                    precision,
                    ReconnectPolicy {
                        max_attempts: setup.reconnect_max_attempts,
                        seed: setup.seed,
                        ..Default::default()
                    },
                ) {
                    Ok(link) => link,
                    // Connect failure is a link failure, not a panic:
                    // report the same shape an in-flight socket death
                    // would (degrade, never hang — §Failure semantics).
                    Err(e) => return Self::transport_failed(&self.problem, setup.specs.len(), e),
                };
                solve_sharded_linked(
                    &self.problem,
                    setup.specs,
                    self.warm_start.as_deref(),
                    &scfg,
                    self.observer.as_deref_mut(),
                    sink.as_deref_mut(),
                    &link,
                )
            }
        }
    }

    /// Failed [`SolveOutput`] for a transport that never came up: no
    /// pool ever ran, so the iterate is the zero vector and the failure
    /// record carries the connect error.
    fn transport_failed(problem: &Problem, shards: usize, e: std::io::Error) -> SolveOutput {
        use crate::coordinator::convergence::{History, SolveError, SolveErrorKind, StopReason};
        let metrics = crate::coordinator::metrics::MetricsSnapshot {
            shards: shards as u64,
            shard_failures: shards as u64,
            ..Default::default()
        };
        SolveOutput {
            w: vec![0.0; problem.n_features()],
            objective: f64::INFINITY,
            nnz: 0,
            history: History::default(),
            metrics,
            stop: StopReason::ShardFailed,
            elapsed_secs: 0.0,
            failure: Some(SolveError {
                shard: None,
                kind: SolveErrorKind::Link,
                message: format!("tcp transport failed to connect: {e}"),
            }),
        }
    }
}

/// Typed, validating builder for [`Solver`]. Every setter is chainable;
/// [`build`](Self::build) rejects incompatible combinations.
pub struct SolverBuilder {
    matrix: Option<CscMatrix>,
    labels: Option<Vec<f64>>,
    loss: Box<dyn Loss>,
    lambda: f64,
    algorithm: Option<Algorithm>,
    select: Option<Box<dyn Select>>,
    accept: Option<Box<dyn Accept>>,
    observer: Option<Box<dyn Observer>>,
    events: Option<SinkFactory>,
    preprocessed: Option<Arc<Preprocessed>>,
    threads: usize,
    seed: u64,
    max_iters: usize,
    max_seconds: f64,
    tol: f64,
    line_search_steps: usize,
    log_every: usize,
    select_size: usize,
    accept_k: usize,
    update_path: UpdatePath,
    buffer_budget_mb: usize,
    coloring_strategy: Strategy,
    normalize: bool,
    warm_start: Option<Vec<f64>>,
    shards: usize,
    shard_strategy: ShardStrategy,
    numa_pin: bool,
    reconcile_every: usize,
    reconcile_max_rounds: usize,
    max_staleness_rounds: usize,
    barrier_timeout_secs: f64,
    transport: Transport,
    screening: bool,
    kkt_every: usize,
    kkt_adaptive: bool,
    fast_kernels: bool,
    kernel: KernelChoice,
    checkpoint_path: Option<std::path::PathBuf>,
    checkpoint_every_rounds: usize,
    resume_from: Option<std::path::PathBuf>,
    reconnect_max_attempts: usize,
}

impl Default for SolverBuilder {
    fn default() -> Self {
        let ecfg = EngineConfig::default();
        Self {
            matrix: None,
            labels: None,
            loss: Box::new(Logistic),
            lambda: 1e-4,
            algorithm: None,
            select: None,
            accept: None,
            observer: None,
            events: None,
            preprocessed: None,
            threads: 1,
            seed: 1,
            max_iters: ecfg.max_iters,
            max_seconds: ecfg.max_seconds,
            tol: ecfg.tol,
            line_search_steps: ecfg.line_search_steps,
            log_every: ecfg.log_every,
            select_size: 0,
            accept_k: 0,
            update_path: UpdatePath::Auto,
            buffer_budget_mb: ecfg.buffer_budget_mb,
            coloring_strategy: Strategy::Greedy,
            normalize: false,
            warm_start: None,
            shards: 1,
            shard_strategy: ShardStrategy::Contiguous,
            numa_pin: false,
            reconcile_every: 1,
            reconcile_max_rounds: 0,
            max_staleness_rounds: 0,
            barrier_timeout_secs: 30.0,
            transport: Transport::Barrier,
            screening: ecfg.screening,
            kkt_every: ecfg.kkt_every,
            kkt_adaptive: ecfg.kkt_adaptive,
            fast_kernels: ecfg.fast_kernels,
            kernel: ecfg.kernel,
            checkpoint_path: None,
            checkpoint_every_rounds: 16,
            resume_from: None,
            reconnect_max_attempts: 0,
        }
    }
}

impl SolverBuilder {
    /// The design matrix X (samples x features, CSC).
    pub fn matrix(mut self, x: CscMatrix) -> Self {
        self.matrix = Some(x);
        self
    }

    /// The label/target vector y (one entry per sample; ±1 for the
    /// classification losses).
    pub fn labels(mut self, y: Vec<f64>) -> Self {
        self.labels = Some(y);
        self
    }

    /// Convenience: matrix + labels from a loaded/generated [`Dataset`].
    pub fn dataset(mut self, ds: Dataset) -> Self {
        self.matrix = Some(ds.x);
        self.labels = Some(ds.y);
        self
    }

    /// The smooth loss (default [`Logistic`]).
    pub fn loss(mut self, loss: impl Loss + 'static) -> Self {
        self.loss = Box::new(loss);
        self
    }

    /// Boxed-loss variant (for `loss::by_name` results).
    pub fn boxed_loss(mut self, loss: Box<dyn Loss>) -> Self {
        self.loss = loss;
        self
    }

    /// l1 regularization strength (default 1e-4).
    pub fn lambda(mut self, lam: f64) -> Self {
        self.lambda = lam;
        self
    }

    /// Use a named preset from the paper's catalogue. Mutually exclusive
    /// with [`select`](Self::select)/[`accept`](Self::accept).
    pub fn algorithm(mut self, alg: Algorithm) -> Self {
        self.algorithm = Some(alg);
        self
    }

    /// Use a custom selection policy. Mutually exclusive with
    /// [`algorithm`](Self::algorithm).
    pub fn select(mut self, select: impl Select + 'static) -> Self {
        self.select = Some(Box::new(select));
        self
    }

    /// Use a custom accept policy (default: accept-all). Requires
    /// [`select`](Self::select).
    pub fn accept(mut self, accept: impl Accept + 'static) -> Self {
        self.accept = Some(Box::new(accept));
        self
    }

    /// Per-iteration observer hook (early stopping, checkpointing,
    /// streaming metrics). Closures work:
    /// `.observer(|info: &IterationInfo<'_>| ControlFlow::Continue(()))`.
    pub fn observer(mut self, observer: impl Observer + 'static) -> Self {
        self.observer = Some(Box::new(observer));
        self
    }

    /// Attach a typed-event [`Subscriber`] (metrics aggregation,
    /// structured logging, phase profiling — see [`crate::event`]).
    /// Compose several with tuples: `.subscriber((log, agg))`. Without
    /// one, the engine runs on the statically-dispatched no-op sink and
    /// every emit site compiles to nothing.
    pub fn subscriber<S: Subscriber + 'static>(mut self, subscriber: S) -> Self {
        self.events = Some(Box::new(move |info: &SolveInfo| {
            Box::new(Subscribed::new(subscriber, info)) as Box<dyn EventSink>
        }));
        self
    }

    /// Inject already-computed preprocessing (P*, coloring) instead of
    /// recomputing at build time — e.g. shared across a lambda path.
    /// Takes `Preprocessed` or `Arc<Preprocessed>`; sharing an `Arc`
    /// keeps injection O(1) (no deep copy of a coloring).
    pub fn preprocessed(mut self, pre: impl Into<Arc<Preprocessed>>) -> Self {
        self.preprocessed = Some(pre.into());
        self
    }

    /// Worker thread count (default 1; the calling thread is worker 0).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Seed for the preset policies' RNG streams and preprocessing.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn max_iters(mut self, iters: usize) -> Self {
        self.max_iters = iters;
        self
    }

    pub fn max_seconds(mut self, secs: f64) -> Self {
        self.max_seconds = secs;
        self
    }

    /// Relative-improvement stop over logged objectives (0 disables).
    pub fn tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    /// Sec. 4.1 refinement steps on accepted proposals.
    pub fn line_search_steps(mut self, steps: usize) -> Self {
        self.line_search_steps = steps;
        self
    }

    /// Objective/NNZ log cadence in iterations (0 = time-based).
    pub fn log_every(mut self, every: usize) -> Self {
        self.log_every = every;
        self
    }

    /// Preset selection-size override (0 = preset default, e.g. P* for
    /// SHOTGUN). Rejected for custom policies.
    pub fn select_size(mut self, size: usize) -> Self {
        self.select_size = size;
        self
    }

    /// TopK accept-budget override (0 = preset default). Rejected for
    /// custom policies.
    pub fn accept_k(mut self, k: usize) -> Self {
        self.accept_k = k;
        self
    }

    /// Update-phase z discipline (see
    /// [`UpdatePath`]). `ConflictFree` is validated at build time.
    pub fn update_path(mut self, path: UpdatePath) -> Self {
        self.update_path = path;
        self
    }

    /// Memory budget (MiB) for the buffered update path's dense
    /// accumulators; past it, buffered iterations spill to sparse
    /// per-thread maps.
    pub fn buffer_budget_mb(mut self, mb: usize) -> Self {
        self.buffer_budget_mb = mb;
        self
    }

    /// Coloring strategy for the COLORING preset's preprocessing.
    pub fn coloring_strategy(mut self, strategy: Strategy) -> Self {
        self.coloring_strategy = strategy;
        self
    }

    /// Shard count for the sharded execution layer (default 1 = the
    /// single engine pool). With `n > 1`, build() partitions the
    /// columns ([`shard_strategy`](Self::shard_strategy)), instantiates
    /// the preset per shard over its local columns, and the solve runs
    /// one worker pool per shard against a shard-local residual replica
    /// reconciled per the configured cadence
    /// ([`reconcile_every`](Self::reconcile_every) /
    /// [`reconcile_max_rounds`](Self::reconcile_max_rounds), optionally
    /// NUMA-pinned via [`numa_pin`](Self::numa_pin); see
    /// [`crate::shard`]). Requires an [`algorithm`](Self::algorithm)
    /// preset; [`threads`](Self::threads) is the *total* worker count,
    /// divided across the shard pools. Clamped to the column count. An
    /// [`observer`](Self::observer) runs on the shard-0 coordinator at
    /// every reconciled round, against the reconciled global iterate.
    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    /// Column-partitioning strategy for `shards > 1` (default
    /// [`ShardStrategy::Contiguous`]).
    pub fn shard_strategy(mut self, strategy: ShardStrategy) -> Self {
        self.shard_strategy = strategy;
        self
    }

    /// Pin each shard pool to a NUMA node, with the shard's residual
    /// replica and engine scratch first-touch-allocated on the pinned
    /// threads so they live in node-local DRAM
    /// ([`crate::shard::engine`] §NUMA; default off). A graceful no-op
    /// on single-node or non-Linux hosts —
    /// [`MetricsSnapshot::numa_nodes`] reports what actually happened.
    ///
    /// [`MetricsSnapshot::numa_nodes`]: crate::coordinator::metrics::MetricsSnapshot::numa_nodes
    pub fn numa_pin(mut self, pin: bool) -> Self {
        self.numa_pin = pin;
        self
    }

    /// Reconcile shard replicas every R rounds instead of every round
    /// ([`crate::shard::engine`] §Reconcile cadence; default 1, must be
    /// >= 1). Rounds in between skip the cross-shard barrier entirely.
    pub fn reconcile_every(mut self, rounds: usize) -> Self {
        self.reconcile_every = rounds;
        self
    }

    /// Upper bound for the *adaptive* reconcile cadence: when above
    /// [`reconcile_every`](Self::reconcile_every), the coordinator
    /// doubles the cadence after conflict-free reconciles and snaps it
    /// back on a divergence spike. 0 (the default) keeps the fixed
    /// cadence.
    pub fn reconcile_max_rounds(mut self, rounds: usize) -> Self {
        self.reconcile_max_rounds = rounds;
        self
    }

    /// Hard bound on replica staleness under the adaptive cadence: a
    /// reconcile is forced whenever the next gap the doubling schedule
    /// wants would leave replicas unreconciled for more than this many
    /// rounds ([`crate::shard::engine`] §Failure semantics; default 0 =
    /// unbounded). Must be 0 or >= [`reconcile_every`](Self::reconcile_every).
    /// [`MetricsSnapshot::staleness_forced_reconciles`] counts how often
    /// the bound bit.
    ///
    /// [`MetricsSnapshot::staleness_forced_reconciles`]:
    ///     crate::coordinator::metrics::MetricsSnapshot::staleness_forced_reconciles
    pub fn max_staleness_rounds(mut self, rounds: usize) -> Self {
        self.max_staleness_rounds = rounds;
        self
    }

    /// Seconds a shard waits at the reconcile barrier before declaring
    /// its peers dead and failing the solve with
    /// [`StopReason::ShardFailed`](crate::coordinator::convergence::StopReason::ShardFailed)
    /// instead of hanging ([`crate::shard::engine`] §Failure semantics;
    /// default 30.0; <= 0 disables the timeout).
    pub fn barrier_timeout_secs(mut self, secs: f64) -> Self {
        self.barrier_timeout_secs = secs;
        self
    }

    /// Reconcile backend for `shards > 1` (default
    /// [`Transport::Barrier`], the in-memory protocol).
    /// [`Transport::Loopback`] routes every reconcile exchange through
    /// the full encode→frame→decode wire protocol in-process
    /// ([`crate::net::LoopbackLink`]); [`Transport::Tcp`] ships the
    /// same frames over blocking sockets ([`crate::net::TcpLink`]),
    /// with [`barrier_timeout_secs`](Self::barrier_timeout_secs)
    /// mapped onto the socket deadlines. Non-barrier transports
    /// require `shards >= 2` (validated at build time — a wire with
    /// one peer is a configuration error, not a degenerate success).
    pub fn transport(mut self, transport: Transport) -> Self {
        self.transport = transport;
        self
    }

    /// Active-set KKT screening ([`crate::screen`]; default off).
    /// Restricts selection to coordinates whose optimality conditions
    /// are not yet confidently satisfied; periodic full-set KKT sweeps
    /// ([`kkt_every`](Self::kkt_every)) reactivate any violator, and a
    /// sweep gates every
    /// [`StopReason::Converged`](crate::coordinator::convergence::StopReason::Converged),
    /// so the converged solution is identical to the unscreened one.
    /// Works with every preset and custom policy, and per shard pool
    /// when sharded. Requires `lambda > 0` (validated at build time).
    pub fn screening(mut self, screening: bool) -> Self {
        self.screening = screening;
        self
    }

    /// Full-set KKT sweep cadence in iterations for
    /// [`screening`](Self::screening) (default 16; must be >= 1 when
    /// screening is on).
    pub fn kkt_every(mut self, every: usize) -> Self {
        self.kkt_every = every;
        self
    }

    /// Drive the sweep cadence from the measured reactivation rate
    /// instead of the fixed [`kkt_every`](Self::kkt_every): clean
    /// sweeps stretch the interval (up to `kkt_every *`
    /// [`KKT_STRETCH_MAX`](crate::coordinator::engine::KKT_STRETCH_MAX)),
    /// any reactivation halves it. The convergence gate is unaffected,
    /// so fixed and adaptive runs certify the same fixed point.
    /// Default off.
    pub fn kkt_adaptive(mut self, adaptive: bool) -> Self {
        self.kkt_adaptive = adaptive;
        self
    }

    /// Route hot gathers through the 4-way unrolled, prefetching
    /// kernels ([`crate::sparse::CscMatrix::dot_col_fast`]). Default
    /// off: the unrolled reduction re-associates floating point, so the
    /// scalar path stays the bit-exactness reference.
    pub fn fast_kernels(mut self, fast: bool) -> Self {
        self.fast_kernels = fast;
        self
    }

    /// SIMD tier ceiling for the fast kernels ([`crate::kernel`]):
    /// `Auto` (the default) probes the CPU once and takes the best
    /// supported tier, a named tier clamps to what the host actually
    /// has. Inert unless [`fast_kernels`](Self::fast_kernels) is on.
    /// The resolved tier is reported in
    /// [`MetricsSnapshot::kernel_tier`](crate::coordinator::metrics::MetricsSnapshot::kernel_tier).
    pub fn kernel(mut self, choice: KernelChoice) -> Self {
        self.kernel = choice;
        self
    }

    /// Column-normalize the matrix at build time (the paper's setting;
    /// default `false` — the matrix is used exactly as given).
    pub fn normalize(mut self, normalize: bool) -> Self {
        self.normalize = normalize;
        self
    }

    /// Start from this weight vector instead of zero.
    pub fn warm_start(mut self, w0: Vec<f64>) -> Self {
        self.warm_start = Some(w0);
        self
    }

    /// Write a CRC-guarded recovery checkpoint to this path
    /// ([`crate::recover::checkpoint`]; sharded solves only — the
    /// shard-0 coordinator writes it at reconciled rounds on the
    /// [`checkpoint_every_rounds`](Self::checkpoint_every_rounds)
    /// cadence and at the stopping round, with atomic rename).
    pub fn checkpoint_path(mut self, path: impl Into<std::path::PathBuf>) -> Self {
        self.checkpoint_path = Some(path.into());
        self
    }

    /// Reconciled rounds between checkpoint writes (default 16; 0
    /// writes only the final, stopping-round checkpoint). Inert without
    /// [`checkpoint_path`](Self::checkpoint_path).
    pub fn checkpoint_every_rounds(mut self, rounds: usize) -> Self {
        self.checkpoint_every_rounds = rounds;
        self
    }

    /// Resume a sharded solve from a checkpoint written by
    /// [`checkpoint_path`](Self::checkpoint_path). `build()` loads and
    /// validates the file against the problem (dimensions, shard count,
    /// seed, lambda) — under exact wire precision the resumed solve
    /// continues bit-exactly where the checkpoint was taken
    /// ([`crate::shard::engine`] §Failure semantics).
    pub fn resume_from(mut self, path: impl Into<std::path::PathBuf>) -> Self {
        self.resume_from = Some(path.into());
        self
    }

    /// Per-peer TCP redial budget for mid-solve disconnects (default 0
    /// = reconnection disabled, the pre-recover behavior: the first
    /// socket error degrades the solve). Attempts follow the bounded
    /// exponential backoff of
    /// [`ReconnectPolicy`](crate::recover::ReconnectPolicy), seeded
    /// from [`seed`](Self::seed); exhausting them degrades to
    /// `StopReason::ShardFailed` + `SolveErrorKind::Link` — never a
    /// hang. Only meaningful with [`Transport::Tcp`].
    pub fn reconnect_max_attempts(mut self, attempts: usize) -> Self {
        self.reconnect_max_attempts = attempts;
        self
    }

    /// Validate the full combination and assemble a runnable [`Solver`].
    pub fn build(self) -> anyhow::Result<Solver> {
        let mut x = self.matrix.ok_or_else(|| {
            anyhow::anyhow!("SolverBuilder: no design matrix (use .matrix(x) or .dataset(ds))")
        })?;
        let y = self.labels.ok_or_else(|| {
            anyhow::anyhow!("SolverBuilder: no labels (use .labels(y) or .dataset(ds))")
        })?;
        anyhow::ensure!(
            y.len() == x.n_rows(),
            "SolverBuilder: {} labels for a matrix with {} rows",
            y.len(),
            x.n_rows()
        );
        anyhow::ensure!(
            self.lambda.is_finite() && self.lambda >= 0.0,
            "SolverBuilder: lambda must be finite and >= 0, got {}",
            self.lambda
        );
        anyhow::ensure!(
            self.threads >= 1,
            "SolverBuilder: threads must be >= 1 (the calling thread is worker 0)"
        );
        if let Some(w0) = &self.warm_start {
            anyhow::ensure!(
                w0.len() == x.n_cols(),
                "SolverBuilder: warm start has {} weights for {} features",
                w0.len(),
                x.n_cols()
            );
        }

        let custom = self.select.is_some() || self.accept.is_some();
        anyhow::ensure!(
            !(self.algorithm.is_some() && custom),
            "SolverBuilder: .algorithm(..) and custom .select(..)/.accept(..) are \
             mutually exclusive — presets already define both policies"
        );
        anyhow::ensure!(
            !(self.accept.is_some() && self.select.is_none()),
            "SolverBuilder: a custom .accept(..) needs a .select(..) policy too"
        );
        anyhow::ensure!(
            self.algorithm.is_some() || self.select.is_some(),
            "SolverBuilder: choose an .algorithm(..) preset or provide a custom \
             .select(..) policy"
        );
        if custom {
            anyhow::ensure!(
                self.select_size == 0 && self.accept_k == 0,
                "SolverBuilder: .select_size/.accept_k are preset sizing knobs; \
                 size a custom policy directly"
            );
        }
        anyhow::ensure!(
            self.shards >= 1,
            "SolverBuilder: shards must be >= 1 (1 = the single engine pool)"
        );
        anyhow::ensure!(
            self.reconcile_every >= 1,
            "SolverBuilder: reconcile_every must be >= 1 (1 = reconcile every round)"
        );
        anyhow::ensure!(
            self.reconcile_max_rounds == 0
                || self.reconcile_max_rounds >= self.reconcile_every,
            "SolverBuilder: reconcile_max_rounds ({}) must be 0 (fixed cadence) or \
             >= reconcile_every ({})",
            self.reconcile_max_rounds,
            self.reconcile_every
        );
        anyhow::ensure!(
            self.max_staleness_rounds == 0
                || self.max_staleness_rounds >= self.reconcile_every,
            "SolverBuilder: max_staleness_rounds ({}) must be 0 (unbounded) or \
             >= reconcile_every ({}) — a staleness bound below the fixed cadence \
             is unsatisfiable",
            self.max_staleness_rounds,
            self.reconcile_every
        );
        anyhow::ensure!(
            self.barrier_timeout_secs == 0.0 || self.barrier_timeout_secs.is_finite(),
            "SolverBuilder: barrier_timeout_secs must be finite (or <= 0 to \
             disable the timeout), got {}",
            self.barrier_timeout_secs
        );
        if self.transport != Transport::Barrier {
            anyhow::ensure!(
                self.shards >= 2,
                "SolverBuilder: transport = {} requires shards >= 2 — the wire \
                 transports carry cross-shard reconcile traffic, and a \
                 single-pool solve has none",
                self.transport.name()
            );
        }
        if let Transport::Tcp { listen, peers, .. } = &self.transport {
            anyhow::ensure!(
                listen.parse::<std::net::SocketAddr>().is_ok(),
                "SolverBuilder: transport = tcp needs a valid listen socket \
                 address (host:port), got {listen:?}"
            );
            for peer in peers {
                anyhow::ensure!(
                    peer.parse::<std::net::SocketAddr>().is_ok(),
                    "SolverBuilder: transport = tcp peer {peer:?} is not a \
                     valid socket address (host:port)"
                );
            }
        }
        if self.screening {
            anyhow::ensure!(
                self.lambda > 0.0,
                "SolverBuilder: screening requires lambda > 0 — KKT screening \
                 deactivates coordinates with subgradient slack, and an \
                 unregularized problem has none"
            );
            anyhow::ensure!(
                self.kkt_every >= 1,
                "SolverBuilder: screening requires kkt_every >= 1 (the full-set \
                 KKT sweep cadence is the reactivation safety net)"
            );
        }
        // effective shard count: never more shards than columns
        let shards = self.shards.min(x.n_cols().max(1));
        if shards > 1 {
            anyhow::ensure!(
                self.algorithm.is_some(),
                "SolverBuilder: shards > 1 instantiates the policy pair per shard, \
                 which needs an .algorithm(..) preset — custom Select/Accept \
                 policies run with shards = 1"
            );
            // observers ARE supported sharded (PR-3's restriction is
            // lifted): the shard-0 coordinator invokes them at every
            // reconciled round on the reconciled global iterate
        }
        // conflict-free plain stores are only sound when every z[i] has
        // a unique writer per Update phase: COLORING's color classes or
        // a single thread. A custom policy cannot prove that here.
        // (Sharded builds re-check per *pool* inside build_shard_specs,
        // where each pool's thread count is known — shards write
        // disjoint replicas, so only intra-pool conflicts matter.)
        anyhow::ensure!(
            shards > 1
                || self.update_path != UpdatePath::ConflictFree
                || self.threads <= 1
                || self.algorithm == Some(Algorithm::Coloring),
            "SolverBuilder: update_path = ConflictFree requires \
             Algorithm::Coloring or threads = 1 (got {} with {} threads); \
             use Buffered or Atomic",
            self.algorithm
                .map(|a| a.name().to_string())
                .unwrap_or_else(|| "a custom policy".into()),
            self.threads
        );

        if self.normalize {
            x.normalize_columns();
        }

        // Crash recovery (recover::checkpoint): both ends of the seam
        // live on the shard-0 coordinator, so they only exist sharded.
        if self.checkpoint_path.is_some() || self.resume_from.is_some() {
            anyhow::ensure!(
                shards >= 2,
                "SolverBuilder: checkpoint_path/resume_from require shards >= 2 \
                 — checkpoints are written (and consumed) by the shard-0 \
                 reconcile coordinator, which a single-pool solve never runs"
            );
        }
        anyhow::ensure!(
            !(self.resume_from.is_some() && self.warm_start.is_some()),
            "SolverBuilder: .resume_from(..) and .warm_start(..) are mutually \
             exclusive — a checkpoint already carries the full iterate"
        );
        let resume = match &self.resume_from {
            None => None,
            Some(path) => {
                let ckpt = Checkpoint::load(path).map_err(|e| {
                    anyhow::anyhow!("SolverBuilder: resume_from {path:?}: {e}")
                })?;
                anyhow::ensure!(
                    ckpt.w.len() == x.n_cols() && ckpt.z.len() == x.n_rows(),
                    "SolverBuilder: checkpoint {path:?} is for a {}x{} problem, \
                     not this {}x{} one",
                    ckpt.z.len(),
                    ckpt.w.len(),
                    x.n_rows(),
                    x.n_cols()
                );
                anyhow::ensure!(
                    ckpt.shards as usize == shards,
                    "SolverBuilder: checkpoint {path:?} was taken with {} shards, \
                     this solve has {} — the shard partition (and thus the \
                     selection streams) would not line up",
                    ckpt.shards,
                    shards
                );
                anyhow::ensure!(
                    ckpt.seed == self.seed,
                    "SolverBuilder: checkpoint {path:?} was taken with seed {}, \
                     this solve uses {} — bit-exact resume replays the selection \
                     streams, which the seed determines",
                    ckpt.seed,
                    self.seed
                );
                anyhow::ensure!(
                    ckpt.lambda == self.lambda,
                    "SolverBuilder: checkpoint {path:?} was taken at lambda {}, \
                     this solve uses {}",
                    ckpt.lambda,
                    self.lambda
                );
                Some(ResumeState::from_checkpoint(ckpt))
            }
        };

        // shards > 1: partition the (now-final) matrix and build each
        // shard's zero-copy sub-problem + local policy pair
        let sharded = if shards > 1 {
            let alg = self.algorithm.expect("validated above");
            Some(ShardedSetup {
                specs: build_shard_specs(
                    &x,
                    &y,
                    self.loss.as_ref(),
                    self.lambda,
                    alg,
                    shards,
                    self.shard_strategy,
                    self.threads,
                    self.select_size,
                    self.accept_k,
                    self.coloring_strategy,
                    self.update_path,
                    self.seed,
                )?,
                numa_pin: self.numa_pin,
                reconcile_every: self.reconcile_every,
                reconcile_max_rounds: if self.reconcile_max_rounds == 0 {
                    self.reconcile_every
                } else {
                    self.reconcile_max_rounds
                },
                max_staleness_rounds: self.max_staleness_rounds,
                barrier_timeout_secs: self.barrier_timeout_secs,
                transport: self.transport,
                checkpoint: self.checkpoint_path.clone().map(|path| CheckpointSpec {
                    path,
                    every_rounds: self.checkpoint_every_rounds,
                    seed: self.seed,
                }),
                resume,
                reconnect_max_attempts: self.reconnect_max_attempts as u32,
                seed: self.seed,
            })
        } else {
            None
        };

        // Policy pair + preprocessing for the single-engine path. A
        // sharded solve runs the per-shard pairs built above and never
        // touches these, so skip the (potentially expensive) full-matrix
        // preprocessing there — COLORING would otherwise pay a redundant
        // whole-matrix coloring on every sharded build. An injected
        // `.preprocessed(..)` is still surfaced through
        // [`Solver::preprocessing`] either way.
        let (pre, select, accept) = match self.algorithm {
            Some(_) if sharded.is_some() => (
                self.preprocessed
                    .unwrap_or_else(|| Arc::new(Preprocessed::none())),
                // placeholders, never invoked (run_sharded consumes the
                // per-shard specs); cheap to construct by design
                crate::coordinator::select::full_set(x.n_cols()),
                accept::all(),
            ),
            Some(alg) => {
                let pre = match self.preprocessed {
                    Some(pre) => pre,
                    None => Arc::new(Preprocessed::for_algorithm(
                        alg,
                        &x,
                        self.coloring_strategy,
                        self.seed,
                    )),
                };
                let inst = instantiate(
                    alg,
                    x.n_cols(),
                    self.threads,
                    self.select_size,
                    self.accept_k,
                    &pre,
                    self.seed,
                )?;
                (pre, inst.selector, inst.acceptor)
            }
            None => (
                self.preprocessed
                    .unwrap_or_else(|| Arc::new(Preprocessed::none())),
                self.select.expect("validated above"),
                self.accept.unwrap_or_else(accept::all),
            ),
        };

        // COLORING's color classes are conflict-free: the paper's
        // synchronization-free Update (Sec. 4.2). An explicit
        // update_path still overrides.
        let update_path = if self.update_path == UpdatePath::Auto
            && self.algorithm == Some(Algorithm::Coloring)
        {
            UpdatePath::ConflictFree
        } else {
            self.update_path
        };

        let cfg = EngineConfig {
            threads: self.threads,
            line_search_steps: self.line_search_steps,
            max_iters: self.max_iters,
            max_seconds: self.max_seconds,
            tol: self.tol,
            log_every: self.log_every,
            force_dloss: None,
            update_path,
            buffer_budget_mb: self.buffer_budget_mb,
            screening: self.screening,
            kkt_every: self.kkt_every,
            kkt_adaptive: self.kkt_adaptive,
            fast_kernels: self.fast_kernels,
            kernel: self.kernel,
            ..Default::default()
        };

        let problem = Problem::new(
            Dataset {
                x,
                y,
                name: String::new(),
            },
            self.loss,
            self.lambda,
        );

        Ok(Solver {
            problem,
            select,
            accept,
            cfg,
            observer: self.observer,
            events: self.events,
            pre,
            algorithm: self.algorithm,
            warm_start: self.warm_start,
            sharded,
        })
    }
}

/// Partition `x` and build one [`ShardSpec`] per non-empty shard: a
/// zero-copy column-range sub-problem (the plan is made contiguous
/// first — identity plans view `x` directly, permuted plans pay one
/// O(nnz) column gather), per-shard preprocessing (P* and colorings are
/// computed on the shard's own columns: a coloring only has to be valid
/// *within* a shard, since cross-shard updates land on different
/// replicas), and the preset's policy pair instantiated over the local
/// column space. Global knobs keep their global meaning: `select_size`
/// / `accept_k` divide across the active shards, and `threads` is the
/// total worker budget — each pool gets `threads / active`, with the
/// first `threads % active` pools taking one extra so no requested
/// worker is dropped.
#[allow(clippy::too_many_arguments)]
pub(crate) fn build_shard_specs(
    x: &CscMatrix,
    y: &[f64],
    loss: &dyn Loss,
    lambda: f64,
    alg: Algorithm,
    shards: usize,
    strategy: ShardStrategy,
    threads_total: usize,
    select_size: usize,
    accept_k: usize,
    coloring_strategy: Strategy,
    update_path: UpdatePath,
    seed: u64,
) -> anyhow::Result<Vec<ShardSpec>> {
    let plan = partition(x, shards, strategy);
    debug_assert!(plan.validate().is_ok());
    let base = if plan.is_identity() {
        x.clone()
    } else {
        x.select_columns(&plan.permutation())
    };
    let active = plan.shards.iter().filter(|c| !c.is_empty()).count().max(1);
    let per_shard = |knob: usize| if knob > 0 { (knob / active).max(1) } else { 0 };
    let pool_threads =
        |pool: usize| (threads_total / active + usize::from(pool < threads_total % active)).max(1);
    // conflict-free plain stores need a unique z-writer per element
    // within each pool (cross-shard writes land on different replicas)
    anyhow::ensure!(
        update_path != UpdatePath::ConflictFree
            || alg == Algorithm::Coloring
            || pool_threads(0) <= 1,
        "SolverBuilder: update_path = ConflictFree requires \
         Algorithm::Coloring or one worker per shard pool (got {} with {} \
         threads over {} shards); use Buffered or Atomic",
        alg.name(),
        threads_total,
        active
    );

    let mut specs = Vec::with_capacity(active);
    let mut lo = 0usize;
    let mut pool = 0usize;
    for (s, cols) in plan.shards.iter().enumerate() {
        let hi = lo + cols.len();
        let range = lo..hi;
        lo = hi;
        if cols.is_empty() {
            continue;
        }
        let threads = pool_threads(pool);
        pool += 1;
        let view = base.col_range_view(range.start, range.end);
        let pre = Preprocessed::for_algorithm(alg, &view, coloring_strategy, seed);
        let inst = instantiate(
            alg,
            view.n_cols(),
            threads,
            per_shard(select_size),
            per_shard(accept_k),
            &pre,
            // distinct deterministic policy stream per shard
            seed.wrapping_add(s as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        )?;
        // COLORING shards default to the paper's synchronization-free
        // updates, like the unsharded builder path
        let shard_update = if update_path == UpdatePath::Auto && alg == Algorithm::Coloring
        {
            UpdatePath::ConflictFree
        } else {
            update_path
        };
        specs.push(ShardSpec {
            problem: Problem::new(
                Dataset {
                    x: view,
                    y: y.to_vec(),
                    name: String::new(),
                },
                loss.clone_box(),
                lambda,
            ),
            cols: cols.clone(),
            select: inst.selector,
            accept: inst.acceptor,
            update_path: shard_update,
            threads,
        });
    }
    Ok(specs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::convergence::StopReason;
    use crate::coordinator::observer::IterationInfo;
    use crate::coordinator::select;
    use crate::util::Pcg64;
    use std::ops::ControlFlow;

    fn small_xy(seed: u64, n: usize, k: usize) -> (CscMatrix, Vec<f64>) {
        let mut rng = Pcg64::seeded(seed);
        let mut b = crate::sparse::CooBuilder::new(n, k);
        for j in 0..k {
            for i in 0..n {
                if rng.next_f64() < 0.3 {
                    b.push(i, j, rng.range_f64(-1.0, 1.0));
                }
            }
        }
        let mut x = b.build();
        x.normalize_columns();
        let y = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        (x, y)
    }

    #[test]
    fn preset_builds_and_descends() {
        let (x, y) = small_xy(1, 40, 20);
        let out = Solver::builder()
            .matrix(x)
            .labels(y)
            .lambda(1e-3)
            .algorithm(Algorithm::Scd)
            .max_iters(300)
            .max_seconds(20.0)
            .build()
            .unwrap()
            .solve();
        let first = out.history.records.first().unwrap().objective;
        assert!(out.objective < first, "{first} -> {}", out.objective);
    }

    #[test]
    fn custom_select_with_default_accept() {
        let (x, y) = small_xy(2, 30, 15);
        let k = x.n_cols();
        let out = Solver::builder()
            .matrix(x)
            .labels(y)
            .lambda(1e-3)
            .select(select::Cyclic { next: 0, k })
            .max_iters(200)
            .max_seconds(20.0)
            .build()
            .unwrap()
            .solve();
        let first = out.history.records.first().unwrap().objective;
        assert!(out.objective < first);
    }

    #[test]
    fn observer_hook_streams_and_stops() {
        let (x, y) = small_xy(3, 30, 15);
        let k = x.n_cols();
        let out = Solver::builder()
            .matrix(x)
            .labels(y)
            .select(select::Cyclic { next: 0, k })
            .observer(|info: &IterationInfo<'_>| {
                if info.iter >= 10 {
                    ControlFlow::Break(())
                } else {
                    ControlFlow::Continue(())
                }
            })
            .max_seconds(30.0)
            .build()
            .unwrap()
            .solve();
        assert_eq!(out.stop, StopReason::Observer);
        assert_eq!(out.metrics.iterations, 10);
    }

    #[test]
    fn coloring_preset_defaults_to_conflict_free() {
        let (x, y) = small_xy(4, 30, 15);
        let solver = Solver::builder()
            .matrix(x)
            .labels(y)
            .algorithm(Algorithm::Coloring)
            .threads(4)
            .build()
            .unwrap();
        assert_eq!(solver.engine_config().update_path, UpdatePath::ConflictFree);
        assert!(solver.preprocessing().coloring.is_some());
    }

    #[test]
    fn warm_start_resumes() {
        let (x, y) = small_xy(5, 30, 15);
        let k = x.n_cols();
        let first = Solver::builder()
            .matrix(x.clone())
            .labels(y.clone())
            .algorithm(Algorithm::Ccd)
            .max_iters(100)
            .max_seconds(20.0)
            .build()
            .unwrap()
            .solve();
        let resumed = Solver::builder()
            .matrix(x)
            .labels(y)
            .algorithm(Algorithm::Ccd)
            .warm_start(first.w.clone())
            .max_iters(k) // one sweep
            .max_seconds(20.0)
            .build()
            .unwrap()
            .solve();
        assert!(resumed.objective <= first.objective + 1e-12);
    }

    #[test]
    fn rejected_combinations() {
        let (x, y) = small_xy(6, 10, 5);
        let base = || {
            Solver::builder()
                .matrix(x.clone())
                .labels(y.clone())
                .algorithm(Algorithm::Scd)
        };
        // no matrix
        assert!(Solver::builder().labels(y.clone()).build().is_err());
        // no labels
        assert!(Solver::builder()
            .matrix(x.clone())
            .algorithm(Algorithm::Scd)
            .build()
            .is_err());
        // label count mismatch
        assert!(Solver::builder()
            .matrix(x.clone())
            .labels(vec![1.0; 3])
            .algorithm(Algorithm::Scd)
            .build()
            .is_err());
        // neither preset nor custom select
        assert!(Solver::builder()
            .matrix(x.clone())
            .labels(y.clone())
            .build()
            .is_err());
        // preset + custom policy
        assert!(base().select(select::Cyclic { next: 0, k: 5 }).build().is_err());
        // custom accept without select
        assert!(Solver::builder()
            .matrix(x.clone())
            .labels(y.clone())
            .accept(accept::AcceptAll)
            .build()
            .is_err());
        // conflict-free without coloring at >1 thread
        assert!(base()
            .threads(4)
            .update_path(UpdatePath::ConflictFree)
            .build()
            .is_err());
        // ... but fine single-threaded
        assert!(base()
            .threads(1)
            .update_path(UpdatePath::ConflictFree)
            .build()
            .is_ok());
        // sizing knobs on custom policies
        assert!(Solver::builder()
            .matrix(x.clone())
            .labels(y.clone())
            .select(select::Cyclic { next: 0, k: 5 })
            .select_size(3)
            .build()
            .is_err());
        // bad lambda / threads / warm-start length
        assert!(base().lambda(f64::NAN).build().is_err());
        assert!(base().lambda(-1.0).build().is_err());
        assert!(base().threads(0).build().is_err());
        assert!(base().warm_start(vec![0.0; 2]).build().is_err());
        // sharding: zero shards and custom policies are rejected;
        // presets are fine, and observers now run sharded (the PR-3
        // restriction is lifted)
        assert!(base().shards(0).build().is_err());
        assert!(Solver::builder()
            .matrix(x.clone())
            .labels(y.clone())
            .select(select::Cyclic { next: 0, k: 5 })
            .shards(2)
            .build()
            .is_err());
        assert!(base()
            .shards(2)
            .observer(|_: &IterationInfo<'_>| ControlFlow::Continue(()))
            .build()
            .is_ok());
        assert!(base().shards(2).build().is_ok());
        // reconcile cadence knobs: 0 cadence and an inverted window are
        // rejected; 0 max (= fixed cadence) and a proper window are fine
        assert!(base().reconcile_every(0).build().is_err());
        assert!(base().reconcile_every(4).reconcile_max_rounds(2).build().is_err());
        assert!(base().reconcile_every(4).build().is_ok());
        assert!(base().reconcile_every(2).reconcile_max_rounds(16).build().is_ok());
        assert!(base().shards(2).numa_pin(true).build().is_ok());
        // staleness bound below the fixed cadence is unsatisfiable;
        // 0 (unbounded) and >= cadence are fine. Barrier timeout must be
        // finite, but 0 / negative (= disabled) are accepted.
        assert!(base().reconcile_every(4).max_staleness_rounds(2).build().is_err());
        assert!(base().reconcile_every(4).max_staleness_rounds(0).build().is_ok());
        assert!(base().reconcile_every(4).max_staleness_rounds(8).build().is_ok());
        assert!(base().barrier_timeout_secs(f64::NAN).build().is_err());
        assert!(base().barrier_timeout_secs(0.0).build().is_ok());
        assert!(base().barrier_timeout_secs(-1.0).build().is_ok());
        // screening: needs a real l1 penalty and a sweep cadence
        assert!(base().lambda(0.0).screening(true).build().is_err());
        assert!(base().screening(true).kkt_every(0).build().is_err());
        assert!(base().screening(true).build().is_ok());
        // kkt_every = 0 is only rejected when screening is on
        assert!(base().kkt_every(0).build().is_ok());
        // wire transports: need >= 2 shards; tcp needs parseable socket
        // addresses for listen and every peer
        let loopback = || Transport::Loopback {
            precision: crate::net::WirePrecision::Exact,
        };
        assert!(base().transport(loopback()).build().is_err());
        assert!(base().shards(2).transport(loopback()).build().is_ok());
        let tcp = |listen: &str, peers: &[&str]| Transport::Tcp {
            listen: listen.into(),
            peers: peers.iter().map(|p| p.to_string()).collect(),
            precision: crate::net::WirePrecision::Exact,
        };
        assert!(base()
            .shards(2)
            .transport(tcp("127.0.0.1:0", &[]))
            .build()
            .is_ok());
        assert!(base()
            .shards(2)
            .transport(tcp("not-an-address", &[]))
            .build()
            .is_err());
        assert!(base()
            .shards(2)
            .transport(tcp("127.0.0.1:0", &["localhost"]))
            .build()
            .is_err());
        // recover: checkpoint/resume are coordinator seams (shards >= 2);
        // resume replaces — never composes with — a warm start
        assert!(base().checkpoint_path("/tmp/gencd-ck.bin").build().is_err());
        assert!(base().resume_from("/tmp/no-such-checkpoint.bin").build().is_err());
        assert!(base()
            .shards(2)
            .checkpoint_path("/tmp/gencd-ck.bin")
            .build()
            .is_ok());
        assert!(base()
            .shards(2)
            .warm_start(vec![0.0; 5])
            .resume_from("/tmp/no-such-checkpoint.bin")
            .build()
            .is_err());
        // a missing checkpoint file is a typed load error, not a panic
        assert!(base()
            .shards(2)
            .resume_from("/tmp/no-such-checkpoint.bin")
            .build()
            .is_err());
    }

    #[test]
    fn screening_knobs_reach_the_engine() {
        let (x, y) = small_xy(9, 20, 10);
        let solver = Solver::builder()
            .matrix(x)
            .labels(y)
            .lambda(1e-3)
            .algorithm(Algorithm::Scd)
            .screening(true)
            .kkt_every(7)
            .kkt_adaptive(true)
            .fast_kernels(true)
            .kernel(KernelChoice::Avx2)
            .build()
            .unwrap();
        let cfg = solver.engine_config();
        assert!(cfg.screening);
        assert_eq!(cfg.kkt_every, 7);
        assert!(cfg.kkt_adaptive);
        assert!(cfg.fast_kernels);
        assert_eq!(cfg.kernel, KernelChoice::Avx2);
    }

    #[test]
    fn sharded_preset_builds_and_descends() {
        let (x, y) = small_xy(7, 40, 20);
        let solver = Solver::builder()
            .matrix(x)
            .labels(y)
            .lambda(1e-3)
            .algorithm(Algorithm::Shotgun)
            .shards(3)
            .shard_strategy(ShardStrategy::MinOverlap)
            .threads(3)
            .max_iters(200)
            .max_seconds(30.0)
            .log_every(20)
            .build()
            .unwrap();
        assert!(solver.is_sharded());
        let out = solver.solve();
        let first = out.history.records.first().unwrap().objective;
        assert!(out.objective < first, "{first} -> {}", out.objective);
        assert_eq!(out.metrics.shards, 3);
        assert_eq!(out.metrics.iterations, 200);
        assert_eq!(out.w.len(), 20);
    }

    #[test]
    fn shards_clamped_to_columns() {
        // more shards than columns: clamp, drop empties, still solve
        let (x, y) = small_xy(8, 20, 4);
        let out = Solver::builder()
            .matrix(x)
            .labels(y)
            .algorithm(Algorithm::Ccd)
            .shards(9)
            .max_iters(40)
            .max_seconds(20.0)
            .build()
            .unwrap()
            .solve();
        assert_eq!(out.metrics.shards, 4);
        assert!(out.objective.is_finite());
    }
}
