//! Small self-contained substrates the coordinator is built on.
//!
//! This crate builds fully offline; the usual ecosystem crates (`rand`,
//! `parking_lot`, `serde`, …) are replaced by the minimal implementations
//! here. Each submodule is independently unit-tested.

pub mod atomic;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
pub mod timer;
pub mod topo;

pub use atomic::{AtomicF64, SyncCell, SyncF64Vec};
pub use par::{CachePadded, SpinBarrier};
pub use rng::Pcg64;
pub use timer::Timer;

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation of a slice.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Soft-threshold operator `s_tau(x) = sign(x) * max(|x| - tau, 0)`
/// (Sec. 3.1 of the paper).
#[inline(always)]
pub fn soft_threshold(x: f64, tau: f64) -> f64 {
    if x > tau {
        x - tau
    } else if x < -tau {
        x + tau
    } else {
        0.0
    }
}

/// The paper's clipping function `psi(x; a, b)` (Sec. 3.1). Requires a <= b.
#[inline(always)]
pub fn clip_psi(x: f64, a: f64, b: f64) -> f64 {
    x.clamp(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soft_threshold_basic() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }

    #[test]
    fn clip_psi_matches_definition() {
        assert_eq!(clip_psi(0.0, -1.0, 1.0), 0.0);
        assert_eq!(clip_psi(-5.0, -1.0, 1.0), -1.0);
        assert_eq!(clip_psi(5.0, -1.0, 1.0), 1.0);
    }

    #[test]
    fn soft_threshold_equals_clip_form() {
        // s_{lam/beta}(w - g/beta) - w == -psi(w; (g-lam)/beta, (g+lam)/beta)
        let cases = [
            (0.3, -1.2, 0.05, 0.25),
            (-0.7, 0.4, 0.01, 1.0),
            (0.0, 0.0, 0.1, 0.5),
            (2.0, 3.0, 0.5, 0.25),
        ];
        for (w, g, lam, beta) in cases {
            let a = soft_threshold(w - g / beta, lam / beta) - w;
            let b = -clip_psi(w, (g - lam) / beta, (g + lam) / beta);
            assert!((a - b).abs() < 1e-12, "w={w} g={g}: {a} vs {b}");
        }
    }

    #[test]
    fn mean_stddev() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((stddev(&[1.0, 1.0, 1.0])).abs() < 1e-12);
        assert!((stddev(&[0.0, 2.0]) - 1.0).abs() < 1e-12);
    }
}
