//! Parallel substrate for the phase-locked GenCD engine: a
//! sense-reversing spin barrier, cache-line padding, and cache-aligned
//! chunking.
//!
//! # Why not `std::sync::Barrier`
//!
//! The engine separates Select/Propose/Accept/Update with barriers, and
//! on small selections each phase is *sub-microsecond*. A
//! `std::sync::Barrier` takes a mutex and parks/unparks on every
//! crossing (several microseconds of futex round-trips), which makes the
//! barrier — not the math — the per-iteration cost and flattens the
//! Fig. 2 speedup curves. [`SpinBarrier`] keeps arrivals on shared
//! atomics: threads spin (bounded) on a generation word and only fall
//! back to parking when the wait is long (oversubscription, a stalled
//! leader), so the common crossing is tens of nanoseconds.
//!
//! # Barrier protocol and memory ordering
//!
//! The barrier is *sense-reversing via a generation counter*: each
//! crossing has a generation `g`; arrivals increment `count` and the
//! last arriver (the *releaser*) resets `count` and bumps `generation`,
//! releasing the spinners.
//!
//! Ordering argument (this is what lets the engine use plain,
//! non-atomic element accesses between phases — see
//! [`crate::util::atomic::SyncF64Vec`]):
//!
//! * every arriver's `count.fetch_add(1, AcqRel)` makes its pre-barrier
//!   writes part of the release sequence on `count`;
//! * the releaser's own `fetch_add` *reads* the previous arrivals, so it
//!   synchronizes-with every earlier arriver (RMWs continue a release
//!   sequence);
//! * the releaser then stores `generation` with `Release`, and every
//!   spinner loads it with `Acquire`; the resulting happens-before edge
//!   is transitive, so **all writes before any thread's `wait()` are
//!   visible to all threads after it** — exactly OpenMP's implicit
//!   region-barrier semantics.
//!
//! The park fallback re-checks `generation` under a mutex, and the
//! releaser bumps `generation` (SeqCst) *before* testing the sleeper
//! count (SeqCst), so the classic store-buffer lost-wakeup interleaving
//! is excluded: if a sleeper registered before the bump became visible,
//! the releaser observes it and notifies; otherwise the sleeper's
//! re-check under the lock sees the new generation and never parks.
//!
//! A thread can be at most one barrier ahead of its peers (the next
//! crossing cannot complete without everyone), and `generation` only
//! grows, so comparing against the captured generation is sufficient —
//! no ABA.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Spin iterations before a waiter falls back to parking. At ~1-3 ns per
/// `spin_loop` hint this is a handful of microseconds — longer than any
/// healthy phase, shorter than a futex sleep/wake pair.
pub const DEFAULT_SPIN: u32 = 1 << 12;

/// What a [`SpinBarrier::wait_timeout`] crossing resolved to.
///
/// The engine's phase barriers keep using the infallible
/// [`SpinBarrier::wait`] (a poisoned phase barrier is a programming
/// error and panics); the *reconcile* barriers of the shard layer use
/// the timeout variant so a dead or wedged peer pool degrades the solve
/// into a structured error instead of hanging it (see
/// [`crate::shard::engine`] §Failure semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WaitOutcome {
    /// All parties arrived; the payload mirrors [`SpinBarrier::wait`]'s
    /// return — `true` on exactly one thread per crossing.
    Released(bool),
    /// The barrier was [`SpinBarrier::poison`]ed by a dying peer.
    Poisoned,
    /// The timeout elapsed with peers still missing. The waiter poisons
    /// the barrier on its way out, so every other party unblocks with
    /// [`WaitOutcome::Poisoned`] (or a panic from plain `wait`) rather
    /// than waiting for a crossing that can no longer complete.
    TimedOut,
}

/// A reusable sense-reversing barrier with bounded spin and a parking
/// fallback. All parties must call [`SpinBarrier::wait`] for any of them
/// to proceed; the barrier is immediately reusable for the next phase.
pub struct SpinBarrier {
    parties: usize,
    spin_limit: u32,
    /// Arrivals in the current generation.
    count: AtomicUsize,
    /// Completed crossings; spinners wait for this to move.
    generation: AtomicUsize,
    /// Parked waiters (gate for the notify path).
    sleepers: AtomicU32,
    /// Set by [`SpinBarrier::poison`]: a party died (panicked); every
    /// current and future `wait` panics instead of blocking forever.
    poisoned: std::sync::atomic::AtomicBool,
    lock: Mutex<()>,
    cv: Condvar,
}

impl SpinBarrier {
    /// Barrier for `parties` threads with the default spin budget.
    pub fn new(parties: usize) -> Self {
        Self::with_spin(parties, DEFAULT_SPIN)
    }

    /// Barrier with an explicit spin budget; `spin_limit == 0` parks
    /// immediately (degenerates to a classic blocking barrier).
    pub fn with_spin(parties: usize, spin_limit: u32) -> Self {
        assert!(parties >= 1, "barrier needs at least one party");
        Self {
            parties,
            spin_limit,
            count: AtomicUsize::new(0),
            generation: AtomicUsize::new(0),
            sleepers: AtomicU32::new(0),
            poisoned: std::sync::atomic::AtomicBool::new(false),
            lock: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    /// Number of participating threads.
    pub fn parties(&self) -> usize {
        self.parties
    }

    /// Block until all parties have arrived. Returns `true` on exactly
    /// one thread per crossing (the releaser), mirroring
    /// `std::sync::Barrier::wait().is_leader()`.
    ///
    /// Panics if the barrier was [`SpinBarrier::poison`]ed — a party
    /// died, so waiting would deadlock.
    #[inline]
    pub fn wait(&self) -> bool {
        if self.parties == 1 {
            return true;
        }
        self.check_poison();
        let gen = self.generation.load(Ordering::Acquire);
        let arrived = self.count.fetch_add(1, Ordering::AcqRel) + 1;
        if arrived == self.parties {
            // Releaser: everyone else is inside this crossing, so the
            // reset cannot race a next-generation arrival.
            self.count.store(0, Ordering::Relaxed);
            self.generation.fetch_add(1, Ordering::SeqCst);
            if self.sleepers.load(Ordering::SeqCst) > 0 {
                let _guard = self.lock.lock().unwrap();
                self.cv.notify_all();
            }
            return true;
        }
        let mut spins = 0u32;
        while self.generation.load(Ordering::Acquire) == gen {
            self.check_poison();
            if spins < self.spin_limit {
                std::hint::spin_loop();
                spins += 1;
            } else {
                self.park(gen);
                return false;
            }
        }
        false
    }

    /// Like [`SpinBarrier::wait`], but bounded: if the crossing does not
    /// complete within `timeout`, the waiter gives up, **poisons the
    /// barrier** (so its peers escape too instead of waiting for a
    /// party that already left), and returns [`WaitOutcome::TimedOut`].
    /// A barrier poisoned by someone else resolves to
    /// [`WaitOutcome::Poisoned`] instead of panicking.
    ///
    /// The happy path is identical to `wait()` — same atomics, same
    /// release protocol, one extra deadline check every 1024 spins — so
    /// a fault-free crossing costs the same tens of nanoseconds.
    pub fn wait_timeout(&self, timeout: Duration) -> WaitOutcome {
        if self.parties == 1 {
            return WaitOutcome::Released(true);
        }
        if self.poisoned.load(Ordering::Relaxed) {
            return WaitOutcome::Poisoned;
        }
        let deadline = Instant::now() + timeout;
        let gen = self.generation.load(Ordering::Acquire);
        let arrived = self.count.fetch_add(1, Ordering::AcqRel) + 1;
        if arrived == self.parties {
            // Releaser path: identical to wait().
            self.count.store(0, Ordering::Relaxed);
            self.generation.fetch_add(1, Ordering::SeqCst);
            if self.sleepers.load(Ordering::SeqCst) > 0 {
                let _guard = self.lock.lock().unwrap();
                self.cv.notify_all();
            }
            return WaitOutcome::Released(true);
        }
        let mut spins = 0u32;
        while self.generation.load(Ordering::Acquire) == gen {
            if self.poisoned.load(Ordering::Relaxed) {
                return WaitOutcome::Poisoned;
            }
            if spins < self.spin_limit {
                std::hint::spin_loop();
                spins += 1;
                if spins & 0x3FF == 0 && Instant::now() >= deadline {
                    self.poison();
                    return WaitOutcome::TimedOut;
                }
            } else {
                return self.park_timeout(gen, deadline);
            }
        }
        WaitOutcome::Released(false)
    }

    #[cold]
    fn park_timeout(&self, gen: usize, deadline: Instant) -> WaitOutcome {
        let mut guard = self.lock.lock().unwrap();
        self.sleepers.fetch_add(1, Ordering::SeqCst);
        let outcome = loop {
            if self.generation.load(Ordering::SeqCst) != gen {
                break WaitOutcome::Released(false);
            }
            if self.poisoned.load(Ordering::SeqCst) {
                break WaitOutcome::Poisoned;
            }
            let now = Instant::now();
            if now >= deadline {
                // Poison in place: we hold `self.lock`, so calling
                // `poison()` (which takes it) would deadlock.
                self.poisoned.store(true, Ordering::SeqCst);
                self.cv.notify_all();
                break WaitOutcome::TimedOut;
            }
            let (g, _timed_out) =
                self.cv.wait_timeout(guard, deadline - now).unwrap();
            guard = g;
        };
        self.sleepers.fetch_sub(1, Ordering::SeqCst);
        drop(guard);
        outcome
    }

    /// Mark a party as dead and wake every waiter; all pending and
    /// future `wait` calls panic instead of blocking forever. Called
    /// from a drop guard when an engine worker panics, turning a
    /// would-be deadlock into a propagating failure.
    pub fn poison(&self) {
        self.poisoned.store(true, Ordering::SeqCst);
        let _guard = self.lock.lock().unwrap();
        self.cv.notify_all();
    }

    /// Whether [`SpinBarrier::poison`] was called.
    pub fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::SeqCst)
    }

    #[inline]
    fn check_poison(&self) {
        if self.poisoned.load(Ordering::Relaxed) {
            panic!("spin barrier poisoned: a participating thread panicked");
        }
    }

    #[cold]
    fn park(&self, gen: usize) {
        let mut guard = self.lock.lock().unwrap();
        self.sleepers.fetch_add(1, Ordering::SeqCst);
        while self.generation.load(Ordering::SeqCst) == gen
            && !self.poisoned.load(Ordering::SeqCst)
        {
            guard = self.cv.wait(guard).unwrap();
        }
        self.sleepers.fetch_sub(1, Ordering::SeqCst);
        drop(guard);
        self.check_poison();
    }
}

impl std::fmt::Debug for SpinBarrier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SpinBarrier")
            .field("parties", &self.parties)
            .field("spin_limit", &self.spin_limit)
            .field("generation", &self.generation.load(Ordering::Relaxed))
            .finish()
    }
}

/// Pads and aligns a value to 128 bytes — two cache lines, covering the
/// adjacent-line prefetcher on modern x86 — so per-thread slots placed in
/// a `Vec` never share a cache line. This is what keeps the per-thread
/// best-proposal slots and work counters contention-free: without it,
/// eight `u64` counters land on one line and every worker write
/// invalidates every other worker's cache.
#[derive(Clone, Copy, Debug, Default)]
#[repr(align(128))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    pub const fn new(value: T) -> Self {
        Self { value }
    }

    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;

    #[inline(always)]
    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> std::ops::DerefMut for CachePadded<T> {
    #[inline(always)]
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

/// Static contiguous chunk of `0..len` owned by thread `tid` of
/// `threads` — the engine's `schedule(static)` work division over index
/// lists (selected/accepted coordinate sets). The chunks are disjoint
/// and cover `0..len`. This is the *canonical* implementation; the
/// engine re-exports it ([`crate::coordinator::engine::chunk`]) and the
/// shard partitioner's contiguous strategy is built on it. For chunks
/// over dense `f64` arrays that threads *write*, prefer
/// [`aligned_chunk`], which additionally aligns interior boundaries to
/// cache lines.
#[inline]
pub fn chunk(len: usize, tid: usize, threads: usize) -> std::ops::Range<usize> {
    let lo = len * tid / threads;
    let hi = len * (tid + 1) / threads;
    lo..hi
}

/// `f64`s per 128-byte alignment unit (see [`aligned_chunk`]).
pub const F64S_PER_LINE: usize = 16;

/// Static contiguous chunk of `0..len` for thread `tid` of `threads`,
/// with interior boundaries rounded to [`F64S_PER_LINE`]-element
/// multiples so two threads writing adjacent chunks of a dense `f64`
/// array (the residual vector `z`, the `dloss` cache) never false-share
/// the boundary cache line. The chunks are disjoint and cover `0..len`.
pub fn aligned_chunk(len: usize, tid: usize, threads: usize) -> std::ops::Range<usize> {
    if threads <= 1 {
        return 0..len;
    }
    let blocks = len.div_ceil(F64S_PER_LINE);
    let lo = (blocks * tid / threads) * F64S_PER_LINE;
    let hi = (blocks * (tid + 1) / threads) * F64S_PER_LINE;
    lo.min(len)..hi.min(len)
}

/// Per-strip stride (in `f64`s) for a slab holding `threads` dense
/// accumulators of `n` elements each: `n` rounded up to a whole number
/// of 128-byte lines, plus one full guard line. With the slab's element
/// 0 line-aligned ([`crate::util::atomic::SyncF64Vec`]), every strip
/// start is line-aligned and the guard line guarantees the last line
/// one thread writes is never the first line its neighbor writes — the
/// parlaylib-style stride padding [`crate::kernel::BlockedScatter`]
/// uses to kill false sharing between per-thread accumulators.
#[inline]
pub fn padded_stride(n: usize) -> usize {
    (n.div_ceil(F64S_PER_LINE) + 1) * F64S_PER_LINE
}

/// Elements covered by one dirty bit: one [`aligned_chunk`] alignment
/// unit (a 128-byte line of `f64`s), so dirty-chunk boundaries coincide
/// with the reconcile fold's chunk boundaries by construction and no
/// chunk ever straddles two shards' fold ranges.
pub const DIRTY_CHUNK_ELEMS: usize = F64S_PER_LINE;

/// A dirty-chunk bitmap over a dense `f64` array: one bit per
/// [`DIRTY_CHUNK_ELEMS`]-element aligned chunk, set when any element of
/// the chunk was written. This is what turns the shard layer's O(n·S)
/// dense reconcile fold into an O(touched) sparse one
/// ([`crate::shard::engine`] §Reconcile cadence): the engine's Update
/// scatter marks the chunks it writes, and the fold visits only chunks
/// some shard dirtied since the last reconcile.
///
/// Marking is write-write safe across threads (atomic `fetch_or`), and
/// the hot path is a plain load: a chunk that is already dirty — the
/// overwhelmingly common case inside a column scatter — costs one read
/// and a predictable branch, no RMW.
#[derive(Debug)]
pub struct DirtyChunks {
    words: Box<[AtomicU64]>,
    chunks: usize,
}

impl DirtyChunks {
    /// Bitmap for a dense array of `len` elements, all chunks clean.
    pub fn new(len: usize) -> Self {
        let chunks = len.div_ceil(DIRTY_CHUNK_ELEMS);
        Self {
            words: (0..chunks.div_ceil(64)).map(|_| AtomicU64::new(0)).collect(),
            chunks,
        }
    }

    /// Number of chunks tracked.
    #[inline]
    pub fn n_chunks(&self) -> usize {
        self.chunks
    }

    /// Mark element `i`'s chunk dirty. Safe under concurrent markers.
    #[inline(always)]
    pub fn mark(&self, i: usize) {
        let c = i / DIRTY_CHUNK_ELEMS;
        let bit = 1u64 << (c % 64);
        let word = &self.words[c / 64];
        // check-first: repeated hits on a hot chunk stay read-only
        if word.load(Ordering::Relaxed) & bit == 0 {
            word.fetch_or(bit, Ordering::Relaxed);
        }
    }

    /// Whether chunk `c` has been marked since the last clear.
    #[inline(always)]
    pub fn is_dirty(&self, c: usize) -> bool {
        debug_assert!(c < self.chunks);
        self.words[c / 64].load(Ordering::Relaxed) & (1u64 << (c % 64)) != 0
    }

    /// Reset every chunk to clean. Caller must be the map's unique
    /// accessor (the shard layer clears between reconcile barrier
    /// crossings, with every writer parked).
    pub fn clear(&self) {
        for w in self.words.iter() {
            w.store(0, Ordering::Relaxed);
        }
    }

    /// Dirty chunks right now (popcount scan).
    pub fn count(&self) -> usize {
        self.words
            .iter()
            .map(|w| w.load(Ordering::Relaxed).count_ones() as usize)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::atomic::Ordering::Relaxed;

    fn exercise_barrier(threads: usize, rounds: usize, spin: u32) {
        let barrier = SpinBarrier::with_spin(threads, spin);
        let counter = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| {
                    for r in 0..rounds {
                        counter.fetch_add(1, Relaxed);
                        barrier.wait();
                        // every thread's increment for round r is visible
                        let seen = counter.load(Relaxed);
                        assert!(
                            seen >= threads * (r + 1),
                            "round {r}: saw {seen}, expected >= {}",
                            threads * (r + 1)
                        );
                        barrier.wait();
                    }
                });
            }
        });
        assert_eq!(counter.load(Relaxed), threads * rounds);
    }

    #[test]
    fn barrier_synchronizes_spinning() {
        exercise_barrier(4, 200, DEFAULT_SPIN);
    }

    #[test]
    fn barrier_synchronizes_parking() {
        // spin budget 0: every crossing goes through the parking path
        exercise_barrier(4, 50, 0);
    }

    #[test]
    fn barrier_oversubscribed() {
        // more threads than cores on any CI box: the fallback must keep
        // this from livelocking
        exercise_barrier(16, 20, 64);
    }

    #[test]
    fn single_party_is_free() {
        let b = SpinBarrier::new(1);
        for _ in 0..10 {
            assert!(b.wait());
        }
    }

    #[test]
    fn exactly_one_releaser_per_crossing() {
        let threads = 4;
        let barrier = SpinBarrier::new(threads);
        let releasers = AtomicUsize::new(0);
        let rounds = 100;
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| {
                    for _ in 0..rounds {
                        if barrier.wait() {
                            releasers.fetch_add(1, Relaxed);
                        }
                    }
                });
            }
        });
        assert_eq!(releasers.load(Relaxed), rounds);
    }

    #[test]
    fn poison_unblocks_and_panics_waiters() {
        use std::sync::Arc;
        for spin in [DEFAULT_SPIN, 0] {
            // spinning waiter and parked waiter must both panic out
            let b = Arc::new(SpinBarrier::with_spin(2, spin));
            let waiter = {
                let b = b.clone();
                std::thread::spawn(move || b.wait())
            };
            // give the waiter time to reach the spin/park loop
            std::thread::sleep(std::time::Duration::from_millis(20));
            b.poison();
            assert!(waiter.join().is_err(), "waiter should panic, not hang");
            assert!(b.is_poisoned());
            // subsequent waits fail fast
            let b2 = b.clone();
            assert!(
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || b2.wait()))
                    .is_err()
            );
        }
    }

    #[test]
    fn wait_timeout_happy_path_matches_wait() {
        // all parties arrive: exactly one Released(true) per crossing,
        // in both the spinning and the parking regime
        for spin in [DEFAULT_SPIN, 0] {
            let threads = 4;
            let barrier = SpinBarrier::with_spin(threads, spin);
            let releasers = AtomicUsize::new(0);
            std::thread::scope(|scope| {
                for _ in 0..threads {
                    scope.spawn(|| {
                        for _ in 0..50 {
                            match barrier.wait_timeout(std::time::Duration::from_secs(5)) {
                                WaitOutcome::Released(true) => {
                                    releasers.fetch_add(1, Relaxed);
                                }
                                WaitOutcome::Released(false) => {}
                                other => panic!("unexpected outcome {other:?}"),
                            }
                        }
                    });
                }
            });
            assert_eq!(releasers.load(Relaxed), 50);
            assert!(!barrier.is_poisoned());
        }
    }

    #[test]
    fn wait_timeout_dead_peer_times_out_and_poisons() {
        use std::time::Duration;
        // a 2-party barrier where the peer never shows: the waiter must
        // escape with TimedOut (not hang) and leave the barrier poisoned
        // so the late peer fails fast instead of waiting forever
        for spin in [DEFAULT_SPIN, 0] {
            let b = SpinBarrier::with_spin(2, spin);
            let start = std::time::Instant::now();
            let out = b.wait_timeout(Duration::from_millis(50));
            assert_eq!(out, WaitOutcome::TimedOut, "spin={spin}");
            assert!(start.elapsed() < Duration::from_secs(10), "took too long");
            assert!(b.is_poisoned(), "timeout must poison for the peers");
            // the late peer now observes the poison instead of blocking
            assert_eq!(
                b.wait_timeout(Duration::from_secs(5)),
                WaitOutcome::Poisoned
            );
        }
    }

    #[test]
    fn wait_timeout_observes_peer_poison() {
        use std::sync::Arc;
        use std::time::Duration;
        for spin in [DEFAULT_SPIN, 0] {
            let b = Arc::new(SpinBarrier::with_spin(2, spin));
            let waiter = {
                let b = b.clone();
                std::thread::spawn(move || b.wait_timeout(Duration::from_secs(30)))
            };
            std::thread::sleep(Duration::from_millis(20));
            b.poison();
            assert_eq!(waiter.join().unwrap(), WaitOutcome::Poisoned);
        }
    }

    #[test]
    fn wait_timeout_single_party_is_free() {
        let b = SpinBarrier::new(1);
        for _ in 0..10 {
            assert_eq!(
                b.wait_timeout(std::time::Duration::from_nanos(1)),
                WaitOutcome::Released(true)
            );
        }
    }

    #[test]
    fn dirty_chunks_property_vs_model() {
        // 100 seeded random cases: DirtyChunks must agree with a naive
        // model set under arbitrary mark/clear interleavings, and the
        // union of two maps (fold-side view) must match set union.
        // Sizes stay small so the Miri job can afford this test.
        use crate::util::Pcg64;
        let mut rng = Pcg64::seeded(0xD1127);
        for case in 0..100 {
            let len = 1 + rng.below(5 * 64 * DIRTY_CHUNK_ELEMS);
            let d = DirtyChunks::new(len);
            let mut model: std::collections::BTreeSet<usize> =
                std::collections::BTreeSet::new();
            let ops = 1 + rng.below(60);
            for _ in 0..ops {
                match rng.below(10) {
                    0 => {
                        d.clear();
                        model.clear();
                    }
                    _ => {
                        let i = rng.below(len);
                        d.mark(i);
                        model.insert(i / DIRTY_CHUNK_ELEMS);
                    }
                }
            }
            assert_eq!(d.count(), model.len(), "case {case} len {len}");
            for c in 0..d.n_chunks() {
                assert_eq!(
                    d.is_dirty(c),
                    model.contains(&c),
                    "case {case} chunk {c}"
                );
            }
            // idempotent re-mark never changes the count
            if let Some(&c) = model.iter().next() {
                d.mark(c * DIRTY_CHUNK_ELEMS);
                assert_eq!(d.count(), model.len());
            }
            // union across two maps == set union (what the reconcile
            // fold computes when it visits "dirty in any shard" chunks)
            let d2 = DirtyChunks::new(len);
            let mut model2 = model.clone();
            for _ in 0..rng.below(20) {
                let i = rng.below(len);
                d2.mark(i);
                model2.insert(i / DIRTY_CHUNK_ELEMS);
            }
            let union_count = (0..d.n_chunks())
                .filter(|&c| d.is_dirty(c) || d2.is_dirty(c))
                .count();
            assert_eq!(union_count, model2.len(), "case {case} union");
        }
    }

    #[test]
    fn cache_padded_layout() {
        assert_eq!(std::mem::align_of::<CachePadded<u8>>(), 128);
        assert_eq!(std::mem::size_of::<CachePadded<u64>>(), 128);
        let v: Vec<CachePadded<u64>> = vec![CachePadded::new(1), CachePadded::new(2)];
        let a = &*v[0] as *const u64 as usize;
        let b = &*v[1] as *const u64 as usize;
        assert!(b - a >= 128, "slots {a:x} and {b:x} share a line");
        assert_eq!(*v[0] + *v[1], 3);
    }

    #[test]
    fn chunks_partition() {
        for len in [0usize, 1, 7, 16, 100, 1023] {
            for threads in [1usize, 2, 3, 5, 8] {
                let mut prev_hi = 0usize;
                let mut covered = 0usize;
                for tid in 0..threads {
                    let r = chunk(len, tid, threads);
                    assert_eq!(r.start, prev_hi, "len={len} t={threads} tid={tid}");
                    covered += r.len();
                    prev_hi = r.end;
                }
                assert_eq!(prev_hi, len);
                assert_eq!(covered, len);
            }
        }
    }

    #[test]
    fn dirty_chunks_mark_clear_count() {
        // 100 elements -> 7 chunks (16 elems each, last partial)
        let d = DirtyChunks::new(100);
        assert_eq!(d.n_chunks(), 7);
        assert_eq!(d.count(), 0);
        d.mark(0);
        d.mark(15); // same chunk
        d.mark(16); // next chunk
        d.mark(99); // last, partial chunk
        assert_eq!(d.count(), 3);
        assert!(d.is_dirty(0) && d.is_dirty(1) && d.is_dirty(6));
        assert!(!d.is_dirty(2));
        d.clear();
        assert_eq!(d.count(), 0);
        assert!(!d.is_dirty(0));
        // > 64 chunks exercises the multi-word path
        let big = DirtyChunks::new(64 * DIRTY_CHUNK_ELEMS * 3);
        big.mark(64 * DIRTY_CHUNK_ELEMS); // first chunk of word 1
        assert!(big.is_dirty(64));
        assert!(!big.is_dirty(63));
        assert_eq!(big.count(), 1);
    }

    #[test]
    fn dirty_chunks_concurrent_marks_lose_nothing() {
        let d = std::sync::Arc::new(DirtyChunks::new(64 * DIRTY_CHUNK_ELEMS));
        std::thread::scope(|scope| {
            for t in 0..4usize {
                let d = d.clone();
                scope.spawn(move || {
                    for c in (t..64).step_by(4) {
                        d.mark(c * DIRTY_CHUNK_ELEMS);
                    }
                });
            }
        });
        assert_eq!(d.count(), 64, "concurrent fetch_or marks must all land");
    }

    #[test]
    fn aligned_chunks_partition() {
        for len in [0usize, 1, 15, 16, 17, 100, 1000, 1024] {
            for threads in [1usize, 2, 3, 4, 7, 8] {
                let mut covered = 0usize;
                let mut prev_hi = 0usize;
                for tid in 0..threads {
                    let r = aligned_chunk(len, tid, threads);
                    assert_eq!(r.start, prev_hi, "len={len} t={threads} tid={tid}");
                    if threads > 1 && r.start < len {
                        assert_eq!(r.start % F64S_PER_LINE, 0);
                    }
                    covered += r.len();
                    prev_hi = r.end;
                }
                assert_eq!(prev_hi, len);
                assert_eq!(covered, len);
            }
        }
    }

    #[test]
    fn padded_stride_is_line_aligned_with_guard() {
        for n in [0usize, 1, 15, 16, 17, 100, 1000, 1024] {
            let s = padded_stride(n);
            assert_eq!(s % F64S_PER_LINE, 0, "n={n}");
            // room for the data plus at least one full guard line
            assert!(s >= n + F64S_PER_LINE, "n={n} stride={s}");
            assert!(s < n + 2 * F64S_PER_LINE + 1, "n={n} stride={s}");
        }
    }
}
