//! Wall-clock timing helpers for the solver's convergence log and the
//! bench harness.

use std::time::{Duration, Instant};

/// A restartable stopwatch.
#[derive(Clone, Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    /// Seconds elapsed since start.
    #[inline]
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn restart(&mut self) {
        self.start = Instant::now();
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

/// Run `f` repeatedly until `min_time` has elapsed and at least
/// `min_iters` runs happened; returns per-run seconds (best, mean).
/// This is the criterion-less micro-bench primitive used by `benches/`.
pub fn bench_loop(min_time: f64, min_iters: usize, mut f: impl FnMut()) -> BenchStats {
    // warmup
    f();
    let mut times = Vec::new();
    let total = Timer::start();
    while times.len() < min_iters || total.elapsed_secs() < min_time {
        let t = Timer::start();
        f();
        times.push(t.elapsed_secs());
        if times.len() > 10_000_000 {
            break;
        }
    }
    let best = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let mean = crate::util::mean(&times);
    let sd = crate::util::stddev(&times);
    BenchStats {
        iters: times.len(),
        best,
        mean,
        stddev: sd,
    }
}

/// Summary statistics from [`bench_loop`].
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub iters: usize,
    pub best: f64,
    pub mean: f64,
    pub stddev: f64,
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "best {:>10.3?}us mean {:>10.3?}us (+-{:.3}us) over {} iters",
            self.best * 1e6,
            self.mean * 1e6,
            self.stddev * 1e6,
            self.iters
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.elapsed_secs();
        let b = t.elapsed_secs();
        assert!(b >= a && a >= 0.0);
    }

    #[test]
    fn timed_returns_result() {
        let (v, s) = timed(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn bench_loop_runs_min_iters() {
        let mut count = 0usize;
        let stats = bench_loop(0.0, 5, || count += 1);
        assert!(stats.iters >= 5);
        assert!(count >= 6); // warmup + timed runs
        assert!(stats.best <= stats.mean + 1e-12);
    }
}
