//! NUMA topology discovery and thread pinning — the substrate of the
//! sharded layer's `numa_pin` mode ([`crate::shard::engine`] §NUMA).
//!
//! On Linux the topology is read from `/sys/devices/system/node/node*/
//! cpulist`; everywhere else (and on machines without the sysfs tree)
//! detection degrades to a single node spanning every CPU, which makes
//! pinning a graceful no-op. Pinning itself is one `sched_setaffinity`
//! call on the *current* thread; spawned threads inherit the caller's
//! affinity mask, which is exactly what the shard layer relies on: pin
//! the shard's leader thread before its pool workers are spawned and
//! the whole pool lands on the node.
//!
//! No `libc` dependency: the crate builds fully offline, so the one
//! syscall wrapper is declared as a raw `extern "C"` item (glibc/musl
//! both export it) and compiled only on Linux.

/// One NUMA node: its sysfs id and the CPUs it owns.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NumaNode {
    pub id: usize,
    pub cpus: Vec<usize>,
}

/// The machine's NUMA layout as far as pinning is concerned: one entry
/// per node, ascending by id. A single-entry topology means pinning has
/// nothing to separate and callers should skip it.
#[derive(Clone, Debug)]
pub struct Topology {
    pub nodes: Vec<NumaNode>,
}

impl Topology {
    /// Detect the host topology: sysfs on Linux, single-node fallback
    /// elsewhere or when the tree is missing/garbled.
    pub fn detect() -> Topology {
        if cfg!(target_os = "linux") {
            if let Some(t) =
                Self::from_sysfs(std::path::Path::new("/sys/devices/system/node"))
            {
                return t;
            }
        }
        Self::single_node()
    }

    /// One node spanning every schedulable CPU — the graceful-fallback
    /// topology (pinning to it is a no-op by construction).
    pub fn single_node() -> Topology {
        let ncpus = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        Topology {
            nodes: vec![NumaNode {
                id: 0,
                cpus: (0..ncpus).collect(),
            }],
        }
    }

    /// Parse a sysfs node tree (`node<N>/cpulist` files). Split out from
    /// [`detect`](Self::detect) and path-parameterized so tests can
    /// exercise it against a fabricated tree. Returns `None` when the
    /// directory is unreadable or yields no node with any CPU.
    pub fn from_sysfs(dir: &std::path::Path) -> Option<Topology> {
        let entries = std::fs::read_dir(dir).ok()?;
        let mut nodes = Vec::new();
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let id: usize = match name.strip_prefix("node").map(str::parse) {
                Some(Ok(id)) => id,
                _ => continue, // not a node<N> entry — skip, don't abort
            };
            let Ok(cpulist) = std::fs::read_to_string(entry.path().join("cpulist"))
            else {
                continue;
            };
            let cpus = parse_cpulist(cpulist.trim());
            if !cpus.is_empty() {
                nodes.push(NumaNode { id, cpus });
            }
        }
        if nodes.is_empty() {
            return None;
        }
        nodes.sort_by_key(|n| n.id);
        Some(Topology { nodes })
    }

    /// Number of nodes.
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// The node shard `s` is assigned to: round-robin over nodes, so
    /// shard counts above the node count still spread evenly.
    pub fn node_for_shard(&self, s: usize) -> &NumaNode {
        &self.nodes[s % self.nodes.len()]
    }

    /// Pin the current thread to node `idx`'s CPUs. Returns `false` on
    /// non-Linux hosts, for an out-of-range node, or when the syscall
    /// fails (e.g. a cgroup that disallows every listed CPU).
    pub fn pin_thread_to_node(&self, idx: usize) -> bool {
        match self.nodes.get(idx) {
            Some(node) => pin_current_thread(&node.cpus),
            None => false,
        }
    }
}

/// Parse the kernel's cpulist format (`"0-3,8,10-11"`) into explicit CPU
/// ids. Malformed fragments are skipped rather than failing the whole
/// list — a best-effort read of a best-effort interface.
pub fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        match part.split_once('-') {
            Some((lo, hi)) => {
                if let (Ok(lo), Ok(hi)) = (lo.trim().parse(), hi.trim().parse::<usize>()) {
                    if lo <= hi && hi - lo < 4096 {
                        out.extend(lo..=hi);
                    }
                }
            }
            None => {
                if let Ok(c) = part.parse() {
                    out.push(c);
                }
            }
        }
    }
    out.sort_unstable();
    out.dedup();
    out
}

/// Restrict the current thread (and, by inheritance, any thread it
/// spawns afterwards) to the given CPUs via `sched_setaffinity`.
/// Returns `true` on success. CPUs above the fixed 1024-bit mask are
/// ignored; an empty effective mask fails fast. Always `false` off
/// Linux — callers treat that as "pinning unavailable", not an error.
#[cfg(target_os = "linux")]
pub fn pin_current_thread(cpus: &[usize]) -> bool {
    const MASK_BITS: usize = 1024;
    let mut mask = [0u64; MASK_BITS / 64];
    let mut any = false;
    for &c in cpus {
        if c < MASK_BITS {
            mask[c / 64] |= 1 << (c % 64);
            any = true;
        }
    }
    if !any {
        return false;
    }
    extern "C" {
        // glibc/musl prototype; pid 0 targets the calling thread
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    // SAFETY: the mask buffer outlives the call and its size is passed
    // explicitly; the syscall has no other memory effects.
    unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
}

/// Non-Linux stub: pinning is unavailable, never an error.
#[cfg(not(target_os = "linux"))]
pub fn pin_current_thread(_cpus: &[usize]) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpulist_parses_ranges_and_singles() {
        assert_eq!(parse_cpulist("0-3,8,10-11"), vec![0, 1, 2, 3, 8, 10, 11]);
        assert_eq!(parse_cpulist("5"), vec![5]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        // malformed fragments are skipped, not fatal
        assert_eq!(parse_cpulist("x,2,3-1,4"), vec![2, 4]);
        // duplicates collapse
        assert_eq!(parse_cpulist("1,1-2"), vec![1, 2]);
    }

    #[test]
    fn sysfs_tree_parsed_and_sorted() {
        let dir = std::env::temp_dir().join(format!("gencd_topo_{}", std::process::id()));
        for (node, list) in [("node1", "4-7"), ("node0", "0-3"), ("has_cpu", "")] {
            std::fs::create_dir_all(dir.join(node)).unwrap();
            if !list.is_empty() {
                std::fs::write(dir.join(node).join("cpulist"), list).unwrap();
            }
        }
        let t = Topology::from_sysfs(&dir).expect("fabricated tree must parse");
        assert_eq!(t.n_nodes(), 2);
        assert_eq!(t.nodes[0].id, 0);
        assert_eq!(t.nodes[0].cpus, vec![0, 1, 2, 3]);
        assert_eq!(t.nodes[1].cpus, vec![4, 5, 6, 7]);
        // round-robin shard assignment wraps
        assert_eq!(t.node_for_shard(0).id, 0);
        assert_eq!(t.node_for_shard(3).id, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn detect_always_yields_a_node() {
        let t = Topology::detect();
        assert!(t.n_nodes() >= 1);
        assert!(!t.nodes[0].cpus.is_empty());
    }

    #[test]
    fn pinning_is_graceful() {
        // empty set: refused everywhere
        assert!(!pin_current_thread(&[]));
        // a full 1024-CPU mask intersects any cgroup's allowed set, so
        // on Linux this must succeed (and does not actually restrict
        // the test thread); elsewhere the stub reports unavailable
        let all: Vec<usize> = (0..1024).collect();
        let ok = pin_current_thread(&all);
        assert_eq!(ok, cfg!(target_os = "linux"));
    }
}
