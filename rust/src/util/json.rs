//! Minimal JSON parser (offline stand-in for `serde_json`), used to read
//! the AOT artifact manifest written by `python/compile/aot.py`.
//!
//! Full JSON value grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null); no serialization beyond what the manifest
//! needs.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|f| *f >= 0.0 && f.fract() == 0.0).map(|f| f as usize)
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Parse a complete JSON document.
pub fn parse(input: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    anyhow::ensure!(p.pos == p.bytes.len(), "trailing garbage at byte {}", p.pos);
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> anyhow::Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        let got = self.bump()?;
        anyhow::ensure!(
            got == b,
            "expected '{}' at byte {}, got '{}'",
            b as char,
            self.pos - 1,
            got as char
        );
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::String(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        anyhow::ensure!(
            self.bytes[self.pos..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.pos
        );
        self.pos += word.len();
        Ok(v)
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => break,
                c => anyhow::bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
        Ok(Json::Object(map))
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => break,
                c => anyhow::bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
        Ok(Json::Array(items))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                b'"' => break,
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                        }
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| anyhow::anyhow!("bad codepoint"))?,
                        );
                    }
                    c => anyhow::bail!("bad escape '\\{}'", c as char),
                },
                c if c < 0x20 => anyhow::bail!("control char in string"),
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        self.pos = start + len;
                        anyhow::ensure!(self.pos <= self.bytes.len(), "truncated utf8");
                        out.push_str(
                            std::str::from_utf8(&self.bytes[start..self.pos])
                                .map_err(|e| anyhow::anyhow!("bad utf8: {e}"))?,
                        );
                    }
                }
            }
        }
        Ok(out)
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = text
            .parse()
            .map_err(|e| anyhow::anyhow!("bad number '{text}': {e}"))?;
        Ok(Json::Number(n))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = parse(
            r#"{
              "format": 1,
              "scalars": ["lam", "beta", "inv_n"],
              "entries": [
                {"kind": "propose", "loss": "logistic", "n": 1024, "b": 16,
                 "file": "p.hlo.txt", "input_shapes": [[1024, 16], [1024], [3]]}
              ]
            }"#,
        )
        .unwrap();
        assert_eq!(doc.get("format").unwrap().as_usize(), Some(1));
        let entries = doc.get("entries").unwrap().as_array().unwrap();
        assert_eq!(entries[0].get("loss").unwrap().as_str(), Some("logistic"));
        assert_eq!(entries[0].get("n").unwrap().as_usize(), Some(1024));
        let shapes = entries[0].get("input_shapes").unwrap().as_array().unwrap();
        assert_eq!(shapes[0].as_array().unwrap()[1].as_usize(), Some(16));
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("-1.5e3").unwrap(), Json::Number(-1500.0));
        assert_eq!(
            parse(r#""a\nb\t\"c\" A""#).unwrap(),
            Json::String("a\nb\t\"c\" A".into())
        );
        assert_eq!(parse(r#""héllo""#).unwrap(), Json::String("héllo".into()));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Array(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Object(Default::default()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(parse("3.5").unwrap().as_usize(), None);
        assert_eq!(parse("-2").unwrap().as_usize(), None);
        assert_eq!(parse("7").unwrap().as_usize(), Some(7));
    }
}
