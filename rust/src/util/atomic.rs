//! Atomic `f64` — the Rust analogue of OpenMP's `#pragma omp atomic` on a
//! `double`, which the paper uses for the shared fitted-value vector `z`
//! (Algorithm 3) and which we additionally use for `w`, `delta`, `phi` so
//! stale cross-thread reads are well-defined rather than UB.

use std::sync::atomic::{AtomicU64, Ordering};

/// An `f64` supporting atomic load/store/fetch-add via `AtomicU64` bit
/// casting. `fetch_add` is a CAS loop, exactly what `omp atomic` compiles
/// to for floating-point addition on x86.
#[derive(Debug)]
pub struct AtomicF64(AtomicU64);

impl AtomicF64 {
    #[inline]
    pub fn new(v: f64) -> Self {
        Self(AtomicU64::new(v.to_bits()))
    }

    #[inline]
    pub fn load(&self, order: Ordering) -> f64 {
        f64::from_bits(self.0.load(order))
    }

    #[inline]
    pub fn store(&self, v: f64, order: Ordering) {
        self.0.store(v.to_bits(), order);
    }

    /// Atomically add `v`; returns the previous value.
    #[inline]
    pub fn fetch_add(&self, v: f64, order: Ordering) -> f64 {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + v).to_bits();
            match self
                .0
                .compare_exchange_weak(cur, new, order, Ordering::Relaxed)
            {
                Ok(prev) => return f64::from_bits(prev),
                Err(next) => cur = next,
            }
        }
    }
}

impl Default for AtomicF64 {
    fn default() -> Self {
        Self::new(0.0)
    }
}

impl Clone for AtomicF64 {
    fn clone(&self) -> Self {
        Self::new(self.load(Ordering::Relaxed))
    }
}

/// Allocate a vector of atomic zeros (the shared arrays of Table 1).
pub fn atomic_vec(len: usize) -> Vec<AtomicF64> {
    (0..len).map(|_| AtomicF64::new(0.0)).collect()
}

/// Snapshot an atomic vector into a plain `Vec<f64>` (Relaxed loads).
pub fn snapshot(xs: &[AtomicF64]) -> Vec<f64> {
    xs.iter().map(|x| x.load(Ordering::Relaxed)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering::Relaxed;

    #[test]
    fn load_store_roundtrip() {
        let a = AtomicF64::new(1.5);
        assert_eq!(a.load(Relaxed), 1.5);
        a.store(-2.25, Relaxed);
        assert_eq!(a.load(Relaxed), -2.25);
        // NaN and infinities round-trip bit-exactly
        a.store(f64::NEG_INFINITY, Relaxed);
        assert_eq!(a.load(Relaxed), f64::NEG_INFINITY);
        a.store(f64::NAN, Relaxed);
        assert!(a.load(Relaxed).is_nan());
    }

    #[test]
    fn fetch_add_returns_previous() {
        let a = AtomicF64::new(1.0);
        assert_eq!(a.fetch_add(2.0, Relaxed), 1.0);
        assert_eq!(a.load(Relaxed), 3.0);
    }

    #[test]
    fn concurrent_fetch_add_loses_nothing() {
        // The exact property the paper relies on for z updates: with
        // atomic adds, concurrent column updates never lose increments.
        let a = std::sync::Arc::new(AtomicF64::new(0.0));
        let threads = 8;
        let per = 10_000;
        let mut handles = Vec::new();
        for _ in 0..threads {
            let a = a.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..per {
                    a.fetch_add(1.0, Relaxed);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.load(Relaxed), (threads * per) as f64);
    }

    #[test]
    fn snapshot_copies() {
        let v = atomic_vec(4);
        v[2].store(7.0, Relaxed);
        assert_eq!(snapshot(&v), vec![0.0, 0.0, 7.0, 0.0]);
    }
}
