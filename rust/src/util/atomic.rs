//! Shared numeric state for the phase-locked engine: an atomic `f64`
//! (the analogue of OpenMP's `#pragma omp atomic` on a `double`, used for
//! the colliding `z` scatters of Algorithm 3) and [`SyncF64Vec`] /
//! [`SyncCell`], which expose the *unsynchronized* views the engine's
//! unique-writer-per-phase protocol makes legal.
//!
//! The seed implementation typed every shared array `Vec<AtomicF64>`,
//! which forced an atomic-typed load/store on every element touch even
//! in phases where no concurrent writer exists (Propose reading `w` /
//! `dloss` / `z`, writing `delta` / `phi`). The protocol — phases
//! separated by barriers, each element having a unique writer within a
//! phase, the barrier providing the happens-before edge (see
//! [`crate::util::par`]) — means plain accesses are race-free there, and
//! the atomic view is only needed where writers can genuinely collide:
//! the CAS `fetch_add` path of the Update phase.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};

/// An `f64` supporting atomic load/store/fetch-add via `AtomicU64` bit
/// casting. `fetch_add` is a CAS loop, exactly what `omp atomic` compiles
/// to for floating-point addition on x86.
///
/// `repr(transparent)` is load-bearing: [`SyncF64Vec::atomic`] reinterprets
/// an `UnsafeCell<f64>` as an `AtomicF64`, which is sound only because
/// this is layout-identical to `AtomicU64`, which is layout-identical to
/// `u64`/`f64` (same size and alignment, per the std guarantees).
#[derive(Debug)]
#[repr(transparent)]
pub struct AtomicF64(AtomicU64);

impl AtomicF64 {
    #[inline]
    pub fn new(v: f64) -> Self {
        Self(AtomicU64::new(v.to_bits()))
    }

    #[inline]
    pub fn load(&self, order: Ordering) -> f64 {
        f64::from_bits(self.0.load(order))
    }

    #[inline]
    pub fn store(&self, v: f64, order: Ordering) {
        self.0.store(v.to_bits(), order);
    }

    /// Atomically add `v`; returns the previous value.
    #[inline]
    pub fn fetch_add(&self, v: f64, order: Ordering) -> f64 {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + v).to_bits();
            match self
                .0
                .compare_exchange_weak(cur, new, order, Ordering::Relaxed)
            {
                Ok(prev) => return f64::from_bits(prev),
                Err(next) => cur = next,
            }
        }
    }
}

impl Default for AtomicF64 {
    fn default() -> Self {
        Self::new(0.0)
    }
}

impl Clone for AtomicF64 {
    fn clone(&self) -> Self {
        Self::new(self.load(Ordering::Relaxed))
    }
}

/// Allocate a vector of atomic zeros (the shared arrays of Table 1).
pub fn atomic_vec(len: usize) -> Vec<AtomicF64> {
    (0..len).map(|_| AtomicF64::new(0.0)).collect()
}

/// Snapshot an atomic vector into a plain `Vec<f64>` (Relaxed loads).
pub fn snapshot(xs: &[AtomicF64]) -> Vec<f64> {
    xs.iter().map(|x| x.load(Ordering::Relaxed)).collect()
}

/// A fixed-length shared `f64` array offering both **plain** and
/// **atomic** element access to the same memory.
///
/// This is the storage behind [`crate::coordinator::problem::SharedState`].
/// The engine's protocol (phases separated by barriers; within a phase
/// every element has a unique writer, and no element is plainly read
/// while another thread writes it) makes the plain accessors race-free
/// in their intended call sites; the barrier's acquire/release edges
/// (see [`crate::util::par`]) publish each phase's writes to the next.
/// The atomic view ([`Self::atomic`], also reachable by indexing) is for
/// the one genuinely colliding access pattern — concurrent `z` scatters
/// in the Update phase — and for out-of-engine callers that want
/// conservatively well-defined access.
///
/// Mixing the two views is sound as long as a plain access never races
/// an atomic *write* to the same element; the engine guarantees this by
/// construction (plain reads of `z` happen in phases with no `z` writer,
/// and the Update phase picks exactly one write discipline per
/// iteration).
///
/// Misusing the plain accessors concurrently *is* a data race (UB) —
/// this type is an engine-internal contract, not a general-purpose
/// container, which is why it lives next to the engine rather than in a
/// public concurrency toolkit.
///
/// Element 0 is placed on a 128-byte boundary (the slab is
/// over-allocated by up to [`crate::util::par::F64S_PER_LINE`] - 1
/// elements and an aligned start offset is chosen), so
/// [`crate::util::par::aligned_chunk`]'s 16-element boundaries land on
/// cache lines *by construction* — the no-false-sharing property does
/// not depend on what the allocator happened to return.
#[derive(Debug)]
pub struct SyncF64Vec {
    cells: Box<[UnsafeCell<f64>]>,
    /// Index of the 128-byte-aligned element the logical vector starts
    /// at (0..16).
    offset: usize,
    len: usize,
}

// SAFETY: access discipline is delegated to the unique-writer protocol
// documented above; the type itself only hands out raw f64 slots.
unsafe impl Send for SyncF64Vec {}
unsafe impl Sync for SyncF64Vec {}

impl SyncF64Vec {
    /// Allocate `len` zeros (the shared arrays of Table 1), with
    /// element 0 on a 128-byte boundary.
    pub fn zeros(len: usize) -> Self {
        const ALIGN_ELEMS: usize = 16; // 128 bytes / 8
        let raw = len + ALIGN_ELEMS - 1;
        let cells: Box<[UnsafeCell<f64>]> =
            (0..raw).map(|_| UnsafeCell::new(0.0)).collect();
        let base = cells.as_ptr() as usize;
        debug_assert_eq!(base % 8, 0);
        let offset = (base.wrapping_neg() % 128) / 8;
        debug_assert!(offset < ALIGN_ELEMS);
        Self { cells, offset, len }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline(always)]
    fn cell(&self, i: usize) -> &UnsafeCell<f64> {
        assert!(i < self.len, "index {i} out of bounds (len {})", self.len);
        &self.cells[self.offset + i]
    }

    /// Plain (non-atomic) read. Caller must ensure no concurrent writer.
    #[inline(always)]
    pub fn get(&self, i: usize) -> f64 {
        unsafe { *self.cell(i).get() }
    }

    /// Plain (non-atomic) write. Caller must be the element's unique
    /// accessor for the current phase.
    #[inline(always)]
    pub fn set(&self, i: usize, v: f64) {
        unsafe { *self.cell(i).get() = v }
    }

    /// Plain read-modify-write `x[i] += v` (no CAS). Same contract as
    /// [`Self::set`].
    #[inline(always)]
    pub fn add(&self, i: usize, v: f64) {
        unsafe { *self.cell(i).get() += v }
    }

    /// Atomic view of element `i` (for colliding writers: the CAS
    /// `fetch_add` Update path). Also available as `vec[i]` via `Index`.
    #[inline(always)]
    pub fn atomic(&self, i: usize) -> &AtomicF64 {
        // SAFETY: AtomicF64 is repr(transparent) over AtomicU64, which
        // has the same size, alignment and in-memory representation as
        // u64 and hence f64; the reference inherits &self's lifetime.
        unsafe { &*(self.cell(i).get() as *const AtomicF64) }
    }

    /// Copy out into a plain vector (plain reads; callers hold the same
    /// no-concurrent-writer obligation as [`Self::get`]).
    pub fn snapshot(&self) -> Vec<f64> {
        (0..self.len()).map(|i| self.get(i)).collect()
    }

    /// Borrow the whole array as a plain `&[f64]` — the zero-cost view
    /// the unrolled gather kernels ([`CscMatrix::dot_col_fast`]) need:
    /// per-element [`Self::get`] carries a bounds check the optimizer
    /// cannot always hoist out of a 4-way-unrolled loop.
    ///
    /// # Safety
    ///
    /// The caller must guarantee **no write of any element** (plain or
    /// atomic) overlaps the returned slice's lifetime — the same phase
    /// contract as [`Self::get`], extended from one element to all of
    /// them. The engine uses this only inside phases where the array has
    /// no writer (e.g. `dloss` during Propose/screen), with the slice
    /// scoped to a single kernel call.
    ///
    /// [`CscMatrix::dot_col_fast`]: crate::sparse::CscMatrix::dot_col_fast
    #[inline(always)]
    pub unsafe fn plain_slice(&self) -> &[f64] {
        // UnsafeCell::raw_get keeps the whole-slab provenance while
        // unwrapping the cell type (repr(transparent) over f64)
        std::slice::from_raw_parts(
            UnsafeCell::raw_get(self.cells.as_ptr().add(self.offset)),
            self.len,
        )
    }

    /// Mutable variant of [`Self::plain_slice`] for slice-shaped
    /// kernels ([`CscMatrix::axpy_col_fast`]).
    ///
    /// # Safety
    ///
    /// The caller must be the array's **unique accessor** (no other
    /// read or write, plain or atomic, on any thread) for the slice's
    /// lifetime — handing overlapping mutable slices to two threads
    /// would be instant UB even on disjoint indices, which is exactly
    /// why the engine's conflict-free scatter uses [`Self::raw_ptr`]
    /// instead (raw stores carry no aliasing claim).
    ///
    /// [`CscMatrix::axpy_col_fast`]: crate::sparse::CscMatrix::axpy_col_fast
    #[allow(clippy::mut_from_ref)]
    #[inline(always)]
    pub unsafe fn plain_slice_mut(&self) -> &mut [f64] {
        std::slice::from_raw_parts_mut(
            UnsafeCell::raw_get(self.cells.as_ptr().add(self.offset)),
            self.len,
        )
    }

    /// Raw pointer to element 0 — the escape hatch for kernels that are
    /// *index-disjoint* across threads but cannot use
    /// [`Self::plain_slice_mut`] (two threads holding overlapping
    /// `&mut [f64]` is UB even when the indices they touch are
    /// disjoint; raw-pointer stores are not). The pointer itself is
    /// safe to obtain; every dereference carries the same
    /// unique-writer-per-element phase contract as [`Self::set`]. Used
    /// by the conflict-free fast scatter
    /// ([`CscMatrix::axpy_col_fast_ptr`]), where COLORING's color
    /// classes guarantee element-disjoint writers.
    ///
    /// [`CscMatrix::axpy_col_fast_ptr`]: crate::sparse::CscMatrix::axpy_col_fast_ptr
    #[inline(always)]
    pub fn raw_ptr(&self) -> *mut f64 {
        // SAFETY of the pointer arithmetic: offset < cells.len() by
        // construction; raw_get keeps whole-slab provenance
        unsafe { UnsafeCell::raw_get(self.cells.as_ptr().add(self.offset)) }
    }

    /// Overwrite from a slice (lengths must match).
    pub fn copy_from(&self, src: &[f64]) {
        assert_eq!(src.len(), self.len(), "length mismatch");
        for (i, &v) in src.iter().enumerate() {
            self.set(i, v);
        }
    }

    /// Set every element to `v`.
    pub fn fill(&self, v: f64) {
        for i in 0..self.len() {
            self.set(i, v);
        }
    }
}

/// Measured cost ratio of a CAS `fetch_add` versus a plain `+=` store on
/// this machine — the input to the engine's fitted `Auto` update-path
/// switch (ROADMAP item: replace the fixed `|J'|·nnz >= n` rule with a
/// calibrated constant).
///
/// Runs a ~100 µs micro-benchmark on first call (a scatter over a
/// 4096-element [`SyncF64Vec`] through each access discipline) and
/// caches the result for the process, so repeated solves (lambda paths,
/// benches) pay the measurement once. The measurement is
/// single-threaded, i.e. *uncontended* CAS cost; under real contention
/// CAS only gets worse, so a switch threshold derived from this ratio is
/// conservative in buffered mode's favor. Returns a value clamped to
/// `[1.0, 64.0]` (a CAS is never cheaper than a plain store; absurd
/// readings on noisy machines are capped).
pub fn cas_plain_ratio() -> f64 {
    static RATIO: std::sync::OnceLock<f64> = std::sync::OnceLock::new();
    *RATIO.get_or_init(measure_cas_plain_ratio)
}

fn measure_cas_plain_ratio() -> f64 {
    const LEN: usize = 4096;
    let v = SyncF64Vec::zeros(LEN);
    // ns per element-op of one full pass, best of `passes` (best-of
    // filters scheduler noise, like the hotpath bench's bench_loop)
    let time_passes = |cas: bool| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..8 {
            let t0 = std::time::Instant::now();
            if cas {
                for i in 0..LEN {
                    v[i].fetch_add(1e-12, Ordering::Relaxed);
                }
            } else {
                for i in 0..LEN {
                    v.add(i, 1e-12);
                }
            }
            let ns = t0.elapsed().as_nanos() as f64 / LEN as f64;
            if ns < best {
                best = ns;
            }
        }
        best
    };
    // one warm pass each (page the slab in), then measure
    time_passes(false);
    time_passes(true);
    let plain = time_passes(false).max(1e-3);
    let cas = time_passes(true);
    (cas / plain).clamp(1.0, 64.0)
}

impl std::ops::Index<usize> for SyncF64Vec {
    type Output = AtomicF64;

    /// Atomic element view, so `state.z[i].fetch_add(..)` keeps reading
    /// like the paper's `#pragma omp atomic`.
    #[inline(always)]
    fn index(&self, i: usize) -> &AtomicF64 {
        self.atomic(i)
    }
}

/// A `Cell` that is `Sync`: a single value writable through `&self` with
/// plain (non-atomic) accesses, for per-thread slots governed by the
/// same unique-writer-per-phase protocol as [`SyncF64Vec`] (each worker
/// writes only its own slot during a phase; the leader reads them all in
/// the following phase, after the barrier). Pair with
/// [`crate::util::par::CachePadded`] to keep slots off shared lines.
#[derive(Debug, Default)]
pub struct SyncCell<T>(UnsafeCell<T>);

// SAFETY: as for SyncF64Vec — the unique-writer protocol, not the type,
// excludes conflicting concurrent access.
unsafe impl<T: Send> Sync for SyncCell<T> {}

impl<T> SyncCell<T> {
    pub const fn new(v: T) -> Self {
        Self(UnsafeCell::new(v))
    }

    /// Plain read of the value. Caller must ensure no concurrent writer.
    #[inline(always)]
    pub fn get(&self) -> T
    where
        T: Copy,
    {
        unsafe { *self.0.get() }
    }

    /// Plain write. Caller must be the slot's unique accessor.
    #[inline(always)]
    pub fn set(&self, v: T) {
        unsafe { *self.0.get() = v }
    }

    pub fn get_mut(&mut self) -> &mut T {
        self.0.get_mut()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering::Relaxed;

    #[test]
    fn load_store_roundtrip() {
        let a = AtomicF64::new(1.5);
        assert_eq!(a.load(Relaxed), 1.5);
        a.store(-2.25, Relaxed);
        assert_eq!(a.load(Relaxed), -2.25);
        // NaN and infinities round-trip bit-exactly
        a.store(f64::NEG_INFINITY, Relaxed);
        assert_eq!(a.load(Relaxed), f64::NEG_INFINITY);
        a.store(f64::NAN, Relaxed);
        assert!(a.load(Relaxed).is_nan());
    }

    #[test]
    fn fetch_add_returns_previous() {
        let a = AtomicF64::new(1.0);
        assert_eq!(a.fetch_add(2.0, Relaxed), 1.0);
        assert_eq!(a.load(Relaxed), 3.0);
    }

    #[test]
    fn concurrent_fetch_add_loses_nothing() {
        // The exact property the paper relies on for z updates: with
        // atomic adds, concurrent column updates never lose increments.
        let a = std::sync::Arc::new(AtomicF64::new(0.0));
        let threads = 8;
        let per = 10_000;
        let mut handles = Vec::new();
        for _ in 0..threads {
            let a = a.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..per {
                    a.fetch_add(1.0, Relaxed);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.load(Relaxed), (threads * per) as f64);
    }

    #[test]
    fn snapshot_copies() {
        let v = atomic_vec(4);
        v[2].store(7.0, Relaxed);
        assert_eq!(snapshot(&v), vec![0.0, 0.0, 7.0, 0.0]);
    }

    #[test]
    fn sync_vec_plain_and_atomic_views_alias() {
        let v = SyncF64Vec::zeros(4);
        v.set(1, 2.5);
        // the atomic view sees the plain write ...
        assert_eq!(v[1].load(Relaxed), 2.5);
        // ... and vice versa, including through fetch_add
        v[1].fetch_add(0.5, Relaxed);
        assert_eq!(v.get(1), 3.0);
        v.add(1, 1.0);
        assert_eq!(v.atomic(1).load(Relaxed), 4.0);
        assert_eq!(v.snapshot(), vec![0.0, 4.0, 0.0, 0.0]);
    }

    #[test]
    fn sync_vec_copy_from_and_fill() {
        let v = SyncF64Vec::zeros(3);
        v.copy_from(&[1.0, -2.0, 3.0]);
        assert_eq!(v.snapshot(), vec![1.0, -2.0, 3.0]);
        v.fill(0.25);
        assert_eq!(v.snapshot(), vec![0.25; 3]);
        assert_eq!(v.len(), 3);
        assert!(!v.is_empty());
        assert!(SyncF64Vec::zeros(0).is_empty());
    }

    #[test]
    fn sync_vec_atomic_bitcast_roundtrips_payloads() {
        // NaN / infinities must survive the UnsafeCell<f64> -> AtomicF64
        // reinterpretation in both directions
        let v = SyncF64Vec::zeros(1);
        v.set(0, f64::NAN);
        assert!(v[0].load(Relaxed).is_nan());
        v[0].store(f64::NEG_INFINITY, Relaxed);
        assert_eq!(v.get(0), f64::NEG_INFINITY);
    }

    #[test]
    fn sync_vec_starts_on_cache_line() {
        // the aligned_chunk no-false-sharing argument needs element 0 on
        // a 128-byte boundary regardless of what the allocator returned
        for len in [1usize, 5, 16, 17, 1000] {
            let v = SyncF64Vec::zeros(len);
            let addr = v.atomic(0) as *const _ as usize;
            assert_eq!(addr % 128, 0, "len={len}: base {addr:#x}");
            assert_eq!(v.len(), len);
        }
    }

    #[test]
    fn plain_slices_alias_element_views() {
        let v = SyncF64Vec::zeros(5);
        v.set(2, 3.0);
        // SAFETY: single-threaded test, no concurrent access
        unsafe {
            assert_eq!(v.plain_slice(), &[0.0, 0.0, 3.0, 0.0, 0.0]);
            v.plain_slice_mut()[4] = 7.0;
        }
        assert_eq!(v.get(4), 7.0);
        assert_eq!(v[4].load(Relaxed), 7.0);
    }

    #[test]
    fn raw_ptr_aliases_element_views() {
        let v = SyncF64Vec::zeros(5);
        v.set(1, 2.0);
        let p = v.raw_ptr();
        // SAFETY: single-threaded test, no concurrent access
        unsafe {
            assert_eq!(*p.add(1), 2.0);
            *p.add(3) += 4.0;
        }
        assert_eq!(v.get(3), 4.0);
        assert_eq!(p as usize % 128, 0, "raw_ptr must start on the aligned base");
    }

    #[test]
    fn cas_ratio_calibration_sane_and_cached() {
        let r = cas_plain_ratio();
        assert!((1.0..=64.0).contains(&r), "ratio {r} outside clamp");
        // cached: second call returns the identical value
        assert_eq!(cas_plain_ratio(), r);
    }

    #[test]
    fn sync_cell_basics() {
        let c = SyncCell::new(7u64);
        assert_eq!(c.get(), 7);
        c.set(9);
        assert_eq!(c.get(), 9);
        let mut c = c;
        *c.get_mut() += 1;
        assert_eq!(c.get(), 10);
    }

    #[test]
    fn sync_vec_cross_thread_phase_handoff() {
        // writer thread fills disjoint halves plainly; after join (a
        // happens-before edge, like the engine's barrier) the reader
        // sees everything
        let v = std::sync::Arc::new(SyncF64Vec::zeros(64));
        let mut handles = Vec::new();
        for t in 0..2usize {
            let v = v.clone();
            handles.push(std::thread::spawn(move || {
                for i in (32 * t)..(32 * (t + 1)) {
                    v.set(i, i as f64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        for i in 0..64 {
            assert_eq!(v.get(i), i as f64);
        }
    }
}
