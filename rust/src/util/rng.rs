//! PCG64 (XSL-RR 128/64) pseudo-random generator.
//!
//! Deterministic, seedable, splittable — every experiment in this repo is
//! reproducible from a single seed. Implemented locally because the build
//! is offline (no `rand` crate); matches the reference PCG output
//! function.

/// PCG XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    /// Convenience constructor with stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent generator (used to hand one stream per thread).
    pub fn split(&mut self, stream: u64) -> Self {
        Self::new(self.next_u64(), stream.wrapping_mul(2654435769).wrapping_add(1))
    }

    #[inline]
    fn step(&mut self) {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) via Lemire's method.
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Poisson sample (Knuth for small mean, normal approx for large).
    pub fn next_poisson(&mut self, mean: f64) -> u64 {
        if mean < 30.0 {
            let l = (-mean).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.next_f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = mean + mean.sqrt() * self.next_normal();
            x.max(0.0).round() as u64
        }
    }

    /// Zipf-distributed integer in [0, n) with exponent `s` (rejection-free
    /// inverse-CDF over a precomputed table is the caller's job for hot
    /// loops; this is the convenience path).
    pub fn next_zipf(&mut self, n: usize, s: f64) -> usize {
        // Inverse transform on the (approximate) continuous Zipf CDF.
        debug_assert!(n > 0);
        let u = 1.0 - self.next_f64(); // (0, 1]
        if (s - 1.0).abs() < 1e-9 {
            let h = (n as f64).ln();
            ((u * h).exp() - 1.0).min((n - 1) as f64) as usize
        } else {
            let p = 1.0 - s;
            let h = ((n as f64).powf(p) - 1.0) / p;
            (((u * h * p + 1.0).powf(1.0 / p) - 1.0).min((n - 1) as f64)) as usize
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n);
        let mut chosen = std::collections::HashSet::with_capacity(m);
        let mut out = Vec::with_capacity(m);
        for j in (n - m)..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stream_dependent() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 1);
        let mut c = Pcg64::new(42, 2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Pcg64::seeded(7);
        for _ in 0..10_000 {
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
            let i = r.below(13);
            assert!(i < 13);
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Pcg64::seeded(3);
        let m: f64 = (0..50_000).map(|_| r.next_f64()).sum::<f64>() / 50_000.0;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(11);
        let xs: Vec<f64> = (0..50_000).map(|_| r.next_normal()).collect();
        let m = crate::util::mean(&xs);
        let s = crate::util::stddev(&xs);
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((s - 1.0).abs() < 0.02, "std {s}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Pcg64::seeded(5);
        for lam in [0.5, 3.0, 7.3, 40.0] {
            let n = 20_000;
            let m: f64 =
                (0..n).map(|_| r.next_poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!((m - lam).abs() < 0.15 * lam.max(1.0), "lam={lam} m={m}");
        }
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Pcg64::seeded(9);
        for _ in 0..100 {
            let s = r.sample_distinct(50, 20);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 20);
            assert!(s.iter().all(|&x| x < 50));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(13);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut r = Pcg64::seeded(17);
        let n = 1000;
        let mut counts = vec![0usize; n];
        for _ in 0..100_000 {
            let z = r.next_zipf(n, 1.1);
            counts[z] += 1;
        }
        // head must dominate tail
        let head: usize = counts[..10].iter().sum();
        let tail: usize = counts[n - 10..].iter().sum();
        assert!(head > 10 * (tail + 1), "head={head} tail={tail}");
    }
}
