//! Minimal property-based testing harness (offline stand-in for
//! `proptest`). Tests draw random inputs from a seeded [`Pcg64`], run a
//! property for many cases, and on failure report the failing case's seed
//! so it can be replayed exactly. A size ramp gives small cases first, so
//! the first reported failure is usually near-minimal.
//!
//! ```
//! use gencd::util::prop;
//! prop::check("add commutes", 100, |rng, size| {
//!     let a = rng.below(size + 1) as i64;
//!     let b = rng.below(size + 1) as i64;
//!     prop::ensure(a + b == b + a, format!("{a} {b}"))
//! });
//! ```

use super::rng::Pcg64;

/// Result of one property case: `Ok(())` or a failure description.
pub type CaseResult = Result<(), String>;

/// Helper: turn a boolean into a [`CaseResult`].
pub fn ensure(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `cases` random cases of `property`. The property receives a fresh
/// seeded RNG and a `size` hint that ramps from 1 to `max_size`.
/// Panics (test failure) on the first failing case, reporting its seed.
pub fn check<F>(name: &str, cases: usize, property: F)
where
    F: Fn(&mut Pcg64, usize) -> CaseResult,
{
    check_seeded(name, cases, base_seed(name), 64, property)
}

/// [`check`] with an explicit base seed and size cap (for replays).
pub fn check_seeded<F>(name: &str, cases: usize, base: u64, max_size: usize, property: F)
where
    F: Fn(&mut Pcg64, usize) -> CaseResult,
{
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let size = 1 + case * max_size / cases.max(1);
        let mut rng = Pcg64::new(seed, 0xB0B);
        if let Err(msg) = property(&mut rng, size) {
            panic!(
                "property '{name}' failed at case {case} \
                 (replay: check_seeded(\"{name}\", 1, {seed}, {size}, ..)): {msg}"
            );
        }
    }
}

/// Deterministic per-property seed from the property name, so adding a
/// property never reshuffles another's cases.
fn base_seed(name: &str) -> u64 {
    // FNV-1a
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Draw a random vector of f64 in [-scale, scale] with length in
/// [1, max_len].
pub fn vec_f64(rng: &mut Pcg64, max_len: usize, scale: f64) -> Vec<f64> {
    let len = 1 + rng.below(max_len.max(1));
    (0..len).map(|_| rng.range_f64(-scale, scale)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("abs is nonneg", 200, |rng, _| {
            let x = rng.range_f64(-100.0, 100.0);
            ensure(x.abs() >= 0.0, format!("{x}"))
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_replay_info() {
        check("always fails", 10, |_, _| Err("nope".into()));
    }

    #[test]
    fn seeds_are_stable_per_name() {
        assert_eq!(base_seed("x"), base_seed("x"));
        assert_ne!(base_seed("x"), base_seed("y"));
    }

    #[test]
    fn vec_f64_respects_bounds() {
        let mut rng = Pcg64::seeded(1);
        for _ in 0..100 {
            let v = vec_f64(&mut rng, 17, 3.0);
            assert!(!v.is_empty() && v.len() <= 17);
            assert!(v.iter().all(|x| x.abs() <= 3.0));
        }
    }
}
