//! Iteration observers: user-side hooks into the solve loop.
//!
//! The engine calls [`Observer::on_iteration`] on the *leader* thread
//! once per completed iteration (and once before the first, with
//! `iter == 0`, so the initial state is observable), while the workers
//! are parked at the Select-phase barrier. Observers enable early
//! stopping (`ControlFlow::Break`), checkpointing (snapshot `w` through
//! [`IterationInfo::state`]), and streaming metrics — without the engine
//! hardwiring any particular consumer. The convergence
//! [`History`](super::convergence::History) is itself just the default
//! observer the engine attaches so
//! [`SolveOutput`](super::engine::SolveOutput) can report a log.
//!
//! Cheap by construction: the engine computes the objective only at its
//! log cadence, so `objective`/`nnz` are `Some` on logged iterations and
//! `None` otherwise. Everything else in [`IterationInfo`] is already on
//! hand each iteration.

use std::ops::ControlFlow;

use super::convergence::{History, Record};
use super::problem::SharedState;

/// Snapshot handed to [`Observer::on_iteration`].
pub struct IterationInfo<'a> {
    /// Completed iterations so far (0 on the pre-first-iteration call).
    pub iter: usize,
    /// Wall-clock seconds since the solve started.
    pub elapsed_secs: f64,
    /// Cumulative coordinate updates applied (Figure 2's numerator).
    pub updates: u64,
    /// |J| of the most recent Select (0 before the first iteration).
    pub selected: usize,
    /// Full objective F(w) + lam |w|_1 — computed only on logged
    /// iterations (`solver.log_every` cadence), `None` otherwise.
    pub objective: Option<f64>,
    /// Nonzero weights — same cadence as `objective`.
    pub nnz: Option<usize>,
    /// The live solver state. The observer runs while all workers are
    /// parked, so plain reads (`state.w_snapshot()`, …) are safe; do
    /// not write.
    pub state: &'a SharedState,
}

/// Per-iteration hook. Return [`ControlFlow::Break`] to stop the solve
/// (the output's stop reason becomes
/// [`StopReason::Observer`](super::convergence::StopReason::Observer)).
///
/// Runs on the leader thread; keep it cheap on non-logged iterations —
/// it sits between two phase barriers. `Send` is required (as for
/// [`Select`](super::select::Select) and
/// [`Accept`](super::accept::Accept)) so a built
/// [`Solver`](crate::solver::Solver) can be moved to another thread
/// before running.
pub trait Observer: Send {
    fn on_iteration(&mut self, info: &IterationInfo<'_>) -> ControlFlow<()>;
}

/// Any `FnMut(&IterationInfo) -> ControlFlow<()>` closure is an
/// observer: `.observer(|info| { …; ControlFlow::Continue(()) })`.
impl<F> Observer for F
where
    F: FnMut(&IterationInfo<'_>) -> ControlFlow<()> + Send,
{
    fn on_iteration(&mut self, info: &IterationInfo<'_>) -> ControlFlow<()> {
        self(info)
    }
}

/// The default observer: record a [`Record`] at every logged iteration.
/// This is exactly how the engine builds [`SolveOutput::history`] — no
/// hardwired history plumbing remains in the iteration loop.
///
/// [`SolveOutput::history`]: super::engine::SolveOutput::history
impl Observer for History {
    fn on_iteration(&mut self, info: &IterationInfo<'_>) -> ControlFlow<()> {
        if let (Some(objective), Some(nnz)) = (info.objective, info.nnz) {
            self.push(Record {
                elapsed_secs: info.elapsed_secs,
                iter: info.iter,
                updates: info.updates,
                objective,
                nnz,
            });
        }
        ControlFlow::Continue(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn info(state: &SharedState, iter: usize, objective: Option<f64>) -> IterationInfo<'_> {
        IterationInfo {
            iter,
            elapsed_secs: iter as f64 * 0.5,
            updates: iter as u64,
            selected: 3,
            objective,
            nnz: objective.map(|_| 2),
            state,
        }
    }

    #[test]
    fn history_records_only_logged_iterations() {
        let state = SharedState::new(4, 3);
        let mut h = History::default();
        assert!(h.on_iteration(&info(&state, 0, Some(1.0))).is_continue());
        assert!(h.on_iteration(&info(&state, 1, None)).is_continue());
        assert!(h.on_iteration(&info(&state, 2, Some(0.5))).is_continue());
        assert_eq!(h.records.len(), 2);
        assert_eq!(h.records[1].iter, 2);
        assert_eq!(h.records[1].objective, 0.5);
    }

    #[test]
    fn closures_are_observers() {
        let state = SharedState::new(2, 2);
        let mut count = 0usize;
        let mut obs = |_: &IterationInfo<'_>| {
            count += 1;
            if count >= 3 {
                ControlFlow::Break(())
            } else {
                ControlFlow::Continue(())
            }
        };
        for i in 0..2 {
            assert!(obs.on_iteration(&info(&state, i, None)).is_continue());
        }
        assert!(obs.on_iteration(&info(&state, 2, None)).is_break());
    }
}
