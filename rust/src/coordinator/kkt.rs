//! KKT optimality certificate for the l1 problem (Eq. 1).
//!
//! At an optimum of `F(w) + lam |w|_1`:
//!   * `w_j > 0`  =>  `g_j = -lam`
//!   * `w_j < 0`  =>  `g_j = +lam`
//!   * `w_j = 0`  =>  `|g_j| <= lam`
//!
//! The *violation* of coordinate j is how far `g_j` is from satisfying
//! its condition; the max over j certifies (sub)optimality — a
//! convergence measure that, unlike objective deltas, does not depend
//! on knowing the optimal value. Reported by `gencd train --kkt` and
//! used by tests to certify solver output.

use super::problem::Problem;
use crate::kernel::KernelMode;
use crate::loss;

/// Per-run KKT summary.
#[derive(Clone, Copy, Debug)]
pub struct KktReport {
    /// Maximum violation over all coordinates.
    pub max_violation: f64,
    /// Mean violation.
    pub mean_violation: f64,
    /// Coordinate attaining the max.
    pub argmax: usize,
    /// Violations exceeding `tol` (strict suboptimality witnesses).
    pub n_violating: usize,
    pub tol: f64,
}

/// Violation of coordinate j given its gradient `g`, weight `w` and
/// `lam`.
#[inline]
pub fn violation(w: f64, g: f64, lam: f64) -> f64 {
    if w > 0.0 {
        (g + lam).abs()
    } else if w < 0.0 {
        (g - lam).abs()
    } else {
        (g.abs() - lam).max(0.0)
    }
}

/// Full KKT check at `w` (computes the exact gradient; O(nnz)).
/// Bit-identical to [`check_mode`] at [`KernelMode::Reference`].
pub fn check(problem: &Problem, w: &[f64], tol: f64) -> KktReport {
    check_mode(problem, w, tol, KernelMode::Reference)
}

/// [`check`] under a per-solve [`KernelMode`]: the full-gradient sweep
/// is one `<X_j, ell'(y, z)>` gather per column — exactly the kernel
/// shape the dispatched SIMD dot accelerates. Fast tiers re-associate
/// each column reduction (1e-12 vs the reference); the violation fold
/// itself is identical in every mode.
pub fn check_mode(problem: &Problem, w: &[f64], tol: f64, mode: KernelMode) -> KktReport {
    let z = problem.x.matvec(w);
    let g = match mode {
        KernelMode::Reference => {
            loss::full_gradient(problem.loss.as_ref(), &problem.x, &problem.y, &z)
        }
        KernelMode::Fast(tier) => {
            let loss = problem.loss.as_ref();
            let n = problem.n_samples() as f64;
            let d: Vec<f64> = problem
                .y
                .iter()
                .zip(&z)
                .map(|(&yi, &zi)| loss.deriv(yi, zi))
                .collect();
            (0..w.len())
                .map(|j| problem.x.dot_col_tier(j, &d, tier) / n)
                .collect()
        }
    };
    let mut max_v = 0.0;
    let mut sum = 0.0;
    let mut argmax = 0;
    let mut n_violating = 0;
    for j in 0..w.len() {
        let v = violation(w[j], g[j], problem.lam);
        sum += v;
        if v > max_v {
            max_v = v;
            argmax = j;
        }
        if v > tol {
            n_violating += 1;
        }
    }
    KktReport {
        max_violation: max_v,
        mean_violation: sum / w.len().max(1) as f64,
        argmax,
        n_violating,
        tol,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::coordinator::driver::run_on;
    use crate::loss::Squared;
    use crate::sparse::io::Dataset;
    use crate::sparse::CooBuilder;
    use crate::util::prop;

    #[test]
    fn violation_cases() {
        let lam = 0.5;
        // active positive weight: g must be -lam
        assert_eq!(violation(1.0, -0.5, lam), 0.0);
        assert!((violation(1.0, -0.3, lam) - 0.2).abs() < 1e-12);
        // active negative weight: g must be +lam
        assert_eq!(violation(-1.0, 0.5, lam), 0.0);
        // zero weight: |g| <= lam is fine
        assert_eq!(violation(0.0, 0.3, lam), 0.0);
        assert_eq!(violation(0.0, -0.5, lam), 0.0);
        assert!((violation(0.0, 0.8, lam) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn closed_form_solution_certifies() {
        // identity design: solution is soft-threshold, violation ~ 0
        let n = 12;
        let mut b = CooBuilder::new(n, n);
        for i in 0..n {
            b.push(i, i, 1.0);
        }
        let y: Vec<f64> = (0..n).map(|i| (i as f64 - 6.0) / 3.0).collect();
        let lam = 0.02;
        let tau = n as f64 * lam;
        let w: Vec<f64> = y.iter().map(|&v| crate::util::soft_threshold(v, tau)).collect();
        let p = crate::coordinator::Problem::new(
            Dataset {
                x: b.build(),
                y,
                name: "id".into(),
            },
            Box::new(Squared),
            lam,
        );
        let r = check(&p, &w, 1e-9);
        assert!(r.max_violation < 1e-12, "{r:?}");
        assert_eq!(r.n_violating, 0);
    }

    #[test]
    fn check_mode_tiers_agree() {
        use crate::kernel::KernelTier;
        let mut rng = crate::util::Pcg64::seeded(21);
        let n = 120usize;
        let k = 10usize;
        let mut b = CooBuilder::new(n, k);
        for j in 0..k {
            for i in 0..n {
                if rng.next_f64() < 0.3 {
                    b.push(i, j, rng.range_f64(-1.0, 1.0));
                }
            }
        }
        let p = crate::coordinator::Problem::new(
            Dataset {
                x: b.build(),
                y: (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect(),
                name: "t".into(),
            },
            crate::loss::by_name("logistic").unwrap(),
            1e-3,
        );
        let w: Vec<f64> = (0..k).map(|_| rng.range_f64(-0.5, 0.5)).collect();
        let reference = check(&p, &w, 1e-6);
        for tier in [KernelTier::Scalar, KernelTier::Avx2, KernelTier::Avx512] {
            let fast = check_mode(&p, &w, 1e-6, KernelMode::Fast(tier));
            assert!(
                (reference.max_violation - fast.max_violation).abs() <= 1e-12,
                "{tier:?}: {} vs {}",
                reference.max_violation,
                fast.max_violation
            );
            assert!((reference.mean_violation - fast.mean_violation).abs() <= 1e-12);
            assert_eq!(reference.argmax, fast.argmax, "{tier:?}");
        }
    }

    #[test]
    fn solver_output_has_small_violation() {
        let ds = crate::data::by_name("reuters@0.02").unwrap();
        let mut cfg = RunConfig::default();
        cfg.dataset.name = "reuters@0.02".into();
        cfg.problem.lam = 1e-3;
        cfg.solver.algorithm = "ccd".into();
        cfg.solver.threads = 1;
        cfg.solver.max_seconds = 6.0;
        cfg.solver.tol = 1e-10;
        cfg.solver.line_search_steps = 10;
        let res = run_on(&cfg, ds, None).unwrap();
        let mut d = crate::data::by_name("reuters@0.02").unwrap();
        d.x.normalize_columns();
        let p = crate::coordinator::Problem::new(
            d,
            crate::loss::by_name("logistic").unwrap(),
            1e-3,
        );
        let r = check(&p, &res.w, 1e-4);
        // far from machine precision (finite budget) but certifiably
        // near-stationary relative to the gradient scale
        assert!(
            r.max_violation < 0.05 * p.lam.max(1e-3) + 5e-4,
            "max violation {} at {}",
            r.max_violation,
            r.argmax
        );
    }

    #[test]
    fn prop_violation_nonnegative_and_zero_only_at_kkt() {
        prop::check("violation >= 0", 200, |rng, _| {
            let w = rng.range_f64(-2.0, 2.0);
            let g = rng.range_f64(-2.0, 2.0);
            let lam = rng.range_f64(1e-4, 1.0);
            let v = violation(w, g, lam);
            prop::ensure(v >= 0.0, format!("negative violation {v}"))?;
            if v == 0.0 && w != 0.0 {
                let want = if w > 0.0 { -lam } else { lam };
                prop::ensure(
                    (g - want).abs() < 1e-12,
                    format!("zero violation but g={g} want {want}"),
                )?;
            }
            Ok(())
        });
    }
}
