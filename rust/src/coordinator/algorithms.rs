//! Named algorithm presets — the paper's Table 2 plus the sequential
//! baselines and the §7 extensions.
//!
//! | Algorithm     | Select        | Accept        |
//! |---------------|---------------|---------------|
//! | CCD           | cyclic single | all           |
//! | SCD           | random single | all           |
//! | SHOTGUN       | rand subset P*| all           |
//! | THREAD-GREEDY | rand subset   | greedy/thread |
//! | GREEDY        | all           | greedy        |
//! | COLORING      | rand color    | all           |
//! | TOPK (§7)     | rand subset   | best K global |
//! | BLOCK-SHOTGUN (§7 "soft coloring") | per-block rand subsets | all |

use super::accept::Acceptor;
use super::select::Selector;
use crate::coloring::{color_features, Coloring, Strategy};
use crate::linalg::{shotgun_pstar, spectral_radius_xtx};
use crate::sparse::CscMatrix;
use crate::util::Pcg64;

/// The algorithm catalogue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Ccd,
    Scd,
    Shotgun,
    ThreadGreedy,
    Greedy,
    Coloring,
    TopK,
    BlockShotgun,
}

impl Algorithm {
    pub fn by_name(name: &str) -> anyhow::Result<Self> {
        Ok(match name {
            "ccd" => Algorithm::Ccd,
            "scd" => Algorithm::Scd,
            "shotgun" => Algorithm::Shotgun,
            "thread-greedy" | "thread_greedy" => Algorithm::ThreadGreedy,
            "greedy" => Algorithm::Greedy,
            "coloring" => Algorithm::Coloring,
            "topk" => Algorithm::TopK,
            "block-shotgun" | "block_shotgun" => Algorithm::BlockShotgun,
            other => anyhow::bail!(
                "unknown algorithm '{other}' \
                 (ccd|scd|shotgun|thread-greedy|greedy|coloring|topk|block-shotgun)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Ccd => "ccd",
            Algorithm::Scd => "scd",
            Algorithm::Shotgun => "shotgun",
            Algorithm::ThreadGreedy => "thread-greedy",
            Algorithm::Greedy => "greedy",
            Algorithm::Coloring => "coloring",
            Algorithm::TopK => "topk",
            Algorithm::BlockShotgun => "block-shotgun",
        }
    }

    /// The four algorithms of the paper's experiments (Sec. 4.1).
    pub fn paper_set() -> [Algorithm; 4] {
        [
            Algorithm::Shotgun,
            Algorithm::ThreadGreedy,
            Algorithm::Greedy,
            Algorithm::Coloring,
        ]
    }

    /// Does this algorithm need the coloring preprocessing?
    pub fn needs_coloring(&self) -> bool {
        matches!(self, Algorithm::Coloring)
    }

    /// Does this algorithm need the spectral-radius / P* estimate?
    pub fn needs_pstar(&self) -> bool {
        matches!(self, Algorithm::Shotgun | Algorithm::BlockShotgun)
    }
}

/// Everything precomputed the policies may need.
pub struct Preprocessed {
    pub pstar: Option<usize>,
    pub rho: Option<f64>,
    pub coloring: Option<Coloring>,
}

impl Preprocessed {
    /// Run the preprocessing an algorithm requires (spectral radius for
    /// SHOTGUN-family, coloring for COLORING).
    pub fn for_algorithm(
        alg: Algorithm,
        x: &CscMatrix,
        coloring_strategy: Strategy,
        seed: u64,
    ) -> Self {
        let (pstar, rho) = if alg.needs_pstar() {
            let est = spectral_radius_xtx(x, 200, 1e-6, seed ^ 0x5EC7);
            (Some(shotgun_pstar(x.n_cols(), est.rho)), Some(est.rho))
        } else {
            (None, None)
        };
        let coloring = alg
            .needs_coloring()
            .then(|| color_features(x, coloring_strategy, seed ^ 0xC0102));
        Self {
            pstar,
            rho,
            coloring,
        }
    }

    pub fn none() -> Self {
        Self {
            pstar: None,
            rho: None,
            coloring: None,
        }
    }
}

/// Policy pair an algorithm resolves to.
pub struct Instantiation {
    pub selector: Selector,
    pub acceptor: Acceptor,
}

/// Resolve an algorithm into (Selector, Acceptor) for a concrete problem.
///
/// * `select_size` overrides the selection size (0 = default: P* for
///   SHOTGUN, `threads * 32` for THREAD-GREEDY/TopK).
/// * `accept_k` overrides TopK's budget (0 = `threads`).
pub fn instantiate(
    alg: Algorithm,
    k: usize,
    threads: usize,
    select_size: usize,
    accept_k: usize,
    pre: &Preprocessed,
    seed: u64,
) -> anyhow::Result<Instantiation> {
    let rng = Pcg64::new(seed, 0xA160);
    let inst = match alg {
        Algorithm::Ccd => Instantiation {
            selector: Selector::Cyclic { next: 0, k },
            acceptor: Acceptor::All,
        },
        Algorithm::Scd => Instantiation {
            selector: Selector::Stochastic { rng, k },
            acceptor: Acceptor::All,
        },
        Algorithm::Shotgun => {
            let size = if select_size > 0 {
                select_size
            } else {
                pre.pstar
                    .ok_or_else(|| anyhow::anyhow!("shotgun needs P* preprocessing"))?
            };
            Instantiation {
                selector: Selector::RandomSubset { rng, k, size },
                acceptor: Acceptor::All,
            }
        }
        Algorithm::ThreadGreedy => {
            // paper: random set, each thread keeps its best; default
            // gives each thread a pool of 32 candidates
            let size = if select_size > 0 {
                select_size
            } else {
                (threads * 32).min(k)
            };
            Instantiation {
                selector: Selector::RandomSubset { rng, k, size },
                acceptor: Acceptor::ThreadGreedy,
            }
        }
        Algorithm::Greedy => Instantiation {
            selector: Selector::All { k },
            acceptor: Acceptor::GlobalBest,
        },
        Algorithm::Coloring => {
            let coloring = pre
                .coloring
                .clone()
                .ok_or_else(|| anyhow::anyhow!("coloring algorithm needs a coloring"))?;
            Instantiation {
                selector: Selector::RandomColor { rng, coloring },
                acceptor: Acceptor::All,
            }
        }
        Algorithm::TopK => {
            let size = if select_size > 0 {
                select_size
            } else {
                (threads * 32).min(k)
            };
            let kk = if accept_k > 0 { accept_k } else { threads };
            Instantiation {
                selector: Selector::RandomSubset { rng, k, size },
                acceptor: Acceptor::GlobalTopK(kk),
            }
        }
        Algorithm::BlockShotgun => {
            // §7: partition columns into `threads` blocks; per-block P*_b
            // approximated by P* / blocks (a faithful "soft coloring"
            // would estimate rho per block; the ablation bench compares).
            let blocks = threads.max(2);
            let total = if select_size > 0 {
                select_size
            } else {
                pre.pstar
                    .ok_or_else(|| anyhow::anyhow!("block-shotgun needs P*"))?
            };
            let per = (total / blocks).max(1);
            Instantiation {
                selector: Selector::BlockSubset {
                    rng,
                    k,
                    blocks,
                    per_block: vec![per; blocks],
                },
                acceptor: Acceptor::All,
            }
        }
    };
    Ok(inst)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooBuilder;

    fn matrix() -> CscMatrix {
        let mut rng = Pcg64::seeded(1);
        let mut b = CooBuilder::new(20, 40);
        for j in 0..40 {
            for _ in 0..3 {
                b.push(rng.below(20), j, 1.0);
            }
        }
        b.build()
    }

    #[test]
    fn name_roundtrip() {
        for alg in [
            Algorithm::Ccd,
            Algorithm::Scd,
            Algorithm::Shotgun,
            Algorithm::ThreadGreedy,
            Algorithm::Greedy,
            Algorithm::Coloring,
            Algorithm::TopK,
            Algorithm::BlockShotgun,
        ] {
            assert_eq!(Algorithm::by_name(alg.name()).unwrap(), alg);
        }
        assert!(Algorithm::by_name("sgd").is_err());
    }

    #[test]
    fn preprocessing_matches_needs() {
        let x = matrix();
        let pre = Preprocessed::for_algorithm(Algorithm::Shotgun, &x, Strategy::Greedy, 1);
        assert!(pre.pstar.is_some() && pre.coloring.is_none());
        let pre = Preprocessed::for_algorithm(Algorithm::Coloring, &x, Strategy::Greedy, 1);
        assert!(pre.pstar.is_none() && pre.coloring.is_some());
        let pre = Preprocessed::for_algorithm(Algorithm::Greedy, &x, Strategy::Greedy, 1);
        assert!(pre.pstar.is_none() && pre.coloring.is_none());
    }

    #[test]
    fn instantiate_all() {
        let x = matrix();
        for alg in [
            Algorithm::Ccd,
            Algorithm::Scd,
            Algorithm::Shotgun,
            Algorithm::ThreadGreedy,
            Algorithm::Greedy,
            Algorithm::Coloring,
            Algorithm::TopK,
            Algorithm::BlockShotgun,
        ] {
            let pre =
                Preprocessed::for_algorithm(alg, &x, Strategy::Greedy, 7);
            let inst = instantiate(alg, x.n_cols(), 4, 0, 0, &pre, 7).unwrap();
            // smoke: selector produces a nonempty in-range selection
            let mut sel = inst.selector;
            let mut out = Vec::new();
            sel.select(&mut out);
            assert!(!out.is_empty());
            assert!(out.iter().all(|&j| (j as usize) < x.n_cols()));
        }
    }

    #[test]
    fn shotgun_without_pstar_errors() {
        assert!(instantiate(
            Algorithm::Shotgun,
            10,
            2,
            0,
            0,
            &Preprocessed::none(),
            1
        )
        .is_err());
        // explicit select_size sidesteps preprocessing
        assert!(instantiate(
            Algorithm::Shotgun,
            10,
            2,
            5,
            0,
            &Preprocessed::none(),
            1
        )
        .is_ok());
    }

    #[test]
    fn thread_greedy_defaults_scale_with_threads() {
        let pre = Preprocessed::none();
        let inst = instantiate(Algorithm::ThreadGreedy, 1000, 8, 0, 0, &pre, 1).unwrap();
        assert_eq!(inst.selector.expected_size(), 256.0);
        assert_eq!(inst.acceptor, Acceptor::ThreadGreedy);
    }
}
