//! Named algorithm presets — the paper's Table 2 plus the sequential
//! baselines and the §7 extensions.
//!
//! | Algorithm     | Select        | Accept        |
//! |---------------|---------------|---------------|
//! | CCD           | cyclic single | all           |
//! | SCD           | random single | all           |
//! | SHOTGUN       | rand subset P*| all           |
//! | THREAD-GREEDY | rand subset   | greedy/thread |
//! | GREEDY        | all           | greedy        |
//! | COLORING      | rand color    | all           |
//! | TOPK (§7)     | rand subset   | best K global |
//! | BLOCK-SHOTGUN (§7 "soft coloring") | per-block rand subsets | all |
//!
//! [`Algorithm`] is a thin *preset catalogue*: [`instantiate`] resolves
//! each name into a ([`Select`], [`Accept`]) trait-object pair built
//! from the constructor functions in [`super::select`] /
//! [`super::accept`]. Nothing in the engine knows about the enum — a
//! custom policy pair built by hand (or through
//! [`crate::solver::SolverBuilder`]) is a first-class citizen.

use super::accept::{self, Accept};
use super::select::{self, Select};
use crate::coloring::{color_features, Coloring, Strategy};
use crate::linalg::{shotgun_pstar, spectral_radius_xtx};
use crate::sparse::CscMatrix;

/// The algorithm catalogue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Ccd,
    Scd,
    Shotgun,
    ThreadGreedy,
    Greedy,
    Coloring,
    TopK,
    BlockShotgun,
}

impl Algorithm {
    /// Every preset, in catalogue order. CLI/TOML name lists and the
    /// `FromStr` error message derive from this — add a preset here and
    /// both stay current.
    pub const ALL: [Algorithm; 8] = [
        Algorithm::Ccd,
        Algorithm::Scd,
        Algorithm::Shotgun,
        Algorithm::ThreadGreedy,
        Algorithm::Greedy,
        Algorithm::Coloring,
        Algorithm::TopK,
        Algorithm::BlockShotgun,
    ];

    /// Resolve a CLI/TOML name.
    #[deprecated(note = "use `name.parse::<Algorithm>()` (FromStr) instead")]
    pub fn by_name(name: &str) -> anyhow::Result<Self> {
        name.parse()
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Ccd => "ccd",
            Algorithm::Scd => "scd",
            Algorithm::Shotgun => "shotgun",
            Algorithm::ThreadGreedy => "thread-greedy",
            Algorithm::Greedy => "greedy",
            Algorithm::Coloring => "coloring",
            Algorithm::TopK => "topk",
            Algorithm::BlockShotgun => "block-shotgun",
        }
    }

    /// The four algorithms of the paper's experiments (Sec. 4.1).
    pub fn paper_set() -> [Algorithm; 4] {
        [
            Algorithm::Shotgun,
            Algorithm::ThreadGreedy,
            Algorithm::Greedy,
            Algorithm::Coloring,
        ]
    }

    /// Does this algorithm need the coloring preprocessing?
    pub fn needs_coloring(&self) -> bool {
        matches!(self, Algorithm::Coloring)
    }

    /// Does this algorithm need the spectral-radius / P* estimate?
    pub fn needs_pstar(&self) -> bool {
        matches!(self, Algorithm::Shotgun | Algorithm::BlockShotgun)
    }
}

impl std::str::FromStr for Algorithm {
    type Err = anyhow::Error;

    /// Accepts the canonical dashed names ([`Algorithm::name`]) plus
    /// underscore spellings (`thread_greedy`).
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let canon = s.replace('_', "-");
        Algorithm::ALL
            .iter()
            .copied()
            .find(|a| a.name() == canon)
            .ok_or_else(|| {
                let names: Vec<&str> = Algorithm::ALL.iter().map(|a| a.name()).collect();
                anyhow::anyhow!("unknown algorithm '{s}' ({})", names.join("|"))
            })
    }
}

impl std::fmt::Display for Algorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Everything precomputed the policies may need.
#[derive(Clone)]
pub struct Preprocessed {
    pub pstar: Option<usize>,
    pub rho: Option<f64>,
    pub coloring: Option<Coloring>,
}

impl Preprocessed {
    /// Run the preprocessing an algorithm requires (spectral radius for
    /// SHOTGUN-family, coloring for COLORING).
    pub fn for_algorithm(
        alg: Algorithm,
        x: &CscMatrix,
        coloring_strategy: Strategy,
        seed: u64,
    ) -> Self {
        let (pstar, rho) = if alg.needs_pstar() {
            let est = spectral_radius_xtx(x, 200, 1e-6, seed ^ 0x5EC7);
            (Some(shotgun_pstar(x.n_cols(), est.rho)), Some(est.rho))
        } else {
            (None, None)
        };
        let coloring = alg
            .needs_coloring()
            .then(|| color_features(x, coloring_strategy, seed ^ 0xC0102));
        Self {
            pstar,
            rho,
            coloring,
        }
    }

    pub fn none() -> Self {
        Self {
            pstar: None,
            rho: None,
            coloring: None,
        }
    }
}

/// Policy pair an algorithm resolves to: boxed [`Select`] / [`Accept`]
/// trait objects, exactly what a custom policy pair would be.
pub struct Instantiation {
    pub selector: Box<dyn Select>,
    pub acceptor: Box<dyn Accept>,
}

/// Resolve an algorithm into its (Select, Accept) pair for a concrete
/// problem.
///
/// * `select_size` overrides the selection size (0 = default: P* for
///   SHOTGUN, `threads * 32` for THREAD-GREEDY/TopK).
/// * `accept_k` overrides TopK's budget (0 = `threads`).
pub fn instantiate(
    alg: Algorithm,
    k: usize,
    threads: usize,
    select_size: usize,
    accept_k: usize,
    pre: &Preprocessed,
    seed: u64,
) -> anyhow::Result<Instantiation> {
    let inst = match alg {
        Algorithm::Ccd => Instantiation {
            selector: select::cyclic(k),
            acceptor: accept::all(),
        },
        Algorithm::Scd => Instantiation {
            selector: select::stochastic(k, seed),
            acceptor: accept::all(),
        },
        Algorithm::Shotgun => {
            let size = if select_size > 0 {
                select_size
            } else {
                pre.pstar
                    .ok_or_else(|| anyhow::anyhow!("shotgun needs P* preprocessing"))?
            };
            Instantiation {
                selector: select::random_subset(k, size, seed),
                acceptor: accept::all(),
            }
        }
        Algorithm::ThreadGreedy => {
            // paper: random set, each thread keeps its best; default
            // gives each thread a pool of 32 candidates
            let size = if select_size > 0 {
                select_size
            } else {
                (threads * 32).min(k)
            };
            Instantiation {
                selector: select::random_subset(k, size, seed),
                acceptor: accept::thread_greedy(),
            }
        }
        Algorithm::Greedy => Instantiation {
            selector: select::full_set(k),
            acceptor: accept::global_best(),
        },
        Algorithm::Coloring => {
            let coloring = pre
                .coloring
                .clone()
                .ok_or_else(|| anyhow::anyhow!("coloring algorithm needs a coloring"))?;
            Instantiation {
                selector: select::random_color(coloring, seed),
                acceptor: accept::all(),
            }
        }
        Algorithm::TopK => {
            let size = if select_size > 0 {
                select_size
            } else {
                (threads * 32).min(k)
            };
            let kk = if accept_k > 0 { accept_k } else { threads };
            Instantiation {
                selector: select::random_subset(k, size, seed),
                acceptor: accept::top_k(kk),
            }
        }
        Algorithm::BlockShotgun => {
            // §7: partition columns into `threads` blocks; per-block P*_b
            // approximated by P* / blocks (a faithful "soft coloring"
            // would estimate rho per block; the ablation bench compares).
            let blocks = threads.max(2);
            let total = if select_size > 0 {
                select_size
            } else {
                pre.pstar
                    .ok_or_else(|| anyhow::anyhow!("block-shotgun needs P*"))?
            };
            let per = (total / blocks).max(1);
            Instantiation {
                selector: select::block_subset(k, blocks, vec![per; blocks], seed),
                acceptor: accept::all(),
            }
        }
    };
    Ok(inst)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooBuilder;
    use crate::util::Pcg64;

    fn matrix() -> CscMatrix {
        let mut rng = Pcg64::seeded(1);
        let mut b = CooBuilder::new(20, 40);
        for j in 0..40 {
            for _ in 0..3 {
                b.push(rng.below(20), j, 1.0);
            }
        }
        b.build()
    }

    #[test]
    fn name_roundtrip() {
        for alg in Algorithm::ALL {
            assert_eq!(alg.name().parse::<Algorithm>().unwrap(), alg);
            assert_eq!(alg.to_string(), alg.name());
        }
        assert!("sgd".parse::<Algorithm>().is_err());
        // underscore spellings keep working
        assert_eq!(
            "thread_greedy".parse::<Algorithm>().unwrap(),
            Algorithm::ThreadGreedy
        );
        assert_eq!(
            "block_shotgun".parse::<Algorithm>().unwrap(),
            Algorithm::BlockShotgun
        );
    }

    #[test]
    fn unknown_name_error_lists_catalogue() {
        let err = "sgd".parse::<Algorithm>().unwrap_err().to_string();
        for alg in Algorithm::ALL {
            assert!(
                err.contains(alg.name()),
                "error should list '{}' (derived from ALL): {err}",
                alg.name()
            );
        }
    }

    #[test]
    #[allow(deprecated)]
    fn by_name_shim_still_works() {
        assert!(matches!(
            Algorithm::by_name("shotgun"),
            Ok(Algorithm::Shotgun)
        ));
        assert!(Algorithm::by_name("sgd").is_err());
    }

    #[test]
    fn preprocessing_matches_needs() {
        let x = matrix();
        let pre = Preprocessed::for_algorithm(Algorithm::Shotgun, &x, Strategy::Greedy, 1);
        assert!(pre.pstar.is_some() && pre.coloring.is_none());
        let pre = Preprocessed::for_algorithm(Algorithm::Coloring, &x, Strategy::Greedy, 1);
        assert!(pre.pstar.is_none() && pre.coloring.is_some());
        let pre = Preprocessed::for_algorithm(Algorithm::Greedy, &x, Strategy::Greedy, 1);
        assert!(pre.pstar.is_none() && pre.coloring.is_none());
    }

    #[test]
    fn instantiate_all() {
        let x = matrix();
        for alg in Algorithm::ALL {
            let pre = Preprocessed::for_algorithm(alg, &x, Strategy::Greedy, 7);
            let inst = instantiate(alg, x.n_cols(), 4, 0, 0, &pre, 7).unwrap();
            // smoke: selector produces a nonempty in-range selection
            let mut sel = inst.selector;
            let mut out = Vec::new();
            out.clear();
            sel.select(&mut out);
            assert!(!out.is_empty());
            assert!(out.iter().all(|&j| (j as usize) < x.n_cols()));
        }
    }

    #[test]
    fn shotgun_without_pstar_errors() {
        assert!(instantiate(
            Algorithm::Shotgun,
            10,
            2,
            0,
            0,
            &Preprocessed::none(),
            1
        )
        .is_err());
        // explicit select_size sidesteps preprocessing
        assert!(instantiate(
            Algorithm::Shotgun,
            10,
            2,
            5,
            0,
            &Preprocessed::none(),
            1
        )
        .is_ok());
    }

    #[test]
    fn thread_greedy_defaults_scale_with_threads() {
        let pre = Preprocessed::none();
        let inst = instantiate(Algorithm::ThreadGreedy, 1000, 8, 0, 0, &pre, 1).unwrap();
        assert_eq!(inst.selector.expected_size(), 256.0);
        assert_eq!(inst.acceptor.name(), "thread-greedy");
    }
}
