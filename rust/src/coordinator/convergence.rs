//! Convergence tracking: the (time, objective, NNZ) history behind the
//! paper's Figure 1, plus stopping criteria.

/// One history sample.
#[derive(Clone, Copy, Debug)]
pub struct Record {
    pub elapsed_secs: f64,
    pub iter: usize,
    /// Total coordinate updates so far (Figure 2's numerator).
    pub updates: u64,
    /// Full objective F(w) + lam |w|_1.
    pub objective: f64,
    /// Nonzero weights (Figure 1's NNZ curves).
    pub nnz: usize,
}

/// The solver's convergence log.
#[derive(Clone, Debug, Default)]
pub struct History {
    pub records: Vec<Record>,
}

impl History {
    pub fn push(&mut self, r: Record) {
        self.records.push(r);
    }

    pub fn last(&self) -> Option<&Record> {
        self.records.last()
    }

    pub fn best_objective(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.objective)
            .fold(f64::INFINITY, f64::min)
    }

    /// Relative improvement between the last two records (used by the
    /// `tol` stop rule).
    pub fn last_rel_improvement(&self) -> f64 {
        let n = self.records.len();
        if n < 2 {
            return f64::INFINITY;
        }
        let prev = self.records[n - 2].objective;
        let cur = self.records[n - 1].objective;
        (prev - cur) / prev.abs().max(1e-300)
    }

    /// First time the objective got within `(1 + rel_gap)` of its final
    /// best — a "time to quality" summary used by the bench harness.
    pub fn time_to_within(&self, rel_gap: f64) -> Option<f64> {
        let best = self.best_objective();
        if !best.is_finite() {
            return None;
        }
        let target = best + rel_gap * best.abs().max(1e-300);
        self.records
            .iter()
            .find(|r| r.objective <= target)
            .map(|r| r.elapsed_secs)
    }

    /// Serialize as CSV (header + rows).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("elapsed_secs,iter,updates,objective,nnz\n");
        for r in &self.records {
            out.push_str(&format!(
                "{:.6},{},{},{:.9},{}\n",
                r.elapsed_secs, r.iter, r.updates, r.objective, r.nnz
            ));
        }
        out
    }
}

/// Why the solver stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    MaxIters,
    MaxSeconds,
    Tolerance,
    /// Objective became non-finite (divergence — e.g. Shotgun past P*).
    Diverged,
    /// An [`Observer`](super::observer::Observer) returned
    /// `ControlFlow::Break` (user-side early stopping).
    Observer,
    /// The tolerance criterion fired **and** a full-set KKT sweep
    /// certified the screened active set: every deactivated coordinate
    /// satisfies its optimality condition exactly at the final iterate,
    /// so the solution is identical to what the unscreened solver's
    /// `Tolerance` stop would accept. Only emitted with
    /// `EngineConfig::screening` on — the sweep gates it
    /// ([`crate::screen`]); unscreened solves keep reporting
    /// [`Tolerance`](Self::Tolerance).
    Converged,
    /// A shard pool died mid-solve — it panicked, timed out on a
    /// reconcile barrier, or observed a poisoned peer — and the sharded
    /// engine terminated the solve with the best-effort iterate instead
    /// of hanging. The structured detail travels in
    /// [`SolveOutput::failure`](super::engine::SolveOutput::failure)
    /// (see [`crate::shard::engine`] §Failure semantics). Only emitted
    /// by the shard layer.
    ShardFailed,
}

impl std::fmt::Display for StopReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            StopReason::MaxIters => "max-iters",
            StopReason::MaxSeconds => "max-seconds",
            StopReason::Tolerance => "tolerance",
            StopReason::Diverged => "diverged",
            StopReason::Observer => "observer",
            StopReason::Converged => "converged",
            StopReason::ShardFailed => "shard-failed",
        };
        write!(f, "{s}")
    }
}

/// Failure class of a [`SolveError`] — what *kind* of thing killed the
/// shard pool, independent of the human-readable message. Embedders
/// match on this instead of parsing strings: a `Timeout` may warrant a
/// retry with a longer `barrier_timeout_secs`, a `Protocol` error means
/// a wire/codec bug (or a corrupting network) and should page someone.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolveErrorKind {
    /// The pool's worker thread panicked (bug in user callbacks, or an
    /// injected kill in the fault simulator).
    Panic,
    /// A reconcile crossing exceeded its deadline
    /// ([`LinkFault::TimedOut`](crate::shard::engine::LinkFault::TimedOut)):
    /// a peer is slow, stuck, or gone, and never arrived.
    Timeout,
    /// The reconcile link itself failed
    /// ([`LinkFault::Poisoned`](crate::shard::engine::LinkFault::Poisoned)):
    /// a dying peer poisoned the exchange, or a transport connection
    /// dropped.
    Link,
    /// The wire protocol was violated
    /// ([`LinkFault::Protocol`](crate::shard::engine::LinkFault::Protocol)):
    /// a frame failed to decode — truncated, bad magic, inconsistent
    /// lengths. Only wire transports ([`crate::net`]) emit this.
    Protocol,
}

impl SolveErrorKind {
    /// The stable string form — the `Display` rendering, the scenario
    /// expectation files' `[expect] kind = "..."` values, and the
    /// `shard_failed` event's `kind` field. Keep these strings stable.
    pub fn name(&self) -> &'static str {
        match self {
            SolveErrorKind::Panic => "panic",
            SolveErrorKind::Timeout => "timeout",
            SolveErrorKind::Link => "link",
            SolveErrorKind::Protocol => "protocol",
        }
    }
}

impl std::fmt::Display for SolveErrorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Structured description of a shard-pool failure: what the solve's
/// [`StopReason::ShardFailed`] actually was. Carried in
/// [`SolveOutput::failure`](super::engine::SolveOutput::failure) so
/// callers can log/match on it without parsing panic payloads.
#[derive(Clone, Debug)]
pub struct SolveError {
    /// Index of the shard whose pool failed, when attributable (a
    /// barrier timeout observed by a *healthy* shard reports that
    /// shard's own index — the dead peer is whichever never arrived).
    pub shard: Option<usize>,
    /// Failure class, for programmatic matching.
    pub kind: SolveErrorKind,
    /// Human-readable cause: the panic payload, or the link fault
    /// ("reconcile barrier timed out", "reconcile barrier poisoned").
    pub message: String,
}

impl std::fmt::Display for SolveError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.shard {
            Some(s) => write!(f, "shard {s}: {}", self.message),
            None => write!(f, "{}", self.message),
        }
    }
}

impl std::error::Error for SolveError {}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(t: f64, obj: f64) -> Record {
        Record {
            elapsed_secs: t,
            iter: 0,
            updates: 0,
            objective: obj,
            nnz: 0,
        }
    }

    #[test]
    fn improvement_tracking() {
        let mut h = History::default();
        assert_eq!(h.last_rel_improvement(), f64::INFINITY);
        h.push(rec(0.0, 1.0));
        h.push(rec(1.0, 0.9));
        assert!((h.last_rel_improvement() - 0.1).abs() < 1e-12);
        h.push(rec(2.0, 0.9));
        assert_eq!(h.last_rel_improvement(), 0.0);
        assert_eq!(h.best_objective(), 0.9);
    }

    #[test]
    fn time_to_within() {
        let mut h = History::default();
        h.push(rec(0.0, 2.0));
        h.push(rec(1.0, 1.0));
        h.push(rec(2.0, 0.5));
        h.push(rec(3.0, 0.5));
        assert_eq!(h.time_to_within(0.0), Some(2.0));
        assert_eq!(h.time_to_within(1.1), Some(1.0)); // within 0.5*(1+1.1)=1.05
        assert_eq!(h.time_to_within(10.0), Some(0.0));
    }

    #[test]
    fn solve_error_kind_display_is_stable() {
        assert_eq!(SolveErrorKind::Panic.to_string(), "panic");
        assert_eq!(SolveErrorKind::Timeout.to_string(), "timeout");
        assert_eq!(SolveErrorKind::Link.to_string(), "link");
        assert_eq!(SolveErrorKind::Protocol.to_string(), "protocol");
        let e = SolveError {
            shard: Some(3),
            kind: SolveErrorKind::Timeout,
            message: "reconcile barrier timed out (peer missing)".into(),
        };
        // Display stays message-shaped (scenario grading substrings
        // depend on it); the kind travels alongside.
        assert_eq!(e.to_string(), "shard 3: reconcile barrier timed out (peer missing)");
        let _: &dyn std::error::Error = &e;
    }

    #[test]
    fn csv_format() {
        let mut h = History::default();
        h.push(Record {
            elapsed_secs: 0.5,
            iter: 3,
            updates: 12,
            objective: 0.25,
            nnz: 7,
        });
        let csv = h.to_csv();
        assert!(csv.starts_with("elapsed_secs,iter,updates,objective,nnz\n"));
        assert!(csv.contains("0.500000,3,12,0.250000000,7\n"));
    }
}
