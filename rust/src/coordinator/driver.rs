//! High-level entry point: config -> dataset -> preprocessing -> solve.
//!
//! This is what the `gencd` binary and the bench harness call. It owns
//! the *config-shaped* surface — dataset resolution, TOML/CLI names,
//! result packaging — and routes everything through the typed
//! [`Solver`](crate::solver::Solver) builder underneath, so the two
//! surfaces cannot drift apart. Library users should go to
//! [`crate::solver::SolverBuilder`] directly.

use super::algorithms::Algorithm;
use super::convergence::{History, StopReason};
use super::engine::{BlockProposer, UpdatePath};
use super::metrics::MetricsSnapshot;
use crate::coloring::Strategy;
use crate::config::{Backend, RunConfig};
use crate::data;
use crate::event::StructuredLog;
use crate::loss;
use crate::net::Transport;
use crate::shard::ShardStrategy;
use crate::solver::Solver;
use crate::sparse::io::Dataset;
use crate::util::Timer;

/// Everything a run produces (the unit of the bench harness).
pub struct SolveResult {
    pub algorithm: Algorithm,
    pub w: Vec<f64>,
    pub objective: f64,
    pub nnz: usize,
    pub history: History,
    pub metrics: MetricsSnapshot,
    pub stop: StopReason,
    pub elapsed_secs: f64,
    /// Preprocessing outputs (Table 3 columns).
    pub pstar: Option<usize>,
    pub rho: Option<f64>,
    pub coloring_colors: Option<usize>,
    pub coloring_mean_size: Option<f64>,
    pub coloring_secs: Option<f64>,
    pub preprocess_secs: f64,
    pub dataset: String,
    /// Structured event-log lines, collected when
    /// `solver.log_format = "json"` attaches a [`StructuredLog`]
    /// subscriber to the solve. Empty under the default text format.
    pub event_log: Vec<String>,
}

/// Load (or generate) the dataset a config names.
pub fn load_dataset(cfg: &RunConfig) -> anyhow::Result<Dataset> {
    let mut ds = match &cfg.dataset.path {
        Some(path) if path.ends_with(".bin") => {
            crate::sparse::io::read_binary(std::path::Path::new(path))?
        }
        Some(path) => {
            let f = std::fs::File::open(path)
                .map_err(|e| anyhow::anyhow!("opening {path}: {e}"))?;
            crate::sparse::io::read_libsvm(f, None)?
        }
        None => data::by_name(&cfg.dataset.name)?,
    };
    if cfg.dataset.normalize {
        ds.x.normalize_columns();
    }
    Ok(ds)
}

/// Run a full experiment described by `cfg`.
pub fn run(cfg: &RunConfig) -> anyhow::Result<SolveResult> {
    // load raw and let run_on apply cfg.dataset.normalize exactly once:
    // normalize_columns is only idempotent up to ulps, and the builder
    // path (and the bit-exactness tests) normalize a single time
    let mut raw = cfg.clone();
    raw.dataset.normalize = false;
    let ds = load_dataset(&raw)?;
    run_on(cfg, ds, None)
}

/// Run on an already-loaded dataset (bench harness reuses datasets
/// across algorithms). Applies `cfg.dataset.normalize` — pass raw data,
/// or set the flag to false for pre-normalized data (normalization is
/// only idempotent up to ulps, which matters for bit-exact
/// comparisons). `block_proposer` overrides the Propose backend.
pub fn run_on(
    cfg: &RunConfig,
    mut ds: Dataset,
    block_proposer: Option<&mut dyn BlockProposer>,
) -> anyhow::Result<SolveResult> {
    if cfg.dataset.normalize {
        ds.x.normalize_columns();
    }
    anyhow::ensure!(
        !(cfg.solver.backend == Backend::DenseBlockHlo && block_proposer.is_none()),
        "backend=hlo requires a block proposer (runtime::propose_backend) — \
         use gencd::runtime::HloProposer::from_manifest"
    );
    anyhow::ensure!(
        !(cfg.solver.shards > 1 && block_proposer.is_some()),
        "backend=hlo binds to a single engine pool — set solver.shards = 1"
    );

    let alg: Algorithm = cfg.solver.algorithm.parse()?;
    let strategy = Strategy::by_name(&cfg.solver.coloring_strategy)?;
    let shard_strategy = ShardStrategy::by_name(&cfg.solver.shard_strategy)?;
    let loss = loss::by_name(&cfg.problem.loss)?;
    let update_path = UpdatePath::by_name(&cfg.solver.update_path)?;
    let kernel = crate::kernel::KernelChoice::by_name(&cfg.solver.kernel)?;
    let transport = Transport::from_config(
        &cfg.solver.transport,
        &cfg.solver.listen,
        &cfg.solver.peers,
        &cfg.solver.wire_precision,
    )
    .ok_or_else(|| {
        anyhow::anyhow!(
            "unknown solver.transport '{}' / wire_precision '{}' \
             (barrier|loopback|tcp, exact|f32)",
            cfg.solver.transport,
            cfg.solver.wire_precision
        )
    })?;
    let dataset_name = ds.name.clone();
    anyhow::ensure!(
        matches!(cfg.solver.log_format.as_str(), "text" | "json"),
        "unknown solver.log_format '{}' (text|json)",
        cfg.solver.log_format
    );
    // json attaches the structured-log subscriber; the default "text"
    // keeps the solve on the statically-dispatched no-op sink (zero
    // emit cost — the observability surface costs nothing unasked)
    let event_log = (cfg.solver.log_format == "json").then(StructuredLog::json);

    // build() runs the algorithm's preprocessing (spectral P*,
    // coloring) and validates the full combination — e.g.
    // conflict-free updates without a coloring are rejected here.
    let pre_timer = Timer::start();
    let mut builder = Solver::builder()
        .dataset(ds)
        .normalize(false) // applied above, per cfg.dataset.normalize
        .boxed_loss(loss)
        .lambda(cfg.problem.lam)
        .algorithm(alg)
        .threads(cfg.solver.threads)
        .seed(cfg.solver.seed)
        .select_size(cfg.solver.select_size)
        .accept_k(cfg.solver.accept_k)
        .line_search_steps(cfg.solver.line_search_steps)
        .max_iters(cfg.solver.max_iters)
        .max_seconds(cfg.solver.max_seconds)
        .tol(cfg.solver.tol)
        .log_every(cfg.solver.log_every)
        .coloring_strategy(strategy)
        .update_path(update_path)
        .buffer_budget_mb(cfg.solver.buffer_budget_mb)
        .shards(cfg.solver.shards)
        .shard_strategy(shard_strategy)
        .numa_pin(cfg.solver.numa_pin)
        .reconcile_every(cfg.solver.reconcile_every)
        .reconcile_max_rounds(cfg.solver.reconcile_max_rounds)
        .max_staleness_rounds(cfg.solver.max_staleness_rounds)
        .barrier_timeout_secs(cfg.solver.barrier_timeout_secs)
        .transport(transport)
        .screening(cfg.solver.screening)
        .kkt_every(cfg.solver.kkt_every)
        .kkt_adaptive(cfg.solver.kkt_adaptive)
        .fast_kernels(cfg.solver.fast_kernels)
        .kernel(kernel)
        .reconnect_max_attempts(cfg.solver.reconnect_max_attempts);
    if !cfg.solver.checkpoint_path.is_empty() {
        builder = builder
            .checkpoint_path(cfg.solver.checkpoint_path.clone())
            .checkpoint_every_rounds(cfg.solver.checkpoint_every_rounds);
    }
    if !cfg.solver.resume_from.is_empty() {
        builder = builder.resume_from(cfg.solver.resume_from.clone());
    }
    if let Some(log) = &event_log {
        builder = builder.subscriber(log.clone());
    }
    let solver = builder.build()?;
    let preprocess_secs = pre_timer.elapsed_secs();

    let pre = solver.preprocessing();
    let (pstar, rho) = (pre.pstar, pre.rho);
    let (coloring_colors, coloring_mean_size, coloring_secs) = match &pre.coloring {
        Some(c) => (
            Some(c.n_colors()),
            Some(c.mean_class_size()),
            Some(c.elapsed_secs),
        ),
        None => (None, None, None),
    };

    let out = solver.solve_with(block_proposer);

    let result = SolveResult {
        algorithm: alg,
        w: out.w,
        objective: out.objective,
        nnz: out.nnz,
        history: out.history,
        metrics: out.metrics,
        stop: out.stop,
        elapsed_secs: out.elapsed_secs,
        pstar,
        rho,
        coloring_colors,
        coloring_mean_size,
        coloring_secs,
        preprocess_secs,
        dataset: dataset_name,
        event_log: event_log.map(|log| log.lines()).unwrap_or_default(),
    };

    if let Some(csv) = &cfg.csv {
        std::fs::write(csv, result.history.to_csv())
            .map_err(|e| anyhow::anyhow!("writing {csv}: {e}"))?;
    }
    Ok(result)
}

impl SolveResult {
    /// One-line summary (CLI output).
    pub fn summary(&self) -> String {
        format!(
            "{:>13} | obj {:.6} | nnz {:>6} | updates {:>9} ({:.2e}/s) | iters {:>7} | {:>6.2}s | stop {}",
            self.algorithm.name(),
            self.objective,
            self.nnz,
            self.metrics.updates,
            self.metrics.updates_per_sec(self.elapsed_secs),
            self.metrics.iterations,
            self.elapsed_secs,
            self.stop,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_cfg(alg: &str) -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.dataset.name = "dorothea@0.02".into();
        cfg.problem.lam = 1e-3;
        cfg.solver.algorithm = alg.into();
        cfg.solver.threads = 2;
        cfg.solver.max_iters = 120;
        cfg.solver.max_seconds = 15.0;
        cfg
    }

    #[test]
    fn all_paper_algorithms_descend_on_dorothea_twin() {
        for alg in ["shotgun", "thread-greedy", "greedy", "coloring"] {
            let res = run(&base_cfg(alg)).unwrap();
            let first = res.history.records.first().unwrap().objective;
            assert!(
                res.objective < first,
                "{alg}: {} -> {}",
                first,
                res.objective
            );
            assert!(res.metrics.updates > 0, "{alg} made no updates");
        }
    }

    #[test]
    fn preprocessing_surfaced_in_result() {
        let res = run(&base_cfg("shotgun")).unwrap();
        assert!(res.pstar.unwrap() >= 1);
        assert!(res.rho.unwrap() > 0.0);
        let res = run(&base_cfg("coloring")).unwrap();
        assert!(res.coloring_colors.unwrap() >= 1);
        assert!(res.coloring_mean_size.unwrap() >= 1.0);
    }

    #[test]
    fn csv_written() {
        let dir = std::env::temp_dir().join("gencd_driver_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("hist.csv");
        let mut cfg = base_cfg("scd");
        cfg.solver.max_iters = 30;
        cfg.csv = Some(path.to_string_lossy().into_owned());
        run(&cfg).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("elapsed_secs,"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn hlo_backend_without_proposer_errors() {
        let mut cfg = base_cfg("shotgun");
        cfg.solver.backend = Backend::DenseBlockHlo;
        assert!(run(&cfg).is_err());
    }

    #[test]
    fn unknown_algorithm_errors() {
        let cfg = base_cfg("adam");
        assert!(run(&cfg).is_err());
    }

    #[test]
    fn sharded_config_flows_through() {
        for strategy in ["contiguous", "round-robin", "min-overlap"] {
            let mut cfg = base_cfg("shotgun");
            cfg.solver.shards = 2;
            cfg.solver.shard_strategy = strategy.into();
            let res = run(&cfg).unwrap();
            let first = res.history.records.first().unwrap().objective;
            assert!(
                res.objective < first,
                "{strategy}: {} -> {}",
                first,
                res.objective
            );
            assert_eq!(res.metrics.shards, 2, "{strategy}");
        }
        let mut cfg = base_cfg("shotgun");
        cfg.solver.shards = 2;
        cfg.solver.shard_strategy = "voronoi".into();
        assert!(run(&cfg).is_err(), "unknown strategy must be rejected");
    }

    #[test]
    fn numa_and_cadence_knobs_flow_through() {
        let mut cfg = base_cfg("shotgun");
        cfg.solver.shards = 2;
        cfg.solver.numa_pin = true;
        cfg.solver.reconcile_every = 2;
        cfg.solver.reconcile_max_rounds = 8;
        let res = run(&cfg).unwrap();
        assert_eq!(res.metrics.shards, 2);
        assert!(res.metrics.numa_nodes >= 1, "numa_pin must at least warn");
        assert!(
            res.metrics.reconcile_rounds_skipped > 0,
            "reconcile_every = 2 must skip rounds"
        );
        // inverted cadence window is refused by the builder
        let mut cfg = base_cfg("shotgun");
        cfg.solver.reconcile_every = 8;
        cfg.solver.reconcile_max_rounds = 2;
        assert!(run(&cfg).is_err());
    }

    #[test]
    fn loopback_transport_flows_through() {
        let mut cfg = base_cfg("shotgun");
        cfg.solver.shards = 2;
        cfg.solver.transport = "loopback".into();
        let res = run(&cfg).unwrap();
        assert_eq!(res.metrics.shards, 2);
        assert!(
            res.metrics.wire_bytes_tx > 0,
            "loopback must route reconciles through the codec"
        );
        let mut cfg = base_cfg("shotgun");
        cfg.solver.transport = "udp".into();
        assert!(run(&cfg).is_err(), "unknown transport must be rejected");
    }

    #[test]
    fn json_log_format_collects_event_lines() {
        let mut cfg = base_cfg("shotgun");
        cfg.solver.max_iters = 40;
        cfg.solver.log_format = "json".into();
        let res = run(&cfg).unwrap();
        assert!(!res.event_log.is_empty(), "json log format must collect lines");
        assert!(res.event_log.iter().all(|l| l.starts_with('{')));
        let report = crate::event::check::check_lines(
            res.event_log.iter().map(|s| s.as_str()),
        )
        .unwrap();
        crate::event::check::verify_coverage(&report).unwrap();
        // default text format stays silent; unknown formats are refused
        let res = run(&base_cfg("shotgun")).unwrap();
        assert!(res.event_log.is_empty());
        let mut cfg = base_cfg("shotgun");
        cfg.solver.log_format = "yaml".into();
        assert!(run(&cfg).is_err());
    }

    #[test]
    fn conflict_free_validation_flows_from_builder() {
        // the builder's validation backs the config surface: a racy
        // conflict-free combination is refused, coloring is allowed
        let mut cfg = base_cfg("shotgun");
        cfg.solver.update_path = "conflict-free".into();
        assert!(run(&cfg).is_err());
        let mut cfg = base_cfg("coloring");
        cfg.solver.update_path = "conflict-free".into();
        assert!(run(&cfg).is_ok());
    }
}
