//! Step one: Select (Sec. 2.1) — which coordinates get proposals this
//! iteration.
//!
//! The policies cover the paper's spectrum: singletons (CCD/SCD), random
//! subsets of a given size (SHOTGUN, THREAD-GREEDY), everything (GREEDY,
//! "full greedy"), one color class (COLORING), and the §7 "soft
//! coloring" extension (per-block random subsets sized by a per-block
//! P*).

use crate::coloring::Coloring;
use crate::util::Pcg64;

/// A selection policy. Stateful (cyclic pointer, RNG) and owned by the
/// leader thread; `select` fills `out` with the iteration's J.
pub enum Selector {
    /// Deterministic single coordinate: 0, 1, 2, … (CCD).
    Cyclic { next: usize, k: usize },
    /// Uniform random single coordinate (SCD).
    Stochastic { rng: Pcg64, k: usize },
    /// Uniform random subset of fixed size without replacement
    /// (SHOTGUN with size = P*, THREAD-GREEDY with size = threads * c).
    RandomSubset { rng: Pcg64, k: usize, size: usize },
    /// All coordinates (GREEDY / full greedy).
    All { k: usize },
    /// A uniformly random color class (COLORING).
    RandomColor { rng: Pcg64, coloring: Coloring },
    /// §7 extension: partition into `blocks` contiguous column blocks,
    /// select an independent random subset of `per_block` from each.
    BlockSubset {
        rng: Pcg64,
        k: usize,
        blocks: usize,
        per_block: Vec<usize>,
    },
}

impl Selector {
    /// Fill `out` with this iteration's selected coordinate set J.
    pub fn select(&mut self, out: &mut Vec<u32>) {
        out.clear();
        match self {
            Selector::Cyclic { next, k } => {
                out.push(*next as u32);
                *next = (*next + 1) % *k;
            }
            Selector::Stochastic { rng, k } => {
                out.push(rng.below(*k) as u32);
            }
            Selector::RandomSubset { rng, k, size } => {
                let size = (*size).min(*k);
                if size * 4 >= *k {
                    // dense regime: shuffle a prefix
                    let mut all: Vec<u32> = (0..*k as u32).collect();
                    for i in 0..size {
                        let j = i + rng.below(*k - i);
                        all.swap(i, j);
                        out.push(all[i]);
                    }
                } else if size <= 64 {
                    // small regime: quadratic rejection into `out` —
                    // allocation-free (§Perf: this runs every iteration
                    // of SHOTGUN, whose P* is often tiny)
                    while out.len() < size {
                        let j = rng.below(*k) as u32;
                        if !out.contains(&j) {
                            out.push(j);
                        }
                    }
                } else {
                    for j in rng.sample_distinct(*k, size) {
                        out.push(j as u32);
                    }
                }
            }
            Selector::All { k } => {
                out.extend(0..*k as u32);
            }
            Selector::RandomColor { rng, coloring } => {
                let c = rng.below(coloring.n_colors());
                out.extend_from_slice(&coloring.classes[c]);
            }
            Selector::BlockSubset {
                rng,
                k,
                blocks,
                per_block,
            } => {
                let bsize = (*k + *blocks - 1) / *blocks;
                for b in 0..*blocks {
                    let lo = b * bsize;
                    let hi = ((b + 1) * bsize).min(*k);
                    if lo >= hi {
                        break;
                    }
                    let m = per_block[b].min(hi - lo);
                    for idx in rng.sample_distinct(hi - lo, m) {
                        out.push((lo + idx) as u32);
                    }
                }
            }
        }
    }

    /// Expected |J| per iteration (sizing hints for metrics/benches).
    pub fn expected_size(&self) -> f64 {
        match self {
            Selector::Cyclic { .. } | Selector::Stochastic { .. } => 1.0,
            Selector::RandomSubset { size, k, .. } => (*size).min(*k) as f64,
            Selector::All { k } => *k as f64,
            Selector::RandomColor { coloring, .. } => coloring.mean_class_size(),
            Selector::BlockSubset { per_block, .. } => {
                per_block.iter().sum::<usize>() as f64
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::{color_features, Strategy};
    use crate::sparse::CooBuilder;

    #[test]
    fn cyclic_wraps() {
        let mut s = Selector::Cyclic { next: 0, k: 3 };
        let mut out = Vec::new();
        let seen: Vec<u32> = (0..7)
            .map(|_| {
                s.select(&mut out);
                out[0]
            })
            .collect();
        assert_eq!(seen, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn stochastic_in_range() {
        let mut s = Selector::Stochastic {
            rng: Pcg64::seeded(1),
            k: 5,
        };
        let mut out = Vec::new();
        let mut hit = [false; 5];
        for _ in 0..200 {
            s.select(&mut out);
            assert_eq!(out.len(), 1);
            hit[out[0] as usize] = true;
        }
        assert!(hit.iter().all(|&h| h), "all coordinates eventually chosen");
    }

    #[test]
    fn random_subset_distinct_and_sized() {
        for size in [1usize, 5, 20, 99, 200] {
            let mut s = Selector::RandomSubset {
                rng: Pcg64::seeded(2),
                k: 100,
                size,
            };
            let mut out = Vec::new();
            s.select(&mut out);
            assert_eq!(out.len(), size.min(100));
            let set: std::collections::HashSet<_> = out.iter().collect();
            assert_eq!(set.len(), out.len(), "size={size} must be distinct");
            assert!(out.iter().all(|&j| j < 100));
        }
    }

    #[test]
    fn all_selects_everything() {
        let mut s = Selector::All { k: 7 };
        let mut out = Vec::new();
        s.select(&mut out);
        assert_eq!(out, (0..7).collect::<Vec<u32>>());
    }

    #[test]
    fn random_color_selects_whole_class() {
        let mut b = CooBuilder::new(4, 6);
        for j in 0..6 {
            b.push(j % 4, j, 1.0);
        }
        let m = b.build();
        let coloring = color_features(&m, Strategy::Greedy, 1);
        let classes = coloring.classes.clone();
        let mut s = Selector::RandomColor {
            rng: Pcg64::seeded(3),
            coloring,
        };
        let mut out = Vec::new();
        for _ in 0..20 {
            s.select(&mut out);
            assert!(
                classes.iter().any(|c| c == &out),
                "selection {out:?} must equal one color class"
            );
        }
    }

    #[test]
    fn block_subset_respects_blocks() {
        let mut s = Selector::BlockSubset {
            rng: Pcg64::seeded(4),
            k: 100,
            blocks: 4,
            per_block: vec![2, 3, 1, 4],
        };
        let mut out = Vec::new();
        s.select(&mut out);
        assert_eq!(out.len(), 10);
        // count selections per 25-wide block
        let mut counts = [0usize; 4];
        for &j in &out {
            counts[(j as usize) / 25] += 1;
        }
        assert_eq!(counts, [2, 3, 1, 4]);
    }

    #[test]
    fn expected_sizes() {
        assert_eq!(Selector::All { k: 9 }.expected_size(), 9.0);
        assert_eq!(
            Selector::RandomSubset {
                rng: Pcg64::seeded(1),
                k: 10,
                size: 25
            }
            .expected_size(),
            10.0
        );
    }
}
