//! Step one: Select (Sec. 2.1) — which coordinates get proposals this
//! iteration.
//!
//! Selection is an *open* extension point: [`Select`] is an object-safe
//! trait, and the paper's policies — singletons (CCD/SCD), random
//! subsets of a given size (SHOTGUN, THREAD-GREEDY), everything (GREEDY,
//! "full greedy"), one color class (COLORING), and the §7 "soft
//! coloring" extension (per-block random subsets sized by a per-block
//! P*) — are plain implementations of it, constructible either as
//! structs ([`Cyclic`], [`RandomSubset`], …) or through the boxed
//! constructor functions ([`cyclic`], [`random_subset`], …) that the
//! [`Algorithm`](super::algorithms::Algorithm) preset catalogue and
//! [`SolverBuilder`](crate::solver::SolverBuilder) use. Implement the
//! trait yourself to plug a new policy (feature clustering, importance
//! sampling, …) into the engine without touching this crate.

use crate::coloring::Coloring;
use crate::util::Pcg64;

/// RNG stream id shared by every stochastic built-in policy. The boxed
/// constructors seed their [`Pcg64`] as `Pcg64::new(seed, POLICY_STREAM)`,
/// which is also what [`super::algorithms::instantiate`] has always done
/// — so a hand-built policy with the same seed reproduces a preset's
/// selection sequence bit-exactly.
pub const POLICY_STREAM: u64 = 0xA160;

/// A selection policy: fills `out` with the iteration's coordinate set
/// `J`.
///
/// # Contract
///
/// * `select` is called exactly once per iteration, on the leader
///   thread, while the workers are parked at a barrier — implementations
///   may be freely stateful (cyclic pointers, RNGs, adaptive scores) and
///   need no internal synchronization. `Send` is required so a built
///   solver can be moved to another thread before running. Exception:
///   with screening enabled the engine wraps policies in
///   [`ScreenedSelect`](crate::screen::ScreenedSelect), which may call
///   the inner `select` several times (redraws over the active set) or
///   zero times (convergence-gate iterations) per engine iteration —
///   see its docs before relying on call-per-iteration state.
/// * The selection should be duplicate-free; the engine additionally
///   collapses repeats (first occurrence wins) before Propose, so a
///   sloppy custom policy degrades performance but not correctness.
/// * Every index must be `< k` (the number of features). Out-of-range
///   indices panic in the engine.
pub trait Select: Send {
    /// Fill `out` with this iteration's selected coordinate set J.
    /// The engine clears `out` before every call — implementations
    /// append only (the single owner of that invariant is the engine's
    /// plan step, not the policies).
    fn select(&mut self, out: &mut Vec<u32>);

    /// Expected |J| per iteration — a *sizing hint* used by the engine's
    /// buffered-update heuristic and by metrics/benches. An estimate is
    /// fine; it never affects correctness.
    fn expected_size(&self) -> f64;

    /// Human-readable policy name (logs and summaries). `String` so
    /// parameterized policies can include their sizing (mirrors
    /// [`Accept::name`](super::accept::Accept::name)).
    fn name(&self) -> String {
        "custom".into()
    }
}

impl<S: Select + ?Sized> Select for Box<S> {
    fn select(&mut self, out: &mut Vec<u32>) {
        (**self).select(out)
    }
    fn expected_size(&self) -> f64 {
        (**self).expected_size()
    }
    fn name(&self) -> String {
        (**self).name()
    }
}

/// Deterministic single coordinate: 0, 1, 2, … (CCD).
pub struct Cyclic {
    pub next: usize,
    pub k: usize,
}

impl Select for Cyclic {
    fn select(&mut self, out: &mut Vec<u32>) {
        out.push(self.next as u32);
        self.next = (self.next + 1) % self.k;
    }

    fn expected_size(&self) -> f64 {
        1.0
    }

    fn name(&self) -> String {
        "cyclic".into()
    }
}

/// Uniform random single coordinate (SCD).
pub struct Stochastic {
    pub rng: Pcg64,
    pub k: usize,
}

impl Select for Stochastic {
    fn select(&mut self, out: &mut Vec<u32>) {
        out.push(self.rng.below(self.k) as u32);
    }

    fn expected_size(&self) -> f64 {
        1.0
    }

    fn name(&self) -> String {
        "stochastic".into()
    }
}

/// Uniform random subset of fixed size without replacement (SHOTGUN
/// with size = P*, THREAD-GREEDY with size = threads * c).
pub struct RandomSubset {
    pub rng: Pcg64,
    pub k: usize,
    pub size: usize,
}

impl Select for RandomSubset {
    fn select(&mut self, out: &mut Vec<u32>) {
        debug_assert!(out.is_empty(), "engine clears the selection buffer");
        let k = self.k;
        let size = self.size.min(k);
        if size * 4 >= k {
            // dense regime: shuffle a prefix
            let mut all: Vec<u32> = (0..k as u32).collect();
            for i in 0..size {
                let j = i + self.rng.below(k - i);
                all.swap(i, j);
                out.push(all[i]);
            }
        } else if size <= 64 {
            // small regime: quadratic rejection into `out` —
            // allocation-free (§Perf: this runs every iteration
            // of SHOTGUN, whose P* is often tiny)
            while out.len() < size {
                let j = self.rng.below(k) as u32;
                if !out.contains(&j) {
                    out.push(j);
                }
            }
        } else {
            for j in self.rng.sample_distinct(k, size) {
                out.push(j as u32);
            }
        }
    }

    fn expected_size(&self) -> f64 {
        self.size.min(self.k) as f64
    }

    fn name(&self) -> String {
        "random-subset".into()
    }
}

/// All coordinates (GREEDY / full greedy).
pub struct FullSet {
    pub k: usize,
}

impl Select for FullSet {
    fn select(&mut self, out: &mut Vec<u32>) {
        out.extend(0..self.k as u32);
    }

    fn expected_size(&self) -> f64 {
        self.k as f64
    }

    fn name(&self) -> String {
        "all".into()
    }
}

/// A uniformly random color class (COLORING).
pub struct RandomColor {
    pub rng: Pcg64,
    pub coloring: Coloring,
}

impl Select for RandomColor {
    fn select(&mut self, out: &mut Vec<u32>) {
        let c = self.rng.below(self.coloring.n_colors());
        out.extend_from_slice(&self.coloring.classes[c]);
    }

    fn expected_size(&self) -> f64 {
        self.coloring.mean_class_size()
    }

    fn name(&self) -> String {
        "random-color".into()
    }
}

/// §7 extension: partition into `blocks` contiguous column blocks,
/// select an independent random subset of `per_block` from each.
pub struct BlockSubset {
    pub rng: Pcg64,
    pub k: usize,
    pub blocks: usize,
    pub per_block: Vec<usize>,
}

impl Select for BlockSubset {
    fn select(&mut self, out: &mut Vec<u32>) {
        let bsize = (self.k + self.blocks - 1) / self.blocks;
        for b in 0..self.blocks {
            let lo = b * bsize;
            let hi = ((b + 1) * bsize).min(self.k);
            if lo >= hi {
                break;
            }
            let m = self.per_block[b].min(hi - lo);
            for idx in self.rng.sample_distinct(hi - lo, m) {
                out.push((lo + idx) as u32);
            }
        }
    }

    fn expected_size(&self) -> f64 {
        self.per_block.iter().sum::<usize>() as f64
    }

    fn name(&self) -> String {
        "block-subset".into()
    }
}

fn policy_rng(seed: u64) -> Pcg64 {
    Pcg64::new(seed, POLICY_STREAM)
}

/// CCD selection over `k` coordinates.
pub fn cyclic(k: usize) -> Box<dyn Select> {
    Box::new(Cyclic { next: 0, k })
}

/// SCD selection over `k` coordinates.
pub fn stochastic(k: usize, seed: u64) -> Box<dyn Select> {
    Box::new(Stochastic {
        rng: policy_rng(seed),
        k,
    })
}

/// SHOTGUN-style random subset of `size` out of `k`.
pub fn random_subset(k: usize, size: usize, seed: u64) -> Box<dyn Select> {
    Box::new(RandomSubset {
        rng: policy_rng(seed),
        k,
        size,
    })
}

/// GREEDY's full selection of all `k` coordinates.
pub fn full_set(k: usize) -> Box<dyn Select> {
    Box::new(FullSet { k })
}

/// COLORING's random-color-class selection.
pub fn random_color(coloring: Coloring, seed: u64) -> Box<dyn Select> {
    Box::new(RandomColor {
        rng: policy_rng(seed),
        coloring,
    })
}

/// BLOCK-SHOTGUN's per-block random subsets.
pub fn block_subset(
    k: usize,
    blocks: usize,
    per_block: Vec<usize>,
    seed: u64,
) -> Box<dyn Select> {
    Box::new(BlockSubset {
        rng: policy_rng(seed),
        k,
        blocks,
        per_block,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coloring::{color_features, Strategy};
    use crate::sparse::CooBuilder;

    #[test]
    fn cyclic_wraps() {
        let mut s = Cyclic { next: 0, k: 3 };
        let mut out = Vec::new();
        let seen: Vec<u32> = (0..7)
            .map(|_| {
                out.clear();
                s.select(&mut out);
                out[0]
            })
            .collect();
        assert_eq!(seen, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn stochastic_in_range() {
        let mut s = Stochastic {
            rng: Pcg64::seeded(1),
            k: 5,
        };
        let mut out = Vec::new();
        let mut hit = [false; 5];
        for _ in 0..200 {
            out.clear();
            s.select(&mut out);
            assert_eq!(out.len(), 1);
            hit[out[0] as usize] = true;
        }
        assert!(hit.iter().all(|&h| h), "all coordinates eventually chosen");
    }

    #[test]
    fn random_subset_distinct_and_sized() {
        for size in [1usize, 5, 20, 99, 200] {
            let mut s = RandomSubset {
                rng: Pcg64::seeded(2),
                k: 100,
                size,
            };
            let mut out = Vec::new();
            s.select(&mut out);
            assert_eq!(out.len(), size.min(100));
            let set: std::collections::HashSet<_> = out.iter().collect();
            assert_eq!(set.len(), out.len(), "size={size} must be distinct");
            assert!(out.iter().all(|&j| j < 100));
        }
    }

    #[test]
    fn all_selects_everything() {
        let mut s = FullSet { k: 7 };
        let mut out = Vec::new();
        s.select(&mut out);
        assert_eq!(out, (0..7).collect::<Vec<u32>>());
    }

    #[test]
    fn random_color_selects_whole_class() {
        let mut b = CooBuilder::new(4, 6);
        for j in 0..6 {
            b.push(j % 4, j, 1.0);
        }
        let m = b.build();
        let coloring = color_features(&m, Strategy::Greedy, 1);
        let classes = coloring.classes.clone();
        let mut s = RandomColor {
            rng: Pcg64::seeded(3),
            coloring,
        };
        let mut out = Vec::new();
        for _ in 0..20 {
            out.clear();
            s.select(&mut out);
            assert!(
                classes.iter().any(|c| c == &out),
                "selection {out:?} must equal one color class"
            );
        }
    }

    #[test]
    fn block_subset_respects_blocks() {
        let mut s = BlockSubset {
            rng: Pcg64::seeded(4),
            k: 100,
            blocks: 4,
            per_block: vec![2, 3, 1, 4],
        };
        let mut out = Vec::new();
        s.select(&mut out);
        assert_eq!(out.len(), 10);
        // count selections per 25-wide block
        let mut counts = [0usize; 4];
        for &j in &out {
            counts[(j as usize) / 25] += 1;
        }
        assert_eq!(counts, [2, 3, 1, 4]);
    }

    #[test]
    fn expected_sizes() {
        assert_eq!(FullSet { k: 9 }.expected_size(), 9.0);
        assert_eq!(
            RandomSubset {
                rng: Pcg64::seeded(1),
                k: 10,
                size: 25
            }
            .expected_size(),
            10.0
        );
    }

    #[test]
    fn boxed_constructors_match_struct_policies() {
        // the boxed constructors must replay the exact stream of the
        // struct form seeded through POLICY_STREAM (the bit-exactness
        // contract that lets external code reproduce presets)
        let mut boxed = random_subset(200, 9, 42);
        let mut plain = RandomSubset {
            rng: Pcg64::new(42, POLICY_STREAM),
            k: 200,
            size: 9,
        };
        let (mut a, mut b) = (Vec::new(), Vec::new());
        for _ in 0..50 {
            a.clear();
            b.clear();
            boxed.select(&mut a);
            plain.select(&mut b);
            assert_eq!(a, b);
        }
        assert_eq!(boxed.name(), "random-subset");
    }

    #[test]
    fn custom_policy_implements_trait() {
        // an out-of-crate-style custom policy: every third coordinate
        struct EveryThird {
            k: usize,
        }
        impl Select for EveryThird {
            fn select(&mut self, out: &mut Vec<u32>) {
                out.clear();
                out.extend((0..self.k as u32).step_by(3));
            }
            fn expected_size(&self) -> f64 {
                (self.k as f64 / 3.0).ceil()
            }
        }
        let mut s: Box<dyn Select> = Box::new(EveryThird { k: 10 });
        let mut out = Vec::new();
        s.select(&mut out);
        assert_eq!(out, vec![0, 3, 6, 9]);
        assert_eq!(s.name(), "custom");
    }
}
